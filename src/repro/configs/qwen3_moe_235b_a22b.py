"""qwen3-moe-235b-a22b — MoE, 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B
family scaled per assignment].

94 layers, d_model 4096, 64 heads (GQA kv=4, head_dim 128), expert
d_ff 1536, 128 experts top-8, vocab 151936.
"""
from repro.models.api import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=1536,
    vocab_size=151936,
    num_experts=128,
    top_k=8,
    capacity_factor=1.25,
    rope_theta=1e6,
    dtype="bfloat16",
    loss_chunk=512,
    source="Qwen3-MoE 235B-A22B [hf:Qwen/Qwen3-30B-A3B]",
)
