"""mamba2-2.7b — attention-free SSM (SSD) [arXiv:2405.21060].

64 layers, d_model 2560 (d_inner 5120 = 2×), ssm_state 128, head dim 64
(80 heads), vocab 50280.  ``long_500k`` runs natively: decode state is
O(1) in sequence length.
"""
from repro.models.api import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_head_dim=64,
    expand=2,
    conv_kernel=4,
    chunk=64,
    dtype="bfloat16",
    loss_chunk=512,
    source="Mamba-2 2.7B, SSD [arXiv:2405.21060]",
)
