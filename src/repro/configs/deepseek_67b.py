"""deepseek-67b — dense llama-arch decoder [arXiv:2401.02954].

95 layers, d_model 8192, 64 heads (GQA kv=8), d_ff 22016, vocab 102400.
"""
from repro.models.api import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b",
    family="dense",
    num_layers=95,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab_size=102400,
    rope_theta=1e4,
    dtype="bfloat16",
    loss_chunk=512,
    source="DeepSeek LLM 67B [arXiv:2401.02954]",
)
