"""The paper's MNIST experiment configuration (§5).

100 clients, 2 unique digits each, single-hidden-layer MLP (200 ReLU),
SGD lr 0.01 momentum 0.9, batch 42, 2 local epochs, K=2, α=0.9.
"""
from repro.core import ControllerConfig, FLConfig

N_CLIENTS = 100
TARGET_ACCURACY = 0.90  # paper Tab. 1 threshold (central model ≈ 93%)

def fl_config(algorithm="fedback", participation=0.1, **kw) -> FLConfig:
    return FLConfig(
        algorithm=algorithm,
        n_clients=kw.pop("n_clients", N_CLIENTS),
        participation=participation,
        rho=kw.pop("rho", 0.01),
        mu=kw.pop("mu", 0.01),
        lr=0.01,
        momentum=0.9,
        epochs=2,
        batch_size=42,
        controller=ControllerConfig(K=2.0, alpha=0.9),
        **kw,
    )
