"""The paper's CIFAR-10 experiment configuration (§5).

100 clients, Dirichlet(β=0.5) split, 3-conv/3-fc CNN, SGD lr 0.01
momentum 0.9, batch 20, 4 local epochs, K=5 (larger parameter space),
α=0.9.
"""
from repro.core import ControllerConfig, FLConfig

N_CLIENTS = 100
TARGET_ACCURACY = 0.78  # paper Tab. 1 threshold (central model ≈ 80%)
DIRICHLET_BETA = 0.5

def fl_config(algorithm="fedback", participation=0.1, **kw) -> FLConfig:
    return FLConfig(
        algorithm=algorithm,
        n_clients=kw.pop("n_clients", N_CLIENTS),
        participation=participation,
        rho=kw.pop("rho", 0.01),
        mu=kw.pop("mu", 0.01),
        lr=0.01,
        momentum=0.9,
        epochs=4,
        batch_size=20,
        controller=ControllerConfig(K=5.0, alpha=0.9),
        **kw,
    )
