"""zamba2-2.7b — hybrid: Mamba-2 backbone + shared attention block
[arXiv:2411.15242].

54 mamba layers (d_model 2560, ssm_state 64) with ONE shared
attention+MLP block (32 heads, kv=32, head_dim 80, d_ff 10240,
parameters re-used at every application) applied after every 6 mamba
layers.  vocab 32000.  For ``long_500k`` the shared attention runs with
a 4096 sliding window (the recurrent backbone carries long-range state;
see DESIGN §Arch-applicability).
"""
from repro.models.api import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab_size=32000,
    ssm_state=64,
    ssm_head_dim=64,
    expand=2,
    conv_kernel=4,
    chunk=64,
    attn_every=6,
    sliding_window=4096,
    dtype="bfloat16",
    loss_chunk=512,
    source="Zamba2 2.7B [arXiv:2411.15242]",
)
