"""hubert-xlarge — encoder-only audio transformer [arXiv:2106.07447].

48 layers, d_model 1280, 16 heads (kv=16, head_dim 80), d_ff 5120,
vocab 504 (masked-prediction codebook targets).  The conv waveform
feature extractor is a stub (assignment carve-out): ``input_specs``
provides precomputed 512-dim frame embeddings.  Encoder-only ⇒ no
decode shapes (noted in DESIGN §Arch-applicability).
"""
from repro.models.api import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504,
    frontend_dim=512,  # wav2vec2/HuBERT conv extractor output width
    encoder_only=True,
    dtype="bfloat16",
    loss_chunk=0,
    source="HuBERT X-Large [arXiv:2106.07447]; conv frontend stubbed",
)
