"""paligemma-3b — VLM: SigLIP patches + Gemma-2B decoder [arXiv:2407.07726].

Transformer backbone only (assignment carve-out): the SigLIP vision
tower is a stub — ``input_specs`` feeds 256 precomputed patch embeddings
(SigLIP-So400m width 1152) through a learned projector; the language
model is the Gemma-2B decoder (18L, d 2048, 8 heads / kv=1 (MQA),
head_dim 256, d_ff 16384, vocab 257216) with PaliGemma's prefix-LM mask
(bidirectional over image+prompt prefix, causal over the suffix).
"""
from repro.models.api import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    prefix_tokens=256,  # 224/14 = 16×16 SigLIP patches
    frontend_dim=1152,  # SigLIP-So400m embedding width
    rope_theta=1e4,
    dtype="bfloat16",
    loss_chunk=512,
    source="PaliGemma [arXiv:2407.07726]; SigLIP frontend stubbed",
)
