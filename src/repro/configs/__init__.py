"""Architecture + workload-shape registry.

Every assigned architecture is a module ``<id>.py`` exporting CONFIG
(exact public-literature spec, source cited) — select with
``--arch <id>`` in the launchers.
"""
from __future__ import annotations

import importlib

from repro.models.api import ModelConfig

ARCHITECTURES = (
    "deepseek_67b",
    "paligemma_3b",
    "mamba2_2_7b",
    "zamba2_2_7b",
    "qwen3_moe_235b_a22b",
    "granite_3_2b",
    "moonshot_v1_16b_a3b",
    "mixtral_8x7b",
    "phi3_medium_14b",
    "hubert_xlarge",
)

# canonical ids as assigned (dashes) → module names (underscores)
_ALIASES = {a.replace("_", "-"): a for a in ARCHITECTURES}
_ALIASES["mamba2-2.7b"] = "mamba2_2_7b"
_ALIASES["zamba2-2.7b"] = "zamba2_2_7b"

# workload shapes: (mode, seq_len, global_batch)
INPUT_SHAPES = {
    "train_4k": ("train", 4_096, 256),
    "prefill_32k": ("prefill", 32_768, 32),
    "decode_32k": ("decode", 32_768, 128),
    "long_500k": ("decode", 524_288, 1),
}


def get_config(arch: str) -> ModelConfig:
    mod_name = _ALIASES.get(arch, arch.replace("-", "_").replace(".", "_"))
    if mod_name not in ARCHITECTURES and mod_name not in (
            "paper_mnist", "paper_cifar"):
        raise KeyError(f"unknown architecture {arch!r}; "
                       f"available: {sorted(_ALIASES)}")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def all_configs() -> dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCHITECTURES}


def shape_applicable(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) per the assignment's skip rules."""
    mode, seq, batch = INPUT_SHAPES[shape]
    if mode == "decode" and not cfg.supports_decode:
        return False, "encoder-only architecture: no autoregressive decode"
    if shape == "long_500k" and not cfg.subquadratic:
        return False, ("full-attention architecture without sliding-window "
                       "variant: long_500k requires sub-quadratic attention")
    return True, ""
