"""mixtral-8x7b — MoE, 8 experts top-2, sliding-window attention
[arXiv:2401.04088].

32 layers, d_model 4096, 32 heads (GQA kv=8, head_dim 128), expert
d_ff 14336, vocab 32000, sliding window 4096.  SWA makes ``long_500k``
eligible (O(W) attention per token, ring-buffer KV cache).
"""
from repro.models.api import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    num_experts=8,
    top_k=2,
    capacity_factor=1.25,
    sliding_window=4096,
    rope_theta=1e6,
    dtype="bfloat16",
    loss_chunk=1024,
    source="Mixtral 8x7B [arXiv:2401.04088]",
)
