"""phi3-medium-14b — dense decoder, RoPE + SwiGLU + GQA [arXiv:2404.14219].

40 layers, d_model 5120, 40 heads (GQA kv=10, head_dim 128), d_ff 17920,
vocab 100352.
"""
from repro.models.api import ModelConfig

CONFIG = ModelConfig(
    name="phi3-medium-14b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=10,
    head_dim=128,
    d_ff=17920,
    vocab_size=100352,
    rope_theta=1e4,
    dtype="bfloat16",
    loss_chunk=512,
    source="Phi-3 Medium [arXiv:2404.14219]",
)
