"""moonshot-v1-16b-a3b — MoE decoder (Moonlight)
[hf:moonshotai/Moonlight-16B-A3B].

48 layers, d_model 2048, 16 heads (kv=16, head_dim 128), expert d_ff
1408, 64 experts top-6, vocab 163840.  (Moonlight's dense first layer
and shared expert are folded into the uniform MoE stack — noted in
DESIGN §Arch-applicability.)
"""
from repro.models.api import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=163840,
    num_experts=64,
    top_k=6,
    capacity_factor=1.25,
    rope_theta=5e4,
    dtype="bfloat16",
    loss_chunk=1024,
    source="Moonlight 16B-A3B [hf:moonshotai/Moonlight-16B-A3B]",
)
