"""Sharding rules: param-tree path → PartitionSpec.

Three modes, selectable per run (and hillclimbed in EXPERIMENTS §Perf):

* ``fsdp`` (baseline) — every ≥2-D parameter is sharded over the
  ``model`` axis on its largest divisible dim and over ``data`` on the
  next largest divisible dim (ZeRO-3 style; XLA inserts per-layer
  all-gathers under the scan).  Robust for any architecture, memory-
  optimal, collective-heavy at decode.
* ``tp`` — Megatron-style named rules: attention heads / FFN hidden /
  MoE experts over ``model``; params *replicated* over ``data``.
  Weight-collective-free at decode (the right regime for serve_step).
* ``fsdp_tp`` — named ``model`` rules + ``data`` sharding on the
  largest remaining divisible dim (hybrid; train regime).

The leading layer axis of scanned stacks is never sharded (a sharded
scan axis would reshard every layer iteration).

GQA caveat: when num_kv_heads < |model|, wk/wv fall back to replicated
output dims (phi3 kv=10, paligemma kv=1) — recorded per-arch in the
roofline table.
"""
from __future__ import annotations

import numpy as np
import jax
from jax.sharding import PartitionSpec as P

# parameter leaves that live under these names form the scanned stacks
_STACKED_CONTAINERS = ("layers",)

# TP named rules: leaf name → (model-sharded dim, kind)
#   dim index is *within the logical param shape* (after any layer axis)
_TP_RULES = {
    # attention: shard head (output) dim of qkv, input dim of wo
    "wq": 1, "wk": 1, "wv": 1, "wo": 0,
    # dense mlp: hidden dim
    "w_gate": 1, "w_up": 1, "w_down": 0,
    # embeddings: vocab dim
    "embed": 0, "lm_head": 1,
    # ssm: inner dim
    "in_proj": 1, "out_proj": 0,
}
# under "moe", experts are stacked: (E, d, f) — shard E (expert parallel)
_TP_MOE_DIM = 0


def _path_names(path):
    names = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            names.append(str(p.key))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            names.append(p.name)
    return names


def _divisible(shape, dim, size):
    return dim < len(shape) and shape[dim] % size == 0 and shape[dim] >= size


def _fsdp_spec(shape, skip, data, model, data_size, model_size):
    """Largest-divisible-dims rule; `skip` dims stay unsharded."""
    spec = [None] * len(shape)
    order = sorted((d for d in range(len(shape)) if d not in skip),
                   key=lambda d: -shape[d])
    for d in order:
        if model and spec[d] is None and shape[d] % model_size == 0 \
                and shape[d] >= model_size:
            spec[d] = model
            model = None
        elif data and spec[d] is None and shape[d] % data_size == 0 \
                and shape[d] >= data_size:
            spec[d] = data
            data = None
    return spec


def param_specs(params_shape, mesh, *, mode="fsdp", data_axis="data",
                model_axis="model", pod_axis=None):
    """PartitionSpec pytree matching `params_shape` (shapes or arrays)."""
    data_size = mesh.shape[data_axis]
    model_size = mesh.shape[model_axis]

    def leaf_spec(path, leaf):
        names = _path_names(path)
        shape = tuple(leaf.shape)
        if not shape or all(s == 1 for s in shape):
            return P()
        stacked = any(c in names for c in _STACKED_CONTAINERS)
        off = 1 if stacked else 0
        skip = set(range(off))
        is_moe = "moe" in names
        name = names[-1] if names else ""
        if len(shape) - off < 2 and name not in ("embed", "lm_head"):
            return P()  # norms / small vectors: replicate

        if name in ("embed", "lm_head"):
            # Output-dim rule (§Perf hillclimb #3): shard the embedding
            # on d (gather stays local — vocab-sharded gathers forced a
            # GSPMD replicate-reshard under the pod-stacked layout) and
            # the head on vocab (Megatron vocab-parallel CE).  The
            # contraction/lookup dims stay unsharded in every mode.
            spec = [None] * len(shape)
            mdim = len(shape) - 1 if name == "embed" else len(shape) - 1
            if name == "embed":
                if _divisible(shape, len(shape) - 1, model_size):
                    spec[-1] = model_axis
            else:  # lm_head (d, V): vocab-parallel
                if _divisible(shape, len(shape) - 1, model_size):
                    spec[-1] = model_axis
            return P(*spec)

        if mode == "fsdp":
            spec = _fsdp_spec(shape, skip, data_axis, model_axis,
                              data_size, model_size)
            return P(*spec)

        # named model rules (tp / fsdp_tp)
        spec = [None] * len(shape)
        mdim = None
        if is_moe and name in ("w_gate", "w_up", "w_down"):
            # Output-dim-only sharding (§Perf hillclimb #2 conclusion):
            # gate/up (E,d,f) shard f; down (E,f,d) shard d — the LAST
            # dim in both cases, never a contraction dim, so no
            # partial-sum all-reduces of capacity buffers.  The data
            # axis ZeRO-shards the expert dim E when divisible (weights
            # all-gathered per layer, 1/|data| of the naive traffic).
            # Expert-parallelism (mode "ep") and intra-expert
            # row-parallel w_down both measured worse under GSPMD —
            # see EXPERIMENTS §Perf for the refuted iterations.
            if mode == "ep" and _divisible(shape, off + _TP_MOE_DIM,
                                           model_size):
                mdim = off + _TP_MOE_DIM
            else:
                mdim = len(shape) - 1
            if mdim is not None and _divisible(shape, mdim, model_size):
                spec[mdim] = model_axis
            if mode in ("fsdp_tp", "ep") and spec[off] is None and \
                    _divisible(shape, off, data_size):
                spec[off] = data_axis
            return P(*spec)
        elif name in _TP_RULES:
            mdim = off + _TP_RULES[name]
        if mdim is not None and _divisible(shape, mdim, model_size):
            spec[mdim] = model_axis
        elif mdim is not None:
            # fall back: try the other matmul dim (e.g. kv heads < |model|)
            alt = off + (1 - _TP_RULES.get(name, 0)) if not is_moe else None
            if alt is not None and _divisible(shape, alt, model_size):
                spec[alt] = model_axis
        if mode == "fsdp_tp":
            taken = {d for d, s in enumerate(spec) if s} | skip
            order = sorted((d for d in range(len(shape)) if d not in taken),
                           key=lambda d: -shape[d])
            for d in order:
                if shape[d] % data_size == 0 and shape[d] >= data_size:
                    spec[d] = data_axis
                    break
        return P(*spec)

    return jax.tree_util.tree_map_with_path(leaf_spec, params_shape)


def pod_stacked_specs(specs, pod_axis="pod"):
    """Prefix every spec with the pod axis (client-stacked state)."""
    return jax.tree.map(lambda s: P(pod_axis, *s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def batch_specs(batch_shape, *, batch_axes):
    """Shard the leading (batch) dim of every input leaf; rest replicated.

    batch_axes: axis name or tuple of axis names (e.g. ("pod", "data")).
    Leaves whose leading dim does not divide are replicated.
    """

    def leaf_spec(leaf):
        shape = tuple(leaf.shape)
        return P(batch_axes, *([None] * (len(shape) - 1))) if shape else P()

    return jax.tree.map(leaf_spec, batch_shape)


def cache_specs(cache_shape, mesh, *, batch_axes, model_axis="model"):
    """KV/SSM cache sharding: batch dim over `batch_axes`, head dim over
    `model` when divisible.  Cache layout: leading layer axis, then
    batch.  Scalars (pos) replicated."""
    sizes = np.prod([mesh.shape[a] for a in (
        batch_axes if isinstance(batch_axes, tuple) else (batch_axes,))])
    model_size = mesh.shape[model_axis]

    def leaf_spec(leaf):
        shape = tuple(leaf.shape)
        if len(shape) <= 1:
            return P()
        # (L, B, ...) — shard B if divisible, plus a heads-like dim
        spec = [None] * len(shape)
        if shape[1] % sizes == 0 and shape[1] >= sizes:
            spec[1] = batch_axes
        for d in range(2, len(shape)):
            if shape[d] % model_size == 0 and shape[d] >= model_size:
                spec[d] = model_axis
                break
        return P(*spec)

    return jax.tree.map(leaf_spec, cache_shape)
