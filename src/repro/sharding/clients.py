"""Client-axis device meshes and shardings for the simulation engine.

The FedBack simulation stacks every client quantity along a leading axis
of size N (``repro.core.state``).  These helpers lay that axis out over
a 1-D ``clients`` device mesh so the vmapped local solves run
embarrassingly parallel across devices, while the consensus mean and
any cross-client reductions lower to all-reduces — the same program
shape ``repro.core.crosspod`` uses for its ``pod`` axis.

All sharding trees returned here are *prefix* pytrees of
``NamedSharding``: a single sharding leaf stands for a whole state
subtree (jit's ``in_shardings``/``out_shardings`` and ``device_put``
both accept prefixes), so nothing needs the concrete leaf ranks.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

CLIENT_AXIS = "clients"


def make_client_mesh(n_devices: int | None = None, *,
                     axis: str = CLIENT_AXIS) -> Mesh:
    """1-D mesh over the first ``n_devices`` local devices (default all)."""
    devices = jax.devices()
    n = len(devices) if n_devices is None else n_devices
    if n > len(devices):
        raise RuntimeError(
            f"need {n} devices for a client mesh, found {len(devices)} — "
            "on CPU set XLA_FLAGS=--xla_force_host_platform_device_count=N "
            "before importing jax")
    return Mesh(np.asarray(devices[:n]), (axis,))


def check_divisible(n_clients: int, mesh: Mesh, *,
                    axis: str = CLIENT_AXIS) -> None:
    """Fail early (with the fix in the message) on uneven client shards."""
    size = mesh.shape[axis]
    if n_clients % size:
        raise ValueError(
            f"n_clients={n_clients} must be divisible by the '{axis}' mesh "
            f"axis size {size}; pick a dividing device count "
            f"(e.g. {max(d for d in range(1, size + 1) if n_clients % d == 0)})")


def _sharded(mesh, axis):
    return NamedSharding(mesh, P(axis))


def _replicated(mesh):
    return NamedSharding(mesh, P())


def fl_state_shardings(mesh: Mesh, *, axis: str = CLIENT_AXIS,
                       batched: bool = False):
    """Prefix-pytree of shardings for :class:`repro.core.state.FLState`.

    Client-stacked subtrees (θ, λ, z_prev, the deferral queue, the
    in-flight delay pipeline of the stale-tolerant engine and the
    per-client controller vectors) shard their leading axis over
    ``axis``; server-side state
    (ω, rng, round counters) is replicated.  Every ``InFlight`` leaf —
    payload slots, ttl/delay vectors and the (N, S+1) issued-event ring
    — keeps the client axis leading, so one prefix leaf covers the whole
    pipeline and an in-flight solve always lands on the device that owns
    the client's state row.  With ``batched=True`` the
    leaves carry an extra leading sweep axis (see ``repro.launch.sweep``)
    which stays replicated while the client axis (now dim 1) is sharded.
    """
    from repro.core.controller import ControllerState
    from repro.core.state import (
        CLIENT_STACKED_FIELDS,
        CTRL_STACKED_FIELDS,
        FLState,
    )

    spec = P(None, axis) if batched else P(axis)
    c = NamedSharding(mesh, spec)
    r = _replicated(mesh)
    ctrl = ControllerState(**{
        f: (c if f in CTRL_STACKED_FIELDS else r)
        for f in ControllerState._fields})
    return FLState(**{
        f: (c if f in CLIENT_STACKED_FIELDS else r)
        for f in FLState._fields if f != "ctrl"}, ctrl=ctrl)


def round_metrics_shardings(mesh: Mesh, *, axis: str = CLIENT_AXIS,
                            batched: bool = False):
    """Prefix-pytree of shardings for ``repro.core.state.RoundMetrics``."""
    from repro.core.state import RoundMetrics

    spec = P(None, axis) if batched else P(axis)
    c = NamedSharding(mesh, spec)
    r = _replicated(mesh)
    return RoundMetrics(events=c, num_events=r, distances=c, delta=c,
                        load=c, train_loss=r, num_deferred=r,
                        realized_capacity=r, realized_slack=r,
                        num_inflight=r, num_landed=r, committed=c)


def client_data_shardings(mesh: Mesh, data, *, axis: str = CLIENT_AXIS):
    """Shard the leading (client) axis of every data leaf."""
    sh = _sharded(mesh, axis)
    return jax.tree.map(lambda _: sh, data)


def shard_client_data(mesh: Mesh, data, *, axis: str = CLIENT_AXIS):
    """``device_put`` the client-sharded data onto the mesh."""
    return jax.device_put(data, client_data_shardings(mesh, data, axis=axis))


def replicate_data(mesh: Mesh, data):
    """``device_put`` data replicated across the mesh.

    The ragged engine's pooled (Σnᵢ, ...) buffer has no client-aligned
    leading axis, so it cannot shard over the ``clients`` axis; it is
    committed replicated (every device reads only its own clients' CSR
    slices out of it — the per-client offsets shard with the state).
    """
    return jax.tree.map(lambda x: jax.device_put(x, _replicated(mesh)),
                        data)


def balanced_permutation(sizes, n_shards: int) -> np.ndarray:
    """Client order that balances total data *rows* across mesh shards.

    The ``clients`` mesh always splits the stacked state into
    ``n_shards`` equal-count contiguous blocks — with equal-size shards
    that also balances work, but ragged clients make client count a bad
    proxy for solver rows.  This returns a permutation (apply it to the
    client order *before* pooling: re-pool shards in this order and
    ``init_state`` as usual) such that each contiguous block of
    N/n_shards clients carries a near-equal Σnᵢ: clients are dealt
    largest-first onto the currently lightest block (LPT greedy, ≤ 4/3
    OPT makespan), deterministically.

    Returns an (N,) intp array ``perm`` — new position j holds old
    client ``perm[j]``.
    """
    sizes = np.asarray(sizes)
    n = len(sizes)
    if n % n_shards:
        raise ValueError(f"{n} clients do not divide into {n_shards} "
                         "equal-count mesh blocks")
    per_block = n // n_shards
    # Largest-first deal onto the lightest non-full block; ties broken
    # by block index so the permutation is deterministic.
    order = np.argsort(-sizes, kind="stable")
    blocks: list[list[int]] = [[] for _ in range(n_shards)]
    loads = np.zeros(n_shards, np.int64)
    for client in order:
        open_blocks = [b for b in range(n_shards)
                       if len(blocks[b]) < per_block]
        b = min(open_blocks, key=lambda i: (loads[i], i))
        blocks[b].append(int(client))
        loads[b] += int(sizes[client])
    # Ascending client index inside each block keeps the layout stable.
    return np.concatenate([np.sort(b) for b in blocks]).astype(np.intp)


def constrain_clients(tree, mesh: Mesh | None, *, axis: str = CLIENT_AXIS):
    """Pin the leading client axis of stacked intermediates inside a
    jitted round.  No-op when ``mesh`` is None so the single-device
    engine pays nothing.
    """
    if mesh is None:
        return tree

    def pin(x):
        if x.ndim == 0:
            return x
        spec = P(axis, *([None] * (x.ndim - 1)))
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return jax.tree.map(pin, tree)
