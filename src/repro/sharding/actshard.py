"""Activation-sharding constraints (MaxText-style logical hints).

GSPMD propagates input shardings through most of the program, but
propagation dies inside `while` bodies fed by reshapes (the chunked-CE
scan replicated a (B, chunk, V) fp32 tensor — 200 GiB — before these
hints existed).  Model code calls ``constrain_batch`` at the few places
that matter (embedding output, pre-loss hidden, per-chunk logits); the
step builders activate the context with the mesh + batch axes of the
current program.  Outside a context the calls are no-ops, so unit tests
and the CPU simulation engine never see a mesh requirement.
"""
from __future__ import annotations

import contextlib

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_STATE: dict = {"mesh": None, "batch": None, "model": None}


@contextlib.contextmanager
def activation_sharding(mesh, batch_axes, model_axis="model"):
    old = dict(_STATE)
    _STATE.update(mesh=mesh, batch=batch_axes, model=model_axis)
    try:
        yield
    finally:
        _STATE.update(old)


def constrain_batch(x, *, vocab_dim: bool = False):
    """Pin leading dim to the batch axes; optionally the last dim to the
    model axis (vocab-parallel logits)."""
    if _STATE["mesh"] is None or x.ndim == 0:
        return x
    spec = [None] * x.ndim
    spec[0] = _STATE["batch"]
    if vocab_dim and x.ndim >= 2:
        size = _STATE["mesh"].shape[_STATE["model"]]
        if x.shape[-1] % size == 0:
            spec[-1] = _STATE["model"]
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_STATE["mesh"], P(*spec)))
