from .specs import param_specs, batch_specs, pod_stacked_specs, cache_specs  # noqa: F401
