from .specs import param_specs, batch_specs, pod_stacked_specs, cache_specs  # noqa: F401
from .clients import (  # noqa: F401
    CLIENT_AXIS,
    client_data_shardings,
    constrain_clients,
    fl_state_shardings,
    make_client_mesh,
    round_metrics_shardings,
    shard_client_data,
)
