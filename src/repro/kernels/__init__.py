"""Pallas TPU kernels for the framework's compute hot spots.

<name>.py           pl.pallas_call + explicit BlockSpec VMEM tiling
ops.py              jit'd public wrappers (auto interpret on non-TPU)
ref.py              pure-jnp oracles (tests assert allclose)
"""
from . import ops  # noqa: F401
