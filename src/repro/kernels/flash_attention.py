"""Pallas TPU kernel: causal/sliding-window flash attention (GQA).

The compute hot spot of every attention-family architecture.  Standard
TPU flash structure: grid = (batch·heads, q-blocks, kv-blocks) with the
KV dimension innermost; the online-softmax statistics (m, l) and the
output accumulator live in fp32 VMEM scratch that persists across the
sequential KV iterations.  GQA is handled in the BlockSpec index maps —
the KV block loaded for head h is head h // group, so grouped K/V are
never materialized per-query-head.

TPU adaptation notes (vs. the CUDA flash kernel):
* no warp-level softmax reductions — the (block_q, block_k) tile sits in
  VREGs and the VPU does the row reductions; block sizes are multiples
  of the (8, 128) lane layout and the MXU's 128×128 systolic shape;
* the causal structure is exploited at *grid* level: fully-masked KV
  blocks are skipped with ``pl.when`` (the sequential grid makes this a
  cheap predicated no-op, halving FLOPs vs. the XLA blockwise path);
* sliding windows additionally skip blocks left of the window.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale, block_q, block_k, causal, window, seq_len):
    i = pl.program_id(1)  # q block
    j = pl.program_id(2)  # kv block

    @pl.when(j == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = i * block_q
    k_start = j * block_k
    # block-level reachability (static per (i, j) at trace time only if
    # grid indices were static — they are not, so predicated):
    reachable = jnp.asarray(True)
    if causal:
        reachable &= k_start <= q_start + block_q - 1
    if window:
        reachable &= k_start + block_k - 1 > q_start - window

    @pl.when(reachable)
    def _():
        q = q_ref[0].astype(jnp.float32) * scale  # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, hd)
        s = q @ k.T  # (bq, bk)
        qa = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        ka = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        ok = ka < seq_len
        if causal:
            ok &= ka <= qa
        if window:
            ok &= ka > qa - window
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        acc_ref[...] = (acc_ref[...] * corr[:, None]
                        + p @ v_ref[0, 0].astype(jnp.float32))
        m_ref[...] = m_new

    @pl.when(j == pl.num_programs(2) - 1)
    def _():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, ...] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True):
    """q: (B, H, S, hd); k, v: (B, KvH, S, hd) → (B, H, S, hd).

    Softmax scale 1/√hd.  Pads S to a block multiple (padded KV columns
    are masked by the in-kernel `ka < seq_len` predicate; padded query
    rows are cropped).
    """
    b, h, s, hd = q.shape
    kvh = k.shape[1]
    assert h % kvh == 0
    g = h // kvh
    bq = min(block_q, max(s, 8))
    bk = min(block_k, max(s, 8))
    s_pad = max(-s % bq, -s % bk)
    if s_pad:
        pad4 = ((0, 0), (0, 0), (0, s_pad), (0, 0))
        q = jnp.pad(q, pad4)
        k = jnp.pad(k, pad4)
        v = jnp.pad(v, pad4)
    sp = q.shape[2]
    bh = b * h
    qr = q.reshape(bh, sp, hd)

    kernel = functools.partial(
        _kernel, scale=hd ** -0.5, block_q=bq, block_k=bk, causal=causal,
        window=window, seq_len=s)
    out = pl.pallas_call(
        kernel,
        grid=(bh, sp // bq, sp // bk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda n, i, j: (n, i, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda n, i, j, g=g, h=h: (n // h, (n % h) // g, j, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda n, i, j, g=g, h=h: (n // h, (n % h) // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda n, i, j: (n, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sp, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qr, k, v)
    return out.reshape(b, h, sp, hd)[:, :, :s]
