"""Public kernel entry points.

Each op dispatches between the Pallas TPU kernel and the pure-jnp
reference.  On this CPU container the kernels execute in interpret mode
(the kernel *body* runs, validating the exact TPU program); on a real
TPU backend set ``interpret=False`` (the default flips automatically).

``trigger_sq_norms_pytree`` is the integration point used by the
FedBack server: it flattens stacked client pytrees into the (N, D)
layout the kernel wants.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from .admm_update import (
    admm_update as _admm_update,
    admm_update_hbm_bytes,  # noqa: F401  (re-export: traffic model)
    admm_update_sharded as _admm_update_sharded,
)
from .flash_attention import flash_attention as _flash_attention
from .fused_gss import (
    fused_gss as _fused_gss,
    fused_gss_hbm_bytes,  # noqa: F401  (re-export: traffic model)
    fused_gss_ref,  # noqa: F401  (re-export: bit-exact jnp form)
)
from .ssd_scan import ssd_scan as _ssd_scan
from .trigger_norms import (
    trigger_sq_norms as _trigger_sq_norms,
    trigger_sq_norms_sharded as _trigger_sq_norms_sharded,
)


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def trigger_sq_norms(z_prev, omega, *, interpret: bool | None = None):
    interpret = _default_interpret() if interpret is None else interpret
    return _trigger_sq_norms(z_prev, omega, interpret=interpret)


def admm_update(theta, lam, omega, *, interpret: bool | None = None,
                with_z: bool = True, mesh=None, axis: str = "clients"):
    """Fused λ⁺/z/center pass over flat (N, D) client state.

    ``with_z=False`` drops the z output (the flat round's pre-solve
    form).  With ``mesh`` the kernel runs under ``shard_map`` over the
    client mesh axis — one launch per device on its local rows.
    """
    interpret = _default_interpret() if interpret is None else interpret
    if mesh is not None:
        return _admm_update_sharded(theta, lam, omega, mesh, axis=axis,
                                    interpret=interpret, with_z=with_z)
    return _admm_update(theta, lam, omega, interpret=interpret,
                        with_z=with_z)


def fused_gss(idx, valid, solved, omega, theta, lam, z_prev=None, *,
              interpret: bool | None = None, with_z: bool = True):
    """Fused gather→ADMM-commit→scatter over the compact plan's slots.

    One Pallas pass replaces the compact round's post-solve commit
    (row gathers for the dual algebra + z assembly + three drop-indexed
    scatters); outputs alias the (N, D) state inputs so the scatter is
    in place.  ``fused_gss_ref`` is the bit-identical jnp form.
    """
    interpret = _default_interpret() if interpret is None else interpret
    return _fused_gss(idx, valid, solved, omega, theta, lam, z_prev,
                      interpret=interpret, with_z=with_z)


def flash_attention(q, k, v, *, causal=True, window=0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool | None = None):
    interpret = _default_interpret() if interpret is None else interpret
    return _flash_attention(q, k, v, causal=causal, window=window,
                            block_q=block_q, block_k=block_k,
                            interpret=interpret)


def ssd_scan(states, decays, *, interpret: bool | None = None):
    interpret = _default_interpret() if interpret is None else interpret
    return _ssd_scan(states, decays, interpret=interpret)


def trigger_sq_norms_pytree(z_prev_stacked, omega, *,
                            interpret: bool | None = None,
                            mesh=None, axis: str = "clients"):
    """Stacked-pytree front-end for the FedBack server trigger.

    z_prev_stacked: pytree with leading client axis N; omega: matching
    pytree.  Returns (N,) fp32 squared distances.  With ``mesh`` the
    kernel runs under ``shard_map`` over the client mesh axis — one
    launch per device on its local client rows (the axis size must
    divide N).
    """
    z_leaves = jax.tree.leaves(z_prev_stacked)
    w_leaves = jax.tree.leaves(omega)
    n = z_leaves[0].shape[0]
    if len(z_leaves) == 1 and z_leaves[0].ndim == 2:
        # Flat layout: the state already *is* the (N, D) operand — read
        # it in place instead of paying a concatenate copy per round.
        z2d = z_leaves[0].astype(jnp.float32)
        w1d = w_leaves[0].reshape(-1).astype(jnp.float32)
    else:
        z2d = jnp.concatenate(
            [x.reshape(n, -1).astype(jnp.float32) for x in z_leaves], axis=1)
        w1d = jnp.concatenate(
            [x.reshape(-1).astype(jnp.float32) for x in w_leaves])
    interpret = _default_interpret() if interpret is None else interpret
    if mesh is not None:
        return _trigger_sq_norms_sharded(z2d, w1d, mesh, axis=axis,
                                         interpret=interpret)
    return trigger_sq_norms(z2d, w1d, interpret=interpret)


# re-export oracles for convenience
trigger_sq_norms_ref = ref.trigger_sq_norms_ref
admm_update_ref = ref.admm_update_ref
flash_attention_ref = ref.flash_attention_ref
ssd_scan_ref = ref.ssd_scan_ref
