"""Pallas TPU kernel: fused ADMM client update (paper Eq. 2.3).

The dual update, the upload variable and the prox center are three
elementwise expressions over the same (N, D) operands:

    λ⁺ = λ + θ − ω ;   z = θ + λ⁺ ;   c = ω − λ⁺

Unfused, XLA emits three HBM passes over N·D elements; the kernel does
one read of (θ, λ, ω-tile) and one write per output — the round-level
client update becomes strictly bandwidth-bound at its floor (5 streams
instead of 9).  Blocks (8, 1024): VPU-aligned, fp32 accumulate-free
(pure elementwise), dtype-preserving.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(th_ref, la_ref, w_ref, lam_out, z_out, c_out):
    th = th_ref[...]
    la = la_ref[...]
    w = w_ref[...][None, :]
    lam_new = la + th - w
    lam_out[...] = lam_new
    z_out[...] = th + lam_new
    c_out[...] = w - lam_new


@functools.partial(jax.jit, static_argnames=("block_n", "block_d",
                                             "interpret"))
def admm_update(theta, lam, omega, *, block_n: int = 8, block_d: int = 1024,
                interpret: bool = True):
    """theta/lam: (N, D); omega: (D,) → (λ⁺, z, center), each (N, D)."""
    n, d = theta.shape
    n_pad = -n % block_n
    d_pad = -d % block_d
    if n_pad or d_pad:
        pad2 = ((0, n_pad), (0, d_pad))
        theta = jnp.pad(theta, pad2)
        lam = jnp.pad(lam, pad2)
    if d_pad:
        omega = jnp.pad(omega, (0, d_pad))
    np_, dp = theta.shape

    shape = jax.ShapeDtypeStruct((np_, dp), theta.dtype)
    spec2 = pl.BlockSpec((block_n, block_d), lambda i, j: (i, j))
    lam_new, z, c = pl.pallas_call(
        _kernel,
        grid=(np_ // block_n, dp // block_d),
        in_specs=[spec2, spec2,
                  pl.BlockSpec((block_d,), lambda i, j: (j,))],
        out_specs=(spec2, spec2, spec2),
        out_shape=(shape, shape, shape),
        interpret=interpret,
    )(theta, lam, omega)
    crop = lambda x: x[:n, :d]
    return crop(lam_new), crop(z), crop(c)
