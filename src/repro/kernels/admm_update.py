"""Pallas TPU kernel: fused ADMM client update (paper Eq. 2.3).

The dual update, the upload variable and the prox center are three
elementwise expressions over the same (N, D) operands:

    λ⁺ = λ + θ − ω ;   z = θ + λ⁺ ;   c = ω − λ⁺

Unfused, XLA emits three HBM passes over N·D elements; the kernel does
one read of (θ, λ, ω-tile) and one write per output — the round-level
client update becomes strictly bandwidth-bound at its floor (5 streams
instead of 9).  Blocks (8, 1024): VPU-aligned, fp32 accumulate-free
(pure elementwise), dtype-preserving.

The flat round engine uses the ``with_z=False`` form: it needs λ⁺ and
the prox center *before* the local solve, while z is assembled from the
post-solve θ (``z = θ_out + λ⁺`` fuses into the event-gated commit), so
dropping the z stream saves one N·D write (4 streams total).

``admm_update_sharded`` runs the same kernel under ``shard_map`` over a
1-D ``clients`` mesh axis: one launch per device on its local client
rows, ω replicated — no collective, bit-identical to single-device.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def admm_update_hbm_bytes(rows: int, dim: int, *, with_z: bool = True,
                          dtype_bytes: int = 4) -> int:
    """Modeled HBM traffic of one fused pass over ``rows`` client rows.

    One read each of θ and λ, one (amortized) read of the ω tile, one
    write per output — 5 streams with z, 4 without.  ``rows`` is the
    lever: the compacted round engine feeds the kernel C = ⌈slack·L̄·N⌉
    gathered rows instead of N, so the modeled bytes (and the measured
    wall-clock) scale with the capacity, not the client count.
    """
    n_out = 3 if with_z else 2
    return dtype_bytes * ((2 + n_out) * rows * dim + dim)


def _kernel3(th_ref, la_ref, w_ref, lam_out, z_out, c_out):
    th = th_ref[...]
    la = la_ref[...]
    w = w_ref[...][None, :]
    lam_new = la + th - w
    lam_out[...] = lam_new
    z_out[...] = th + lam_new
    c_out[...] = w - lam_new


def _kernel2(th_ref, la_ref, w_ref, lam_out, c_out):
    th = th_ref[...]
    la = la_ref[...]
    w = w_ref[...][None, :]
    lam_new = la + th - w
    lam_out[...] = lam_new
    c_out[...] = w - lam_new


@functools.partial(jax.jit, static_argnames=("block_n", "block_d",
                                             "interpret", "with_z"))
def admm_update(theta, lam, omega, *, block_n: int = 8, block_d: int = 1024,
                interpret: bool = True, with_z: bool = True):
    """theta/lam: (N, D); omega: (D,) → (λ⁺, z, center) each (N, D).

    With ``with_z=False`` the z stream is skipped and the result is
    (λ⁺, center) — the pre-solve half of the round's client update.
    """
    n, d = theta.shape
    n_pad = -n % block_n
    d_pad = -d % block_d
    if n_pad or d_pad:
        pad2 = ((0, n_pad), (0, d_pad))
        theta = jnp.pad(theta, pad2)
        lam = jnp.pad(lam, pad2)
    if d_pad:
        omega = jnp.pad(omega, (0, d_pad))
    np_, dp = theta.shape

    shape = jax.ShapeDtypeStruct((np_, dp), theta.dtype)
    spec2 = pl.BlockSpec((block_n, block_d), lambda i, j: (i, j))
    n_out = 3 if with_z else 2
    outs = pl.pallas_call(
        _kernel3 if with_z else _kernel2,
        grid=(np_ // block_n, dp // block_d),
        in_specs=[spec2, spec2,
                  pl.BlockSpec((block_d,), lambda i, j: (j,))],
        out_specs=(spec2,) * n_out,
        out_shape=(shape,) * n_out,
        interpret=interpret,
    )(theta, lam, omega)
    return tuple(x[:n, :d] for x in outs)


def admm_update_sharded(theta, lam, omega, mesh, *, axis: str = "clients",
                        block_n: int = 8, block_d: int = 1024,
                        interpret: bool = True, with_z: bool = True):
    """Client-sharded fused update: ``shard_map`` over the ``clients``
    mesh axis, one kernel launch per device on its local rows.

    theta/lam: (N, D) sharded over ``axis``; omega: (D,) replicated.
    Pure elementwise per client row — no collective is introduced.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    kernel = functools.partial(admm_update, block_n=block_n, block_d=block_d,
                               interpret=interpret, with_z=with_z)
    n_out = 3 if with_z else 2
    fn = shard_map(kernel, mesh=mesh,
                   in_specs=(P(axis, None), P(axis, None), P(None)),
                   out_specs=(P(axis, None),) * n_out,
                   check_rep=False)
    return fn(theta, lam, omega)
