"""Pure-jnp oracles for every Pallas kernel (the correctness ground
truth for the interpret-mode allclose sweeps in tests/test_kernels.py)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def trigger_sq_norms_ref(z_prev, omega):
    """Per-client squared trigger distances ‖z_i − ω‖² (fp32).

    z_prev: (N, D); omega: (D,) → (N,) fp32.
    """
    diff = z_prev.astype(jnp.float32) - omega.astype(jnp.float32)[None]
    return jnp.sum(diff * diff, axis=1)


def admm_update_ref(theta, lam, omega):
    """Fused ADMM client update (Eq. 2.3 dual + z):

        λ⁺ = λ + θ − ω ;  z = θ + λ⁺ ;  c = ω − λ⁺  (prox center)
    theta/lam: (N, D); omega: (D,) → (λ⁺, z, c) each (N, D).
    """
    lam_new = lam + theta - omega[None]
    z = theta + lam_new
    center = omega[None] - lam_new
    return lam_new, z, center


def flash_attention_ref(q, k, v, *, causal=True, window=0):
    """Masked softmax attention oracle.

    q: (B, H, S, hd); k, v: (B, KvH, S, hd) (GQA: H % KvH == 0).
    """
    b, h, s, hd = q.shape
    kvh = k.shape[1]
    g = h // kvh
    qg = q.reshape(b, kvh, g, s, hd).astype(jnp.float32)
    scores = jnp.einsum("bkgqh,bkth->bkgqt", qg,
                        k.astype(jnp.float32)) / hd ** 0.5
    qa = jnp.arange(s)[:, None]
    ka = jnp.arange(s)[None, :]
    ok = jnp.ones((s, s), bool)
    if causal:
        ok &= ka <= qa
    if window:
        ok &= ka > qa - window
    scores = jnp.where(ok[None, None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqt,bkth->bkgqh", w, v.astype(jnp.float32))
    return out.reshape(b, h, s, hd).astype(q.dtype)


def ssd_scan_ref(states, decays):
    """Inter-chunk SSD state scan oracle.

    states: (B, C, H, P, N) — per-chunk compressed inputs;
    decays: (B, C, H)       — per-chunk total decay.
    Returns h_prev (B, C, H, P, N): the carried state *entering* each
    chunk (exclusive scan), plus the final state (B, H, P, N).
    """
    b, c, h, p, n = states.shape

    def body(h_prev, xs):
        st, dec = xs
        h_new = h_prev * dec[..., None, None] + st
        return h_new, h_prev

    h0 = jnp.zeros((b, h, p, n), states.dtype)
    h_last, h_prevs = jax.lax.scan(
        body, h0, (states.swapaxes(0, 1), decays.swapaxes(0, 1)))
    return h_prevs.swapaxes(0, 1), h_last
