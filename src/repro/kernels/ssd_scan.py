"""Pallas TPU kernel: Mamba-2 inter-chunk state scan (SSD).

After the intra-chunk SSD contraction, each chunk c of each (batch,
head) owns a compressed state increment S_c ∈ R^{P×N} and a scalar
decay a_c; the recurrence

    H_c = a_c · H_{c−1} + S_{c−1},     H_0 = 0

must run sequentially over chunks.  XLA lowers the natural lax.scan to
per-step HBM round-trips of the (P, N) carry; the kernel instead walks
the chunk dimension as the innermost sequential grid with the carry in
fp32 VMEM scratch — one HBM read per S_c, one write per H_c, carry
never leaves VMEM.  (P, N) = (64, 128) tiles are exactly one fp32 VREG
page set, matching the (8, 128) layout.

Returns the *entering* state per chunk (exclusive scan) — what the
intra-chunk pass consumes — plus the final carry for decode handoff.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(s_ref, a_ref, h_ref, last_ref, carry_ref):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    h_ref[0, 0, ...] = carry_ref[...].astype(h_ref.dtype)
    carry_ref[...] = (carry_ref[...] * a_ref[0, 0]
                      + s_ref[0, 0].astype(jnp.float32))

    @pl.when(c == pl.num_programs(1) - 1)
    def _():
        last_ref[0, ...] = carry_ref[...].astype(last_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_scan(states, decays, *, interpret: bool = True):
    """states: (B, C, H, P, N); decays: (B, C, H) →
    (h_prev (B, C, H, P, N), h_last (B, H, P, N))."""
    b, c, h, p, n = states.shape
    bh = b * h
    # (BH, C, P, N) layout: chunk dim innermost-sequential per (b, h)
    sr = states.transpose(0, 2, 1, 3, 4).reshape(bh, c, p, n)
    ar = decays.transpose(0, 2, 1).reshape(bh, c)

    h_prev, h_last = pl.pallas_call(
        _kernel,
        grid=(bh, c),
        in_specs=[
            pl.BlockSpec((1, 1, p, n), lambda m, j: (m, j, 0, 0)),
            pl.BlockSpec((1, 1), lambda m, j: (m, j)),
        ],
        out_specs=(
            pl.BlockSpec((1, 1, p, n), lambda m, j: (m, j, 0, 0)),
            pl.BlockSpec((1, p, n), lambda m, j: (m, 0, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((bh, c, p, n), states.dtype),
            jax.ShapeDtypeStruct((bh, p, n), states.dtype),
        ),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(sr, ar)
    h_prev = h_prev.reshape(b, h, c, p, n).transpose(0, 2, 1, 3, 4)
    return h_prev, h_last.reshape(b, h, p, n)
