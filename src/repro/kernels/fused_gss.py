"""Pallas TPU megakernel: fused gather → ADMM commit → scatter.

The compacted round (``core/compact.py``) commits a solve by touching
the (N, D) client state three separate times: gather θ/λ rows for the
dual algebra, assemble z = θ_out + λ⁺, then three drop-indexed scatters
write θ/λ/z_prev back.  Each pass is a full HBM round-trip over the
touched rows, and XLA will not fuse a gather with a scatter across the
solve boundary.  This kernel collapses the post-solve commit into ONE
pass: a per-slot grid whose BlockSpec index maps consume the
``CompactPlan`` slot indices directly (scalar-prefetch operands), so
for capacity slot i the pipeline

    * gathers θ[idx[i]], λ[idx[i]] (and z_prev[idx[i]]) into VMEM,
    * recomputes λ⁺ = λ + θ − ω and z = θ_solved + λ⁺ in registers —
      the exact ``_kernel3``/``_kernel2`` expressions of
      ``kernels/admm_update.py``, same op order, bit-identical fp32 —
    * and scatters all outputs back to row idx[i] in place
      (``input_output_aliases`` pins each state output onto its input
      buffer, so an un-planned row is never copied and a masked
      ``plan.valid`` lane writes its own gathered row back unchanged).

Solver HBM traffic drops from the three-pass reference's ~10 streams
over the C committed rows to 7 (``fused_gss_hbm_bytes``).  Plan indices
are distinct by construction (a ``jnp.lexsort`` permutation prefix), so
masked write-back never races a genuine commit.

``fused_gss_ref`` is the jnp three-pass form of the *same* expression
graph — gather, ``λ + θ − ω``, drop-indexed scatters — kept as the
bit-exact parity oracle and as the execution path on backends where
interpret-mode Pallas is slower than XLA fusion (CPU CI).

VMEM budget per grid step: 7 blocks of (1, block_d) fp32 plus the
(block_d,) ω tile — 8·block_d·4 B ≈ 4 KiB at block_d=128, far under
the ~16 MiB VMEM ceiling; block_d rounds D up to the 128-lane register
width and stays ≤ 1024 so wide models pipeline over the d grid axis.

On CPU the kernel executes under ``interpret=True`` (the exact TPU
program, validated bit-for-bit against ``fused_gss_ref`` in
tests/test_fused_gss.py); on real hardware pass ``interpret=False``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def fused_gss_hbm_bytes(rows: int, dim: int, *, with_z: bool = True,
                        presolve: bool = False,
                        dtype_bytes: int = 4) -> int:
    """Modeled HBM traffic of one fused commit over ``rows`` slots.

    Kernel streams: reads θ/λ (+ z_prev) gathered rows and the solved
    (C, D) buffer, one (amortized) ω tile, writes one row per output —
    7 streams with z, 5 without, each ``rows·dim`` elements.  With
    ``presolve=True`` the round-level pre-solve λ⁺/center pass (2 row
    reads + 1 center write + ω) is added, giving the full fused compact
    round's solver-state model: 10·rows·dim + 2·dim elements.  Compare
    ``admm_update_hbm_bytes`` + 3 separate scatter passes for the
    unfused reference.
    """
    n_stream = 7 if with_z else 5
    total = n_stream * rows * dim + dim
    if presolve:
        total += 3 * rows * dim + dim
    return dtype_bytes * total


def _fused_gss3(idx_ref, vm_ref, s_ref, w_ref, th_ref, la_ref, z_ref,
                tho_ref, lao_ref, zo_ref):
    # One capacity slot per grid row: th/la/z blocks arrive gathered
    # from row idx[i] by the BlockSpec index maps; an invalid lane
    # writes its gathered rows back unchanged (aliased outputs make
    # that a no-op commit, never a clobber).
    v = vm_ref[pl.program_id(0)] != 0
    th = th_ref[...]
    la = la_ref[...]
    w = w_ref[...][None, :]
    lam_new = la + th - w  # _kernel3 op order — bit-identical λ⁺
    z = s_ref[...] + lam_new
    tho_ref[...] = jnp.where(v, s_ref[...], th)
    lao_ref[...] = jnp.where(v, lam_new, la)
    zo_ref[...] = jnp.where(v, z, z_ref[...])


def _fused_gss2(idx_ref, vm_ref, s_ref, w_ref, th_ref, la_ref,
                tho_ref, lao_ref):
    v = vm_ref[pl.program_id(0)] != 0
    th = th_ref[...]
    la = la_ref[...]
    w = w_ref[...][None, :]
    lam_new = la + th - w
    tho_ref[...] = jnp.where(v, s_ref[...], th)
    lao_ref[...] = jnp.where(v, lam_new, la)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret",
                                             "with_z"))
def fused_gss(idx, valid, solved, omega, theta, lam, z_prev=None, *,
              block_d: int = 1024, interpret: bool = True,
              with_z: bool = True):
    """Fused commit: scatter ``solved`` + ADMM duals into (N, D) state.

    idx: (C,) int32 plan slot → state row (distinct rows); valid: (C,)
    bool commit mask; solved: (C, D) post-solve θ rows; omega: (D,);
    theta/lam/z_prev: (N, D) state.  Returns (θ', λ', z') — or
    (θ', λ') with ``with_z=False`` — where row idx[i] of each output
    holds the committed update when valid[i] and the untouched input
    row otherwise.

    Outputs alias the state inputs (``input_output_aliases``), so under
    a donating jit the scatter is a true in-place update — no (N, D)
    copy — whenever D is already a multiple of the 128-lane width
    (otherwise a one-off pad copy re-layouts the state).
    """
    if with_z and z_prev is None:
        raise ValueError("with_z=True needs z_prev")
    n, d = theta.shape
    c = idx.shape[0]
    dp = d + (-d % 128)  # lane-align; keep blocks ≤ block_d
    block_d = min(block_d, dp)
    if dp != d:
        pad2 = ((0, 0), (0, dp - d))
        solved = jnp.pad(solved, pad2)
        theta = jnp.pad(theta, pad2)
        lam = jnp.pad(lam, pad2)
        omega = jnp.pad(omega, (0, dp - d))
        if with_z:
            z_prev = jnp.pad(z_prev, pad2)

    vmask = valid.astype(jnp.int32)
    row = pl.BlockSpec((1, block_d), lambda i, j, idx, vm: (idx[i], j))
    slot = pl.BlockSpec((1, block_d), lambda i, j, idx, vm: (i, j))
    wtile = pl.BlockSpec((block_d,), lambda i, j, idx, vm: (j,))
    n_out = 3 if with_z else 2
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(c, dp // block_d),
        in_specs=[slot, wtile] + [row] * n_out,
        out_specs=[row] * n_out,
    )
    operands = (idx.astype(jnp.int32), vmask, solved, omega, theta, lam)
    if with_z:
        operands += (z_prev,)
    outs = pl.pallas_call(
        _fused_gss3 if with_z else _fused_gss2,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((n, dp), theta.dtype)] * n_out,
        # alias positions count the scalar-prefetch operands: state
        # inputs sit at 4/5/6 of (idx, vmask, solved, ω, θ, λ[, z]).
        input_output_aliases={4 + k: k for k in range(n_out)},
        interpret=interpret,
    )(*operands)
    if dp != d:
        outs = [o[:, :d] for o in outs]
    return tuple(outs)


def fused_gss_ref(idx, valid, solved, omega, theta, lam, z_prev=None, *,
                  with_z: bool = True):
    """jnp three-pass reference: the kernel's exact expression graph.

    Gathers θ/λ rows, recomputes λ⁺ with the ``_kernel3`` op order, and
    commits through drop-indexed scatters (invalid lanes route to an
    out-of-bounds row, same no-op semantics as the kernel's masked
    write-back).  Bit-identical to :func:`fused_gss` on every lane.
    """
    if with_z and z_prev is None:
        raise ValueError("with_z=True needs z_prev")
    n = theta.shape[0]
    th_rows = theta[idx]
    la_rows = lam[idx]
    lam_new = la_rows + th_rows - omega[None, :]
    drop = jnp.where(valid, idx, n)
    tho = theta.at[drop].set(solved.astype(theta.dtype), mode="drop")
    lao = lam.at[drop].set(lam_new.astype(lam.dtype), mode="drop")
    if not with_z:
        return tho, lao
    z_rows = solved + lam_new
    zo = z_prev.at[drop].set(z_rows.astype(z_prev.dtype), mode="drop")
    return tho, lao, zo
