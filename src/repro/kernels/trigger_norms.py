"""Pallas TPU kernel: per-client trigger distances (FedBack's server
hot spot).

Computes r_i = ‖z_i^prev − ω‖² for all N clients in a single pass over
HBM.  Workload is pure bandwidth: N·D reads of z plus D reads of ω
(re-read per client block — ω stays VMEM-resident across the inner
grid dimension).

TPU adaptation (vs. a CUDA atomics reduction): the grid is
(client-blocks × param-blocks) with the param dimension innermost;
per-client partial sums live in an fp32 VMEM scratch that persists
across the sequential inner grid, so each client's accumulator never
round-trips to HBM.  Blocks are (8, 1024) — 8-row sublane alignment,
128-lane multiples — 32 KiB of VMEM per z tile in fp32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(z_ref, w_ref, o_ref, acc_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    diff = z_ref[...].astype(jnp.float32) - w_ref[...].astype(jnp.float32)
    acc_ref[...] += jnp.sum(diff * diff, axis=1)

    @pl.when(j == pl.num_programs(1) - 1)
    def _():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("block_n", "block_d",
                                             "interpret"))
def trigger_sq_norms(z_prev, omega, *, block_n: int = 8,
                     block_d: int = 1024, interpret: bool = True):
    """z_prev: (N, D), omega: (D,) → (N,) fp32 squared distances.

    Pads N and D to block multiples (ω pads with the same zeros as z, so
    padding contributes exactly 0 to every sum).
    """
    n, d = z_prev.shape
    n_pad = -n % block_n
    d_pad = -d % block_d
    if n_pad or d_pad:
        z_prev = jnp.pad(z_prev, ((0, n_pad), (0, d_pad)))
    if d_pad:
        omega = jnp.pad(omega, (0, d_pad))
    np_, dp = z_prev.shape

    out = pl.pallas_call(
        _kernel,
        grid=(np_ // block_n, dp // block_d),
        in_specs=[
            pl.BlockSpec((block_n, block_d), lambda i, j: (i, j)),
            pl.BlockSpec((block_d,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((np_,), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_n,), jnp.float32)],
        interpret=interpret,
    )(z_prev, omega)
    return out[:n]


def trigger_sq_norms_sharded(z_prev, omega, mesh, *, axis: str = "clients",
                             block_n: int = 8, block_d: int = 1024,
                             interpret: bool = True):
    """Client-sharded trigger norms: ``shard_map`` over the ``clients``
    mesh axis, one Pallas kernel launch per device on its local rows.

    z_prev: (N, D) sharded over ``axis`` (the axis size must divide N);
    omega: (D,) replicated.  The per-client reduction over D is device-
    local — the only collective in the FedBack round stays the consensus
    mean — so the result is bit-identical to the single-device kernel.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    kernel = functools.partial(trigger_sq_norms, block_n=block_n,
                               block_d=block_d, interpret=interpret)
    fn = shard_map(kernel, mesh=mesh,
                   in_specs=(P(axis, None), P(None)), out_specs=P(axis),
                   check_rep=False)
    return fn(z_prev, omega)
