"""Pytree checkpointing (npz-based, no external deps).

Stores arbitrary pytrees (FLState included: server ω, stacked client
θ/λ/z_prev, controller state, PRNG key) with structure round-tripping
via flattened key paths.  Atomic write (tmp + rename); ``step``-suffixed
files with ``latest_checkpoint`` discovery.

Dtypes round-trip exactly, including the extended ml_dtypes family
(bf16 client state): ``np.savez`` serializes bfloat16 as a raw 2-byte
void dtype, so the writer records every leaf's true dtype in a
``__dtypes__`` sidecar and the loader re-views the bytes before any
comparison.  ``load_checkpoint`` then *casts* to the template leaf's
dtype when the kinds are compatible (float→float covers bf16↔fp32
resume, int→int, exact bool/uint) and raises on genuinely incompatible
kinds — restoring a float row into an int32 queue age is corruption,
not a cast.  The stored treedef is verified against the template up
front, so a structure mismatch is a clear error instead of an opaque
missing-leaf ``KeyError``.
"""
from __future__ import annotations

import json
import os
import re
import tempfile

import jax
import numpy as np

_SEP = "/"


def _flatten(tree):
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_part(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _part(p) -> str:
    if isinstance(p, jax.tree_util.DictKey):
        return f"d:{p.key}"
    if isinstance(p, jax.tree_util.SequenceKey):
        return f"s:{p.idx}"
    if isinstance(p, jax.tree_util.GetAttrKey):
        return f"a:{p.name}"
    raise TypeError(f"unsupported key path entry {p!r}")


def _json_blob(obj) -> np.ndarray:
    return np.frombuffer(json.dumps(obj).encode(), dtype=np.uint8)


def _read_blob(arr) -> object:
    return json.loads(np.asarray(arr).tobytes().decode())


def save_checkpoint(directory: str, step: int, tree, *, prefix="ckpt") -> str:
    """Serialize `tree` to `<directory>/<prefix>_<step>.npz` atomically."""
    os.makedirs(directory, exist_ok=True)
    tree = jax.device_get(tree)
    flat = _flatten(tree)
    treedef = jax.tree_util.tree_structure(tree)
    path = os.path.join(directory, f"{prefix}_{step:08d}.npz")
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f,
                     __treedef__=_json_blob(str(treedef)),
                     __dtypes__=_json_blob(
                         {k: str(v.dtype) for k, v in flat.items()}),
                     **flat)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    return path


_META_KEYS = ("__treedef__", "__dtypes__")


def _compatible_cast(arr: np.ndarray, key: str, want) -> np.ndarray:
    """``arr`` cast to the template dtype ``want``; loud on a kind clash.

    Compatibility is by dtype *kind* through jax's extended lattice
    (so bfloat16 — numpy kind 'V' — still counts as floating): both
    floating, both signed-integer, or both unsigned-integer casts are
    value-preserving resumes; everything else (float↔int, bool↔number,
    ...) is state corruption and raises.
    """
    import jax.numpy as jnp

    want = np.dtype(want)
    if arr.dtype == want:
        return arr
    for lattice_kind in (jnp.floating, jnp.signedinteger,
                         jnp.unsignedinteger):
        if jnp.issubdtype(arr.dtype, lattice_kind) \
                and jnp.issubdtype(want, lattice_kind):
            return arr.astype(want)
    raise ValueError(
        f"incompatible dtype for {key}: checkpoint {arr.dtype} cannot "
        f"restore into a {want} leaf (only floating→floating and "
        f"matching-signedness integer casts are allowed)")


def load_checkpoint(path: str, like):
    """Restore into the structure of `like` (a template pytree).

    Leaves come back *in the template's dtype* (a bf16 checkpoint
    restores into a bf16 template unchanged, and resumes into an fp32
    template via an explicit cast); a checkpoint whose tree structure
    differs from ``like`` fails fast with both structures spelled out.
    """
    with np.load(path) as zf:
        stored_treedef = (_read_blob(zf["__treedef__"])
                          if "__treedef__" in zf.files else None)
        stored_dtypes = (_read_blob(zf["__dtypes__"])
                         if "__dtypes__" in zf.files else {})
        flat = {k: zf[k] for k in zf.files if k not in _META_KEYS}
    like_treedef = str(jax.tree_util.tree_structure(like))
    if stored_treedef is not None and stored_treedef != like_treedef:
        raise ValueError(
            f"checkpoint structure mismatch:\n  stored   "
            f"{stored_treedef}\n  template {like_treedef}")
    leaves_like = jax.tree_util.tree_flatten_with_path(like)[0]
    out = []
    for keypath, leaf in leaves_like:
        key = _SEP.join(_part(p) for p in keypath)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = flat[key]
        stored = stored_dtypes.get(key)
        if stored is not None and str(arr.dtype) != stored:
            # np.savez round-trips extended dtypes (bfloat16, ...) as
            # raw void bytes; re-view them as what was written.
            arr = arr.view(np.dtype(stored))
        if hasattr(leaf, "shape") and tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs {leaf.shape}")
        if hasattr(leaf, "dtype"):
            arr = _compatible_cast(arr, key, leaf.dtype)
        out.append(arr)
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), out)


def latest_checkpoint(directory: str, *, prefix="ckpt") -> str | None:
    if not os.path.isdir(directory):
        return None
    pat = re.compile(rf"{re.escape(prefix)}_(\d+)\.npz$")
    best, best_step = None, -1
    for name in os.listdir(directory):
        m = pat.match(name)
        if m and int(m.group(1)) > best_step:
            best, best_step = os.path.join(directory, name), int(m.group(1))
    return best
