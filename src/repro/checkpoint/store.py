"""Pytree checkpointing (npz-based, no external deps).

Stores arbitrary pytrees (FLState included: server ω, stacked client
θ/λ/z_prev, controller state, PRNG key) with structure round-tripping
via flattened key paths.  Atomic write (tmp + rename); ``step``-suffixed
files with ``latest_checkpoint`` discovery.
"""
from __future__ import annotations

import json
import os
import re
import tempfile

import jax
import numpy as np

_SEP = "/"


def _flatten(tree):
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_part(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _part(p) -> str:
    if isinstance(p, jax.tree_util.DictKey):
        return f"d:{p.key}"
    if isinstance(p, jax.tree_util.SequenceKey):
        return f"s:{p.idx}"
    if isinstance(p, jax.tree_util.GetAttrKey):
        return f"a:{p.name}"
    raise TypeError(f"unsupported key path entry {p!r}")


def save_checkpoint(directory: str, step: int, tree, *, prefix="ckpt") -> str:
    """Serialize `tree` to `<directory>/<prefix>_<step>.npz` atomically."""
    os.makedirs(directory, exist_ok=True)
    tree = jax.device_get(tree)
    flat = _flatten(tree)
    treedef = jax.tree_util.tree_structure(tree)
    path = os.path.join(directory, f"{prefix}_{step:08d}.npz")
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, __treedef__=np.frombuffer(
                json.dumps(str(treedef)).encode(), dtype=np.uint8), **flat)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    return path


def load_checkpoint(path: str, like):
    """Restore into the structure of `like` (a template pytree)."""
    with np.load(path) as zf:
        flat = {k: zf[k] for k in zf.files if k != "__treedef__"}
    leaves_like = jax.tree_util.tree_flatten_with_path(like)[0]
    out = []
    for keypath, leaf in leaves_like:
        key = _SEP.join(_part(p) for p in keypath)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = flat[key]
        if hasattr(leaf, "shape") and tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs {leaf.shape}")
        out.append(arr)
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), out)


def latest_checkpoint(directory: str, *, prefix="ckpt") -> str | None:
    if not os.path.isdir(directory):
        return None
    pat = re.compile(rf"{re.escape(prefix)}_(\d+)\.npz$")
    best, best_step = None, -1
    for name in os.listdir(directory):
        m = pat.match(name)
        if m and int(m.group(1)) > best_step:
            best, best_step = os.path.join(directory, name), int(m.group(1))
    return best
