"""Flat client-state codec: pytree ⇄ contiguous (N, D) fp32 matrices.

The round engine's client-side algebra (dual ascent, prox centers,
trigger norms, gated commits) is elementwise over every parameter of
every client.  Stored as stacked *pytrees*, each per-round pass costs
one HBM sweep per leaf and the Pallas kernels need a ``jnp.concatenate``
copy to build their (N, D) operands.  Stored *flat* — one contiguous
(N, D) fp32 matrix per state field — the same algebra is a single-pass
kernel over one buffer and the kernels read the state in place.

``FlatSpec`` is the static codec: the leaf layout (treedef, shapes,
dtypes, offsets) captured once from a template pytree.  It is a frozen,
hashable dataclass, so it can be closed over by jitted programs without
retracing and used as a static argument.

Typical use::

    spec = make_flat_spec(params0)
    state = init_state(cfg, params0, spec=spec)          # flat FLState
    round_fn = make_round_fn(cfg, loss_fn, data, spec=spec)

The solver unravels one (D,) row back into the model pytree *inside*
the vmapped local solve (pure reshapes/slices — XLA folds them into the
surrounding program), so model code never sees the flat layout.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class FlatSpec:
    """Static description of a pytree's flat (D,) fp32 layout.

    Hashable (usable as a jit static argument): dtypes are stored by
    name and the treedef by jax's hashable ``PyTreeDef``.
    """

    treedef: Any
    shapes: tuple[tuple[int, ...], ...]
    dtypes: tuple[str, ...]
    offsets: tuple[int, ...]
    dim: int  # total flat width D

    def flatten(self, tree) -> jax.Array:
        """Pytree (matching the template) → contiguous (D,) fp32."""
        leaves = self.treedef.flatten_up_to(tree)
        return jnp.concatenate(
            [jnp.asarray(x).astype(jnp.float32).reshape(-1)
             for x in leaves]) if leaves else jnp.zeros((0,), jnp.float32)

    def unflatten(self, vec: jax.Array):
        """(D,) vector → pytree with the template's shapes and dtypes."""
        leaves = [
            jax.lax.slice_in_dim(
                vec, o,
                o + int(np.prod(s, dtype=np.int64)))  # tracecheck: ok
            .reshape(s).astype(d)
            for o, s, d in zip(self.offsets, self.shapes, self.dtypes,
                               strict=True)
        ]
        return jax.tree.unflatten(self.treedef, leaves)

    def zeros_stacked(self, n: int) -> jax.Array:
        """Empty (n, D) fp32 client-stacked buffer in the flat layout.

        The allocation primitive for auxiliary client-state buffers that
        must mirror θ's layout without being derived from a live value —
        e.g. the in-flight payload slots of the stale-tolerant round
        engine (``repro.core.state.InFlight``): under the flat codec the
        pipeline parks solve results as rows of one contiguous matrix,
        so landing a payload is a single-buffer masked select exactly
        like every other flat-state commit.
        """
        return jnp.zeros((n, self.dim), jnp.float32)

    def zeros_stacked_host(self, n: int) -> np.ndarray:
        """Host-memory twin of :meth:`zeros_stacked`: an (n, D) fp32
        ``numpy`` buffer.  The allocation primitive of the host-offloaded
        state backend (``repro.core.hoststate``), where the client-
        stacked matrices never live on device — a plain C-contiguous
        array the streaming round gathers/scatters with fancy indexing.
        """
        return np.zeros((n, self.dim), np.float32)

    def host_broadcast_rows(self, vec, n: int) -> np.ndarray:
        """(D,) template → writable (n, D) fp32 host buffer, every row
        an exact bitwise copy of ``vec`` (mirrors the device engine's
        ``tree_broadcast_like`` init so both backends start identical).
        """
        row = np.asarray(vec, np.float32).reshape(1, self.dim)
        return np.repeat(row, n, axis=0)

    def flatten_stacked(self, tree) -> jax.Array:
        """Stacked pytree (N, ...) leaves → contiguous (N, D) fp32."""
        leaves = self.treedef.flatten_up_to(tree)
        n = jax.tree.leaves(tree)[0].shape[0]
        return jnp.concatenate(
            [jnp.asarray(x).astype(jnp.float32).reshape(n, -1)
             for x in leaves], axis=1)

    def unflatten_stacked(self, mat: jax.Array):
        """(N, D) matrix → stacked pytree with leading axis N."""
        return jax.vmap(self.unflatten)(mat)


def make_flat_spec(template) -> FlatSpec:
    """Capture the static flat layout of ``template`` (a params pytree)."""
    leaves, treedef = jax.tree.flatten(template)
    shapes = tuple(tuple(x.shape) for x in leaves)
    dtypes = tuple(jnp.dtype(x.dtype).name for x in leaves)
    sizes = [int(np.prod(s, dtype=np.int64)) for s in shapes]
    offsets = tuple(int(o) for o in np.cumsum([0] + sizes[:-1]))
    return FlatSpec(treedef=treedef, shapes=shapes, dtypes=dtypes,
                    offsets=offsets, dim=int(sum(sizes)))


def flat_loss_fn(spec: FlatSpec, loss_fn: Callable) -> Callable:
    """Adapt ``loss_fn(params_pytree, x, y)`` to flat (D,) parameters."""

    def flat_loss(vec, x, y):
        return loss_fn(spec.unflatten(vec), x, y)

    return flat_loss


def flatten_problem(params0, loss_fn: Callable):
    """One-call front end: (spec, flat_params0, flat_loss_fn)."""
    spec = make_flat_spec(params0)
    return spec, spec.flatten(params0), flat_loss_fn(spec, loss_fn)
