"""Ragged client shards: a CSR codec over one pooled data buffer.

FedBack's premise is that clients make *heterogeneous* local progress —
yet a client-stacked ``(N, n_i, ...)`` data layout forces equal-size
shards, and trimming shards to the minimum size throws away exactly the
per-client imbalance that drives participation dynamics (Wang & Ji
2022; Chen et al. 2020).  This module is the substrate that retires the
rectangular assumption:

* all clients' examples live in **one pooled** ``(Σnᵢ, ...)`` buffer
  (row-major, client-contiguous), and
* :class:`RaggedSpec` is the static CSR index — per-client ``offsets``
  and ``sizes`` — describing which rows belong to whom.

Like ``repro.utils.flatstate.FlatSpec``, the spec is a frozen, hashable
dataclass built from *python ints only*, so jitted round programs close
over it without retracing and every offset lowers to an XLA constant.
The round engine never materializes per-client shards: the scanned SGD
solver already gathers minibatches by index (``jnp.take(x, idx)``), so
feeding it the pooled buffer with **global** indices
``offsets[i] + local_idx`` reads exactly the same fp32 values as the
rectangular layout — which is why uniform sizes reproduce the dense and
compacted engines bit for bit (events AND ω; pinned by the golden
traces and tests/test_ragged.py).

**Size buckets.**  Vmapping one solver over clients needs one static
scan length, but ragged clients have ragged epoch lengths.  The spec
groups clients into at most ``max_buckets`` size buckets; each bucket
runs one rectangular vmapped program at the bucket's capacity
(pad-to-bucket-max with masked loss — see ``repro.core.fedback``), so
XLA sees a few rectangular programs, not N.  A bucket whose members all
match its capacity carries no padding and is *statically* known to need
no mask — the uniform case degenerates to today's engine, same code
path, bit for bit.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class RaggedBucket:
    """One rectangular solve program of a ragged round (static)."""

    capacity: int  # padded shard size the bucket's program is traced at
    members: tuple[int, ...]  # client indices, ascending
    padded: bool  # any member smaller than the capacity (needs the mask)


@dataclasses.dataclass(frozen=True)
class RaggedSpec:
    """Static CSR layout of N client shards pooled into (Σnᵢ, ...) rows.

    Hashable (tuples of python ints), so it can be closed over by jitted
    programs and used as a jit static argument — exactly like
    ``FlatSpec``.
    """

    sizes: tuple[int, ...]  # n_i per client
    offsets: tuple[int, ...]  # CSR row offsets: offsets[i] = Σ_{j<i} n_j
    buckets: tuple[RaggedBucket, ...]  # size-bucketed solve plan

    # --- static views ---------------------------------------------------
    @property
    def n_clients(self) -> int:
        return len(self.sizes)

    @property
    def total(self) -> int:
        """Σ nᵢ — the pooled buffer's leading dim (conservation anchor)."""
        return self.offsets[-1] + self.sizes[-1] if self.sizes else 0

    @property
    def max_size(self) -> int:
        return max(self.sizes) if self.sizes else 0

    @property
    def min_size(self) -> int:
        return min(self.sizes) if self.sizes else 0

    @property
    def uniform(self) -> bool:
        """True iff every client holds the same number of rows — the
        degenerate case that must reproduce the rectangular engine bit
        for bit."""
        return len(set(self.sizes)) <= 1

    @property
    def padding(self) -> int:
        """Zero rows appended after the last client's slice so that a
        static ``max(nᵢ)``-length block slice starting at *any* client's
        offset stays in bounds (``dynamic_slice`` would otherwise clamp
        the start and silently shift the window).  0 for uniform specs.
        """
        return self.max_size - self.sizes[-1] if self.sizes else 0

    @property
    def buffer_rows(self) -> int:
        """Leading dim of the pooled buffer: Σnᵢ + padding.  The data
        rows are still exactly ``total`` — padding rows are never
        addressed by any client's CSR slice."""
        return self.total + self.padding

    def client_slice(self, i: int) -> slice:
        """Host-side CSR slice of client i's rows in the pooled buffer."""
        return slice(self.offsets[i], self.offsets[i] + self.sizes[i])

    # --- device-side index vectors --------------------------------------
    def offsets_array(self) -> jnp.ndarray:
        """(N,) int32 row offsets — the dynamic-gather companion of the
        static spec (the compacted engine indexes it by plan slot)."""
        return jnp.asarray(self.offsets, jnp.int32)

    def sizes_array(self) -> jnp.ndarray:
        """(N,) int32 per-client sizes."""
        return jnp.asarray(self.sizes, jnp.int32)

    # --- codec ----------------------------------------------------------
    def split(self, pooled) -> list:
        """Pooled (Σnᵢ, ...) array → list of per-client (nᵢ, ...) views."""
        return [np.asarray(pooled)[self.client_slice(i)]
                for i in range(self.n_clients)]

    def permute(self, perm: Sequence[int]) -> "RaggedSpec":
        """Spec for the client order ``perm`` (new client j is old
        ``perm[j]``) — used with :func:`pool_rows` after mesh balancing;
        re-pool the shards in the same order so rows stay contiguous."""
        return make_ragged_spec([self.sizes[int(p)] for p in perm],
                                max_buckets=max(len(self.buckets), 1))


def _bucket_plan(sizes: Sequence[int],
                 max_buckets: int) -> tuple[RaggedBucket, ...]:
    """Deterministic size-bucket assignment.

    Capacities are the unique shard sizes when few, else the maxima of
    ``max_buckets`` contiguous groups of the sorted unique sizes; each
    client joins the smallest bucket that fits its shard.  Members stay
    in ascending client order, so a uniform spec yields one bucket whose
    member list is exactly ``range(N)`` — the identity layout the
    bit-for-bit parity relies on.
    """
    uniq = sorted({int(s) for s in sizes})
    if len(uniq) <= max_buckets:
        caps = uniq
    else:
        caps = [int(group[-1])
                for group in np.array_split(np.asarray(uniq), max_buckets)
                if len(group)]
    buckets = []
    for cap in caps:
        members = tuple(i for i, s in enumerate(sizes)
                        if s <= cap and not any(s <= c for c in caps
                                                if c < cap))
        if members:
            buckets.append(RaggedBucket(
                capacity=cap, members=members,
                padded=any(sizes[i] < cap for i in members)))
    return tuple(buckets)


def make_ragged_spec(sizes: Iterable[int], *,
                     max_buckets: int = 4) -> RaggedSpec:
    """Build the static CSR spec for per-client shard sizes ``sizes``."""
    sizes = tuple(int(s) for s in sizes)
    if not sizes:
        raise ValueError("ragged spec needs at least one client")
    if any(s <= 0 for s in sizes):
        raise ValueError(f"client shard sizes must be positive: {sizes}")
    if max_buckets < 1:
        raise ValueError(f"max_buckets must be >= 1, got {max_buckets}")
    offsets = tuple(int(o) for o in np.cumsum((0,) + sizes[:-1]))
    return RaggedSpec(sizes=sizes, offsets=offsets,
                      buckets=_bucket_plan(sizes, max_buckets))


def pool_rows(shards: Sequence, *, max_buckets: int = 4):
    """Concatenate per-client (nᵢ, ...) shards into the pooled buffer.

    Returns ``(pooled, spec)`` with ``pooled.shape[0] ==
    spec.buffer_rows``: the first ``spec.total`` rows are every example
    of every shard in client order — none dropped (the conservation
    guarantee the partitioners assert) — followed by ``spec.padding``
    zero rows that keep static block slices in bounds (see
    :attr:`RaggedSpec.padding`; no CSR slice ever addresses them).
    """
    shards = [np.asarray(s) for s in shards]
    spec = make_ragged_spec([len(s) for s in shards],
                            max_buckets=max_buckets)
    parts = list(shards)
    if spec.padding:
        parts.append(np.zeros((spec.padding,) + shards[0].shape[1:],
                              shards[0].dtype))
    pooled = np.concatenate(parts, axis=0)
    assert pooled.shape[0] == spec.buffer_rows, \
        (pooled.shape, spec.buffer_rows)
    return pooled, spec


def pool_data(xs: Sequence, ys: Sequence, *, max_buckets: int = 4):
    """Pool parallel x/y shard lists into a round-engine data dict.

    Returns ``(data, spec)`` where ``data = {"x": (Σnᵢ, ...),
    "y": (Σnᵢ,)}`` jnp arrays share one spec (x/y shard lengths must
    agree per client).
    """
    if [len(s) for s in xs] != [len(s) for s in ys]:
        raise ValueError("x and y shard sizes disagree")
    pooled_x, spec = pool_rows(xs, max_buckets=max_buckets)
    pooled_y, _ = pool_rows(ys, max_buckets=max_buckets)
    return {"x": jnp.asarray(pooled_x), "y": jnp.asarray(pooled_y)}, spec
