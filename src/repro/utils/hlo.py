"""HLO-text analysis: collective inventory for the roofline.

`cost_analysis()` does not expose collective traffic, so we parse the
compiled (post-SPMD) HLO.  Shapes in the compiled module are already
per-device, so summed operand bytes are per-chip quantities — exactly
what the roofline's collective term wants.

Ring-algorithm byte multipliers (bytes actually serialized on links,
per device, group size n):
    all-gather       result_bytes · (n−1)/n
    reduce-scatter   operand_bytes · (n−1)/n
    all-reduce       2 · operand_bytes · (n−1)/n   (RS + AG)
    all-to-all       operand_bytes · (n−1)/n
    collective-permute  operand_bytes
"""
from __future__ import annotations

import re
from collections import defaultdict


def cost_analysis_dict(ca) -> dict:
    """Normalize ``Compiled.cost_analysis()`` across jax versions.

    jax < 0.4.35 returns a list with one properties-dict per program;
    newer versions return the dict directly (and either may be None when
    the backend provides no analysis).  ``dict(list_of_dicts)`` raises
    ``ValueError: dictionary update sequence element #0 has length 53``,
    which used to error every dry-run on version drift.
    """
    if ca is None:
        return {}
    if isinstance(ca, dict):
        return dict(ca)
    if isinstance(ca, (list, tuple)):
        out: dict = {}
        for entry in ca:
            if entry:
                out.update(entry)
        return out
    return dict(ca)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+"
    r"(all-reduce-start|all-reduce|all-gather-start|all-gather|"
    r"reduce-scatter|all-to-all|collective-permute-start|"
    r"collective-permute)\b(.*)$")
_GROUPS_RE = re.compile(r"replica_groups=\{(.*?)\}\s*(?:,|$)")
_GROUPS_SHAPE_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(rest: str, default: int) -> int:
    m = _GROUPS_SHAPE_RE.search(rest)
    if m:  # replica_groups=[G,S]<=[...] form: G groups of size S
        return int(m.group(2))
    m = _GROUPS_RE.search(rest)
    if m:
        first = m.group(1).split("}")[0].lstrip("{")
        ids = [x for x in first.split(",") if x.strip() != ""]
        if ids:
            return len(ids)
    return default


def collective_inventory(hlo_text: str, *, world_size: int):
    """Per-op-kind collective byte totals (per device).

    Returns dict kind → {"count": int, "bytes": payload-on-link bytes,
    "raw_bytes": tensor bytes}.
    """
    inv = defaultdict(lambda: {"count": 0, "bytes": 0.0, "raw_bytes": 0.0})
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        shape_str, kind, rest = m.groups()
        kind = kind.replace("-start", "")
        size = _shape_bytes(shape_str)
        n = _group_size(rest, world_size)
        frac = (n - 1) / n if n > 1 else 0.0
        if kind == "all-reduce":
            moved = 2.0 * size * frac
        elif kind == "all-gather":
            moved = size * frac
        elif kind == "reduce-scatter":
            moved = size * frac
        elif kind == "all-to-all":
            moved = size * frac
        else:  # collective-permute
            moved = float(size)
        inv[kind]["count"] += 1
        inv[kind]["bytes"] += moved
        inv[kind]["raw_bytes"] += float(size)
    return dict(inv)


def total_collective_bytes(hlo_text: str, *, world_size: int) -> float:
    inv = collective_inventory(hlo_text, world_size=world_size)
    return sum(v["bytes"] for v in inv.values())


def count_op(hlo_text: str, opname: str) -> int:
    """Number of <opname>(...) call sites (not name mentions)."""
    return len(re.findall(rf"\b{re.escape(opname)}\(", hlo_text))


def jaxpr_eqn_counts(jaxpr) -> dict:
    """Primitive-name → count over a jaxpr, recursing into sub-jaxprs.

    Accepts a ``ClosedJaxpr`` (what ``jax.make_jaxpr`` returns) or a raw
    ``Jaxpr``.  Descends into every jaxpr-valued equation param (pjit
    bodies, scan/while/cond branches, custom-call wrappers) so kernels
    wrapped in nested ``jax.jit`` are still counted — this is what the
    fused-round op-count assertions use (one Pallas ``pallas_call`` per
    fused pass, no duplicated elementwise sweeps).
    """
    from collections import Counter

    counts: Counter = Counter()

    def visit_param(v):
        if hasattr(v, "eqns"):  # Jaxpr
            visit(v)
        elif hasattr(v, "jaxpr"):  # ClosedJaxpr
            visit(v.jaxpr)
        elif isinstance(v, (list, tuple)):
            for item in v:
                visit_param(item)

    def visit(jx):
        for eqn in jx.eqns:
            counts[eqn.primitive.name] += 1
            for v in eqn.params.values():
                visit_param(v)

    visit(jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr)
    return dict(counts)


def toplevel_elementwise_shapes(jaxpr, prims=("add", "sub", "mul")) -> list:
    """Output shapes of top-level elementwise eqns (no sub-jaxpr
    descent, but pjit bodies are inlined one level).

    Used to assert the flat round has no separate full-width λ/z/center
    HBM sweeps outside the fused kernel: any surviving top-level
    add/sub over the whole (N, D) state shows up here.
    """
    shapes = []

    def visit(jx, depth):
        for eqn in jx.eqns:
            if eqn.primitive.name in prims:
                shapes.extend(tuple(ov.aval.shape) for ov in eqn.outvars)
            elif eqn.primitive.name in ("pjit", "closed_call") and depth < 1:
                for v in eqn.params.values():
                    if hasattr(v, "jaxpr"):
                        visit(v.jaxpr, depth + 1)

    visit(jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr, 0)
    return shapes
