"""HLO-text and jaxpr analysis: collective inventory, donation
aliases, entry signatures, op budgets.

`cost_analysis()` does not expose collective traffic, so we parse the
compiled (post-SPMD) HLO.  Shapes in the compiled module are already
per-device, so summed operand bytes are per-chip quantities — exactly
what the roofline's collective term wants.  The same parsers back the
``repro.analysis`` rule engine: donation audits read the module
header's ``input_output_alias`` map, collective budgets read the
inventory, host-transfer bans read instruction sites.

Ring-algorithm byte multipliers (bytes actually serialized on links,
per device, group size n):
    all-gather       result_bytes · (n−1)/n
    reduce-scatter   operand_bytes · (n−1)/n
    all-reduce       2 · operand_bytes · (n−1)/n   (RS + AG)
    all-to-all       operand_bytes · (n−1)/n
    collective-permute  operand_bytes
"""
from __future__ import annotations

import math
import re
from collections import defaultdict


def cost_analysis_dict(ca) -> dict:
    """Normalize ``Compiled.cost_analysis()`` across jax versions.

    jax < 0.4.35 returns a list with one properties-dict per program;
    newer versions return the dict directly (and either may be None when
    the backend provides no analysis).  ``dict(list_of_dicts)`` raises
    ``ValueError: dictionary update sequence element #0 has length 53``,
    which used to error every dry-run on version drift.
    """
    if ca is None:
        return {}
    if isinstance(ca, dict):
        return dict(ca)
    if isinstance(ca, (list, tuple)):
        out: dict = {}
        for entry in ca:
            if entry:
                out.update(entry)
        return out
    return dict(ca)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
    # low-precision families (one byte unless noted)
    "f8e4m3": 1, "f8e5m2": 1, "f8e4m3fn": 1, "f8e4m3fnuz": 1,
    "f8e4m3b11fnuz": 1, "f8e5m2fnuz": 1, "f8e3m4": 1, "f8e8m0fnu": 1,
    "f4e2m1fn": 0.5, "s4": 0.5, "u4": 0.5,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+"
    r"(all-reduce-start|all-reduce|all-gather-start|all-gather|"
    r"reduce-scatter|all-to-all|collective-permute-start|"
    r"collective-permute)\b(.*)$")
# iota form: replica_groups=[G,S]<=[...] (G groups of S) or [N]<=[...]
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[([\d,]+)\]<=\[")


def _shape_bytes(shape_str: str) -> int:
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return int(math.ceil(total))


def _balanced_braces(text: str, start: int) -> str | None:
    """Contents of the brace group opening at ``text[start] == '{'``."""
    if start < 0 or start >= len(text) or text[start] != "{":
        return None
    depth, j = 0, start
    while j < len(text):
        if text[j] == "{":
            depth += 1
        elif text[j] == "}":
            depth -= 1
            if depth == 0:
                return text[start + 1:j]
        j += 1
    return None


def _group_size(rest: str, default: int) -> int:
    """Size of the largest replica group named on a collective line.

    Handles the explicit list form (``replica_groups={{0,1},{2,3,4,5}}``
    → 4, not the first group's 2), the flat single-group form
    (``replica_groups={0,1,2}`` → 3) and both iota forms
    (``[G,S]<=[...]`` → S, ``[N]<=[...]`` → N).  Falls back to
    ``default`` (the world size) when no group annotation is present.
    """
    m = _GROUPS_IOTA_RE.search(rest)
    if m:
        dims = [int(d) for d in m.group(1).split(",") if d]
        if dims:
            return dims[-1]
    key = "replica_groups="
    at = rest.find(key)
    if at >= 0:
        body = _balanced_braces(rest, at + len(key))
        if body is not None:
            groups = re.findall(r"\{([^{}]*)\}", body)
            if groups:  # explicit list of groups
                sizes = [len([t for t in g.split(",") if t.strip()])
                         for g in groups]
                sizes = [s for s in sizes if s > 0]
                if sizes:
                    return max(sizes)
            else:  # one flat group
                ids = [t for t in body.split(",") if t.strip()]
                if ids:
                    return len(ids)
    return default


def collective_inventory(hlo_text: str, *, world_size: int):
    """Per-op-kind collective byte totals (per device).

    Returns dict kind → {"count": int, "bytes": payload-on-link bytes,
    "raw_bytes": tensor bytes}.
    """
    inv = defaultdict(lambda: {"count": 0, "bytes": 0.0, "raw_bytes": 0.0})
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        shape_str, kind, rest = m.groups()
        kind = kind.replace("-start", "")
        size = _shape_bytes(shape_str)
        n = _group_size(rest, world_size)
        frac = (n - 1) / n if n > 1 else 0.0
        if kind == "all-reduce":
            moved = 2.0 * size * frac
        elif kind == "all-gather":
            moved = size * frac
        elif kind == "reduce-scatter":
            moved = size * frac
        elif kind == "all-to-all":
            moved = size * frac
        else:  # collective-permute
            moved = float(size)
        inv[kind]["count"] += 1
        inv[kind]["bytes"] += moved
        inv[kind]["raw_bytes"] += float(size)
    return dict(inv)


def total_collective_bytes(hlo_text: str, *, world_size: int) -> float:
    inv = collective_inventory(hlo_text, world_size=world_size)
    return sum(v["bytes"] for v in inv.values())


def count_op(hlo_text: str, opname: str) -> int:
    """Number of ``<opname>(...)`` *instruction sites*.

    Only counts lines of the form ``%name = <shape> <opname>(...)`` —
    bare name mentions inside fusion labels, ``calls=`` references or
    ``metadata={op_name="..."}`` strings do not match.
    """
    pat = re.compile(
        r"=\s*(?:\([^)]*\)|[\w\[\],{}]+)\s+" + re.escape(opname) + r"\(")
    return sum(1 for line in hlo_text.splitlines() if pat.search(line))


_ALIAS_PAIR_RE = re.compile(
    r"\{([\d\s,]*)\}:\s*\((\d+),\s*\{([\d\s,]*)\}\s*(?:,\s*([\w-]+))?\)")


def parse_input_output_aliases(hlo_text: str) -> list:
    """Donation/aliasing map from the module header.

    Parses ``input_output_alias={ {0}: (0, {}, may-alias), ... }`` into
    a list of ``{"output_index", "param_number", "param_index",
    "kind"}`` dicts.  Empty when the module declares no aliasing (e.g.
    a jit without donated arguments).
    """
    key = "input_output_alias="
    at = hlo_text.find(key)
    if at < 0:
        return []
    body = _balanced_braces(hlo_text, at + len(key))
    if body is None:
        return []
    out = []
    for m in _ALIAS_PAIR_RE.finditer(body):
        out.append({
            "output_index": tuple(
                int(t) for t in m.group(1).split(",") if t.strip()),
            "param_number": int(m.group(2)),
            "param_index": tuple(
                int(t) for t in m.group(3).split(",") if t.strip()),
            "kind": m.group(4) or "may-alias",
        })
    return out


_PARAM_RE = re.compile(r"([%\w.\-]+)\s*:\s*(\w+)\[([\d,]*)\]")


def entry_parameters(hlo_text: str) -> list:
    """``[(name, dtype, shape)]`` of the ENTRY computation's parameters.

    Shapes are per-device in a post-SPMD module, so together with
    :func:`parse_input_output_aliases` this answers "which state
    buffers does the compiled round update in place".
    """
    for line in hlo_text.splitlines():
        ls = line.strip()
        if not ls.startswith("ENTRY "):
            continue
        head = ls.split(" -> ")[0]
        lp = head.find("(")
        if lp < 0:
            return []
        sig = head[lp + 1:]
        if sig.endswith(")"):
            sig = sig[:-1]
        return [
            (name, dtype, tuple(int(d) for d in dims.split(",") if d))
            for name, dtype, dims in _PARAM_RE.findall(sig)
        ]
    return []


#: numpy dtype name → HLO dtype token (for matching state leaves
#: against entry-parameter shapes).
NUMPY_TO_HLO_DTYPE = {
    "bool": "pred", "int8": "s8", "uint8": "u8", "int16": "s16",
    "uint16": "u16", "bfloat16": "bf16", "float16": "f16",
    "int32": "s32", "uint32": "u32", "float32": "f32", "int64": "s64",
    "uint64": "u64", "float64": "f64", "complex64": "c64",
    "complex128": "c128",
}


def count_dtype_refs(hlo_text: str, dtype: str = "f64") -> int:
    """Occurrences of ``dtype[...]`` shapes anywhere in the module."""
    return len(re.findall(rf"\b{re.escape(dtype)}\[", hlo_text))


#: HLO opcodes that move data across the host boundary.
HOST_TRANSFER_OPS = ("infeed", "outfeed", "send", "recv")


def count_host_transfer_ops(hlo_text: str) -> int:
    """Host-boundary instruction sites: infeed/outfeed/send/recv plus
    python-callback custom-calls."""
    n = sum(count_op(hlo_text, op) for op in HOST_TRANSFER_OPS)
    n += len(re.findall(r'custom_call_target="[^"]*callback[^"]*"',
                        hlo_text))
    return n


def jaxpr_eqn_counts(jaxpr) -> dict:
    """Primitive-name → count over a jaxpr, recursing into sub-jaxprs.

    Accepts a ``ClosedJaxpr`` (what ``jax.make_jaxpr`` returns) or a raw
    ``Jaxpr``.  Descends into every jaxpr-valued equation param (pjit
    bodies, scan/while/cond branches, custom-call wrappers) so kernels
    wrapped in nested ``jax.jit`` are still counted — this is what the
    fused-round op-count assertions use (one Pallas ``pallas_call`` per
    fused pass, no duplicated elementwise sweeps).
    """
    from collections import Counter

    counts: Counter = Counter()

    def visit_param(v):
        if hasattr(v, "eqns"):  # Jaxpr
            visit(v)
        elif hasattr(v, "jaxpr"):  # ClosedJaxpr
            visit(v.jaxpr)
        elif isinstance(v, (list, tuple)):
            for item in v:
                visit_param(item)

    def visit(jx):
        for eqn in jx.eqns:
            counts[eqn.primitive.name] += 1
            for v in eqn.params.values():
                visit_param(v)

    visit(jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr)
    return dict(counts)


def jaxpr_pallas_kernel_names(jaxpr) -> dict:
    """Kernel-function-name → count over every ``pallas_call`` equation.

    Recurses like :func:`jaxpr_eqn_counts`; the name comes from the
    equation's ``name_and_src_info`` param (the kernel body's python
    function name, e.g. ``_kernel3`` / ``_fused_gss3``), so rules can
    budget *which* kernels a round launches, not just how many.
    Unnamed pallas calls count under ``"<unknown>"``.
    """
    from collections import Counter

    counts: Counter = Counter()

    def visit_param(v):
        if hasattr(v, "eqns"):
            visit(v)
        elif hasattr(v, "jaxpr"):
            visit(v.jaxpr)
        elif isinstance(v, (list, tuple)):
            for item in v:
                visit_param(item)

    def visit(jx):
        for eqn in jx.eqns:
            if eqn.primitive.name == "pallas_call":
                info = eqn.params.get("name_and_src_info")
                counts[getattr(info, "name", None) or "<unknown>"] += 1
            for v in eqn.params.values():
                visit_param(v)

    visit(jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr)
    return dict(counts)


def jaxpr_dtypes(jaxpr) -> set:
    """Set of output dtype names over all equations (recursive).

    The static half of the no-f64 rule: a stray ``float64`` promotion
    (x64 mode, a numpy scalar leaking in) shows up in the jaxpr long
    before the compiled module.
    """
    dtypes: set = set()

    def visit_param(v):
        if hasattr(v, "eqns"):
            visit(v)
        elif hasattr(v, "jaxpr"):
            visit(v.jaxpr)
        elif isinstance(v, (list, tuple)):
            for item in v:
                visit_param(item)

    def visit(jx):
        for eqn in jx.eqns:
            for ov in eqn.outvars:
                dt = getattr(ov.aval, "dtype", None)
                if dt is not None:
                    dtypes.add(str(dt))
            for v in eqn.params.values():
                visit_param(v)

    visit(jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr)
    return dtypes


def toplevel_elementwise_shapes(jaxpr, prims=("add", "sub", "mul")) -> list:
    """Output shapes of top-level elementwise eqns (no sub-jaxpr
    descent, but pjit bodies are inlined one level).

    Used to assert the flat round has no separate full-width λ/z/center
    HBM sweeps outside the fused kernel: any surviving top-level
    add/sub over the whole (N, D) state shows up here.
    """
    shapes = []

    def visit(jx, depth):
        for eqn in jx.eqns:
            if eqn.primitive.name in prims:
                shapes.extend(tuple(ov.aval.shape) for ov in eqn.outvars)
            elif eqn.primitive.name in ("pjit", "closed_call") and depth < 1:
                for v in eqn.params.values():
                    if hasattr(v, "jaxpr"):
                        visit(v.jaxpr, depth + 1)

    visit(jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr, 0)
    return shapes
