"""Pytree utilities used across the framework.

Conventions
-----------
* "stacked" pytrees carry a leading client axis of size N on every leaf
  (client i's state is ``tree_index(stacked, i)``).
* All norms are *global* L2 norms across every leaf (the paper's
  ``|.|`` over the flattened parameter vector θ ∈ R^d).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_zeros_like(tree):
    return jax.tree.map(jnp.zeros_like, tree)


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(tree, c):
    return jax.tree.map(lambda x: x * c, tree)


def tree_axpy(a, x, y):
    """a*x + y, leafwise."""
    return jax.tree.map(lambda xl, yl: a * xl + yl, x, y)


def tree_dot(a, b):
    """Global inner product across all leaves (fp32 accumulation)."""
    parts = jax.tree.map(
        lambda x, y: jnp.vdot(x.astype(jnp.float32), y.astype(jnp.float32)), a, b
    )
    return jax.tree.reduce(jnp.add, parts, jnp.float32(0.0))


def tree_sq_norm(tree):
    parts = jax.tree.map(
        lambda x: jnp.sum(jnp.square(x.astype(jnp.float32))), tree
    )
    return jax.tree.reduce(jnp.add, parts, jnp.float32(0.0))


def tree_norm(tree):
    return jnp.sqrt(tree_sq_norm(tree))


def tree_stack(trees):
    """Stack a list of pytrees along a new leading axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def tree_unstack(tree, n):
    return [jax.tree.map(lambda x: x[i], tree) for i in range(n)]


def tree_index(tree, i):
    return jax.tree.map(lambda x: x[i], tree)


def tree_broadcast_like(tree, n):
    """Tile a pytree along a new leading client axis of size n."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), tree
    )


def tree_where(mask, a, b):
    """Leafwise select with a per-client boolean mask over the leading axis.

    mask: (N,) bool; a, b: stacked pytrees with leading axis N.
    """

    def sel(x, y):
        m = mask.reshape((-1,) + (1,) * (x.ndim - 1))
        return jnp.where(m, x, y)

    return jax.tree.map(sel, a, b)


def stacked_sq_norms(stacked_diff):
    """Per-client global squared norms of a stacked pytree.

    Returns (N,) fp32 vector: ``r_i = Σ_leaves ‖leaf[i]‖²``.
    """
    parts = jax.tree.map(
        lambda x: jnp.sum(
            jnp.square(x.astype(jnp.float32)).reshape(x.shape[0], -1), axis=1
        ),
        stacked_diff,
    )
    return jax.tree.reduce(jnp.add, parts, jnp.float32(0.0))


def tree_size(tree):
    """Total number of scalars in the pytree."""
    return sum(
        int(np.prod(x.shape))  # tracecheck: ok (static shapes)
        for x in jax.tree.leaves(tree))


def tree_bytes(tree):
    return sum(
        int(np.prod(x.shape))  # tracecheck: ok (static shapes)
        * jnp.dtype(x.dtype).itemsize
        for x in jax.tree.leaves(tree)
    )


def tree_cast(tree, dtype):
    return jax.tree.map(lambda x: x.astype(dtype), tree)


def tree_ravel(tree):
    """Flatten a pytree into a single 1-D vector (fp32)."""
    leaves = [x.astype(jnp.float32).reshape(-1) for x in jax.tree.leaves(tree)]
    return jnp.concatenate(leaves) if leaves else jnp.zeros((0,), jnp.float32)
