"""GQA attention: blockwise (memory-efficient) prefill + cached decode.

The training/prefill path never materializes the (S, S) score matrix:
it scans KV blocks with an online-softmax carry, so 32k-token prefill
fits activation memory even on the XLA (non-Pallas) path.  The Pallas
flash kernel (repro.kernels.flash_attention) is the TPU hot path for the
same contraction; this module is the lowering-friendly fallback and the
oracle the kernel is tested against.

Masks are index predicates (never materialized tensors):
  causal        kv ≤ q
  sliding(W)    q−W < kv ≤ q          (Mixtral; Zamba2 shared block @500k)
  prefix(P)     kv ≤ q  or  kv < P    (PaliGemma prefix-LM)
  bidir         all                   (HuBERT encoder)

Note: the blockwise scan visits *all* KV blocks and masks — causal
attention therefore costs ~2× its optimal FLOPs on this path. This is
deliberate baseline honesty (see EXPERIMENTS §Perf for the hillclimb
that claws it back; the flash kernel's triangular grid does it on TPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import apply_rope, dense_init

NEG_INF = -1e30


def attention_init(key, d_model, num_heads, num_kv_heads, head_dim, dtype):
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": dense_init(kq, d_model, num_heads * head_dim, dtype),
        "wk": dense_init(kk, d_model, num_kv_heads * head_dim, dtype),
        "wv": dense_init(kv, d_model, num_kv_heads * head_dim, dtype),
        "wo": dense_init(ko, num_heads * head_dim, d_model, dtype),
    }


def _allowed(q_pos, kv_pos, *, mask_mode, window, prefix_len):
    """Boolean mask (…, Sq, Skv) from position indices."""
    q = q_pos[..., :, None]
    k = kv_pos[..., None, :]
    if mask_mode == "bidir":
        ok = jnp.ones(jnp.broadcast_shapes(q.shape, k.shape), bool)
    elif mask_mode == "causal":
        ok = k <= q
    elif mask_mode == "prefix":
        ok = (k <= q) | (k < prefix_len)
    else:
        raise ValueError(mask_mode)
    if window:
        ok = ok & (k > q - window)
    return ok


def blockwise_attention(q, k, v, *, q_positions, kv_positions, kv_valid=None,
                        mask_mode="causal", window=0, prefix_len=0,
                        kv_block=512, unroll=False):
    """Online-softmax attention.

    q: (B, Sq, H, hd); k, v: (B, Skv, Kv, hd); positions: (Sq,) / (Skv,).
    Returns (B, Sq, H, hd).
    """
    b, sq, h, hd = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    scale = hd ** -0.5
    if kv_valid is None:
        kv_valid = jnp.ones((skv,), bool)

    # pad KV to a block multiple
    nb = -(-skv // kv_block)
    pad = nb * kv_block - skv
    if pad:
        padkv = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k, v = padkv(k), padkv(v)
        kv_positions = jnp.pad(kv_positions, (0, pad))
        kv_valid = jnp.pad(kv_valid, (0, pad))

    qg = (q * scale).reshape(b, sq, kvh, g, hd)
    kb = k.reshape(b, nb, kv_block, kvh, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nb, kv_block, kvh, hd).transpose(1, 0, 2, 3, 4)
    pos_b = kv_positions.reshape(nb, kv_block)
    val_b = kv_valid.reshape(nb, kv_block)

    m0 = jnp.full((b, sq, kvh, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, sq, kvh, g), jnp.float32)
    a0 = jnp.zeros((b, sq, kvh, g, hd), jnp.float32)

    def body(carry, xs):
        m, l, acc = carry
        kblk, vblk, pos, val = xs
        s = jnp.einsum("bskgh,btkh->bskgt", qg, kblk,
                       preferred_element_type=jnp.float32)
        ok = _allowed(q_positions, pos, mask_mode=mask_mode, window=window,
                      prefix_len=prefix_len) & val[None, :]  # (Sq, t)
        s = jnp.where(ok[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bskgt,btkh->bskgh", p.astype(vblk.dtype), vblk,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kb, vb, pos_b, val_b),
                                  unroll=nb if unroll else 1)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, sq, h, hd).astype(q.dtype)


def attention_forward(p, x, *, positions, rope_theta, num_heads, num_kv_heads,
                      head_dim, mask_mode="causal", window=0, prefix_len=0,
                      kv_block=512, return_kv=False, unroll=False):
    """Self-attention over x: (B, S, d)."""
    b, s, d = x.shape
    q = (x @ p["wq"]).reshape(b, s, num_heads, head_dim)
    k = (x @ p["wk"]).reshape(b, s, num_kv_heads, head_dim)
    v = (x @ p["wv"]).reshape(b, s, num_kv_heads, head_dim)
    q = apply_rope(q, positions[None, :], rope_theta)
    k = apply_rope(k, positions[None, :], rope_theta)
    out = blockwise_attention(
        q, k, v, q_positions=positions, kv_positions=positions,
        mask_mode=mask_mode, window=window, prefix_len=prefix_len,
        kv_block=min(kv_block, s), unroll=unroll)
    y = out.reshape(b, s, num_heads * head_dim) @ p["wo"]
    return (y, (k, v)) if return_kv else y


def attention_decode(p, x, kv_cache, cache_pos, *, rope_theta, num_heads,
                     num_kv_heads, head_dim, window=0):
    """Single-token decode against a (B, S_max, Kv, hd) ring/linear cache.

    x: (B, 1, d); cache_pos: () int32 — the position being generated.
    With a sliding window the cache is a ring buffer of size W and
    absolute positions are reconstructed modulo W.
    """
    b = x.shape[0]
    k_cache, v_cache = kv_cache
    s_max = k_cache.shape[1]
    q = (x @ p["wq"]).reshape(b, 1, num_heads, head_dim)
    k = (x @ p["wk"]).reshape(b, 1, num_kv_heads, head_dim)
    v = (x @ p["wv"]).reshape(b, 1, num_kv_heads, head_dim)
    pos = cache_pos[None]  # (1,)
    q = apply_rope(q, pos[None, :], rope_theta)
    k = apply_rope(k, pos[None, :], rope_theta)

    slot = jnp.where(window > 0, cache_pos % s_max, cache_pos) if window \
        else cache_pos
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k, slot, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v, slot, axis=1)

    # absolute positions of cache slots
    idx = jnp.arange(s_max)
    if window:
        # ring buffer: slot holds the latest position ≡ slot (mod s_max)
        kv_pos = cache_pos - ((cache_pos - idx) % s_max)
        valid = (kv_pos >= 0) & (kv_pos >= cache_pos - window + 1)
    else:
        kv_pos = idx
        valid = idx <= cache_pos

    g = num_heads // num_kv_heads
    scale = head_dim ** -0.5
    qg = (q * scale).reshape(b, num_kv_heads, g, head_dim).astype(jnp.float32)
    s = jnp.einsum("bkgh,btkh->bkgt", qg, k_cache.astype(jnp.float32))
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,btkh->bkgh", w, v_cache.astype(jnp.float32))
    out = out.reshape(b, 1, num_heads * head_dim).astype(x.dtype)
    return out @ p["wo"], (k_cache, v_cache)
