"""The paper's experiment models.

* ``init_mlp`` / MNIST classifier — single hidden layer, 200 ReLU units
  (paper §5 MNIST).
* ``init_cnn`` / CIFAR classifier — 3 conv + 3 fc layers, ReLU
  (paper §5 CIFAR-10).

Pure-functional: params are dict pytrees; apply functions take flat
pixel inputs (the data pipeline stores images flat).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _dense_init(key, n_in, n_out):
    wk, _ = jax.random.split(key)
    scale = jnp.sqrt(2.0 / n_in)
    return {
        "w": jax.random.normal(wk, (n_in, n_out), jnp.float32) * scale,
        "b": jnp.zeros((n_out,), jnp.float32),
    }


def init_mlp(key, n_in: int = 784, hidden: int = 200, n_out: int = 10):
    k1, k2 = jax.random.split(key)
    return {"fc1": _dense_init(k1, n_in, hidden),
            "fc2": _dense_init(k2, hidden, n_out)}


def mlp_logits(params, x):
    h = jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"])
    return h @ params["fc2"]["w"] + params["fc2"]["b"]


def _conv_init(key, kh, kw, cin, cout):
    scale = jnp.sqrt(2.0 / (kh * kw * cin))
    return {
        "w": jax.random.normal(key, (kh, kw, cin, cout), jnp.float32) * scale,
        "b": jnp.zeros((cout,), jnp.float32),
    }


def init_cnn(key, image_hw: int = 32, channels: int = 3, n_out: int = 10):
    ks = jax.random.split(key, 6)
    params = {
        "conv1": _conv_init(ks[0], 3, 3, channels, 32),
        "conv2": _conv_init(ks[1], 3, 3, 32, 64),
        "conv3": _conv_init(ks[2], 3, 3, 64, 64),
    }
    feat = (image_hw // 8) ** 2 * 64  # three stride-2 pools
    params["fc1"] = _dense_init(ks[3], feat, 128)
    params["fc2"] = _dense_init(ks[4], 128, 64)
    params["fc3"] = _dense_init(ks[5], 64, n_out)
    return params


def _conv_block(p, x):
    y = jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    y = jax.nn.relu(y + p["b"])
    return jax.lax.reduce_window(
        y, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


def cnn_logits(params, x, image_hw: int = 32, channels: int = 3):
    x = x.reshape(x.shape[0], image_hw, image_hw, channels)
    for name in ("conv1", "conv2", "conv3"):
        x = _conv_block(params[name], x)
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"])
    x = jax.nn.relu(x @ params["fc2"]["w"] + params["fc2"]["b"])
    return x @ params["fc3"]["w"] + params["fc3"]["b"]


def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def make_loss_fn(logits_fn):
    def loss_fn(params, x, y):
        return cross_entropy(logits_fn(params, x), y)

    return loss_fn


def make_loss_and_acc_fn(logits_fn):
    def fn(params, x, y):
        logits = logits_fn(params, x)
        loss = cross_entropy(logits, y)
        acc = jnp.mean((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
        return loss, acc

    return fn
