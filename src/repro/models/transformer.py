"""Unified stack assembler for the assigned architecture families.

One init/forward pair covers:
  dense   — [GQA attn + SwiGLU] × L                 (deepseek/granite/phi3)
  moe     — [GQA attn + top-k MoE] × L              (qwen3/mixtral/moonshot)
  ssm     — [Mamba-2 mixer] × L                     (mamba2)
  hybrid  — Mamba-2 backbone + ONE shared attn+MLP block applied after
            every ``attn_every`` mamba layers (Zamba2's shared-block
            design: the same parameters are re-applied at 9 depths)
  vlm     — dense decoder with a patch-embedding projector and
            prefix-LM masking over the image tokens (PaliGemma)
  audio   — bidirectional encoder over frame embeddings (HuBERT)

Layer parameters are stacked (leading L axis) and the forward pass is a
(rematerialized) ``lax.scan``, so deepseek-67b's 95 layers lower to the
same HLO size as 2 layers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding.actshard import constrain_batch

from .attention import attention_decode, attention_forward, attention_init
from .layers import (
    chunked_lm_loss,
    dense_init,
    embed_init,
    rmsnorm,
    rmsnorm_init,
    stacked_init,
    swiglu,
    swiglu_init,
)
from .moe import moe_apply, moe_init
from .ssm import (
    ssm_cache_init,
    ssm_decode_step,
    ssm_forward,
    ssm_init,
)

# ----------------------------------------------------------------------
# per-layer blocks
# ----------------------------------------------------------------------


def _attn_block_init(key, cfg):
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": rmsnorm_init(cfg.d_model, cfg.param_dtype),
        "attn": attention_init(k1, cfg.d_model, cfg.num_heads,
                               cfg.num_kv_heads, cfg.head_dim,
                               cfg.param_dtype),
        "ln2": rmsnorm_init(cfg.d_model, cfg.param_dtype),
    }
    if cfg.family in ("moe",):
        p["moe"] = moe_init(k2, cfg.d_model, cfg.d_ff, cfg.num_experts,
                            cfg.param_dtype)
    else:
        p["mlp"] = swiglu_init(k2, cfg.d_model, cfg.d_ff, cfg.param_dtype)
    return p


def _attn_block_apply(cfg, p, h, positions, *, mask_mode, prefix_len,
                      window, return_kv=False):
    aux = jnp.zeros((), jnp.float32)
    h = constrain_batch(h)  # re-pin batch sharding inside the scan body
    x = rmsnorm(h, p["ln1"], cfg.norm_eps)
    att = attention_forward(
        p["attn"], x, positions=positions, rope_theta=cfg.rope_theta,
        num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.head_dim, mask_mode=mask_mode, prefix_len=prefix_len,
        window=window, kv_block=cfg.kv_block, return_kv=return_kv,
        unroll=cfg.unroll_inner)
    if return_kv:
        att, kv = att
    h = h + att
    x = rmsnorm(h, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        y, aux = moe_apply(p["moe"], x, top_k=cfg.top_k,
                           capacity_factor=cfg.capacity_factor)
    else:
        y = swiglu(p["mlp"], x)
    h = constrain_batch(h + y)
    return (h, aux, kv) if return_kv else (h, aux)


def _attn_block_decode(cfg, p, h, kv_cache, pos, *, window):
    x = rmsnorm(h, p["ln1"], cfg.norm_eps)
    att, kv_cache = attention_decode(
        p["attn"], x, kv_cache, pos, rope_theta=cfg.rope_theta,
        num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.head_dim, window=window)
    h = h + att
    x = rmsnorm(h, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        y, _ = moe_apply(p["moe"], x, top_k=cfg.top_k,
                         capacity_factor=cfg.capacity_factor,
                         return_aux=False)
    else:
        y = swiglu(p["mlp"], x)
    return h + y, kv_cache


def _ssm_block_init(key, cfg):
    return {
        "ln": rmsnorm_init(cfg.d_model, cfg.param_dtype),
        "ssm": ssm_init(key, cfg.d_model, expand=cfg.expand,
                        ssm_state=cfg.ssm_state, head_dim=cfg.ssm_head_dim,
                        conv_kernel=cfg.conv_kernel, dtype=cfg.param_dtype),
    }


def _ssm_block_apply(cfg, p, h):
    h = constrain_batch(h)
    x = rmsnorm(h, p["ln"], cfg.norm_eps)
    return h + ssm_forward(
        p["ssm"], x, expand=cfg.expand, ssm_state=cfg.ssm_state,
        head_dim=cfg.ssm_head_dim, conv_kernel=cfg.conv_kernel,
        chunk=cfg.chunk,
        intra_dtype=jnp.float32 if cfg.ssd_intra_dtype == "float32_forced"
        else None)


def _ssm_block_decode(cfg, p, h, cache):
    x = rmsnorm(h, p["ln"], cfg.norm_eps)
    y, cache = ssm_decode_step(
        p["ssm"], x, cache, expand=cfg.expand, ssm_state=cfg.ssm_state,
        head_dim=cfg.ssm_head_dim, conv_kernel=cfg.conv_kernel)
    return h + y, cache


# ----------------------------------------------------------------------
# init
# ----------------------------------------------------------------------


def init_params(key, cfg):
    keys = jax.random.split(key, 8)
    params = {"final_ln": rmsnorm_init(cfg.d_model, cfg.param_dtype)}
    if cfg.family == "audio":
        params["frontend_proj"] = dense_init(
            keys[3], cfg.frontend_dim, cfg.d_model, cfg.param_dtype)
    else:
        params["embed"] = embed_init(keys[0], cfg.vocab_padded,
                                     cfg.d_model, cfg.param_dtype)
    params["lm_head"] = dense_init(keys[1], cfg.d_model, cfg.vocab_padded,
                                   cfg.param_dtype)
    if cfg.family == "vlm":
        params["patch_proj"] = dense_init(
            keys[4], cfg.frontend_dim, cfg.d_model, cfg.param_dtype)

    if cfg.family in ("dense", "moe", "vlm", "audio"):
        params["layers"] = stacked_init(
            lambda k: _attn_block_init(k, cfg), keys[2], cfg.num_layers)
    elif cfg.family == "ssm":
        params["layers"] = stacked_init(
            lambda k: _ssm_block_init(k, cfg), keys[2], cfg.num_layers)
    elif cfg.family == "hybrid":
        assert cfg.num_layers % cfg.attn_every == 0
        params["layers"] = stacked_init(
            lambda k: _ssm_block_init(k, cfg), keys[2], cfg.num_layers)
        params["shared"] = _attn_block_init(keys[5], cfg)
    else:
        raise ValueError(cfg.family)
    return params


# ----------------------------------------------------------------------
# forward stacks
# ----------------------------------------------------------------------


def _maybe_remat(cfg, fn):
    return jax.checkpoint(fn) if cfg.remat else fn


def _group(cfg, stacked):
    """Reshape stacked layer params (L, ...) → (L/G, G, ...) so each
    checkpoint unit spans G layers (activation stash ∝ L/G)."""
    g = cfg.remat_group if cfg.num_layers % max(cfg.remat_group, 1) == 0 \
        else 1
    if g <= 1:
        return 1, stacked
    return g, jax.tree.map(
        lambda x: x.reshape((x.shape[0] // g, g) + x.shape[1:]), stacked)


def _stack_attn(cfg, params, h, positions, *, mask_mode, prefix_len):
    g, stacked = _group(cfg, params["layers"])

    def body(carry, glp):
        hh, aux = carry
        for i in range(g):
            lp = jax.tree.map(lambda x, i=i: x[i], glp) if g > 1 else glp
            hh, a = _attn_block_apply(cfg, lp, hh, positions,
                                      mask_mode=mask_mode,
                                      prefix_len=prefix_len,
                                      window=cfg.sliding_window)
            aux = aux + a
        return (hh, aux), None

    (h, aux), _ = jax.lax.scan(_maybe_remat(cfg, body),
                               (h, jnp.zeros((), jnp.float32)), stacked,
                               unroll=cfg.unroll_layers)
    return h, aux


def _stack_ssm(cfg, params, h):
    g, stacked = _group(cfg, params["layers"])

    def body(hh, glp):
        for i in range(g):
            lp = jax.tree.map(lambda x, i=i: x[i], glp) if g > 1 else glp
            hh = _ssm_block_apply(cfg, lp, hh)
        return hh, None

    h, _ = jax.lax.scan(_maybe_remat(cfg, body), h, stacked,
                        unroll=cfg.unroll_layers)
    return h, jnp.zeros((), jnp.float32)


def _stack_hybrid(cfg, params, h, positions, *, mask_mode="causal"):
    g = cfg.attn_every
    ng = cfg.num_layers // g
    grouped = jax.tree.map(
        lambda x: x.reshape((ng, g) + x.shape[1:]), params["layers"])
    shared = params["shared"]

    def group_body(carry, glp):
        hh, aux = carry

        def inner(hi, lp):
            return _ssm_block_apply(cfg, lp, hi), None

        hh, _ = jax.lax.scan(inner, hh, glp, unroll=cfg.unroll_layers)
        hh, a = _attn_block_apply(
            cfg, shared, hh, positions, mask_mode=mask_mode, prefix_len=0,
            window=cfg.sliding_window)
        return (hh, aux + a), None

    (h, aux), _ = jax.lax.scan(_maybe_remat(cfg, group_body),
                               (h, jnp.zeros((), jnp.float32)), grouped,
                               unroll=cfg.unroll_layers)
    return h, aux


def forward_hidden(cfg, params, batch):
    """Embed inputs and run the stack → final hidden states (B, S, d),
    plus (labels, aux) bookkeeping."""
    if cfg.family == "audio":
        h = batch["features"].astype(cfg.param_dtype) @ params["frontend_proj"]
        positions = jnp.arange(h.shape[1])
        h, aux = _stack_attn(cfg, params, h, positions, mask_mode="bidir",
                             prefix_len=0)
        return h, aux
    if cfg.family == "vlm":
        patches = batch["patches"].astype(cfg.param_dtype) @ params["patch_proj"]
        text = jnp.take(params["embed"], batch["tokens"], axis=0)
        h = jnp.concatenate([patches, text], axis=1)
        positions = jnp.arange(h.shape[1])
        h, aux = _stack_attn(cfg, params, h, positions, mask_mode="prefix",
                             prefix_len=cfg.prefix_tokens)
        return h, aux
    h = constrain_batch(jnp.take(params["embed"], batch["tokens"], axis=0))
    positions = jnp.arange(h.shape[1])
    if cfg.family == "ssm":
        h, aux = _stack_ssm(cfg, params, h)
    elif cfg.family == "hybrid":
        h, aux = _stack_hybrid(cfg, params, h, positions)
    else:
        h, aux = _stack_attn(cfg, params, h, positions, mask_mode="causal",
                             prefix_len=0)
    return h, aux


def loss_fn(cfg, params, batch):
    """Training loss (next-token / masked-prediction / prefix-LM CE)."""
    h, aux = forward_hidden(cfg, params, batch)
    h = constrain_batch(rmsnorm(h, params["final_ln"], cfg.norm_eps))
    labels = batch["labels"]
    if cfg.family == "vlm":
        h = h[:, cfg.prefix_tokens:]  # loss only over text positions
    ce = chunked_lm_loss(h, params["lm_head"], labels, cfg.loss_chunk,
                         valid_vocab=cfg.vocab_size)
    return ce + cfg.aux_coef * aux


# ----------------------------------------------------------------------
# serving: prefill + single-token decode
# ----------------------------------------------------------------------


def init_cache(cfg, batch_size, max_seq, dtype=None):
    dtype = dtype or cfg.param_dtype
    if cfg.family in ("dense", "moe", "vlm"):
        s = min(max_seq, cfg.sliding_window) if cfg.sliding_window else max_seq
        shape = (cfg.num_layers, batch_size, s, cfg.num_kv_heads, cfg.head_dim)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
                "pos": jnp.zeros((), jnp.int32)}
    if cfg.family == "ssm":
        one = ssm_cache_init(batch_size, cfg.d_model, expand=cfg.expand,
                             ssm_state=cfg.ssm_state,
                             head_dim=cfg.ssm_head_dim,
                             conv_kernel=cfg.conv_kernel, dtype=dtype)
        return {
            "layers": jax.tree.map(
                lambda x: jnp.zeros((cfg.num_layers,) + x.shape, x.dtype), one),
            "pos": jnp.zeros((), jnp.int32),
        }
    if cfg.family == "hybrid":
        ng = cfg.num_layers // cfg.attn_every
        one = ssm_cache_init(batch_size, cfg.d_model, expand=cfg.expand,
                             ssm_state=cfg.ssm_state,
                             head_dim=cfg.ssm_head_dim,
                             conv_kernel=cfg.conv_kernel, dtype=dtype)
        s = min(max_seq, cfg.sliding_window) if cfg.sliding_window else max_seq
        kv = (ng, batch_size, s, cfg.num_kv_heads, cfg.head_dim)
        return {
            "layers": jax.tree.map(
                lambda x: jnp.zeros((cfg.num_layers,) + x.shape, x.dtype), one),
            "k": jnp.zeros(kv, dtype), "v": jnp.zeros(kv, dtype),
            "pos": jnp.zeros((), jnp.int32),
        }
    raise ValueError(f"no cache for family {cfg.family}")


def prefill(cfg, params, batch, max_seq=None):
    """Process a prompt; returns (last-token logits, filled cache).

    Implemented for attention families via the blockwise path with KV
    collection; SSM/hybrid prefill runs the chunked scan and keeps the
    final recurrent state.
    """
    if cfg.family == "audio":
        raise ValueError("encoder-only architectures have no decode path")
    tokens = batch["tokens"]
    b, s = tokens.shape
    max_seq = max_seq or s
    h = jnp.take(params["embed"], tokens, axis=0)
    positions = jnp.arange(s)
    mask_mode, prefix_len = "causal", 0
    if cfg.family == "vlm":
        patches = batch["patches"].astype(cfg.param_dtype) @ params["patch_proj"]
        h = jnp.concatenate([patches, h], axis=1)
        s = h.shape[1]
        positions = jnp.arange(s)
        mask_mode, prefix_len = "prefix", cfg.prefix_tokens

    if cfg.family in ("dense", "moe", "vlm"):
        def body(carry, lp):
            hh, _ = carry
            hh, aux, kv = _attn_block_apply(
                cfg, lp, hh, positions, mask_mode=mask_mode,
                prefix_len=prefix_len, window=cfg.sliding_window,
                return_kv=True)
            return (hh, aux), kv

        (h, _), (ks, vs) = jax.lax.scan(
            _maybe_remat(cfg, body), (h, jnp.zeros((), jnp.float32)),
            params["layers"], unroll=cfg.unroll_layers)
        cache = _fit_kv_cache(cfg, ks, vs, max_seq, s)
    elif cfg.family == "ssm":
        def body(hh, lp):
            x = rmsnorm(hh, lp["ln"], cfg.norm_eps)
            y, st = ssm_forward(
                lp["ssm"], x, expand=cfg.expand, ssm_state=cfg.ssm_state,
                head_dim=cfg.ssm_head_dim, conv_kernel=cfg.conv_kernel,
                chunk=cfg.chunk, return_state=True)
            # conv tail: last K-1 pre-activation conv inputs
            return hh + y, (st, _conv_tail(cfg, lp, x))

        h, (ssm_states, conv_tails) = jax.lax.scan(
            body, h, params["layers"], unroll=cfg.unroll_layers)
        cache = {"layers": {"ssm": ssm_states, "conv": conv_tails},
                 "pos": jnp.asarray(s, jnp.int32)}
    else:  # hybrid
        g = cfg.attn_every
        ng = cfg.num_layers // g
        grouped = jax.tree.map(
            lambda x: x.reshape((ng, g) + x.shape[1:]), params["layers"])
        shared = params["shared"]

        def group_body(hh, glp):
            def inner(hi, lp):
                x = rmsnorm(hi, lp["ln"], cfg.norm_eps)
                y, st = ssm_forward(
                    lp["ssm"], x, expand=cfg.expand, ssm_state=cfg.ssm_state,
                    head_dim=cfg.ssm_head_dim, conv_kernel=cfg.conv_kernel,
                    chunk=cfg.chunk, return_state=True)
                return hi + y, (st, _conv_tail(cfg, lp, x))

            hh, inner_caches = jax.lax.scan(inner, hh, glp,
                                            unroll=cfg.unroll_layers)
            hh, _, kv = _attn_block_apply(
                cfg, shared, hh, positions, mask_mode="causal", prefix_len=0,
                window=cfg.sliding_window, return_kv=True)
            return hh, (inner_caches, kv)

        h, ((ssm_states, conv_tails), (ks, vs)) = jax.lax.scan(
            group_body, h, grouped, unroll=cfg.unroll_layers)
        flat = lambda x: x.reshape((cfg.num_layers,) + x.shape[2:])
        kvc = _fit_kv_cache(cfg, ks, vs, max_seq, s)
        cache = {"layers": {"ssm": flat(ssm_states), "conv": flat(conv_tails)},
                 "k": kvc["k"], "v": kvc["v"],
                 "pos": jnp.asarray(s, jnp.int32)}

    h = rmsnorm(h[:, -1:], params["final_ln"], cfg.norm_eps)
    logits = (h @ params["lm_head"]).astype(jnp.float32)
    return logits[..., :cfg.vocab_size], cache


def _conv_tail(cfg, lp, x):
    """Last (K−1) conv inputs of a mamba layer (for the decode ring)."""
    d_inner = cfg.expand * cfg.d_model
    zxbcdt = x @ lp["ssm"]["in_proj"]
    xi = zxbcdt[..., d_inner:2 * d_inner]
    bm = zxbcdt[..., 2 * d_inner:2 * d_inner + cfg.ssm_state]
    cm = zxbcdt[..., 2 * d_inner + cfg.ssm_state:
                2 * d_inner + 2 * cfg.ssm_state]
    xbc = jnp.concatenate([xi, bm, cm], axis=-1)
    return xbc[:, -(cfg.conv_kernel - 1):]


def _fit_kv_cache(cfg, ks, vs, max_seq, s):
    """Pad/crop prefill KV (L, B, S, Kv, hd) into the serving cache."""
    window = cfg.sliding_window
    size = min(max_seq, window) if window else max_seq
    if window and s > size:
        # keep the last `size` positions, ring-aligned: slot = pos % size
        ks, vs = ks[:, :, -size:], vs[:, :, -size:]
        shift = s % size
        ks = jnp.roll(ks, shift, axis=2)
        vs = jnp.roll(vs, shift, axis=2)
    elif s < size:
        pad = ((0, 0), (0, 0), (0, size - s), (0, 0), (0, 0))
        ks, vs = jnp.pad(ks, pad), jnp.pad(vs, pad)
    return {"k": ks, "v": vs, "pos": jnp.asarray(s, jnp.int32)}


def decode_step(cfg, params, token, cache):
    """One token (B, 1) given a filled cache → (logits (B,1,V), cache)."""
    if cfg.family == "audio":
        raise ValueError("encoder-only architectures have no decode path")
    h = jnp.take(params["embed"], token, axis=0)
    pos = cache["pos"]

    if cfg.family in ("dense", "moe", "vlm"):
        def body(hh, xs):
            lp, kc, vc = xs
            hh, (kc, vc) = _attn_block_decode(
                cfg, lp, hh, (kc, vc), pos, window=cfg.sliding_window)
            return hh, (kc, vc)

        h, (ks, vs) = jax.lax.scan(
            body, h, (params["layers"], cache["k"], cache["v"]),
            unroll=cfg.unroll_layers)
        new_cache = {"k": ks, "v": vs, "pos": pos + 1}
    elif cfg.family == "ssm":
        def body(hh, xs):
            lp, lc = xs
            hh, lc = _ssm_block_decode(cfg, lp, hh, lc)
            return hh, lc

        h, layer_caches = jax.lax.scan(
            body, h, (params["layers"], cache["layers"]),
            unroll=cfg.unroll_layers)
        new_cache = {"layers": layer_caches, "pos": pos + 1}
    else:  # hybrid
        g = cfg.attn_every
        ng = cfg.num_layers // g
        grouped = jax.tree.map(
            lambda x: x.reshape((ng, g) + x.shape[1:]), params["layers"])
        gcache = jax.tree.map(
            lambda x: x.reshape((ng, g) + x.shape[1:]), cache["layers"])
        shared = params["shared"]

        def group_body(hh, xs):
            glp, glc, kc, vc = xs

            def inner(hi, ys):
                lp, lc = ys
                hi, lc = _ssm_block_decode(cfg, lp, hi, lc)
                return hi, lc

            hh, glc = jax.lax.scan(inner, hh, (glp, glc),
                                   unroll=cfg.unroll_layers)
            hh, (kc, vc) = _attn_block_decode(
                cfg, shared, hh, (kc, vc), pos, window=cfg.sliding_window)
            return hh, (glc, kc, vc)

        h, (glc, ks, vs) = jax.lax.scan(
            group_body, h, (grouped, gcache, cache["k"], cache["v"]),
            unroll=cfg.unroll_layers)
        new_cache = {
            "layers": jax.tree.map(
                lambda x: x.reshape((cfg.num_layers,) + x.shape[2:]), glc),
            "k": ks, "v": vs, "pos": pos + 1,
        }

    h = rmsnorm(h, params["final_ln"], cfg.norm_eps)
    logits = (h @ params["lm_head"]).astype(jnp.float32)
    return logits[..., :cfg.vocab_size], new_cache
