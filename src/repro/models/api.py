"""Public model API: configuration + build.

``build_model(cfg)`` returns a ``Model`` bundle of pure functions
(init / loss / train_step pieces / prefill / decode_step / input_specs)
shared by the smoke tests, the launchers and the multi-pod dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from . import transformer as tf

_DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
           "float16": jnp.float16}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # moe
    num_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    aux_coef: float = 0.01
    # ssm / hybrid
    ssm_state: int = 0
    ssm_head_dim: int = 64
    expand: int = 2
    conv_kernel: int = 4
    chunk: int = 64
    ssd_intra_dtype: str = "float32"  # §Perf: bf16 halves intra-chunk HBM
    attn_every: int = 0
    # attention
    sliding_window: int = 0
    rope_theta: float = 1e4
    kv_block: int = 512
    # modality frontends (stubbed: precomputed embeddings)
    prefix_tokens: int = 0
    frontend_dim: int = 0
    encoder_only: bool = False
    norm_eps: float = 1e-5
    dtype: str = "float32"
    loss_chunk: int = 0
    remat: bool = True
    remat_group: int = 1  # layers per checkpoint unit: stash ∝ L/group
    unroll_inner: bool = False  # unroll inner (kv-block) loops — used by
    # the dry-run so XLA's cost analysis (which counts while bodies
    # once) sees the true FLOPs
    unroll_layers: bool = False  # unroll the layer scan itself (cost-
    # correction lowerings only: 1–2 layer variants)
    source: str = ""  # citation for the assigned architecture

    @property
    def param_dtype(self):
        return _DTYPES[self.dtype]

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded to a 256 multiple: shards cleanly over the
        model axis (Megatron vocab-parallel head); padded logit columns
        are masked in the loss and sliced off in serving."""
        return -(-self.vocab_size // 256) * 256

    @property
    def supports_decode(self) -> bool:
        return not self.encoder_only and self.family != "audio"

    @property
    def subquadratic(self) -> bool:
        """Eligible for long_500k (SSM/hybrid state or sliding window)."""
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0

    def reduced(self, **overrides) -> "ModelConfig":
        """Smoke-test variant of the same family (≤2 layers, small dims)."""
        kw = dict(
            name=self.name + "-smoke",
            num_layers=min(self.num_layers, 2),
            d_model=min(self.d_model, 128),
            num_heads=min(self.num_heads, 4),
            num_kv_heads=min(self.num_kv_heads, 2),
            head_dim=min(self.head_dim, 32),
            d_ff=min(self.d_ff, 256) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            num_experts=min(self.num_experts, 4),
            top_k=min(self.top_k, 2),
            # drop-free capacity so prefill/decode agree exactly in tests
            capacity_factor=float(max(self.num_experts, 1)),
            ssm_state=min(self.ssm_state, 16),
            ssm_head_dim=min(self.ssm_head_dim, 16),
            chunk=8,
            attn_every=2 if self.attn_every else 0,
            sliding_window=min(self.sliding_window, 16)
            if self.sliding_window else 0,
            prefix_tokens=min(self.prefix_tokens, 4),
            frontend_dim=min(self.frontend_dim, 32)
            if self.frontend_dim else 0,
            kv_block=8,
            loss_chunk=0,
            dtype="float32",
            remat=False,
        )
        if self.family == "hybrid":
            kw["num_layers"] = 4  # 2 groups of 2
        kw.update(overrides)
        return dataclasses.replace(self, **kw)


class Model(NamedTuple):
    config: ModelConfig
    init: Callable  # rng -> params
    loss: Callable  # (params, batch) -> scalar
    prefill: Callable  # (params, batch, max_seq) -> (logits, cache)
    decode_step: Callable  # (params, token, cache) -> (logits, cache)
    init_cache: Callable  # (batch, max_seq) -> cache pytree


def build_model(cfg: ModelConfig) -> Model:
    return Model(
        config=cfg,
        init=lambda rng: tf.init_params(rng, cfg),
        loss=lambda params, batch: tf.loss_fn(cfg, params, batch),
        prefill=lambda params, batch, max_seq=None: tf.prefill(
            cfg, params, batch, max_seq),
        decode_step=lambda params, token, cache: tf.decode_step(
            cfg, params, token, cache),
        init_cache=lambda batch, max_seq: tf.init_cache(cfg, batch, max_seq),
    )


# ----------------------------------------------------------------------
# abstract inputs (ShapeDtypeStruct) per workload shape — the dry-run's
# stand-ins: weak-type-correct, shardable, zero allocation.
# ----------------------------------------------------------------------


def input_specs(cfg: ModelConfig, *, mode: str, batch: int, seq: int):
    """Returns the abstract batch pytree for train/prefill/decode."""
    tok = lambda *s: jax.ShapeDtypeStruct(s, jnp.int32)
    emb = lambda *s: jax.ShapeDtypeStruct(s, cfg.param_dtype)

    if mode == "train":
        if cfg.family == "audio":
            return {"features": emb(batch, seq, cfg.frontend_dim),
                    "labels": tok(batch, seq)}
        if cfg.family == "vlm":
            text = seq - cfg.prefix_tokens
            return {"patches": emb(batch, cfg.prefix_tokens, cfg.frontend_dim),
                    "tokens": tok(batch, text), "labels": tok(batch, text)}
        return {"tokens": tok(batch, seq), "labels": tok(batch, seq)}
    if mode == "prefill":
        if cfg.family == "vlm":
            text = seq - cfg.prefix_tokens
            return {"patches": emb(batch, cfg.prefix_tokens, cfg.frontend_dim),
                    "tokens": tok(batch, text)}
        return {"tokens": tok(batch, seq)}
    if mode == "decode":
        return {"token": tok(batch, 1)}
    raise ValueError(mode)


def abstract_params(model: Model):
    """Shape-only param pytree via eval_shape (no allocation)."""
    return jax.eval_shape(model.init, jax.random.PRNGKey(0))


def abstract_cache(model: Model, batch: int, max_seq: int):
    return jax.eval_shape(
        lambda: model.init_cache(batch, max_seq))


def param_count(cfg: ModelConfig) -> int:
    model = build_model(cfg)
    shapes = abstract_params(model)
    import numpy as np
    return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes))


def active_param_count(cfg: ModelConfig) -> int:
    """Active parameters per token (MoE: top_k of num_experts)."""
    total = param_count(cfg)
    if not cfg.num_experts:
        return total
    model = build_model(cfg)
    shapes = abstract_params(model)
    import numpy as np
    expert = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        keys = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        if any(k in ("w_gate", "w_up", "w_down") and "moe" in keys
               for k in keys):
            expert += int(np.prod(leaf.shape))
    active_expert = expert * cfg.top_k // cfg.num_experts
    return total - expert + active_expert
