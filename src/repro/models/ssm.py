"""Mamba-2 (SSD — state-space duality) mixer layer.

Faithful to Dao & Gu 2024 (arXiv:2405.21060) with n_groups=1:

  in_proj  : d → [z (d_in), x (d_in), B (N), C (N), dt (H)]
  conv1d   : causal depthwise over the concatenated (x, B, C) channels
  SSD core : h_t = a_t h_{t-1} + dt_t (B_t ⊗ x_t),  a_t = exp(A·dt_t)
             y_t = C_t · h_t + D ⊙ x_t           (scalar-per-head A < 0)
  gate     : y ← RMSNorm(y · silu(z)); out_proj: d_in → d

Training uses the *chunked* SSD algorithm: intra-chunk attention-like
term through the decay kernel L_ij = exp(Σ log a) (lower-triangular),
plus an inter-chunk scan over compressed chunk states (B, H, P, N) —
O(S·Q) work instead of O(S²), and the chunk scan is the TPU Pallas
kernel's target (repro.kernels.ssd_scan validates against this module).

Decode is the O(1) recurrence with a (conv ring, ssm state) cache —
this is what makes ``long_500k`` viable for SSM/hybrid archs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense_init, rmsnorm


def ssm_dims(d_model, expand, ssm_state, head_dim):
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    conv_dim = d_inner + 2 * ssm_state
    return d_inner, n_heads, conv_dim


def ssm_init(key, d_model, *, expand, ssm_state, head_dim, conv_kernel,
             dtype):
    d_inner, n_heads, conv_dim = ssm_dims(d_model, expand, ssm_state,
                                          head_dim)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    proj_out = 2 * d_inner + 2 * ssm_state + n_heads
    return {
        "in_proj": dense_init(k1, d_model, proj_out, dtype),
        "conv_w": (jax.random.normal(k2, (conv_kernel, conv_dim), jnp.float32)
                   * (1.0 / conv_kernel) ** 0.5).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads).astype(jnp.float32)),
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.log(jnp.exp(
            jnp.linspace(1e-3, 0.1, n_heads).astype(jnp.float32)) - 1.0 + 1e-9),
        "norm_g": jnp.ones((d_inner,), dtype),
        "out_proj": dense_init(k3, d_inner, d_model, dtype),
    }


def _split_proj(zxbcdt, d_inner, ssm_state, n_heads):
    z = zxbcdt[..., :d_inner]
    x = zxbcdt[..., d_inner:2 * d_inner]
    bmat = zxbcdt[..., 2 * d_inner:2 * d_inner + ssm_state]
    cmat = zxbcdt[..., 2 * d_inner + ssm_state:2 * d_inner + 2 * ssm_state]
    dt = zxbcdt[..., -n_heads:]
    return z, x, bmat, cmat, dt


def _causal_conv(xbc, w, b):
    """Depthwise causal conv over (B, S, Cdim) with kernel (K, Cdim)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i] for i in range(k))
    return jax.nn.silu(out + b)


def ssd_chunked(x, dt, a_log, bmat, cmat, *, chunk,
                intra_dtype=None):
    """Chunked SSD core.

    x: (B, S, H, P); dt: (B, S, H); bmat/cmat: (B, S, N).
    Returns y: (B, S, H, P) and final state (B, H, P, N).

    Precision policy (§Perf hillclimb #1 — byte attribution showed
    *dtype converts* were >40% of the layer's HBM traffic under the
    original everything-fp32 policy): all LARGE tensors (x, B, C, the
    5-D decay kernel, chunk states) stay in the input dtype
    (``intra_dtype`` overrides); the numerically critical SMALL
    tensors — per-step log-decays, their cumulative sums, and the
    inter-chunk state scan carry — are fp32 always.
    """
    b, s, h, p = x.shape
    n = bmat.shape[-1]
    q = chunk
    s_orig = s
    if s % q:
        # pad with dt=0 steps: decay exp(0·A)=1, zero input → h untouched
        pad = q - s % q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
        s = s + pad
    nc = s // q
    wide = intra_dtype or x.dtype  # big-tensor dtype (bf16 at scale)
    a = -jnp.exp(a_log)  # (H,) negative
    loga = (dt.astype(jnp.float32) * a)  # (B, S, H) log decay per step

    xc = x.reshape(b, nc, q, h, p).astype(wide)
    dtc = dt.reshape(b, nc, q, h)  # fp32 (from softplus)
    bc = bmat.reshape(b, nc, q, n).astype(wide)
    cc = cmat.reshape(b, nc, q, n).astype(wide)
    logac = loga.reshape(b, nc, q, h)
    cum = jnp.cumsum(logac, axis=2)  # (B, nc, Q, H) inclusive, fp32

    # --- intra-chunk (quadratic within the chunk) ---------------------
    g = jnp.einsum("bcin,bcjn->bcij", cc, bc,
                   preferred_element_type=jnp.float32)  # (B, nc, Q, Q)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,Qi,Qj,H)
    li = jnp.tril(jnp.ones((q, q), bool))
    decay = jnp.exp(jnp.where(li[None, None, :, :, None], seg,
                              -jnp.inf)).astype(wide)
    m = g.astype(wide)[..., None] * decay  # (B, nc, Qi, Qj, H)
    xdt = xc * dtc[..., None].astype(wide)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", m, xdt,
                         preferred_element_type=jnp.float32)

    # --- chunk states + inter-chunk scan -------------------------------
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum).astype(wide)
    states = jnp.einsum("bcjhp,bcjn,bcjh->bchpn", xdt, bc, decay_to_end,
                        preferred_element_type=jnp.float32)
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # (B, nc, H) fp32

    def scan_body(h_prev, xs):
        st, dec = xs  # (B, H, P, N), (B, H)
        h_new = h_prev * dec[:, :, None, None] + st.astype(jnp.float32)
        return h_new, h_prev.astype(wide)

    h0 = jnp.zeros((b, h, p, n), jnp.float32)
    h_last, h_befores = jax.lax.scan(
        scan_body,
        h0,
        (states.astype(wide).transpose(1, 0, 2, 3, 4),
         chunk_decay.transpose(1, 0, 2)),
    )
    h_prevs = h_befores.transpose(1, 0, 2, 3, 4)  # (B, nc, H, P, N)

    y_inter = jnp.einsum("bcin,bchpn,bcih->bcihp", cc, h_prevs,
                         jnp.exp(cum).astype(wide),
                         preferred_element_type=jnp.float32)
    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y[:, :s_orig], h_last


def ssm_forward(params, hidden, *, expand, ssm_state, head_dim, conv_kernel,
                chunk, return_state=False, intra_dtype=None):
    """Full Mamba-2 mixer. hidden: (B, S, d)."""
    b, s, d = hidden.shape
    d_inner, n_heads, conv_dim = ssm_dims(d, expand, ssm_state, head_dim)
    zxbcdt = hidden @ params["in_proj"]
    z, x, bmat, cmat, dt = _split_proj(zxbcdt, d_inner, ssm_state, n_heads)
    xbc = jnp.concatenate([x, bmat, cmat], axis=-1)
    xbc = _causal_conv(xbc, params["conv_w"], params["conv_b"])
    x, bmat, cmat = (xbc[..., :d_inner],
                     xbc[..., d_inner:d_inner + ssm_state],
                     xbc[..., d_inner + ssm_state:])
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"])  # (B, S, H)
    xh = x.reshape(b, s, n_heads, head_dim)
    y, h_last = ssd_chunked(xh, dt, params["A_log"], bmat, cmat, chunk=chunk,
                            intra_dtype=intra_dtype)
    y = y.astype(hidden.dtype) + (params["D"].astype(hidden.dtype)
                                  [None, None, :, None] * xh)
    y = y.reshape(b, s, d_inner)
    y = y * jax.nn.silu(z)
    y = rmsnorm(y, params["norm_g"])
    out = y @ params["out_proj"]
    if return_state:
        return out, h_last
    return out


# ----------------------------------------------------------------------
# O(1) decode recurrence
# ----------------------------------------------------------------------

def ssm_cache_init(batch, d_model, *, expand, ssm_state, head_dim,
                   conv_kernel, dtype):
    d_inner, n_heads, conv_dim = ssm_dims(d_model, expand, ssm_state,
                                          head_dim)
    return {
        "conv": jnp.zeros((batch, conv_kernel - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, n_heads, head_dim, ssm_state), jnp.float32),
    }


def ssm_decode_step(params, hidden, cache, *, expand, ssm_state, head_dim,
                    conv_kernel):
    """hidden: (B, 1, d) → (out (B, 1, d), new cache)."""
    b, _, d = hidden.shape
    d_inner, n_heads, conv_dim = ssm_dims(d, expand, ssm_state, head_dim)
    zxbcdt = hidden[:, 0] @ params["in_proj"]  # (B, proj)
    z, x, bmat, cmat, dt = _split_proj(zxbcdt, d_inner, ssm_state, n_heads)
    xbc = jnp.concatenate([x, bmat, cmat], axis=-1)  # (B, conv_dim)
    window = jnp.concatenate([cache["conv"], xbc[:, None]], axis=1)  # (B,K,C)
    conv_out = jnp.einsum("bkc,kc->bc", window, params["conv_w"])
    xbc = jax.nn.silu(conv_out + params["conv_b"])
    x, bmat, cmat = (xbc[:, :d_inner], xbc[:, d_inner:d_inner + ssm_state],
                     xbc[:, d_inner + ssm_state:])
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,H)
    a = jnp.exp(-jnp.exp(params["A_log"]) * dt)  # (B, H)
    xh = x.reshape(b, n_heads, head_dim).astype(jnp.float32)
    upd = (dt[..., None] * xh)[..., None] * bmat[:, None, None, :]
    h_new = cache["ssm"] * a[..., None, None] + upd  # (B,H,P,N)
    y = jnp.einsum("bhpn,bn->bhp", h_new, cmat)
    y = y + params["D"][None, :, None] * xh
    y = y.reshape(b, d_inner).astype(hidden.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm(y, params["norm_g"])
    out = (y @ params["out_proj"])[:, None]
    return out, {"conv": window[:, 1:], "ssm": h_new}
