"""Mixture-of-Experts layer with scatter-based top-k dispatch.

Routing follows Mixtral/Qwen3: softmax router, top-k experts per token,
gates renormalized over the selected k.  Dispatch is *scatter-based*
(position-in-expert via a per-group cumulative count, then
``at[...].set`` into an (E, C, d) buffer) rather than the classic
one-hot dispatch einsum — the einsum formulation costs
T²·k·cf·d "phantom" FLOPs that would poison every roofline number at
32k-token shards; scatter costs bytes only, and the expert GEMMs then
account for exactly the *active* FLOPs (6·N_active·D accounting works).

Tokens are grouped by batch row (GShard-style groups): capacity and
dispatch are computed per group, which keeps the cumulative count local
to a data shard — no cross-shard cumsum, and under expert-parallel
sharding XLA lowers the buffer exchange to an all-to-all over the
``model`` axis.

Overflow tokens (beyond capacity C = ceil(S·k/E · cf)) are dropped —
their combine weight is zero, as in Switch/GShard.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding.actshard import constrain_batch

from .layers import dense_init


def moe_init(key, d_model, d_ff, num_experts, dtype):
    kr, kg, ku, kd = jax.random.split(key, 4)
    se = (2.0 / (d_model + d_ff)) ** 0.5
    shape = (num_experts, d_model, d_ff)

    def experts(k):
        return (jax.random.normal(k, shape, jnp.float32) * se).astype(dtype)

    return {
        "router": dense_init(kr, d_model, num_experts, jnp.float32),
        "w_gate": experts(kg),
        "w_up": experts(ku),
        "w_down": (jax.random.normal(kd, (num_experts, d_ff, d_model),
                                     jnp.float32) * se).astype(dtype),
    }


def moe_apply(p, x, *, top_k, capacity_factor=1.25, return_aux=True):
    """x: (B, S, d) → (out (B, S, d), aux load-balance loss)."""
    b, s, d = x.shape
    e = p["router"].shape[1]
    cap = int(-(-s * top_k // e) * capacity_factor)
    cap = max(min(cap, s * top_k), 1)

    logits = (x.astype(jnp.float32) @ p["router"])  # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eids = jax.lax.top_k(probs, top_k)  # (B, S, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # --- per-group (batch-row) dispatch ------------------------------
    r = s * top_k
    eids_f = eids.reshape(b, r)  # row-major: token-major then k
    gates_f = gates.reshape(b, r)
    # position of each row within its expert (per group)
    onehot = jax.nn.one_hot(eids_f, e, dtype=jnp.int32)  # (B, R, E)
    pos = jnp.take_along_axis(
        jnp.cumsum(onehot, axis=1), eids_f[..., None], axis=-1
    )[..., 0] - 1  # (B, R)
    keep = pos < cap
    slot = jnp.where(keep, pos, cap)  # dropped rows land in a spill slot

    tok_rows = constrain_batch(jnp.repeat(x, top_k, axis=1))  # (B, R, d)

    def dispatch(rows, eid, slt):
        buf = jnp.zeros((e, cap + 1, d), rows.dtype)
        return buf.at[eid, slt].set(rows)[:, :cap]

    # explicit batch pinning: GSPMD's scatter/gather partitioner falls
    # back to replicate-and-all-reduce when operand shardings are left
    # to inference (measured 16 GiB/layer of gather all-reduces)
    buffers = constrain_batch(
        jax.vmap(dispatch)(tok_rows, eids_f, slot))  # (B, E, C, d)

    # --- expert computation (active FLOPs only) -----------------------
    hgate = jax.nn.silu(jnp.einsum("becd,edf->becf", buffers, p["w_gate"]))
    hup = jnp.einsum("becd,edf->becf", buffers, p["w_up"])
    hout = constrain_batch(
        jnp.einsum("becf,efd->becd", hgate * hup, p["w_down"]))

    # --- combine -------------------------------------------------------
    def gather(buf, eid, slt):
        return buf[eid, jnp.minimum(slt, cap - 1)]

    rows_out = constrain_batch(
        jax.vmap(gather)(hout, eids_f, slot))  # (B, R, d)
    rows_out = jnp.where(keep[..., None], rows_out, 0.0)
    out = (rows_out.reshape(b, s, top_k, d)
           * gates.astype(rows_out.dtype)[..., None]).sum(axis=2)

    if not return_aux:
        return out, jnp.zeros((), jnp.float32)
    # Switch-style load balance: E·Σ_e f_e·p̄_e (top-1 dispatch fraction)
    top1 = eids[..., 0].reshape(-1)
    f = jnp.mean(jax.nn.one_hot(top1, e, dtype=jnp.float32), axis=0)
    pbar = jnp.mean(probs.reshape(-1, e), axis=0)
    aux = e * jnp.sum(f * pbar)
    return out, aux


def moe_ref(p, x, *, top_k):
    """Dense oracle: computes every expert for every token (test-only)."""
    b, s, d = x.shape
    logits = x.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gates, eids = jax.lax.top_k(probs, top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    hg = jax.nn.silu(jnp.einsum("bsd,edf->besf", x, p["w_gate"]))
    hu = jnp.einsum("bsd,edf->besf", x, p["w_up"])
    ho = jnp.einsum("besf,efd->besd", hg * hu, p["w_down"])  # (B,E,S,d)
    sel = jax.nn.one_hot(eids, ho.shape[1], dtype=jnp.float32)  # (B,S,k,E)
    w = (sel * gates[..., None]).sum(2)  # (B,S,E)
    return jnp.einsum("bse,besd->bsd", w.astype(ho.dtype), ho)
