"""Shared transformer building blocks (pure functional, dict pytrees).

Layer stacks are *stacked*: every leaf carries a leading ``num_layers``
axis and the forward pass is a ``jax.lax.scan`` over it, keeping the
lowered HLO compact for 95-layer configs and letting the dry-run compile
in seconds instead of minutes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dense_init(key, n_in, n_out, dtype, scale=None):
    s = scale if scale is not None else (2.0 / (n_in + n_out)) ** 0.5
    return (jax.random.normal(key, (n_in, n_out), jnp.float32) * s).astype(dtype)


def rmsnorm_init(dim, dtype):
    return jnp.ones((dim,), dtype)


def rmsnorm(x, gamma, eps=1e-5):
    x32 = x.astype(jnp.float32)
    rms = jax.lax.rsqrt(jnp.mean(jnp.square(x32), axis=-1, keepdims=True) + eps)
    return (x32 * rms).astype(x.dtype) * gamma


def swiglu_init(key, d_model, d_ff, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d_model, d_ff, dtype),
        "w_up": dense_init(k2, d_model, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, d_model, dtype),
    }


def swiglu(p, x):
    g = jax.nn.silu(x @ p["w_gate"])
    return (g * (x @ p["w_up"])) @ p["w_down"]


def rope_frequencies(head_dim, theta):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta=1e4):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def embed_init(key, vocab, d_model, dtype):
    return (jax.random.normal(key, (vocab, d_model), jnp.float32)
            * (1.0 / d_model ** 0.5)).astype(dtype)


def stacked_init(fn, key, num_layers, *args):
    """vmap an init over per-layer keys → stacked (L, ...) param tree."""
    keys = jax.random.split(key, num_layers)
    return jax.vmap(lambda k: fn(k, *args))(keys)


def cross_entropy_logits(logits, labels, ignore_index=-100,
                         valid_vocab: int = 0):
    """Token CE with masking; logits fp32 for stability.

    valid_vocab > 0 masks padded vocabulary columns (the embedding /
    head are padded to a 256-multiple so the vocab dim shards cleanly
    over the model axis; padded logits get -inf before the softmax).
    """
    logits = logits.astype(jnp.float32)
    if valid_vocab and valid_vocab < logits.shape[-1]:
        col = jnp.arange(logits.shape[-1])
        logits = jnp.where(col < valid_vocab, logits, -1e30)
    logp = jax.nn.log_softmax(logits, axis=-1)
    valid = labels != ignore_index
    safe = jnp.where(valid, labels, 0)
    ll = jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    n = jnp.maximum(jnp.sum(valid), 1)
    return -jnp.sum(jnp.where(valid, ll, 0.0)) / n


def chunked_lm_loss(hidden, embed_out, labels, chunk: int = 0,
                    ignore_index=-100, valid_vocab: int = 0):
    """LM head + CE, chunked over the sequence axis.

    Avoids materializing the full (B, S, V) logits tensor — at
    vocab=102400, d=8192 that is the single largest activation of the
    whole model.  The chunk loop is a *Python* (unrolled) loop, not a
    lax.scan: an unrolled loop is costed correctly by XLA's analysis
    (while bodies are counted once) and GSPMD propagates the batch
    sharding into every chunk; the buffer allocator still reuses one
    chunk's logits buffer across iterations.
    hidden: (B, S, d); embed_out: (d, V).
    """
    from repro.sharding.actshard import constrain_batch

    b, s, d = hidden.shape
    if not chunk or s <= chunk:
        logits = constrain_batch(hidden @ embed_out, vocab_dim=True)
        return cross_entropy_logits(logits, labels, ignore_index,
                                    valid_vocab)
    n = -(-s // chunk)
    loss_sum = jnp.zeros((), jnp.float32)
    tok_sum = jnp.zeros((), jnp.int32)
    col = jnp.arange(embed_out.shape[-1])

    @jax.checkpoint  # recompute chunk logits in backward: the (B, c, V)
    # fp32 logp never joins the residual stash
    def chunk_loss(hc, yc):
        hc = constrain_batch(hc)
        logits = constrain_batch((hc @ embed_out).astype(jnp.float32),
                                 vocab_dim=True)
        if valid_vocab and valid_vocab < logits.shape[-1]:
            logits = jnp.where(col < valid_vocab, logits, -1e30)
        logp = jax.nn.log_softmax(logits, axis=-1)
        valid = yc != ignore_index
        safe = jnp.where(valid, yc, 0)
        ll = jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
        return -jnp.sum(jnp.where(valid, ll, 0.0)), jnp.sum(valid)

    for i in range(n):
        hc = jax.lax.dynamic_slice_in_dim(hidden, i * chunk,
                                          min(chunk, s - i * chunk), axis=1)
        yc = jax.lax.dynamic_slice_in_dim(labels, i * chunk,
                                          min(chunk, s - i * chunk), axis=1)
        li, ti = chunk_loss(hc, yc)
        loss_sum = loss_sum + li
        tok_sum = tok_sum + ti
    return loss_sum / jnp.maximum(tok_sum, 1)
