from .synthetic import make_synthetic_mnist, make_synthetic_cifar, \
    make_least_squares  # noqa: F401
from .partition import partition_label_shard, partition_dirichlet  # noqa: F401
from .pipeline import federated_arrays  # noqa: F401
