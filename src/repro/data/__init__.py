from .synthetic import (  # noqa: F401
    make_least_squares,
    make_synthetic_cifar,
    make_synthetic_mnist,
)
from .partition import partition_label_shard, partition_dirichlet  # noqa: F401
from .pipeline import federated_arrays  # noqa: F401
