from .synthetic import (  # noqa: F401
    make_least_squares,
    make_synthetic_cifar,
    make_synthetic_mnist,
)
from .partition import (  # noqa: F401
    PartitionStats,
    label_histogram,
    partition_dirichlet,
    partition_label_shard,
)
from .pipeline import (  # noqa: F401
    federated_arrays,
    federated_pooled,
    stack_trimmed,
)
