"""Federated data pipeline glue."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .partition import partition_dirichlet, partition_label_shard
from .synthetic import Dataset


def federated_arrays(ds: Dataset, *, n_clients: int, scheme: str = "label_shard",
                     classes_per_client: int = 2, beta: float = 0.5,
                     seed: int = 0):
    """Partition a Dataset into device arrays for the round engine.

    Returns (data, test) where data = {"x": (N, n_i, ...), "y": (N, n_i)}.
    """
    if scheme == "label_shard":
        xs, ys = partition_label_shard(
            ds.x_train, ds.y_train, n_clients=n_clients,
            classes_per_client=classes_per_client, seed=seed)
    elif scheme == "dirichlet":
        xs, ys = partition_dirichlet(
            ds.x_train, ds.y_train, n_clients=n_clients, beta=beta, seed=seed)
    elif scheme == "iid":
        rng = np.random.default_rng(seed)
        idx = rng.permutation(len(ds.y_train))
        n_i = len(idx) // n_clients
        idx = idx[: n_i * n_clients].reshape(n_clients, n_i)
        xs, ys = ds.x_train[idx], ds.y_train[idx]
    else:
        raise ValueError(f"unknown scheme {scheme}")
    data = {"x": jnp.asarray(xs), "y": jnp.asarray(ys)}
    test = {"x": jnp.asarray(ds.x_test), "y": jnp.asarray(ds.y_test)}
    return data, test
