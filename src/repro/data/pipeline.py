"""Federated data pipeline glue.

Two layouts feed the round engine:

* **ragged / pooled (lossless)** — :func:`federated_pooled` keeps the
  partitioners' full heterogeneous shards: all examples live in one
  pooled ``(Σnᵢ, ...)`` buffer indexed by a static CSR
  :class:`repro.utils.ragged.RaggedSpec` (pass it to ``make_round_fn``
  as ``ragged=``).  Conservation holds by construction — Σnᵢ equals the
  dataset size.
* **rectangular (legacy, visibly lossy)** — :func:`federated_arrays`
  stacks equal-size ``(N, nᵢ, ...)`` shards by trimming every client to
  the smallest shard (:func:`stack_trimmed`).  This is the old
  ``_equalize`` behavior moved where the loss is explicit: the
  partition itself never drops data any more, only this stacking step
  does, and it reports how many points it threw away.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.utils.ragged import pool_data
from .partition import partition_dirichlet, partition_label_shard
from .synthetic import Dataset


def stack_trimmed(shards_x, shards_y, *, seed: int = 0):
    """Ragged shards → equal-size stacked arrays by per-client trimming.

    Keeps a uniform random ``n_min``-subset of each client's shard
    (n_min = the smallest shard).  Returns ``(xs, ys, dropped)`` where
    ``dropped`` counts the examples the rectangular layout cost — the
    loss the ragged pooled path exists to avoid.
    """
    rng = np.random.default_rng(seed)
    n_min = min(len(s) for s in shards_y)
    xs, ys, total = [], [], 0
    for sx, sy in zip(shards_x, shards_y, strict=True):
        idx = rng.permutation(len(sy))[:n_min]
        xs.append(np.asarray(sx)[idx])
        ys.append(np.asarray(sy)[idx])
        total += len(sy)
    return np.stack(xs), np.stack(ys), total - n_min * len(shards_y)


def _partition(ds: Dataset, *, n_clients: int, scheme: str,
               classes_per_client: int, beta: float, seed: int):
    """Ragged shards + stats for any scheme (iid included)."""
    if scheme == "label_shard":
        return partition_label_shard(
            ds.x_train, ds.y_train, n_clients=n_clients,
            classes_per_client=classes_per_client, seed=seed)
    if scheme == "dirichlet":
        return partition_dirichlet(
            ds.x_train, ds.y_train, n_clients=n_clients, beta=beta,
            seed=seed)
    if scheme == "iid":
        from .partition import _finalize
        rng = np.random.default_rng(seed)
        idx = rng.permutation(len(ds.y_train))
        client_idx = np.array_split(idx, n_clients)
        num_classes = int(ds.y_train.max()) + 1
        return _finalize(ds.x_train, ds.y_train, client_idx, num_classes)
    raise ValueError(f"unknown scheme {scheme}")


def federated_arrays(ds: Dataset, *, n_clients: int, scheme: str = "label_shard",
                     classes_per_client: int = 2, beta: float = 0.5,
                     seed: int = 0):
    """Partition a Dataset into rectangular device arrays (legacy layout).

    Returns (data, test) where data = {"x": (N, n_i, ...), "y": (N, n_i)}.
    Shards are trimmed to the smallest client (`stack_trimmed`) — use
    :func:`federated_pooled` for the lossless ragged layout.
    """
    shards_x, shards_y, _ = _partition(
        ds, n_clients=n_clients, scheme=scheme,
        classes_per_client=classes_per_client, beta=beta, seed=seed)
    xs, ys, _ = stack_trimmed(shards_x, shards_y, seed=seed)
    data = {"x": jnp.asarray(xs), "y": jnp.asarray(ys)}
    test = {"x": jnp.asarray(ds.x_test), "y": jnp.asarray(ds.y_test)}
    return data, test


def federated_pooled(ds: Dataset, *, n_clients: int,
                     scheme: str = "dirichlet", classes_per_client: int = 2,
                     beta: float = 0.5, seed: int = 0, max_buckets: int = 4):
    """Partition a Dataset into the pooled ragged layout (lossless).

    Returns ``(data, test, spec, stats)``:

    * data = {"x": (Σnᵢ, ...), "y": (Σnᵢ,)} — one pooled buffer, every
      training example present exactly once (Σnᵢ == len(y_train));
    * spec — the static CSR :class:`RaggedSpec` (pass to
      ``make_round_fn(..., ragged=spec)``);
    * stats — :class:`repro.data.partition.PartitionStats` (per-client
      sizes, label histogram, dropped == 0).
    """
    shards_x, shards_y, stats = _partition(
        ds, n_clients=n_clients, scheme=scheme,
        classes_per_client=classes_per_client, beta=beta, seed=seed)
    data, spec = pool_data(shards_x, shards_y, max_buckets=max_buckets)
    assert spec.total == len(ds.y_train) and stats.dropped == 0, \
        (spec.total, len(ds.y_train), stats.dropped)
    test = {"x": jnp.asarray(ds.x_test), "y": jnp.asarray(ds.y_test)}
    return data, test, spec, stats
