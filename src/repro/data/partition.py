"""Non-i.i.d. client partitioners (paper §5).

* ``partition_label_shard`` — MNIST setup: each client holds points
  restricted to ``classes_per_client`` unique labels (paper: 2 digits
  per client, 100 clients).
* ``partition_dirichlet``  — CIFAR setup: class proportions per client
  drawn from Dirichlet(β) (paper: β = 0.5), following Yurochkin et al. /
  Wang et al.

Both return **ragged** shards — per-client lists of (nᵢ, ...) arrays —
plus a :class:`PartitionStats` record.  Nothing is trimmed: the old
``_equalize`` step silently dropped examples to force equal-size shards
for the rectangular engine, flattening exactly the per-client imbalance
the paper says drives participation dynamics.  The partitioners now
guarantee **conservation** (Σnᵢ equals the dataset size, asserted at
return) and the ragged CSR substrate (``repro.utils.ragged``) carries
the heterogeneity all the way into the round engine.  Rectangular
consumers stack-and-trim explicitly via
``repro.data.pipeline.stack_trimmed`` — a visible, accounted-for loss
instead of a silent one.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np


class PartitionStats(NamedTuple):
    """Heterogeneity accounting of one partition.

    ``dropped`` exists to make the conservation guarantee auditable: the
    ragged partitioners always report 0 (and assert it); only an
    explicit downstream ``stack_trimmed`` ever loses points.
    """

    sizes: np.ndarray  # (N,) int64 — per-client shard sizes nᵢ
    label_histogram: np.ndarray  # (N, C) int64 — per-client label counts
    dropped: int  # examples lost by the partition itself (always 0)

    @property
    def total(self) -> int:
        return int(self.sizes.sum())


def label_histogram(y_shards, num_classes: int) -> np.ndarray:
    """(N, C) label counts — works on ragged shard lists and on stacked
    (N, nᵢ) arrays alike; used by tests/examples to show non-iid-ness."""
    return np.stack([
        np.bincount(np.asarray(ys).ravel(), minlength=num_classes)
        for ys in y_shards
    ])


def _finalize(x, y, client_idx, num_classes: int):
    """Materialize ragged shards + stats; assert conservation."""
    shards_x = [x[np.asarray(ci, dtype=np.intp)] for ci in client_idx]
    shards_y = [y[np.asarray(ci, dtype=np.intp)] for ci in client_idx]
    sizes = np.asarray([len(ci) for ci in client_idx], np.int64)
    stats = PartitionStats(
        sizes=sizes,
        label_histogram=label_histogram(shards_y, num_classes),
        dropped=len(y) - int(sizes.sum()))
    assert stats.dropped == 0, \
        f"partition dropped {stats.dropped} of {len(y)} examples"
    return shards_x, shards_y, stats


def partition_label_shard(x, y, *, n_clients: int, classes_per_client: int = 2,
                          seed: int = 0):
    """Each client gets shards from exactly `classes_per_client` labels.

    Returns ``(x_shards, y_shards, stats)``: ragged per-client lists
    (every example assigned to exactly one client) + PartitionStats.
    Each client holds exactly ``classes_per_client`` distinct labels (a
    client's shards are dealt N positions apart from a class-major pool,
    and no class spans more than N consecutive pool slots, so the same
    class can never hit one client twice) — provided every class has at
    least as many examples as its shard count, ≈ N·cpc/num_classes
    (``np.array_split`` hands out empty shards for rarer classes, which
    only weakens "exactly" to "at most"; conservation always holds).
    """
    rng = np.random.default_rng(seed)
    num_classes = int(y.max()) + 1
    if classes_per_client > num_classes:
        raise ValueError(f"classes_per_client={classes_per_client} exceeds "
                         f"the {num_classes} classes present")
    # Split the classes into exactly n_clients * classes_per_client
    # shards (spread the remainder over the first classes) — the pool
    # covers every example, so the deal conserves the dataset.
    total_shards = n_clients * classes_per_client
    if total_shards < num_classes:
        raise ValueError(
            f"{total_shards} shards cannot cover {num_classes} classes "
            "without dropping data; raise n_clients or classes_per_client")
    base, extra = divmod(total_shards, num_classes)
    shard_pool = []
    for c in range(num_classes):
        idx = np.flatnonzero(y == c)
        rng.shuffle(idx)
        for s in np.array_split(idx, base + (1 if c < extra else 0)):
            shard_pool.append(s)
    # Deal class-major: (shuffled) client i takes pool slots i, i+N, ...
    order = rng.permutation(n_clients)
    client_idx = [
        np.concatenate([shard_pool[i + k * n_clients]
                        for k in range(classes_per_client)])
        for i in order
    ]
    return _finalize(x, y, client_idx, num_classes)


def partition_dirichlet(x, y, *, n_clients: int, beta: float = 0.5,
                        seed: int = 0, min_points: int = 8):
    """Dirichlet(β) label-proportion split (Li et al. 2021).

    Returns ``(x_shards, y_shards, stats)`` — ragged, conservation
    guaranteed (every example lands on exactly one client; redraws until
    every client holds ≥ ``min_points``).
    """
    rng = np.random.default_rng(seed)
    num_classes = int(y.max()) + 1
    while True:
        client_idx = [[] for _ in range(n_clients)]
        for c in range(num_classes):
            idx = np.flatnonzero(y == c)
            rng.shuffle(idx)
            p = rng.dirichlet(np.full(n_clients, beta))
            cuts = (np.cumsum(p) * len(idx)).astype(int)[:-1]
            for i, part in enumerate(np.split(idx, cuts)):
                client_idx[i].extend(part.tolist())
        if min(len(ci) for ci in client_idx) >= min_points:
            break
    return _finalize(x, y, client_idx, num_classes)
