"""Non-i.i.d. client partitioners (paper §5).

* ``partition_label_shard`` — MNIST setup: each client holds an equal
  number of points restricted to ``classes_per_client`` unique labels
  (paper: 2 digits per client, 100 clients).
* ``partition_dirichlet``  — CIFAR setup: class proportions per client
  drawn from Dirichlet(β) (paper: β = 0.5), following Yurochkin et al. /
  Wang et al.

Both return equal-size shards (largest size that divides evenly; points
are duplicated-free trimmed) so client states stack into rectangular
arrays for the vmapped engine.
"""
from __future__ import annotations

import numpy as np


def _equalize(shards_x, shards_y, rng):
    n_min = min(len(y) for y in shards_y)
    xs, ys = [], []
    for x, y in zip(shards_x, shards_y):
        idx = rng.permutation(len(y))[:n_min]
        xs.append(x[idx])
        ys.append(y[idx])
    return np.stack(xs), np.stack(ys)


def partition_label_shard(x, y, *, n_clients: int, classes_per_client: int = 2,
                          seed: int = 0):
    """Each client gets shards from exactly `classes_per_client` labels.

    Returns (x_shards, y_shards): (N, n_i, ...) equal-size arrays.
    """
    rng = np.random.default_rng(seed)
    num_classes = int(y.max()) + 1
    # Split each class into contiguous shards; deal 'classes_per_client'
    # shards to each client (the classic FedAvg pathological split).
    total_shards = n_clients * classes_per_client
    shards_per_class = max(-(-total_shards // num_classes), 1)  # ceil
    by_class = [np.flatnonzero(y == c) for c in range(num_classes)]
    for idx in by_class:
        rng.shuffle(idx)
    shard_pool = []
    for c, idx in enumerate(by_class):
        for s in np.array_split(idx, shards_per_class):
            shard_pool.append((c, s))
    rng.shuffle(shard_pool)
    shards_x, shards_y = [], []
    for i in range(n_clients):
        take = shard_pool[i * classes_per_client:(i + 1) * classes_per_client]
        idx = np.concatenate([s for _, s in take])
        shards_x.append(x[idx])
        shards_y.append(y[idx])
    return _equalize(shards_x, shards_y, rng)


def partition_dirichlet(x, y, *, n_clients: int, beta: float = 0.5,
                        seed: int = 0, min_points: int = 8):
    """Dirichlet(β) label-proportion split (Li et al. 2021)."""
    rng = np.random.default_rng(seed)
    num_classes = int(y.max()) + 1
    while True:
        client_idx = [[] for _ in range(n_clients)]
        for c in range(num_classes):
            idx = np.flatnonzero(y == c)
            rng.shuffle(idx)
            p = rng.dirichlet(np.full(n_clients, beta))
            cuts = (np.cumsum(p) * len(idx)).astype(int)[:-1]
            for i, part in enumerate(np.split(idx, cuts)):
                client_idx[i].extend(part.tolist())
        if min(len(ci) for ci in client_idx) >= min_points:
            break
    shards_x = [x[np.asarray(ci)] for ci in client_idx]
    shards_y = [y[np.asarray(ci)] for ci in client_idx]
    return _equalize(shards_x, shards_y, rng)


def label_histogram(y_shards, num_classes: int) -> np.ndarray:
    """(N, C) label counts — used by tests to assert non-iid-ness."""
    return np.stack([
        np.bincount(ys, minlength=num_classes) for ys in y_shards
    ])
