"""Deterministic synthetic image-classification datasets.

The container is offline, so MNIST/CIFAR-10 are replaced by structured
synthetic sets with matched shapes and difficulty knobs:

* ``make_synthetic_mnist``  — 10 classes, 784-dim inputs in [0, 1].
* ``make_synthetic_cifar``  — 10 classes, 32×32×3 inputs in [-1, 1].

Each class c is a mixture of ``modes_per_class`` anisotropic Gaussian
modes around a class prototype, plus heavy per-sample pixel noise and a
shared nuisance subspace that correlates classes — the noise scale is
calibrated (tests/test_data.py) so a centrally-trained MLP reaches
~90-95% test accuracy, mirroring the paper's 93% (MNIST-MLP) / 80%
(CIFAR-CNN) regimes.  All draws are from a fixed PRNG key: every run,
test and benchmark sees byte-identical data.

The paper's *claims are relative* (FedBack vs. random-selection
baselines under identical data); matching the distributional structure
(non-iid label shards / Dirichlet splits, class count, dimensionality)
is what matters for the reproduction, not the actual MNIST pixels.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np


class Dataset(NamedTuple):
    x_train: np.ndarray
    y_train: np.ndarray
    x_test: np.ndarray
    y_test: np.ndarray
    num_classes: int


def _make_blobs(rng: np.random.Generator, *, n_train, n_test, dim,
                num_classes, modes_per_class, proto_scale, mode_scale,
                noise, nuisance_dim, nuisance_scale, clip01,
                signal_dim=None, label_flip=0.0, smooth_hwc=None):
    """Class signal lives in a ``signal_dim``-dim random subspace (keeps
    effective SNR low despite the ambient dimension); ``label_flip``
    relabels that fraction of points uniformly — an irreducible-error
    floor that pins the achievable test accuracy (≈ 1 − label_flip).

    ``smooth_hwc=(H, W, C, coarse)``: draw the signal/nuisance bases as
    coarse ``coarse×coarse`` grids upsampled to H×W — low-frequency
    spatial patterns that convolution + pooling stacks can actually
    exploit (a flat random basis is invisible to a CNN)."""
    sd = signal_dim or dim

    def draw_basis(k):
        if smooth_hwc is None:
            return rng.normal(size=(k, dim)) / np.sqrt(sd)
        h, w, c, coarse = smooth_hwc
        g = rng.normal(size=(k, coarse, coarse, c))
        up = np.kron(g, np.ones((1, h // coarse, w // coarse, 1)))
        return up.reshape(k, h * w * c) / np.sqrt(sd)

    basis = draw_basis(sd)
    protos = rng.normal(size=(num_classes, sd)) * proto_scale
    modes = protos[:, None, :] + rng.normal(
        size=(num_classes, modes_per_class, sd)) * mode_scale
    nuis = draw_basis(nuisance_dim) * np.sqrt(sd / max(nuisance_dim, 1))

    def sample(n):
        y = rng.integers(0, num_classes, size=n)
        m = rng.integers(0, modes_per_class, size=n)
        x = modes[y, m] @ basis
        x = x + rng.normal(size=(n, dim)) * noise
        # shared nuisance subspace (class-independent structure)
        coef = rng.normal(size=(n, nuisance_dim)) * nuisance_scale
        x = x + coef @ nuis
        if clip01:
            x = 1.0 / (1.0 + np.exp(-x))  # squash into (0,1) like pixels
        else:
            x = np.tanh(x)
        if label_flip > 0:
            flip = rng.random(n) < label_flip
            y = np.where(flip, rng.integers(0, num_classes, size=n), y)
        return x.astype(np.float32), y.astype(np.int32)

    x_tr, y_tr = sample(n_train)
    x_te, y_te = sample(n_test)
    return Dataset(x_tr, y_tr, x_te, y_te, num_classes)


def make_synthetic_mnist(n_train: int = 12000, n_test: int = 2000,
                         seed: int = 1234) -> Dataset:
    """784-dim, 10-class 'MNIST'. Difficulty tuned for ~93% central MLP."""
    rng = np.random.default_rng(seed)
    return _make_blobs(
        rng, n_train=n_train, n_test=n_test, dim=784, num_classes=10,
        modes_per_class=3, proto_scale=1.0, mode_scale=0.45, noise=1.2,
        nuisance_dim=32, nuisance_scale=0.8, clip01=True,
        signal_dim=24, label_flip=0.055)


def make_synthetic_cifar(n_train: int = 10000, n_test: int = 2000,
                         seed: int = 4321) -> Dataset:
    """32×32×3, 10-class 'CIFAR-10'. Harder: more modes, more noise
    (central CNN ≈ 80%). Returned flat (n, 3072); reshape in the model."""
    rng = np.random.default_rng(seed)
    ds = _make_blobs(
        rng, n_train=n_train, n_test=n_test, dim=3072, num_classes=10,
        modes_per_class=8, proto_scale=0.7, mode_scale=0.9, noise=1.5,
        nuisance_dim=96, nuisance_scale=0.6, clip01=False,
        signal_dim=40, label_flip=0.17, smooth_hwc=(32, 32, 3, 8))
    return ds


def make_least_squares(n_clients: int, n_points: int = 16, dim: int = 8,
                       seed: int = 0):
    """Per-client least-squares shards with heterogeneous targets.

    The analytically-solvable problem family used by the engine tests,
    sweep demos and round benchmarks: client i holds (A_i, b_i) with
    b_i = A_i θ_i^true, so local minimizers genuinely differ (non-iid).

    Returns (data, params0, ls_loss) ready for ``make_round_fn``:
    data = {"x": (N, n_points, dim), "y": (N, n_points)} jnp arrays,
    params0 = {"theta": zeros(dim)}, ls_loss(params, x, y) → scalar.
    """
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    A = rng.normal(size=(n_clients, n_points, dim)).astype(np.float32)
    theta_true = rng.normal(size=(n_clients, dim)).astype(np.float32)
    b = np.einsum("npd,nd->np", A, theta_true).astype(np.float32)

    def ls_loss(params, x, y):
        r = x @ params["theta"] - y
        return 0.5 * jnp.mean(r * r)

    return ({"x": jnp.asarray(A), "y": jnp.asarray(b)},
            {"theta": jnp.zeros((dim,), jnp.float32)}, ls_loss)
