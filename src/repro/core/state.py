"""Federated state containers.

All client-side quantities are *stacked* pytrees with a leading client
axis of size N — one jittable program advances every client at once:
vmap over the axis on a single device, or lay it out over the 1-D
``clients`` device mesh (``repro.sharding.clients``) so the same program
runs the local solves embarrassingly parallel across devices and the
consensus mean as a cross-device all-reduce.

**Flat layout (the engine's primary layout).**  When the round is built
with a ``FlatSpec`` (``repro.utils.flatstate``), θ, λ and z_prev are
stored as contiguous (N, D) fp32 matrices — a single-leaf pytree each —
and ω as a (D,) vector.  Every per-round elementwise pass then touches
exactly one buffer (and the Pallas trigger/ADMM kernels read the state
in place, no per-round ``concatenate`` copy).  The stacked-pytree
("tree") layout remains fully supported: FLState fields hold whichever
layout the state was initialized with, and all generic consumers
(checkpointing, shardings, tree_map algebra) work on both.

``CLIENT_STACKED_FIELDS`` names the FLState fields that carry the
stacked axis; everything else (ω, rng, round) is server-side and stays
replicated under the mesh layout.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax

from .controller import ControllerState

#: FLState fields whose leaves carry the leading (N, ...) client axis.
CLIENT_STACKED_FIELDS = ("theta", "lam", "z_prev")

#: ControllerState fields with a per-client (N,) vector.
CTRL_STACKED_FIELDS = ("delta", "load", "event_count")


class FLState(NamedTuple):
    theta: Any  # stacked pytree (N, ...) — local primal variables θ_i
    lam: Any  # stacked pytree (N, ...) — dual variables λ_i (zeros for FedAvg/Prox)
    z_prev: Any  # stacked pytree (N, ...) — server copies z_i^prev = θ_i + λ_i
    omega: Any  # pytree — server parameters ω
    ctrl: ControllerState  # participation controller (inert for random selection)
    rng: jax.Array  # PRNG key advanced once per round
    round: jax.Array  # () int32


class RoundMetrics(NamedTuple):
    events: jax.Array  # (N,) bool — S_i^k (trigger/selection decisions)
    num_events: jax.Array  # () int32
    distances: jax.Array  # (N,) fp32 — ‖ω − z_i^prev‖
    delta: jax.Array  # (N,) fp32 — thresholds after the round
    load: jax.Array  # (N,) fp32 — low-pass participation estimates
    train_loss: jax.Array  # () fp32 — mean local loss among participants
    num_deferred: jax.Array  # () int32 — fired clients beyond capacity
    #                          (0 in the dense engine; see core/compact.py)
