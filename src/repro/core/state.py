"""Federated state containers.

All client-side quantities are *stacked* pytrees with a leading client
axis of size N — one jittable program advances every client at once:
vmap over the axis on a single device, or lay it out over the 1-D
``clients`` device mesh (``repro.sharding.clients``) so the same program
runs the local solves embarrassingly parallel across devices and the
consensus mean as a cross-device all-reduce.

**Flat layout (the engine's primary layout).**  When the round is built
with a ``FlatSpec`` (``repro.utils.flatstate``), θ, λ and z_prev are
stored as contiguous (N, D) fp32 matrices — a single-leaf pytree each —
and ω as a (D,) vector.  Every per-round elementwise pass then touches
exactly one buffer (and the Pallas trigger/ADMM kernels read the state
in place, no per-round ``concatenate`` copy).  The stacked-pytree
("tree") layout remains fully supported: FLState fields hold whichever
layout the state was initialized with, and all generic consumers
(checkpointing, shardings, tree_map algebra) work on both.

``CLIENT_STACKED_FIELDS`` names the FLState fields that carry the
stacked axis; everything else (ω, rng, round) is server-side and stays
replicated under the mesh layout.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils.pytree import tree_zeros_like

from .controller import ControllerState

#: FLState fields whose leaves carry the leading (N, ...) client axis.
CLIENT_STACKED_FIELDS = ("theta", "lam", "z_prev", "queue", "inflight",
                         "comm")

#: ControllerState fields with a per-client (N,) vector.
CTRL_STACKED_FIELDS = ("delta", "load", "event_count")


class DeferQueue(NamedTuple):
    """Persistent deferral queue of the compacted engine (core/compact.py).

    Clients that fired but overflowed the round's capacity are *carried*
    into the next round's plan instead of waiting to re-trigger.  Both
    fields are per-client (N,) vectors, so the queue is shard-local
    under the ``clients`` mesh by construction — a deferred client is
    always served by the device that owns its state row (documented
    no-cross-shard-migration invariant; see docs/compaction.md).
    """

    age: jax.Array  # (N,) int32 — rounds spent deferred; 0 = not pending.
    #                 Monotone +1 per unserved round, reset on commit.
    load: jax.Array  # (N,) fp32 — EMA of demand membership (fired ∪
    #                  pending); Σ over a shard estimates that shard's
    #                  per-round solver-row demand (adaptive capacity).


class InFlight(NamedTuple):
    """Per-client delay pipeline of the stale-tolerant round engine.

    A solve *serviced* at round k does not commit immediately: its
    result is parked here and lands at round k+δ_i, where δ_i is the
    client's (deterministic, per-run-static) delay drawn by
    :func:`delay_schedule`.  Because a client with an in-flight solve is
    ineligible to re-fire (the eligibility mask threaded through
    ``core/compact.py`` planning), one slot per client suffices — the
    pipeline is a bounded-staleness commit rule, never an unbounded
    backlog.  All fields are client-stacked (leading axis N), so the
    pipeline is shard-local under the ``clients`` mesh exactly like the
    ``DeferQueue`` — an in-flight solve always lands on the device that
    owns the client's state row.

    ``hist`` is the issued-event ring buffer that gives the controller
    commit-time measurements: the server learns that client i fired at
    round k only when the upload lands at round k+δ_i (at
    ``max_staleness=0`` the ring has one column and the measurement is
    the issue itself — the synchronous engine, bit for bit).
    """

    delay: jax.Array  # (N,) int32 — per-client commit delay δ_i in
    #                   [0, max_staleness]; static over the run.
    ttl: jax.Array  # (N,) int32 — rounds until the parked payload
    #                 lands; 0 = no solve in flight (client eligible).
    theta: Any  # stacked pytree (N, ...) — parked θ_i solve results
    lam: Any  # stacked pytree (N, ...) — parked λ_i^{k+1}
    z: Any  # stacked pytree (N, ...) — parked z_i = θ_i + λ_i uploads
    hist: jax.Array  # (N, max_staleness+1) bool — issued-event ring
    #                  buffer (column k mod (S+1) holds round k's
    #                  issues); read back δ_i rounds later.


def delay_schedule(n_clients: int, max_staleness: int, *,
                   kind: str = "roundrobin", seed: int = 0) -> jax.Array:
    """Deterministic per-client delay draw δ_i ∈ [0, max_staleness].

    ``roundrobin`` (default) cycles 0..S over the client index — fully
    reproducible with an exactly uniform delay histogram.  ``uniform``
    draws i.i.d. uniform delays from a seed-derived PRNG key (still
    deterministic per seed).  Traces stay reproducible either way.
    """
    if max_staleness < 0:
        raise ValueError(f"max_staleness must be >= 0, got {max_staleness}")
    if kind == "roundrobin":
        return jnp.arange(n_clients, dtype=jnp.int32) % (max_staleness + 1)
    if kind == "uniform":
        key = jax.random.fold_in(jax.random.PRNGKey(seed), 0x5A1E)
        return jax.random.randint(key, (n_clients,), 0, max_staleness + 1,
                                  jnp.int32)
    raise ValueError(f"unknown delay schedule kind: {kind}")


def init_inflight(template, n_clients: int, max_staleness: int, *,
                  kind: str = "roundrobin", seed: int = 0) -> InFlight:
    """Empty pipeline: nothing in flight, all-False event history.

    ``template`` is any client-stacked state pytree (θ works for both
    the flat (N, D) and the stacked-pytree layout) — the payload
    buffers mirror its structure.
    """
    return InFlight(
        delay=delay_schedule(n_clients, max_staleness, kind=kind, seed=seed),
        ttl=jnp.zeros((n_clients,), jnp.int32),
        theta=tree_zeros_like(template),
        lam=tree_zeros_like(template),
        z=tree_zeros_like(template),
        hist=jnp.zeros((n_clients, max_staleness + 1), bool),
    )


class FLState(NamedTuple):
    theta: Any  # stacked pytree (N, ...) — local primal variables θ_i
    lam: Any  # stacked pytree (N, ...) — dual variables λ_i (zeros for FedAvg/Prox)
    z_prev: Any  # stacked pytree (N, ...) — server copies z_i^prev = θ_i + λ_i
    omega: Any  # pytree — server parameters ω
    ctrl: ControllerState  # participation controller (inert for random selection)
    rng: jax.Array  # PRNG key advanced once per round
    round: jax.Array  # () int32
    queue: Any = None  # DeferQueue — compaction carry state (zeros/ones
    #                    at init; passed through unchanged by the dense
    #                    engine).  Optional for hand-built states in
    #                    tests; init_state always materializes it.
    inflight: Any = None  # InFlight — stale-tolerant commit pipeline;
    #                       materialized by init_state iff
    #                       cfg.max_staleness is not None (None = the
    #                       synchronous engine, no pipeline state).
    comm: Any = None  # (N, D) fp32 — per-client error-feedback residual
    #                   of the compressed consensus (core/compress.py);
    #                   materialized by init_state iff
    #                   cfg.consensus_compress != "none" (None = the
    #                   uncompressed wire, no residual state).


@dataclasses.dataclass
class HostState:
    """Host-offloaded client state (``FLConfig.state_backend="host"``).

    The client-stacked (N, D) matrices — θ, λ, z_prev, the EF residual
    ``comm`` and the parked in-flight payloads — live in host ``numpy``
    buffers; only ω, the controller/queue/pipeline *vectors* (O(N)
    scalars per client, not O(N·D) rows) and the per-round (C, D)
    active-row working set ever reach device memory.  The streaming
    round (``repro.core.hoststate``) gathers the ``CompactPlan``'s C
    rows out of these buffers, streams them to the device in
    double-buffered tiles, solves at the same capacity width as the
    device engine, and scatters results back in place — the buffers are
    mutated between rounds, which is exactly why this is a (mutable)
    dataclass and not part of the immutable ``FLState`` pytree.

    ``distances`` caches the next round's trigger distances
    ‖ω − z_i^prev‖: the server's consensus and trigger passes both read
    the full z_prev, so the streaming round computes them together in
    ONE full-width pass at the end of round k (ω_k first, then the
    round-k+1 distances from ω_k and the same z rows) instead of
    streaming z_prev twice per round.  It is derived state — never
    checkpointed, recomputed on restore (``from_checkpoint_tree``).

    ``inflight`` reuses the :class:`InFlight` container with its
    delay/ttl/hist vectors on device and its θ/λ/z payload *matrices* as
    host numpy buffers (the commit pipeline is a per-row copy between
    host buffers, no device round-trip).
    """

    theta: np.ndarray  # (N, D) fp32 host
    lam: np.ndarray  # (N, D) fp32 host
    z_prev: np.ndarray  # (N, D) fp32 host
    omega: jax.Array  # (D,) device
    ctrl: ControllerState  # per-client (N,) vectors, device
    rng: jax.Array
    round: jax.Array  # () int32
    queue: DeferQueue  # (N,) vectors, device
    distances: jax.Array | None = None  # (N,) fp32 device — NEXT round's
    #                       trigger distances, pipelined from the
    #                       aggregate pass; None = not yet computed
    #                       (fresh init / just restored) — the round
    #                       engine fills it in with one trigger pass
    inflight: InFlight | None = None  # delay/ttl/hist on device, parked
    #                                   θ/λ/z payloads as host numpy
    comm: np.ndarray | None = None  # (N, D) fp32 host — EF residual

    def to_checkpoint_tree(self) -> "FLState":
        """FLState-shaped pytree with the host buffers as numpy leaves.

        ``checkpoint.store.save_checkpoint`` device_gets the tree —
        numpy leaves pass through untouched, so the (N, D) matrices are
        written straight from host memory with no device round-trip.
        The tree structure equals a device-backend ``FLState`` with the
        same config, so checkpoints resume across backends both ways.
        ``distances`` is derived state and deliberately not stored.
        """
        return FLState(theta=self.theta, lam=self.lam, z_prev=self.z_prev,
                       omega=self.omega, ctrl=self.ctrl, rng=self.rng,
                       round=self.round, queue=self.queue,
                       inflight=self.inflight, comm=self.comm)

    def device_state_bytes(self) -> int:
        """Live device bytes of the *persistent* state: O(N) vectors +
        the (D,) server ω — no (N, D) client matrix is device-resident
        between rounds (the working set and the one full-width server
        pass are transient within a round)."""
        leaves = jax.tree.leaves(
            (self.omega, self.ctrl, self.rng, self.round, self.queue,
             self.distances,  # None (lazy) contributes no leaves
             None if self.inflight is None else
             (self.inflight.delay, self.inflight.ttl, self.inflight.hist)))
        return sum(x.size * x.dtype.itemsize for x in leaves)

    def host_state_bytes(self) -> int:
        """Bytes of the host-resident (N, D) client matrices."""
        mats = [self.theta, self.lam, self.z_prev]
        if self.comm is not None:
            mats.append(self.comm)
        if self.inflight is not None:
            mats += [self.inflight.theta, self.inflight.lam,
                     self.inflight.z]
        return sum(m.nbytes for m in mats)


class RoundMetrics(NamedTuple):
    events: jax.Array  # (N,) bool — S_i^k (trigger/selection decisions)
    num_events: jax.Array  # () int32
    distances: jax.Array  # (N,) fp32 — ‖ω − z_i^prev‖
    delta: jax.Array  # (N,) fp32 — thresholds after the round
    load: jax.Array  # (N,) fp32 — low-pass participation estimates
    train_loss: jax.Array  # () fp32 — mean local loss among participants
    num_deferred: jax.Array  # () int32 — deferral-queue length after the
    #                          round (demand − served; 0 in the dense
    #                          engine; see core/compact.py)
    realized_capacity: jax.Array  # () int32 — solver rows the round was
    #                               allowed to commit (Σ over shards of
    #                               the adaptive per-device limit; N on
    #                               the dense path)
    realized_slack: jax.Array  # () fp32 — realized_capacity / (L̄·N),
    #                            the round's effective capacity slack
    #                            (1/L̄ on the dense path)
    num_inflight: Any = None  # () int32 — solves in flight after the
    #                           round (0 on the synchronous engine)
    num_landed: Any = None  # () int32 — delayed solves that committed
    #                         this round (0 on the synchronous engine)
    committed: Any = None  # (N,) bool — clients whose θ/λ/z_prev rows
    #                        committed this round (= events on the dense
    #                        synchronous path; serviced rows under
    #                        compaction; direct|landed under staleness).
    #                        The serve loop (core/schedule.py) pairs this
    #                        against admissions for per-commit latency.
