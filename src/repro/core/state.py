"""Federated state containers.

All client-side quantities are *stacked* pytrees with a leading client
axis of size N — one jittable program advances every client at once:
vmap over the axis on a single device, or lay it out over the 1-D
``clients`` device mesh (``repro.sharding.clients``) so the same program
runs the local solves embarrassingly parallel across devices and the
consensus mean as a cross-device all-reduce.

**Flat layout (the engine's primary layout).**  When the round is built
with a ``FlatSpec`` (``repro.utils.flatstate``), θ, λ and z_prev are
stored as contiguous (N, D) fp32 matrices — a single-leaf pytree each —
and ω as a (D,) vector.  Every per-round elementwise pass then touches
exactly one buffer (and the Pallas trigger/ADMM kernels read the state
in place, no per-round ``concatenate`` copy).  The stacked-pytree
("tree") layout remains fully supported: FLState fields hold whichever
layout the state was initialized with, and all generic consumers
(checkpointing, shardings, tree_map algebra) work on both.

``CLIENT_STACKED_FIELDS`` names the FLState fields that carry the
stacked axis; everything else (ω, rng, round) is server-side and stays
replicated under the mesh layout.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax

from .controller import ControllerState

#: FLState fields whose leaves carry the leading (N, ...) client axis.
CLIENT_STACKED_FIELDS = ("theta", "lam", "z_prev", "queue")

#: ControllerState fields with a per-client (N,) vector.
CTRL_STACKED_FIELDS = ("delta", "load", "event_count")


class DeferQueue(NamedTuple):
    """Persistent deferral queue of the compacted engine (core/compact.py).

    Clients that fired but overflowed the round's capacity are *carried*
    into the next round's plan instead of waiting to re-trigger.  Both
    fields are per-client (N,) vectors, so the queue is shard-local
    under the ``clients`` mesh by construction — a deferred client is
    always served by the device that owns its state row (documented
    no-cross-shard-migration invariant; see docs/compaction.md).
    """

    age: jax.Array  # (N,) int32 — rounds spent deferred; 0 = not pending.
    #                 Monotone +1 per unserved round, reset on commit.
    load: jax.Array  # (N,) fp32 — EMA of demand membership (fired ∪
    #                  pending); Σ over a shard estimates that shard's
    #                  per-round solver-row demand (adaptive capacity).


class FLState(NamedTuple):
    theta: Any  # stacked pytree (N, ...) — local primal variables θ_i
    lam: Any  # stacked pytree (N, ...) — dual variables λ_i (zeros for FedAvg/Prox)
    z_prev: Any  # stacked pytree (N, ...) — server copies z_i^prev = θ_i + λ_i
    omega: Any  # pytree — server parameters ω
    ctrl: ControllerState  # participation controller (inert for random selection)
    rng: jax.Array  # PRNG key advanced once per round
    round: jax.Array  # () int32
    queue: Any = None  # DeferQueue — compaction carry state (zeros/ones
    #                    at init; passed through unchanged by the dense
    #                    engine).  Optional for hand-built states in
    #                    tests; init_state always materializes it.


class RoundMetrics(NamedTuple):
    events: jax.Array  # (N,) bool — S_i^k (trigger/selection decisions)
    num_events: jax.Array  # () int32
    distances: jax.Array  # (N,) fp32 — ‖ω − z_i^prev‖
    delta: jax.Array  # (N,) fp32 — thresholds after the round
    load: jax.Array  # (N,) fp32 — low-pass participation estimates
    train_loss: jax.Array  # () fp32 — mean local loss among participants
    num_deferred: jax.Array  # () int32 — deferral-queue length after the
    #                          round (demand − served; 0 in the dense
    #                          engine; see core/compact.py)
    realized_capacity: jax.Array  # () int32 — solver rows the round was
    #                               allowed to commit (Σ over shards of
    #                               the adaptive per-device limit; N on
    #                               the dense path)
    realized_slack: jax.Array  # () fp32 — realized_capacity / (L̄·N),
    #                            the round's effective capacity slack
    #                            (1/L̄ on the dense path)
