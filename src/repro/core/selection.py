"""Client-selection strategies.

The paper contrasts two families:

* **fedback** — deterministic event-triggered selection driven by the
  integral feedback controller (Alg. 1).  The server fires client i when
  ‖ω^k − z_i^prev‖ ≥ δ_i^k and adapts δ_i to hit the target rate L̄_i.
* **random** — the classical scheme used by FedAvg/FedProx/FedADMM: an
  ⌊L̄·N⌋-subset sampled uniformly at random each round.

Both produce an (N,) boolean event vector per round; they are
interchangeable inside the round engine, which is exactly how the paper
frames its baselines ("FedADMM is FedBack with random selection").

Every strategy takes an optional ``ctrl_overrides`` dict of *runtime*
controller-gain overrides (e.g. ``{"K": k, "target_rate": r}``) whose
values may be traced scalars — this is what lets the batched sweep
runner (``repro.launch.sweep``) vmap one compiled round program over a
whole grid of controller gains.  Strategies whose controller is inert
(random/full/...) ignore it.

All strategies are pure per-client programs except the permutation-based
ones (random, round_robin), which need the global client count; under a
client-sharded mesh GSPMD keeps the permutation replicated and scatters
the events, so every strategy works unchanged on the sharded engine.

**Capacity interplay.**  Under the compacted engine (``cfg.compact``,
``repro.core.compact``) the events a strategy emits are *selection*
decisions: when this round's demand (fresh events plus the carried
deferral queue) exceeds the round's commit limit, the overflow enters
the persistent ``DeferQueue`` and is served in a later round with
age-ordered, starvation-free priority — a deferred client does not
need to re-fire; it is carried into every subsequent plan until served
(``RoundMetrics.num_deferred`` is the queue length).  The controller
keeps measuring the raw events — it regulates the trigger, and the
integral law drives the trigger rate toward L̄ ≤ C/N, so the queue
drains from the round-0 burst with per-client wait bounded by ⌈N/C⌉
rounds regardless of N.  Strategies need no capacity awareness of
their own.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from .controller import ControllerConfig, ControllerState, \
    clamp_target_rate, controller_step
from .trigger import evaluate_trigger


class _SelectionBase:
    """Decide/measure split shared by every strategy.

    ``decide`` emits the round's selection events without touching the
    controller; ``measure`` advances the controller given the events the
    server actually *observed* — the same events on the synchronous
    engine, the delayed commit-time stream on the stale-tolerant one
    (``staleness_delay`` is the per-client delay vector; the target rate
    is clamped to the feasible ceiling 1/(1+δ_i) as anti-windup, see
    ``controller.feasible_rate``).  ``__call__`` is the one-shot
    synchronous composition the dense/compact engines use.

    ``decide`` takes the engine's eligibility mask (None on the
    synchronous engine): feedback strategies ignore it (the engine
    masks their events and the integral law self-corrects), but the
    open-loop k-subset strategies (random, round-robin) draw their k
    picks *among eligible clients* — discarding in-flight picks instead
    would systematically under-shoot the target rate (at uniform delay
    δ the fixed point of f = L̄·(1−f) is L̄/(1+L̄), below the feasible
    1/(1+δ)).  With everyone eligible the mask-aware draw reduces to
    the unrestricted one bit for bit, which keeps the staleness-0
    parity exact.
    """

    def _measure_cfg(self, ctrl_overrides) -> ControllerConfig:
        raise NotImplementedError

    def decide(self, rng, state, distances, ctrl_overrides=None,
               eligible=None):
        raise NotImplementedError

    def measure(self, ctrl: ControllerState, events, ctrl_overrides=None,
                *, staleness_delay=None) -> ControllerState:
        cfg = self._measure_cfg(ctrl_overrides)
        if staleness_delay is not None:
            cfg = cfg._replace(target_rate=clamp_target_rate(
                cfg.target_rate, staleness_delay))
        return controller_step(ctrl, events, cfg)

    def __call__(self, rng, state, distances, ctrl_overrides=None):
        events = self.decide(rng, state, distances, ctrl_overrides)
        return events, self.measure(state.ctrl, events, ctrl_overrides)


def _first_k_eligible(order_rank, eligible, k):
    """Events for the first k eligible clients in a given total order.

    order_rank: (N,) int32 — each client's position in the strategy's
    draw order (a permutation rank or cyclic distance).  With
    ``eligible=None`` this is exactly ``order_rank < k``; otherwise
    ineligible clients are pushed behind every eligible one (order
    preserved within each group) and the first k *eligible* fire — the
    redraw that keeps open-loop strategies on target under staleness.
    """
    n = order_rank.shape[0]
    if eligible is None:
        return order_rank < k
    keyed = jnp.where(eligible, order_rank, order_rank + n)
    pos = jnp.zeros((n,), jnp.int32).at[
        jnp.argsort(keyed).astype(jnp.int32)].set(
        jnp.arange(n, dtype=jnp.int32))
    return (pos < k) & eligible


def subset_size(rate: float, n: int) -> int:
    """k = max(⌊L̄·N⌋, 1) — the paper's k-subset cardinality.

    ``round`` (the old code) applied banker's rounding, so 0.25·10 → 2
    but 0.35·10 → 4 and 0.45·10 → 4: inconsistent across rates and off
    the spec.  Plain ``floor`` has its own trap: 0.29·100 is
    28.999999999999996 in binary, so ``floor(rate*n)`` would drop an
    exactly-representable product by one — the epsilon absorbs that
    representation error (any real mis-specification is ≫ 1e-9·n away
    from an integer).
    """
    return max(math.floor(rate * n + 1e-9), 1)


@dataclasses.dataclass(frozen=True)
class FedBackSelection(_SelectionBase):
    controller: ControllerConfig
    metric: str = "l2"

    def _measure_cfg(self, ctrl_overrides):
        return (self.controller if not ctrl_overrides
                else self.controller._replace(**ctrl_overrides))

    def decide(self, rng, state, distances, ctrl_overrides=None,
               eligible=None):
        # The trigger is feedback-controlled: the engine masks the
        # events and the integral law absorbs the lost participation
        # (with the feasible-rate clamp as the target's ceiling).
        return evaluate_trigger(distances, state.ctrl.delta)


@dataclasses.dataclass(frozen=True)
class RandomSelection(_SelectionBase):
    """Uniform L̄-fraction sampling without replacement (paper baselines)."""

    rate: float

    def _measure_cfg(self, ctrl_overrides):
        # Controller state still tracks realized events for metrics parity.
        return ControllerConfig(K=0.0, target_rate=self.rate)

    def decide(self, rng, state, distances, ctrl_overrides=None,
               eligible=None):
        n = state.ctrl.delta.shape[0]
        k = subset_size(self.rate, n)
        perm = jax.random.permutation(rng, n)
        rank = jnp.zeros((n,), jnp.int32).at[perm].set(
            jnp.arange(n, dtype=jnp.int32))
        return _first_k_eligible(rank, eligible, k)


@dataclasses.dataclass(frozen=True)
class BernoulliSelection(_SelectionBase):
    """I.i.d. Bernoulli(L̄) participation — unreliable-client ablation."""

    rate: float

    def _measure_cfg(self, ctrl_overrides):
        return ControllerConfig(K=0.0, target_rate=self.rate)

    def decide(self, rng, state, distances, ctrl_overrides=None,
               eligible=None):
        # i.i.d. coin flips model *unreliable clients* — an in-flight
        # client whose flip is discarded is exactly the modeled
        # unreliability, so no eligibility-aware redraw here.
        n = state.ctrl.delta.shape[0]
        return jax.random.bernoulli(rng, self.rate, (n,))


@dataclasses.dataclass(frozen=True)
class FullSelection(_SelectionBase):
    """δ ≡ 0 — vanilla consensus ADMM (every client, every round)."""

    def _measure_cfg(self, ctrl_overrides):
        return ControllerConfig(K=0.0, target_rate=1.0)

    def decide(self, rng, state, distances, ctrl_overrides=None,
               eligible=None):
        n = state.ctrl.delta.shape[0]
        return jnp.ones((n,), bool)


@dataclasses.dataclass(frozen=True)
class RoundRobinSelection(_SelectionBase):
    """Deterministic cyclic ⌊L̄N⌋-subset — a feedback-free deterministic
    control, used in ablations to isolate the value of the *adaptive*
    trigger over mere determinism."""

    rate: float

    def _measure_cfg(self, ctrl_overrides):
        return ControllerConfig(K=0.0, target_rate=self.rate)

    def decide(self, rng, state, distances, ctrl_overrides=None,
               eligible=None):
        n = state.ctrl.delta.shape[0]
        k = subset_size(self.rate, n)
        start = (state.round * k) % n
        cyclic = (jnp.arange(n, dtype=jnp.int32) - start) % n
        return _first_k_eligible(cyclic, eligible, k)


def make_selection(name: str, *, rate: float, controller: ControllerConfig,
                   metric: str = "l2"):
    name = name.lower()
    if name == "fedback":
        return FedBackSelection(controller=controller, metric=metric)
    if name == "random":
        return RandomSelection(rate=rate)
    if name == "bernoulli":
        return BernoulliSelection(rate=rate)
    if name == "full":
        return FullSelection()
    if name == "round_robin":
        return RoundRobinSelection(rate=rate)
    raise ValueError(f"unknown selection strategy: {name}")
