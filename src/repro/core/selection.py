"""Client-selection strategies.

The paper contrasts two families:

* **fedback** — deterministic event-triggered selection driven by the
  integral feedback controller (Alg. 1).  The server fires client i when
  ‖ω^k − z_i^prev‖ ≥ δ_i^k and adapts δ_i to hit the target rate L̄_i.
* **random** — the classical scheme used by FedAvg/FedProx/FedADMM: an
  ⌊L̄·N⌋-subset sampled uniformly at random each round.

Both produce an (N,) boolean event vector per round; they are
interchangeable inside the round engine, which is exactly how the paper
frames its baselines ("FedADMM is FedBack with random selection").

Every strategy takes an optional ``ctrl_overrides`` dict of *runtime*
controller-gain overrides (e.g. ``{"K": k, "target_rate": r}``) whose
values may be traced scalars — this is what lets the batched sweep
runner (``repro.launch.sweep``) vmap one compiled round program over a
whole grid of controller gains.  Strategies whose controller is inert
(random/full/...) ignore it.

All strategies are pure per-client programs except the permutation-based
ones (random, round_robin), which need the global client count; under a
client-sharded mesh GSPMD keeps the permutation replicated and scatters
the events, so every strategy works unchanged on the sharded engine.

**Capacity interplay.**  Under the compacted engine (``cfg.compact``,
``repro.core.compact``) the events a strategy emits are *selection*
decisions: when this round's demand (fresh events plus the carried
deferral queue) exceeds the round's commit limit, the overflow enters
the persistent ``DeferQueue`` and is served in a later round with
age-ordered, starvation-free priority — a deferred client does not
need to re-fire; it is carried into every subsequent plan until served
(``RoundMetrics.num_deferred`` is the queue length).  The controller
keeps measuring the raw events — it regulates the trigger, and the
integral law drives the trigger rate toward L̄ ≤ C/N, so the queue
drains from the round-0 burst with per-client wait bounded by ⌈N/C⌉
rounds regardless of N.  Strategies need no capacity awareness of
their own.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .controller import ControllerConfig, ControllerState, controller_step
from .trigger import trigger_distances, evaluate_trigger


@dataclasses.dataclass(frozen=True)
class FedBackSelection:
    controller: ControllerConfig
    metric: str = "l2"

    def __call__(self, rng, state, distances, ctrl_overrides=None):
        cfg = (self.controller if not ctrl_overrides
               else self.controller._replace(**ctrl_overrides))
        events = evaluate_trigger(distances, state.ctrl.delta)
        ctrl = controller_step(state.ctrl, events, cfg)
        return events, ctrl


@dataclasses.dataclass(frozen=True)
class RandomSelection:
    """Uniform L̄-fraction sampling without replacement (paper baselines)."""

    rate: float

    def __call__(self, rng, state, distances, ctrl_overrides=None):
        n = state.ctrl.delta.shape[0]
        k = max(int(round(self.rate * n)), 1)
        perm = jax.random.permutation(rng, n)
        events = jnp.zeros((n,), bool).at[perm[:k]].set(True)
        # Controller state still tracks realized events for metrics parity.
        ctrl = controller_step(state.ctrl, events,
                               ControllerConfig(K=0.0, target_rate=self.rate))
        return events, ctrl


@dataclasses.dataclass(frozen=True)
class BernoulliSelection:
    """I.i.d. Bernoulli(L̄) participation — unreliable-client ablation."""

    rate: float

    def __call__(self, rng, state, distances, ctrl_overrides=None):
        n = state.ctrl.delta.shape[0]
        events = jax.random.bernoulli(rng, self.rate, (n,))
        ctrl = controller_step(state.ctrl, events,
                               ControllerConfig(K=0.0, target_rate=self.rate))
        return events, ctrl


@dataclasses.dataclass(frozen=True)
class FullSelection:
    """δ ≡ 0 — vanilla consensus ADMM (every client, every round)."""

    def __call__(self, rng, state, distances, ctrl_overrides=None):
        n = state.ctrl.delta.shape[0]
        events = jnp.ones((n,), bool)
        ctrl = controller_step(state.ctrl, events,
                               ControllerConfig(K=0.0, target_rate=1.0))
        return events, ctrl


@dataclasses.dataclass(frozen=True)
class RoundRobinSelection:
    """Deterministic cyclic ⌊L̄N⌋-subset — a feedback-free deterministic
    control, used in ablations to isolate the value of the *adaptive*
    trigger over mere determinism."""

    rate: float

    def __call__(self, rng, state, distances, ctrl_overrides=None):
        n = state.ctrl.delta.shape[0]
        k = max(int(round(self.rate * n)), 1)
        start = (state.round * k) % n
        idx = (start + jnp.arange(k)) % n
        events = jnp.zeros((n,), bool).at[idx].set(True)
        ctrl = controller_step(state.ctrl, events,
                               ControllerConfig(K=0.0, target_rate=self.rate))
        return events, ctrl


def make_selection(name: str, *, rate: float, controller: ControllerConfig,
                   metric: str = "l2"):
    name = name.lower()
    if name == "fedback":
        return FedBackSelection(controller=controller, metric=metric)
    if name == "random":
        return RandomSelection(rate=rate)
    if name == "bernoulli":
        return BernoulliSelection(rate=rate)
    if name == "full":
        return FullSelection()
    if name == "round_robin":
        return RoundRobinSelection(rate=rate)
    raise ValueError(f"unknown selection strategy: {name}")
