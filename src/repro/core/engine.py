"""Shared round-engine primitives.

The FedBack algorithm family is one program shape instantiated twice in
this repo: the client-stacked *simulation* engine (``repro.core.fedback``,
N clients on a ``clients`` device-mesh axis) and the *cross-pod*
distributed engine (``repro.core.crosspod``, P pods on a ``pod`` axis).
Both engines are the same per-round algebra:

    dual ascent      λ_i ← λ_i + θ_i − ω                (Eq. 2.3, dual)
    prox center      c_i = ω − λ_i
    local solve      θ_i ← inexact prox of f_i at c_i   (vmapped / sharded)
    gated commit     state_i ← proposed_i  iff  S_i^k
    consensus        ω = (1/N) Σ_i z_i^prev             (Eq. 2.4)

This module holds that algebra once.  Every helper is written over
stacked pytrees with a leading client/pod axis; when that axis is laid
out over a device mesh the ``jnp.mean`` in :func:`consensus_mean` lowers
to a cross-device all-reduce and everything else stays embarrassingly
parallel — which is exactly why the two engines can share code.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.utils.pytree import tree_where


def dual_ascent(lam, theta, omega):
    """λ_i^{k+1} = λ_i^k + θ_i^k − ω^k over the stacked client axis.

    ``lam``/``theta`` are stacked pytrees (N, ...); ``omega`` is the
    unstacked server pytree (broadcast over the client axis).
    """
    return jax.tree.map(lambda l, t, w: l + t - w[None], lam, theta, omega)


def prox_center(omega, lam_new):
    """Per-client prox center c_i = ω^k − λ_i^{k+1} (Eq. 2.3)."""
    return jax.tree.map(lambda w, l: w[None] - l, omega, lam_new)


def gated_commit(events, proposed, current):
    """Event-gated state commit: client i keeps ``current`` unless S_i^k."""
    return tree_where(events, proposed, current)


def consensus_mean(z_prev):
    """ω = (1/N) Σ_i z_i^prev — stale entries included (Eq. 2.4).

    Under a client-sharded layout this mean is the round's one genuine
    collective (an all-reduce over the client mesh axis).
    """
    return jax.tree.map(lambda z: jnp.mean(z, axis=0), z_prev)


def participant_mean(per_client, events, fallback, num_events=None):
    """Mean over participants only (FedAvg/FedProx aggregation).

    per_client: stacked pytree (N, ...); ``fallback`` (unstacked) is
    returned when no client fired this round.
    """
    if num_events is None:
        num_events = jnp.sum(events.astype(jnp.int32))
    denom = jnp.maximum(num_events, 1).astype(jnp.float32)

    def avg(z, w):
        m = events.reshape((-1,) + (1,) * (z.ndim - 1))
        # accumulate in at-least-fp32 (never truncating f64), result cast
        # back to the leaf dtype so bf16 states don't silently upcast.
        acc = jnp.promote_types(z.dtype, jnp.float32)
        s = (jnp.sum(jnp.where(m, z, 0).astype(acc), axis=0)
             / denom.astype(acc))
        return jnp.where(num_events > 0, s.astype(z.dtype), w)

    return jax.tree.map(avg, per_client, fallback)


def masked_batch_loss(loss_fn, params, xb, yb, weights):
    """Weighted mean of per-example losses from a batch-mean ``loss_fn``.

    The ragged engine pads size-bucketed minibatches to the bucket
    capacity; padding slots must not contribute loss or gradient.  The
    engine's loss contract is ``loss_fn(params, x, y) -> mean over the
    batch``, so evaluating it on singleton batches (vmapped over the
    batch axis) recovers the per-example losses, which are then
    re-reduced under ``weights`` (0 = padding).  An all-zero weight
    vector yields 0 loss (and zero gradient) — a no-op solver step.
    """
    per = jax.vmap(
        lambda xe, ye: loss_fn(params, xe[None], ye[None]))(xb, yb)
    return jnp.sum(per * weights) / jnp.maximum(jnp.sum(weights), 1.0)


def participant_mean_loss(losses, events):
    """Mean local train loss among this round's participants ((), fp32)."""
    ev = events.astype(jnp.float32)
    return jnp.sum(losses * ev) / jnp.maximum(jnp.sum(ev), 1.0)


# --- stale-tolerant commit pipeline (bounded-staleness rounds) ----------
#
# The async engine separates *service* (the solver runs) from *commit*
# (the result lands in θ/λ/z_prev and the consensus sees it): a solve
# serviced at round k lands at round k+δ_i.  Everything below is pure
# per-client mask algebra over the stacked axis — shard-local under the
# clients mesh like the rest of the round, so the only collective stays
# the consensus mean.  With δ ≡ 0 every mask path reduces to the
# synchronous ``gated_commit`` bit for bit (land is never true, defer is
# never true, direct == serviced).


def staleness_masks(serviced, delay, ttl):
    """One pipeline step of the bounded-staleness commit rule.

    serviced: (N,) bool — rows the solver ran this round (ttl == 0 for
    all of them: an in-flight client is ineligible and a plan may never
    service it).  Returns ``(land, direct, defer, new_ttl)``:

    * ``land``   — parked payloads whose countdown expires this round;
    * ``direct`` — serviced rows with δ_i = 0 (the synchronous path);
    * ``defer``  — serviced rows with δ_i > 0 (payload parks, ttl = δ);
    * ``new_ttl``— countdown after the round.

    ``land`` and ``direct``/``defer`` are disjoint by construction:
    landing requires ttl ≥ 1, service requires ttl = 0.
    """
    land = ttl == 1
    direct = serviced & (delay == 0)
    defer = serviced & (delay > 0)
    new_ttl = jnp.where(defer, delay, jnp.maximum(ttl - 1, 0))
    return land, direct, defer, new_ttl


def staleness_commit(current, proposed, parked, land, direct, defer):
    """Route a proposed state field through the delay pipeline.

    Returns ``(committed, new_parked)``: rows landing from the pipeline
    take the parked payload, δ=0 service commits directly, everything
    else keeps ``current``; deferred service overwrites the parked slot
    (one outstanding solve per client — eligibility guarantees no
    clobbering).
    """
    committed = tree_where(land, parked, tree_where(direct, proposed,
                                                    current))
    new_parked = tree_where(defer, proposed, parked)
    return committed, new_parked


def record_issue(hist, issued, rnd):
    """Write round ``rnd``'s issued events into the (N, S+1) ring."""
    return hist.at[:, rnd % hist.shape[1]].set(issued)


def measured_commits(hist, delay, rnd):
    """Commit-time event measurements for the controller.

    Client i's issue at round k is *measured* at round k+δ_i — the
    server learns about participation when the upload lands, not when
    the trigger fires.  Reads column (rnd − δ_i) mod (S+1) of the ring
    (freshly written for δ_i = 0, i.e. the synchronous measurement);
    rounds earlier than δ_i read the all-False initialization.
    """
    col = (rnd - delay) % hist.shape[1]
    return jnp.take_along_axis(hist, col[:, None], axis=1)[:, 0]
