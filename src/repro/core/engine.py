"""Shared round-engine primitives.

The FedBack algorithm family is one program shape instantiated twice in
this repo: the client-stacked *simulation* engine (``repro.core.fedback``,
N clients on a ``clients`` device-mesh axis) and the *cross-pod*
distributed engine (``repro.core.crosspod``, P pods on a ``pod`` axis).
Both engines are the same per-round algebra:

    dual ascent      λ_i ← λ_i + θ_i − ω                (Eq. 2.3, dual)
    prox center      c_i = ω − λ_i
    local solve      θ_i ← inexact prox of f_i at c_i   (vmapped / sharded)
    gated commit     state_i ← proposed_i  iff  S_i^k
    consensus        ω = (1/N) Σ_i z_i^prev             (Eq. 2.4)

This module holds that algebra once.  Every helper is written over
stacked pytrees with a leading client/pod axis; when that axis is laid
out over a device mesh the ``jnp.mean`` in :func:`consensus_mean` lowers
to a cross-device all-reduce and everything else stays embarrassingly
parallel — which is exactly why the two engines can share code.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.utils.pytree import tree_where


def dual_ascent(lam, theta, omega):
    """λ_i^{k+1} = λ_i^k + θ_i^k − ω^k over the stacked client axis.

    ``lam``/``theta`` are stacked pytrees (N, ...); ``omega`` is the
    unstacked server pytree (broadcast over the client axis).
    """
    return jax.tree.map(lambda l, t, w: l + t - w[None], lam, theta, omega)


def prox_center(omega, lam_new):
    """Per-client prox center c_i = ω^k − λ_i^{k+1} (Eq. 2.3)."""
    return jax.tree.map(lambda w, l: w[None] - l, omega, lam_new)


def gated_commit(events, proposed, current):
    """Event-gated state commit: client i keeps ``current`` unless S_i^k."""
    return tree_where(events, proposed, current)


def consensus_mean(z_prev):
    """ω = (1/N) Σ_i z_i^prev — stale entries included (Eq. 2.4).

    Under a client-sharded layout this mean is the round's one genuine
    collective (an all-reduce over the client mesh axis).
    """
    return jax.tree.map(lambda z: jnp.mean(z, axis=0), z_prev)


def participant_mean(per_client, events, fallback, num_events=None):
    """Mean over participants only (FedAvg/FedProx aggregation).

    per_client: stacked pytree (N, ...); ``fallback`` (unstacked) is
    returned when no client fired this round.
    """
    if num_events is None:
        num_events = jnp.sum(events.astype(jnp.int32))
    denom = jnp.maximum(num_events, 1).astype(jnp.float32)

    def avg(z, w):
        m = events.reshape((-1,) + (1,) * (z.ndim - 1))
        # accumulate in at-least-fp32 (never truncating f64), result cast
        # back to the leaf dtype so bf16 states don't silently upcast.
        acc = jnp.promote_types(z.dtype, jnp.float32)
        s = (jnp.sum(jnp.where(m, z, 0).astype(acc), axis=0)
             / denom.astype(acc))
        return jnp.where(num_events > 0, s.astype(z.dtype), w)

    return jax.tree.map(avg, per_client, fallback)


def participant_mean_loss(losses, events):
    """Mean local train loss among this round's participants ((), fp32)."""
    ev = events.astype(jnp.float32)
    return jnp.sum(losses * ev) / jnp.maximum(jnp.sum(ev), 1.0)
