"""Host-offloaded streaming round engine (``state_backend="host"``).

The compact engine (core/compact.py) made per-round solver *compute*
∝ C = ⌈slack·L̄·N⌉, but the device backend still materializes every
(N, D) row of θ/λ/z_prev (and the EF residual ``comm``) in device
memory — footprint ∝ N, not ∝ the participation rate the FedBack
controller is explicitly driving down.  This module keeps the
client-stacked matrices in host ``numpy`` buffers (``HostState``) and
runs each round as three jitted device programs glued by host-side
row gathers/scatters:

1. **plan** — full-N but O(N)-vector work: PRNG split, selection,
   ``compact_plan``, queue update, (async) staleness masks and the
   commit-time controller step.  In/out: only (N,) vectors and the
   (C,) slot indices.  The (C, 2) slot PRNG keys stay on device.
2. **solve** — the (C, D) working set.  The host gathers the C active
   θ/λ rows out of its buffers with fancy indexing, streams them up as
   ``stream_tiles`` double-buffered ``jax.device_put`` tiles (puts are
   dispatched back-to-back, so copy t+1 overlaps the device consuming
   copy t; the tiles are donated — they are jax-owned copies, the host
   buffers stay the source of truth), and the program concatenates
   them back to the full capacity width C before the vmapped solve —
   concatenation is exact, so the solve runs at the *same* vmap width
   as the device block and is bit-identical to it.  Training data
   (rectangular (N, n, ...) or the pooled CSR buffer) is round-static
   and stays device-resident; the program gathers/slices it by slot
   index exactly like ``make_compact_block``.
3. **aggregate** — ONE full-width server pass per round: ``device_put``
   the scattered z_prev (and ``comm``), compute the consensus mean (or
   EF-compressed consensus) *and* the next round's trigger distances
   ‖ω_{k+1} − z_i‖ in the same program.  Consensus and trigger both
   read all N rows — Ω(N·D) server work the roofline already prices —
   so fusing them halves the full-width H2D traffic; the distances are
   cached on ``HostState.distances`` for the next plan step.

Results come back with a D2H fetch of the three (C, D) row matrices
and are scattered into the host buffers in place (numpy fancy-index
assignment at the valid slots' distinct client ids ≡ the device
``scatter_rows`` drop-scatter).  Under bounded staleness the commit
routes rows through the host-resident park buffers exactly like
``engine.staleness_commit`` (land: park→state copy; direct: slot
row→state; defer: slot row→park; serviced clients are ttl==0, so land
and serviced are disjoint).

**Bit-exactness.**  The device path stays the default and the parity
oracle.  Host == device bit for bit (events AND fp32 ω/θ/λ/z_prev)
because every device computation runs the same jnp ops at the same
shapes on the same values: selection/plan math is identical, the solve
runs at width C like the block, host gather/scatter moves exact fp32
rows, and XLA CPU/TPU reductions are run-to-run deterministic for a
given op shape.  Host-side numpy never *computes* — it only copies
rows — precisely because numpy and XLA reduction orders differ.

Per-round transfer budget (priced by the tracecheck
``host-transfer-budget`` rule): row-stream legs 2·C·D·4 B up +
3·C·D·4 B down (≤ the budgeted 8·C·D·4), one full-width server leg
N·D·4 B up (×2 with ``comm``, +N·D·4 down for the residual), and O(N)
bytes of plan vectors.  Persistent *device* state between rounds is
O(C·D) working set↔0 (transient) + O(N) vectors + the (D,) ω —
``HostState.device_state_bytes`` / ``host_state_bytes`` report both.
"""
from __future__ import annotations

import time
from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils.flatstate import FlatSpec

from .compact import (
    adaptive_limit,
    capacity_bounds,
    compact_plan,
    init_queue,
    queue_update,
)
from .compress import check_mode, ef_consensus, ef_participant_mean
from .controller import init_controller
from .engine import (
    consensus_mean,
    dual_ascent,
    measured_commits,
    participant_mean,
    participant_mean_loss,
    prox_center,
    record_issue,
    staleness_masks,
)
from .fedback import (
    ADMM_FAMILY,
    _ctrl_cfg,
    _epoch_indices,
    _local_solve,
    _masked_local_solve,
    _resolve_kernel_flag,
)
from .selection import make_selection
from .state import (
    DeferQueue,
    FLState,
    HostState,
    InFlight,
    RoundMetrics,
    delay_schedule,
)
from .trigger import trigger_distances


class _PlanView(NamedTuple):
    """The slice of FLState the selection strategies actually read
    (``decide`` touches only ``state.ctrl`` and ``state.round``)."""

    ctrl: Any
    round: Any


def _require(cond: bool, what: str) -> None:
    if not cond:
        raise ValueError(f"state_backend='host' {what}")


def init_host_state(cfg, params0, *, spec: FlatSpec) -> HostState:
    """Host-buffer twin of ``init_state``: same values, (N, D) matrices
    in host numpy.  ``distances`` starts lazy (None) — the first round
    fills it with one trigger pass, so init itself never touches the
    device with an (N, D) operand."""
    _require(spec is not None, "needs the flat (spec=) layout")
    _require(cfg.compact, "needs compact=True (the streaming round is "
             "built on the CompactPlan slot indices)")
    n = cfg.n_clients
    compress = check_mode(cfg.consensus_compress)
    flat0 = np.asarray(spec.flatten(params0))  # (D,) fp32
    inflight = None
    if cfg.max_staleness is not None:
        inflight = InFlight(
            delay=delay_schedule(n, cfg.max_staleness,
                                 kind=cfg.staleness_schedule,
                                 seed=cfg.seed),
            ttl=jnp.zeros((n,), jnp.int32),
            theta=spec.zeros_stacked_host(n),
            lam=spec.zeros_stacked_host(n),
            z=spec.zeros_stacked_host(n),
            hist=jnp.zeros((n, cfg.max_staleness + 1), bool),
        )
    return HostState(
        theta=spec.host_broadcast_rows(flat0, n),
        lam=spec.zeros_stacked_host(n),
        z_prev=spec.host_broadcast_rows(flat0, n),
        omega=jnp.asarray(flat0),
        ctrl=init_controller(n, _ctrl_cfg(cfg)),
        rng=jax.random.PRNGKey(cfg.seed),
        round=jnp.zeros((), jnp.int32),
        queue=init_queue(n),
        distances=None,
        inflight=inflight,
        comm=(spec.zeros_stacked_host(n) if compress != "none" else None),
    )


def host_state_from_tree(tree: FLState, cfg, *, spec: FlatSpec) -> HostState:
    """Rebuild a ``HostState`` from an FLState-shaped checkpoint tree.

    Leaves may be numpy (a host-backend checkpoint read straight off
    disk) or device arrays (a device-backend state being migrated):
    the (N, D) matrices land in host numpy buffers, the O(N) vectors
    on device.  ``distances`` is left lazy — recomputed by the first
    round — so restoring never stages an (N, D) device transfer.
    """
    _require(spec is not None, "needs the flat (spec=) layout")

    def mat(x) -> np.ndarray:
        return np.array(x, np.float32, copy=True)  # writable host buffer

    inflight = None
    if tree.inflight is not None:
        f = tree.inflight
        inflight = InFlight(delay=jnp.asarray(f.delay),
                            ttl=jnp.asarray(f.ttl),
                            theta=mat(f.theta), lam=mat(f.lam),
                            z=mat(f.z), hist=jnp.asarray(f.hist))
    return HostState(
        theta=mat(tree.theta), lam=mat(tree.lam), z_prev=mat(tree.z_prev),
        omega=jnp.asarray(tree.omega),
        ctrl=jax.tree.map(jnp.asarray, tree.ctrl),
        rng=jnp.asarray(tree.rng),
        round=jnp.asarray(tree.round),
        queue=jax.tree.map(jnp.asarray, tree.queue),
        distances=None,
        inflight=inflight,
        comm=(None if tree.comm is None else mat(tree.comm)),
    )


def host_state_to_device(host: HostState) -> FLState:
    """Materialize a device-backend ``FLState`` from host buffers (the
    host→device resume path; the one place an (N, D) upload of every
    field is the *point*)."""
    return jax.tree.map(jnp.asarray, host.to_checkpoint_tree())


def _tile_spans(capacity: int, tiles: int) -> tuple[tuple[int, int], ...]:
    """Static, contiguous, exhaustive [a, b) row spans of the working
    set — the double-buffer granularity of the H2D stream."""
    t = max(1, min(int(tiles), capacity))
    edges = [round(capacity * i / t) for i in range(t + 1)]
    return tuple((a, b) for a, b in zip(edges[:-1], edges[1:]))


def make_host_round_fn(cfg, loss_fn, data, *, jit: bool = True, mesh=None,
                       client_axis: str = "clients", donate=None,
                       ctrl_arg: bool = False, arrivals_arg: bool = False,
                       spec: FlatSpec | None = None, ragged=None,
                       body_transform=None):
    """Build the streaming round: ``round_fn(HostState) -> (HostState,
    RoundMetrics)``, bit-identical to ``make_round_fn`` with the same
    config on the device backend.

    ``body_transform`` wraps the *solve* program (the per-round hot
    program) before jit — the analysis layer's mutation/retrace hook,
    mirroring its role on the device path.
    """
    _require(mesh is None, "is a single-host backend (mesh must be None "
             "— shard the device backend instead)")
    _require(not ctrl_arg and not arrivals_arg,
             "does not take ctrl/arrivals runtime args")
    _require(jit, "requires jit=True (the streaming legs wrap jitted "
             "device programs)")
    _require(spec is not None, "needs the flat (spec=) layout")
    _require(cfg.compact, "needs compact=True")
    n = cfg.n_clients
    dim = spec.dim
    compress = check_mode(cfg.consensus_compress)
    is_admm = cfg.algorithm in ADMM_FAMILY
    async_mode = cfg.max_staleness is not None
    fused = is_admm and _resolve_kernel_flag(cfg.fused_gss)
    if cfg.fused_gss and not fused:
        raise ValueError(
            "fused_gss=True needs compact=True, an ADMM-family "
            "algorithm and the flat (spec=) layout — got "
            f"compact={cfg.compact}, algorithm={cfg.algorithm!r}, "
            "flat=True")
    # ``fused`` is accepted but has nothing extra to fuse here: the
    # streaming round's solve already IS the one-pass gather→solve→
    # scatter dataflow over the (C, D) working set (the scatter happens
    # on the host), and fused ≡ unfused is bitwise on the device path.

    if ragged is not None:
        if ragged.n_clients != n:
            raise ValueError(f"ragged spec describes {ragged.n_clients} "
                             f"clients, cfg.n_clients={n}")
        assert data["x"].shape[0] == ragged.buffer_rows, \
            (data["x"].shape, ragged.buffer_rows)
        n_points = ragged.max_size
        masked = not ragged.uniform
    else:
        assert data["x"].shape[0] == n, (data["x"].shape, n)
        n_points = data["x"].shape[1]
        masked = False

    select = make_selection(cfg.selection_name(), rate=cfg.participation,
                            controller=_ctrl_cfg(cfg),
                            metric=cfg.trigger_metric)
    rho = cfg.local_rho()
    tree_solver = partial(_local_solve, loss_fn, rho=rho, lr=cfg.lr,
                          momentum=cfg.momentum)
    tree_masked_solver = partial(_masked_local_solve, loss_fn, rho=rho,
                                 lr=cfg.lr, momentum=cfg.momentum)

    def solver(theta0_vec, center_vec, x, y, idx):
        theta, loss = tree_solver(spec.unflatten(theta0_vec),
                                  spec.unflatten(center_vec), x, y, idx)
        return spec.flatten(theta), loss

    def masked_solver(theta0_vec, center_vec, x, y, offset, size, idx):
        theta, loss = tree_masked_solver(
            spec.unflatten(theta0_vec), spec.unflatten(center_vec),
            x, y, offset, size, idx)
        return spec.flatten(theta), loss

    epoch_fn = partial(_epoch_indices, n_points=n_points,
                       batch_size=cfg.batch_size, epochs=cfg.epochs)
    c_min, capacity = capacity_bounds(n, cfg.participation,
                                      cfg.capacity_slack, cfg.capacity)
    adaptive = cfg.adaptive_capacity and cfg.capacity is None
    alpha = _ctrl_cfg(cfg).alpha
    rate_floor = cfg.participation * n
    spans = _tile_spans(capacity, getattr(cfg, "stream_tiles", 2))
    if donate is None:
        donate = jax.default_backend() != "cpu"

    # Round-static device residents: training data (gathered by slot
    # index inside the solve program, same op as the device block) and
    # the CSR index columns.
    x_dev = jnp.asarray(data["x"])
    y_dev = jnp.asarray(data["y"])
    if ragged is not None:
        offsets_dev = ragged.offsets_array()
        sizes_dev = ragged.sizes_array()

    # ------------------------------------------------------------------
    # program 1: plan — full-N vector work, no (N, D) operand anywhere
    # ------------------------------------------------------------------
    def _plan(rng, round_, ctrl, age, qload, distances, delay, ttl, hist):
        rng, sel_rng, data_rng = jax.random.split(rng, 3)
        view = _PlanView(ctrl=ctrl, round=round_)
        if async_mode:
            eligible = ttl == 0
            events = select.decide(sel_rng, view, distances, None,
                                   eligible=eligible) & eligible
        else:
            eligible = jnp.ones((n,), bool)
            events = select.decide(sel_rng, view, distances, None)
        limit = (adaptive_limit(qload, c_min, capacity)
                 if adaptive else None)
        plan = compact_plan(events, distances, capacity, age=age,
                            limit=limit, eligible=eligible)
        queue = queue_update(DeferQueue(age=age, load=qload), plan,
                             alpha=alpha)
        keys = jax.random.split(data_rng, n)
        out = dict(rng=rng, events=events, idx=plan.idx, valid=plan.valid,
                   age=queue.age, load=queue.load, limit=plan.limit,
                   keys_rows=keys[plan.idx],
                   num_events=jnp.sum(events.astype(jnp.int32)),
                   num_deferred=jnp.sum(
                       (queue.age > 0).astype(jnp.int32)))
        if async_mode:
            land, direct, defer, new_ttl = staleness_masks(
                plan.committed, delay, ttl)
            hist2 = record_issue(hist, events, round_)
            measured = measured_commits(hist2, delay, round_)
            ctrl2 = select.measure(ctrl, measured, None,
                                   staleness_delay=delay)
            out.update(ctrl=ctrl2, land=land, ttl=new_ttl, hist=hist2,
                       committed=direct | land,
                       num_inflight=jnp.sum(
                           (new_ttl > 0).astype(jnp.int32)),
                       num_landed=jnp.sum(land.astype(jnp.int32)))
        else:
            out.update(ctrl=select.measure(ctrl, events, None),
                       committed=plan.committed,
                       num_inflight=jnp.zeros((), jnp.int32),
                       num_landed=jnp.zeros((), jnp.int32))
        out["num_committed"] = jnp.sum(
            out["committed"].astype(jnp.int32))
        out["realized_slack"] = (plan.limit.astype(jnp.float32)
                                 / (rate_floor if rate_floor > 0 else 1.0))
        return out

    # ------------------------------------------------------------------
    # program 2: solve — width-C working set, the per-round hot program
    # ------------------------------------------------------------------
    def _cat(tiles):
        return tiles[0] if len(tiles) == 1 else jnp.concatenate(tiles, 0)

    def _solve(omega, idx, keys_rows, th_tiles, lam_tiles):
        # Exact bit mirror of make_compact_block's post-plan sequence:
        # the tiles concatenate back to the same (C, D) rows the device
        # block gathers, and every op below matches it at width C.
        th_rows, lam_rows = _cat(th_tiles), _cat(lam_tiles)
        if is_admm:
            lam_new_rows = dual_ascent(lam_rows, th_rows, omega)
            center_rows = prox_center(omega, lam_new_rows)
        else:
            lam_new_rows = lam_rows  # stays zero
            center_rows = jnp.broadcast_to(omega[None], (capacity, dim))
        theta0_rows = (jnp.broadcast_to(omega[None], (capacity, dim))
                       if cfg.warm_start else th_rows)
        idx_b = jax.vmap(epoch_fn)(keys_rows)
        if ragged is None:
            x_slots, y_slots = x_dev[idx], y_dev[idx]
            th_out, losses = jax.vmap(solver)(
                theta0_rows, center_rows, x_slots, y_slots, idx_b)
        else:
            off_rows = offsets_dev[idx]
            size_rows = sizes_dev[idx]
            block_len = ragged.max_size

            def slice_rows(buf):
                return jax.vmap(
                    lambda o: jax.lax.dynamic_slice_in_dim(
                        buf, o, block_len, 0))(off_rows)

            x_rows, y_rows = slice_rows(x_dev), slice_rows(y_dev)
            if masked:
                th_out, losses = jax.vmap(masked_solver)(
                    theta0_rows, center_rows, x_rows, y_rows,
                    jnp.zeros_like(off_rows), size_rows, idx_b)
            else:
                th_out, losses = jax.vmap(solver)(
                    theta0_rows, center_rows, x_rows, y_rows, idx_b)
        z_rows = th_out + lam_new_rows if is_admm else th_out
        return th_out, lam_new_rows, z_rows, losses

    if body_transform is not None:
        _solve = body_transform(_solve)

    # ------------------------------------------------------------------
    # program 3: aggregate — the one full-width server pass (consensus
    # + next round's trigger distances over the same z rows)
    # ------------------------------------------------------------------
    def _aggregate(z_full, omega, comm, committed, num_committed,
                   losses, valid):
        if is_admm:
            if compress != "none":
                omega2, comm2 = ef_consensus(z_full, omega, comm,
                                             mode=compress,
                                             block=cfg.compress_block)
            else:
                omega2, comm2 = consensus_mean(z_full), comm
        else:
            if compress != "none":
                omega2, comm2 = ef_participant_mean(
                    z_full, committed, omega, comm, num_committed,
                    mode=compress, block=cfg.compress_block)
            else:
                omega2 = participant_mean(z_full, committed, omega,
                                          num_events=num_committed)
                comm2 = comm
        dists = trigger_distances(omega2, z_full, cfg.trigger_metric)
        return omega2, comm2, dists, participant_mean_loss(losses, valid)

    plan_step = jax.jit(_plan)
    solve_step = (jax.jit(_solve, donate_argnums=(3, 4)) if donate
                  else jax.jit(_solve))
    agg_step = (jax.jit(_aggregate, donate_argnums=(0,)) if donate
                else jax.jit(_aggregate))
    trig_step = jax.jit(partial(trigger_distances,
                                metric=cfg.trigger_metric))

    stats = {"rounds": 0, "h2d_row_bytes": 0, "d2h_row_bytes": 0,
             "h2d_full_bytes": 0, "d2h_full_bytes": 0,
             "d2h_plan_bytes": 0,
             # Wall-clock per glue phase (seconds, cumulative) — the
             # bench's phase breakdown.  Timers bracket dispatch sites,
             # so async backends attribute hidden copy time to the
             # phase that forces the sync, not the one that issued it.
             "plan_s": 0.0, "h2d_s": 0.0, "solve_s": 0.0, "d2h_s": 0.0,
             "scatter_s": 0.0, "agg_s": 0.0}
    _delay_np: list = []  # static per-client delays, fetched once

    def _put_tiles(rows: np.ndarray):
        # Dispatch every tile's H2D back-to-back (double-buffered
        # stream: the runtime overlaps copy t+1 with compute on t).
        t0 = time.perf_counter()
        tiles = tuple(jax.device_put(rows[a:b]) for a, b in spans)
        stats["h2d_row_bytes"] += rows.nbytes
        stats["h2d_s"] += time.perf_counter() - t0
        return tiles

    def round_fn(state: HostState):
        if state.distances is None:
            # Fresh init / just restored: one trigger pass seeds the
            # pipelined distance cache (afterwards the aggregate pass
            # maintains it for free).
            z_dev = jax.device_put(state.z_prev)
            stats["h2d_full_bytes"] += state.z_prev.nbytes
            state = HostState(**{**state.__dict__,
                                 "distances": trig_step(state.omega,
                                                        z_dev)})
        inflight = state.inflight
        t0 = time.perf_counter()
        p = plan_step(state.rng, state.round, state.ctrl,
                      state.queue.age, state.queue.load, state.distances,
                      None if inflight is None else inflight.delay,
                      None if inflight is None else inflight.ttl,
                      None if inflight is None else inflight.hist)
        np_idx = np.asarray(p["idx"])
        np_valid = np.asarray(p["valid"])
        stats["d2h_plan_bytes"] += np_idx.nbytes + np_valid.nbytes
        stats["plan_s"] += time.perf_counter() - t0

        th_tiles = _put_tiles(state.theta[np_idx])
        lam_tiles = _put_tiles(state.lam[np_idx])
        t0 = time.perf_counter()
        th_out, lam_new, z_rows, losses = solve_step(
            state.omega, p["idx"], p["keys_rows"], th_tiles, lam_tiles)
        stats["solve_s"] += time.perf_counter() - t0
        t0 = time.perf_counter()
        np_th = np.asarray(th_out)
        np_lam = np.asarray(lam_new)
        np_z = np.asarray(z_rows)
        stats["d2h_row_bytes"] += np_th.nbytes + np_lam.nbytes + np_z.nbytes
        stats["d2h_s"] += time.perf_counter() - t0

        # --- host scatter: the valid slots' distinct client rows ------
        t0 = time.perf_counter()
        slot = np.flatnonzero(np_valid)
        cids = np_idx[slot]
        new_inflight = inflight
        if async_mode:
            if not _delay_np:
                _delay_np.append(np.asarray(inflight.delay))
            np_land = np.asarray(p["land"])
            stats["d2h_plan_bytes"] += np_land.nbytes
            land_rows = np.flatnonzero(np_land)
            for buf, park in ((state.theta, inflight.theta),
                              (state.lam, inflight.lam),
                              (state.z_prev, inflight.z)):
                buf[land_rows] = park[land_rows]
            d0 = _delay_np[0][cids] == 0
            for buf, park, rows in ((state.theta, inflight.theta, np_th),
                                    (state.lam, inflight.lam, np_lam),
                                    (state.z_prev, inflight.z, np_z)):
                buf[cids[d0]] = rows[slot[d0]]  # direct commits
                park[cids[~d0]] = rows[slot[~d0]]  # deferred → park
            new_inflight = InFlight(delay=inflight.delay, ttl=p["ttl"],
                                    theta=inflight.theta,
                                    lam=inflight.lam, z=inflight.z,
                                    hist=p["hist"])
        else:
            state.theta[cids] = np_th[slot]
            state.z_prev[cids] = np_z[slot]
            if is_admm:
                state.lam[cids] = np_lam[slot]
        stats["scatter_s"] += time.perf_counter() - t0

        # --- one full-width server pass -------------------------------
        t0 = time.perf_counter()
        z_dev = jax.device_put(state.z_prev)
        stats["h2d_full_bytes"] += state.z_prev.nbytes
        comm_dev = None
        if compress != "none":
            comm_dev = jax.device_put(state.comm)
            stats["h2d_full_bytes"] += state.comm.nbytes
        omega2, comm2, dists, train_loss = agg_step(
            z_dev, state.omega, comm_dev, p["committed"],
            p["num_committed"], losses, p["valid"])
        comm_np = state.comm
        if compress != "none":
            comm_np = np.asarray(comm2)
            stats["d2h_full_bytes"] += comm_np.nbytes
        stats["agg_s"] += time.perf_counter() - t0

        metrics = RoundMetrics(
            events=p["events"], num_events=p["num_events"],
            distances=state.distances, delta=p["ctrl"].delta,
            load=p["ctrl"].load, train_loss=train_loss,
            num_deferred=p["num_deferred"],
            realized_capacity=p["limit"],
            realized_slack=p["realized_slack"],
            num_inflight=p["num_inflight"], num_landed=p["num_landed"],
            committed=p["committed"])
        new_state = HostState(
            theta=state.theta, lam=state.lam, z_prev=state.z_prev,
            omega=omega2, ctrl=p["ctrl"], rng=p["rng"],
            round=state.round + 1,
            queue=DeferQueue(age=p["age"], load=p["load"]),
            distances=dists, inflight=new_inflight, comm=comm_np)
        stats["rounds"] += 1
        return new_state, metrics

    # --- metadata for the analysis layer and the benches --------------
    def solve_example_args():
        """Zero-valued operands matching the solve program's signature
        (the analysis layer traces/lowers ``solve_fn`` with these)."""
        th = tuple(jnp.zeros((b - a, dim), jnp.float32) for a, b in spans)
        lam = tuple(jnp.zeros((b - a, dim), jnp.float32)
                    for a, b in spans)
        return (jnp.zeros((dim,), jnp.float32),
                jnp.zeros((capacity,), jnp.int32),
                jnp.zeros((capacity, 2), jnp.uint32), th, lam)

    row_h2d = 2 * capacity * dim * 4  # θ, λ tiles up
    row_d2h = 3 * capacity * dim * 4  # θ_out, λ⁺, z rows down
    full_mult = 2 if compress != "none" else 1
    round_fn.planned_bytes = {
        "row_stream_h2d": row_h2d,
        "row_stream_d2h": row_d2h,
        "row_stream_budget": 8 * capacity * dim * 4,
        "server_pass_h2d": n * dim * 4 * full_mult,
        "server_pass_d2h": (n * dim * 4 if compress != "none" else 0),
        "plan_d2h": capacity * 5 + (n if async_mode else 0),
    }
    round_fn.stats = stats
    round_fn.solve_fn = _solve
    round_fn.solve_example_args = solve_example_args
    round_fn.solve_donate_argnums = (3, 4) if donate else ()
    round_fn.plan_step = plan_step
    round_fn.solve_step = solve_step
    round_fn.aggregate_step = agg_step
    round_fn.static_info = {
        "backend": "host", "capacity": capacity, "c_min": c_min,
        "adaptive": adaptive, "is_admm": is_admm,
        "ragged": ragged is not None, "masked": masked,
        "tiles": len(spans), "donate": donate, "fused": fused,
        "async": async_mode, "compress": compress,
    }
    return round_fn
