"""Event trigger (paper Eq. 3.1): S_i^k(δ) = 1{ ‖ω^k − z_i^prev‖ ≥ δ_i }.

The distance is the global L2 norm over the flattened parameter vector.
The server holds z_i^prev (the last uploaded θ_i + λ_i per client) and
evaluates all N triggers each round — the O(N·d) hot spot of FedBack's
server side.  ``trigger_distances`` is the reference path (pure jnp over
stacked pytrees); the Pallas kernel ``repro.kernels.ops.trigger_sq_norms``
is the TPU fast path and is used when ``use_kernel=True``.

Remark 3 of the paper allows any distance metric as long as gradients are
bounded; we expose l2 (default), l-inf and a cosine variant.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.utils.pytree import stacked_sq_norms


def trigger_distances(omega, z_prev, metric: str = "l2") -> jax.Array:
    """Per-client distances ‖ω − z_i^prev‖ → (N,) fp32.

    omega: pytree (server parameters); z_prev: stacked pytree (N, ...).
    """
    n = jax.tree.leaves(z_prev)[0].shape[0]
    diff = jax.tree.map(
        lambda zp, w: zp.astype(jnp.float32) - w[None].astype(jnp.float32),
        z_prev,
        omega,
    )
    if metric == "l2":
        return jnp.sqrt(stacked_sq_norms(diff))
    if metric == "linf":
        parts = jax.tree.map(
            lambda x: jnp.max(jnp.abs(x).reshape(n, -1), axis=1), diff
        )
        return jax.tree.reduce(jnp.maximum, parts, jnp.zeros((n,), jnp.float32))
    if metric == "cosine":
        num = stacked_sq_norms(diff)
        den = jnp.sqrt(stacked_sq_norms(z_prev)) + 1e-12
        return jnp.sqrt(num) / den
    raise ValueError(f"unknown trigger metric: {metric}")


def evaluate_trigger(distances: jax.Array, delta: jax.Array) -> jax.Array:
    """S_i = 1 iff distance_i ≥ δ_i.  Negative δ always fires (Lemma 1
    dynamics explicitly drive δ negative to force participation)."""
    return distances >= delta


def trigger_events(omega, z_prev, delta, metric: str = "l2") -> jax.Array:
    return evaluate_trigger(trigger_distances(omega, z_prev, metric), delta)
