"""Cross-pod FedBack: the paper's cross-silo setting mapped onto a
multi-pod TPU mesh.

Each *pod* plays the role of one silo/client: it trains its local model
replica data-/model-parallel **within** the pod, while the ADMM consensus
``ω = (1/P) Σ_i z_i^prev`` is an all-reduce over the ``pod`` mesh axis.
FedBack's event trigger gates what each pod *commits* into the consensus:
a non-participating pod contributes a zero Δz (and, at the orchestration
level, a round in which no pod fires skips the collective entirely —
``num_events`` is produced before aggregation precisely so the host can
make that call, which is where the paper's communication savings
physically materialize on a real interconnect).

The whole round is one pjit-able program: client-stacked pytrees carry a
leading pod axis (sharded ``P("pod")``), parameters inside each client
follow the per-arch sharding rules over ("data", "model"), and XLA
derives the trigger-norm partial reductions and the consensus
all-reduce from the shardings.  This program — FedBack as a first-class
collective — is what the multi-pod dry-run lowers and what §Roofline's
collective term measures.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.optim.sgd import sgd_init, sgd_step
from repro.utils.pytree import (
    stacked_sq_norms,
    tree_broadcast_like,
    tree_zeros_like,
)
from .controller import ControllerConfig, ControllerState, controller_step, init_controller
from .engine import (
    consensus_mean,
    dual_ascent,
    gated_commit,
    participant_mean_loss,
    prox_center,
)


@dataclasses.dataclass(frozen=True)
class CrossPodConfig:
    n_pods: int = 2
    rho: float = 1e-4  # prox weight at LM scale (grad norms are O(1))
    lr: float = 3e-4
    momentum: float = 0.9
    local_steps: int = 4  # microbatch SGD steps per round (inexact prox)
    controller: ControllerConfig = ControllerConfig(K=0.5, alpha=0.9,
                                                    target_rate=0.5)
    param_dtype: Any = jnp.float32


class CrossPodState(NamedTuple):
    theta: Any  # stacked (P, ...) — per-pod primal replicas
    lam: Any  # stacked (P, ...) — per-pod duals
    z_prev: Any  # stacked (P, ...) — last committed θ+λ per pod
    ctrl: ControllerState  # (P,) controller state (replicated)
    rng: jax.Array
    round: jax.Array


class CrossPodMetrics(NamedTuple):
    events: jax.Array  # (P,) bool
    num_events: jax.Array  # () int32 — host reads this to skip dead rounds
    distances: jax.Array  # (P,)
    delta: jax.Array  # (P,)
    train_loss: jax.Array  # () fp32


def init_cross_pod_state(cfg: CrossPodConfig, params0) -> CrossPodState:
    theta = tree_broadcast_like(params0, cfg.n_pods)
    return CrossPodState(
        theta=theta,
        lam=tree_zeros_like(theta),
        z_prev=theta,
        ctrl=init_controller(cfg.n_pods, cfg.controller),
        rng=jax.random.PRNGKey(0),
        round=jnp.zeros((), jnp.int32),
    )


def make_cross_pod_round(cfg: CrossPodConfig, loss_fn: Callable):
    """Build round_fn(state, batch) -> (state, metrics).

    loss_fn(params, batch) -> scalar.  ``batch`` is a pytree whose leaves
    have leading axes (P, local_steps, ...): pod-sharded, pre-split into
    the local microbatch schedule.
    """
    p = cfg.n_pods

    def local_solve(theta0, center, batch_i):
        vg = jax.value_and_grad(loss_fn)

        def body(carry, micro):
            params, opt = carry
            loss, g = vg(params, micro)
            g = jax.tree.map(lambda gl, pr, c: gl + cfg.rho * (pr - c),
                             g, params, center)
            params, opt = sgd_step(params, g, opt, cfg.lr, cfg.momentum)
            return (params, opt), loss

        # unrolled: local_steps is small (≤4) and XLA's cost analysis
        # counts while bodies once — unrolling keeps the dry-run honest
        (theta, _), losses = jax.lax.scan(
            body, (theta0, sgd_init(theta0)), batch_i,
            unroll=cfg.local_steps)
        return theta, jnp.mean(losses)

    def round_fn(state: CrossPodState, batch):
        # --- consensus + trigger (ω is the all-reduce over pods) -------
        omega = consensus_mean(state.z_prev)
        diff = jax.tree.map(lambda z, w: z - w[None], state.z_prev, omega)
        distances = jnp.sqrt(stacked_sq_norms(diff))
        events = distances >= state.ctrl.delta
        ctrl = controller_step(state.ctrl, events, cfg.controller)

        # --- local ADMM prox updates (per pod) --------------------------
        lam_new = dual_ascent(state.lam, state.theta, omega)
        center = prox_center(omega, lam_new)
        theta0 = tree_broadcast_like(omega, p)
        theta_out, losses = jax.vmap(local_solve)(theta0, center, batch)
        z_new = jax.tree.map(jnp.add, theta_out, lam_new)

        # --- event-gated commit ----------------------------------------
        theta = gated_commit(events, theta_out, state.theta)
        lam = gated_commit(events, lam_new, state.lam)
        z_prev = gated_commit(events, z_new, state.z_prev)

        metrics = CrossPodMetrics(
            events=events,
            num_events=jnp.sum(events.astype(jnp.int32)),
            distances=distances,
            delta=ctrl.delta,
            train_loss=participant_mean_loss(losses, events),
        )
        rng, _ = jax.random.split(state.rng)
        return CrossPodState(theta, lam, z_prev, ctrl, rng,
                             state.round + 1), metrics

    return round_fn
