"""FedBack core — the paper's contribution as composable JAX modules."""
from repro.utils.flatstate import (  # noqa: F401  (re-export: flat layout)
    FlatSpec,
    flatten_problem,
    make_flat_spec,
)
from repro.utils.ragged import (  # noqa: F401  (re-export: ragged shards)
    RaggedSpec,
    make_ragged_spec,
    pool_data,
    pool_rows,
)
from .compact import (  # noqa: F401
    CompactPlan,
    adaptive_limit,
    capacity_bounds,
    capacity_for,
    compact_plan,
    init_queue,
    queue_update,
)
from .compress import (  # noqa: F401
    consensus_wire_bytes,
    ef_consensus,
    ef_participant_mean,
    init_residual,
    int8_dequantize,
    int8_quantize,
    quantize_dequantize,
)
from .controller import (  # noqa: F401
    ControllerConfig,
    ControllerState,
    clamp_target_rate,
    controller_step,
    delta_bounds,
    feasible_rate,
    init_controller,
    realized_rate,
    tracking_error_bounds,
)
from .trigger import trigger_distances, trigger_events, evaluate_trigger  # noqa: F401
from .fedback import (  # noqa: F401
    FLConfig,
    init_state,
    make_eval_fn,
    make_round_fn,
    run_rounds,
)
from .hoststate import (  # noqa: F401
    host_state_from_tree,
    host_state_to_device,
    init_host_state,
    make_host_round_fn,
)
from .schedule import (  # noqa: F401
    ServeReport,
    TraceConfig,
    make_trace,
    run_trace,
    serve,
    sync_trace,
)
from .state import (  # noqa: F401
    DeferQueue,
    FLState,
    HostState,
    InFlight,
    RoundMetrics,
    delay_schedule,
    init_inflight,
)
