"""FedBack core — the paper's contribution as composable JAX modules."""
from repro.utils.flatstate import (  # noqa: F401  (re-export: flat layout)
    FlatSpec,
    flatten_problem,
    make_flat_spec,
)
from .compact import CompactPlan, capacity_for, compact_plan  # noqa: F401
from .controller import (  # noqa: F401
    ControllerConfig,
    ControllerState,
    controller_step,
    delta_bounds,
    init_controller,
    realized_rate,
    tracking_error_bounds,
)
from .trigger import trigger_distances, trigger_events, evaluate_trigger  # noqa: F401
from .fedback import (  # noqa: F401
    FLConfig,
    init_state,
    make_eval_fn,
    make_round_fn,
    run_rounds,
)
from .state import FLState, RoundMetrics  # noqa: F401
