"""Baseline algorithm presets.

The paper's baselines (FedADMM, FedAvg, FedProx) are *instances* of the
generic round engine in ``fedback.py`` — exactly how the paper frames
them ("a version of FedAvg/FedProx may be recovered from FedADMM by
enforcing ρ=0 / λ≡0 and a non-weighted server aggregation").  SCAFFOLD
(Karimireddy et al. 2020) needs client/server control variates and twice
the upload payload, so it gets its own engine here; the paper discusses
it as the 2×-communication reference point.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.optim.sgd import sgd_init, sgd_step
from repro.utils.pytree import tree_broadcast_like, tree_where, tree_zeros_like
from .fedback import FLConfig, _epoch_indices


def baseline_config(name: str, **kw) -> FLConfig:
    """Named presets matching the paper's experimental setup."""
    name = name.lower()
    presets = {
        "fedback": dict(algorithm="fedback"),
        "fedadmm": dict(algorithm="fedadmm"),
        "admm": dict(algorithm="admm", participation=1.0),
        "fedavg": dict(algorithm="fedavg", rho=0.0),
        "fedprox": dict(algorithm="fedprox"),
    }
    if name not in presets:
        raise ValueError(f"unknown baseline {name}")
    return FLConfig(**{**presets[name], **kw})


# ----------------------------------------------------------------------
# SCAFFOLD (beyond-paper baseline; 2× communication per participation).
# ----------------------------------------------------------------------

class ScaffoldState(NamedTuple):
    c_server: Any  # server control variate
    c_clients: Any  # stacked (N, ...) client control variates
    omega: Any
    rng: jax.Array
    round: jax.Array


def init_scaffold(cfg: FLConfig, params0) -> ScaffoldState:
    n = cfg.n_clients
    return ScaffoldState(
        c_server=tree_zeros_like(params0),
        c_clients=tree_zeros_like(tree_broadcast_like(params0, n)),
        omega=params0,
        rng=jax.random.PRNGKey(cfg.seed),
        round=jnp.zeros((), jnp.int32),
    )


def make_scaffold_round(cfg: FLConfig, loss_fn: Callable, data, *, jit=True):
    """SCAFFOLD with option-II control-variate updates and uniform
    random selection at rate cfg.participation."""
    n = cfg.n_clients
    n_points = data["x"].shape[1]
    k_sel = max(int(round(cfg.participation * n)), 1)

    def local(omega, ci, c, x, y, idx):
        vg = jax.value_and_grad(loss_fn)

        def body(carry, idx_b):
            params, opt, steps = carry
            xb = jnp.take(x, idx_b, 0)
            yb = jnp.take(y, idx_b, 0)
            loss, g = vg(params, xb, yb)
            g = jax.tree.map(lambda gl, cs, cc: gl + cs - cc, g, c, ci)
            params, opt = sgd_step(params, g, opt, cfg.lr, cfg.momentum)
            return (params, opt, steps + 1), loss

        (theta, _, steps), losses = jax.lax.scan(
            body, (omega, sgd_init(omega), jnp.zeros((), jnp.int32)), idx)
        # option II: c_i+ = c_i − c + (ω − θ)/(steps·lr)
        coef = 1.0 / (steps.astype(jnp.float32) * cfg.lr)
        ci_new = jax.tree.map(
            lambda cil, cl, w, t: cil - cl + coef * (w - t), ci, c, omega,
            theta)
        return theta, ci_new, jnp.mean(losses)

    def round_fn(state: ScaffoldState):
        rng, sel_rng, data_rng = jax.random.split(state.rng, 3)
        perm = jax.random.permutation(sel_rng, n)
        events = jnp.zeros((n,), bool).at[perm[:k_sel]].set(True)

        idx = jax.vmap(
            lambda k: _epoch_indices(k, n_points, cfg.batch_size, cfg.epochs)
        )(jax.random.split(data_rng, n))
        omega_b = tree_broadcast_like(state.omega, n)
        c_b = tree_broadcast_like(state.c_server, n)
        theta, ci_new, losses = jax.vmap(local)(
            omega_b, state.c_clients, c_b, data["x"], data["y"], idx)

        ev = events.astype(jnp.float32)
        denom = jnp.maximum(jnp.sum(ev), 1.0)
        omega = jax.tree.map(lambda t, w: w + jnp.sum(
            jnp.where(events.reshape((-1,) + (1,) * (t.ndim - 1)), t - w[None],
                      0.0), 0) / denom, theta, state.omega)
        dc = jax.tree.map(lambda cn, co: jnp.sum(
            jnp.where(events.reshape((-1,) + (1,) * (cn.ndim - 1)),
                      cn - co, 0.0), 0) / n, ci_new, state.c_clients)
        c_server = jax.tree.map(jnp.add, state.c_server, dc)
        c_clients = tree_where(events, ci_new, state.c_clients)

        train_loss = jnp.sum(losses * ev) / denom
        new = ScaffoldState(c_server, c_clients, omega, rng, state.round + 1)
        return new, {"events": events, "train_loss": train_loss,
                     "num_events": jnp.sum(events.astype(jnp.int32))}

    return jax.jit(round_fn) if jit else round_fn
