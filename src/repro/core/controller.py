"""Integral feedback controller for client participation (paper Alg. 1).

The controller treats per-client participation as a discrete-time
dynamical system:

    measurement   S_i^k(δ_i^k) ∈ {0, 1}           (event trigger, Eq. 3.1)
    low-pass      L_i^{k+1} = (1−α) L_i^k + α S_i^k          (Eq. 3.4)
    integral law  δ_i^{k+1} = δ_i^k + K (L_i^k − L̄_i)        (Eq. 3.3)

Theorem 2 guarantees (1/T) Σ_k S_i^k → L̄_i at rate O(1/T) for any K>0,
and Lemma 1 bounds δ_i^k for all k given a trigger saturation level δ₊.

Everything is vectorized over the client axis: states are (N,) arrays and
one ``controller_step`` advances all clients at once, which makes the
controller itself a (trivially) shardable program over the client mesh
axis.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class ControllerConfig(NamedTuple):
    """Gains of the participation controller.

    K:            integral gain (paper: 2 for MNIST-scale, 5 for CIFAR —
                  scales with the magnitude of parameter-space distances).
    alpha:        low-pass time constant in (0, 1) (paper: 0.9; larger α
                  weighs recent participation more).
    target_rate:  L̄ — desired participation rate, scalar or (N,) array
                  (the paper allows heterogeneous L̄_i).
    delta0:       initial threshold δ⁰ (paper: 0, so every client fires in
                  round 0 and the consensus starts from a true average).
    use_filtered_error: if True uses (L^{k+1} − L̄) in the integral law
                  instead of the paper's (L^k − L̄). Kept for ablations;
                  the default is the faithful form.
    """

    K: float = 2.0
    alpha: float = 0.9
    target_rate: float | jax.Array = 0.1
    delta0: float = 0.0
    use_filtered_error: bool = False


class ControllerState(NamedTuple):
    delta: jax.Array  # (N,) fp32 — thresholds δ_i^k
    load: jax.Array  # (N,) fp32 — low-pass participation estimate L_i^k
    round: jax.Array  # () int32  — k
    event_count: jax.Array  # (N,) int32 — Σ_j S_i^j, for Thm. 2 checks


def init_controller(n_clients: int, cfg: ControllerConfig) -> ControllerState:
    return ControllerState(
        delta=jnp.full((n_clients,), cfg.delta0, jnp.float32),
        load=jnp.zeros((n_clients,), jnp.float32),
        round=jnp.zeros((), jnp.int32),
        event_count=jnp.zeros((n_clients,), jnp.int32),
    )


def controller_step(
    state: ControllerState, events: jax.Array, cfg: ControllerConfig
) -> ControllerState:
    """Advance the closed loop one round given measured events S^k (N,) bool.

    Faithful to Alg. 1: the threshold update uses the *pre-update* load
    L_i^k (Eq. 3.3), and the filter then incorporates S_i^k (Eq. 3.4).
    """
    s = events.astype(jnp.float32)
    target = jnp.asarray(cfg.target_rate, jnp.float32)
    new_load = (1.0 - cfg.alpha) * state.load + cfg.alpha * s
    err_load = new_load if cfg.use_filtered_error else state.load
    new_delta = state.delta + cfg.K * (err_load - target)
    return ControllerState(
        delta=new_delta,
        load=new_load,
        round=state.round + 1,
        event_count=state.event_count + events.astype(jnp.int32),
    )


def demand_load_step(load: jax.Array, demand: jax.Array,
                     alpha: float) -> jax.Array:
    """Low-pass demand estimate, the controller filter (Eq. 3.4) reused
    for solver-row *demand* (fired ∪ pending) instead of raw events.

    The compacted engine (``core/compact.py``) keeps one such EMA per
    client (``DeferQueue.load``); its per-shard sum is the load estimate
    that drives the adaptive round capacity.  Like every controller
    quantity it is a pure per-client map — trivially shardable and
    vmappable.
    """
    return (1.0 - alpha) * load + alpha * demand.astype(jnp.float32)


def feasible_rate(delay: jax.Array) -> jax.Array:
    """Participation-rate ceiling under bounded-staleness rounds.

    A client whose solve takes δ_i rounds to land is ineligible to
    re-fire while in flight, so its issue stream has a minimum
    inter-event gap of δ_i + 1 rounds — the highest achievable
    time-averaged rate is 1/(1+δ_i).  The async engine clamps the
    controller target to this ceiling (``clamp_target_rate``): without
    the clamp the integral law winds up without bound for any client
    whose L̄_i exceeds the ceiling (the error L_i − L̄_i can never close,
    so δ_i^k → −∞ instead of settling at the Lemma 1 bound).  With
    δ_i = 0 the ceiling is 1 and the clamp is the identity — the
    synchronous controller, bit for bit.
    """
    return 1.0 / (1.0 + delay.astype(jnp.float32))


def clamp_target_rate(target_rate, delay: jax.Array) -> jax.Array:
    """Anti-windup target for the staleness-aware controller:
    L̄_i ← min(L̄_i, 1/(1+δ_i)) per client (broadcasts a scalar L̄)."""
    return jnp.minimum(jnp.asarray(target_rate, jnp.float32),
                       feasible_rate(delay))


def delta_bounds(cfg: ControllerConfig, delta_plus: float) -> tuple[float, float]:
    """Lemma 1 bounds on δ_i^k, given trigger saturation level δ₊.

    δ₊ is any value such that S(δ) = 0 for all δ ≥ δ₊ (exists whenever the
    local gradients are bounded).  Returns (lower, upper).
    """
    K, a, d0 = cfg.K, cfg.alpha, cfg.delta0
    lower = min(d0 - K / a, -K * (1 + a) / a)
    upper = max(delta_plus + K * (1 + a) / a, d0 + K / a)
    return lower, upper


def tracking_error_bounds(
    cfg: ControllerConfig, delta_plus: float, horizon: int
) -> tuple[float, float]:
    """Theorem 2: c1/T ≤ (1/T)Σ S^k − L̄ ≤ c2/T, returns (c1/T, c2/T)."""
    K, a, d0 = cfg.K, cfg.alpha, cfg.delta0
    c1 = min(-2.0 / a, -d0 / K - (2.0 + a) / a)
    c2 = max((delta_plus - d0) / K + (2.0 + a) / a, (2.0 + a) / a)
    return c1 / horizon, c2 / horizon


def realized_rate(state: ControllerState) -> jax.Array:
    """Time-averaged participation rate (1/T) Σ_k S_i^k per client."""
    t = jnp.maximum(state.round, 1).astype(jnp.float32)
    return state.event_count.astype(jnp.float32) / t
