"""Compressed consensus: quantized z-deltas with error feedback.

The round's one genuine collective is the consensus aggregation over
the client-stacked z rows (``engine.consensus_mean`` for the ADMM
family, ``engine.participant_mean`` for FedAvg/Prox).  At full fp32
width that moves 4 bytes per model coordinate per round through the
``clients`` mesh.  This module replaces the aggregation with an
**error-feedback** compressed form (``FLConfig.consensus_compress ∈
{"none", "bf16", "int8"}``) so the wire cost per round shrinks
alongside the round count FedBack already saves:

    δ_i  = z_i − ω_prev + e_i        z-delta with residual carry-in
    t_i  = Q(δ_i)                    level-1 per-client quantization
    e_i⁺ = δ_i − D(t_i)              client residual (FLState.comm)
    ω⁺   = ω_prev + (Σ_i D(t_i)) / denom   via the compressed wire

``ω_prev`` is the previous broadcast — already in ``FLState.omega`` —
so the reference costs no extra state.  The residual ``e_i`` is a
client-stacked (N, D) fp32 buffer (``FLState.comm``) that shards under
the clients mesh like the DeferQueue and threads through scan-of-vmap
sweeps and checkpoints as regular carry state.  Error feedback keeps
the scheme unbiased over time: whatever a round's quantizer drops is
replayed into the next round's delta, so the accumulated broadcast —
and with it the controller's trigger measurements ‖ω − z_i‖ — tracks
the uncompressed consensus instead of drifting (the composition
argument of *Optimal Client Sampling*, arXiv 2010.13723: compression
error lives in a feedback loop of its own and does not fight the
participation controller's integral action; cf. docs/compression.md).

**Two levels, one residual.**  Quantization happens twice: per client
(level 1: bf16 cast, or per-block symmetric int8 with fp32 scales) and
per mesh shard on the wire (level 2: each device's partial sum of
dequantized deltas is re-quantized so the cross-device collective
itself moves narrow bytes — an ``s8`` (D,) SUM all-reduce under a
shared per-block scale for int8, a ``u16``-bitcast all-gather of the
bf16 partials for bf16; naive bf16 ``psum`` would silently upcast the
collective to f32).  Level-2 wire error is shard-local and folded back
into the transmitting clients' residuals (1/m each), so a single
(N, D) residual buffer conserves every dropped bit:

    Σ_i e_i⁺  +  Σ transmitted  ==  Σ_i δ_i      (at every prefix)

**Layout/scope.**  Flat layout only (z as an (N, D) fp32 matrix) — the
engine's primary layout; ``make_round_fn`` rejects compression on the
stacked-pytree layout loudly.  ``consensus_compress="none"`` never
reaches this module: the round keeps the exact uncompressed
``consensus_mean``/``participant_mean`` calls and ``FLState.comm``
stays ``None``, so jaxprs and golden traces are bit-identical.

**Device-count semantics.**  The single-device path runs the same
two-level math with one shard (no collectives), so conservation and
error bounds are identical; exact bit-parity across device counts is
only promised for ``"none"`` (the int8 wire headroom ⌊127/n_shards⌋
and the bf16 partial-sum rounding depend on the shard count).
"""
from __future__ import annotations

from functools import partial

import jax.numpy as jnp
from jax import lax

#: Supported ``FLConfig.consensus_compress`` values.
MODES = ("none", "bf16", "int8")

#: Symmetric int8 code range; level-2 divides it by the shard count so
#: the s8 SUM all-reduce can never overflow.
INT8_CLIP = 127

#: Wire bytes per model coordinate by mode (the consensus payload term
#: of the CollectiveBudget rule and the roofline collective model).
WIRE_BYTES = {"none": 4, "bf16": 2, "int8": 1}


def check_mode(mode: str) -> str:
    if mode not in MODES:
        raise ValueError(
            f"consensus_compress must be one of {MODES}, got {mode!r}")
    return mode


def block_layout(dim: int, block: int) -> tuple[int, int]:
    """(n_blocks, block_size) of the per-block int8 scale layout.

    The block size is clamped to the vector length (a 16-coordinate toy
    problem must not pad to a 256-wide block), so ``n_blocks =
    ⌈D / min(block, D)⌉`` and padding is at most block−1 zeros.
    """
    b = max(1, min(int(block), int(dim)))
    return -(-int(dim) // b), b


def _blocked(x, block):
    """(..., D) → (..., nb, B) zero-padded block view."""
    d = x.shape[-1]
    nb, b = block_layout(d, block)
    pad = nb * b - d
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x.reshape(x.shape[:-1] + (nb, b))


def int8_quantize(x, *, block: int = 256, clip: int = INT8_CLIP):
    """Per-block symmetric int8 codes + fp32 scales.

    x: (..., D) fp32.  Returns ``(codes, scales)`` with codes int8 of
    shape (..., nb, B) (zero-padded past D) and scales fp32 (..., nb) =
    blockwise max|x| / clip.  An all-zero block quantizes to zero codes
    with scale 0 (dequantizes to exact zeros).
    """
    xb = _blocked(x, block)
    scale = jnp.max(jnp.abs(xb), axis=-1) / clip
    safe = jnp.where(scale > 0, scale, 1.0)
    codes = jnp.clip(jnp.round(xb / safe[..., None]),
                     -clip, clip).astype(jnp.int8)
    return codes, scale.astype(jnp.float32)


def int8_dequantize(codes, scales, dim: int):
    """Inverse of :func:`int8_quantize`: (..., nb, B) codes → (..., D)."""
    xb = codes.astype(jnp.float32) * scales[..., None]
    return xb.reshape(xb.shape[:-2] + (-1,))[..., :dim]


def quantize_dequantize(x, mode: str, *, block: int = 256):
    """The level-1 transmit operator D(Q(x)): fp32 → fp32 through the
    wire dtype.  Round-trip error is 0 for ``none``, one bf16 ulp
    (≤ 2⁻⁸·|x|) for ``bf16`` and at most half a scale step
    (max|x_block| / (2·127)) per coordinate for ``int8``.
    """
    if mode == "none":
        return x
    if mode == "bf16":
        return x.astype(jnp.bfloat16).astype(jnp.float32)
    codes, scales = int8_quantize(x, block=block)
    return int8_dequantize(codes, scales, x.shape[-1])


def _wire_int8(p, *, block, axis, n_shards):
    """Level-2 int8 wire: a genuine s8 (D,) SUM all-reduce.

    Every shard quantizes its fp32 partial sum ``p`` under a SHARED
    per-block scale (a tiny (nb,) fp32 MAX all-reduce of the blockwise
    |p| maxima), with codes clipped to ±⌊127/n_shards⌋ so the summed
    codes cannot overflow int8.  Returns ``(total, werr)``: the
    dequantized global sum (replicated) and this shard's local wire
    error ``p − sent``.
    """
    d = p.shape[-1]
    pb = _blocked(p, block)
    local_max = jnp.max(jnp.abs(pb), axis=-1)              # (nb,)
    gmax = lax.pmax(local_max, axis) if axis is not None else local_max
    clip = INT8_CLIP // n_shards
    scale = gmax / clip
    safe = jnp.where(scale > 0, scale, 1.0)
    codes = jnp.clip(jnp.round(pb / safe[..., None]),
                     -clip, clip).astype(jnp.int8)
    sent = codes.astype(jnp.float32) * safe[..., None]
    werr = (pb - sent).reshape(-1)[:d]
    total_codes = lax.psum(codes, axis) if axis is not None else codes
    total = (total_codes.astype(jnp.float32)
             * safe[..., None]).reshape(-1)[:d]
    return total, werr


def _wire_bf16(p, *, axis):
    """Level-2 bf16 wire: a u16-bitcast all-gather of the partials.

    A bf16 ``psum`` (and a GSPMD bf16 sum) upcasts the collective to
    f32 on the wire; bitcasting the bf16 partial to u16 before
    ``all_gather`` keeps the collective at 2 bytes/coordinate, and the
    f32 accumulation of the gathered shard partials happens locally.
    """
    sent16 = p.astype(jnp.bfloat16)
    sent = sent16.astype(jnp.float32)
    werr = p - sent
    if axis is None:
        return sent, werr
    u = lax.bitcast_convert_type(sent16, jnp.uint16)
    gathered = lax.all_gather(u, axis)                     # (n_shards, D)
    vals = lax.bitcast_convert_type(gathered, jnp.bfloat16)
    return jnp.sum(vals.astype(jnp.float32), axis=0), werr


def _ef_body(z, omega, resid, mask, denom, *, mode, block, axis,
             n_shards):
    """Shard-local EF aggregation (full arrays when ``axis`` is None).

    z: (n_loc, D) fp32 rows; omega: (D,) replicated broadcast; resid:
    (n_loc, D) client residuals; mask: (n_loc,) bool transmitters or
    None (= every row, the ADMM family); denom: the global divisor —
    a static float N for the consensus mean, the traced committed
    count for the participant mean (ω falls back to itself at 0).
    Returns ``(omega_new, resid_new)``.
    """
    delta = z - omega[None, :] + resid                     # carry-in
    d = quantize_dequantize(delta, mode, block=block)
    if mask is None:
        resid1 = delta - d
        m_loc = jnp.float32(z.shape[0])
    else:
        mz = mask[:, None]
        d = jnp.where(mz, d, 0.0)
        resid1 = jnp.where(mz, delta - d, resid)
        m_loc = jnp.maximum(jnp.sum(mask.astype(jnp.float32)), 1.0)
    p = jnp.sum(d, axis=0)                                 # shard partial
    if mode == "int8":
        total, werr = _wire_int8(p, block=block, axis=axis,
                                 n_shards=n_shards)
    elif mode == "bf16":
        total, werr = _wire_bf16(p, axis=axis)
    else:  # exact wire — the EF identity check path of the tests
        total = lax.psum(p, axis) if axis is not None else p
        werr = jnp.zeros_like(p)
    # Shard-local wire error folds back into the transmitting rows'
    # residuals (1/m each): one (N, D) buffer conserves both levels.
    # A shard with zero transmitters has p == 0 exactly, hence werr == 0.
    share = werr[None, :] / m_loc
    if mask is None:
        resid_new = resid1 + share
        omega_new = omega + total / denom
    else:
        resid_new = jnp.where(mask[:, None], resid1 + share, resid1)
        denom_f = jnp.maximum(denom.astype(jnp.float32), 1.0)
        omega_new = jnp.where(denom > 0, omega + total / denom_f, omega)
    return omega_new, resid_new


def _mapped(body, mesh, axis, *, with_mask):
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    c, r = P(axis), P()
    in_specs = (c, r, c, c, r) if with_mask else (c, r, c)
    # check_rep=False: psum/pmax/all_gather outputs are replicated by
    # construction but the static inference can't see through the
    # bitcast chain (same opt-out as the sharded ragged solve).
    return shard_map(body, mesh=mesh, in_specs=in_specs,
                     out_specs=(r, c), check_rep=False)


def ef_consensus(z, omega, resid, *, mode: str, block: int = 256,
                 mesh=None, axis: str = "clients"):
    """EF-compressed consensus mean (ADMM family, Eq. 2.4):
    ω⁺ = ω + (1/N) Σ_i D(Q(z_i − ω + e_i)).  Exact quantizers (mode
    ``"none"``) recover ``consensus_mean(z)`` with e ≡ 0.

    Returns ``(omega_new, resid_new)``.
    """
    check_mode(mode)
    n = z.shape[0]
    if mesh is None:
        return _ef_body(z, omega, resid, None, float(n), mode=mode,
                        block=block, axis=None, n_shards=1)
    n_shards = mesh.shape[axis]
    body = partial(_ef_body, mask=None, denom=float(n), mode=mode,
                   block=block, axis=axis, n_shards=n_shards)
    return _mapped(lambda zz, ww, rr: body(zz, ww, rr), mesh, axis,
                   with_mask=False)(z, omega, resid)


def ef_participant_mean(z, committed, omega, resid, num_committed, *,
                        mode: str, block: int = 256, mesh=None,
                        axis: str = "clients"):
    """EF-compressed participant mean (FedAvg/Prox aggregation):
    ω⁺ = ω + (1/|committed|) Σ_{i∈committed} D(Q(z_i − ω + e_i)), with
    ω unchanged (and nothing transmitted) when no client committed.
    Non-committed rows keep their residuals untouched.

    Returns ``(omega_new, resid_new)``.
    """
    check_mode(mode)
    if mesh is None:
        return _ef_body(z, omega, resid, committed, num_committed,
                        mode=mode, block=block, axis=None, n_shards=1)
    n_shards = mesh.shape[axis]
    body = partial(_ef_body, mode=mode, block=block, axis=axis,
                   n_shards=n_shards)
    return _mapped(body, mesh, axis, with_mask=True)(
        z, omega, resid, committed, num_committed)


def init_residual(n_clients: int, dim: int):
    """Zero-initialized client EF residual (``FLState.comm``)."""
    return jnp.zeros((n_clients, dim), jnp.float32)


def consensus_wire_bytes(dim: int, *, mode: str = "none",
                         block: int = 256,
                         world_size: int = 1) -> dict:
    """Modeled per-device link bytes of one consensus aggregation.

    Ring model (matching ``utils.hlo.collective_inventory``): an
    all-reduce moves 2·bytes·(n−1)/n per device, an all-gather moves
    output_bytes·(n−1)/n.  ``payload`` is the z-term — the number the
    never-increase gate and the ≤ 0.3× int8 acceptance ratio read —
    and ``overhead`` the int8 shared-scale MAX all-reduce.  ``uplink``
    is the client→server story (bytes one client's transmit occupies),
    which compresses on a single device too.
    """
    check_mode(mode)
    w = WIRE_BYTES[mode]
    nb, _ = block_layout(dim, block)
    frac = (world_size - 1) / world_size if world_size > 1 else 0.0
    if mode == "bf16":
        payload = world_size * dim * 2 * frac              # u16 all-gather
    else:
        payload = 2.0 * dim * w * frac                     # ring all-reduce
    overhead = 2.0 * nb * 4 * frac if mode == "int8" else 0.0
    uplink = dim * w + (nb * 4 if mode == "int8" else 0)
    return {
        "payload_link_bytes": payload,
        "overhead_link_bytes": overhead,
        "total_link_bytes": payload + overhead,
        "uplink_bytes_per_client": uplink,
    }
