"""The FedBack round engine (paper Alg. 2) and its baseline instances.

One generic, jittable round program covers the whole algorithm family:

  ================  =========  ==========  ===============  ============
  algorithm         selection  dual λ      local prox ρ     aggregation
  ================  =========  ==========  ===============  ============
  fedback           fedback    ADMM        ρ (Eq. 2.3)      mean z_i^prev
  fedadmm           random     ADMM        ρ                mean z_i^prev
  admm (vanilla)    full       ADMM        ρ                mean z_i^prev
  fedavg            random     0           0                mean over I_s
  fedprox           random     0           μ (center ω)     mean over I_s
  ================  =========  ==========  ===============  ============

Client states are stacked pytrees (leading axis N); local training is a
``vmap`` of a scanned SGD prox solver; participation gates state commits
through ``tree_where`` masks so the whole round is one XLA program.  In
the *simulation* engine all N local solves are computed and masked — the
paper's efficiency metric (participation events) is accounted exactly,
while wall-clock savings appear in the distributed cross-pod engine
(``repro.core.crosspod``) where non-participation suppresses real
collective payloads.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.optim.sgd import sgd_init, sgd_step
from repro.utils.pytree import (
    tree_broadcast_like,
    tree_where,
    tree_zeros_like,
)
from .controller import ControllerConfig, init_controller
from .selection import make_selection
from .state import FLState, RoundMetrics
from .trigger import trigger_distances

ADMM_FAMILY = ("fedback", "fedadmm", "admm")
AVG_FAMILY = ("fedavg", "fedprox")


@dataclasses.dataclass(frozen=True)
class FLConfig:
    """Hyper-parameters of the federated optimization run."""

    algorithm: str = "fedback"
    n_clients: int = 100
    participation: float = 0.1  # L̄ (target rate / random fraction)
    rho: float = 0.01  # ADMM proximal parameter (Assumption 2)
    mu: float = 0.0  # FedProx proximal coefficient
    lr: float = 0.01
    momentum: float = 0.9
    epochs: int = 2
    batch_size: int = 42
    controller: ControllerConfig = ControllerConfig()
    trigger_metric: str = "l2"
    warm_start: bool = True  # init local solve at ω (paper footnote 2)
    selection: str | None = None  # override; defaults by algorithm
    seed: int = 0

    def selection_name(self) -> str:
        if self.selection is not None:
            return self.selection
        if self.algorithm == "fedback":
            return "fedback"
        if self.algorithm == "admm":
            return "full"
        return "random"

    def local_rho(self) -> float:
        if self.algorithm in ADMM_FAMILY:
            return self.rho
        if self.algorithm == "fedprox":
            return self.mu
        return 0.0


def _ctrl_cfg(cfg: "FLConfig") -> ControllerConfig:
    """Controller config with L̄ defaulted from cfg.participation (a
    per-client array in cfg.controller.target_rate takes precedence)."""
    c = cfg.controller
    if isinstance(c.target_rate, float):
        c = c._replace(target_rate=cfg.participation)
    return c


def init_state(cfg: FLConfig, params0) -> FLState:
    """Alg. 2 initialization: θ_i = z⁰, λ_i = 0, z_i^prev = θ_i, ω = z⁰."""
    n = cfg.n_clients
    theta = tree_broadcast_like(params0, n)
    ctrl = init_controller(n, _ctrl_cfg(cfg))
    return FLState(
        theta=theta,
        lam=tree_zeros_like(theta),
        z_prev=theta,
        omega=params0,
        ctrl=ctrl,
        rng=jax.random.PRNGKey(cfg.seed),
        round=jnp.zeros((), jnp.int32),
    )


def _epoch_indices(rng, n_points: int, batch_size: int, epochs: int):
    """(steps, batch) gather indices covering `epochs` shuffled passes."""
    per_epoch = n_points // batch_size

    def one_epoch(key):
        perm = jax.random.permutation(key, n_points)
        return perm[: per_epoch * batch_size].reshape(per_epoch, batch_size)

    keys = jax.random.split(rng, epochs)
    return jax.vmap(one_epoch)(keys).reshape(epochs * per_epoch, batch_size)


def _local_solve(loss_fn, theta0, center, x, y, idx, *, rho, lr, momentum):
    """Inexact prox update (Eq. 2.3): SGD on f_i(θ) + ρ/2‖θ − c‖²."""
    vg = jax.value_and_grad(loss_fn)

    def body(carry, idx_b):
        params, opt = carry
        xb = jnp.take(x, idx_b, axis=0)
        yb = jnp.take(y, idx_b, axis=0)
        loss, g = vg(params, xb, yb)
        if rho:
            g = jax.tree.map(lambda gl, p, c: gl + rho * (p - c), g, params,
                             center)
        params, opt = sgd_step(params, g, opt, lr, momentum)
        return (params, opt), loss

    (theta, _), losses = jax.lax.scan(body, (theta0, sgd_init(theta0)), idx)
    return theta, jnp.mean(losses)


def make_round_fn(cfg: FLConfig, loss_fn: Callable, data: dict[str, Any],
                  *, jit: bool = True):
    """Build the per-round step.

    loss_fn(params, x_batch, y_batch) -> scalar mean loss.
    data: {"x": (N, n_i, ...), "y": (N, n_i)} — equal-size client shards.
    Returns round_fn(state) -> (state, RoundMetrics).
    """
    n = cfg.n_clients
    assert data["x"].shape[0] == n, (data["x"].shape, n)
    n_points = data["x"].shape[1]
    select = make_selection(
        cfg.selection_name(),
        rate=cfg.participation,
        controller=_ctrl_cfg(cfg),
        metric=cfg.trigger_metric,
    )
    rho = cfg.local_rho()
    is_admm = cfg.algorithm in ADMM_FAMILY

    solver = partial(_local_solve, loss_fn, rho=rho, lr=cfg.lr,
                     momentum=cfg.momentum)

    def round_fn(state: FLState):
        rng, sel_rng, data_rng = jax.random.split(state.rng, 3)

        # --- server: trigger distances + selection --------------------
        distances = trigger_distances(state.omega, state.z_prev,
                                      cfg.trigger_metric)
        events, ctrl = select(sel_rng, state, distances)

        # --- client-side computation (vmapped, masked commit) ---------
        if is_admm:
            # λ_i^{k+1} = λ_i^k + θ_i^k − ω^k           (Eq. 2.3, dual)
            lam_new = jax.tree.map(
                lambda l, t, w: l + t - w[None], state.lam, state.theta,
                state.omega)
            # prox center c_i = ω^k − λ_i^{k+1}
            center = jax.tree.map(lambda w, l: w[None] - l, state.omega,
                                  lam_new)
        else:
            lam_new = state.lam  # stays zero
            center = tree_broadcast_like(state.omega, n)

        theta_init = (tree_broadcast_like(state.omega, n) if cfg.warm_start
                      else state.theta)
        idx = jax.vmap(
            lambda k: _epoch_indices(k, n_points, cfg.batch_size, cfg.epochs)
        )(jax.random.split(data_rng, n))
        theta_out, losses = jax.vmap(solver)(
            theta_init, center, data["x"], data["y"], idx)

        z_new = (jax.tree.map(jnp.add, theta_out, lam_new) if is_admm
                 else theta_out)

        theta = tree_where(events, theta_out, state.theta)
        lam = tree_where(events, lam_new, state.lam)
        z_prev = tree_where(events, z_new, state.z_prev)

        # --- server-side aggregation -----------------------------------
        num_events = jnp.sum(events.astype(jnp.int32))
        if is_admm:
            # ω^{k+1} = (1/N) Σ_i z_i^prev  (stale entries included, Eq. 2.4)
            omega = jax.tree.map(lambda z: jnp.mean(z, axis=0), z_prev)
        else:
            # FedAvg/FedProx: non-weighted mean over participants only.
            denom = jnp.maximum(num_events, 1).astype(jnp.float32)

            def avg(z, w):
                m = events.reshape((-1,) + (1,) * (z.ndim - 1))
                s = jnp.sum(jnp.where(m, z, 0.0), axis=0) / denom
                return jnp.where(num_events > 0, s, w)

            omega = jax.tree.map(avg, z_new, state.omega)

        ev_f = events.astype(jnp.float32)
        train_loss = jnp.sum(losses * ev_f) / jnp.maximum(jnp.sum(ev_f), 1.0)
        metrics = RoundMetrics(
            events=events,
            num_events=num_events,
            distances=distances,
            delta=ctrl.delta,
            load=ctrl.load,
            train_loss=train_loss,
        )
        new_state = FLState(theta=theta, lam=lam, z_prev=z_prev, omega=omega,
                            ctrl=ctrl, rng=rng, round=state.round + 1)
        return new_state, metrics

    # Note: no donation — θ and z_prev alias the same buffers at init
    # (Alg. 2 sets z⁰ = θ⁰), and the simulation state is small.
    return jax.jit(round_fn) if jit else round_fn


def make_eval_fn(loss_and_acc_fn: Callable, *, jit: bool = True):
    """loss_and_acc_fn(params, x, y) -> (loss, accuracy) on the server ω."""

    def eval_fn(state: FLState, x, y):
        return loss_and_acc_fn(state.omega, x, y)

    return jax.jit(eval_fn) if jit else eval_fn


def run_rounds(round_fn, state: FLState, num_rounds: int):
    """Python-loop driver returning stacked per-round metrics (host side)."""
    history = []
    for _ in range(num_rounds):
        state, m = round_fn(state)
        history.append(jax.device_get(m))
    metrics = jax.tree.map(lambda *xs: jnp.stack(
        [jnp.asarray(x) for x in xs]), *history) if history else None
    return state, metrics
