"""The FedBack round engine (paper Alg. 2) and its baseline instances.

One generic, jittable round program covers the whole algorithm family:

  ================  =========  ==========  ===============  ============
  algorithm         selection  dual λ      local prox ρ     aggregation
  ================  =========  ==========  ===============  ============
  fedback           fedback    ADMM        ρ (Eq. 2.3)      mean z_i^prev
  fedadmm           random     ADMM        ρ                mean z_i^prev
  admm (vanilla)    full       ADMM        ρ                mean z_i^prev
  fedavg            random     0           0                mean over I_s
  fedprox           random     0           μ (center ω)     mean over I_s
  ================  =========  ==========  ===============  ============

Client states are stacked pytrees (leading axis N); local training is a
``vmap`` of a scanned SGD prox solver; participation gates state commits
through ``tree_where`` masks so the whole round is one XLA program.

**Device-mesh scaling.**  Pass ``mesh=`` (a 1-D ``clients`` mesh from
``repro.sharding.clients.make_client_mesh``) and the same program shards
every client-stacked pytree — θ, λ, z_prev, controller vectors, data
shards — over the mesh: local solves run embarrassingly parallel across
devices, per-client trigger norms stay device-local, and the consensus
``ω = mean(z_i^prev)`` lowers to a cross-device all-reduce.  This is the
program shape ``repro.core.crosspod`` uses for pods, unified here for
the N-client simulation (shared algebra in ``repro.core.engine``).
Event decisions are bit-identical to the single-device engine (per-
client reductions never cross devices); ω matches within fp32 collective
reduction-order tolerance.

**Participation-proportional compute.**  With ``compact=True`` the
round's local-solve work scales with the controller's target rate L̄,
not with N: after selection, this round's *demand* — fresh trigger
events plus the deferral queue carried from earlier rounds — is
gathered into dense capacity-C buffers (C = ⌈slack·L̄·N⌉, per-device
under the mesh via ``shard_map``), the vmapped scanned SGD prox solver
runs over C rows of state *and data* instead of N, and committed rows
are scattered back.  Overflow is never dropped: it enters the
persistent ``DeferQueue`` (part of ``FLState``) with age-ordered,
starvation-free priority and is served in a later round
(``RoundMetrics.num_deferred`` is the queue length).  The per-round
commit limit additionally adapts to the controller's demand-load
estimate within [⌈L̄·N⌉, C] (``adaptive_capacity``; realized limit in
``RoundMetrics.realized_capacity``/``realized_slack``).  The dense path
(``compact=False``) runs all N solves behind a ``tree_where`` mask and
remains the bitwise reference for baselines; with ``capacity=N`` the
two paths agree (bit-identical events, fp32-tolerance state).  See
``repro.core.compact`` and docs/compaction.md.

**Stale-tolerant rounds.**  With ``max_staleness=S`` (None = the
synchronous engine) the round becomes a bounded-staleness pipeline: a
serviced solve lands in θ/λ/z_prev up to S rounds later (deterministic
per-client delay schedule in ``FLState.inflight``), while the consensus
average runs every round over the freshest available z-rows — Eq. 2.4
already tolerates stale rows by construction.  A client with an
in-flight solve is ineligible to re-fire (the eligibility mask threads
through compact planning), the controller measures *commit-time* events
through an issued-event ring buffer with a 1/(1+δ) feasible-rate
anti-windup clamp, and ``max_staleness=0`` reproduces the synchronous
engine bit for bit.  See docs/async.md.

**Ragged heterogeneous shards.**  Pass ``ragged=`` (a
``repro.utils.ragged.RaggedSpec``) and client data no longer needs
equal-size shards: all examples live in one pooled ``(Σnᵢ, ...)``
buffer and the solver gathers minibatches through each client's CSR
slice (``offsets[i] + local_idx``) — no per-client data rows are ever
materialized.  The dense path runs one vmapped solve per *size bucket*
(a few rectangular XLA programs, pad-to-bucket-capacity with masked
loss via ``engine.masked_batch_loss``); the compacted path streams CSR
slices through the capacity slots at the static ``max(nᵢ)`` scan shape
(masked when sizes differ).  Uniform sizes select the unmasked code
path *statically* and reproduce the rectangular dense and compact
engines bit for bit — events AND ω (tests/test_ragged.py and the
ragged golden trace pin this).  Composes with ``spec=`` (flat layout),
``compact=``, ``max_staleness=`` and ``mesh=`` (the pooled buffer is
replicated across devices; balance client *rows* onto the mesh with
``repro.sharding.clients.balanced_permutation``).

**Flat layout.**  Pass ``spec=`` (a ``repro.utils.flatstate.FlatSpec``
built from the params template) and θ, λ, z_prev live as contiguous
(N, D) fp32 matrices, ω as a (D,) vector: the trigger kernel reads the
state in place (no per-round concatenate copy) and the ADMM dual/center
algebra runs as ONE fused Pallas pass (``kernels.admm_update``,
``use_admm_kernel``) instead of separate λ/z/center HBM sweeps.  The
local solver unravels one (D,) row back into the model pytree inside
the vmap, so model code is layout-agnostic.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.sgd import sgd_init, sgd_step
from repro.utils.flatstate import FlatSpec
from repro.utils.ragged import RaggedSpec
from repro.utils.pytree import (
    tree_broadcast_like,
    tree_zeros_like,
)
from .compact import capacity_bounds, init_queue, make_compact_block, \
    shard_mapped_block
from .compress import check_mode, ef_consensus, ef_participant_mean, \
    init_residual
from .controller import ControllerConfig, init_controller
from .engine import (
    consensus_mean,
    dual_ascent,
    gated_commit,
    masked_batch_loss,
    measured_commits,
    participant_mean,
    participant_mean_loss,
    prox_center,
    record_issue,
    staleness_commit,
    staleness_masks,
)
from .selection import make_selection
from .state import FLState, InFlight, RoundMetrics, init_inflight
from .trigger import trigger_distances

ADMM_FAMILY = ("fedback", "fedadmm", "admm")
AVG_FAMILY = ("fedavg", "fedprox")


@dataclasses.dataclass(frozen=True)
class FLConfig:
    """Hyper-parameters of the federated optimization run."""

    algorithm: str = "fedback"
    n_clients: int = 100
    participation: float = 0.1  # L̄ (target rate / random fraction)
    rho: float = 0.01  # ADMM proximal parameter (Assumption 2)
    mu: float = 0.0  # FedProx proximal coefficient
    lr: float = 0.01
    momentum: float = 0.9
    epochs: int = 2
    batch_size: int = 42
    controller: ControllerConfig = ControllerConfig()
    trigger_metric: str = "l2"
    warm_start: bool = True  # init local solve at ω (paper footnote 2)
    selection: str | None = None  # override; defaults by algorithm
    use_trigger_kernel: bool | None = False  # Pallas trigger norms (l2);
    #                               explicit opt-in, None → auto (TPU)
    use_admm_kernel: bool | None = False  # fused λ⁺/center Pallas pass
    #            (flat layout only); explicit opt-in, None → auto (TPU)
    fused_gss: bool | None = False  # fused gather→ADMM→scatter commit on
    #            the compacted flat ADMM round (kernels/fused_gss.py):
    #            one pass over the (N, D) state instead of three.  The
    #            Pallas megakernel runs when ``use_admm_kernel`` also
    #            resolves on; otherwise the bit-identical jnp form
    #            carries the same fused dataflow.  Explicit opt-in,
    #            None → auto (TPU); ignored on dense rounds.
    compact: bool = False  # capacity-bounded compaction (core/compact.py)
    capacity_slack: float = 1.5  # C = ⌈slack·L̄·N⌉ solver rows per round
    capacity: int | None = None  # explicit global solver-row budget
    #            (fixes the per-round limit: adaptive capacity is only
    #             active when the budget is slack-derived)
    adaptive_capacity: bool = True  # per-round commit limit follows the
    #            demand-load estimate within [⌈L̄·N⌉, ⌈slack·L̄·N⌉]
    max_staleness: int | None = None  # stale-tolerant rounds: a serviced
    #            solve lands up to this many rounds later (per-client
    #            delay schedule; the consensus runs every round over the
    #            freshest available z-rows).  None = the synchronous
    #            engine (no pipeline state); 0 = the async pipeline with
    #            zero delay, which reproduces the synchronous engine bit
    #            for bit (the parity the tests pin down).
    staleness_schedule: str = "roundrobin"  # per-client delay draw, see
    #            repro.core.state.delay_schedule ("roundrobin"|"uniform")
    consensus_compress: str = "none"  # compressed consensus wire
    #            ("none"|"bf16"|"int8", core/compress.py): clients
    #            communicate quantized z-deltas with a persistent
    #            error-feedback residual (FLState.comm), so the
    #            consensus collective moves 2×/4× fewer bytes.  "none"
    #            keeps the exact uncompressed aggregation — bit-
    #            identical jaxprs, no residual state.  Flat layout
    #            (spec=) only.
    compress_block: int = 256  # per-block int8 scale granularity
    #            (coordinates per shared fp32 scale; clamped to D)
    state_backend: str = "device"  # where the (N, D) client matrices
    #            live ("device"|"host").  "device" is the bit-exact
    #            default: FLState on device, one jitted round program.
    #            "host" keeps θ/λ/z_prev/comm in host numpy buffers and
    #            streams only the (C, D) active-row working set per
    #            round (core/hoststate.py) — same events and fp32 state
    #            bits, device memory O(C·D) instead of O(N·D).
    #            Compact + flat layout only, single host (no mesh).
    stream_tiles: int = 2  # host backend: H2D chunks the (C, D) row
    #            stream is double-buffered into (copy/compute overlap
    #            granularity; never affects the solve width or bits)
    seed: int = 0

    def selection_name(self) -> str:
        if self.selection is not None:
            return self.selection
        if self.algorithm == "fedback":
            return "fedback"
        if self.algorithm == "admm":
            return "full"
        return "random"

    def local_rho(self) -> float:
        if self.algorithm in ADMM_FAMILY:
            return self.rho
        if self.algorithm == "fedprox":
            return self.mu
        return 0.0


def _ctrl_cfg(cfg: "FLConfig") -> ControllerConfig:
    """Controller config with L̄ defaulted from cfg.participation (a
    per-client array in cfg.controller.target_rate takes precedence).

    Any python scalar counts as "not per-client": an ``int`` target
    (e.g. ``target_rate=1``) must not silently bypass the defaulting.
    """
    c = cfg.controller
    if isinstance(c.target_rate, (bool, int, float)):
        c = c._replace(target_rate=float(cfg.participation))
    return c


def init_state(cfg: FLConfig, params0, *, mesh=None,
               client_axis: str = "clients",
               spec: FlatSpec | None = None) -> FLState:
    """Alg. 2 initialization: θ_i = z⁰, λ_i = 0, z_i^prev = θ_i, ω = z⁰.

    θ, z_prev and ω are materialized as *distinct* buffers (Alg. 2 sets
    them all from z⁰, but aliased or caller-owned buffers would break
    donating the state to the jitted round — donating ω must not delete
    the caller's ``params0``).  With ``mesh`` the stacked state is
    placed client-sharded across devices.  With ``spec`` the state is
    stored in the flat layout: θ/λ/z_prev as (N, D) fp32 matrices, ω as
    a (D,) vector (pass the same spec to ``make_round_fn``).
    """
    n = cfg.n_clients
    if cfg.state_backend not in ("device", "host"):
        raise ValueError(f"unknown state_backend: {cfg.state_backend!r} "
                         "(expected 'device' or 'host')")
    if cfg.state_backend == "host":
        from .hoststate import init_host_state
        if mesh is not None:
            raise ValueError("state_backend='host' is a single-host "
                             "backend (mesh must be None)")
        return init_host_state(cfg, params0, spec=spec)
    if check_mode(cfg.consensus_compress) != "none" and spec is None:
        raise ValueError(
            "consensus_compress="
            f"{cfg.consensus_compress!r} needs the flat (spec=) layout — "
            "the EF residual is an (N, D) matrix over the flat state")
    if spec is not None:
        params0 = spec.flatten(params0)
    theta = tree_broadcast_like(params0, n)
    z_prev = tree_broadcast_like(params0, n)  # separate buffers for donation
    ctrl = init_controller(n, _ctrl_cfg(cfg))
    inflight = None
    if cfg.max_staleness is not None:
        template = (spec.zeros_stacked(n) if spec is not None
                    else tree_zeros_like(theta))
        inflight = init_inflight(template, n, cfg.max_staleness,
                                 kind=cfg.staleness_schedule, seed=cfg.seed)
    comm = (init_residual(n, spec.dim)
            if cfg.consensus_compress != "none" else None)
    state = FLState(
        theta=theta,
        lam=tree_zeros_like(theta),
        z_prev=z_prev,
        omega=jax.tree.map(lambda x: jnp.array(x, copy=True), params0),
        ctrl=ctrl,
        rng=jax.random.PRNGKey(cfg.seed),
        round=jnp.zeros((), jnp.int32),
        queue=init_queue(n),
        inflight=inflight,
        comm=comm,
    )
    if mesh is not None:
        from repro.sharding.clients import check_divisible, fl_state_shardings
        check_divisible(n, mesh, axis=client_axis)
        state = jax.device_put(
            state, fl_state_shardings(mesh, axis=client_axis))
    return state


def _epoch_indices(rng, n_points: int, batch_size: int, epochs: int):
    """(steps, batch) gather indices covering `epochs` shuffled passes.

    The effective batch size is clamped to the shard size: with
    ``batch_size > n_points`` the old code produced a zero-length scan
    and ``jnp.mean([])`` → NaN train loss.
    """
    batch_size = min(batch_size, n_points)
    per_epoch = n_points // batch_size

    def one_epoch(key):
        perm = jax.random.permutation(key, n_points)
        return perm[: per_epoch * batch_size].reshape(per_epoch, batch_size)

    keys = jax.random.split(rng, epochs)
    return jax.vmap(one_epoch)(keys).reshape(epochs * per_epoch, batch_size)


def _local_solve(loss_fn, theta0, center, x, y, idx, *, rho, lr, momentum):
    """Inexact prox update (Eq. 2.3): SGD on f_i(θ) + ρ/2‖θ − c‖²."""
    vg = jax.value_and_grad(loss_fn)

    def body(carry, idx_b):
        params, opt = carry
        xb = jnp.take(x, idx_b, axis=0)
        yb = jnp.take(y, idx_b, axis=0)
        loss, g = vg(params, xb, yb)
        if rho:
            g = jax.tree.map(lambda gl, p, c: gl + rho * (p - c), g, params,
                             center)
        params, opt = sgd_step(params, g, opt, lr, momentum)
        return (params, opt), loss

    (theta, _), losses = jax.lax.scan(body, (theta0, sgd_init(theta0)), idx)
    return theta, jnp.mean(losses)


def _masked_local_solve(loss_fn, theta0, center, x, y, offset, size, idx,
                        *, rho, lr, momentum):
    """Inexact prox update over one ragged client's CSR slice.

    ``x``/``y`` are row buffers holding the client's slice at
    ``offset`` — the whole pooled (Σnᵢ, ...) buffer on the dense
    bucketed path, or the client's pre-sliced (max(nᵢ), ...) block
    (offset 0) on the compacted path.  ``idx`` holds virtual per-step
    indices in [0, bucket capacity).  Virtual rows beyond the client's
    ``size`` are padding: gathered clamped to the last real row (so
    every gather stays inside the client's CSR slice) and weighted 0
    in the per-example loss, so neither loss nor gradient sees them.
    A step whose batch is *all* padding is skipped outright — params,
    momentum and the reported mean loss are untouched — so a small
    client's solve equals a solve over only the steps that carry its
    data (no extra prox-pull toward the center, no 0-loss dilution of
    the train-loss metric).  With ``size == capacity`` every weight is
    1, no step skips, and the update equals :func:`_local_solve` on
    the same rows.
    """
    vg = jax.value_and_grad(
        lambda params, xb, yb, w: masked_batch_loss(loss_fn, params,
                                                    xb, yb, w))

    def body(carry, idx_b):
        params, opt = carry
        weights = (idx_b < size).astype(jnp.float32)
        live = jnp.sum(weights) > 0
        g_idx = offset + jnp.minimum(idx_b, size - 1)
        xb = jnp.take(x, g_idx, axis=0)
        yb = jnp.take(y, g_idx, axis=0)
        loss, g = vg(params, xb, yb, weights)
        if rho:
            g = jax.tree.map(lambda gl, p, c: gl + rho * (p - c), g, params,
                             center)
        new_params, new_opt = sgd_step(params, g, opt, lr, momentum)
        keep = lambda nw, od: jnp.where(live, nw, od)  # noqa: E731
        params = jax.tree.map(keep, new_params, params)
        opt = jax.tree.map(keep, new_opt, opt)
        return (params, opt), (loss, live)

    (theta, _), (losses, lives) = jax.lax.scan(
        body, (theta0, sgd_init(theta0)), idx)
    lives = lives.astype(jnp.float32)
    return theta, jnp.sum(losses * lives) / jnp.maximum(jnp.sum(lives), 1.0)


def _resolve_kernel_flag(flag: bool | None) -> bool:
    """None → auto: Pallas fast paths on TPU, jnp reference elsewhere
    (interpret-mode kernels validate the program but are slow on CPU)."""
    return jax.default_backend() == "tpu" if flag is None else flag


def _trigger(cfg: FLConfig, state: FLState, mesh, client_axis):
    """Per-client trigger distances; optionally the Pallas kernel path.

    Under the flat layout the kernel reads the (N, D) state in place
    (``trigger_sq_norms_pytree`` detects the single-matrix case)."""
    if _resolve_kernel_flag(cfg.use_trigger_kernel) \
            and cfg.trigger_metric == "l2":
        from repro.kernels import ops
        sq = ops.trigger_sq_norms_pytree(
            state.z_prev, state.omega, mesh=mesh, axis=client_axis)
        return jnp.sqrt(sq)
    return trigger_distances(state.omega, state.z_prev, cfg.trigger_metric)


def make_round_fn(cfg: FLConfig, loss_fn: Callable, data: dict[str, Any],
                  *, jit: bool = True, mesh=None,
                  client_axis: str = "clients", donate: bool | None = None,
                  ctrl_arg: bool = False, arrivals_arg: bool = False,
                  spec: FlatSpec | None = None,
                  ragged: RaggedSpec | None = None,
                  body_transform: Callable | None = None):
    """Build the per-round step.

    loss_fn(params, x_batch, y_batch) -> scalar mean loss.
    data: {"x": (N, n_i, ...), "y": (N, n_i)} — equal-size client
    shards; or, with ``ragged=``, the pooled {"x": (Σnᵢ, ...),
    "y": (Σnᵢ,)} buffers whose CSR layout the given
    ``repro.utils.ragged.RaggedSpec`` describes.

    mesh:   optional 1-D ``clients`` mesh; shards all client-stacked
            pytrees (state, data) over its axis and jits with explicit
            in/out shardings, turning the consensus mean into a
            cross-device all-reduce.
    donate: donate the input FLState buffers to the round (the state is
            produced fresh each round, so XLA can update it in place).
            Default: on for accelerator backends, off on CPU where
            donation is unimplemented and only warns.
    ctrl_arg: build ``round_fn(state, ctrl_overrides)`` instead, where
            ``ctrl_overrides`` is a dict of runtime controller-gain
            overrides (e.g. ``{"K": k, "target_rate": r}``) — the hook
            the batched sweep runner vmaps over.
    arrivals_arg: build ``round_fn(state, arrivals)`` instead (the
            serve step, ``repro.core.schedule``): ``arrivals`` is an
            (N,) bool *runtime* operand marking the clients whose
            updates reached the server this tick.  Fresh selection
            events are gated to arrived clients — the open-loop
            k-subset strategies draw among arrivals, the feedback
            trigger is masked and its integral law self-corrects —
            while plan eligibility is untouched, so demand already in
            the DeferQueue keeps being served whether or not the
            client re-arrives.  Arrival masks vary per call without
            retracing (one jitted program across the whole trace);
            with ``arrivals = ones(N)`` every tick, the step
            reproduces the plain round engine bit for bit — events
            AND fp32 ω (the degenerate-trace parity the serve tests
            pin).  Composes with ``ctrl_arg`` as
            ``round_fn(state, ctrl_overrides, arrivals)``.
    spec:   flat-layout codec (``repro.utils.flatstate.FlatSpec``); the
            state must come from ``init_state(..., spec=spec)``.  The
            given ``loss_fn`` still takes the model pytree — it is
            unravelled per client row inside the vmapped solver.

    ragged: CSR pooled-data spec (``repro.utils.ragged.RaggedSpec``);
            the local solver gathers minibatches through each client's
            CSR slice of the pooled buffer — size-bucketed vmapped
            solves on the dense path, slot-gathered slices at the
            static max(nᵢ) shape on the compacted path.  Uniform sizes
            reproduce the rectangular engines bit for bit.

    body_transform: optional wrapper applied to the finished round
            function *before* jit — ``round_fn = body_transform(
            round_fn)``.  The hook the static-analysis layer
            (``repro.analysis``) uses to count traces (retrace sentry)
            and to seed mutations in its self-tests; transforms must
            preserve the round signature.

    Returns round_fn(state[, ctrl_overrides]) -> (state, RoundMetrics).
    """
    if cfg.state_backend not in ("device", "host"):
        raise ValueError(f"unknown state_backend: {cfg.state_backend!r} "
                         "(expected 'device' or 'host')")
    if cfg.state_backend == "host":
        from .hoststate import make_host_round_fn
        return make_host_round_fn(
            cfg, loss_fn, data, jit=jit, mesh=mesh,
            client_axis=client_axis, donate=donate, ctrl_arg=ctrl_arg,
            arrivals_arg=arrivals_arg, spec=spec, ragged=ragged,
            body_transform=body_transform)
    n = cfg.n_clients
    if ragged is not None:
        if ragged.n_clients != n:
            raise ValueError(f"ragged spec describes {ragged.n_clients} "
                             f"clients, cfg.n_clients={n}")
        assert data["x"].shape[0] == ragged.buffer_rows, \
            (data["x"].shape, ragged.buffer_rows)
        # Static scan shape of slot-gathered (compacted) solves; the
        # dense path refines this per size bucket.
        n_points = ragged.max_size
    else:
        assert data["x"].shape[0] == n, (data["x"].shape, n)
        n_points = data["x"].shape[1]
    flat = spec is not None
    compress = check_mode(cfg.consensus_compress)
    if compress != "none" and not flat:
        raise ValueError(
            f"consensus_compress={compress!r} needs the flat (spec=) "
            "layout — the EF residual is an (N, D) matrix over the "
            "flat state")
    use_admm_kernel = flat and _resolve_kernel_flag(cfg.use_admm_kernel)
    select = make_selection(
        cfg.selection_name(),
        rate=cfg.participation,
        controller=_ctrl_cfg(cfg),
        metric=cfg.trigger_metric,
    )
    rho = cfg.local_rho()
    is_admm = cfg.algorithm in ADMM_FAMILY

    if mesh is not None:
        from repro.sharding.clients import (
            check_divisible,
            constrain_clients,
            fl_state_shardings,
            round_metrics_shardings,
            shard_client_data,
        )
        check_divisible(n, mesh, axis=client_axis)
        if ragged is None:
            data = shard_client_data(mesh, data, axis=client_axis)
        else:
            # The pooled buffer has no client-aligned leading axis: it
            # stays replicated; per-client offsets shard with the state.
            from repro.sharding.clients import replicate_data
            data = replicate_data(mesh, data)
        pin = partial(constrain_clients, mesh=mesh, axis=client_axis)
    else:
        pin = lambda t, **_: t  # noqa: E731

    solver = partial(_local_solve, loss_fn, rho=rho, lr=cfg.lr,
                     momentum=cfg.momentum)
    masked_solver = partial(_masked_local_solve, loss_fn, rho=rho,
                            lr=cfg.lr, momentum=cfg.momentum)
    if flat:
        # Convert at the solver boundary only: unflatten θ⁰/center once
        # per client, scan the SGD steps in native pytree space (same
        # per-step codegen as the tree layout), flatten the result.
        tree_solver = solver
        tree_masked_solver = masked_solver

        def solver(theta0_vec, center_vec, x, y, idx):
            theta, loss = tree_solver(spec.unflatten(theta0_vec),
                                      spec.unflatten(center_vec), x, y, idx)
            return spec.flatten(theta), loss

        def masked_solver(theta0_vec, center_vec, x, y, offset, size, idx):
            theta, loss = tree_masked_solver(
                spec.unflatten(theta0_vec), spec.unflatten(center_vec),
                x, y, offset, size, idx)
            return spec.flatten(theta), loss

    epoch_fn = partial(_epoch_indices, n_points=n_points,
                       batch_size=cfg.batch_size, epochs=cfg.epochs)

    fused = cfg.compact and is_admm and flat \
        and _resolve_kernel_flag(cfg.fused_gss)
    if cfg.fused_gss and not fused:
        raise ValueError(
            "fused_gss=True needs compact=True, an ADMM-family "
            "algorithm and the flat (spec=) layout — got "
            f"compact={cfg.compact}, algorithm={cfg.algorithm!r}, "
            f"flat={flat}")

    if cfg.compact:
        n_shards = mesh.shape[client_axis] if mesh is not None else 1
        c_min, cap = capacity_bounds(n, cfg.participation,
                                     cfg.capacity_slack, cfg.capacity,
                                     n_shards=n_shards)
        # An explicit budget pins the limit; adaptive capacity only
        # modulates the slack-derived one.
        adaptive = cfg.adaptive_capacity and cfg.capacity is None
        block = make_compact_block(solver, epoch_fn, cap, is_admm=is_admm,
                                   warm_start=cfg.warm_start,
                                   use_admm_kernel=use_admm_kernel,
                                   c_min=c_min, adaptive=adaptive,
                                   alpha=_ctrl_cfg(cfg).alpha,
                                   ragged=ragged,
                                   masked_solver=masked_solver,
                                   fused=fused,
                                   use_fused_kernel=(fused
                                                     and use_admm_kernel))
        if mesh is not None:
            block = shard_mapped_block(block, mesh, axis=client_axis,
                                       ragged=ragged is not None)

    async_mode = cfg.max_staleness is not None

    def _duals_and_centers(state):
        """λ⁺ and prox centers for every client (shared by the dense
        rectangular and dense ragged paths)."""
        if is_admm:
            if use_admm_kernel:
                from repro.kernels import ops
                lam_new, center = ops.admm_update(
                    state.theta, state.lam, state.omega, with_z=False,
                    mesh=mesh, axis=client_axis)
            else:
                lam_new = dual_ascent(state.lam, state.theta, state.omega)
                center = prox_center(state.omega, lam_new)
        else:
            lam_new = state.lam  # stays zero
            center = tree_broadcast_like(state.omega, n)
        return lam_new, center

    def dense_client_update(state, events, data_rng):
        """All-N solve behind the event mask (the bitwise baseline).

        Returns *service proposals* (θ_out, λ⁺, z) — the caller gates
        them into state (synchronous ``gated_commit``) or routes them
        through the delay pipeline (``staleness_commit``)."""
        lam_new, center = _duals_and_centers(state)
        theta_init = (tree_broadcast_like(state.omega, n) if cfg.warm_start
                      else state.theta)
        idx = jax.vmap(epoch_fn)(jax.random.split(data_rng, n))
        theta_out, losses = jax.vmap(solver)(
            pin(theta_init), pin(center), data["x"], data["y"], pin(idx))
        theta_out = pin(theta_out)

        z_new = (jax.tree.map(jnp.add, theta_out, lam_new) if is_admm
                 else theta_out)
        return theta_out, lam_new, z_new, losses

    # Per-bucket gather constants, staged once at build time.  The
    # traced round closes over them (they become jaxpr constants), so
    # no host→device transfer is staged inside the round — the
    # host-transfer rule in repro.analysis pins this down.
    if ragged is not None:
        _bucket_consts = tuple(
            (bucket,
             jnp.asarray(bucket.members, jnp.int32),
             jnp.asarray([ragged.offsets[i] for i in bucket.members],
                         jnp.int32),
             (jnp.asarray([ragged.sizes[i] for i in bucket.members],
                          jnp.int32) if bucket.padded else None))
            for bucket in ragged.buckets)

    # Shard-local bucket tables (dense ragged path under a mesh).
    # Bucket members interleave across the client axis, so a global
    # (θ, center)[members] gather crosses shard boundaries and SPMD
    # lowers it to 2·N·D·4 B of all-reduce per round (tracecheck, PR 6).
    # Instead each shard gets its OWN member table — per-shard local
    # row indices padded to the max local bucket population, shipped as
    # client-axis-sharded runtime operands so shard_map hands every
    # device its slice — and the bucket gathers/scatters never leave
    # the device.  Padded lanes clamp to local row 0 (always in
    # bounds), solve discarded work, and drop out of the scatter.
    if ragged is not None and mesh is not None:
        _n_shards = mesh.shape[client_axis]
        _n_local = n // _n_shards
        _local_tables = []
        for bucket in ragged.buckets:
            per_shard: list = [[] for _ in range(_n_shards)]
            for m in bucket.members:
                per_shard[m // _n_local].append(m % _n_local)
            cap_b = max(1, max(len(p) for p in per_shard))
            lmem = np.zeros((_n_shards, cap_b), np.int32)
            lval = np.zeros((_n_shards, cap_b), bool)
            for s, p in enumerate(per_shard):
                lmem[s, : len(p)] = p
                lval[s, : len(p)] = True
            _local_tables.append((jnp.asarray(lmem.reshape(-1)),
                                  jnp.asarray(lval.reshape(-1))))
        _local_tables = tuple(_local_tables)

        def _sharded_ragged_solve(theta_init, center, keys):
            from jax.experimental.shard_map import shard_map
            from jax.sharding import PartitionSpec as P

            def body(theta_init, center, keys, offsets, sizes, x, y,
                     tables):
                n_loc = keys.shape[0]
                theta_out = theta_init
                losses = jnp.zeros((n_loc,), jnp.float32)
                for (bucket, *_), (lmem, lval) in zip(_bucket_consts,
                                                      tables, strict=True):
                    rows = jax.tree.map(lambda a, m=lmem: a[m],
                                        (theta_init, center))
                    offs = offsets[lmem]
                    bucket_epochs = partial(_epoch_indices,
                                            n_points=bucket.capacity,
                                            batch_size=cfg.batch_size,
                                            epochs=cfg.epochs)
                    idx_v = jax.vmap(bucket_epochs)(keys[lmem])

                    # Materialize each lane's CSR block as one
                    # contiguous slice (never ``take(pool, offset+idx)``
                    # inside the scan — that form miscompiles under
                    # shard_map on this jax; see core/compact.py).
                    def slice_rows(buf, o_=offs, ln=bucket.capacity):
                        return jax.vmap(
                            lambda o: jax.lax.dynamic_slice_in_dim(
                                buf, o, ln, 0))(o_)

                    x_rows, y_rows = slice_rows(x), slice_rows(y)
                    if bucket.padded:
                        th, ls = jax.vmap(masked_solver)(
                            rows[0], rows[1], x_rows, y_rows,
                            jnp.zeros_like(offs), sizes[lmem], idx_v)
                    else:
                        th, ls = jax.vmap(solver)(
                            rows[0], rows[1], x_rows, y_rows, idx_v)
                    drop = jnp.where(lval, lmem, n_loc)
                    theta_out = jax.tree.map(
                        lambda acc, r, d=drop: acc.at[d].set(
                            r.astype(acc.dtype), mode="drop"),
                        theta_out, th)
                    losses = losses.at[drop].set(ls, mode="drop")
                return theta_out, losses

            c, r = P(client_axis), P()
            mapped = shard_map(
                body, mesh=mesh,
                in_specs=(c, c, c, c, c, r, r, c),
                out_specs=(c, c), check_rep=False)
            return mapped(theta_init, center, keys,
                          ragged.offsets_array(), ragged.sizes_array(),
                          data["x"], data["y"], _local_tables)

    def ragged_dense_update(state, events, data_rng):
        """All-N solve over pooled CSR data, one vmap per size bucket.

        Same service-proposal contract as ``dense_client_update``; the
        solver streams each client's minibatches straight out of the
        pooled buffer (global indices ``offset_i + local_idx``), so a
        uniform spec — one bucket, no padding — reproduces the
        rectangular dense path bit for bit.
        """
        lam_new, center = _duals_and_centers(state)
        theta_init = pin(tree_broadcast_like(state.omega, n)
                         if cfg.warm_start else state.theta)
        center = pin(center)
        keys = jax.random.split(data_rng, n)
        if mesh is not None:
            # Per-shard bucket solves: same per-client computation
            # (row, center, key, CSR slice all identical), gathered
            # through shard-local member tables under shard_map — the
            # only collective in the round stays the consensus mean.
            theta_out, losses = _sharded_ragged_solve(theta_init,
                                                      center, keys)
            theta_out = pin(theta_out)
            z_new = (jax.tree.map(jnp.add, theta_out, lam_new)
                     if is_admm else theta_out)
            return theta_out, lam_new, z_new, losses
        theta_out = theta_init  # every row overwritten below
        losses = jnp.zeros((n,), jnp.float32)
        for bucket, mem, offs, szs in _bucket_consts:
            rows = jax.tree.map(lambda a, m=mem: a[m],
                                (theta_init, center))
            bucket_epochs = partial(_epoch_indices,
                                    n_points=bucket.capacity,
                                    batch_size=cfg.batch_size,
                                    epochs=cfg.epochs)
            idx_v = jax.vmap(bucket_epochs)(keys[mem])
            if bucket.padded:
                th, ls = jax.vmap(
                    masked_solver, in_axes=(0, 0, None, None, 0, 0, 0))(
                    rows[0], rows[1], data["x"], data["y"], offs, szs,
                    idx_v)
            else:
                gidx = offs[:, None, None] + idx_v
                th, ls = jax.vmap(solver, in_axes=(0, 0, None, None, 0))(
                    rows[0], rows[1], data["x"], data["y"], gidx)
            theta_out = jax.tree.map(
                lambda acc, r, m=mem: acc.at[m].set(r.astype(acc.dtype)),
                theta_out, th)
            losses = losses.at[mem].set(ls)
        theta_out = pin(theta_out)
        z_new = (jax.tree.map(jnp.add, theta_out, lam_new) if is_admm
                 else theta_out)
        return theta_out, lam_new, z_new, losses

    # Dynamic-gather companions of the static CSR spec (the compact
    # plan indexes them by slot; client-stacked, so they shard with the
    # state under the mesh while the pooled buffer stays replicated).
    ragged_offsets = ragged.offsets_array() if ragged is not None else None
    ragged_sizes = ragged.sizes_array() if ragged is not None else None

    def compact_client_update(state, events, distances, eligible,
                              data_rng):
        """Gather demand rows into capacity slots, solve C rows, scatter."""
        keys = jax.random.split(data_rng, n)
        args = (events, distances, eligible, state.queue.age,
                state.queue.load, state.theta, state.lam,
                state.z_prev, state.omega, data["x"], data["y"], keys)
        if ragged is not None:
            args += (ragged_offsets, ragged_sizes)
        return block(*args)

    def round_body(state: FLState, ctrl_overrides, arrivals=None):
        rng, sel_rng, data_rng = jax.random.split(state.rng, 3)

        # --- server: trigger distances + selection --------------------
        distances = _trigger(cfg, state, mesh, client_axis)
        if async_mode:
            # A client with an in-flight solve is ineligible to re-fire
            # until its payload lands (one outstanding solve per client).
            inflight = state.inflight
            eligible = inflight.ttl == 0
            admit = eligible if arrivals is None else eligible & arrivals
            events = select.decide(sel_rng, state, distances,
                                   ctrl_overrides,
                                   eligible=admit) & admit
            ctrl = None  # stepped below on commit-time measurements
        elif arrivals is not None:
            # Serve step: fresh events only from this tick's arrivals.
            # Plan eligibility stays all-ones — deferred demand is
            # served whether or not the client re-arrives (a queued
            # client's work must never be dropped by a quiet tick).
            eligible = jnp.ones((n,), bool)
            events = select.decide(sel_rng, state, distances,
                                   ctrl_overrides,
                                   eligible=arrivals) & arrivals
            ctrl = select.measure(state.ctrl, events, ctrl_overrides)
        else:
            eligible = jnp.ones((n,), bool)
            events, ctrl = select(sel_rng, state, distances,
                                  ctrl_overrides=ctrl_overrides)

        # --- client-side computation (service proposals) --------------
        if cfg.compact:
            (theta_p, lam_p, z_p, q_age, q_load, serviced, losses,
             loss_mask, limits) = \
                compact_client_update(state, events, distances, eligible,
                                      data_rng)
            queue = state.queue._replace(age=q_age, load=q_load)
            # Σ over shards of the per-device commit limits (shape
            # (n_shards,) under the mesh, (1,) on a single device).
            realized_capacity = jnp.sum(limits)
            num_deferred = jnp.sum((q_age > 0).astype(jnp.int32))
        else:
            client_update = (ragged_dense_update if ragged is not None
                             else dense_client_update)
            theta_p, lam_p, z_p, losses = \
                client_update(state, events, data_rng)
            serviced, loss_mask = events, events
            queue = state.queue
            realized_capacity = jnp.asarray(n, jnp.int32)
            num_deferred = None  # 0 below (dense rounds never defer)

        # --- commit: synchronous gate or bounded-staleness pipeline ----
        if async_mode:
            land, direct, defer, new_ttl = staleness_masks(
                serviced, inflight.delay, inflight.ttl)
            theta, park_theta = staleness_commit(
                state.theta, theta_p, inflight.theta, land, direct, defer)
            lam, park_lam = staleness_commit(
                state.lam, lam_p, inflight.lam, land, direct, defer)
            z_prev, park_z = staleness_commit(
                state.z_prev, z_p, inflight.z, land, direct, defer)
            z_prev = pin(z_prev)
            committed = direct | land
            # Commit-time participation accounting: the controller
            # measures an issue δ_i rounds after the fact, with the
            # feasible-rate ceiling as anti-windup.
            hist = record_issue(inflight.hist, events, state.round)
            measured = measured_commits(hist, inflight.delay, state.round)
            ctrl = select.measure(state.ctrl, measured, ctrl_overrides,
                                  staleness_delay=inflight.delay)
            new_inflight = InFlight(delay=inflight.delay, ttl=new_ttl,
                                    theta=park_theta, lam=park_lam,
                                    z=park_z, hist=hist)
            num_inflight = jnp.sum((new_ttl > 0).astype(jnp.int32))
            num_landed = jnp.sum(land.astype(jnp.int32))
            if num_deferred is None:
                num_deferred = jnp.zeros((), jnp.int32)
        elif cfg.compact:
            theta, lam, z_prev = theta_p, lam_p, pin(z_p)
            committed, new_inflight = serviced, state.inflight
            num_inflight = num_landed = jnp.zeros((), jnp.int32)
        else:
            theta = gated_commit(events, theta_p, state.theta)
            lam = gated_commit(events, lam_p, state.lam)
            z_prev = pin(gated_commit(events, z_p, state.z_prev))
            committed, new_inflight = events, state.inflight
            num_inflight = num_landed = jnp.zeros((), jnp.int32)

        # --- server-side aggregation -----------------------------------
        num_events = jnp.sum(events.astype(jnp.int32))
        num_committed = jnp.sum(committed.astype(jnp.int32))
        if num_deferred is None:
            num_deferred = num_events - num_committed
        comm = state.comm
        if is_admm:
            # ω^{k+1} = (1/N) Σ_i z_i^prev — stale entries included
            # (Eq. 2.4); under staleness the freshest *available* rows.
            if compress != "none":
                omega, comm = ef_consensus(
                    z_prev, state.omega, comm, mode=compress,
                    block=cfg.compress_block, mesh=mesh, axis=client_axis)
            else:
                omega = consensus_mean(z_prev)
        else:
            # FedAvg/FedProx: non-weighted mean over participants only.
            # (z_prev carries this round's committed uploads; stale rows
            # are masked out by ``committed``.)
            if compress != "none":
                omega, comm = ef_participant_mean(
                    z_prev, committed, state.omega, comm, num_committed,
                    mode=compress, block=cfg.compress_block, mesh=mesh,
                    axis=client_axis)
            else:
                omega = participant_mean(z_prev, committed, state.omega,
                                         num_events=num_committed)

        rate_floor = cfg.participation * n
        metrics = RoundMetrics(
            events=events,
            num_events=num_events,
            distances=distances,
            delta=ctrl.delta,
            load=ctrl.load,
            train_loss=participant_mean_loss(losses, loss_mask),
            num_deferred=num_deferred,
            realized_capacity=realized_capacity,
            realized_slack=(realized_capacity.astype(jnp.float32)
                            / (rate_floor if rate_floor > 0 else 1.0)),
            num_inflight=num_inflight,
            num_landed=num_landed,
            committed=committed,
        )
        new_state = FLState(theta=theta, lam=lam, z_prev=z_prev, omega=omega,
                            ctrl=ctrl, rng=rng, round=state.round + 1,
                            queue=queue, inflight=new_inflight, comm=comm)
        return new_state, metrics

    if ctrl_arg and arrivals_arg:
        round_fn = round_body
    elif ctrl_arg:
        def round_fn(state, ctrl_overrides):
            return round_body(state, ctrl_overrides)
    elif arrivals_arg:
        def round_fn(state, arrivals):
            return round_body(state, None, arrivals)
    else:
        def round_fn(state):
            return round_body(state, None)

    if body_transform is not None:
        round_fn = body_transform(round_fn)

    if not jit:
        return round_fn

    # Donation is safe now that init_state materializes z_prev separately
    # from θ; CPU has no donation support and would warn on every call.
    if donate is None:
        donate = jax.default_backend() != "cpu"
    donate_argnums = (0,) if donate else ()

    if mesh is None:
        return jax.jit(round_fn, donate_argnums=donate_argnums)

    from jax.sharding import NamedSharding, PartitionSpec
    state_sh = fl_state_shardings(mesh, axis=client_axis)
    metrics_sh = round_metrics_shardings(mesh, axis=client_axis)
    in_sh: tuple = (state_sh,)
    if ctrl_arg:
        in_sh += (None,)
    if arrivals_arg:
        in_sh += (NamedSharding(mesh, PartitionSpec(client_axis)),)
    return jax.jit(round_fn, in_shardings=in_sh,
                   out_shardings=(state_sh, metrics_sh),
                   donate_argnums=donate_argnums)


def make_eval_fn(loss_and_acc_fn: Callable, *, jit: bool = True,
                 spec: FlatSpec | None = None):
    """loss_and_acc_fn(params, x, y) -> (loss, accuracy) on the server ω.

    With ``spec`` (flat layout) the flat ω is unravelled back into the
    model pytree before evaluation.
    """

    def eval_fn(state: FLState, x, y):
        omega = spec.unflatten(state.omega) if spec is not None \
            else state.omega
        return loss_and_acc_fn(omega, x, y)

    return jax.jit(eval_fn) if jit else eval_fn


def run_rounds(round_fn, state: FLState, num_rounds: int):
    """Python-loop driver returning stacked per-round metrics.

    Metrics stay on device until the final stack — the loop never calls
    ``device_get``, so each ``round_fn`` dispatch is asynchronous and
    donation/async dispatch pipeline across rounds.  The returned
    metrics pytree has leaves of shape (num_rounds, ...); fetch to host
    once at the end (``jax.device_get``/``np.asarray``) if needed.
    """
    history = []
    for _ in range(num_rounds):
        state, m = round_fn(state)
        history.append(m)
    metrics = (jax.tree.map(lambda *xs: jnp.stack(xs), *history)
               if history else None)
    return state, metrics
