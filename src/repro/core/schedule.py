"""Rounds-as-a-service: the event-driven admission scheduler.

The round engine (``repro.core.fedback``) beats on a fixed cadence —
every client that wants to participate waits for the next round
boundary.  This module replaces the outer loop with an event-driven
scheduler in the continuous-batching style: client updates *arrive* on
a generated trace (:func:`make_trace` — Poisson / diurnal / bursty /
the degenerate "everyone fires every tick"), are admitted into free
capacity slots immediately through the existing ``CompactPlan`` +
``DeferQueue`` machinery (overflow defers, never drops), and the
consensus mean ticks on its own clock — every tick averages the
freshest available z-rows, however few clients arrived.

The inner step stays ONE jitted program: ``make_round_fn(...,
arrivals_arg=True)`` takes the tick's (N,) bool arrival mask as a
runtime operand, so the whole trace runs through a single compiled
round (the retrace sentry in ``repro.analysis`` pins this).  The host
loop (:func:`serve`) only drains the trace, fetches the tick's commit
mask and stamps wall-clock times; :class:`ServeReport` carries p50/p99
admission→commit latency and sustained commits/sec (the
``BENCH_serve.json`` artifact, gated in ``benchmarks/compare.py``).

**Parity anchor.**  The all-ones trace makes every tick a synchronous
round: fresh events are masked by ``& ones`` (a no-op) and the
k-subset strategies draw among "everyone" — the serve step reproduces
the plain round engine bit for bit, events AND fp32 ω
(tests/test_serve.py pins the {uniform,ragged} × {1,2}-device matrix).

See docs/serving.md.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

TRACE_KINDS = ("sync", "poisson", "diurnal", "bursty")


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    """Generator spec for a (ticks, N) boolean arrival trace.

    ``sync``     everyone arrives every tick — the degenerate trace
                 that reproduces the synchronous round engine.
    ``poisson``  i.i.d. Bernoulli(rate) per client-tick (the Poisson
                 process thinned onto the tick grid).
    ``diurnal``  Bernoulli with a sinusoidal rate, period ``period``
                 ticks and relative amplitude ``amplitude``.
    ``bursty``   quiet Bernoulli(rate·quiet_frac) baseline; every
                 ``burst_every`` ticks a ``burst_len``-tick burst at
                 Bernoulli(burst_rate) — the flash-crowd adversary the
                 DeferQueue absorbs.
    """

    kind: str = "poisson"
    n_clients: int = 64
    ticks: int = 64
    rate: float = 0.5  # per-tick arrival probability (mean load)
    seed: int = 0
    period: int = 24  # diurnal period, ticks
    amplitude: float = 0.9  # diurnal relative swing, in [0, 1]
    quiet_frac: float = 0.25  # bursty baseline = rate · quiet_frac
    burst_every: int = 16
    burst_len: int = 4
    burst_rate: float = 0.9


def make_trace(cfg: TraceConfig) -> np.ndarray:
    """(ticks, N) bool arrival mask; deterministic per seed."""
    if cfg.kind not in TRACE_KINDS:
        raise ValueError(f"unknown trace kind {cfg.kind!r}; "
                         f"expected one of {TRACE_KINDS}")
    t, n = cfg.ticks, cfg.n_clients
    if cfg.kind == "sync":
        return np.ones((t, n), bool)
    rng = np.random.default_rng(cfg.seed)
    if cfg.kind == "poisson":
        rates = np.full((t,), cfg.rate)
    elif cfg.kind == "diurnal":
        phase = 2.0 * np.pi * np.arange(t) / max(cfg.period, 1)
        rates = cfg.rate * (1.0 + cfg.amplitude * np.sin(phase))
    else:  # bursty
        rates = np.full((t,), cfg.rate * cfg.quiet_frac)
        for start in range(0, t, max(cfg.burst_every, 1)):
            rates[start: start + cfg.burst_len] = cfg.burst_rate
    rates = np.clip(rates, 0.0, 1.0)
    return rng.random((t, n)) < rates[:, None]


def sync_trace(n_clients: int, ticks: int) -> np.ndarray:
    """The degenerate "everyone fires every tick" parity trace."""
    return make_trace(TraceConfig(kind="sync", n_clients=n_clients,
                                  ticks=ticks))


@dataclasses.dataclass
class ServeReport:
    """What the serve loop observed: admissions, commits, latencies.

    *Admission* is the tick a client's arrival fired an event (the
    server accepted the update for service); *commit* is the tick its
    θ/λ/z_prev row actually landed (same tick on the dense synchronous
    path; later under capacity deferral and/or staleness).  One
    latency sample per admission→commit pair, earliest admission kept
    when a pending client re-fires.  Wall-clock latency spans the
    admission tick's dispatch to the commit tick's observed completion
    (the host fetch), so it includes everything a client would wait
    for; compile time is excluded only when the loop is warmed up
    (``serve(..., warmup=True)``).
    """

    ticks: int
    n_clients: int
    arrivals_total: int          # Σ trace — raw arrival opportunities
    admitted_total: int          # admission events (latency starts)
    commits_total: int           # committed rows (latency stops)
    pending_final: int           # still queued/in-flight at the end
    conservation_ok: bool        # admitted − commits == pending, and
    #                              pending == deferred + in-flight (the
    #                              engine-side queue/pipeline agree)
    latency_ticks: np.ndarray    # (commits_total,) int
    latency_us: np.ndarray       # (commits_total,) float
    wall_s: float                # whole-trace wall time
    final_num_deferred: int
    final_num_inflight: int

    @property
    def commits_per_sec(self) -> float:
        return self.commits_total / max(self.wall_s, 1e-12)

    @property
    def ticks_per_sec(self) -> float:
        return self.ticks / max(self.wall_s, 1e-12)

    def percentiles(self, q=(50, 99)) -> dict:
        out: dict = {}
        for name, arr in (("ticks", self.latency_ticks),
                          ("us", self.latency_us)):
            for p in q:
                key = f"p{p}_latency_{name}"
                out[key] = (float(np.percentile(arr, p))
                            if arr.size else 0.0)
        return out

    def summary(self) -> dict:
        """JSON-able digest (the BENCH_serve.json section body)."""
        return {
            "ticks": self.ticks,
            "n_clients": self.n_clients,
            "arrivals_total": self.arrivals_total,
            "admitted_total": self.admitted_total,
            "commits_total": self.commits_total,
            "pending_final": self.pending_final,
            "conservation_ok": self.conservation_ok,
            **self.percentiles(),
            "commits_per_sec": self.commits_per_sec,
            "ticks_per_sec": self.ticks_per_sec,
            "wall_s": self.wall_s,
            "final_num_deferred": self.final_num_deferred,
            "final_num_inflight": self.final_num_inflight,
        }


def _copy_state(state):
    return jax.tree.map(lambda x: jnp.array(x, copy=True)
                        if isinstance(x, jax.Array) else x, state)


def serve(round_fn, state, trace, *, warmup: bool = False,
          collect_metrics: bool = False):
    """Drain an arrival trace through the jitted serve step.

    ``round_fn`` must come from ``make_round_fn(...,
    arrivals_arg=True)``; ``trace`` is a (ticks, N) bool array.  Per
    tick the host converts one arrival row to a device array, steps
    the program and fetches the tick's ``committed`` mask plus the
    scalar queue/pipeline depths — nothing else crosses the host
    boundary, so the step itself stays transfer-free (the tracecheck
    ``host-transfer-budget`` rule inspects it).

    ``warmup=True`` compiles the step on a deep copy of ``state``
    before timing starts (safe under donation — only the copy's
    buffers are consumed), so wall-clock latencies exclude compile.

    Returns ``(state, ServeReport)`` — or ``(state, report, history)``
    with ``collect_metrics=True``, where ``history`` is the list of
    per-tick ``RoundMetrics`` (host copies).
    """
    trace = np.asarray(trace, bool)
    ticks, n = trace.shape
    if warmup and ticks:
        probe = round_fn(_copy_state(state),
                         jnp.zeros((n,), bool))
        jax.block_until_ready(probe)
        del probe

    pending_tick = np.full((n,), -1, np.int64)
    pending_wall = np.zeros((n,), np.float64)
    latency_ticks: list = []
    latency_us: list = []
    admitted_total = 0
    commits_total = 0
    history: list = []
    final_deferred = final_inflight = 0

    t_begin = time.perf_counter()
    for t in range(ticks):
        t_dispatch = time.perf_counter()
        arrivals = jnp.asarray(trace[t])
        state, metrics = round_fn(state, arrivals)
        events = np.asarray(metrics.events)
        committed = np.asarray(metrics.committed)
        t_done = time.perf_counter()
        if collect_metrics:
            history.append(jax.device_get(metrics))
        final_deferred = int(metrics.num_deferred)
        final_inflight = int(metrics.num_inflight)

        # Demand is one bit per client: a commit closes the *earliest*
        # open admission, and a re-fire while pending (or on the very
        # tick the commit lands) merges into it — exactly the
        # DeferQueue's events|age semantics, so no extra admission.
        was_pending = pending_tick >= 0
        landed = committed & was_pending
        for i in np.nonzero(landed)[0]:
            latency_ticks.append(t - pending_tick[i])
            latency_us.append((t_done - pending_wall[i]) * 1e6)
            pending_tick[i] = -1
        commits_total += int(landed.sum())

        fresh = events & ~was_pending
        admitted_total += int(fresh.sum())
        # Same-tick service: admitted and committed in one step.
        instant = fresh & committed
        for _ in range(int(instant.sum())):
            latency_ticks.append(0)
            latency_us.append((t_done - t_dispatch) * 1e6)
        commits_total += int(instant.sum())
        opened = fresh & ~instant
        pending_tick[opened] = t
        pending_wall[opened] = t_dispatch
    wall_s = time.perf_counter() - t_begin

    pending_final = int((pending_tick >= 0).sum())
    report = ServeReport(
        ticks=ticks,
        n_clients=n,
        arrivals_total=int(trace.sum()),
        admitted_total=admitted_total,
        commits_total=commits_total,
        pending_final=pending_final,
        conservation_ok=(admitted_total - commits_total == pending_final
                         and pending_final
                         == final_deferred + final_inflight),
        latency_ticks=np.asarray(latency_ticks, np.int64),
        latency_us=np.asarray(latency_us, np.float64),
        wall_s=wall_s,
        final_num_deferred=final_deferred,
        final_num_inflight=final_inflight,
    )
    if collect_metrics:
        return state, report, history
    return state, report


def run_trace(round_fn, state, trace):
    """Device-side trace driver (no latency accounting): step every
    tick, stack the metrics — the serve analogue of ``run_rounds``
    (golden traces and parity tests use it)."""
    history = []
    for t in range(np.asarray(trace).shape[0]):
        state, m = round_fn(state, jnp.asarray(np.asarray(trace)[t]))
        history.append(m)
    metrics = (jax.tree.map(lambda *xs: jnp.stack(xs), *history)
               if history else None)
    return state, metrics


__all__ = [
    "TRACE_KINDS",
    "TraceConfig",
    "make_trace",
    "sync_trace",
    "ServeReport",
    "serve",
    "run_trace",
]
