"""Capacity-bounded compaction: lossless, self-tuning solver dispatch.

The dense round engine runs the local solver for all N clients and
throws away the non-participants' work behind an event mask — exact
event accounting, but O(N) local-solve FLOPs per round regardless of
the controller's target rate L̄.  This module is the MoE-style dispatch
that makes round *compute* follow round *participation*:

    1. **plan**    — rank this round's *demand* (fresh trigger events ∪
       the deferral queue carried from earlier rounds) and assign the
       top slots up to the round's capacity limit; the rest stays in
       the queue (``DeferQueue``, part of ``FLState``).
    2. **gather**  — pull the planned clients' rows (θ, λ, data shard,
       PRNG key) into contiguous (C, ...) buffers — the solver and the
       fused ADMM kernel touch only C rows of state *and* data.
    3. **solve**   — run the vmapped scanned SGD prox solver over C rows
       instead of N.
    4. **scatter** — write committed rows back into the (N, ...) state;
       invalid slots (limit exceeds demand) drop out via an
       out-of-bounds scatter index.

**Deferral queue (lossless carry).**  A client that fired but missed a
slot is not dropped: it enters the queue (``age = 1``) and is carried
into every subsequent plan until served, with age-ordered priority —
a client deferred k rounds outranks every fresh event and every client
deferred < k rounds, so the plan serves the queue oldest-first and no
client can starve: with per-round limit C ≥ 1 a deferred client is
served within ⌈P/C⌉ rounds where P is the queue length when it joined
(later arrivals are strictly younger and never overtake it).  No unit
of work is lost or duplicated across rounds:

    demand_k  = events_k ∪ pending_k
    served_k  = top-C_k of demand_k          (committed)
    pending_{k+1} = demand_k \\ served_k      (ages += 1)

(a pending client whose trigger re-fires merges into its existing queue
entry — the carry is a state sync, idempotent by construction).

**Adaptive capacity.**  The static buffer size is C_max = ⌈slack·L̄·N⌉
(XLA shapes cannot change per round), but the per-round *commit limit*
C_k adapts to the controller's own load estimate: each client keeps an
EMA of its demand membership (``DeferQueue.load``, the Eq. 3.4 filter
applied to fired ∪ pending), and

    C_k = clip(⌈Σ_shard load⌉, ⌈L̄·n_shard⌉, C_max_shard)

so ``slack`` is a *bound*, not a constant — under light load the round
commits near the L̄·N floor, under bursts it opens up to the slack
ceiling.  The realized limit is surfaced per round as
``RoundMetrics.realized_capacity`` / ``realized_slack``.  C_k models
the *served-row budget* of a deployed server (upload/participation
bandwidth, the quantity FedBack's Θ(L̄·N) claim is about); the
simulator itself still executes all C_max slots every round — static
XLA shapes — so the benchmark HBM model is deliberately parameterized
by the static C, never by C_k.

Under a ``clients`` device mesh the block runs per-device via
``shard_map`` with per-shard budgets that round *up* (the global sum of
per-shard capacities always covers the global budget — see
:func:`capacity_for`).  Gather/solve/scatter and the queue itself never
cross devices — a deferred client is always served by the device owning
its state row (no-cross-shard-migration invariant) — so the only
collective in the round remains the consensus mean.  With
``capacity ≥ N`` no client is ever deferred and the compacted round
reproduces the dense path (bit-identical events, fp32-tolerance state)
— see tests/test_compact.py and tests/test_compact_properties.py.
"""
from __future__ import annotations

import math
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.utils.pytree import tree_broadcast_like

from .controller import demand_load_step
from .state import DeferQueue


class CompactPlan(NamedTuple):
    idx: jax.Array  # (C,) int32 — client row feeding each capacity slot
    valid: jax.Array  # (C,) bool — slot carries a genuine demand client
    committed: jax.Array  # (N,) bool — in demand AND within the limit
    num_deferred: jax.Array  # () int32 — demand beyond the limit (queue
    #                          length after this round)
    demand: jax.Array  # (N,) bool — fresh events ∪ carried deferrals
    num_demand: jax.Array  # () int32
    limit: jax.Array  # () int32 — rows this plan may commit (C_k ≤ C)


def init_queue(n_clients: int) -> DeferQueue:
    """Empty queue; load starts at 1 because δ⁰ = 0 makes every client
    fire in round 0 (paper Alg. 2) — the estimate predicts that burst,
    so the adaptive limit opens to the slack ceiling immediately."""
    return DeferQueue(age=jnp.zeros((n_clients,), jnp.int32),
                      load=jnp.ones((n_clients,), jnp.float32))


def capacity_for(n_clients: int, rate: float, slack: float,
                 capacity: int | None = None, *, n_shards: int = 1) -> int:
    """Static per-shard slot count C.

    ``capacity`` (if given) is the *global* solver-row budget; otherwise
    C_global = ⌈slack·L̄·N⌉.  The per-shard budget rounds **up**
    (⌈C_global/n_shards⌉) so the global sum of per-shard capacities
    never loses remainder clients when C_global is not divisible by the
    shard count; it is then clamped to [1, local client count] (a shard
    cannot commit more rows than it owns).
    """
    total = capacity if capacity is not None else math.ceil(
        slack * rate * n_clients)
    if n_clients % n_shards:
        raise ValueError(
            f"n_clients={n_clients} must be divisible by n_shards="
            f"{n_shards} (equal-size client shards)")
    n_local = n_clients // n_shards
    per_shard = max(1, min(math.ceil(total / n_shards), n_local))
    # Rounding up guarantees the global budget is covered (up to the
    # hard N ceiling — no plan can commit more rows than exist).
    assert per_shard * n_shards >= min(total, n_clients), \
        (per_shard, n_shards, total, n_clients)
    return per_shard


def capacity_bounds(n_clients: int, rate: float, slack: float,
                    capacity: int | None = None, *,
                    n_shards: int = 1) -> tuple[int, int]:
    """(C_min, C_max) per shard for the adaptive limit.

    C_max is :func:`capacity_for` (the static slot count); C_min is the
    participation floor ⌈L̄·n_local⌉ — the adaptive limit may never
    throttle below the controller's own target throughput.
    """
    c_max = capacity_for(n_clients, rate, slack, capacity,
                         n_shards=n_shards)
    n_local = n_clients // n_shards
    c_min = max(1, min(math.ceil(rate * n_local), c_max))
    return c_min, c_max


def adaptive_limit(qload: jax.Array, c_min: int, c_max: int) -> jax.Array:
    """Per-round commit limit C_k from the shard's demand-load estimate.

    qload: (n_local,) fp32 per-client demand EMAs; their sum estimates
    this shard's expected solver rows per round.  Returns a traced ()
    int32 in [c_min, c_max] — the *buffers* stay C_max-sized (static
    shapes), only the commit mask tightens.
    """
    est = jnp.ceil(jnp.sum(qload)).astype(jnp.int32)
    return jnp.clip(est, c_min, c_max)


def compact_plan(events: jax.Array, priority: jax.Array, capacity: int,
                 *, age: jax.Array | None = None,
                 limit: jax.Array | int | None = None,
                 eligible: jax.Array | None = None) -> CompactPlan:
    """Assign demand (events ∪ queue) to capacity slots.

    events: (N,) bool; priority: (N,) fp32 (trigger distances — larger
    means more urgent); age: (N,) int32 deferral ages (None ⇒ no queue).
    Ordering is lexicographic — demand first, then age descending
    (starvation-freedom: a client deferred k rounds outranks any fresh
    event and any younger deferral), then priority descending, then
    client index ascending — fully deterministic, so the plan is
    reproducible and vmap/shard_map friendly.

    ``limit`` (traced or static, ≤ capacity) caps how many slots may
    commit this round (adaptive capacity); the slot *buffers* stay
    ``capacity``-sized.

    ``eligible`` (None ⇒ everyone) masks clients out of the demand set
    entirely — the stale-tolerant engine passes ``ttl == 0`` so a
    client with an in-flight solve can neither re-fire nor be planned
    again until its payload lands (one outstanding solve per client).
    A queued client is always eligible by construction (it has not been
    serviced, so nothing of it is in flight); the mask enforces that
    invariant against the plan rather than assuming it.
    """
    n = events.shape[0]
    if age is None:
        age = jnp.zeros((n,), jnp.int32)
    demand = events | (age > 0)
    if eligible is not None:
        demand = demand & eligible
    # jnp.lexsort: last key is primary; ascending.  Index as the least-
    # significant key forces the low-index tie-break on every backend.
    order = jnp.lexsort((jnp.arange(n, dtype=jnp.int32),
                         -priority.astype(jnp.float32),
                         -age, ~demand)).astype(jnp.int32)
    idx = order[:capacity]
    num_demand = jnp.sum(demand.astype(jnp.int32))
    lim = jnp.minimum(jnp.asarray(capacity if limit is None else limit,
                                  jnp.int32), capacity)
    valid = jnp.arange(capacity, dtype=jnp.int32) < jnp.minimum(num_demand,
                                                                lim)
    rank = jnp.zeros((n,), jnp.int32).at[order].set(
        jnp.arange(n, dtype=jnp.int32))
    committed = demand & (rank < lim)
    return CompactPlan(
        idx=idx, valid=valid, committed=committed,
        num_deferred=jnp.maximum(num_demand - lim, 0),
        demand=demand, num_demand=num_demand, limit=lim)


def queue_update(queue: DeferQueue, plan: CompactPlan, *,
                 alpha: float) -> DeferQueue:
    """Advance the deferral queue one round.

    Served clients leave the queue (age → 0); unserved demand ages by
    one (fresh overflow enters at age 1).  The demand EMA is the
    controller low-pass (Eq. 3.4) applied to demand membership.
    """
    new_age = jnp.where(plan.demand & ~plan.committed, queue.age + 1, 0)
    return DeferQueue(age=new_age.astype(jnp.int32),
                      load=demand_load_step(queue.load, plan.demand, alpha))


def gather_rows(tree, idx):
    """Pull rows ``idx`` of every (N, ...) leaf into (C, ...) buffers."""
    return jax.tree.map(lambda x: x[idx], tree)


def scatter_rows(current, rows, idx, valid):
    """Write slot rows back into the (N, ...) state; invalid slots are
    routed to an out-of-bounds index and dropped by the scatter."""
    n = jax.tree.leaves(current)[0].shape[0]
    drop_idx = jnp.where(valid, idx, n)
    return jax.tree.map(
        lambda c, r: c.at[drop_idx].set(r.astype(c.dtype), mode="drop"),
        current, rows)


def make_compact_block(solver: Callable, epoch_fn: Callable, capacity: int,
                       *, is_admm: bool, warm_start: bool,
                       use_admm_kernel: bool = False,
                       c_min: int | None = None, adaptive: bool = False,
                       alpha: float = 0.9, ragged=None,
                       masked_solver: Callable | None = None,
                       fused: bool = False,
                       use_fused_kernel: bool = False) -> Callable:
    """Build the per-shard gather→solve→scatter block.

    solver(theta0, center, x, y, idx) -> (theta, mean_loss), vmapped
    over capacity slots; epoch_fn(key) -> (steps, batch) gather indices.
    With ``adaptive`` the per-round commit limit follows the queue's
    demand-load estimate within [c_min, capacity]; otherwise the limit
    is the full ``capacity``.  The block is a pure function of one
    shard's rows — the deferral queue included, so a deferred client is
    always served by its own shard — and the caller can run it directly
    (single device) or under ``shard_map`` (mesh).

    Returns block(events, distances, eligible, age, qload, theta, lam,
    z_prev, omega, x, y, keys) -> (theta', lam', z_prev', age', qload',
    committed, slot_losses, slot_valid, limit(1,)).  ``eligible`` is the
    stale-tolerant engine's in-flight mask (all-True on the synchronous
    engine); state outputs are *service proposals* — the synchronous
    caller uses them as the committed state directly, the async caller
    routes them through the delay pipeline (``engine.staleness_commit``).

    With ``ragged`` (a ``repro.utils.ragged.RaggedSpec``) the block
    takes two trailing inputs — per-client CSR ``offsets`` and
    ``sizes`` — and ``x``/``y`` are the *pooled* (Σnᵢ+pad, ...)
    buffers: each capacity slot slices its client's CSR block out of
    the pool (``dynamic_slice`` at the static ``max(nᵢ)`` length — the
    spec's padding guarantees the slice never clamps), so the solver
    still streams C rows of data, they just come from CSR slices
    instead of a rectangular gather.  A non-uniform spec routes through
    ``masked_solver`` (pad-to-max with masked loss); a uniform spec
    statically selects the unmasked ``solver`` and reproduces the
    rectangular block bit for bit.

    With ``fused`` (flat-layout ADMM only) the post-solve commit — z
    assembly plus the three scatters — runs as one fused
    gather→ADMM→scatter pass (``kernels.fused_gss``): the Pallas
    megakernel when ``use_fused_kernel``, its bit-identical jnp form
    otherwise.  The reference three-pass path stays the parity oracle.
    """
    masked = ragged is not None and not ragged.uniform
    if masked and masked_solver is None:
        raise ValueError("non-uniform ragged compaction needs masked_solver")
    if fused and not is_admm:
        raise ValueError("fused commit is the ADMM dual algebra — "
                         "non-ADMM compaction has no λ/z streams to fuse")

    def solve_slots(theta0_rows, center_rows, x, y, keys_rows,
                    off_rows, size_rows):
        idx_b = jax.vmap(epoch_fn)(keys_rows)
        if ragged is None:
            # x/y here are the slot-gathered (C, nᵢ, ...) rows.
            return jax.vmap(solver)(theta0_rows, center_rows, x, y, idx_b)
        # Materialize each slot's (max_size, ...) CSR block — a single
        # contiguous slice per slot, never crossing into another
        # client's valid indices (padding keeps the last slices in
        # bounds; sliced-in neighbor rows beyond a slot's ``size`` are
        # unreachable: local indices are clamped to size-1).
        block_len = ragged.max_size

        def slice_rows(buf):
            return jax.vmap(
                lambda o: jax.lax.dynamic_slice_in_dim(buf, o, block_len,
                                                       0))(off_rows)

        x_rows, y_rows = slice_rows(x), slice_rows(y)
        if masked:
            return jax.vmap(masked_solver)(
                theta0_rows, center_rows, x_rows, y_rows,
                jnp.zeros_like(off_rows), size_rows, idx_b)
        return jax.vmap(solver)(theta0_rows, center_rows, x_rows, y_rows,
                                idx_b)

    def block(events, distances, eligible, age, qload, theta, lam, z_prev,
              omega, x, y, keys, offsets=None, sizes=None):
        limit = (adaptive_limit(qload, c_min, capacity)
                 if adaptive else None)
        plan = compact_plan(events, distances, capacity, age=age,
                            limit=limit, eligible=eligible)
        queue = queue_update(DeferQueue(age=age, load=qload), plan,
                             alpha=alpha)
        th_rows = gather_rows(theta, plan.idx)
        lam_rows = gather_rows(lam, plan.idx)

        if is_admm:
            if use_admm_kernel and not fused:
                from repro.kernels import ops
                lam_new_rows, center_rows = ops.admm_update(
                    th_rows, lam_rows, omega, with_z=False)
            else:
                # The fused path re-derives λ⁺ inside the commit kernel
                # — the pre-solve pass stays jnp (the solver only needs
                # the center), so one round launches ONE state kernel.
                from repro.core.engine import dual_ascent, prox_center
                lam_new_rows = dual_ascent(lam_rows, th_rows, omega)
                center_rows = prox_center(omega, lam_new_rows)
        else:
            lam_new_rows = lam_rows  # stays zero
            center_rows = tree_broadcast_like(omega, capacity)

        theta0_rows = (tree_broadcast_like(omega, capacity) if warm_start
                       else th_rows)
        # Data and PRNG keys flow through the same capacity slots: the
        # vmapped solver streams C rows of x/y (C CSR slices of the
        # pooled buffer when ragged), not N.
        if ragged is None:
            x_slots, y_slots = gather_rows(x, plan.idx), \
                gather_rows(y, plan.idx)
            off_rows = size_rows = None
        else:
            x_slots, y_slots = x, y  # pooled; sliced inside the solver
            off_rows = gather_rows(offsets, plan.idx)
            size_rows = gather_rows(sizes, plan.idx)
        th_out_rows, losses = solve_slots(
            theta0_rows, center_rows, x_slots, y_slots,
            gather_rows(keys, plan.idx), off_rows, size_rows)
        if fused:
            # One pass over the state instead of three: the fused op
            # re-derives λ⁺ from the gathered θ/λ rows (bit-identical
            # _kernel3 op order — λ is unchanged since the pre-solve
            # pass), assembles z = θ_out + λ⁺ in VMEM, and scatters all
            # three outputs in place on their aliased input buffers.
            from repro.kernels import ops
            op = ops.fused_gss if use_fused_kernel else ops.fused_gss_ref
            theta_new, lam_new, z_new = op(
                plan.idx, plan.valid, th_out_rows, omega, theta, lam,
                z_prev, with_z=True)
        else:
            z_rows = (jax.tree.map(jnp.add, th_out_rows, lam_new_rows)
                      if is_admm else th_out_rows)
            theta_new = scatter_rows(theta, th_out_rows, plan.idx,
                                     plan.valid)
            z_new = scatter_rows(z_prev, z_rows, plan.idx, plan.valid)
            lam_new = (scatter_rows(lam, lam_new_rows, plan.idx,
                                    plan.valid) if is_admm else lam)
        return (theta_new, lam_new, z_new, queue.age, queue.load,
                plan.committed, losses, plan.valid,
                plan.limit.reshape((1,)))

    # Static plan facts for the analysis layer (repro.analysis): the
    # solve width and limit bounds the compiled program was built for.
    block.static_info = {"capacity": capacity, "c_min": c_min,
                         "adaptive": adaptive, "is_admm": is_admm,
                         "use_admm_kernel": use_admm_kernel,
                         "fused": fused,
                         "use_fused_kernel": use_fused_kernel,
                         "ragged": ragged is not None}
    return block


def shard_mapped_block(block: Callable, mesh, *, axis: str = "clients",
                       ragged: bool = False) -> Callable:
    """Run the compact block per-device over the client mesh axis.

    Every input except ω is client-stacked (the deferral queue
    included — deferred clients never migrate across shards); the
    per-device commit limits come back stacked (n_shards,) so the
    caller can sum them into the round's realized capacity.  With
    ``ragged`` the x/y inputs are the pooled CSR buffers and stay
    replicated, while the trailing per-client offsets/sizes shard with
    the state — the offsets are *global* rows of the replicated pool,
    so a shard's solves read exactly its own clients' slices and
    gather/solve/scatter still never cross devices.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    c, r = P(axis), P()
    data_spec = (r, r) if ragged else (c, c)
    extra = (c, c) if ragged else ()
    mapped = shard_map(
        block, mesh=mesh,
        in_specs=(c, c, c, c, c, c, c, c, r) + data_spec + (c,) + extra,
        out_specs=(c, c, c, c, c, c, c, c, c),
        check_rep=False)
    info = getattr(block, "static_info", None)
    if info is not None:  # carried through for the analysis layer
        mapped.static_info = dict(info, n_shards=mesh.shape[axis])
    return mapped
