"""Capacity-bounded compaction: solver work proportional to L̄·N.

The dense round engine runs the local solver for all N clients and
throws away the non-participants' work behind an event mask — exact
event accounting, but O(N) local-solve FLOPs per round regardless of
the controller's target rate L̄.  This module is the MoE-style dispatch
that makes round *compute* follow round *participation*:

    1. **plan**    — rank this round's fired clients by trigger distance
       (stalest first) and assign the top C = ⌈slack·L̄·N⌉ to dense
       capacity slots; overflow beyond C is *deferred* (the client keeps
       its state, the event still feeds the controller, and the count is
       surfaced as ``RoundMetrics.num_deferred``).
    2. **gather**  — pull the planned clients' rows (θ, λ, data shard,
       PRNG key) into contiguous (C, ...) buffers.
    3. **solve**   — run the vmapped scanned SGD prox solver over C rows
       instead of N.
    4. **scatter** — write committed rows back into the (N, ...) state;
       invalid slots (capacity exceeds fired count) drop out via an
       out-of-bounds scatter index.

Under a ``clients`` device mesh the block runs per-device via
``shard_map`` with a local capacity ⌈C/devices⌉: gather/solve/scatter
never cross devices, so the only collective in the round remains the
consensus mean.  With ``capacity ≥ N`` no client is ever deferred and
the compacted round reproduces the dense path (bit-identical events,
fp32-tolerance state) — see tests/test_compact.py.
"""
from __future__ import annotations

import math
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.utils.pytree import tree_broadcast_like


class CompactPlan(NamedTuple):
    idx: jax.Array  # (C,) int32 — client row feeding each capacity slot
    valid: jax.Array  # (C,) bool — slot carries a genuinely fired client
    committed: jax.Array  # (N,) bool — fired AND within capacity
    num_deferred: jax.Array  # () int32 — fired beyond capacity


def capacity_for(n_clients: int, rate: float, slack: float,
                 capacity: int | None = None, *, n_shards: int = 1) -> int:
    """Static per-shard capacity C.

    ``capacity`` (if given) is the *global* solver-row budget; otherwise
    C_global = ⌈slack·L̄·N⌉.  Per shard the budget splits evenly and is
    clamped to [1, local client count].
    """
    total = capacity if capacity is not None else math.ceil(
        slack * rate * n_clients)
    n_local = n_clients // n_shards
    return max(1, min(math.ceil(total / n_shards), n_local))


def compact_plan(events: jax.Array, priority: jax.Array,
                 capacity: int) -> CompactPlan:
    """Assign fired clients to capacity slots, stalest-first.

    events: (N,) bool; priority: (N,) fp32 (trigger distances — larger
    means more urgent).  Deterministic: ties break toward the lower
    client index (stable argsort), so the plan is reproducible and
    vmap/shard_map friendly.
    """
    n = events.shape[0]
    key = jnp.where(events, -priority.astype(jnp.float32), jnp.inf)
    order = jnp.argsort(key).astype(jnp.int32)  # fired first, urgent first
    idx = order[:capacity]
    num_events = jnp.sum(events.astype(jnp.int32))
    valid = jnp.arange(capacity, dtype=jnp.int32) < num_events
    rank = jnp.zeros((n,), jnp.int32).at[order].set(
        jnp.arange(n, dtype=jnp.int32))
    committed = events & (rank < capacity)
    return CompactPlan(idx=idx, valid=valid, committed=committed,
                       num_deferred=jnp.maximum(num_events - capacity, 0))


def gather_rows(tree, idx):
    """Pull rows ``idx`` of every (N, ...) leaf into (C, ...) buffers."""
    return jax.tree.map(lambda x: x[idx], tree)


def scatter_rows(current, rows, idx, valid):
    """Write slot rows back into the (N, ...) state; invalid slots are
    routed to an out-of-bounds index and dropped by the scatter."""
    n = jax.tree.leaves(current)[0].shape[0]
    drop_idx = jnp.where(valid, idx, n)
    return jax.tree.map(
        lambda c, r: c.at[drop_idx].set(r.astype(c.dtype), mode="drop"),
        current, rows)


def make_compact_block(solver: Callable, epoch_fn: Callable, capacity: int,
                       *, is_admm: bool, warm_start: bool,
                       use_admm_kernel: bool = False) -> Callable:
    """Build the per-shard gather→solve→scatter block.

    solver(theta0, center, x, y, idx) -> (theta, mean_loss), vmapped
    over capacity slots; epoch_fn(key) -> (steps, batch) gather indices.
    The block is a pure function of one shard's rows, so the caller can
    run it directly (single device) or under ``shard_map`` (mesh).

    Returns block(events, distances, theta, lam, z_prev, omega, x, y,
    keys) -> (theta', lam', z_prev', committed, slot_losses, slot_valid).
    """

    def block(events, distances, theta, lam, z_prev, omega, x, y, keys):
        plan = compact_plan(events, distances, capacity)
        th_rows = gather_rows(theta, plan.idx)
        lam_rows = gather_rows(lam, plan.idx)

        if is_admm:
            if use_admm_kernel:
                from repro.kernels import ops
                lam_new_rows, center_rows = ops.admm_update(
                    th_rows, lam_rows, omega, with_z=False)
            else:
                from repro.core.engine import dual_ascent, prox_center
                lam_new_rows = dual_ascent(lam_rows, th_rows, omega)
                center_rows = prox_center(omega, lam_new_rows)
        else:
            lam_new_rows = lam_rows  # stays zero
            center_rows = tree_broadcast_like(omega, capacity)

        theta0_rows = (tree_broadcast_like(omega, capacity) if warm_start
                       else th_rows)
        idx_b = jax.vmap(epoch_fn)(keys[plan.idx])
        th_out_rows, losses = jax.vmap(solver)(
            theta0_rows, center_rows, x[plan.idx], y[plan.idx], idx_b)
        z_rows = (jax.tree.map(jnp.add, th_out_rows, lam_new_rows)
                  if is_admm else th_out_rows)

        theta_new = scatter_rows(theta, th_out_rows, plan.idx, plan.valid)
        z_new = scatter_rows(z_prev, z_rows, plan.idx, plan.valid)
        lam_new = (scatter_rows(lam, lam_new_rows, plan.idx, plan.valid)
                   if is_admm else lam)
        return theta_new, lam_new, z_new, plan.committed, losses, plan.valid

    return block


def shard_mapped_block(block: Callable, mesh, *,
                       axis: str = "clients") -> Callable:
    """Run the compact block per-device over the client mesh axis."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    c, r = P(axis), P()
    return shard_map(
        block, mesh=mesh,
        in_specs=(c, c, c, c, c, r, c, c, c),
        out_specs=(c, c, c, c, c, c),
        check_rep=False)
