from .sgd import SGDState, sgd_init, sgd_step  # noqa: F401
from .adam import AdamState, adam_init, adam_step  # noqa: F401
from .prox import prox_grad_fn, solve_prox  # noqa: F401
from .schedules import constant, cosine_decay, warmup_cosine  # noqa: F401
