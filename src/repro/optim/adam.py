"""AdamW — used by the large-architecture training steps (train_4k)."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    mu: object
    nu: object
    step: jax.Array


def adam_init(params) -> AdamState:
    # First/second moments in fp32 regardless of param dtype (mixed precision).
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamState(
        mu=jax.tree.map(f32, params),
        nu=jax.tree.map(f32, params),
        step=jnp.zeros((), jnp.int32),
    )


def adam_step(params, grads, state: AdamState, lr, b1=0.9, b2=0.95,
              eps=1e-8, weight_decay=0.0):
    lr_t = lr(state.step) if callable(lr) else lr
    step = state.step + 1
    mu = jax.tree.map(
        lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state.mu, grads
    )
    nu = jax.tree.map(
        lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
        state.nu,
        grads,
    )
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m, v):
        u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        if weight_decay:
            u = u + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr_t * u).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, AdamState(mu=mu, nu=nu, step=step)
