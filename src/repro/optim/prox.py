"""Inexact proximal local solver for the ADMM primal update (Eq. 2.3).

Solves   θ⁺ ≈ argmin_θ  f_i(θ) + (ρ/2) ‖θ − c‖²,   c = ω − λ⁺,
by E epochs of mini-batch SGD with momentum, warm-started at ω (the
paper's footnote 2: warm-starting at the server parameters is not
required by ADMM but empirically superior — and required to recover
FedAvg as a special case).

The paper only requires ε_k-stationarity with ε_k → 0 (Alg. 2); the
epoch/step budget plays the role of the accuracy sequence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .sgd import sgd_init, sgd_step


def prox_grad_fn(loss_fn, rho: float):
    """Gradient of the prox-augmented objective.

    loss_fn(params, batch) -> scalar. Returns grad_fn(params, center, batch).
    """
    gf = jax.grad(loss_fn)

    def grad_fn(params, center, batch):
        g = gf(params, batch)
        return jax.tree.map(
            lambda gl, p, c: gl + rho * (p - c), g, params, center
        )

    return grad_fn


def solve_prox(loss_fn, params0, center, batches, *, rho: float, lr: float,
               momentum: float = 0.9):
    """Run SGD over a fixed batch schedule.

    batches: pytree of arrays with leading axis = number of SGD steps
    (epochs already unrolled by the data pipeline); scanned, so the
    lowered program is compact regardless of the local step budget.
    Returns (params, mean loss over the schedule).
    """
    grad_loss = jax.value_and_grad(loss_fn)

    def body(carry, batch):
        params, opt = carry
        loss, g = grad_loss(params, batch)
        g = jax.tree.map(lambda gl, p, c: gl + rho * (p - c), g, params, center)
        params, opt = sgd_step(params, g, opt, lr, momentum)
        return (params, opt), loss

    (params, _), losses = jax.lax.scan(body, (params0, sgd_init(params0)), batches)
    return params, jnp.mean(losses)
