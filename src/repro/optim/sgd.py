"""SGD with (heavy-ball) momentum — the paper's local solver
(lr 0.01, momentum 0.9 in both experiment suites)."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class SGDState(NamedTuple):
    momentum: object  # pytree like params
    step: jax.Array


def sgd_init(params) -> SGDState:
    return SGDState(
        momentum=jax.tree.map(jnp.zeros_like, params),
        step=jnp.zeros((), jnp.int32),
    )


def sgd_step(params, grads, state: SGDState, lr, momentum: float = 0.9,
             weight_decay: float = 0.0, nesterov: bool = False):
    """One SGD+momentum update. ``lr`` may be a scalar or callable(step)."""
    lr_t = lr(state.step) if callable(lr) else lr
    if weight_decay:
        grads = jax.tree.map(lambda g, p: g + weight_decay * p, grads, params)
    buf = jax.tree.map(lambda m, g: momentum * m + g, state.momentum, grads)
    upd = (
        jax.tree.map(lambda g, m: g + momentum * m, grads, buf)
        if nesterov
        else buf
    )
    new_params = jax.tree.map(lambda p, u: p - lr_t * u, params, upd)
    return new_params, SGDState(momentum=buf, step=state.step + 1)
