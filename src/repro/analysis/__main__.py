"""``python -m repro.analysis`` → the tracecheck CLI.

Importing ``repro.analysis.cli`` sets ``XLA_FLAGS`` for the 2-device
matrix legs before jax loads (the package ``__init__`` is
deliberately jax-free so this ordering holds).
"""
import sys

from repro.analysis.cli import main

sys.exit(main())
