"""tracecheck — static-invariant analysis of the compiled round engine.

The FedBack efficiency story (one fused ADMM pass, donated (N, D)
state, a single consensus all-reduce, no host transfers, one trace per
run) is only real if the *compiled* program keeps those properties.
This package states them as data and checks them against every engine
configuration:

- ``artifacts``  — builds (jaxpr, compiled HLO) artifacts for each
  configuration in the {dense, compact} × {flat, tree} × {sync, async}
  × {uniform, ragged} × {1, 2}-device matrix;
- ``rules``      — the declarative rule engine (op-count budgets,
  donation audits, collective budgets, host-transfer bans);
- ``retrace``    — the retrace sentry and the ``jax.transfer_guard``
  execution harness;
- ``astlint``    — a repo-specific AST lint over the traced scopes of
  ``src/repro/{core,kernels,utils}``;
- ``cli``        — ``python -m repro.analysis --matrix fast|full``
  (console script ``tracecheck``) with a committed baseline gate.

This module stays import-light (no jax): the CLI must be able to set
``XLA_FLAGS`` for the 2-device configurations before jax loads.
"""

__all__ = ["artifacts", "astlint", "cli", "retrace", "rules"]
