"""Repo-specific AST lint: traced-scope footguns in core|kernels|utils.

Generic linters can't know which functions end up inside ``jax.jit``.
``TRACED_SCOPES`` records exactly that — per module, the functions
whose bodies execute under tracing (``"*"`` = every function in the
file).  Nested functions and lambdas inherit the traced property from
their enclosing scope.

Checks (all are silent performance or correctness bugs under jit):

- ``TC101`` ``np.*``/``numpy.*`` call — traces to a host constant at
  best, a ``TracerArrayConversionError`` at worst;
- ``TC102`` ``.item()`` — forces a device→host sync per call;
- ``TC103`` ``float(...)``/``int(...)``/``bool(...)`` applied directly
  to a ``jnp``/``jax`` expression — same sync, or a trace error;
- ``TC104`` ``if``/``while`` whose test contains a ``jnp``/``jax``
  call — python branching on a traced value.

A line ending in ``# tracecheck: ok`` (with an optional reason) is
exempt — the opt-out for deliberate trace-time constant computation
on *static* values (e.g. ``np.prod`` over a static shape tuple).

This module is import-light (stdlib only): the lint runs before jax
is ever imported, including under the CLI's env setup.
"""
from __future__ import annotations

import ast
import dataclasses
import pathlib
import re

PRAGMA_RE = re.compile(r"#\s*tracecheck:\s*ok\b")

#: Module (relative to ``src/repro``) → traced function names, or
#: ``"*"`` for every function in the file.  Files not listed are not
#: linted — add the entry when a new module grows jitted bodies.
TRACED_SCOPES: dict = {
    "core/engine.py": "*",
    "core/compress.py": "*",
    "core/trigger.py": "*",
    "core/controller.py": "*",
    "core/selection.py": "*",
    "core/fedback.py": (
        "_local_solve", "_masked_local_solve", "_epoch_indices",
        "_trigger", "_duals_and_centers", "dense_client_update",
        "ragged_dense_update", "compact_client_update", "round_body",
        "solver", "masked_solver", "eval_fn"),
    "core/compact.py": (
        "adaptive_limit", "compact_plan", "queue_update", "gather_rows",
        "scatter_rows", "solve_slots", "slice_rows", "block"),
    # Only the three jitted programs — the surrounding glue moves rows
    # with numpy on purpose (that IS the host backend).
    "core/hoststate.py": ("_plan", "_solve", "_aggregate", "_cat",
                          "solver", "masked_solver"),
    "kernels/admm_update.py": (
        "_kernel3", "_kernel2", "admm_update", "admm_update_sharded"),
    "kernels/trigger_norms.py": (
        "_kernel", "trigger_sq_norms", "trigger_sq_norms_sharded"),
    "kernels/flash_attention.py": ("_kernel",),
    "kernels/ssd_scan.py": ("_kernel",),
    "kernels/ops.py": (
        "trigger_sq_norms", "admm_update", "trigger_sq_norms_pytree"),
    "utils/pytree.py": "*",
    "utils/flatstate.py": (
        "flatten", "unflatten", "zeros_stacked", "flatten_stacked",
        "unflatten_stacked", "flat_loss"),
}

_NUMPY_ROOTS = ("np", "numpy")
_TRACED_ROOTS = ("jnp", "jax", "lax", "pl", "plgpu", "pltpu")


@dataclasses.dataclass(frozen=True)
class LintFinding:
    path: str
    line: int
    code: str
    message: str

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def _call_root(node: ast.AST) -> str | None:
    """Leftmost name of a call's function expression, if any."""
    f = node.func if isinstance(node, ast.Call) else node
    while isinstance(f, ast.Attribute):
        f = f.value
    if isinstance(f, ast.Name):
        return f.id
    return None


def _contains_traced_call(node: ast.AST) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call) and _call_root(sub) in _TRACED_ROOTS:
            return True
    return False


class _Linter(ast.NodeVisitor):
    def __init__(self, relpath: str, source: str, traced):
        self.relpath = relpath
        self.lines = source.splitlines()
        self.traced = traced  # "*" or set of function names
        self.depth = 0        # > 0 ⇔ inside a traced scope
        self.findings: list = []

    def _is_traced_def(self, name: str) -> bool:
        return self.traced == "*" or name in self.traced

    def _exempt(self, node) -> bool:
        line = self.lines[node.lineno - 1] if node.lineno <= len(
            self.lines) else ""
        return bool(PRAGMA_RE.search(line))

    def _add(self, node, code: str, message: str):
        if not self._exempt(node):
            self.findings.append(LintFinding(
                path=self.relpath, line=node.lineno, code=code,
                message=message))

    # --- scope tracking -------------------------------------------
    def _visit_func(self, node, name: str):
        enter = self.depth > 0 or self._is_traced_def(name)
        self.depth += 1 if enter else 0
        self.generic_visit(node)
        self.depth -= 1 if enter else 0

    def visit_FunctionDef(self, node):
        self._visit_func(node, node.name)

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Lambda(self, node):
        if self.depth:
            self._visit_func(node, "<lambda>")
        else:
            self.generic_visit(node)

    # --- checks ----------------------------------------------------
    def visit_Call(self, node):
        if self.depth > 0:
            root = _call_root(node)
            if root in _NUMPY_ROOTS:
                self._add(node, "TC101",
                          "numpy call inside a traced scope (host "
                          "constant or trace error)")
            if (isinstance(node.func, ast.Attribute)
                    and node.func.attr == "item" and not node.args):
                self._add(node, "TC102",
                          ".item() inside a traced scope forces a "
                          "device sync")
            if (isinstance(node.func, ast.Name)
                    and node.func.id in ("float", "int", "bool")
                    and node.args
                    and isinstance(node.args[0], ast.Call)
                    and _call_root(node.args[0]) in _TRACED_ROOTS):
                self._add(node, "TC103",
                          f"{node.func.id}() coercion of a traced "
                          f"expression (device sync / trace error)")
        self.generic_visit(node)

    def _check_branch(self, node):
        if self.depth > 0 and _contains_traced_call(node.test):
            self._add(node, "TC104",
                      "python branch on a traced value (use jnp.where "
                      "or lax.cond)")
        self.generic_visit(node)

    visit_If = _check_branch
    visit_While = _check_branch


def lint_source(source: str, relpath: str, scopes=None) -> list:
    """Lint one module's source; ``relpath`` keys into the registry."""
    scopes = TRACED_SCOPES if scopes is None else scopes
    traced = scopes.get(relpath)
    if traced is None:
        return []
    if traced != "*":
        traced = set(traced)
    linter = _Linter(relpath, source, traced)
    linter.visit(ast.parse(source))
    return sorted(linter.findings, key=lambda f: (f.path, f.line))


def lint_repo(src_root=None, scopes=None) -> list:
    """All findings over the registered traced scopes."""
    if src_root is None:
        src_root = pathlib.Path(__file__).resolve().parents[1]
    src_root = pathlib.Path(src_root)
    scopes = TRACED_SCOPES if scopes is None else scopes
    findings: list = []
    for relpath in sorted(scopes):
        path = src_root / relpath
        if not path.exists():
            findings.append(LintFinding(
                path=relpath, line=0, code="TC100",
                message="registered module missing on disk"))
            continue
        findings.extend(lint_source(path.read_text(), relpath,
                                    scopes=scopes))
    return findings
