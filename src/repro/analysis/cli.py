"""tracecheck CLI: compile the matrix, evaluate rules, gate on a
baseline.

``python -m repro.analysis --matrix fast|full [--json report.json]
[--baseline benchmarks/baselines/ANALYSIS.json]`` — also installed as
the ``tracecheck`` console script.

The report is machine-readable and deterministic (no wall-clock
numbers), so the committed baseline compare is exact: a rule that
regresses from pass to fail, a changed Pallas-call count or an
all-reduce byte growth over the drift allowance fails the gate —
mirror of ``benchmarks/compare.py`` for structural facts instead of
timings.
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def _ensure_host_devices(n: int = 2) -> None:
    """Force ≥ n host CPU devices — must run before jax is imported
    (the 2-device matrix legs need a real mesh even on CPU)."""
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={n}").strip()


_ensure_host_devices()

from repro.analysis import astlint  # noqa: E402
from repro.analysis.artifacts import MATRICES, build_artifact  # noqa: E402
from repro.analysis.retrace import (  # noqa: E402
    run_serve_trace_check,
    run_single_trace_check,
    run_transfer_guard_check,
)
from repro.analysis.rules import evaluate  # noqa: E402

#: All-reduce byte drift tolerated against the baseline before the
#: gate trips (absolute bytes/round, covers benign scalar-metric churn).
ALLREDUCE_DRIFT_BYTES = 64.0


def _env_fingerprint() -> str:
    import platform

    import jax
    return (f"jax={jax.__version__};backend={jax.default_backend()};"
            f"machine={platform.machine()}")


def run_matrix(matrix_name: str, *, execute: bool = True,
               lint: bool = True, log=print) -> dict:
    """Evaluate every rule over the configuration matrix → report."""
    import jax

    report: dict = {
        "_env": _env_fingerprint(),
        "_matrix": matrix_name,
        "lint": None,
        "exec": {},
        "configs": {},
    }
    if lint:
        findings = astlint.lint_repo()
        report["lint"] = {
            "status": "fail" if findings else "pass",
            "findings": [f.to_json() for f in findings],
        }
        log(f"astlint: {report['lint']['status']} "
            f"({len(findings)} findings)")
    for key in MATRICES[matrix_name]:
        if key.devices > jax.device_count():
            report["configs"][key.name] = {
                "_status": "skip",
                "_reason": f"needs {key.devices} devices"}
            log(f"{key.name}: SKIP (needs {key.devices} devices)")
            continue
        art = build_artifact(key)
        results = evaluate(art)
        report["configs"][key.name] = {
            r.rule: r.to_json() for r in results}
        bad = [r for r in results if r.status == "fail"]
        log(f"{key.name}: {'FAIL' if bad else 'ok'} "
            f"({sum(r.status == 'pass' for r in results)} pass, "
            f"{sum(r.status == 'skip' for r in results)} skip)")
        for r in bad:
            for v in r.violations:
                log(f"  {r.rule}: {v}")
    if execute:
        for check in (run_single_trace_check, run_serve_trace_check,
                      run_transfer_guard_check):
            res = check()
            report["exec"][res.rule] = res.to_json()
            log(f"exec {res.rule}: {res.status}")
    return report


def report_failures(report: dict) -> list:
    """Flat list of every failing rule/lint/exec entry in a report."""
    failures = []
    lint = report.get("lint")
    if lint and lint["status"] == "fail":
        failures.append(f"astlint: {len(lint['findings'])} findings")
    for name, res in report.get("exec", {}).items():
        if res["status"] == "fail":
            failures.append(f"exec/{name}: {res['violations']}")
    for cfg, rules in report.get("configs", {}).items():
        for rule, res in rules.items():
            if rule.startswith("_"):
                continue
            if res["status"] == "fail":
                failures.append(f"{cfg}/{rule}: {res['violations']}")
    return failures


def compare_to_baseline(base: dict, fresh: dict) -> list:
    """Regressions of ``fresh`` against a committed baseline report.

    Gates on structure, not timings: status regressions (pass →
    fail/missing), Pallas-call count changes, and all-reduce byte
    growth beyond the drift allowance.  New configurations and rules
    are allowed (they gate from the next baseline update on).
    """
    regressions = []
    if base.get("_env") != fresh.get("_env"):
        # Structural facts should survive an env bump, so keep
        # comparing — but record the mismatch for the log.
        regressions_note = (f"env drift: baseline {base.get('_env')} "
                            f"vs {fresh.get('_env')}")
    else:
        regressions_note = None
    for cfg, base_rules in base.get("configs", {}).items():
        if base_rules.get("_status") == "skip":
            continue  # the baseline run never evaluated it
        fresh_rules = fresh.get("configs", {}).get(cfg)
        if fresh_rules is None:
            regressions.append(f"{cfg}: configuration vanished from "
                               f"the matrix")
            continue
        for rule, bres in base_rules.items():
            if rule.startswith("_"):
                continue
            fres = fresh_rules.get(rule)
            if fres is None:
                regressions.append(f"{cfg}/{rule}: rule vanished")
                continue
            if bres["status"] == "pass" and fres["status"] != "pass":
                regressions.append(
                    f"{cfg}/{rule}: pass → {fres['status']} "
                    f"{fres.get('violations')}")
                continue
            bm, fm = bres.get("metrics", {}), fres.get("metrics", {})
            if ("pallas_call" in bm
                    and fm.get("pallas_call") != bm["pallas_call"]):
                regressions.append(
                    f"{cfg}/{rule}: pallas_call "
                    f"{bm['pallas_call']} → {fm.get('pallas_call')}")
            bar = bm.get("all-reduce", {}).get("bytes")
            far = fm.get("all-reduce", {}).get("bytes")
            if (bar is not None and far is not None
                    and far > bar + ALLREDUCE_DRIFT_BYTES):
                regressions.append(
                    f"{cfg}/{rule}: all-reduce bytes {bar} → {far} "
                    f"(+{ALLREDUCE_DRIFT_BYTES:.0f} allowed)")
    if regressions and regressions_note:
        regressions.append(regressions_note)
    return regressions


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="tracecheck",
        description="Static-invariant analysis of the compiled round "
                    "engine (see docs/analysis.md)")
    ap.add_argument("--matrix", choices=sorted(MATRICES),
                    default="fast")
    ap.add_argument("--json", metavar="PATH",
                    help="write the machine-readable report here")
    ap.add_argument("--baseline", metavar="PATH",
                    help="committed baseline report to gate against")
    ap.add_argument("--no-exec", action="store_true",
                    help="skip the retrace/transfer-guard runs")
    ap.add_argument("--no-lint", action="store_true",
                    help="skip the AST lint")
    args = ap.parse_args(argv)

    report = run_matrix(args.matrix, execute=not args.no_exec,
                        lint=not args.no_lint)
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(report, fh, indent=2, sort_keys=True)
            fh.write("\n")
        print(f"wrote {args.json}")

    failures = report_failures(report)
    for f in failures:
        print(f"FAIL {f}")
    if args.baseline:
        with open(args.baseline) as fh:
            base = json.load(fh)
        regressions = compare_to_baseline(base, report)
        for r in regressions:
            print(f"REGRESSION {r}")
        failures.extend(regressions)
    print("tracecheck:", "FAIL" if failures else "ok",
          f"({len(report['configs'])} configurations)")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
