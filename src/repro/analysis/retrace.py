"""Dynamic invariants: one trace per run, no host transfers at run
time.

The static rules see one trace by construction; these harnesses run
the engine and check the properties that only show up under
execution:

- :class:`TraceSentry` counts how many times the round *body* is
  traced.  PR 5's one-compile grid property says controller-gain
  overrides (``ctrl_arg``) vary as runtime values, so stepping the
  round across rounds **and** across override values must trace
  exactly once.
- :func:`run_transfer_guard_check` replays rounds under
  ``jax.transfer_guard("disallow")`` — any implicit host↔device
  transfer in the steady state raises.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.analysis.artifacts import ConfigKey, build_problem, build_config
from repro.analysis.rules import RuleResult, _result
from repro.core.fedback import init_state, make_round_fn


class TraceSentry:
    """Counts traces of the round body via the ``body_transform`` hook.

    ``make_round_fn(..., body_transform=sentry.transform)`` wraps the
    round function; the wrapper body executes once per trace (jit
    caches thereafter), so ``sentry.traces`` is the trace count.
    """

    def __init__(self):
        self.traces = 0

    def transform(self, body):
        def counted(*args):
            self.traces += 1
            return body(*args)
        return counted


def run_single_trace_check(key: ConfigKey | None = None, *, n: int = 16,
                           n_points: int = 8, dim: int = 8,
                           rounds: int = 3,
                           rates: tuple = (0.3, 0.7, 0.5),
                           shape_mutation: bool = False) -> RuleResult:
    """Step ``rounds × len(rates)`` rounds varying the controller-gain
    overrides; the round must trace exactly once.

    ``shape_mutation=True`` is the seeded violation for the
    self-tests: it feeds per-client (N,) target rates on alternating
    calls, changing the override avals and forcing a retrace.
    """
    key = key or ConfigKey("dense", "flat", "sync", "uniform", 1)
    data, params0, loss_fn, spec, ragged = build_problem(
        key, n=n, n_points=n_points, dim=dim)
    cfg = build_config(key, n=n)
    sentry = TraceSentry()
    round_fn = make_round_fn(cfg, loss_fn, data, jit=True, donate=False,
                             ctrl_arg=True, spec=spec, ragged=ragged,
                             body_transform=sentry.transform)
    state = init_state(cfg, params0, spec=spec)
    calls = 0
    for i, rate in enumerate(rates):
        if shape_mutation and i % 2:
            tgt = jnp.full((n,), rate, jnp.float32)  # (N,): new aval
        else:
            tgt = jnp.float32(rate)
        overrides = {"K": jnp.float32(0.2), "target_rate": tgt}
        for _ in range(rounds):
            state, _metrics = round_fn(state, overrides)
            calls += 1
    jax.block_until_ready(state)
    violations = [] if sentry.traces == 1 else [
        f"{key.name}: {sentry.traces} traces over {calls} rounds "
        f"(override values and state must not retrace)"]
    return _result("single-trace", violations,
                   {"traces": sentry.traces, "rounds": calls})


def run_serve_trace_check(key: ConfigKey | None = None, *, n: int = 16,
                          n_points: int = 8, dim: int = 8,
                          ticks: int = 6,
                          shape_mutation: bool = False) -> RuleResult:
    """Drain a varying arrival trace through the serve step; the round
    must trace exactly once — arrival masks are runtime values, so the
    whole trace runs through one compiled admission program
    (``core.schedule`` relies on this for sustained commits/sec).

    ``shape_mutation=True`` is the seeded violation: alternating ticks
    feed the arrival mask as int32 instead of bool, changing the aval
    and forcing a retrace.
    """
    key = key or ConfigKey("compact", "flat", "serve", "uniform", 1)
    data, params0, loss_fn, spec, ragged = build_problem(
        key, n=n, n_points=n_points, dim=dim)
    cfg = build_config(key, n=n)
    sentry = TraceSentry()
    round_fn = make_round_fn(cfg, loss_fn, data, jit=True, donate=False,
                             arrivals_arg=True, spec=spec, ragged=ragged,
                             body_transform=sentry.transform)
    state = init_state(cfg, params0, spec=spec)
    rng = jax.random.PRNGKey(17)
    calls = 0
    for t in range(ticks):
        rng, sub = jax.random.split(rng)
        arrivals = jax.random.bernoulli(sub, 0.5, (n,))
        if shape_mutation and t % 2:
            arrivals = arrivals.astype(jnp.int32)  # new aval
        state, _metrics = round_fn(state, arrivals)
        calls += 1
    jax.block_until_ready(state)
    violations = [] if sentry.traces == 1 else [
        f"{key.name}: {sentry.traces} traces over {calls} ticks "
        f"(arrival masks are runtime values and must not retrace)"]
    return _result("serve-single-trace", violations,
                   {"traces": sentry.traces, "ticks": calls})


def run_transfer_guard_check(key: ConfigKey | None = None, *,
                             n: int = 16, n_points: int = 8,
                             dim: int = 8,
                             rounds: int = 3) -> RuleResult:
    """Steady-state rounds under ``jax.transfer_guard("disallow")``.

    The first call (compile + constant staging) runs outside the
    guard; every subsequent round must touch the host zero times.
    """
    key = key or ConfigKey("dense", "flat", "sync", "uniform", 1)
    data, params0, loss_fn, spec, ragged = build_problem(
        key, n=n, n_points=n_points, dim=dim)
    cfg = build_config(key, n=n)
    round_fn = make_round_fn(cfg, loss_fn, data, jit=True, donate=False,
                             spec=spec, ragged=ragged)
    state = init_state(cfg, params0, spec=spec)
    state, _ = round_fn(state)  # warm-up: compile outside the guard
    jax.block_until_ready(state)
    violations = []
    try:
        with jax.transfer_guard("disallow"):
            for _ in range(rounds):
                state, _metrics = round_fn(state)
            jax.block_until_ready(state)
    except Exception as e:  # noqa: BLE001 — the guard raises RuntimeError
        violations.append(f"{key.name}: transfer under guard: {e}")
    return _result("transfer-guard", violations, {"rounds": rounds})
