"""The tracecheck rule engine: each invariant is data, not prose.

A rule is a frozen dataclass whose fields *are* the budget (expected
op counts, byte allowances, banned primitive lists).  ``check`` maps
an :class:`~repro.analysis.artifacts.EngineArtifact` to a
:class:`RuleResult`; ``applies`` gates rules that only make sense for
some configurations (e.g. collective budgets need ≥ 2 devices).

The default ``RULES`` tuple encodes the engine's performance
contract:

- ``fused-admm-pass``     exactly two Pallas calls per flat round and
                          the right two *by kernel name*: trigger
                          norms plus the fused gather→ADMM→scatter
                          megakernel on the compacted path (the
                          standalone ``admm_update`` pass must be
                          gone) or the ``admm_update`` pass on the
                          dense path; zero kernels on the tree layout;
- ``no-full-width-sweeps`` at most one surviving top-level (N, D)
                          elementwise sweep on the dense flat round
                          (the z assembly), zero on the compacted one;
- ``no-f64-ops``          no float64/complex128 anywhere (jaxpr or
                          compiled module);
- ``donated-state-aliases`` every θ/λ/z_prev/DeferQueue/InFlight/ω
                          buffer aliases an input in the compiled
                          module's ``input_output_alias`` map;
- ``collective-budget``   per-round all-reduce link bytes within the
                          consensus + RNG + scalar allowance, and no
                          all-gather bigger than a control vector
                          (the replicated pool must never be gathered);
- ``host-transfer-budget`` no ``device_put``/callback primitives staged
                          in the round jaxpr, no infeed/outfeed/
                          send/recv or python-callback custom-calls in
                          the HLO — on *any* backend.  Host-backend
                          legs additionally price their glue-layer row
                          streaming against the planned-byte model:
                          the per-round H2D+D2H row stream must fit
                          8·C·D·4 B (tiles of the (C, D) working set,
                          never the (N, D) state).

Adding a rule = adding a dataclass here and appending an instance to
``RULES`` (see docs/analysis.md).
"""
from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Any

import jax

from repro.core.compress import WIRE_BYTES, block_layout
from repro.core.state import CLIENT_STACKED_FIELDS
from repro.utils import hlo as H


@dataclasses.dataclass
class RuleResult:
    rule: str
    status: str                # "pass" | "fail" | "skip"
    violations: list
    metrics: dict

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def _result(name: str, violations: list, metrics: dict) -> RuleResult:
    return RuleResult(rule=name, status="fail" if violations else "pass",
                      violations=violations, metrics=metrics)


def _skip(name: str, why: str) -> RuleResult:
    return RuleResult(rule=name, status="skip", violations=[],
                      metrics={"skipped": why})


@dataclasses.dataclass(frozen=True)
class FusedPassBudget:
    """Pallas-call count AND composition: two fused passes per flat
    round, and the *right* two.

    Policy (not read from the config — a mis-flagged config must turn
    this rule red, not adapt it): a flat ADMM round launches the
    trigger-norm kernel plus exactly ONE state kernel.  On the
    compacted path that state kernel is the fused gather→ADMM→scatter
    megakernel (``_fused_gss3``/``_fused_gss2``) and the separate
    ``admm_update`` pass (``_kernel3``/``_kernel2``) must be gone; on
    the dense path it is the ``admm_update`` pass.  The tree layout
    launches no kernels.  Kernel identity comes from the pallas_call
    equations' ``name_and_src_info`` (exact match on the kernel body's
    function name).
    """

    name: str = "fused-admm-pass"
    expected_flat: int = 2   # state kernel + trigger_sq_norms
    expected_tree: int = 0
    fused_kernels: tuple = ("_fused_gss3", "_fused_gss2")
    admm_kernels: tuple = ("_kernel3", "_kernel2")

    def applies(self, art) -> bool:
        return True

    def check(self, art) -> RuleResult:
        from repro.core.fedback import ADMM_FAMILY

        counts = H.jaxpr_eqn_counts(art.jaxpr)
        got = counts.get("pallas_call", 0)
        want = (self.expected_flat if art.kernels_on
                else self.expected_tree)
        violations = [] if got == want else [
            f"{art.key.name}: {got} pallas_call eqns, expected {want}"]
        names = H.jaxpr_pallas_kernel_names(art.jaxpr)
        fused_got = sum(names.get(k, 0) for k in self.fused_kernels)
        admm_got = sum(names.get(k, 0) for k in self.admm_kernels)
        is_admm = art.cfg.algorithm in ADMM_FAMILY
        fused_want = 1 if (art.kernels_on and is_admm
                           and art.cfg.compact) else 0
        admm_want = 1 if (art.kernels_on and is_admm
                          and not art.cfg.compact) else 0
        if fused_got != fused_want:
            violations.append(
                f"{art.key.name}: {fused_got} fused gather-solve-"
                f"scatter kernel(s), policy expects {fused_want}")
        if admm_got != admm_want:
            violations.append(
                f"{art.key.name}: {admm_got} standalone admm_update "
                f"kernel(s), policy expects {admm_want}")
        return _result(self.name, violations,
                       {"pallas_call": got, "expected": want,
                        "kernel_names": dict(sorted(names.items())),
                        "fused": fused_got, "admm": admm_got})


@dataclasses.dataclass(frozen=True)
class FullWidthSweepBudget:
    """Surviving top-level (N, D) elementwise sweeps outside kernels.

    The dense flat round keeps exactly one (the z = θ + λ assembly);
    the compacted round runs its algebra at capacity width C < N and
    must keep zero.  The EF-compressed consensus legitimately adds
    four (the δ = z − ω + e carry-in and the residual/wire-error
    fold-back are (N, D) algebra by design — every client carries a
    residual row).  Only meaningful where the full (N, D) shape is
    visible at the jaxpr top level: flat layout, single device.
    """

    name: str = "no-full-width-sweeps"
    dense_budget: int = 1
    compact_budget: int = 0
    host_budget: int = 0  # the streamed solve program is (C, D) only
    ef_extra: int = 4  # δ carry-in (sub+add) + residual (sub) + fold (add)
    prims: tuple = ("add", "sub", "mul")

    def applies(self, art) -> bool:
        if getattr(art.key, "backend", "device") == "host":
            # The host leg's jaxpr is the streamed solve program — a
            # single (N, D) op in it means the full state leaked onto
            # the device, so the rule applies with a zero budget.
            return art.world_size == 1
        return art.kernels_on and art.world_size == 1

    def check(self, art) -> RuleResult:
        if not self.applies(art):
            return _skip(self.name, "flat single-device only")
        shapes = H.toplevel_elementwise_shapes(art.jaxpr,
                                               prims=self.prims)
        full = [s for s in shapes if tuple(s) == (art.n, art.dim)]
        if getattr(art.key, "backend", "device") == "host":
            budget = self.host_budget  # EF algebra runs server-side
        else:
            budget = (self.compact_budget if art.cfg.compact
                      else self.dense_budget)
            if getattr(art.cfg, "consensus_compress", "none") != "none":
                budget += self.ef_extra
        violations = [] if len(full) <= budget else [
            f"{art.key.name}: {len(full)} top-level (N={art.n}, "
            f"D={art.dim}) elementwise sweeps, budget {budget}"]
        return _result(self.name, violations,
                       {"full_width_sweeps": len(full),
                        "budget": budget})


@dataclasses.dataclass(frozen=True)
class DtypeBan:
    """No f64/c128 anywhere — the engine is fp32 end to end."""

    name: str = "no-f64-ops"
    banned_jaxpr: tuple = ("float64", "complex128")
    banned_hlo: tuple = ("f64", "c128")

    def applies(self, art) -> bool:
        return True

    def check(self, art) -> RuleResult:
        violations = []
        seen = H.jaxpr_dtypes(art.jaxpr)
        for dt in self.banned_jaxpr:
            if dt in seen:
                violations.append(
                    f"{art.key.name}: {dt} values in the round jaxpr")
        hlo_refs = 0
        if art.compiled_text is not None:
            for dt in self.banned_hlo:
                refs = H.count_dtype_refs(art.compiled_text, dt)
                hlo_refs += refs
                if refs:
                    violations.append(
                        f"{art.key.name}: {refs} {dt} shapes in the "
                        f"compiled module")
        return _result(self.name, violations,
                       {"jaxpr_dtypes": sorted(seen),
                        "banned_hlo_refs": hlo_refs})


def required_alias_avals(art) -> Counter:
    """(hlo_dtype, per-device shape) multiset of state buffers that
    must be donated: θ/λ/z_prev/DeferQueue/InFlight plus ω.

    Client-stacked leading axes are divided by the world size — the
    compiled module's parameter shapes are per-device post-SPMD.
    """
    required: Counter = Counter()
    fields = set(CLIENT_STACKED_FIELDS) | {"omega"}
    for fname in fields:
        val = getattr(art.state, fname, None)
        if val is None:
            continue
        stacked = fname in CLIENT_STACKED_FIELDS
        for leaf in jax.tree.leaves(val):
            shape = tuple(int(d) for d in leaf.shape)
            if (stacked and art.world_size > 1 and shape
                    and shape[0] % art.world_size == 0):
                shape = ((shape[0] // art.world_size,) + shape[1:])
            dt = H.NUMPY_TO_HLO_DTYPE.get(str(leaf.dtype), str(leaf.dtype))
            required[(dt, shape)] += 1
    return required


@dataclasses.dataclass(frozen=True)
class DonationAudit:
    """Every live state buffer must alias an input in the compiled
    module — a dropped donation doubles the (N, D) working set.

    Device legs only.  The host backend's solve program takes the
    working set as C/t-row *tiles* and concatenates them inside the
    program, so no parameter shares a shape with any output — XLA
    aliasing is whole-buffer, and donating the tiles frees them early
    instead of aliasing them.  The (N, D) matrices it protects on the
    device legs never enter a program on the host legs at all.
    """

    name: str = "donated-state-aliases"

    def applies(self, art) -> bool:
        return (art.compiled_text is not None
                and getattr(art.key, "backend", "device") == "device")

    def check(self, art) -> RuleResult:
        if not self.applies(art):
            if getattr(art.key, "backend", "device") == "host":
                return _skip(self.name, "host backend: streamed tiles "
                             "cannot alias full-width outputs")
            return _skip(self.name, "no compiled module")
        text = art.compiled_text
        aliases = H.parse_input_output_aliases(text)
        params = dict(enumerate(H.entry_parameters(text)))
        aliased: Counter = Counter()
        for a in aliases:
            p = params.get(a["param_number"])
            if p is not None and not a["param_index"]:
                aliased[(p[1], p[2])] += 1
        required = required_alias_avals(art)
        violations = []
        for aval, need in sorted(required.items(), key=str):
            have = aliased.get(aval, 0)
            if have < need:
                dt, shape = aval
                violations.append(
                    f"{art.key.name}: {need - have} un-donated "
                    f"{dt}{list(shape)} state buffer(s) "
                    f"(need {need} aliased, found {have})")
        return _result(self.name, violations,
                       {"aliased_params": len(aliases),
                        "required_buffers": sum(required.values())})


@dataclasses.dataclass(frozen=True)
class CollectiveBudget:
    """Per-round collective bytes against the roofline consensus term.

    The round's one genuine collective is the consensus aggregation —
    a (D,) all-reduce — plus the PRNG-key fold and a handful of scalar
    metric reductions.  Ring model: 2 · bytes · (n−1)/n per
    all-reduce.  All-gathers are capped at a control-vector size: the
    replicated pool and the (N, D) state must never be gathered.

    The budget is **dtype-aware**: under ``consensus_compress`` the
    consensus term is priced at the wire dtype (an s8 (D,) ring term
    for int8 — NOT fp32 — plus the tiny (nb,) fp32 shared-scale MAX
    all-reduce), and the bf16 leg moves its payload over the u16
    all-gather instead, so the all-reduce budget drops the consensus
    term entirely and the all-gather cap grows by exactly that wire.
    A compressed round that still emits an fp32-sized collective blows
    the (much tighter) budget and turns the rule red.
    """

    name: str = "collective-budget"
    scalar_allowance_bytes: float = 256.0
    allgather_max_bytes: float = 512.0
    safety: float = 1.5

    def applies(self, art) -> bool:
        return art.world_size > 1 and art.compiled_text is not None

    @staticmethod
    def consensus_term_bytes(art) -> float:
        """Modeled consensus z-term on the all-reduce/all-gather wire:
        2 · (ws−1)/ws · D · wire_bytes.  The number ANALYSIS.json
        carries for the compressed-vs-fp32 byte-ratio acceptance."""
        ws = art.world_size
        frac = (ws - 1) / ws
        mode = getattr(art.cfg, "consensus_compress", "none")
        return 2.0 * frac * art.dim * WIRE_BYTES[mode]

    def budget_bytes(self, art) -> float:
        ws = art.world_size
        frac = (ws - 1) / ws
        mode = getattr(art.cfg, "consensus_compress", "none")
        if mode == "bf16":
            # The payload rides the u16 all-gather (see allgather_cap);
            # no consensus all-reduce survives in the budget.
            consensus = 0.0
        elif mode == "int8":
            nb, b = block_layout(art.dim, art.cfg.compress_block)
            # s8 codes all-reduce (zero-padded to nb·B) + fp32 scales.
            consensus = 2.0 * frac * (nb * b * 1 + nb * 4)
        else:
            consensus = 2.0 * frac * art.dim * 4    # (D,) f32 mean
        rng = 2.0 * frac * (2 * art.n * 4)          # u32 key fold
        # The dense ragged round used to add 2·N·D·4 B here: its
        # bucket gathers crossed shard boundaries and SPMD paid an
        # all-reduce per round.  Shard-local member tables (PR 7)
        # keep every bucket gather on its own device, so the budget
        # is back to the consensus + RNG terms for every path.
        return (self.safety * (consensus + rng)
                + self.scalar_allowance_bytes)

    def allgather_cap(self, art) -> float:
        mode = getattr(art.cfg, "consensus_compress", "none")
        if mode == "bf16":
            # The (ws, D) u16 gathered wire of the bf16 consensus.
            return (self.allgather_max_bytes
                    + art.world_size * art.dim * 2)
        return self.allgather_max_bytes

    def check(self, art) -> RuleResult:
        if not self.applies(art):
            return _skip(self.name, "single device")
        inv = H.collective_inventory(art.compiled_text,
                                     world_size=art.world_size)
        ar = inv.get("all-reduce", {"bytes": 0.0, "count": 0})
        ag = inv.get("all-gather", {"raw_bytes": 0.0, "count": 0})
        budget = self.budget_bytes(art)
        ag_cap = self.allgather_cap(art)
        violations = []
        if ar["bytes"] > budget:
            violations.append(
                f"{art.key.name}: {ar['bytes']:.0f} all-reduce link "
                f"bytes/round exceeds budget {budget:.0f}")
        if ag.get("raw_bytes", 0.0) > ag_cap:
            violations.append(
                f"{art.key.name}: {ag['raw_bytes']:.0f} all-gather "
                f"bytes — the replicated pool/state must not be "
                f"gathered (max {ag_cap:.0f})")
        metrics = {k: {"count": v["count"], "bytes": round(v["bytes"], 1)}
                   for k, v in sorted(inv.items())}
        metrics["budget_bytes"] = round(budget, 1)
        metrics["compress"] = getattr(art.cfg, "consensus_compress",
                                      "none")
        metrics["consensus_term_bytes"] = round(
            self.consensus_term_bytes(art), 1)
        return _result(self.name, violations, metrics)


@dataclasses.dataclass(frozen=True)
class HostTransferBudget:
    """Transfers are either *staged* (inside a traced program) or
    *planned* (the host backend's glue-layer row streaming).

    Staged transfers are banned everywhere: no transfer or callback
    primitives in the jaxpr, no host-boundary ops in the compiled
    module.  On device-backend legs that is the whole rule — the
    round must stay on device (the old blanket ``no-host-transfers``
    contract).

    Host-backend legs move rows by design, but only through the glue
    layer *between* the jitted programs, and only working-set-sized
    tiles: the planned per-round row stream (θ/λ up, θ'/λ⁺/z down —
    5·C·D·4 B) must fit the 8·C·D·4 B budget.  A full-width (N, D)
    transfer cannot fit the budget and cannot hide in a program
    either — a ``device_put`` staged inside the solve jaxpr turns the
    rule red just like on the device legs.
    """

    name: str = "host-transfer-budget"
    banned_prims: tuple = ("device_put", "io_callback", "pure_callback",
                           "debug_callback", "callback", "infeed",
                           "outfeed")
    row_budget_factor: int = 8  # × C·D·4 B per round

    def applies(self, art) -> bool:
        return True

    def check(self, art) -> RuleResult:
        counts = H.jaxpr_eqn_counts(art.jaxpr)
        violations = []
        staged = {}
        for prim in self.banned_prims:
            c = counts.get(prim, 0)
            if c:
                staged[prim] = c
                violations.append(
                    f"{art.key.name}: {c} {prim} eqn(s) in the round "
                    f"jaxpr")
        hlo_ops = 0
        if art.compiled_text is not None:
            hlo_ops = H.count_host_transfer_ops(art.compiled_text)
            if hlo_ops:
                violations.append(
                    f"{art.key.name}: {hlo_ops} host-boundary op(s) in "
                    f"the compiled module")
        metrics: dict = {"jaxpr": staged, "hlo_host_ops": hlo_ops,
                         "backend": getattr(art.key, "backend", "device")}
        if (getattr(art.key, "backend", "device") == "host"
                and art.round_fn is not None):
            planned = art.round_fn.planned_bytes
            streamed = (planned["row_stream_h2d"]
                        + planned["row_stream_d2h"])
            budget = (self.row_budget_factor
                      * art.capacity * art.dim * 4)
            metrics.update(
                planned_row_stream_bytes=streamed,
                row_stream_budget=budget,
                server_pass_bytes=(planned["server_pass_h2d"]
                                   + planned["server_pass_d2h"]))
            if streamed > budget:
                violations.append(
                    f"{art.key.name}: {streamed} planned row-stream "
                    f"bytes/round exceeds the {budget} B budget "
                    f"({self.row_budget_factor}·C·D·4)")
        return _result(self.name, violations, metrics)


#: The engine's performance contract, in evaluation order.
RULES = (
    FusedPassBudget(),
    FullWidthSweepBudget(),
    DtypeBan(),
    DonationAudit(),
    CollectiveBudget(),
    HostTransferBudget(),
)


def evaluate(art, rules=RULES) -> list:
    """All rule results for one artifact (skips included)."""
    return [rule.check(art) for rule in rules]
