"""Engine artifacts: one traced/compiled round per configuration.

An :class:`EngineArtifact` bundles everything the rule engine looks
at — the round's jaxpr, the compiled (post-SPMD) HLO text, the state
it was traced with and the static problem facts (N, D, capacity,
world size).  :func:`build_artifact` is the single entry point; the
matrices (``FAST_MATRIX``/``FULL_MATRIX``) enumerate the supported
engine configurations.

The toy problem is deliberately small but *not* degenerate: N and D
are large enough that a full-width (N, D) buffer is clearly bigger
than every legitimate control collective, so byte budgets separate
signal from noise.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Callable

import jax
import numpy as np

from repro.core.compact import capacity_bounds
from repro.core.fedback import FLConfig, init_state, make_round_fn
from repro.data.synthetic import make_least_squares
from repro.utils.flatstate import make_flat_spec
from repro.utils.hlo import cost_analysis_dict
from repro.utils.ragged import pool_data

#: Default toy-problem dimensions (see module docstring).
DEFAULT_N = 32
DEFAULT_POINTS = 8
DEFAULT_DIM = 16


@dataclasses.dataclass(frozen=True, order=True)
class ConfigKey:
    """One point of the engine-configuration matrix."""

    path: str     # "dense" | "compact"
    layout: str   # "flat" | "tree"
    timing: str   # "sync" | "async" | "serve"
    shards: str   # "uniform" | "ragged"
    devices: int = 1
    compress: str = "none"  # consensus wire ("none" | "bf16" | "int8")
    backend: str = "device"  # client-state residency ("device" | "host")

    @property
    def name(self) -> str:
        base = (f"{self.path}-{self.layout}-{self.timing}-"
                f"{self.shards}-{self.devices}d")
        # Suffix only when compressing / host-offloaded, so the
        # pre-existing baseline keys (all device, uncompressed) stay
        # stable.
        if self.compress != "none":
            base = f"{base}-{self.compress}"
        return base if self.backend == "device" else f"{base}-host"

    @property
    def kernels_on(self) -> bool:
        """Policy: flat-layout *device* rounds run the fused Pallas
        kernels.  The host backend's streamed solve program runs at
        working-set width on whatever device serves it — the (N, D)
        kernels never see the full state, so the kernel policy does
        not apply."""
        return self.layout == "flat" and self.backend == "device"


def _matrix(devices=(1, 2)) -> tuple:
    return tuple(
        ConfigKey(path, layout, timing, shards, dev)
        for path, layout, timing, shards, dev in itertools.product(
            ("dense", "compact"), ("flat", "tree"),
            ("sync", "async", "serve"), ("uniform", "ragged"), devices))


def _compress_matrix() -> tuple:
    """Compressed-consensus legs (flat layout only — the EF residual
    is an (N, D) matrix over the flat state)."""
    legs = []
    for mode in ("bf16", "int8"):
        for path in ("dense", "compact"):
            for dev in (1, 2):
                legs.append(
                    ConfigKey(path, "flat", "sync", "uniform", dev, mode))
    # The stale-tolerant and serve paths share the same aggregation
    # splice; one representative leg each keeps nightly wall-clock sane.
    legs.append(ConfigKey("compact", "flat", "async", "ragged", 1, "int8"))
    legs.append(ConfigKey("compact", "flat", "async", "ragged", 2, "int8"))
    legs.append(ConfigKey("compact", "flat", "serve", "uniform", 1, "int8"))
    return tuple(legs)


def _host_matrix() -> tuple:
    """Host-offloaded client-state legs (compact flat single-device
    only — the streamed working set reuses the CompactPlan slots, and
    the host buffers live on this process's RAM)."""
    return (
        ConfigKey("compact", "flat", "sync", "uniform", 1, "none", "host"),
        ConfigKey("compact", "flat", "async", "ragged", 1, "none", "host"),
        ConfigKey("compact", "flat", "sync", "uniform", 1, "int8", "host"),
        ConfigKey("compact", "flat", "async", "ragged", 1, "int8", "host"),
    )


#: All supported configurations (nightly): the 48-point uncompressed
#: product plus the flat compressed-consensus legs and the
#: host-offloaded state legs.  ``timing="serve"`` is the admission
#: step of the rounds-as-a-service scheduler (``core.schedule``): the
#: same round program taking the tick's (N,) bool arrival mask as a
#: runtime operand.
FULL_MATRIX = _matrix() + _compress_matrix() + _host_matrix()

#: PR-gate subset: the canonical fused round, the compacted round, the
#: kitchen sink (compact+async+ragged), the tree layout (pallas-free
#: budget), the serve admission step, and the two-device legs that
#: exercise collectives/donation under the mesh.
FAST_MATRIX = (
    ConfigKey("dense", "flat", "sync", "uniform", 1),
    ConfigKey("compact", "flat", "sync", "uniform", 1),
    ConfigKey("compact", "flat", "async", "ragged", 1),
    ConfigKey("dense", "tree", "sync", "uniform", 1),
    ConfigKey("compact", "flat", "serve", "uniform", 1),
    ConfigKey("dense", "flat", "sync", "uniform", 2),
    ConfigKey("compact", "flat", "async", "ragged", 2),
    # Compressed consensus: the int8 single/two-device legs (dtype-aware
    # CollectiveBudget, s8 ring term) and the bf16 two-device leg (u16
    # all-gather wire).
    ConfigKey("dense", "flat", "sync", "uniform", 1, "int8"),
    ConfigKey("dense", "flat", "sync", "uniform", 2, "int8"),
    ConfigKey("dense", "flat", "sync", "uniform", 2, "bf16"),
    # Host-offloaded client state: the streamed solve program of the
    # canonical compact round and the kitchen-sink async+ragged leg.
    ConfigKey("compact", "flat", "sync", "uniform", 1, "none", "host"),
    ConfigKey("compact", "flat", "async", "ragged", 1, "none", "host"),
)

MATRICES = {"fast": FAST_MATRIX, "full": FULL_MATRIX}


@dataclasses.dataclass
class EngineArtifact:
    """Everything the rule engine inspects for one configuration."""

    key: ConfigKey
    cfg: FLConfig
    n: int
    dim: int
    capacity: int | None        # solver-row budget (compact path)
    c_min: int | None
    world_size: int
    donated: bool
    jaxpr: Any                  # ClosedJaxpr of the un-jitted round
    compiled_text: str | None   # post-SPMD HLO, None if compile=False
    cost: dict                  # normalized Compiled.cost_analysis()
    state: Any                  # FLState the round was traced with
    round_fn: Callable | None   # the jitted round (None if compile=False)
    spec: Any
    ragged: Any
    mesh: Any

    @property
    def kernels_on(self) -> bool:
        return self.key.kernels_on


def ragged_sizes(n: int, n_points: int) -> list:
    """Deterministic non-uniform client shard sizes (3-way cycle)."""
    return [max(n_points - 2 * (i % 3), 2) for i in range(n)]


def build_problem(key: ConfigKey, *, n: int = DEFAULT_N,
                  n_points: int = DEFAULT_POINTS, dim: int = DEFAULT_DIM,
                  seed: int = 0):
    """(data, params0, loss_fn, spec, ragged) for one configuration."""
    data, params0, loss_fn = make_least_squares(
        n, n_points=n_points, dim=dim, seed=seed)
    ragged = None
    if key.shards == "ragged":
        sizes = ragged_sizes(n, n_points)
        data, ragged = pool_data(
            [np.asarray(data["x"][i])[:s] for i, s in enumerate(sizes)],
            [np.asarray(data["y"][i])[:s] for i, s in enumerate(sizes)])
    spec = make_flat_spec(params0) if key.layout == "flat" else None
    return data, params0, loss_fn, spec, ragged


def build_config(key: ConfigKey, *, n: int = DEFAULT_N,
                 overrides: dict | None = None) -> FLConfig:
    """The FLConfig a configuration key stands for."""
    kw: dict = dict(
        n_clients=n,
        participation=0.25,
        rho=1.0,
        lr=0.1,
        momentum=0.0,
        epochs=1,
        batch_size=4,
        compact=key.path == "compact",
        max_staleness=2 if key.timing == "async" else None,
        use_admm_kernel=key.kernels_on,
        use_trigger_kernel=key.kernels_on,
        # Policy (mirrored by the fused-admm-pass rule): the compacted
        # flat round commits through the fused megakernel.
        fused_gss=key.kernels_on and key.path == "compact",
        consensus_compress=key.compress,
        state_backend=key.backend,
    )
    kw.update(overrides or {})
    return FLConfig(**kw)


def _client_mesh(world_size: int):
    from repro.sharding.clients import make_client_mesh
    return make_client_mesh(world_size)


def build_artifact(key: ConfigKey, *, n: int = DEFAULT_N,
                   n_points: int = DEFAULT_POINTS, dim: int = DEFAULT_DIM,
                   seed: int = 0, compile: bool = True,
                   donate: bool = True, body_transform=None,
                   cfg_overrides: dict | None = None) -> EngineArtifact:
    """Trace (and optionally compile) one engine configuration.

    ``body_transform`` threads through to ``make_round_fn`` — the
    mutation hook the self-tests use.  ``compile=False`` skips the
    XLA compile and yields a jaxpr-only artifact (cheap: the jaxpr
    rules still apply).
    """
    if key.devices > 1 and jax.device_count() < key.devices:
        raise RuntimeError(
            f"{key.name} needs {key.devices} devices, have "
            f"{jax.device_count()} (set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={key.devices} "
            f"before importing jax)")
    data, params0, loss_fn, spec, ragged = build_problem(
        key, n=n, n_points=n_points, dim=dim, seed=seed)
    cfg = build_config(key, n=n, overrides=cfg_overrides)
    mesh = _client_mesh(key.devices) if key.devices > 1 else None
    state = init_state(cfg, params0, mesh=mesh, spec=spec)

    serve = key.timing == "serve"
    common: dict = dict(mesh=mesh, spec=spec, ragged=ragged,
                        arrivals_arg=serve,
                        body_transform=body_transform)
    compiled_text = None
    cost: dict = {}
    round_fn = None
    if key.backend == "host":
        # The host round is glue (numpy row copies + three jitted
        # programs); what the rule engine must vet is the streamed
        # *solve* program — the per-round hot loop that touches the
        # (C, D) working set.  ``body_transform`` already wrapped it
        # inside make_round_fn, so tracing ``solve_fn`` sees the
        # mutation.  The glue-layer streaming transfers live outside
        # every jaxpr by design; HostTransferBudget prices them from
        # ``round_fn.planned_bytes`` instead.
        round_fn = make_round_fn(cfg, loss_fn, data, jit=True,
                                 donate=donate, **common)
        solve_args = round_fn.solve_example_args()
        jaxpr = jax.make_jaxpr(round_fn.solve_fn)(*solve_args)
        if compile:
            compiled = round_fn.solve_step.lower(*solve_args).compile()
            compiled_text = compiled.as_text()
            cost = cost_analysis_dict(compiled.cost_analysis())
    else:
        # The serve step takes the tick's arrival mask as a runtime
        # operand; any representative (N,) bool aval traces it.
        example_args = ((state, jax.numpy.ones((n,), bool)) if serve
                        else (state,))
        traced = make_round_fn(cfg, loss_fn, data, jit=False, **common)
        jaxpr = jax.make_jaxpr(traced)(*example_args)
        if compile:
            round_fn = make_round_fn(cfg, loss_fn, data, jit=True,
                                     donate=donate, **common)
            compiled = round_fn.lower(*example_args).compile()
            compiled_text = compiled.as_text()
            cost = cost_analysis_dict(compiled.cost_analysis())

    capacity = c_min = None
    if cfg.compact:
        c_min, capacity = capacity_bounds(
            n, cfg.participation, cfg.capacity_slack, cfg.capacity,
            n_shards=key.devices)
    dim_total = spec.dim if spec is not None else sum(
        int(np.prod(x.shape)) for x in jax.tree.leaves(params0))
    return EngineArtifact(
        key=key, cfg=cfg, n=n, dim=dim_total, capacity=capacity,
        c_min=c_min, world_size=key.devices, donated=donate,
        jaxpr=jaxpr, compiled_text=compiled_text, cost=cost,
        state=state, round_fn=round_fn, spec=spec, ragged=ragged,
        mesh=mesh)
