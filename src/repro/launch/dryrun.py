import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

Lowers + compiles every (architecture × input-shape × mesh) program on
placeholder host devices — 256 chips single-pod (16×16) and 512 chips
multi-pod (2×16×16) — proving the sharding configs are coherent without
hardware, and extracting the roofline terms (deliverable g) from the
compiled artifact.

  train_4k     → train_step      (single-pod: FedBack local prox step;
                                  multi-pod: the full cross-pod FedBack
                                  round incl. the event-gated consensus)
  prefill_32k  → prefill
  decode_32k   → serve_step      (1 token, 32k KV/SSM cache)
  long_500k    → serve_step      (1 token, 524k context; sub-quadratic
                                  archs only)

Usage:
  python -m repro.launch.dryrun --arch mixtral-8x7b --shape train_4k
  python -m repro.launch.dryrun --arch all --mesh both --out experiments/dryrun
"""

import argparse
import json
import time
import traceback

import jax
import numpy as np

from repro.configs import ARCHITECTURES, INPUT_SHAPES, get_config, \
    shape_applicable
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import (
    model_flops_per_device,
    roofline_terms,
    summarize,
)
from repro.launch.steps import (
    make_cross_pod_step,
    make_decode_step,
    make_prefill_step,
    make_train_step,
)
from repro.models.api import active_param_count, build_model, param_count
from repro.utils.hlo import cost_analysis_dict


def _mem_dict(ma) -> dict:
    if ma is None:
        return {}
    fields = ("generated_code_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "temp_size_in_bytes",
              "alias_size_in_bytes")
    return {f: int(getattr(ma, f, 0)) for f in fields}


def analytic_hbm_bytes(cfg, *, step_mode, batch, seq, n_chips,
                       multi_pod, local_steps):
    """First-principles per-chip HBM estimate for the TPU target.

    Recorded alongside the measured CPU-backend temp size, which
    over-counts: XLA-CPU's fusion of the residual-stash update
    materializes a second fp32 copy of the whole stash (see
    EXPERIMENTS §Dry-run) that the TPU assignment keeps bf16 in-loop.
    """
    p = param_count(cfg)
    bp = 2 if cfg.dtype == "bfloat16" else 4
    d_eff = cfg.d_model
    if step_mode == "train":
        # params + grads + prox center (bp each) + adam m,v (fp32)
        state = p * (3 * bp + 8)
        if multi_pod:
            state += p * 3 * bp  # θ, λ, z_prev per pod (pod-sharded)
        # activations are batch-sharded only (not model-sharded): per-chip
        # slice of the stash is B/(data·pod) sequences
        stash = cfg.num_layers / max(cfg.remat_group, 1) * \
            (batch / n_chips * 16) * seq * d_eff * bp
        transient = 6 * (batch / n_chips * 16) * seq * max(
            cfg.d_ff or 2 * cfg.d_model, cfg.num_heads * cfg.head_dim or 0,
            2 * d_eff) * bp
        return state / n_chips + stash + transient
    if step_mode == "prefill":
        acts = 8 * (batch * 16 / n_chips) * seq * d_eff * bp
        cache = (cfg.num_layers * batch * seq * max(
            cfg.num_kv_heads * cfg.head_dim, 1) * 2 * bp / n_chips
            if cfg.family in ("dense", "moe", "vlm") else
            cfg.num_layers * batch * 2 * cfg.expand * d_eff *
            cfg.ssm_state * 4 / n_chips)
        return p * bp / n_chips + acts + cache
    # decode
    window = cfg.sliding_window or seq
    kv_len = min(seq, window) if cfg.sliding_window else seq
    cache = (cfg.num_layers * batch * kv_len *
             max(cfg.num_kv_heads * cfg.head_dim, 1) * 2 * bp
             if cfg.family in ("dense", "moe", "vlm") else
             cfg.num_layers * batch * cfg.expand * d_eff *
             cfg.ssm_state * 4)
    if cfg.family == "hybrid":
        ng = cfg.num_layers // cfg.attn_every
        cache += ng * batch * min(seq, cfg.sliding_window or seq) *             cfg.num_kv_heads * cfg.head_dim * 2 * bp
    return p * bp / n_chips + cache / min(n_chips, max(batch, 1)) +         2 ** 28


def build_step(cfg, shape: str, *, multi_pod: bool,
               mode: str = "fsdp", local_steps: int = 2):
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        return None, reason
    step_mode, seq, batch = INPUT_SHAPES[shape]
    model = build_model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    baxes = ("pod", "data") if multi_pod else ("data",)
    if step_mode == "train":
        if multi_pod:
            built = make_cross_pod_step(model, mesh, batch=batch, seq=seq,
                                        mode=mode, local_steps=local_steps)
        else:
            built = make_train_step(model, mesh, batch=batch, seq=seq,
                                    mode=mode, batch_axes=baxes)
    elif step_mode == "prefill":
        built = make_prefill_step(model, mesh, batch=batch, seq=seq,
                                  mode=mode, batch_axes=baxes)
    else:
        built = make_decode_step(model, mesh, batch=batch, seq=seq,
                                 mode=mode, batch_axes=baxes)
    return (cfg, model, mesh, built, step_mode, seq, batch), ""


def _reduced_layers(cfg, n_units: int):
    """Config with n_units scan iterations (hybrid: units are groups)."""
    import dataclasses
    if cfg.family == "hybrid":
        return dataclasses.replace(cfg, num_layers=n_units * cfg.attn_every)
    g = max(cfg.remat_group, 1)
    if cfg.num_layers % g == 0 and g > 1:
        return dataclasses.replace(cfg, num_layers=n_units * g)
    return dataclasses.replace(cfg, num_layers=n_units)


def _scan_units(cfg) -> int:
    if cfg.family == "hybrid":
        return cfg.num_layers // cfg.attn_every
    g = max(cfg.remat_group, 1)
    return cfg.num_layers // g if cfg.num_layers % g == 0 else cfg.num_layers


def _compile_cost(cfg, shape, *, multi_pod, mode, local_steps):
    """cost_analysis + collective bytes for one config (no mem record)."""
    built, _ = build_step(cfg, shape, multi_pod=multi_pod, mode=mode,
                          local_steps=local_steps)
    _, model, mesh, (fn, in_sh, out_sh, args), step_mode, seq, batch = built
    compiled = jax.jit(fn, in_shardings=in_sh,
                       out_shardings=out_sh).lower(*args).compile()
    ca = cost_analysis_dict(compiled.cost_analysis())
    hlo = compiled.as_text()
    n_chips = int(np.prod(list(mesh.shape.values())))
    from repro.utils.hlo import total_collective_bytes
    return {
        "flops": float(ca.get("flops", 0.0) or 0.0),
        "bytes": float(ca.get("bytes accessed", 0.0) or 0.0),
        "coll": total_collective_bytes(hlo, world_size=n_chips),
    }


def corrected_cost(cfg, shape, *, multi_pod, mode, local_steps):
    """XLA's cost analysis counts `while` bodies ONCE — the layer scan
    (L iterations) is invisible beyond its first trip.  Correct it by
    lowering 1-unit and 2-unit variants of the same program:

        cost(L) = cost(1 unit) + (units − 1) · (cost(2) − cost(1))

    Inner (kv-block / CE-chunk / microbatch) loops are unrolled at
    trace time (cfg.unroll_inner), so the per-unit delta is exact for
    them; only the SSD inter-chunk scan (negligible FLOPs) stays rolled.
    """
    import dataclasses
    cfg_u = dataclasses.replace(cfg, unroll_inner=True, unroll_layers=True)
    units = _scan_units(cfg_u)
    c1 = _compile_cost(_reduced_layers(cfg_u, 1), shape, multi_pod=multi_pod,
                       mode=mode, local_steps=local_steps)
    c2 = _compile_cost(_reduced_layers(cfg_u, 2), shape, multi_pod=multi_pod,
                       mode=mode, local_steps=local_steps)
    return {
        k: c1[k] + (units - 1) * max(c2[k] - c1[k], 0.0)
        for k in ("flops", "bytes", "coll")
    }


def dry_run(arch: str, shape: str, *, multi_pod: bool = False,
            mode: str = "fsdp", local_steps: int = 2,
            cost_correction: bool = True, cfg=None) -> dict:
    """Lower + compile one (arch × shape × mesh) program; return the
    §Dry-run/§Roofline record."""
    t0 = time.time()
    cfg = cfg or get_config(arch)
    built, reason = build_step(cfg, shape, multi_pod=multi_pod, mode=mode,
                               local_steps=local_steps)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    base = {"arch": arch, "shape": shape, "mesh": mesh_name,
            "sharding_mode": mode}
    if built is None:
        return {**base, "status": "skipped", "reason": reason}
    cfg, model, mesh, (fn, in_sh, out_sh, args), step_mode, seq, batch = built

    lowered = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh).lower(
        *args)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    ca = cost_analysis_dict(compiled.cost_analysis())
    ma = compiled.memory_analysis()
    hlo = compiled.as_text()
    n_chips = int(np.prod(list(mesh.shape.values())))
    if cost_correction:
        cc = corrected_cost(cfg, shape, multi_pod=multi_pod, mode=mode,
                            local_steps=local_steps)
        ca = dict(ca)
        ca["flops"] = cc["flops"]
        ca["bytes accessed"] = cc["bytes"]
        ca["collective_bytes_override"] = cc["coll"]
    terms = roofline_terms(ca, hlo, world_size=n_chips)
    if cost_correction:
        terms["collective_s"] = cc["coll"] / 50e9
        terms["collective_bytes_per_device"] = cc["coll"]
        terms["dominant"] = max(
            ("compute_s", "memory_s", "collective_s"),
            key=lambda k: terms[k]).replace("_s", "")
        terms["bound_time_s"] = max(terms["compute_s"], terms["memory_s"],
                                    terms["collective_s"])
    # NOTE: global_batch already spans the cross-pod local steps
    # (batch = pods × local_steps × per-step), so no extra multiplier.
    mf = model_flops_per_device(
        cfg, mode=step_mode, batch=batch, seq=seq, n_chips=n_chips,
        active_params=active_param_count(cfg))
    mem = _mem_dict(ma)
    per_dev_bytes = sum(mem.get(k, 0) for k in
                        ("argument_size_in_bytes", "temp_size_in_bytes",
                         "output_size_in_bytes"))
    analytic = analytic_hbm_bytes(cfg, step_mode=step_mode, batch=batch,
                                  seq=seq, n_chips=n_chips,
                                  multi_pod=multi_pod,
                                  local_steps=local_steps)
    record = {
        **base,
        "status": "ok",
        "step": step_mode,
        "seq": seq,
        "batch": batch,
        "n_chips": n_chips,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory_analysis": mem,
        "bytes_per_device": per_dev_bytes,
        "analytic_hbm_bytes": int(analytic),
        "fits_hbm_16GiB": bool(analytic < 16 * 2 ** 30),
        "cpu_measured_fits": bool(per_dev_bytes < 16 * 2 ** 30),
        "model_flops_per_device": mf,
        "useful_flops_ratio": (mf / terms["hlo_flops_per_device"]
                               if terms["hlo_flops_per_device"] else None),
        "roofline": terms,
    }
    return record


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all",
                    help="architecture id or 'all'")
    ap.add_argument("--shape", default="all",
                    help=f"one of {list(INPUT_SHAPES)} or 'all'")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--sharding", default="fsdp",
                    choices=["fsdp", "tp", "fsdp_tp"])
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--out", default=None,
                    help="directory for per-combo JSON records")
    ap.add_argument("--skip-existing", action="store_true",
                    help="skip combos whose JSON already exists in --out")
    ap.add_argument("--set", action="append", default=[],
                    help="ModelConfig overrides key=value (repeatable); "
                         "e.g. --set chunk=32 --set ssd_intra_dtype=bfloat16")
    ap.add_argument("--tag", default="",
                    help="suffix for output filenames (perf variants)")
    args = ap.parse_args()

    import dataclasses as _dc

    def apply_overrides(cfg):
        for kv in args.set:
            k, v = kv.split("=", 1)
            cur = getattr(cfg, k)
            if isinstance(cur, bool):
                v = v.lower() in ("1", "true", "yes")
            elif isinstance(cur, int):
                v = int(v)
            elif isinstance(cur, float):
                v = float(v)
            cfg = _dc.replace(cfg, **{k: v})
        return cfg

    archs = list(ARCHITECTURES) if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}|{shape}|{'multi' if mp else 'single'}"
                fname = (f"{arch}__{shape}__{'multi' if mp else 'single'}"
                         f"__{args.sharding}"
                         f"{('__' + args.tag) if args.tag else ''}.json")
                if (args.skip_existing and args.out and
                        os.path.exists(os.path.join(args.out, fname))):
                    print(f"{tag}: exists, skipping", flush=True)
                    continue
                try:
                    rec = dry_run(arch, shape, multi_pod=mp,
                                  mode=args.sharding,
                                  local_steps=args.local_steps,
                                  cfg=apply_overrides(get_config(arch)))
                    if args.set:
                        rec["overrides"] = list(args.set)
                except Exception:
                    failures += 1
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "status": "error",
                           "error": traceback.format_exc()[-2000:]}
                if rec["status"] == "ok":
                    print(summarize(rec), flush=True)
                    mem = rec["memory_analysis"]
                    print(f"    memory/device: args="
                          f"{mem.get('argument_size_in_bytes', 0)/2**30:.2f}"
                          f"GiB temp="
                          f"{mem.get('temp_size_in_bytes', 0)/2**30:.2f}GiB "
                          f"fits16GiB={rec['fits_hbm_16GiB']} "
                          f"compile={rec['compile_s']:.1f}s", flush=True)
                else:
                    print(f"{tag}: {rec['status']}: "
                          f"{rec.get('reason', rec.get('error', ''))[:300]}",
                          flush=True)
                if args.out:
                    os.makedirs(args.out, exist_ok=True)
                    fname = (f"{arch}__{shape}__"
                             f"{'multi' if mp else 'single'}"
                             f"__{args.sharding}"
                             f"{('__' + args.tag) if args.tag else ''}.json")
                    with open(os.path.join(args.out, fname), "w") as f:
                        json.dump(rec, f, indent=1)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
