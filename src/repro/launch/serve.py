"""Disambiguation shim — ``repro.launch.serve`` grew two meanings.

* ``python -m repro.launch.serve_lm`` — the LM inference demo
  (batched prefill + decode over the model zoo).  This module
  forwards there, so existing ``python -m repro.launch.serve``
  invocations keep working.
* ``python -m repro.launch.serve_fl`` — the federated
  rounds-as-a-service engine (event-driven admission on an arrival
  trace; see ``repro.core.schedule`` and docs/serving.md).
"""
from __future__ import annotations

import sys

from repro.launch.serve_lm import main  # noqa: F401  (forwarded entry)

if __name__ == "__main__":
    print("note: `repro.launch.serve` is the LM demo (now "
          "`repro.launch.serve_lm`); the federated serving engine is "
          "`repro.launch.serve_fl`.", file=sys.stderr)
    main()
