"""Distributed step builders shared by the dry-run, the trainer and the
server.

Every step is the *paper's* computation at the appropriate scope:

* ``make_train_step`` (single-pod) — one client-local FedBack inner
  iteration (Eq. 2.3): grad of loss + ρ(θ − c) prox pull toward the
  ADMM center c = ω − λ, then an AdamW update.  ω/λ enter as a
  param-shaped ``center`` input sharded like the parameters.
* ``make_cross_pod_step`` (multi-pod) — a full FedBack round with one
  silo per pod: trigger norms, controller, gated local updates and the
  event-gated consensus psum over the ``pod`` axis
  (repro.core.crosspod).
* ``make_prefill_step`` / ``make_decode_step`` — serving paths with KV
  or SSM-state caches.

All builders return ``(fn, in_shardings, out_shardings, abstract_args)``
ready for ``jax.jit(...).lower(*abstract_args).compile()``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.controller import ControllerConfig
from repro.core.crosspod import (
    CrossPodConfig,
    init_cross_pod_state,
    make_cross_pod_round,
)
from repro.models.api import Model, abstract_params, input_specs
from repro.optim.adam import adam_init, adam_step
from repro.sharding.actshard import activation_sharding
from repro.sharding.specs import (
    batch_specs,
    cache_specs,
    param_specs,
    pod_stacked_specs,
)

DEFAULT_RHO = 1e-4
DEFAULT_LR = 3e-4


def _named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def _abstract(tree):
    return jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree)


# ----------------------------------------------------------------------
# train
# ----------------------------------------------------------------------


def make_train_step(model: Model, mesh, *, batch: int, seq: int,
                    mode: str = "fsdp", rho: float = DEFAULT_RHO,
                    lr: float = DEFAULT_LR, batch_axes=("data",),
                    grad_accum: int = 1):
    cfg = model.config
    p_abs = abstract_params(model)
    opt_abs = jax.eval_shape(adam_init, p_abs)
    b_abs = input_specs(cfg, mode="train", batch=batch, seq=seq)

    pspec = param_specs(p_abs, mesh, mode=mode)
    # Adam state mirrors the param tree twice plus a step scalar:
    opt_spec = type(opt_abs)(mu=pspec, nu=pspec, step=P())
    bspec = batch_specs(b_abs, batch_axes=tuple(batch_axes)
                        if len(batch_axes) > 1 else batch_axes[0])

    baxes_spec = tuple(batch_axes) if len(batch_axes) > 1 else batch_axes[0]

    def train_step(params, opt, center, batch):
        # gradient accumulation: an *unrolled* microbatch loop (counted
        # correctly by cost analysis, buffers reused by the allocator);
        # shrinks activation temps by the accumulation factor.
        with activation_sharding(mesh, baxes_spec):
            if grad_accum > 1:
                loss = jnp.zeros((), jnp.float32)
                g = jax.tree.map(
                    lambda x: jnp.zeros(x.shape, x.dtype), params)
                for i in range(grad_accum):
                    micro = jax.tree.map(
                        lambda x, i=i: x.reshape(
                            (grad_accum, x.shape[0] // grad_accum)
                            + x.shape[1:])[i], batch)
                    li, gi = jax.value_and_grad(model.loss)(params, micro)
                    loss = loss + li / grad_accum
                    g = jax.tree.map(
                        lambda a, b_: a + b_ / grad_accum, g, gi)
            else:
                loss, g = jax.value_and_grad(model.loss)(params, batch)
        g = jax.tree.map(lambda gl, pl_, c: gl + rho * (
            pl_.astype(jnp.float32) - c.astype(jnp.float32)).astype(gl.dtype),
            g, params, center)
        params, opt = adam_step(params, g, opt, lr)
        return params, opt, loss

    in_sh = (_named(mesh, pspec), _named(mesh, opt_spec),
             _named(mesh, pspec), _named(mesh, bspec))
    out_sh = (_named(mesh, pspec), _named(mesh, opt_spec), None)
    args = (p_abs, opt_abs, p_abs, b_abs)
    return train_step, in_sh, out_sh, args


def make_cross_pod_step(model: Model, mesh, *, batch: int, seq: int,
                        mode: str = "fsdp", local_steps: int = 2,
                        rho: float = DEFAULT_RHO, lr: float = DEFAULT_LR,
                        target_rate: float = 0.5):
    """Full FedBack round across pods (the multi-pod dry-run program)."""
    cfg = model.config
    n_pods = mesh.shape["pod"]
    cp = CrossPodConfig(
        n_pods=n_pods, rho=rho, lr=lr, local_steps=local_steps,
        controller=ControllerConfig(K=0.5, alpha=0.9,
                                    target_rate=target_rate))

    def sharded_loss(params, batch):
        with activation_sharding(mesh, "data"):
            return model.loss(params, batch)

    round_fn = make_cross_pod_round(cp, sharded_loss)

    p_abs = abstract_params(model)
    state_abs = _abstract(jax.eval_shape(
        lambda p: init_cross_pod_state(cp, p), p_abs))
    # batch: (pods, local_steps, per-step-batch, ...)
    per_step = batch // (n_pods * local_steps)
    assert per_step >= 1, (batch, n_pods, local_steps)
    flat = input_specs(cfg, mode="train", batch=per_step, seq=seq)
    b_abs = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(
            (n_pods, local_steps) + l.shape, l.dtype), flat)

    pspec = param_specs(p_abs, mesh, mode=mode)
    pod_pspec = pod_stacked_specs(pspec)
    ctrl_spec = jax.tree.map(lambda _: P(), state_abs.ctrl)
    state_spec = type(state_abs)(
        theta=pod_pspec, lam=pod_pspec, z_prev=pod_pspec,
        ctrl=ctrl_spec, rng=P(), round=P())
    bspec = jax.tree.map(
        lambda l: P("pod", None, "data", *([None] * (len(l.shape) - 3))),
        b_abs)

    metrics_spec = None  # small per-pod vectors: let XLA place them
    in_sh = (_named(mesh, state_spec), _named(mesh, bspec))
    out_sh = (_named(mesh, state_spec), metrics_spec)
    return round_fn, in_sh, out_sh, (state_abs, b_abs)


# ----------------------------------------------------------------------
# serve
# ----------------------------------------------------------------------


def make_prefill_step(model: Model, mesh, *, batch: int, seq: int,
                      mode: str = "fsdp", batch_axes=("data",)):
    cfg = model.config
    p_abs = abstract_params(model)
    b_abs = input_specs(cfg, mode="prefill", batch=batch, seq=seq)
    cache_abs = jax.eval_shape(partial(model.init_cache, batch, seq))
    baxes = tuple(batch_axes) if len(batch_axes) > 1 else batch_axes[0]

    pspec = param_specs(p_abs, mesh, mode=mode)
    bspec = batch_specs(b_abs, batch_axes=baxes)
    cspec = cache_specs(cache_abs, mesh, batch_axes=baxes)

    def prefill_step(params, batch):
        with activation_sharding(mesh, baxes):
            return model.prefill(params, batch, seq)

    in_sh = (_named(mesh, pspec), _named(mesh, bspec))
    out_sh = (None, _named(mesh, cspec))
    return prefill_step, in_sh, out_sh, (p_abs, b_abs)


def make_decode_step(model: Model, mesh, *, batch: int, seq: int,
                     mode: str = "fsdp", batch_axes=("data",)):
    """serve_step: ONE new token against a seq-length cache."""
    cfg = model.config
    p_abs = abstract_params(model)
    tok_abs = input_specs(cfg, mode="decode", batch=batch, seq=seq)["token"]
    cache_abs = jax.eval_shape(partial(model.init_cache, batch, seq))
    baxes = tuple(batch_axes) if len(batch_axes) > 1 else batch_axes[0]

    pspec = param_specs(p_abs, mesh, mode=mode)
    tspec = P(baxes, None) if batch > 1 else P()
    cspec = cache_specs(cache_abs, mesh, batch_axes=baxes)

    def decode_step(params, token, cache):
        with activation_sharding(mesh, baxes if batch > 1 else None):
            return model.decode_step(params, token, cache)

    in_sh = (_named(mesh, pspec), NamedSharding(mesh, tspec),
             _named(mesh, cspec))
    out_sh = (None, _named(mesh, cspec))
    return decode_step, in_sh, out_sh, (p_abs, tok_abs, cache_abs)
