"""LM serving demo: batched prefill + decode on a mesh.

This is the *language-model* inference demo over the model zoo
(``repro.models``) — for the federated rounds-as-a-service engine, see
``repro.launch.serve_fl`` (``python -m repro.launch.serve_fl``).

On real TPU hardware this serves the full configs; on CPU use
``--reduced`` for a runnable demonstration of the identical program:

    PYTHONPATH=src python -m repro.launch.serve_lm --arch mixtral-8x7b \\
        --reduced --batch 4 --prompt-len 32 --new-tokens 16

Throughput accounting: the decode loop runs ``new_tokens - 1`` steps
(the first token falls out of prefill), so the reported rate divides
``batch × (new_tokens − 1)`` generated tokens by the decode loop's
wall time.  Both programs are warmed up before the clock starts —
jit trace + XLA compile used to land inside the timed region and
understated tok/s by an order of magnitude on small configs; compile
time is now reported separately.
"""
from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs import get_config
    from repro.models.api import build_model, param_count

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if not cfg.supports_decode:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode path")
    model = build_model(cfg)
    print(f"serving {cfg.name} ({param_count(cfg)/1e6:.1f}M params)")
    params = model.init(jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    max_seq = args.prompt_len + args.new_tokens
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)}
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.normal(size=(args.batch, cfg.prefix_tokens,
                             cfg.frontend_dim)) * 0.2, cfg.param_dtype)

    prefill = jax.jit(lambda p, b: model.prefill(p, b, max_seq))
    decode = jax.jit(model.decode_step)

    # Warm-up: compile both programs off the clock.  Prefill and decode
    # are pure, so the timed run below recomputes identical values
    # through the jit cache.
    t0 = time.time()
    logits, cache = prefill(params, batch)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    warm = decode(params, tok, cache)
    jax.block_until_ready(warm)
    del warm
    print(f"compile (prefill + decode): {(time.time()-t0)*1e3:.0f} ms")

    t0 = time.time()
    logits, cache = prefill(params, batch)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    jax.block_until_ready(tok)
    print(f"prefill {args.batch}×{args.prompt_len}: "
          f"{(time.time()-t0)*1e3:.0f} ms")
    t0 = time.time()
    outs = [tok]
    for _ in range(args.new_tokens - 1):
        logits, cache = decode(params, tok, cache)
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        outs.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    # new_tokens − 1 decode steps generate batch tokens each; the first
    # token of every sequence is prefill's and is costed there.
    n = args.batch * (args.new_tokens - 1)
    print(f"decode {n} tokens: {dt*1e3:.0f} ms ({n/max(dt,1e-9):.0f} tok/s)")
    print("request 0:", jnp.concatenate(outs, 1)[0].tolist())


if __name__ == "__main__":
    main()
