"""Roofline-term extraction from a compiled dry-run artifact.

Hardware model: TPU v5e —
    197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI.

``compiled.cost_analysis()`` returns **per-device** (post-SPMD) FLOPs
and bytes (validated empirically: a (8,64)×(64,128) matmul on a (2,4)
mesh reports 1/8 of the global FLOPs), so:

    compute term    = flops_per_device / PEAK_FLOPS
    memory term     = bytes_per_device / HBM_BW
    collective term = link_bytes_per_device / LINK_BW

with link bytes from the ring-multiplier inventory in utils/hlo.py
(HLO shapes are per-device too).  MODEL_FLOPS = 6·N_active·D (train) or
2·N_active·D (inference) per device, ratioed against HLO FLOPs to
expose remat/dispatch/mask waste.
"""
from __future__ import annotations

from typing import Any

from repro.utils.hlo import collective_inventory, total_collective_bytes

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
LINK_BW = 50e9  # bytes/s per ICI link (conservative single-link model)
PCIE_BW = 32e9  # bytes/s host<->device (PCIe gen4 x16, sustained)


def model_flops_per_device(cfg, *, mode: str, batch: int, seq: int,
                           n_chips: int, active_params: int,
                           local_steps: int = 1) -> float:
    """6·N·D (train: fwd+bwd) / 2·N·D (inference fwd) per device."""
    if mode == "train":
        tokens = batch * seq * local_steps
        factor = 6.0
    elif mode == "prefill":
        tokens = batch * seq
        factor = 2.0
    else:  # decode: one token per sequence
        tokens = batch * 1
        factor = 2.0
    return factor * active_params * tokens / n_chips


def roofline_terms(cost: dict[str, Any], hlo_text: str, *,
                   world_size: int) -> dict[str, Any]:
    flops = float(cost.get("flops", 0.0) or 0.0)
    bytes_hbm = float(cost.get("bytes accessed", 0.0) or 0.0)
    coll = total_collective_bytes(hlo_text, world_size=world_size)
    t_c = flops / PEAK_FLOPS
    t_m = bytes_hbm / HBM_BW
    t_x = coll / LINK_BW
    terms = {"compute_s": t_c, "memory_s": t_m, "collective_s": t_x}
    dominant = max(terms, key=terms.get)
    return {
        **terms,
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": bytes_hbm,
        "collective_bytes_per_device": coll,
        "dominant": dominant.replace("_s", ""),
        "bound_time_s": max(t_c, t_m, t_x),
        "collectives": collective_inventory(hlo_text,
                                            world_size=world_size),
    }


def fedback_round_hbm_bytes(n_clients: int, solver_rows: int, dim: int,
                            *, data_bytes_per_client: int = 0,
                            dtype_bytes: int = 4,
                            fused: bool = False) -> dict[str, int]:
    """Modeled per-round HBM traffic of the flat FedBack round engine.

    The server side is irreducibly O(N·D): one trigger read of z_prev,
    one consensus read, and one commit write per state field (θ, λ,
    z_prev).  Everything client-side flows through the capacity slots —
    ``solver_rows`` is N on the dense path and C = ⌈slack·L̄·N⌉ (the
    realized adaptive limit at most) on the compacted path:

    * the fused λ⁺/center pass (``kernels.admm_update``, with_z=False
      form: 2 reads + 2 writes per row),
    * the post-solve z = θ_out + λ⁺ assembly (2 reads + 1 write),
    * the gathered data shards (``data_bytes_per_client`` per row) —
      the solver streams C rows of x/y, not N.

    With ``fused=True`` (the fused gather→ADMM→scatter commit,
    ``kernels.fused_gss``) the solver-state term is the honest fused
    model instead: the pre-solve center pass plus ONE kernel pass that
    gathers θ/λ/z_prev rows, re-derives λ⁺ and z, and scatters in
    place (``fused_gss_hbm_bytes(..., presolve=True)``) — the separate
    z assembly and per-output scatter passes are gone.  The dense
    path's model is unchanged (dense rounds never gather or scatter,
    so the historical 4+3-stream formula is exact there).

    Returns the separate server/solver terms plus the total, so the
    benchmark can show the solver term scaling with C while the server
    term stays pinned at N.
    """
    server = (1 + 1 + 3) * n_clients * dim * dtype_bytes
    if fused:
        from repro.kernels.fused_gss import fused_gss_hbm_bytes
        solver_state = fused_gss_hbm_bytes(solver_rows, dim, with_z=True,
                                           presolve=True,
                                           dtype_bytes=dtype_bytes)
    else:
        from repro.kernels.admm_update import admm_update_hbm_bytes
        solver_state = (admm_update_hbm_bytes(solver_rows, dim,
                                              with_z=False,
                                              dtype_bytes=dtype_bytes)
                        + 3 * solver_rows * dim * dtype_bytes)
    solver_data = solver_rows * data_bytes_per_client
    return {
        "server_bytes": server,
        "solver_state_bytes": solver_state,
        "solver_data_bytes": solver_data,
        "solver_bytes": solver_state + solver_data,
        "total_bytes": server + solver_state + solver_data,
    }


def fedback_ragged_round_hbm_bytes(n_clients: int, solver_rows: int,
                                   dim: int, *, sizes,
                                   row_bytes: int,
                                   dtype_bytes: int = 4) -> dict[str, int]:
    """Ragged variant of :func:`fedback_round_hbm_bytes`.

    With heterogeneous shards the solver's data term is governed by the
    pooled row count Σnᵢ, not by nᵢ·N: the dense ragged round (solver
    rows = N) streams every client's CSR slice once — Σnᵢ·row_bytes —
    via per-batch gathers from the pool.  The compacted round
    (solver_rows < N) materializes one *static* ``max(nᵢ)``-length
    block slice per capacity slot (``core.compact.solve_slots``), so
    its honest data term is ``solver_rows · max(nᵢ) · row_bytes`` —
    rows sliced, not merely rows used; the two coincide for uniform
    sizes.  State terms are unchanged (state rows are (N, D) regardless
    of shard sizes).  ``sizes`` is the per-client row-count sequence
    (``RaggedSpec.sizes``); ``row_bytes`` the bytes of one data row
    (x and y together).
    """
    base = fedback_round_hbm_bytes(n_clients, solver_rows, dim,
                                   data_bytes_per_client=0,
                                   dtype_bytes=dtype_bytes)
    sizes = tuple(int(s) for s in sizes)
    total_rows = sum(sizes)
    if solver_rows >= n_clients:  # dense: every CSR slice, streamed once
        solver_data = total_rows * row_bytes
    else:  # compacted: static max-length block slice per slot
        solver_data = solver_rows * max(sizes) * row_bytes
    return {
        "server_bytes": base["server_bytes"],
        "solver_state_bytes": base["solver_state_bytes"],
        "solver_data_bytes": solver_data,
        "solver_bytes": base["solver_state_bytes"] + solver_data,
        "total_bytes": base["server_bytes"] + base["solver_state_bytes"]
        + solver_data,
        "data_rows_total": total_rows,
    }


def host_stream_bytes(n_clients: int, capacity: int, dim: int, *,
                      compress: str = "none",
                      data_bytes_per_client: int = 0,
                      dtype_bytes: int = 4) -> dict[str, float]:
    """Planned host<->device traffic of one host-backend round
    (``state_backend="host"``, ``core.hoststate``) plus the modeled
    stream/solve overlap of the double-buffered working set.

    The byte model mirrors ``make_host_round_fn``'s
    ``round_fn.planned_bytes`` exactly — the pair is what the
    ``host-transfer-budget`` tracecheck rule and the BENCH_round gate
    compare against measured transfer counters:

    * row stream up:    θ, λ gather tiles            → 2·C·D·b
    * row stream down:  θ', λ⁺, z working-set rows   → 3·C·D·b
    * budget:           8·C·D·b (headroom for a future z_prev/EF tile)
    * server pass up:   z_prev (plus the EF residual under
                        ``consensus_compress``)       → N·D·b·{1,2}
    * server pass down: the folded-back EF residual   → N·D·b·{0,1}

    Training data never crosses per round — it is round-static and
    stays device-resident, gathered by slot index inside the solve
    program (the same dataflow as the device backend's compact block).

    ``modeled_overlap_fraction`` is the share of the row stream a
    double-buffered schedule can hide behind the solve compute:
    min(t_solve, t_stream)/t_stream on the PCIe + HBM model.  The
    benchmark reports the measured fraction next to it (≈ 0 on CPU,
    where transfers are memcpys on the compute thread).
    """
    row_h2d = 2 * capacity * dim * dtype_bytes
    row_d2h = 3 * capacity * dim * dtype_bytes
    full_mult = 2 if compress != "none" else 1
    server_h2d = n_clients * dim * dtype_bytes * full_mult
    server_d2h = (n_clients * dim * dtype_bytes
                  if compress != "none" else 0)
    solver = fedback_round_hbm_bytes(
        n_clients, capacity, dim,
        data_bytes_per_client=data_bytes_per_client,
        dtype_bytes=dtype_bytes)
    t_stream = (row_h2d + row_d2h) / PCIE_BW
    t_solve = solver["solver_bytes"] / HBM_BW
    return {
        "row_stream_h2d_bytes": row_h2d,
        "row_stream_d2h_bytes": row_d2h,
        "row_stream_budget_bytes": 8 * capacity * dim * dtype_bytes,
        "server_pass_h2d_bytes": server_h2d,
        "server_pass_d2h_bytes": server_d2h,
        "device_working_set_bytes": 5 * capacity * dim * dtype_bytes,
        "stream_s": t_stream,
        "solve_s": t_solve,
        "modeled_overlap_fraction": (
            min(t_solve, t_stream) / max(t_stream, 1e-30)),
    }


def consensus_collective_s(dim: int, *, mode: str = "none",
                           block: int = 256,
                           world_size: int = 1) -> dict[str, float]:
    """Modeled wire time of one consensus aggregation under
    ``consensus_compress`` (the compressed collective term).

    Delegates the byte model to :func:`repro.core.compress.
    consensus_wire_bytes` — an fp32/int8 ring all-reduce at the wire
    dtype (int8 adds the (nb,) fp32 shared-scale MAX reduce as an
    overhead term), a u16 all-gather for bf16 — and prices it at
    ``LINK_BW``.  The returned dict carries the byte breakdown next to
    ``collective_s`` so BENCH_comm.json can gate bytes and the roofline
    can stack times from the same numbers.
    """
    from repro.core.compress import consensus_wire_bytes

    wire = consensus_wire_bytes(dim, mode=mode, block=block,
                                world_size=world_size)
    return {**wire, "collective_s": wire["total_link_bytes"] / LINK_BW}


def fedback_round_memory_s(n_clients: int, solver_rows: int, dim: int,
                           *, data_bytes_per_client: int = 0,
                           dtype_bytes: int = 4) -> float:
    """Memory roofline term (seconds) of one flat FedBack round."""
    return fedback_round_hbm_bytes(
        n_clients, solver_rows, dim,
        data_bytes_per_client=data_bytes_per_client,
        dtype_bytes=dtype_bytes)["total_bytes"] / HBM_BW


def fedback_async_overlap(n_clients: int, solver_rows: int, dim: int, *,
                          max_staleness: int, n_chips: int = 1,
                          data_bytes_per_client: int = 0,
                          dtype_bytes: int = 4,
                          compress: str = "none",
                          compress_block: int = 256) -> dict[str, float]:
    """Modeled round-time overlap of the stale-tolerant engine.

    The synchronous round's critical path is serial: the solver term
    (gathered state + data through the capacity slots) must finish
    before the server term (trigger read, consensus all-reduce, commit
    writes) can run.  With ``max_staleness ≥ 1`` the commit rule
    tolerates solves landing up to S rounds late, so the solver stream
    of round k overlaps the server/collective stream of rounds
    k..k+S−1 and the steady-state critical path is the *maximum* of the
    two terms, not their sum:

        t_sync  = t_solver + t_server (+ t_collective)
        t_async = max(t_solver, t_server + t_collective)

    The collective term models the consensus all-reduce over the
    ``clients`` mesh (ring all-reduce moves ~2·D bytes per chip);
    under ``compress`` it switches to the compressed wire model
    (:func:`consensus_collective_s`) — the uncompressed default keeps
    the historical conservative no-(n−1)/n-discount formula so
    committed BENCH_round baselines stay comparable.
    Returns both modeled times plus the overlap speedup — the number
    the async rows of BENCH_round.json carry next to the measured
    wall-clock, so the benchmark can show how much of the modeled
    overlap the XLA schedule actually realizes.
    """
    hbm = fedback_round_hbm_bytes(
        n_clients, solver_rows, dim,
        data_bytes_per_client=data_bytes_per_client,
        dtype_bytes=dtype_bytes)
    t_solver = hbm["solver_bytes"] / HBM_BW
    t_server = hbm["server_bytes"] / HBM_BW
    if n_chips <= 1:
        t_coll = 0.0
    elif compress == "none":
        t_coll = 2.0 * dim * dtype_bytes / LINK_BW
    else:
        t_coll = consensus_collective_s(
            dim, mode=compress, block=compress_block,
            world_size=n_chips)["collective_s"]
    t_sync = t_solver + t_server + t_coll
    t_async = (max(t_solver, t_server + t_coll) if max_staleness > 0
               else t_sync)
    return {
        "solver_s": t_solver,
        "server_s": t_server,
        "collective_s": t_coll,
        "modeled_sync_s": t_sync,
        "modeled_async_s": t_async,
        "modeled_overlap_speedup": t_sync / max(t_async, 1e-30),
    }


def summarize(record: dict) -> str:
    r = record
    t = r["roofline"]
    mfu = (r.get("model_flops_per_device", 0.0) /
           max(t["hlo_flops_per_device"], 1.0))
    return (f"{r['arch']:24s} {r['shape']:12s} mesh={r['mesh']:10s} "
            f"compute={t['compute_s']*1e3:9.3f}ms "
            f"memory={t['memory_s']*1e3:9.3f}ms "
            f"coll={t['collective_s']*1e3:9.3f}ms "
            f"dom={t['dominant']:10s} useful/hlo={mfu:5.2f}")
