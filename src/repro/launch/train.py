"""Training launcher.

Two modes:
* ``--engine sim``   — the paper's cross-silo simulation (N clients on
  one host; any algorithm; paper datasets).  This is the e2e driver the
  benchmarks use.
* ``--engine crosspod`` — the distributed FedBack engine on a real mesh
  (pods × data × model).  On TPU hardware this is the production entry
  point; on CPU it runs reduced configs over forced host devices
  (``--host-devices``).

    PYTHONPATH=src python -m repro.launch.train --engine sim \\
        --dataset mnist --algorithm fedback --rate 0.1 --rounds 200
    PYTHONPATH=src python -m repro.launch.train --engine crosspod \\
        --arch granite-3-2b --reduced --rounds 10 --host-devices 8
"""
from __future__ import annotations

import argparse
import os
import sys


def _sim(args):
    import jax
    from repro.configs import paper_cifar, paper_mnist
    from repro.checkpoint import save_checkpoint
    from repro.core import init_state, make_eval_fn, make_round_fn
    from repro.data import federated_arrays, make_synthetic_cifar, \
        make_synthetic_mnist
    from repro.models.mlp import (
        cnn_logits, init_cnn, init_mlp, make_loss_and_acc_fn, make_loss_fn,
        mlp_logits)

    if args.dataset == "mnist":
        ds = make_synthetic_mnist()
        data, test = federated_arrays(ds, n_clients=args.clients,
                                      scheme="label_shard")
        params0, logits = init_mlp(jax.random.PRNGKey(0)), mlp_logits
        cfg = paper_mnist.fl_config(args.algorithm, args.rate,
                                    n_clients=args.clients)
    else:
        ds = make_synthetic_cifar()
        data, test = federated_arrays(ds, n_clients=args.clients,
                                      scheme="dirichlet", beta=0.5)
        params0, logits = init_cnn(jax.random.PRNGKey(0)), cnn_logits
        cfg = paper_cifar.fl_config(args.algorithm, args.rate,
                                    n_clients=args.clients)

    state = init_state(cfg, params0)
    round_fn = make_round_fn(cfg, make_loss_fn(logits), data)
    eval_fn = make_eval_fn(make_loss_and_acc_fn(logits))
    cum = 0
    for k in range(args.rounds):
        state, m = round_fn(state)
        cum += int(m.num_events)
        if k % args.log_every == 0 or k == args.rounds - 1:
            loss, acc = eval_fn(state, test["x"], test["y"])
            print(f"round {k:4d} events={int(m.num_events):3d} cum={cum:6d}"
                  f" loss={float(loss):.4f} acc={float(acc):.4f}",
                  flush=True)
        if args.ckpt_dir and k and k % 100 == 0:
            save_checkpoint(args.ckpt_dir, k, state)


def _crosspod(args):
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import get_config
    from repro.core.controller import ControllerConfig
    from repro.core.crosspod import (
        CrossPodConfig, init_cross_pod_state, make_cross_pod_round)
    from repro.models.api import build_model
    from repro.sharding.actshard import activation_sharding
    from repro.sharding.specs import param_specs, pod_stacked_specs

    n_dev = len(jax.devices())
    pods = args.pods
    rest = n_dev // pods
    dshape = (pods, max(rest // args.model_par, 1), args.model_par)
    mesh = jax.sharding.Mesh(
        np.asarray(jax.devices()[:dshape[0] * dshape[1] * dshape[2]])
        .reshape(dshape), ("pod", "data", "model"))
    print(f"mesh: {dict(mesh.shape)}")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(num_layers=2, d_model=128, vocab_size=512,
                          remat=False)
    model = build_model(cfg)
    cp = CrossPodConfig(
        n_pods=pods, rho=args.rho, lr=args.lr, local_steps=args.local_steps,
        controller=ControllerConfig(K=args.gain, alpha=0.9,
                                    target_rate=args.rate))

    def sharded_loss(params, batch):
        with activation_sharding(mesh, "data"):
            return model.loss(params, batch)

    round_fn = make_cross_pod_round(cp, sharded_loss)
    params0 = model.init(jax.random.PRNGKey(0))
    state = init_cross_pod_state(cp, params0)

    pspec = param_specs(jax.eval_shape(lambda: params0), mesh, mode="fsdp")
    pod_pspec = pod_stacked_specs(pspec)
    named = lambda t: jax.tree.map(
        lambda s: NamedSharding(mesh, s), t,
        is_leaf=lambda x: isinstance(x, P))
    state_sh = type(state)(
        theta=named(pod_pspec), lam=named(pod_pspec),
        z_prev=named(pod_pspec),
        ctrl=jax.tree.map(lambda _: NamedSharding(mesh, P()), state.ctrl),
        rng=NamedSharding(mesh, P()), round=NamedSharding(mesh, P()))
    batch_sh = NamedSharding(mesh, P("pod", None, "data", None))
    step = jax.jit(round_fn, in_shardings=(
        state_sh, {"tokens": batch_sh, "labels": batch_sh}),
        out_shardings=(state_sh, None))

    rng = np.random.default_rng(0)
    state = jax.device_put(state, state_sh)
    cum = 0
    for k in range(args.rounds):
        toks = rng.integers(
            0, cfg.vocab_size,
            (pods, cp.local_steps, args.batch, args.seq + 1))
        batch = jax.device_put(
            {"tokens": jnp.asarray(toks[..., :-1], jnp.int32),
             "labels": jnp.asarray(toks[..., 1:], jnp.int32)}, (
                {"tokens": batch_sh, "labels": batch_sh}))
        state, m = step(state, batch)
        cum += int(m.num_events)
        print(f"round {k:3d} events={np.asarray(m.events).astype(int)} "
              f"cum={cum} loss={float(m.train_loss):.4f}", flush=True)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--engine", default="sim", choices=["sim", "crosspod"])
    # sim
    ap.add_argument("--dataset", default="mnist",
                    choices=["mnist", "cifar"])
    ap.add_argument("--algorithm", default="fedback")
    ap.add_argument("--clients", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--ckpt-dir", default=None)
    # crosspod
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--pods", type=int, default=2)
    ap.add_argument("--model-par", type=int, default=2)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--rho", type=float, default=1e-3)
    ap.add_argument("--lr", type=float, default=5e-3)
    ap.add_argument("--gain", type=float, default=0.05)
    ap.add_argument("--host-devices", type=int, default=0)
    # shared
    ap.add_argument("--rate", type=float, default=0.1)
    ap.add_argument("--rounds", type=int, default=100)
    args = ap.parse_args()

    if args.host_devices and "jax" not in sys.modules:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.host_devices}")
    (_sim if args.engine == "sim" else _crosspod)(args)


if __name__ == "__main__":
    main()
