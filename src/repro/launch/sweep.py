"""Batched experiment runner: a whole sweep compiled as ONE XLA program.

A Table-1 row or a controller-gain ablation is many runs of the same
round program that differ only in the PRNG seed and a few controller
scalars.  Tracing and compiling the program once per run wastes minutes
per row; instead this module vmaps the round program over a flattened
(seed × gain × target-rate) grid and ``lax.scan``s it over rounds, so
the entire sweep lowers to a single XLA program that compiles once.

    runs, final_states, history = run_sweep(
        cfg, loss_fn, data, params0, rounds=100,
        seeds=(0, 1, 2, 3), gains=(0.5, 2.0))

``history`` leaves are (rounds, runs, ...) stacked metrics.  (Lower
level: ``init_sweep`` builds the stacked states + overrides once, and
``make_sweep_fn`` returns the reusable jitted program.)  The gain
overrides flow into the controller at *runtime* (``ctrl_arg`` hook of
``make_round_fn``), so a gain grid does not retrace anything.  Gains
only steer algorithms with a live feedback controller (``fedback``);
for random-selection baselines sweep seeds only.

With ``mesh=`` the client axis (dim 1 of every stacked leaf) is
additionally sharded over a ``clients`` device mesh — sweeps and client
scaling compose.  So does capacity-bounded compaction (``cfg.compact``):
the deferral queue and demand-load EMA live inside ``FLState``
(``FLState.queue``), so they thread through the scan-of-vmap as regular
(runs, N) carry state — every run keeps its own independent queue and
adaptive capacity limit, and ``history.num_deferred`` /
``history.realized_slack`` come back per run.  The stale-tolerant
delay pipeline (``cfg.max_staleness``, ``FLState.inflight``) threads
the same way: per-run in-flight payloads and issued-event rings are
just more (runs, N, ...) carry leaves, with ``history.num_inflight`` /
``history.num_landed`` per run.

Ragged heterogeneous shards (``repro.utils.ragged``) compose too: the
pooled CSR buffer is run-independent like the rectangular shards, so
``make_sweep_fn(..., ragged=spec)`` vmaps state over runs while every
run reads the same pool (``--ragged`` on the CLI).

The host-offloaded backend (``--state-backend host``,
``repro.core.hoststate``) does NOT compose with the scan-of-vmap: its
round is jitted device programs glued by host-side numpy row
gathers/scatters, which ``vmap``/``scan`` cannot trace through.  The
CLI instead runs that grid sequentially — one streaming round engine
per grid point — and prints the same CSV, so a million-client sweep
fits one host at the cost of per-run compiles.

CLI demo (quadratic problem, prints per-run realized rates):

    PYTHONPATH=src python -m repro.launch.sweep --n-clients 64 \
        --seeds 0,1,2,3 --gains 0.5,2.0 --rounds 60
"""
from __future__ import annotations

import argparse
import dataclasses
import itertools
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

from repro.core.fedback import FLConfig, init_state, make_round_fn
from repro.utils.pytree import tree_stack


@dataclasses.dataclass(frozen=True)
class SweepGrid:
    """Flattened run grid: the cartesian product of the given axes."""

    seeds: tuple[int, ...] = (0, 1, 2, 3)
    gains: tuple[float, ...] | None = None  # controller K values
    target_rates: tuple[float, ...] | None = None  # L̄ values

    def runs(self, cfg: FLConfig):
        gains = self.gains if self.gains is not None else (
            cfg.controller.K,)
        targets = self.target_rates if self.target_rates is not None else (
            cfg.participation,)
        return list(itertools.product(self.seeds, gains, targets))


def init_sweep(cfg: FLConfig, params0, grid: SweepGrid, *, spec=None):
    """Stacked initial states (runs, N, ...) + runtime ctrl overrides.

    With ``spec`` (a ``repro.utils.flatstate.FlatSpec``) the stacked
    states use the flat (runs, N, D) layout.
    """
    runs = grid.runs(cfg)
    states = tree_stack([
        init_state(dataclasses.replace(cfg, seed=seed), params0, spec=spec)
        for seed, _, _ in runs
    ])
    overrides = {
        "K": jnp.asarray([k for _, k, _ in runs], jnp.float32),
        "target_rate": jnp.asarray([t for _, _, t in runs], jnp.float32),
    }
    return states, overrides, runs


def make_sweep_fn(cfg: FLConfig, loss_fn: Callable, data: dict[str, Any],
                  *, rounds: int, jit: bool = True, mesh=None,
                  client_axis: str = "clients", spec=None, ragged=None):
    """Build sweep_fn(states, overrides) -> (final_states, history).

    states/overrides come from :func:`init_sweep`; leaves carry a
    leading runs axis.  The whole (rounds × runs × clients) program is
    one jit — XLA sees a single scan-of-vmap and compiles once.  With
    ``spec`` the round runs on the flat (N, D) client-state layout
    (``cfg.compact`` composes: the capacity gather/solve/scatter is
    vmapped over the run axis like everything else).  With ``ragged``
    (a ``repro.utils.ragged.RaggedSpec``) ``data`` is the pooled CSR
    buffer — run-independent like the rectangular shards, so the sweep
    vmaps state while every run reads the same pool.
    """
    if mesh is not None:
        from repro.sharding.clients import check_divisible, \
            replicate_data, shard_client_data
        check_divisible(cfg.n_clients, mesh, axis=client_axis)
        # Commit the (run-independent) client shards to the mesh so GSPMD
        # reads them sharded instead of replicating a full copy per device
        # (the ragged pool has no client axis and stays replicated).
        data = (replicate_data(mesh, data) if ragged is not None
                else shard_client_data(mesh, data, axis=client_axis))
    round_fn = make_round_fn(cfg, loss_fn, data, jit=False, ctrl_arg=True,
                             spec=spec, ragged=ragged)
    vround = jax.vmap(round_fn, in_axes=(0, 0))

    def sweep_fn(states, overrides):
        def body(ss, _):
            ss, metrics = vround(ss, overrides)
            return ss, metrics

        return jax.lax.scan(body, states, None, length=rounds)

    if not jit:
        return sweep_fn
    if mesh is None:
        return jax.jit(sweep_fn)

    from repro.sharding.clients import fl_state_shardings
    state_sh = fl_state_shardings(mesh, axis=client_axis, batched=True)
    # history leaves are (rounds, runs, N?) — client axis at dim 2 for
    # per-client metrics; scalars replicated.  Let GSPMD place history.
    return jax.jit(sweep_fn, in_shardings=(state_sh, None),
                   out_shardings=(state_sh, None))


def run_sweep(cfg: FLConfig, loss_fn: Callable, data: dict[str, Any],
              params0, *, rounds: int,
              seeds: Sequence[int] = (0, 1, 2, 3),
              gains: Sequence[float] | None = None,
              target_rates: Sequence[float] | None = None,
              mesh=None, spec=None, ragged=None):
    """One-call convenience: returns (runs, final_states, history)."""
    grid = SweepGrid(seeds=tuple(seeds),
                     gains=tuple(gains) if gains is not None else None,
                     target_rates=(tuple(target_rates)
                                   if target_rates is not None else None))
    states, overrides, runs = init_sweep(cfg, params0, grid, spec=spec)
    sweep_fn = make_sweep_fn(cfg, loss_fn, data, rounds=rounds, mesh=mesh,
                             spec=spec, ragged=ragged)
    final_states, history = sweep_fn(states, overrides)
    return runs, final_states, history


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n-clients", type=int, default=64)
    ap.add_argument("--rounds", type=int, default=60)
    ap.add_argument("--participation", type=float, default=0.3)
    ap.add_argument("--seeds", default="0,1,2,3")
    ap.add_argument("--gains", default=None,
                    help="comma-separated controller K values")
    ap.add_argument("--devices", type=int, default=0,
                    help="shard the client axis over this many devices "
                         "(0 = single device)")
    ap.add_argument("--tree-layout", action="store_true",
                    help="use the stacked-pytree layout instead of the "
                         "default flat (N, D) client-state layout")
    ap.add_argument("--compact", action="store_true",
                    help="capacity-bounded compaction: solver rows per "
                         "round follow ⌈slack·L̄·N⌉ instead of N "
                         "(lossless — overflow is queue-carried)")
    ap.add_argument("--slack", type=float, default=1.5,
                    help="capacity slack bound (adaptive limit lives in "
                         "[⌈L̄·N⌉, ⌈slack·L̄·N⌉])")
    ap.add_argument("--fused-gss", action="store_true",
                    help="fused gather→ADMM→scatter commit on the "
                         "compacted round (kernels/fused_gss.py): one "
                         "pass over the (N, D) state instead of three; "
                         "needs --compact and the flat layout")
    ap.add_argument("--max-staleness", type=int, default=None,
                    help="stale-tolerant rounds: serviced solves land up "
                         "to this many rounds later (deterministic "
                         "per-client delay schedule; 0 = async pipeline "
                         "that reproduces the synchronous engine bit for "
                         "bit; omit for the synchronous engine)")
    ap.add_argument("--consensus-compress", default="none",
                    choices=("none", "bf16", "int8"),
                    help="compressed consensus wire (core/compress.py): "
                         "clients transmit quantized z-deltas with a "
                         "persistent error-feedback residual; 'none' is "
                         "the exact fp32 aggregation (needs the flat "
                         "layout when != none)")
    ap.add_argument("--state-backend", default="device",
                    choices=("device", "host"),
                    help="where the (N, D) client matrices live "
                         "(repro.core.hoststate): 'host' keeps them in "
                         "host RAM and streams a (C, D) working set "
                         "through the CompactPlan slots — needs "
                         "--compact and the flat layout, and runs the "
                         "grid sequentially (one streaming engine per "
                         "grid point) instead of as one scan-of-vmap "
                         "program")
    ap.add_argument("--ragged", action="store_true",
                    help="heterogeneous client shards: per-client sizes "
                         "drawn seed-deterministically in [n/2, n] points "
                         "and pooled into one CSR buffer "
                         "(repro.utils.ragged) — the engine runs "
                         "size-bucketed masked solves instead of one "
                         "rectangular vmap")
    args = ap.parse_args()

    import numpy as np
    from repro.core.controller import ControllerConfig
    from repro.data import make_least_squares
    from repro.utils.flatstate import make_flat_spec

    cfg = FLConfig(algorithm="fedback", n_clients=args.n_clients,
                   participation=args.participation, rho=1.0, lr=0.1,
                   momentum=0.0, epochs=2, batch_size=8,
                   compact=args.compact, capacity_slack=args.slack,
                   fused_gss=args.fused_gss,
                   max_staleness=args.max_staleness,
                   consensus_compress=args.consensus_compress,
                   controller=ControllerConfig(K=0.2, alpha=0.9))
    data, params0, loss_fn = make_least_squares(args.n_clients)
    ragged = None
    if args.ragged:
        from repro.utils.ragged import pool_data
        n_pts = data["x"].shape[1]
        sizes = np.random.default_rng(0).integers(
            max(n_pts // 2, 1), n_pts + 1, size=args.n_clients)
        data, ragged = pool_data(
            [np.asarray(data["x"][i])[:s] for i, s in enumerate(sizes)],
            [np.asarray(data["y"][i])[:s] for i, s in enumerate(sizes)])
        print(f"# ragged: {ragged.total} pooled rows over "
              f"{args.n_clients} clients, sizes in "
              f"[{ragged.min_size}, {ragged.max_size}], "
              f"{len(ragged.buckets)} solve buckets")
    spec = None if args.tree_layout else make_flat_spec(params0)
    seeds = [int(s) for s in args.seeds.split(",")]
    gains = ([float(g) for g in args.gains.split(",")]
             if args.gains else None)

    if args.state_backend == "host":
        if args.tree_layout:
            raise SystemExit("--state-backend host needs the flat "
                             "(N, D) layout — drop --tree-layout")
        if not args.compact:
            raise SystemExit("--state-backend host needs --compact "
                             "(the streaming round is built on the "
                             "CompactPlan slot indices)")
        if args.devices:
            raise SystemExit("--state-backend host is a single-host "
                             "backend — drop --devices (shard the "
                             "device backend instead)")
        from repro.core import run_rounds
        grid = SweepGrid(seeds=tuple(seeds),
                         gains=tuple(gains) if gains else None)
        print("seed,K,target,realized_rate,realized_slack,queue_depth,"
              "inflight_depth,final_train_loss")
        for seed, k, tgt in grid.runs(cfg):
            rcfg = dataclasses.replace(
                cfg, seed=seed, participation=tgt, state_backend="host",
                controller=cfg.controller._replace(K=k))
            hstate = init_state(rcfg, params0, spec=spec)
            host_rf = make_round_fn(rcfg, loss_fn, data, spec=spec,
                                    ragged=ragged)
            hstate, h = run_rounds(host_rf, hstate, args.rounds)
            print(f"{seed},{k},{tgt},"
                  f"{np.asarray(h.events, np.float32).mean():.3f},"
                  f"{np.asarray(h.realized_slack).mean():.2f},"
                  f"{int(np.asarray(h.num_deferred)[-1])},"
                  f"{int(np.asarray(h.num_inflight)[-1])},"
                  f"{float(np.asarray(h.train_loss)[-1]):.5f}")
        return

    mesh = None
    if args.devices:
        from repro.sharding.clients import make_client_mesh
        mesh = make_client_mesh(args.devices)

    runs, final, hist = run_sweep(cfg, loss_fn, data, params0,
                                  rounds=args.rounds, seeds=seeds,
                                  gains=gains, mesh=mesh, spec=spec,
                                  ragged=ragged)
    rates = np.asarray(jnp.mean(
        hist.events.astype(jnp.float32), axis=(0, 2)))
    slacks = np.asarray(jnp.mean(hist.realized_slack, axis=0))
    queues = np.asarray(hist.num_deferred[-1])
    inflight = np.asarray(hist.num_inflight[-1])
    print("seed,K,target,realized_rate,realized_slack,queue_depth,"
          "inflight_depth,final_train_loss")
    for (seed, k, tgt), rate, slk, q, fl, loss in zip(
            runs, rates, slacks, queues, inflight,
            np.asarray(hist.train_loss[-1]), strict=True):
        print(f"{seed},{k},{tgt},{rate:.3f},{slk:.2f},{int(q)},{int(fl)},"
              f"{loss:.5f}")


if __name__ == "__main__":
    main()
