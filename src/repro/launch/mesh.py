"""Production meshes (TPU v5e).

single-pod: (16, 16)      axes ("data", "model")         — 256 chips
multi-pod:  (2, 16, 16)   axes ("pod", "data", "model")  — 512 chips

Functions, not module constants — importing this module never touches
jax device state (the dry-run launcher must set XLA_FLAGS before any
device query).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, found {len(devices)} — "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before importing jax (launch/dryrun.py does this)")
    import numpy as np
    dev = np.asarray(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(dev, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small host-device mesh for CI (requires the XLA flag set by the
    test's subprocess/session to ≥ prod(shape) host devices)."""
    import numpy as np
    n = 1
    for s in shape:
        n *= s
    dev = np.asarray(jax.devices()[:n]).reshape(shape)
    return jax.sharding.Mesh(dev, axes)
