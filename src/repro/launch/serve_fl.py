"""FL serving launcher: rounds-as-a-service over an arrival trace.

Drives the event-driven scheduler (``repro.core.schedule``) over a
generated client-arrival trace: updates are admitted into free
capacity slots the tick they arrive (no round barrier — the
``CompactPlan`` + ``DeferQueue`` machinery absorbs overflow), the
consensus mean ticks every tick over the freshest z-rows, and the
host loop records per-commit latency into a :class:`ServeReport`.

    PYTHONPATH=src python -m repro.launch.serve_fl --trace bursty \\
        --n-clients 256 --ticks 96 --rate 0.25 --json BENCH_serve.json

``--trace sync`` (everyone fires every tick) reproduces the
synchronous round engine bit for bit — the parity anchor
(tests/test_serve.py).  The LM inference demo lives at
``repro.launch.serve_lm``.
"""
from __future__ import annotations

import argparse
import json


def build_serve_problem(n_clients: int, *, dim: int = 16,
                        n_points: int = 8, seed: int = 0,
                        algorithm: str = "fedback",
                        participation: float = 0.25,
                        compact: bool = True,
                        max_staleness: int | None = None,
                        adaptive_capacity: bool = True,
                        fused_gss: bool | None = False):
    """(cfg, round_fn, state) for a flat-layout serve run on the
    synthetic least-squares problem — shared by the launcher, the
    serve benchmark and the tests."""
    from repro.core.fedback import FLConfig, init_state, make_round_fn
    from repro.data.synthetic import make_least_squares
    from repro.utils.flatstate import make_flat_spec

    data, params0, loss_fn = make_least_squares(
        n_clients, n_points=n_points, dim=dim, seed=seed)
    spec = make_flat_spec(params0)
    cfg = FLConfig(
        algorithm=algorithm, n_clients=n_clients,
        participation=participation, rho=1.0, lr=0.1, momentum=0.0,
        epochs=1, batch_size=4, compact=compact,
        max_staleness=max_staleness,
        adaptive_capacity=adaptive_capacity, fused_gss=fused_gss,
        seed=seed)
    round_fn = make_round_fn(cfg, loss_fn, data, spec=spec,
                             arrivals_arg=True)
    state = init_state(cfg, params0, spec=spec)
    return cfg, round_fn, state


def main(argv=None) -> int:
    from repro.core.schedule import TRACE_KINDS, TraceConfig, make_trace, \
        serve

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--trace", choices=TRACE_KINDS, default="bursty")
    ap.add_argument("--n-clients", type=int, default=256)
    ap.add_argument("--ticks", type=int, default=96)
    ap.add_argument("--rate", type=float, default=0.25,
                    help="mean per-tick arrival probability (and the "
                         "controller's target rate L̄)")
    ap.add_argument("--dim", type=int, default=16)
    ap.add_argument("--algorithm", default="fedback")
    ap.add_argument("--dense", action="store_true",
                    help="dense rounds (default: capacity-bounded "
                         "compaction)")
    ap.add_argument("--max-staleness", type=int, default=None,
                    help="bounded-staleness commit pipeline (default: "
                         "synchronous commits)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", metavar="PATH",
                    help="write the ServeReport summary here")
    args = ap.parse_args(argv)

    cfg, round_fn, state = build_serve_problem(
        args.n_clients, dim=args.dim, seed=args.seed,
        algorithm=args.algorithm, participation=args.rate,
        compact=not args.dense, max_staleness=args.max_staleness)
    trace = make_trace(TraceConfig(
        kind=args.trace, n_clients=args.n_clients, ticks=args.ticks,
        rate=args.rate, seed=args.seed))
    state, report = serve(round_fn, state, trace, warmup=True)

    summary = report.summary()
    print(f"serve[{args.trace}] N={args.n_clients} ticks={args.ticks} "
          f"rate={args.rate} compact={cfg.compact} "
          f"staleness={cfg.max_staleness}")
    for k, v in summary.items():
        print(f"  {k}: {v:.3f}" if isinstance(v, float) else f"  {k}: {v}")
    if not report.conservation_ok:
        print("  WARNING: conservation violated (admitted − commits != "
              "deferred + in-flight)")
    if args.json:
        with open(args.json, "w") as fh:
            json.dump({f"serve_{args.trace}": summary}, fh, indent=2,
                      sort_keys=True)
            fh.write("\n")
        print(f"wrote {args.json}")
    return 0 if report.conservation_ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
