"""Bench-regression gate: diff fresh benchmark artifacts against the
committed baselines.

The perf trajectory of the round engine is tracked by four
machine-readable artifacts — ``BENCH_round.json`` (round wall-clock,
solver rows, modeled HBM split, async overlap), ``BENCH_kernels.json``
(per-kernel µs + modeled traffic), ``BENCH_serve.json`` (the
rounds-as-a-service scheduler: p50/p99 admission→commit latency and
sustained commits/sec under a bursty trace, plus the degenerate-trace
parity flag) and ``BENCH_comm.json`` (the compressed consensus wire:
modeled bytes per round per ``consensus_compress`` mode and
rounds-to-target under compression × participation rate; see
``benchmarks/comm_bench.py``).  This module is the CI gate that keeps
them honest:

* **wall-clock** — any section's ``per_round_us`` regressing more than
  ``--tolerance`` (default 15%) against the committed baseline fails;
* **solver rows** — ``solver_rows_per_round`` may never increase: the
  participation-proportional compute claim is monotone by construction,
  so any increase is a planner/capacity bug, not noise;
* **kernels** — modeled HBM bytes may never increase (deterministic),
  µs compared under the looser ``--kernel-tolerance`` (interpret-mode
  CPU timings are noisy);
* **solver HBM model** — ``modeled_solver_hbm_bytes_per_round`` is
  deterministic and may never increase (a dataflow regression — e.g.
  the fused commit falling back to three passes — not noise);
* **async parity** — the fresh report's ``async_parity`` flag (the
  staleness-0 pipeline tracking the synchronous engine) must hold;
* **fused commit** — ``compact_fused.fused_parity_bitexact`` (the fused
  gather→ADMM→scatter commit tracking the three-pass reference bit for
  bit) and ``compact_fused.roofline_within_15pct`` must hold;
* **host backend** — ``host_parity.host_parity_bitexact`` (the
  streaming host-state round tracking the device backend bit for bit)
  and each ``host_stream_*`` section's ``bytes_match_plan`` /
  ``within_budget`` / ``device_state_sub_full_matrix`` flags gate
  unconditionally; the streamed per-round transfer counters are
  deterministic (2·C·D·4 up, 3·C·D·4 down) and may never increase;
* **serving** — ``serve_parity.serve_parity_bitexact`` (degenerate
  trace ≡ sync engine) and ``serve_bursty.conservation_ok`` gate
  unconditionally; tick-denominated p50/p99 latencies are
  deterministic and may never increase; µs latencies and commits/sec
  gate under the env-fingerprint guard;
* **comm** — modeled consensus wire bytes are deterministic and may
  **never increase** per mode; the int8 payload must stay ≤ 0.3× the
  fp32 term (the acceptance ratio); every compressed leg's
  rounds-to-target must stay within ``--comm-tolerance`` (+2 rounds
  absolute slack) of the fp32 anchor at the same participation rate —
  error feedback failing shows up exactly here.

Wall-clock legs only run when the fresh artifacts carry the same
``_env`` fingerprint (jax version / backend / machine) as the
baselines — cross-machine absolute timings differ by more than any
tolerance, so on a mismatch the timing checks are skipped with a
visible note (``--force-wallclock`` overrides) while the deterministic
checks above still gate.  Same policy as the golden traces.

Two entry modes::

    python -m benchmarks.compare --schema-only  # tier-1: well-formed?
    python -m benchmarks.compare                # nightly: fresh vs base

The nightly ``nightly-bench`` job runs the full diff right after the
benchmark artifacts are produced and uploaded; the tier-1 job runs the
schema check so a malformed baseline commit is caught on every push
without paying for a benchmark run.  Baselines live in
``benchmarks/baselines/`` and are regenerated intentionally by running
the benchmarks with ``BENCH_DIR=benchmarks/baselines``.
"""
from __future__ import annotations

import argparse
import json
import numbers
import os

BASELINE_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            "baselines")
ROUND_JSON = "BENCH_round.json"
KERNELS_JSON = "BENCH_kernels.json"
SERVE_JSON = "BENCH_serve.json"
COMM_JSON = "BENCH_comm.json"

#: BENCH_round.json sections every report must carry, with the keys the
#: gate reads from each.  Extra sections/keys are always allowed — the
#: schema pins the gate's inputs, not the report's full shape.
ROUND_SCHEMA = {
    "dense_flat_n1024": ("per_round_us", "solver_rows_per_round"),
    "dense": ("per_round_us", "solver_rows_per_round"),
    "compact": ("per_round_us", "solver_rows_per_round"),
    "compact_async_s0": ("per_round_us", "solver_rows_per_round"),
    "compact_async_s2": ("per_round_us", "solver_rows_per_round",
                         "modeled_overlap_speedup"),
    "ragged_dirichlet": ("per_round_us", "solver_rows_per_round",
                         "data_rows_total", "uniform_parity_bitexact",
                         "conservation_ok"),
    "compact_fused": ("per_round_us", "solver_rows_per_round",
                      "speedup_vs_dense", "fused_parity_bitexact",
                      "modeled_solver_hbm_bytes_per_round",
                      "roofline_within_15pct"),
    "comparison": ("solver_rows_ratio", "speedup_per_round"),
    "async_parity": ("s0_matches_sync_compact",),
    "sweep": ("steady_us",),
    "host_stream_n65536": ("per_round_us", "solver_rows_per_round",
                           "streamed_h2d_bytes_per_round",
                           "streamed_d2h_bytes_per_round",
                           "bytes_match_plan", "within_budget",
                           "device_state_sub_full_matrix"),
    "host_stream_n1m": ("per_round_us", "solver_rows_per_round",
                        "streamed_h2d_bytes_per_round",
                        "streamed_d2h_bytes_per_round",
                        "bytes_match_plan", "within_budget",
                        "device_state_sub_full_matrix"),
    "host_parity": ("host_parity_bitexact",),
}

#: Host-backend streamed transfer counters: deterministic (a pure
#: function of C and D), so like solver rows they may never increase.
HOST_STREAM_BYTE_KEYS = ("streamed_h2d_bytes_per_round",
                         "streamed_d2h_bytes_per_round")


#: BENCH_serve.json sections/keys the serving-engine gate reads
#: (benchmarks/serve_bench.py emits them; see docs/serving.md).
SERVE_SCHEMA = {
    "serve_bursty": ("p50_latency_ticks", "p99_latency_ticks",
                     "p50_latency_us", "p99_latency_us",
                     "commits_per_sec", "ticks_per_sec",
                     "admitted_total", "commits_total",
                     "conservation_ok"),
    "serve_parity": ("serve_parity_bitexact",),
}

#: Wall-clock serve keys (env-fingerprint-guarded, tolerance-compared:
#: lower is better for latency, higher is better for throughput).
SERVE_LATENCY_KEYS = ("p50_latency_us", "p99_latency_us")
SERVE_THROUGHPUT_KEYS = ("commits_per_sec", "ticks_per_sec")

#: BENCH_comm.json sections/keys the compressed-consensus gate reads
#: (benchmarks/comm_bench.py emits them; see docs/compression.md).
COMM_WIRE_BYTE_KEYS = ("payload_link_bytes", "total_link_bytes",
                       "uplink_bytes_per_client")
COMM_CONV_RATES = (10, 25, 50)  # participation grid, in percent
COMM_MODES = ("none", "bf16", "int8")
COMM_SCHEMA = {
    **{f"wire_{m}": COMM_WIRE_BYTE_KEYS for m in COMM_MODES},
    "wire_ratio": ("int8_vs_fp32", "bf16_vs_fp32"),
    **{f"conv_p{r}_{m}": ("rounds_to_target", "final_loss",
                          "target_loss")
       for r in COMM_CONV_RATES for m in COMM_MODES},
}

#: The acceptance ceiling on the int8-vs-fp32 modeled payload ratio.
COMM_INT8_RATIO_MAX = 0.3


class Gate:
    """Accumulates findings; renders a readable verdict table."""

    def __init__(self):
        self.failures: list[str] = []
        self.notes: list[str] = []

    def fail(self, msg: str) -> None:
        self.failures.append(msg)

    def ok(self, msg: str) -> None:
        self.notes.append(msg)

    def report(self, print_fn=print) -> int:
        for n in self.notes:
            print_fn(f"  ok   {n}")
        for f in self.failures:
            print_fn(f"  FAIL {f}")
        verdict = "FAIL" if self.failures else "PASS"
        print_fn(f"bench-compare,{verdict},"
                 f"failures={len(self.failures)} checks="
                 f"{len(self.notes) + len(self.failures)}")
        return 1 if self.failures else 0


def _load(path: str, gate: Gate, *, required: bool):
    if not os.path.exists(path):
        if required:
            gate.fail(f"missing artifact: {path}")
        return None
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        gate.fail(f"unreadable artifact {path}: {e}")
        return None


def check_round_schema(report: dict, gate: Gate, *, label: str) -> None:
    for section, keys in ROUND_SCHEMA.items():
        entry = report.get(section)
        if not isinstance(entry, dict):
            gate.fail(f"{label}: section '{section}' missing")
            continue
        for key in keys:
            val = entry.get(key)
            if isinstance(val, bool):
                continue  # parity flags
            if not isinstance(val, numbers.Real):
                gate.fail(f"{label}: {section}.{key} missing or "
                          f"non-numeric ({val!r})")
            elif key.endswith("_us") and val <= 0:
                gate.fail(f"{label}: {section}.{key} must be positive, "
                          f"got {val}")
    if not gate.failures:
        gate.ok(f"{label}: schema ({len(ROUND_SCHEMA)} sections)")


def check_serve_schema(report: dict, gate: Gate, *, label: str) -> None:
    before = len(gate.failures)
    for section, keys in SERVE_SCHEMA.items():
        entry = report.get(section)
        if not isinstance(entry, dict):
            gate.fail(f"{label}: section '{section}' missing")
            continue
        for key in keys:
            val = entry.get(key)
            if isinstance(val, bool):
                continue  # parity/conservation flags
            if not isinstance(val, numbers.Real):
                gate.fail(f"{label}: {section}.{key} missing or "
                          f"non-numeric ({val!r})")
            elif val < 0:
                gate.fail(f"{label}: {section}.{key} must be "
                          f"non-negative, got {val}")
    if len(gate.failures) == before:
        gate.ok(f"{label}: schema ({len(SERVE_SCHEMA)} sections)")


def compare_serve(base: dict, fresh: dict, gate: Gate, *,
                  tolerance: float, wallclock: bool = True) -> None:
    """Gate the serving engine: parity and conservation flags are
    deterministic and gate unconditionally; p50/p99 latency and
    sustained commits/sec only on a matching env fingerprint."""
    parity = fresh.get("serve_parity", {})
    if parity.get("serve_parity_bitexact") is not True:
        gate.fail("serve: serve_parity.serve_parity_bitexact is not "
                  "true — the degenerate trace no longer reproduces "
                  "the synchronous round engine")
    else:
        gate.ok("serve: degenerate trace reproduces the sync engine "
                "bit for bit (events AND fp32 ω)")
    bursty = fresh.get("serve_bursty", {})
    if bursty.get("conservation_ok") is not True:
        gate.fail("serve: serve_bursty.conservation_ok is not true "
                  "(admitted − commits != deferred + in-flight)")
    else:
        gate.ok("serve: bursty trace conserves admissions")
    base_bursty = base.get("serve_bursty", {})
    # Tick-denominated latencies are deterministic per seed/config —
    # any increase over the baseline is a scheduler regression.
    for key in ("p50_latency_ticks", "p99_latency_ticks"):
        b, f = base_bursty.get(key), bursty.get(key)
        if not isinstance(b, numbers.Real):
            continue
        if not isinstance(f, numbers.Real):
            gate.fail(f"serve: serve_bursty.{key} missing fresh")
        elif f > b:
            gate.fail(f"serve: {key} increased {b} -> {f} ticks "
                      "(deterministic; any increase fails)")
        else:
            gate.ok(f"serve: {key} {f} <= {b} ticks")
    if not wallclock:
        return
    for key in SERVE_LATENCY_KEYS:
        b, f = base_bursty.get(key), bursty.get(key)
        if isinstance(b, numbers.Real) and b > 0:
            if not isinstance(f, numbers.Real):
                gate.fail(f"serve: serve_bursty.{key} missing fresh")
            elif f > b * (1.0 + tolerance):
                gate.fail(f"serve: {key} regressed {f / b - 1.0:+.1%} "
                          f"({b:.0f} -> {f:.0f} us, tol "
                          f"{tolerance:.0%})")
            else:
                gate.ok(f"serve: {key} {f / b - 1.0:+.1%}")
    for key in SERVE_THROUGHPUT_KEYS:
        b, f = base_bursty.get(key), bursty.get(key)
        if isinstance(b, numbers.Real) and b > 0:
            if not isinstance(f, numbers.Real):
                gate.fail(f"serve: serve_bursty.{key} missing fresh")
            elif f < b * (1.0 - tolerance):
                gate.fail(f"serve: {key} regressed {f / b - 1.0:+.1%} "
                          f"({b:.0f} -> {f:.0f} /s, tol "
                          f"{tolerance:.0%})")
            else:
                gate.ok(f"serve: {key} {f / b - 1.0:+.1%}")


def check_comm_schema(report: dict, gate: Gate, *, label: str) -> None:
    before = len(gate.failures)
    for section, keys in COMM_SCHEMA.items():
        entry = report.get(section)
        if not isinstance(entry, dict):
            gate.fail(f"{label}: section '{section}' missing")
            continue
        for key in keys:
            val = entry.get(key)
            if not isinstance(val, numbers.Real):
                gate.fail(f"{label}: {section}.{key} missing or "
                          f"non-numeric ({val!r})")
            elif val < 0:
                gate.fail(f"{label}: {section}.{key} must be "
                          f"non-negative, got {val}")
    if len(gate.failures) == before:
        gate.ok(f"{label}: schema ({len(COMM_SCHEMA)} sections)")


def compare_comm(base: dict, fresh: dict, gate: Gate, *,
                 comm_tolerance: float) -> None:
    """Gate the compressed consensus wire.  Everything here is
    deterministic (modeled bytes and fixed-seed round counts), so no
    env-fingerprint guard applies."""
    # Modeled wire bytes: never-increase per mode against the baseline.
    for mode in COMM_MODES:
        section = f"wire_{mode}"
        b_entry = base.get(section, {})
        f_entry = fresh.get(section, {})
        for key in COMM_WIRE_BYTE_KEYS:
            b, f = b_entry.get(key), f_entry.get(key)
            if not isinstance(b, numbers.Real):
                continue
            if not isinstance(f, numbers.Real):
                gate.fail(f"comm: {section}.{key} missing fresh")
            elif f > b:
                gate.fail(f"comm: {section}.{key} increased {b} -> {f} "
                          "(modeled; any increase fails)")
            else:
                gate.ok(f"comm: {section}.{key} {f} <= {b}")
    # The acceptance ratio: int8 consensus payload vs the fp32 term.
    ratio = fresh.get("wire_ratio", {}).get("int8_vs_fp32")
    if not isinstance(ratio, numbers.Real):
        gate.fail("comm: wire_ratio.int8_vs_fp32 missing fresh")
    elif ratio > COMM_INT8_RATIO_MAX:
        gate.fail(f"comm: int8 payload is {ratio:.3f}x the fp32 "
                  f"consensus term (must be <= {COMM_INT8_RATIO_MAX})")
    else:
        gate.ok(f"comm: int8 payload {ratio:.3f}x fp32 <= "
                f"{COMM_INT8_RATIO_MAX}")
    # Convergence: every compressed leg within tolerance of the fp32
    # anchor at the same participation rate (fresh-vs-fresh — the
    # anchor travels with the run, so backend changes can't skew it).
    for rate in COMM_CONV_RATES:
        anchor = fresh.get(f"conv_p{rate}_none", {}).get(
            "rounds_to_target")
        if not isinstance(anchor, numbers.Real):
            gate.fail(f"comm: conv_p{rate}_none.rounds_to_target "
                      "missing fresh")
            continue
        for mode in COMM_MODES[1:]:
            rtt = fresh.get(f"conv_p{rate}_{mode}", {}).get(
                "rounds_to_target")
            cap = anchor * (1.0 + comm_tolerance) + 2
            if not isinstance(rtt, numbers.Real):
                gate.fail(f"comm: conv_p{rate}_{mode}.rounds_to_target "
                          "missing fresh")
            elif rtt > cap:
                gate.fail(
                    f"comm: {mode} at p={rate}% needs {rtt} rounds to "
                    f"target vs fp32 anchor {anchor} (cap {cap:.1f}) — "
                    "error feedback is not tracking the uncompressed "
                    "consensus")
            else:
                gate.ok(f"comm: p{rate}% {mode} rounds-to-target "
                        f"{rtt} (anchor {anchor})")


def check_kernels_schema(report: dict, gate: Gate, *, label: str) -> None:
    if not isinstance(report, dict) or not report:
        gate.fail(f"{label}: empty or non-dict kernel report")
        return
    bad = [k for k, v in report.items()
           if not k.startswith("_")  # metadata (e.g. _env fingerprint)
           and (not isinstance(v, dict)
                or not (v.get("us_per_call") is None  # modeled-only rows
                        or isinstance(v.get("us_per_call"), numbers.Real)))]
    if bad:
        gate.fail(f"{label}: kernels missing numeric us_per_call: {bad}")
    else:
        gate.ok(f"{label}: schema ({len(report)} kernels)")


def wallclock_comparable(base: dict | None, fresh: dict | None,
                         gate: Gate, *, label: str,
                         force: bool) -> bool:
    """Timings are only meaningful on a matching env fingerprint.

    The committed baselines carry the machine they were measured on
    (``_env``); on a different jaxlib/arch/backend the absolute
    wall-clock differs by far more than any regression tolerance, so
    the timing legs are skipped (with a visible note) and only the
    deterministic checks — solver rows, modeled bytes, parity flags,
    schema — gate the run.  ``--force-wallclock`` overrides (e.g. for
    pinned self-hosted runners); baselines regenerated on the CI runner
    class re-enable the timing legs automatically."""
    b_env = (base or {}).get("_env")
    f_env = (fresh or {}).get("_env")
    if force or (b_env is not None and b_env == f_env):
        return True
    gate.ok(f"{label}: wall-clock legs skipped — env mismatch "
            f"(baseline {b_env!r}, fresh {f_env!r}); deterministic "
            "checks still gate")
    return False


def compare_round(base: dict, fresh: dict, gate: Gate, *,
                  tolerance: float, wallclock: bool = True) -> None:
    for section, entry in base.items():
        if not isinstance(entry, dict):
            continue
        fresh_entry = fresh.get(section)
        if not isinstance(fresh_entry, dict):
            gate.fail(f"round: section '{section}' vanished from the "
                      "fresh report")
            continue
        b_us, f_us = entry.get("per_round_us"), \
            fresh_entry.get("per_round_us")
        if wallclock and isinstance(b_us, numbers.Real) and b_us > 0:
            if not isinstance(f_us, numbers.Real):
                gate.fail(f"round: {section}.per_round_us missing fresh")
            elif f_us > b_us * (1.0 + tolerance):
                gate.fail(
                    f"round: {section} wall-clock regressed "
                    f"{f_us / b_us - 1.0:+.1%} "
                    f"({b_us:.0f} -> {f_us:.0f} us, tol "
                    f"{tolerance:.0%})")
            else:
                gate.ok(f"round: {section} per_round_us "
                        f"{f_us / b_us - 1.0:+.1%}")
        b_rows = entry.get("solver_rows_per_round")
        f_rows = fresh_entry.get("solver_rows_per_round")
        if isinstance(b_rows, numbers.Real):
            if not isinstance(f_rows, numbers.Real):
                gate.fail(f"round: {section}.solver_rows_per_round "
                          "missing fresh")
            elif f_rows > b_rows:
                gate.fail(
                    f"round: {section} solver rows increased "
                    f"{b_rows} -> {f_rows} (any increase fails)")
            else:
                gate.ok(f"round: {section} solver rows {f_rows} <= "
                        f"{b_rows}")
        # The modeled solver-HBM split is deterministic (a pure function
        # of N/C/D and the roofline formulas), so like solver rows it
        # may never increase — an increase is a model or dataflow
        # regression (e.g. the fused commit falling back to three
        # passes), not noise.
        b_hbm = entry.get("modeled_solver_hbm_bytes_per_round")
        f_hbm = fresh_entry.get("modeled_solver_hbm_bytes_per_round")
        if isinstance(b_hbm, numbers.Real):
            if not isinstance(f_hbm, numbers.Real):
                gate.fail(f"round: {section}."
                          "modeled_solver_hbm_bytes_per_round missing "
                          "fresh")
            elif f_hbm > b_hbm:
                gate.fail(f"round: {section} modeled solver HBM bytes "
                          f"increased {b_hbm} -> {f_hbm} (any increase "
                          "fails)")
            else:
                gate.ok(f"round: {section} solver HBM bytes {f_hbm} <= "
                        f"{b_hbm}")
        # Host-backend streamed bytes: deterministic per-round transfer
        # counters (2·C·D·4 up, 3·C·D·4 down) — any increase means the
        # streaming round started moving rows the plan doesn't price.
        for key in HOST_STREAM_BYTE_KEYS:
            b_sb, f_sb = entry.get(key), fresh_entry.get(key)
            if not isinstance(b_sb, numbers.Real):
                continue
            if not isinstance(f_sb, numbers.Real):
                gate.fail(f"round: {section}.{key} missing fresh")
            elif f_sb > b_sb:
                gate.fail(f"round: {section} {key} increased "
                          f"{b_sb} -> {f_sb} (any increase fails)")
            else:
                gate.ok(f"round: {section} {key} {f_sb} <= {b_sb}")
    parity = fresh.get("async_parity", {})
    if parity.get("s0_matches_sync_compact") is not True:
        gate.fail("round: async_parity.s0_matches_sync_compact is not "
                  "true in the fresh report")
    else:
        gate.ok("round: staleness-0 pipeline tracks the synchronous "
                "engine")
    ragged = fresh.get("ragged_dirichlet", {})
    for flag, meaning in (("uniform_parity_bitexact",
                           "uniform ragged tracks the rectangular "
                           "compact engine bit for bit"),
                          ("conservation_ok",
                           "ragged pool conserves every data point")):
        if ragged.get(flag) is not True:
            gate.fail(f"round: ragged_dirichlet.{flag} is not true in "
                      "the fresh report")
        else:
            gate.ok(f"round: {meaning}")
    fused = fresh.get("compact_fused", {})
    for flag, meaning in (("fused_parity_bitexact",
                           "fused commit tracks the three-pass "
                           "reference bit for bit (events AND ω)"),
                          ("roofline_within_15pct",
                           "fused round solver-state model within 15% "
                           "of the kernel roofline")):
        if fused.get(flag) is not True:
            gate.fail(f"round: compact_fused.{flag} is not true in the "
                      "fresh report")
        else:
            gate.ok(f"round: {meaning}")
    if fresh.get("host_parity", {}).get("host_parity_bitexact") is not True:
        gate.fail("round: host_parity.host_parity_bitexact is not true "
                  "in the fresh report")
    else:
        gate.ok("round: host backend tracks the device backend bit for "
                "bit (events AND fp32 ω/θ/λ/z_prev)")
    for section in ("host_stream_n65536", "host_stream_n1m"):
        entry = fresh.get(section, {})
        for flag, meaning in (
                ("bytes_match_plan",
                 "measured transfers equal the planned byte model"),
                ("within_budget",
                 "planned row stream within the 8·C·D·4 budget"),
                ("device_state_sub_full_matrix",
                 "device-resident client state below one full (N, D) "
                 "matrix")):
            if entry.get(flag) is not True:
                gate.fail(f"round: {section}.{flag} is not true in the "
                          "fresh report")
            else:
                gate.ok(f"round: {section} — {meaning}")


def compare_kernels(base: dict, fresh: dict, gate: Gate, *,
                    tolerance: float, wallclock: bool = True) -> None:
    for name, entry in base.items():
        if name.startswith("_") or not isinstance(entry, dict):
            continue  # metadata (e.g. _env fingerprint)
        fresh_entry = fresh.get(name)
        if not isinstance(fresh_entry, dict):
            gate.fail(f"kernels: '{name}' vanished from the fresh report")
            continue
        b_bytes = entry.get("modeled_hbm_bytes")
        f_bytes = fresh_entry.get("modeled_hbm_bytes")
        if isinstance(b_bytes, numbers.Real) \
                and isinstance(f_bytes, numbers.Real) and f_bytes > b_bytes:
            gate.fail(f"kernels: {name} modeled HBM bytes increased "
                      f"{b_bytes} -> {f_bytes}")
        b_us, f_us = entry.get("us_per_call"), \
            fresh_entry.get("us_per_call")
        if wallclock and isinstance(b_us, numbers.Real) and b_us > 0 \
                and isinstance(f_us, numbers.Real):
            if f_us > b_us * (1.0 + tolerance):
                gate.fail(f"kernels: {name} regressed "
                          f"{f_us / b_us - 1.0:+.1%} ({b_us:.0f} -> "
                          f"{f_us:.0f} us, tol {tolerance:.0%})")
            else:
                gate.ok(f"kernels: {name} {f_us / b_us - 1.0:+.1%}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline-dir", default=BASELINE_DIR,
                    help="directory of the committed baseline artifacts")
    ap.add_argument("--fresh-dir", default=os.environ.get("BENCH_DIR", "."),
                    help="directory of the freshly produced artifacts "
                         "(default: $BENCH_DIR or .)")
    ap.add_argument("--tolerance", type=float, default=0.15,
                    help="per-round wall-clock regression tolerance "
                         "(fraction; default 0.15)")
    ap.add_argument("--kernel-tolerance", type=float, default=0.5,
                    help="kernel microbench regression tolerance "
                         "(looser: interpret-mode CPU timings)")
    ap.add_argument("--comm-tolerance", type=float, default=0.25,
                    help="rounds-to-target tolerance of the compressed "
                         "legs vs the fp32 anchor (fraction, plus 2 "
                         "rounds absolute slack; default 0.25)")
    ap.add_argument("--schema-only", action="store_true",
                    help="validate the committed baselines' schema and "
                         "exit (no fresh artifacts needed — the fast "
                         "tier-1 check)")
    ap.add_argument("--force-wallclock", action="store_true",
                    help="compare timings even when the baseline's env "
                         "fingerprint differs from the fresh run's "
                         "(for pinned self-hosted runners)")
    args = ap.parse_args(argv)

    gate = Gate()
    base_round = _load(os.path.join(args.baseline_dir, ROUND_JSON), gate,
                       required=True)
    base_kernels = _load(os.path.join(args.baseline_dir, KERNELS_JSON),
                         gate, required=True)
    base_serve = _load(os.path.join(args.baseline_dir, SERVE_JSON), gate,
                       required=True)
    base_comm = _load(os.path.join(args.baseline_dir, COMM_JSON), gate,
                      required=True)
    if base_round is not None:
        check_round_schema(base_round, gate, label="baseline round")
    if base_kernels is not None:
        check_kernels_schema(base_kernels, gate, label="baseline kernels")
    if base_serve is not None:
        check_serve_schema(base_serve, gate, label="baseline serve")
    if base_comm is not None:
        check_comm_schema(base_comm, gate, label="baseline comm")

    if not args.schema_only:
        fresh_round = _load(os.path.join(args.fresh_dir, ROUND_JSON), gate,
                            required=True)
        fresh_kernels = _load(os.path.join(args.fresh_dir, KERNELS_JSON),
                              gate, required=True)
        if base_round is not None and fresh_round is not None:
            check_round_schema(fresh_round, gate, label="fresh round")
            compare_round(base_round, fresh_round, gate,
                          tolerance=args.tolerance,
                          wallclock=wallclock_comparable(
                              base_round, fresh_round, gate,
                              label="round", force=args.force_wallclock))
        if base_kernels is not None and fresh_kernels is not None:
            compare_kernels(base_kernels, fresh_kernels, gate,
                            tolerance=args.kernel_tolerance,
                            wallclock=wallclock_comparable(
                                base_kernels, fresh_kernels, gate,
                                label="kernels",
                                force=args.force_wallclock))
        fresh_serve = _load(os.path.join(args.fresh_dir, SERVE_JSON), gate,
                            required=True)
        if base_serve is not None and fresh_serve is not None:
            check_serve_schema(fresh_serve, gate, label="fresh serve")
            compare_serve(base_serve, fresh_serve, gate,
                          tolerance=args.tolerance,
                          wallclock=wallclock_comparable(
                              base_serve, fresh_serve, gate,
                              label="serve", force=args.force_wallclock))
        fresh_comm = _load(os.path.join(args.fresh_dir, COMM_JSON), gate,
                           required=True)
        if base_comm is not None and fresh_comm is not None:
            check_comm_schema(fresh_comm, gate, label="fresh comm")
            compare_comm(base_comm, fresh_comm, gate,
                         comm_tolerance=args.comm_tolerance)

    return gate.report()


if __name__ == "__main__":
    raise SystemExit(main())
