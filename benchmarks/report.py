"""Generate EXPERIMENTS.md-ready markdown tables from the cached
artifacts (dry-run JSONs + paper-trace JSONs)."""
from __future__ import annotations

import glob
import json
import os

from repro.configs import ARCHITECTURES, INPUT_SHAPES


def roofline_markdown(dryrun_dir="experiments/dryrun",
                      sharding="fsdp") -> str:
    recs = {}
    for path in glob.glob(os.path.join(dryrun_dir, f"*__{sharding}.json")):
        with open(path) as f:
            r = json.load(f)
        recs[(r["arch"], r["shape"], r["mesh"])] = r
    lines = [
        "| arch | shape | mesh | compute | memory | collective | dominant "
        "| useful/HLO | HBM est (analytic) | fits 16GiB | compile |",
        "|---|---|---|---|---|---|---|---|---|---|---|".replace(
            "|---|---|---|---|---|---|---|---|---|---|---|",
            "|---|---|---|---:|---:|---:|---|---:|---:|---|---:|"),
    ]
    for arch in ARCHITECTURES:
        for shape in INPUT_SHAPES:
            for mesh in ("16x16", "2x16x16"):
                r = recs.get((arch, shape, mesh))
                if r is None:
                    lines.append(f"| {arch} | {shape} | {mesh} | — | — | — "
                                 f"| *missing* | | | | |")
                    continue
                if r["status"] == "skipped":
                    lines.append(
                        f"| {arch} | {shape} | {mesh} | | | | "
                        f"**skip**: {r['reason'][:60]} | | | | |")
                    continue
                if r["status"] != "ok":
                    lines.append(f"| {arch} | {shape} | {mesh} | | | | "
                                 f"**error** | | | | |")
                    continue
                t = r["roofline"]
                lines.append(
                    f"| {arch} | {shape} | {mesh} "
                    f"| {t['compute_s']*1e3:.1f} ms "
                    f"| {t['memory_s']*1e3:.1f} ms "
                    f"| {t['collective_s']*1e3:.1f} ms "
                    f"| **{t['dominant']}** "
                    f"| {(r.get('useful_flops_ratio') or 0):.2f} "
                    f"| {r.get('analytic_hbm_bytes', 0)/2**30:.1f} GiB "
                    f"| {'✓' if r.get('fits_hbm_16GiB') else '✗'} "
                    f"| {r.get('compile_s', 0):.0f}s |")
    return "\n".join(lines)


def paper_tables_markdown(cache_dir="experiments/paper",
                          preset="quick") -> str:
    from .common import (accuracy_variance, events_to_accuracy,
                         realized_rate)
    traces = []
    for path in glob.glob(os.path.join(cache_dir, f"*_{preset}_s0.json")):
        with open(path) as f:
            traces.append(json.load(f))
    if not traces:
        return "(no cached traces)"
    out = ["### Events-to-target (Tab. 1 analogue)", "",
           "| dataset | L̄ | FedBack | FedADMM | FedAvg | FedProx |",
           "|---|---:|---:|---:|---:|---:|"]
    key = {}
    for t in traces:
        key[(t["dataset"], t["rate"], t["algorithm"])] = t
    rates = sorted({t["rate"] for t in traces})
    dsets = sorted({t["dataset"] for t in traces})
    for ds in dsets:
        for r in rates:
            row = [f"| {ds} | {r} "]
            for alg in ("fedback", "fedadmm", "fedavg", "fedprox"):
                t = key.get((ds, r, alg))
                e = events_to_accuracy(t) if t else None
                row.append(f"| {e if e is not None else 'N/A'} ")
            out.append("".join(row) + "|")
    out += ["", "### Realized participation (Tab. 2 analogue)", "",
            "| dataset | L̄ | realized | abs err |", "|---|---:|---:|---:|"]
    for ds in dsets:
        for r in rates:
            t = key.get((ds, r, "fedback"))
            if t:
                rr = realized_rate(t)
                out.append(f"| {ds} | {r} | {rr:.4f} | {abs(rr-r):.4f} |")
    out += ["", "### Tail accuracy step-variance (Fig. 1 claim)", "",
            "| dataset | L̄ | FedBack | FedADMM | FedAvg | FedProx |",
            "|---|---:|---:|---:|---:|---:|"]
    for ds in dsets:
        for r in rates:
            row = [f"| {ds} | {r} "]
            for alg in ("fedback", "fedadmm", "fedavg", "fedprox"):
                t = key.get((ds, r, alg))
                row.append(f"| {accuracy_variance(t):.2e} " if t else "| ")
            out.append("".join(row) + "|")
    return "\n".join(out)


if __name__ == "__main__":
    import sys
    which = sys.argv[1] if len(sys.argv) > 1 else "roofline"
    if which == "roofline":
        print(roofline_markdown(*sys.argv[2:]))
    else:
        print(paper_tables_markdown(*sys.argv[2:]))
