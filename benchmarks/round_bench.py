"""Round-engine benchmarks: the client-sharded simulation at scale.

Demonstrates the scaling claims of the device-mesh round engine:

* one FedBack round at **N ≥ 1000 clients** as a single XLA program
  (flat (N, D) client-state layout; sharded over every available local
  device via the ``clients`` mesh when more than one is present),
* **participation-proportional compute**: at L̄=0.25, slack=1.5 the
  capacity-bounded compacted round runs ⌈slack·L̄·N⌉ solver rows per
  round (≤ 0.5× the dense path's N) — state *and* data are gathered
  through the capacity slots, so the solver-side HBM model scales with
  C, not N — with training curves statistically matching the dense
  engine on the synthetic least-squares workload.  The deferral queue
  makes the compaction lossless (carried overflow, realized adaptive
  slack reported per section),
* a **multi-seed × controller-gain sweep compiled as ONE program**
  (scan-of-vmap, see ``repro.launch.sweep``).

Emits CSV rows (name, value, derived context) *and* a machine-readable
``BENCH_round.json`` (wall-clock per round, solver rows per round,
modeled server/solver HBM bytes from ``repro.launch.roofline``) — the
artifact the perf trajectory tracks.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ControllerConfig, FLConfig, init_state, \
    make_flat_spec, make_round_fn, pool_data, run_rounds
from repro.core.compact import capacity_for
from repro.data import make_least_squares
from repro.kernels.fused_gss import fused_gss_hbm_bytes
from repro.launch.roofline import fedback_async_overlap, \
    fedback_ragged_round_hbm_bytes, fedback_round_hbm_bytes, \
    host_stream_bytes
from repro.launch.sweep import init_sweep, make_sweep_fn, SweepGrid

BENCH_DIR = os.environ.get("BENCH_DIR", ".")


def _env_fingerprint() -> str:
    """Environment the wall-clock numbers were measured on — the
    bench-regression gate only compares timings on a matching
    fingerprint (same guard as the golden traces); rows/bytes/parity
    are compared unconditionally."""
    import platform
    return (f"jax={jax.__version__};backend={jax.default_backend()};"
            f"machine={platform.machine()}")


def _cfg(n_clients: int, n_points: int, **kw) -> FLConfig:
    base = dict(algorithm="fedback", n_clients=n_clients,
                participation=0.2, rho=1.0, lr=0.1, momentum=0.0,
                epochs=1, batch_size=n_points,
                controller=ControllerConfig(K=0.5, alpha=0.9))
    base.update(kw)
    return FLConfig(**base)


def _data_bytes_per_client(data) -> int:
    """fp32 bytes of one client's (x, y) shard — the data the solver
    streams per capacity slot."""
    per = 0
    for leaf in jax.tree.leaves(data):
        per += int(np.prod(leaf.shape[1:])) * 4
    return per


def _timed_rounds(round_fn, state, rounds: int, *, repeats: int = 1):
    """(compile_s, per_round_us, final_state, stacked_metrics).

    Round 0 doubles as the compile warm-up for timing purposes but its
    metrics are kept — it carries the full-participation burst (and,
    compacted, the dominant deferral term), so dropping it would skew
    the reported totals.  ``repeats`` re-times additional passes
    (continuing from the evolved state — same compiled program) and
    reports the **minimum** per-round time: small rounds are a couple
    of ms on CPU, where a single pass is scheduler-noise-dominated and
    would flake the ±15% bench-regression gate; the min over passes is
    the standard noise-robust wall-clock estimator.  Metrics come from
    the first pass only, so the reported trajectories stay those of
    rounds 0..rounds."""
    t0 = time.perf_counter()
    state, m0 = jax.block_until_ready(round_fn(state))
    compile_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    state, hist = run_rounds(round_fn, state, rounds)
    hist = jax.device_get(jax.block_until_ready(hist))
    per_round_us = (time.perf_counter() - t0) / rounds * 1e6
    for _ in range(repeats - 1):
        t0 = time.perf_counter()
        state, extra = run_rounds(round_fn, state, rounds)
        jax.block_until_ready(extra)
        per_round_us = min(per_round_us,
                           (time.perf_counter() - t0) / rounds * 1e6)
    m0 = jax.device_get(m0)
    hist = jax.tree.map(
        lambda first, rest: np.concatenate(
            [np.asarray(first)[None], np.asarray(rest)]), m0, hist)
    return compile_s, per_round_us, state, hist


def run(print_fn=print, *, n_clients: int = 1024, n_points: int = 16,
        dim: int = 64, rounds: int = 5, compact_clients: int = 256,
        compact_rounds: int = 40, sweep_clients: int = 256,
        sweep_seeds: int = 4, sweep_gains: int = 2, sweep_rounds: int = 40):
    report: dict = {}
    data, params0, loss_fn = make_least_squares(n_clients, n_points, dim)
    spec = make_flat_spec(params0)
    cfg = _cfg(n_clients, n_points)

    # --- N >= 1000 client round (sharded over all local devices) -------
    n_dev = len(jax.devices())
    mesh = None
    if n_dev > 1:
        from repro.sharding.clients import make_client_mesh
        usable = max(d for d in range(1, n_dev + 1) if n_clients % d == 0)
        mesh = make_client_mesh(usable)
    state = init_state(cfg, params0, mesh=mesh, spec=spec)
    round_fn = make_round_fn(cfg, loss_fn, data, mesh=mesh, spec=spec)
    compile_s, per_round_us, state, hist = _timed_rounds(
        round_fn, state, rounds, repeats=3)
    devs = mesh.devices.size if mesh is not None else 1
    print_fn(f"fedback_round_n{n_clients},{per_round_us:.1f},"
             f"devices={devs} compile_s={compile_s:.2f} "
             f"events_r{rounds}={int(hist.num_events[-1])}")
    hbm = fedback_round_hbm_bytes(
        n_clients, n_clients, spec.dim,
        data_bytes_per_client=_data_bytes_per_client(data))
    report["dense_flat_n1024"] = {
        "n_clients": n_clients, "dim": spec.dim, "devices": devs,
        "per_round_us": per_round_us, "compile_s": compile_s,
        "solves_per_round": n_clients,
        "solver_rows_per_round": n_clients,
        "modeled_hbm_bytes_per_round": hbm["total_bytes"],
        "modeled_solver_hbm_bytes_per_round": hbm["solver_bytes"],
        "modeled_server_hbm_bytes_per_round": hbm["server_bytes"],
    }

    # --- participation-proportional compute: dense vs compacted --------
    rate, slack = 0.25, 1.5
    cdata, cparams0, closs = make_least_squares(compact_clients, n_points,
                                                dim)
    cspec = make_flat_spec(cparams0)
    curves = {}
    for name, compact in (("dense", False), ("compact", True)):
        ccfg = _cfg(compact_clients, n_points, participation=rate,
                    compact=compact, capacity_slack=slack)
        cstate = init_state(ccfg, cparams0, spec=cspec)
        crf = make_round_fn(ccfg, closs, cdata, spec=cspec)
        c_s, us, cstate, chist = _timed_rounds(crf, cstate,
                                               compact_rounds,
                                               repeats=3)
        solves = (capacity_for(compact_clients, rate, slack) if compact
                  else compact_clients)
        curves[name] = np.asarray(chist.train_loss, np.float64)
        chbm = fedback_round_hbm_bytes(
            compact_clients, int(solves), cspec.dim,
            data_bytes_per_client=_data_bytes_per_client(cdata))
        report[name] = {
            "n_clients": compact_clients, "dim": cspec.dim,
            "participation": rate, "capacity_slack": slack,
            "rounds": compact_rounds + 1,  # incl. the warm-up round 0
            "per_round_us": us, "compile_s": c_s,
            "solves_per_round": int(solves),
            "solver_rows_per_round": int(solves),
            # num_deferred is the queue *length* after each round, so the
            # sum counts client-rounds spent waiting (a client carried k
            # rounds contributes k), not deferral events.
            "deferred_client_rounds": int(np.sum(chist.num_deferred)),
            "queue_depth_final": int(np.asarray(chist.num_deferred)[-1]),
            "realized_slack_mean": float(
                np.mean(np.asarray(chist.realized_slack))),
            "realized_capacity_mean": float(
                np.mean(np.asarray(chist.realized_capacity))),
            "modeled_hbm_bytes_per_round": chbm["total_bytes"],
            "modeled_solver_hbm_bytes_per_round": chbm["solver_bytes"],
            "modeled_server_hbm_bytes_per_round": chbm["server_bytes"],
            "train_loss_curve": curves[name].tolist(),
            "final_train_loss": float(curves[name][-1]),
        }
        print_fn(f"fedback_{name}_n{compact_clients},{us:.1f},"
                 f"solves_per_round={int(solves)} "
                 f"realized_slack={report[name]['realized_slack_mean']:.2f} "
                 f"final_loss={curves[name][-1]:.5f}")

    tail = max(compact_rounds // 4, 1)
    d_tail = float(np.mean(curves["dense"][-tail:]))
    c_tail = float(np.mean(curves["compact"][-tail:]))
    ratio = report["compact"]["solves_per_round"] / \
        report["dense"]["solves_per_round"]
    rel = abs(c_tail - d_tail) / max(abs(d_tail), 1e-12)
    report["comparison"] = {
        "solver_rows_ratio": ratio,
        "solver_hbm_bytes_ratio": (
            report["compact"]["modeled_solver_hbm_bytes_per_round"]
            / report["dense"]["modeled_solver_hbm_bytes_per_round"]),
        "tail_loss_dense": d_tail,
        "tail_loss_compact": c_tail,
        "tail_loss_rel_err": rel,
        "curves_match": bool(rel < 0.1),
        "speedup_per_round": (report["dense"]["per_round_us"]
                              / max(report["compact"]["per_round_us"], 1e-9)),
    }
    print_fn(f"fedback_compact_vs_dense,{ratio:.3f},"
             f"tail_loss_rel_err={rel:.4f} "
             f"speedup={report['comparison']['speedup_per_round']:.2f}x")

    # --- fused gather→ADMM→scatter commit at N >= 1000 -----------------
    # The compacted round at benchmark scale with the fused commit
    # (kernels/fused_gss.py): λ⁺/z re-derived and scattered in ONE pass
    # over the (N, D) state instead of the reference three-scatter
    # commit.  Timed against the dense N=1024 round above (same N, same
    # D — the perf claim of this path), with the reference compacted
    # engine re-run at the same config to pin bit-parity (events AND ω)
    # as a benchmark flag the nightly compare job gates on.
    fcfg = _cfg(n_clients, n_points, participation=rate, compact=True,
                capacity_slack=slack, fused_gss=True)
    fstate = init_state(fcfg, params0, mesh=mesh, spec=spec)
    frf = make_round_fn(fcfg, loss_fn, data, mesh=mesh, spec=spec)
    f_s, f_us, fstate, fhist = _timed_rounds(frf, fstate, rounds,
                                             repeats=3)
    f_solves = capacity_for(n_clients, rate, slack)
    fhbm = fedback_round_hbm_bytes(
        n_clients, int(f_solves), spec.dim,
        data_bytes_per_client=_data_bytes_per_client(data), fused=True)
    # The kernel-level roofline the round-level solver-state model must
    # stay within 15% of — drift between the two means the round model
    # stopped tracking what the kernel actually streams.
    kernel_roofline = fused_gss_hbm_bytes(int(f_solves), spec.dim,
                                          with_z=True, presolve=True)
    roof_ratio = fhbm["solver_state_bytes"] / kernel_roofline
    # Bit-parity vs the reference three-pass commit, fresh states.
    refcfg = _cfg(n_clients, n_points, participation=rate, compact=True,
                  capacity_slack=slack, fused_gss=False)
    pf_state = init_state(fcfg, params0, mesh=mesh, spec=spec)
    pr_state = init_state(refcfg, params0, mesh=mesh, spec=spec)
    pr_rf = make_round_fn(refcfg, loss_fn, data, mesh=mesh, spec=spec)
    pf_state, pf_hist = run_rounds(frf, pf_state, 10)
    pr_state, pr_hist = run_rounds(pr_rf, pr_state, 10)
    fused_parity = bool(
        np.array_equal(np.asarray(pf_hist.events),
                       np.asarray(pr_hist.events))
        and np.asarray(pf_state.omega, np.float32).tobytes()
        == np.asarray(pr_state.omega, np.float32).tobytes())
    speedup = report["dense_flat_n1024"]["per_round_us"] / max(f_us, 1e-9)
    report["compact_fused"] = {
        "n_clients": n_clients, "dim": spec.dim, "devices": devs,
        "participation": rate, "capacity_slack": slack,
        "rounds": rounds + 1,
        "per_round_us": f_us, "compile_s": f_s,
        "solves_per_round": int(f_solves),
        "solver_rows_per_round": int(f_solves),
        "speedup_vs_dense": speedup,
        "speedup_ok": bool(speedup >= 1.3),
        "fused_parity_bitexact": fused_parity,
        "modeled_hbm_bytes_per_round": fhbm["total_bytes"],
        "modeled_solver_hbm_bytes_per_round": fhbm["solver_bytes"],
        "modeled_server_hbm_bytes_per_round": fhbm["server_bytes"],
        "modeled_solver_state_hbm_bytes_per_round":
            fhbm["solver_state_bytes"],
        "fused_gss_roofline_bytes": int(kernel_roofline),
        "solver_state_vs_roofline_ratio": roof_ratio,
        "roofline_within_15pct": bool(abs(roof_ratio - 1.0) <= 0.15),
    }
    print_fn(f"fedback_compact_fused_n{n_clients},{f_us:.1f},"
             f"speedup_vs_dense={speedup:.2f}x "
             f"parity={int(fused_parity)} "
             f"roofline_ratio={roof_ratio:.3f}")

    # --- stale-tolerant rounds: bounded-staleness commit pipeline ------
    # Same compacted workload with solves allowed to land up to S rounds
    # late; the consensus average runs every round over the freshest
    # available z-rows.  Solver rows per round are unchanged (the async
    # pipeline changes *when* results commit, never how many solves
    # run), so the bench-regression gate's no-solver-row-increase check
    # applies to these rows too.
    for staleness in (0, 2):
        acfg = _cfg(compact_clients, n_points, participation=rate,
                    compact=True, capacity_slack=slack,
                    max_staleness=staleness)
        astate = init_state(acfg, cparams0, spec=cspec)
        arf = make_round_fn(acfg, closs, cdata, spec=cspec)
        a_s, a_us, astate, ahist = _timed_rounds(
            arf, astate, compact_rounds, repeats=3)
        solves = capacity_for(compact_clients, rate, slack)
        overlap = fedback_async_overlap(
            compact_clients, int(solves), cspec.dim,
            max_staleness=staleness,
            data_bytes_per_client=_data_bytes_per_client(cdata))
        curve = np.asarray(ahist.train_loss, np.float64)
        name = f"compact_async_s{staleness}"
        report[name] = {
            "n_clients": compact_clients, "dim": cspec.dim,
            "participation": rate, "capacity_slack": slack,
            "max_staleness": staleness,
            "rounds": compact_rounds + 1,
            "per_round_us": a_us, "compile_s": a_s,
            "solves_per_round": int(solves),
            "solver_rows_per_round": int(solves),
            "landed_per_round_mean": float(
                np.mean(np.asarray(ahist.num_landed))),
            "inflight_depth_mean": float(
                np.mean(np.asarray(ahist.num_inflight))),
            "queue_depth_final": int(np.asarray(ahist.num_deferred)[-1]),
            "modeled_sync_s": overlap["modeled_sync_s"],
            "modeled_async_s": overlap["modeled_async_s"],
            "modeled_overlap_speedup": overlap["modeled_overlap_speedup"],
            "train_loss_curve": curve.tolist(),
            "final_train_loss": float(curve[-1]),
        }
        print_fn(f"fedback_{name}_n{compact_clients},{a_us:.1f},"
                 f"landed/round={report[name]['landed_per_round_mean']:.1f} "
                 f"inflight={report[name]['inflight_depth_mean']:.1f} "
                 f"modeled_overlap="
                 f"{overlap['modeled_overlap_speedup']:.2f}x "
                 f"final_loss={curve[-1]:.5f}")
    # staleness=0 must track the synchronous compacted engine exactly
    # (bit-identical events ⇒ identical loss curve) — surfaced so the
    # nightly compare job would catch an async-parity regression as a
    # benchmark diff even before the test suite runs.
    report["async_parity"] = {
        "s0_matches_sync_compact": bool(np.allclose(
            np.asarray(report["compact_async_s0"]["train_loss_curve"]),
            np.asarray(report["compact"]["train_loss_curve"]),
            rtol=1e-6, atol=1e-7)),
    }
    print_fn(f"fedback_async_parity,"
             f"{int(report['async_parity']['s0_matches_sync_compact'])},"
             f"staleness0_equals_sync")

    # --- ragged heterogeneous clients: Dirichlet-size CSR pool ---------
    # The same compacted workload with per-client shard sizes drawn from
    # a Dirichlet over clients (the heterogeneity the rectangular layout
    # trims away) pooled into one CSR buffer: the solver streams CSR
    # slices through the capacity slots, so solver rows per round are
    # unchanged and the HBM data term follows Σnᵢ, not nᵢ·N.
    r_points = 2 * n_points
    rdata, rparams0, rloss = make_least_squares(compact_clients, r_points,
                                                dim, seed=5)
    size_rng = np.random.default_rng(7)
    props = size_rng.dirichlet(np.full(compact_clients, 3.0))
    sizes = np.clip((props * compact_clients * r_points * 0.6).astype(int),
                    4, r_points)
    pooled, rrspec = pool_data(
        [np.asarray(rdata["x"][i])[:s] for i, s in enumerate(sizes)],
        [np.asarray(rdata["y"][i])[:s] for i, s in enumerate(sizes)])
    # Conservation, measured on the actual buffers (not the spec, which
    # is derived from the same inputs): every sliced row landed in the
    # pool — the regression this flag exists to catch is pool_data (or
    # a partition layer feeding it) dropping rows.
    conservation = bool(
        int(pooled["x"].shape[0]) - rrspec.padding == int(sizes.sum())
        and int(pooled["y"].shape[0]) - rrspec.padding == int(sizes.sum()))
    rcfg = _cfg(compact_clients, r_points, participation=rate,
                compact=True, capacity_slack=slack)
    rrspec_flat = make_flat_spec(rparams0)
    rstate = init_state(rcfg, rparams0, spec=rrspec_flat)
    rrf = make_round_fn(rcfg, rloss, pooled, spec=rrspec_flat,
                        ragged=rrspec)
    r_s, r_us, rstate, rhist = _timed_rounds(rrf, rstate, compact_rounds,
                                             repeats=3)
    r_solves = capacity_for(compact_clients, rate, slack)
    # one data row = one x feature vector + its scalar target, fp32
    row_bytes = 4 * (int(np.prod(rdata["x"].shape[2:])) + 1)
    rhbm = fedback_ragged_round_hbm_bytes(
        compact_clients, int(r_solves), rrspec_flat.dim,
        sizes=rrspec.sizes, row_bytes=row_bytes)
    # Uniform sizes must reproduce the rectangular compact engine bit
    # for bit (events AND ω) — surfaced as a benchmark flag so the
    # nightly compare job catches a ragged-parity regression even
    # before the test suite runs (same idea as async_parity).
    updata, upspec = pool_data(
        [np.asarray(cdata["x"][i]) for i in range(compact_clients)],
        [np.asarray(cdata["y"][i]) for i in range(compact_clients)])
    pcfg = _cfg(compact_clients, n_points, participation=rate,
                compact=True, capacity_slack=slack)
    pstate_a = init_state(pcfg, cparams0, spec=cspec)
    pstate_b = init_state(pcfg, cparams0, spec=cspec)
    prf_a = make_round_fn(pcfg, closs, cdata, spec=cspec)
    prf_b = make_round_fn(pcfg, closs, updata, spec=cspec, ragged=upspec)
    pstate_a, phist_a = run_rounds(prf_a, pstate_a, 10)
    pstate_b, phist_b = run_rounds(prf_b, pstate_b, 10)
    parity = bool(
        np.array_equal(np.asarray(phist_a.events),
                       np.asarray(phist_b.events))
        and np.array_equal(
            np.asarray(pstate_a.omega, np.float32).tobytes(),
            np.asarray(pstate_b.omega, np.float32).tobytes()))
    rcurve = np.asarray(rhist.train_loss, np.float64)
    report["ragged_dirichlet"] = {
        "n_clients": compact_clients, "dim": rrspec_flat.dim,
        "participation": rate, "capacity_slack": slack,
        "rounds": compact_rounds + 1,
        "per_round_us": r_us, "compile_s": r_s,
        "solves_per_round": int(r_solves),
        "solver_rows_per_round": int(r_solves),
        "data_rows_total": rrspec.total,
        "sizes_min": int(rrspec.min_size),
        "sizes_max": int(rrspec.max_size),
        "sizes_mean": float(np.mean(sizes)),
        "solve_buckets": len(rrspec.buckets),
        "conservation_ok": conservation,
        "uniform_parity_bitexact": parity,
        "modeled_hbm_bytes_per_round": rhbm["total_bytes"],
        "modeled_solver_hbm_bytes_per_round": rhbm["solver_bytes"],
        "modeled_server_hbm_bytes_per_round": rhbm["server_bytes"],
        "train_loss_curve": rcurve.tolist(),
        "final_train_loss": float(rcurve[-1]),
    }
    print_fn(f"fedback_ragged_dirichlet_n{compact_clients},{r_us:.1f},"
             f"rows={rrspec.total} sizes=[{rrspec.min_size},"
             f"{rrspec.max_size}] buckets={len(rrspec.buckets)} "
             f"uniform_parity={int(parity)} "
             f"final_loss={rcurve[-1]:.5f}")

    # --- sweep: seeds x gains as ONE compiled program -------------------
    grid = SweepGrid(seeds=tuple(range(sweep_seeds)),
                     gains=tuple(1.0 * (i + 1) for i in range(sweep_gains)))
    small = make_least_squares(sweep_clients, n_points, dim)
    scfg = _cfg(sweep_clients, n_points)
    sspec = make_flat_spec(small[1])
    n_runs = len(grid.runs(scfg))
    states, overrides, _ = init_sweep(scfg, small[1], grid, spec=sspec)
    sweep_fn = make_sweep_fn(scfg, small[2], small[0], rounds=sweep_rounds,
                             spec=sspec)
    t0 = time.perf_counter()
    final, shist = jax.block_until_ready(sweep_fn(states, overrides))
    first_s = time.perf_counter() - t0
    # min over repeats: the steady_us row feeds the 15%-tolerance
    # bench-regression gate, so a single noise-dominated pass won't do.
    steady_s = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        final, shist = jax.block_until_ready(sweep_fn(states, overrides))
        steady_s = min(steady_s, time.perf_counter() - t0)
    srate = float(jnp.mean(shist.events.astype(jnp.float32)))
    print_fn(f"fedback_sweep_{n_runs}runs_x{sweep_rounds}rounds,"
             f"{steady_s * 1e6:.1f},one_program=True "
             f"compile+run_s={first_s:.2f} realized_rate={srate:.3f}")
    report["sweep"] = {
        "runs": n_runs, "rounds": sweep_rounds, "one_program": True,
        "steady_us": steady_s * 1e6, "compile_plus_run_s": first_s,
        "realized_rate": srate,
    }

    # --- host-offloaded client state: double-buffered row streaming ----
    # state_backend="host" (core/hoststate.py): the (N, D) client
    # matrices live in host RAM; the device holds ω, the controller
    # vectors and a (C, D) working set streamed through the CompactPlan
    # slots.  Two scales at D=64: N=65536 timed, and the million-client
    # smoke — the demo that one host runs N=1e6 clients with
    # device-resident client-state bytes O(C·D), wall-clock tracking C.
    # Measured transfer counters are gated against the planned byte
    # model (round_fn.planned_bytes ≡ roofline.host_stream_bytes ≡ the
    # host-transfer-budget tracecheck rule).
    h_slack = 1.5
    phase_keys = ("plan_s", "h2d_s", "solve_s", "d2h_s", "scatter_s",
                  "agg_s")
    for sec, h_n, h_pts, h_rate, h_rounds, h_repeats in (
            ("host_stream_n65536", 65536, 4, 0.02, 3, 2),
            ("host_stream_n1m", 1_000_000, 2, 0.001, 2, 1)):
        hdata, hparams0, hloss = make_least_squares(h_n, h_pts, dim)
        hspec = make_flat_spec(hparams0)
        hcfg = _cfg(h_n, h_pts, participation=h_rate, compact=True,
                    capacity_slack=h_slack, state_backend="host")
        hstate = init_state(hcfg, hparams0, spec=hspec)
        hrf = make_round_fn(hcfg, hloss, hdata, spec=hspec)
        cap = int(capacity_for(h_n, h_rate, h_slack))
        planned = hrf.planned_bytes
        model = host_stream_bytes(
            h_n, cap, hspec.dim,
            data_bytes_per_client=_data_bytes_per_client(hdata))
        # Round 0 compiles all three programs and seeds the lazy
        # distance cache (one extra full-width H2D, priced below).
        t0 = time.perf_counter()
        hstate, hm0 = hrf(hstate)
        jax.block_until_ready((hstate.omega, hm0))
        h_compile_s = time.perf_counter() - t0
        snap = dict(hrf.stats)
        t0 = time.perf_counter()
        hstate, hhist = run_rounds(hrf, hstate, h_rounds)
        jax.block_until_ready((hstate.omega, hhist))
        wall_first_us = (time.perf_counter() - t0) / h_rounds * 1e6
        h_us = wall_first_us
        phase_us = {k: (hrf.stats[k] - snap[k]) / h_rounds * 1e6
                    for k in phase_keys}
        for _ in range(h_repeats - 1):
            t0 = time.perf_counter()
            hstate, extra = run_rounds(hrf, hstate, h_rounds)
            jax.block_until_ready((hstate.omega, extra))
            h_us = min(h_us, (time.perf_counter() - t0) / h_rounds * 1e6)
        # Measured counters vs plan.  Row streams must match the plan
        # exactly per round; the full-width leg is rounds × server pass
        # + the one-off distance seed (z_prev once, N·D·4).
        done = hrf.stats["rounds"]
        row_h2d_pr = hrf.stats["h2d_row_bytes"] / done
        row_d2h_pr = hrf.stats["d2h_row_bytes"] / done
        seed_bytes = h_n * hspec.dim * 4
        bytes_match = bool(
            row_h2d_pr == planned["row_stream_h2d"]
            and row_d2h_pr == planned["row_stream_d2h"]
            and hrf.stats["h2d_full_bytes"]
            == done * planned["server_pass_h2d"] + seed_bytes
            and hrf.stats["d2h_full_bytes"]
            == done * planned["server_pass_d2h"]
            and planned["row_stream_h2d"] == model["row_stream_h2d_bytes"]
            and planned["row_stream_d2h"] == model["row_stream_d2h_bytes"])
        # Phase timers tile the measured wall, so any *positive* gap of
        # Σphases over the wall is copy time hidden under compute; on
        # CPU transfers are memcpys on the compute thread, so the
        # honest measured fraction is ~0 (the modeled fraction is the
        # PCIe/HBM-roofline value a device part can hide).
        stream_us = phase_us["h2d_s"] + phase_us["d2h_s"]
        overlap_measured = max(
            0.0, (sum(phase_us.values()) - wall_first_us)
            / max(stream_us, 1e-9))
        report[sec] = {
            "n_clients": h_n, "dim": hspec.dim, "participation": h_rate,
            "capacity_slack": h_slack, "rounds": h_rounds + 1,
            "stream_tiles": hrf.static_info["tiles"],
            "per_round_us": h_us, "compile_s": h_compile_s,
            "solves_per_round": cap, "solver_rows_per_round": cap,
            "streamed_h2d_bytes_per_round": int(row_h2d_pr),
            "streamed_d2h_bytes_per_round": int(row_d2h_pr),
            "planned_h2d_bytes_per_round": planned["row_stream_h2d"],
            "planned_d2h_bytes_per_round": planned["row_stream_d2h"],
            "row_stream_budget_bytes": planned["row_stream_budget"],
            "server_pass_h2d_bytes_per_round": planned["server_pass_h2d"],
            "bytes_match_plan": bytes_match,
            "within_budget": bool(
                planned["row_stream_h2d"] + planned["row_stream_d2h"]
                <= planned["row_stream_budget"]),
            "device_state_bytes": int(hstate.device_state_bytes()),
            "host_state_bytes": int(hstate.host_state_bytes()),
            "device_state_sub_full_matrix": bool(
                hstate.device_state_bytes() < h_n * hspec.dim * 4),
            "plan_us": phase_us["plan_s"], "h2d_us": phase_us["h2d_s"],
            "solve_us": phase_us["solve_s"], "d2h_us": phase_us["d2h_s"],
            "scatter_us": phase_us["scatter_s"],
            "agg_us": phase_us["agg_s"],
            "overlap_fraction_measured": overlap_measured,
            "modeled_overlap_fraction": model["modeled_overlap_fraction"],
            "modeled_stream_s": model["stream_s"],
            "modeled_solve_s": model["solve_s"],
            "events_final": int(np.asarray(hhist.num_events)[-1]),
        }
        print_fn(
            f"fedback_{sec},{h_us:.1f},"
            f"C={cap} h2d/round={int(row_h2d_pr)}B "
            f"d2h/round={int(row_d2h_pr)}B "
            f"bytes_match_plan={int(bytes_match)} "
            f"device_state={int(hstate.device_state_bytes())}B "
            f"overlap={overlap_measured:.2f}"
            f"/{model['modeled_overlap_fraction']:.2f}(model)")
        del hdata, hstate, hrf  # free the (N, ...) buffers before 1M

    # Bit-parity vs the device backend at small N: same config modulo
    # state_backend, 10 rounds, events AND the fp32 client matrices
    # must agree byte for byte (same flag pattern as fused/async/ragged
    # parity — the nightly compare job gates on it unconditionally).
    hp_n, hp_rate = compact_clients, 0.25
    hpcfg_d = _cfg(hp_n, n_points, participation=hp_rate, compact=True,
                   capacity_slack=h_slack, state_backend="device")
    hpcfg_h = _cfg(hp_n, n_points, participation=hp_rate, compact=True,
                   capacity_slack=h_slack, state_backend="host")
    hp_state_d = init_state(hpcfg_d, cparams0, spec=cspec)
    hp_state_h = init_state(hpcfg_h, cparams0, spec=cspec)
    hp_rf_d = make_round_fn(hpcfg_d, closs, cdata, spec=cspec)
    hp_rf_h = make_round_fn(hpcfg_h, closs, cdata, spec=cspec)
    hp_state_d, hp_hist_d = run_rounds(hp_rf_d, hp_state_d, 10)
    hp_state_h, hp_hist_h = run_rounds(hp_rf_h, hp_state_h, 10)
    host_parity = bool(
        np.array_equal(np.asarray(hp_hist_d.events),
                       np.asarray(hp_hist_h.events))
        and all(
            np.asarray(getattr(hp_state_d, f), np.float32).tobytes()
            == np.asarray(getattr(hp_state_h, f), np.float32).tobytes()
            for f in ("omega", "theta", "lam", "z_prev")))
    report["host_parity"] = {"host_parity_bitexact": host_parity}
    print_fn(f"fedback_host_parity,{int(host_parity)},"
             f"host_equals_device_bitexact_n{hp_n}")

    report["_env"] = _env_fingerprint()
    path = os.path.join(BENCH_DIR, "BENCH_round.json")
    with open(path, "w") as f:
        json.dump(report, f, indent=2)
    print_fn(f"bench_json,{path},sections={len(report)}")
    return report


if __name__ == "__main__":
    run()
