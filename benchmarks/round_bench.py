"""Round-engine benchmarks: the client-sharded simulation at scale.

Demonstrates the two scaling claims of the device-mesh round engine:

* one FedBack round at **N ≥ 1000 clients** as a single XLA program
  (client-stacked vmap; sharded over every available local device via
  the ``clients`` mesh when more than one is present), and
* a **multi-seed × controller-gain sweep compiled as ONE program**
  (scan-of-vmap, see ``repro.launch.sweep``) — compile once, then every
  additional (seed, gain) run rides the same executable.

CSV columns follow kernel_bench: name, value, derived context.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import ControllerConfig, FLConfig, init_state, make_round_fn
from repro.data import make_least_squares
from repro.launch.sweep import init_sweep, make_sweep_fn, SweepGrid


def _cfg(n_clients: int, n_points: int) -> FLConfig:
    return FLConfig(algorithm="fedback", n_clients=n_clients,
                    participation=0.2, rho=1.0, lr=0.1, momentum=0.0,
                    epochs=1, batch_size=n_points,
                    controller=ControllerConfig(K=0.5, alpha=0.9))


def run(print_fn=print, *, n_clients: int = 1024, n_points: int = 16,
        dim: int = 64, rounds: int = 5, sweep_clients: int = 256,
        sweep_seeds: int = 4, sweep_gains: int = 2, sweep_rounds: int = 40):
    data, params0, loss_fn = make_least_squares(n_clients, n_points, dim)
    cfg = _cfg(n_clients, n_points)

    # --- N >= 1000 client round (sharded over all local devices) -------
    n_dev = len(jax.devices())
    mesh = None
    if n_dev > 1:
        from repro.sharding.clients import make_client_mesh
        usable = max(d for d in range(1, n_dev + 1) if n_clients % d == 0)
        mesh = make_client_mesh(usable)
    state = init_state(cfg, params0, mesh=mesh)
    round_fn = make_round_fn(cfg, loss_fn, data, mesh=mesh)

    t0 = time.perf_counter()
    state, m = jax.block_until_ready(round_fn(state))
    compile_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    for _ in range(rounds):
        state, m = jax.block_until_ready(round_fn(state))
    per_round_us = (time.perf_counter() - t0) / rounds * 1e6
    devs = mesh.devices.size if mesh is not None else 1
    print_fn(f"fedback_round_n{n_clients},{per_round_us:.1f},"
             f"devices={devs} compile_s={compile_s:.2f} "
             f"events_r{rounds}={int(m.num_events)}")

    # --- sweep: seeds x gains as ONE compiled program -------------------
    grid = SweepGrid(seeds=tuple(range(sweep_seeds)),
                     gains=tuple(1.0 * (i + 1) for i in range(sweep_gains)))
    small = make_least_squares(sweep_clients, n_points, dim)
    scfg = _cfg(sweep_clients, n_points)
    n_runs = len(grid.runs(scfg))
    states, overrides, _ = init_sweep(scfg, small[1], grid)
    sweep_fn = make_sweep_fn(scfg, small[2], small[0], rounds=sweep_rounds)
    t0 = time.perf_counter()
    final, hist = jax.block_until_ready(sweep_fn(states, overrides))
    first_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    final, hist = jax.block_until_ready(sweep_fn(states, overrides))
    steady_s = time.perf_counter() - t0
    rate = float(jnp.mean(hist.events.astype(jnp.float32)))
    print_fn(f"fedback_sweep_{n_runs}runs_x{sweep_rounds}rounds,"
             f"{steady_s * 1e6:.1f},one_program=True "
             f"compile+run_s={first_s:.2f} realized_rate={rate:.3f}")


if __name__ == "__main__":
    run()
