"""Roofline summary benchmark: reads the dry-run artifacts under
experiments/dryrun/ and emits the §Roofline table as CSV."""
from __future__ import annotations

import glob
import json
import os


def run(dryrun_dir: str = "experiments/dryrun", print_fn=print):
    print_fn("roofline,arch,shape,mesh,sharding,status,compute_ms,"
             "memory_ms,collective_ms,dominant,useful_flops_ratio,"
             "analytic_hbm_GiB,fits_16GiB,compile_s")
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if r.get("status") == "skipped":
            print_fn(f"roofline,{r['arch']},{r['shape']},{r['mesh']},"
                     f"{r.get('sharding_mode','fsdp')},skipped({r['reason'][:40]})"
                     ",,,,,,,")
            continue
        if r.get("status") != "ok":
            print_fn(f"roofline,{r['arch']},{r['shape']},{r['mesh']},"
                     f"{r.get('sharding_mode','fsdp')},error,,,,,,,")
            continue
        t = r["roofline"]
        rows.append(r)
        print_fn(
            f"roofline,{r['arch']},{r['shape']},{r['mesh']},"
            f"{r.get('sharding_mode','fsdp')},ok,"
            f"{t['compute_s']*1e3:.2f},{t['memory_s']*1e3:.2f},"
            f"{t['collective_s']*1e3:.2f},{t['dominant']},"
            f"{(r.get('useful_flops_ratio') or 0):.3f},"
            f"{r.get('analytic_hbm_bytes', 0)/2**30:.2f},"
            f"{r.get('fits_hbm_16GiB','')},{r.get('compile_s','')}")
    return rows
