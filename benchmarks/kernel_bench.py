"""Kernel microbenchmarks: interpret-mode Pallas vs. jnp reference.

On CPU the interpret path measures *correct execution* of the exact TPU
program (not TPU speed); the derived column reports the achieved
bandwidth of the jnp reference as the apples-to-apples CPU number and
the analytic TPU-roofline time for the kernel's traffic.

Emits CSV rows and a machine-readable ``BENCH_kernels.json`` (µs per
call, modeled HBM bytes, TPU roofline µs per kernel).
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

HBM_BW = 819e9
BENCH_DIR = os.environ.get("BENCH_DIR", ".")


def _time(fn, *args, iters=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6  # µs


def run(print_fn=print):
    rng = np.random.default_rng(0)
    report: dict = {}

    def record(name, us, *, hbm_bytes=None, tpu_roofline_us=None,
               flops=None, note=None):
        entry = {"us_per_call": us}
        if hbm_bytes is not None:
            entry["modeled_hbm_bytes"] = hbm_bytes
        if tpu_roofline_us is not None:
            entry["tpu_roofline_us"] = tpu_roofline_us
        if flops is not None:
            entry["flops"] = flops
        if note:
            entry["note"] = note
        report[name] = entry

    print_fn("name,us_per_call,derived")

    # trigger norms: 100 clients × 159k params (paper MNIST scale)
    n, d = 100, 159_010
    z = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(d,)), jnp.float32)
    us_ref = _time(jax.jit(ops.trigger_sq_norms_ref), z, w)
    bytes_moved = (n * d + d) * 4
    tpu_us = bytes_moved / HBM_BW * 1e6
    print_fn(f"trigger_norms_ref_jnp,{us_ref:.1f},"
             f"tpu_roofline_us={tpu_us:.1f}")
    record("trigger_norms_ref_jnp", us_ref, hbm_bytes=bytes_moved,
           tpu_roofline_us=tpu_us)

    # admm fused update (3-output form; the round uses with_z=False)
    th = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    la = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    us_ref = _time(jax.jit(lambda a, b, c: ops.admm_update_ref(a, b, c)),
                   th, la, w)
    bytes_moved = n * d * 4 * 5  # 2 reads + 3 writes (ω cached)
    tpu_us = bytes_moved / HBM_BW * 1e6
    print_fn(f"admm_update_ref_jnp,{us_ref:.1f},"
             f"tpu_roofline_us={tpu_us:.1f}")
    record("admm_update_ref_jnp", us_ref, hbm_bytes=bytes_moved,
           tpu_roofline_us=tpu_us)
    # pre-solve form: λ⁺ + center only, 4 streams instead of 5.  No
    # measured time — modeled roofline only, so us_per_call stays null.
    bytes_pre = n * d * 4 * 4
    report["admm_update_presolve_modeled"] = {
        "us_per_call": None, "modeled_hbm_bytes": bytes_pre,
        "tpu_roofline_us": bytes_pre / HBM_BW * 1e6,
        "note": "with_z=False round form (2 reads + 2 writes)",
    }

    # fused gather→ADMM→scatter commit (compact-round capacity slots):
    # C=384 planned rows of an N=1024 state, paper-scale D.  The jnp
    # reference is the measured CPU number; the modeled row is the
    # kernel's one-pass traffic (7 streams + ω, fused_gss_hbm_bytes).
    from repro.kernels.fused_gss import fused_gss_hbm_bytes
    gn, gc, gd = 1024, 384, 4096
    gth = jnp.asarray(rng.normal(size=(gn, gd)), jnp.float32)
    gla = jnp.asarray(rng.normal(size=(gn, gd)), jnp.float32)
    gz = jnp.asarray(rng.normal(size=(gn, gd)), jnp.float32)
    gw = jnp.asarray(rng.normal(size=(gd,)), jnp.float32)
    gsolved = jnp.asarray(rng.normal(size=(gc, gd)), jnp.float32)
    gidx = jnp.asarray(rng.permutation(gn)[:gc], jnp.int32)
    gvalid = jnp.asarray(rng.random(gc) < 0.9)
    us_ref = _time(jax.jit(lambda *a: ops.fused_gss_ref(*a, with_z=True)),
                   gidx, gvalid, gsolved, gw, gth, gla, gz)
    bytes_moved = fused_gss_hbm_bytes(gc, gd, with_z=True)
    tpu_us = bytes_moved / HBM_BW * 1e6
    print_fn(f"fused_gss_ref_jnp,{us_ref:.1f},"
             f"tpu_roofline_us={tpu_us:.1f}")
    record("fused_gss_ref_jnp", us_ref, hbm_bytes=bytes_moved,
           tpu_roofline_us=tpu_us)
    # reference three-pass commit traffic over the same rows: θ/λ
    # gathers (2 reads + 2 compact writes), z assembly (2 reads + 1
    # write), three scatter writes — ~10 streams vs the kernel's 7.
    bytes_3pass = 4 * (10 * gc * gd + gd)
    report["fused_gss_unfused_3pass_modeled"] = {
        "us_per_call": None, "modeled_hbm_bytes": bytes_3pass,
        "tpu_roofline_us": bytes_3pass / HBM_BW * 1e6,
        "note": "reference gather + z-assembly + 3-scatter commit "
                "traffic over the same planned rows",
    }

    # flash attention (single head-block workload)
    b, h, kvh, s, hd = 1, 8, 2, 1024, 64
    q = jnp.asarray(rng.normal(size=(b, h, s, hd)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(b, kvh, s, hd)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(b, kvh, s, hd)), jnp.bfloat16)
    us_ref = _time(jax.jit(
        lambda q, k, v: ops.flash_attention_ref(q, k, v)), q, k, v)
    flops = 2 * 2 * b * h * s * s * hd  # qk + pv
    tpu_us = flops / 197e12 * 1e6
    print_fn(f"flash_attention_ref_jnp,{us_ref:.1f},"
             f"tpu_compute_roofline_us={tpu_us:.2f}")
    record("flash_attention_ref_jnp", us_ref, flops=flops,
           tpu_roofline_us=tpu_us)

    # ssd inter-chunk scan
    bb, c, hh, p, nn = 4, 64, 80, 64, 128
    states = jnp.asarray(rng.normal(size=(bb, c, hh, p, nn)), jnp.float32)
    decays = jnp.asarray(rng.uniform(0.5, 0.99, (bb, c, hh)), jnp.float32)
    us_ref = _time(jax.jit(lambda s_, d_: ops.ssd_scan_ref(s_, d_)[0]),
                   states, decays)
    bytes_moved = states.size * 4 * 2
    tpu_us = bytes_moved / HBM_BW * 1e6
    print_fn(f"ssd_scan_ref_jnp,{us_ref:.1f},"
             f"tpu_roofline_us={tpu_us:.1f}")
    record("ssd_scan_ref_jnp", us_ref, hbm_bytes=bytes_moved,
           tpu_roofline_us=tpu_us)

    # interpret-mode kernels (correctness-path timing, CPU-only number)
    us_k = _time(lambda: ops.trigger_sq_norms(z[:8, :4096], w[:4096],
                                              interpret=True))
    print_fn(f"trigger_norms_pallas_interpret_small,{us_k:.1f},"
             f"interpret_mode=True")
    record("trigger_norms_pallas_interpret_small", us_k,
           note="interpret mode (CPU correctness path)")

    us_k = _time(lambda: ops.admm_update(th[:8, :4096], la[:8, :4096],
                                         w[:4096], interpret=True,
                                         with_z=False)[0])
    print_fn(f"admm_update_pallas_interpret_small,{us_k:.1f},"
             f"interpret_mode=True with_z=False")
    record("admm_update_pallas_interpret_small", us_k,
           note="interpret mode, with_z=False (round form)")

    us_k = _time(lambda: ops.fused_gss(
        gidx[:8], gvalid[:8], gsolved[:8, :4096], gw[:4096],
        gth[:, :4096], gla[:, :4096], gz[:, :4096], interpret=True)[0])
    print_fn(f"fused_gss_pallas_interpret_small,{us_k:.1f},"
             f"interpret_mode=True with_z=True")
    record("fused_gss_pallas_interpret_small", us_k,
           note="interpret mode, 8 slots of the (1024, 4096) state "
                "(CPU correctness path)")

    import platform
    report["_env"] = (f"jax={jax.__version__};"
                      f"backend={jax.default_backend()};"
                      f"machine={platform.machine()}")
    path = os.path.join(BENCH_DIR, "BENCH_kernels.json")
    with open(path, "w") as f:
        json.dump(report, f, indent=2)
    print_fn(f"bench_json,{path},kernels={len(report)}")
    return report


if __name__ == "__main__":
    run()
