"""Benchmark driver — one section per paper table/figure plus the
kernel microbenches and the dry-run roofline summary.

  PYTHONPATH=src python -m benchmarks.run              # quick preset
  PYTHONPATH=src python -m benchmarks.run --preset mid # EXPERIMENTS.md scale
  PYTHONPATH=src python -m benchmarks.run --only table1,kernels

Prints ``name,...`` CSV rows (cached FL traces under experiments/paper/).
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--preset", default="quick",
                    choices=["quick", "mid", "paper"])
    ap.add_argument("--datasets", default="mnist,cifar")
    ap.add_argument("--only",
                    default="table1,table2,fig1,kernels,round,roofline")
    ap.add_argument("--smoke", action="store_true",
                    help="run the tier-1 test command (the CI hook) and "
                         "exit with its status")
    args = ap.parse_args()
    if args.smoke:
        env = dict(os.environ)
        env["PYTHONPATH"] = "src" + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
        raise SystemExit(subprocess.call(
            [sys.executable, "-m", "pytest", "-x", "-q"], env=env))
    only = set(args.only.split(","))
    datasets = args.datasets.split(",")

    from . import fig1, kernel_bench, round_bench, roofline_bench, table1, \
        table2

    for ds in datasets:
        if "table1" in only:
            rows = table1.run(ds, preset=args.preset)
            table1.emit(rows)
        if "table2" in only:
            rows = table2.run(ds, preset=args.preset)
            table2.emit(rows)
        if "fig1" in only:
            rows = fig1.run(ds, preset=args.preset)
            fig1.emit(rows)
    if "kernels" in only:
        kernel_bench.run()
    if "round" in only:
        round_bench.run()
    if "roofline" in only:
        roofline_bench.run()
    sys.stdout.flush()


if __name__ == "__main__":
    main()
