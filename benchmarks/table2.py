"""Paper Table 2: realized average participation rate vs. target L̄ —
the controller-tracking claim (Thm. 2): sub-1% error on long runs.

With ``grid=True`` (default; ``--smoke`` selects the tiny always-on
tier) every rate is advanced in ONE scan-of-vmap program via
``repro.launch.sweep`` (the target rate is a runtime controller
override), traces cached under ``experiments/paper/``.
"""
from __future__ import annotations

import argparse

from .common import PRESETS, realized_rate, run_grid, run_sweep


def run(dataset: str = "mnist", preset: str = "quick", rates=None,
        grid: bool = True):
    rates = rates or PRESETS[preset]["rates"]
    if grid:
        run_grid(dataset, "fedback", preset_name=preset, rates=rates)
    rows = []
    for rate in rates:
        trace = run_sweep(dataset, "fedback", rate, preset_name=preset)
        rows.append({
            "dataset": dataset, "rate": rate,
            "realized": realized_rate(trace),
            "abs_error": abs(realized_rate(trace) - rate),
        })
    return rows


def emit(rows, print_fn=print):
    print_fn("table2,dataset,target_rate,realized_rate,abs_error")
    for r in rows:
        print_fn(f"table2,{r['dataset']},{r['rate']},{r['realized']:.4f},"
                 f"{r['abs_error']:.4f}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dataset", default="mnist",
                    choices=["mnist", "cifar"])
    ap.add_argument("--preset", default="quick", choices=list(PRESETS))
    ap.add_argument("--smoke", action="store_true",
                    help="smoke tier: tiny one-program grid, traces "
                         "cached under experiments/paper/ (full grids "
                         "stay nightly)")
    ap.add_argument("--no-grid", action="store_true",
                    help="fall back to the per-run python-loop driver")
    args = ap.parse_args()
    preset = "smoke" if args.smoke else args.preset
    emit(run(args.dataset, preset=preset, grid=not args.no_grid))


if __name__ == "__main__":
    main()
