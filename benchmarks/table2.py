"""Paper Table 2: realized average participation rate vs. target L̄ —
the controller-tracking claim (Thm. 2): sub-1% error on long runs."""
from __future__ import annotations

from .common import PRESETS, realized_rate, run_sweep


def run(dataset: str = "mnist", preset: str = "quick", rates=None):
    rates = rates or PRESETS[preset]["rates"]
    rows = []
    for rate in rates:
        trace = run_sweep(dataset, "fedback", rate, preset_name=preset)
        rows.append({
            "dataset": dataset, "rate": rate,
            "realized": realized_rate(trace),
            "abs_error": abs(realized_rate(trace) - rate),
        })
    return rows


def emit(rows, print_fn=print):
    print_fn("table2,dataset,target_rate,realized_rate,abs_error")
    for r in rows:
        print_fn(f"table2,{r['dataset']},{r['rate']},{r['realized']:.4f},"
                 f"{r['abs_error']:.4f}")
