"""Beyond-paper ablations (§Ablations in EXPERIMENTS.md).

1. **Selection strategy** — isolates WHY FedBack wins: `fedback`
   (adaptive deterministic) vs `round_robin` (deterministic, not
   adaptive) vs `random` (FedADMM) vs `bernoulli` (unreliable clients).
   If determinism alone explained the variance reduction, round-robin
   would match FedBack; the trigger's state-awareness is the remainder.
2. **Trigger metric** — Remark 3 allows any metric with bounded
   gradients: l2 (paper) vs l∞ vs cosine.
3. **Controller variant** — the faithful integral law uses the
   *pre-update* load L^k (Eq. 3.3); `use_filtered_error=True` uses
   L^{k+1} (a PI-flavored variant).

    PYTHONPATH=src python -m benchmarks.ablations
"""
from __future__ import annotations

import dataclasses
import json
import os

import jax
import numpy as np

from repro.configs import paper_mnist
from repro.core import (
    ControllerConfig,
    init_state,
    make_eval_fn,
    make_round_fn,
    realized_rate,
)
from repro.data import federated_arrays, make_synthetic_mnist
from repro.models.mlp import (
    init_mlp,
    make_loss_and_acc_fn,
    make_loss_fn,
    mlp_logits,
)

CACHE = os.path.join(
    os.environ.get("REPRO_PAPER_CACHE", "experiments/paper"), "ablations")


def _run(cfg, data, test, params0, loss_fn, eval_fn, rounds=200):
    state = init_state(cfg, params0)
    round_fn = make_round_fn(cfg, loss_fn, data)
    events, accs = [], []
    for k in range(rounds):
        state, m = round_fn(state)
        events.append(int(m.num_events))
        if k % 4 == 0 or k == rounds - 1:
            _, acc = eval_fn(state, test["x"], test["y"])
            accs.append((k, float(acc)))
    rate = float(np.asarray(realized_rate(state.ctrl)).mean())
    tail = np.asarray([a for _, a in accs])[len(accs) // 2:]
    return {
        "events_total": int(np.sum(events)),
        "final_acc": accs[-1][1],
        "best_acc": max(a for _, a in accs),
        "realized_rate": rate,
        "tail_step_var": float(np.var(np.diff(tail))),
        "events_to_90": next(
            (int(np.cumsum(events)[k]) for k, a in accs if a >= 0.9), None),
    }


def run(rounds=200, n_clients=32, rate=0.15, print_fn=print,
        use_cache=True):
    os.makedirs(CACHE, exist_ok=True)
    path = os.path.join(CACHE, f"abl_N{n_clients}_r{rounds}_L{rate}.json")
    if use_cache and os.path.exists(path):
        with open(path) as f:
            rows = json.load(f)
    else:
        ds = make_synthetic_mnist(n_train=6400, n_test=1500)
        data, test = federated_arrays(ds, n_clients=n_clients,
                                      scheme="label_shard")
        params0 = init_mlp(jax.random.PRNGKey(0))
        loss_fn = make_loss_fn(mlp_logits)
        eval_fn = make_eval_fn(make_loss_and_acc_fn(mlp_logits))
        base = paper_mnist.fl_config("fedback", rate, n_clients=n_clients)

        variants = {
            # 1. selection strategies
            "fedback(l2)": base,
            "round_robin": dataclasses.replace(base, selection="round_robin"),
            "random": dataclasses.replace(base, selection="random"),
            "bernoulli": dataclasses.replace(base, selection="bernoulli"),
            # 2. trigger metrics (Remark 3)
            "fedback(linf)": dataclasses.replace(
                base, trigger_metric="linf",
                controller=ControllerConfig(K=0.02, alpha=0.9)),
            "fedback(cosine)": dataclasses.replace(
                base, trigger_metric="cosine",
                controller=ControllerConfig(K=0.005, alpha=0.9)),
            # 3. controller error-signal variant
            "fedback(PI-filtered)": dataclasses.replace(
                base, controller=ControllerConfig(
                    K=2.0, alpha=0.9, use_filtered_error=True)),
            # 4. no warm start (faithful-ADMM footnote-2 ablation)
            "fedback(cold-start)": dataclasses.replace(
                base, warm_start=False),
        }
        rows = {}
        for name, cfg in variants.items():
            rows[name] = _run(cfg, data, test, params0, loss_fn, eval_fn,
                              rounds)
        with open(path, "w") as f:
            json.dump(rows, f, indent=1)

    print_fn("ablation,variant,events_total,events_to_90,final_acc,"
             "realized_rate,tail_step_var")
    for name, r in rows.items():
        print_fn(f"ablation,{name},{r['events_total']},"
                 f"{r['events_to_90']},{r['final_acc']:.4f},"
                 f"{r['realized_rate']:.4f},{r['tail_step_var']:.2e}")
    return rows


if __name__ == "__main__":
    run()
