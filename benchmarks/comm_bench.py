"""Compressed-consensus benchmark → ``BENCH_comm.json``.

The communication story of the compressed wire (``core/compress.py``),
as a machine-readable artifact the nightly-bench gate tracks:

* **bytes on wire per round** — the modeled consensus collective term
  per ``consensus_compress`` mode (ring all-reduce at the wire dtype,
  u16 all-gather for bf16, the int8 shared-scale overhead accounted
  separately), from the same :func:`repro.core.compress.
  consensus_wire_bytes` model tracecheck's ``CollectiveBudget`` prices
  its budgets with.  The int8-vs-fp32 payload ratio here is the
  acceptance number (≤ 0.3×), and ``benchmarks/compare.py`` gates every
  byte figure as never-increase against the committed baseline;

* **rounds-to-target under compression × participation rate** — small
  fixed-seed FedBack runs on the synthetic least-squares workload, one
  per (participation, mode) grid point, measuring the round at which
  the global loss at ω first covers 95% of the fp32 anchor's
  first-to-final loss descent at the same participation rate (an
  absolute-final-loss target would sit just above the consensus floor
  and be reached immediately — the *descent* fraction is what
  discriminates).  Error feedback is doing its job exactly when the
  compressed legs reach the target within tolerance of the anchor —
  the convergence-rounds gate in ``compare.py``.

Emits CSV-ish progress lines and writes ``BENCH_comm.json`` to
``$BENCH_DIR`` (default "."), with the same ``_env`` fingerprint
convention as the other bench artifacts.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ControllerConfig, FLConfig, init_state, \
    make_flat_spec, make_round_fn
from repro.core.compress import MODES, WIRE_BYTES
from repro.data import make_least_squares
from repro.launch.roofline import consensus_collective_s

BENCH_DIR = os.environ.get("BENCH_DIR", ".")

#: Bench problem (fixed: the grid is seed-deterministic end to end).
N_CLIENTS = 64
N_POINTS = 8
DIM = 32
ROUNDS = 60
SEED = 0
BLOCK = 256
WORLD_SIZE = 2          # the modeled mesh of the wire-bytes section
PARTICIPATION_GRID = (0.1, 0.25, 0.5)
TARGET_DESCENT = 0.95   # fraction of the fp32 anchor's first-to-final
#                         loss descent the target sits at


def _env_fingerprint() -> str:
    import platform
    return (f"jax={jax.__version__};backend={jax.default_backend()};"
            f"machine={platform.machine()}")


def _grid_name(rate: float, mode: str) -> str:
    return f"conv_p{int(round(rate * 100))}_{mode}"


def wire_sections(report: dict, print_fn=print) -> None:
    for mode in MODES:
        wire = consensus_collective_s(DIM, mode=mode, block=BLOCK,
                                      world_size=WORLD_SIZE)
        report[f"wire_{mode}"] = {
            "dim": DIM, "block": BLOCK, "world_size": WORLD_SIZE,
            "wire_bytes_per_coord": WIRE_BYTES[mode], **wire,
        }
        print_fn(f"comm_wire_{mode},{wire['total_link_bytes']:.1f},"
                 f"payload={wire['payload_link_bytes']:.1f} "
                 f"uplink={wire['uplink_bytes_per_client']}")
    fp32 = report["wire_none"]["payload_link_bytes"]
    report["wire_ratio"] = {
        "int8_vs_fp32": report["wire_int8"]["payload_link_bytes"] / fp32,
        "bf16_vs_fp32": report["wire_bf16"]["payload_link_bytes"] / fp32,
        "int8_total_vs_fp32": (report["wire_int8"]["total_link_bytes"]
                               / fp32),
    }
    print_fn(f"comm_wire_ratio_int8,"
             f"{report['wire_ratio']['int8_vs_fp32']:.3f},"
             f"bf16={report['wire_ratio']['bf16_vs_fp32']:.3f}")


def _global_loss_fn(data, loss_fn, spec):
    """Jitted mean loss over EVERY client's shard at the server ω —
    the convergence measurement (participant-set independent, unlike
    the per-round train_loss metric)."""

    def global_loss(omega):
        params = spec.unflatten(omega)
        per = jax.vmap(lambda x, y: loss_fn(params, x, y))(
            data["x"], data["y"])
        return jnp.mean(per)

    return jax.jit(global_loss)


def _run_leg(rate: float, mode: str, data, params0, loss_fn, spec):
    """Loss-at-ω curve of one (participation, mode) grid point."""
    cfg = FLConfig(algorithm="fedback", n_clients=N_CLIENTS,
                   participation=rate, rho=1.0, lr=0.1, momentum=0.0,
                   epochs=1, batch_size=N_POINTS, seed=SEED,
                   consensus_compress=mode, compress_block=BLOCK,
                   controller=ControllerConfig(K=0.5, alpha=0.9))
    state = init_state(cfg, params0, spec=spec)
    round_fn = make_round_fn(cfg, loss_fn, data, spec=spec)
    global_loss = _global_loss_fn(data, loss_fn, spec)
    curve = []
    for _ in range(ROUNDS):
        state, _ = round_fn(state)
        curve.append(global_loss(state.omega))
    return np.asarray(jax.device_get(jnp.stack(curve)), np.float64)


def rounds_to_target(curve: np.ndarray, target: float) -> int:
    """First round index (1-based) whose loss-at-ω reaches the target;
    ROUNDS + 1 when the leg never gets there (gate-visible)."""
    hit = np.nonzero(curve <= target)[0]
    return int(hit[0]) + 1 if hit.size else ROUNDS + 1


def convergence_sections(report: dict, print_fn=print) -> None:
    data, params0, loss_fn = make_least_squares(
        N_CLIENTS, N_POINTS, DIM, seed=SEED)
    spec = make_flat_spec(params0)
    for rate in PARTICIPATION_GRID:
        curves = {mode: _run_leg(rate, mode, data, params0, loss_fn,
                                 spec) for mode in MODES}
        anchor = curves["none"]
        target = float(anchor[0]
                       - TARGET_DESCENT * (anchor[0] - anchor[-1]))
        for mode in MODES:
            rtt = rounds_to_target(curves[mode], target)
            report[_grid_name(rate, mode)] = {
                "participation": rate, "mode": mode,
                "rounds_to_target": rtt,
                "target_loss": target,
                "final_loss": float(curves[mode][-1]),
                "rounds_run": ROUNDS,
            }
            print_fn(f"{_grid_name(rate, mode)},{rtt},"
                     f"final_loss={curves[mode][-1]:.5f} "
                     f"target={target:.5f}")


def run(print_fn=print) -> dict:
    report: dict = {}
    wire_sections(report, print_fn)
    convergence_sections(report, print_fn)
    report["_env"] = _env_fingerprint()
    path = os.path.join(BENCH_DIR, "BENCH_comm.json")
    with open(path, "w") as f:
        json.dump(report, f, indent=2)
    print_fn(f"wrote {path}")
    return report


if __name__ == "__main__":
    run()
