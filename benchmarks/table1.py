"""Paper Table 1: participation events to reach the target accuracy,
per algorithm × L̄.  Reproduces the paper's headline claim: FedBack
needs up to ~50% fewer events than random selection at the same L̄."""
from __future__ import annotations

from .common import ALGORITHMS, PRESETS, events_to_accuracy, run_sweep


def run(dataset: str = "mnist", preset: str = "quick", rates=None,
        algorithms=ALGORITHMS):
    rates = rates or PRESETS[preset]["rates"]
    rows = []
    for rate in rates:
        for alg in algorithms:
            trace = run_sweep(dataset, alg, rate, preset_name=preset)
            ev = events_to_accuracy(trace)
            rows.append({
                "dataset": dataset, "algorithm": alg, "rate": rate,
                "events_to_target": ev,
                "target": trace["target_accuracy"],
                "final_acc": trace["accuracy"][-1][1],
            })
    return rows


def emit(rows, print_fn=print):
    print_fn("table1,dataset,algorithm,rate,events_to_target,final_acc")
    for r in rows:
        ev = r["events_to_target"]
        print_fn(f"table1,{r['dataset']},{r['algorithm']},{r['rate']},"
                 f"{ev if ev is not None else 'N/A'},"
                 f"{r['final_acc']:.4f}")
