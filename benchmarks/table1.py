"""Paper Table 1: participation events to reach the target accuracy,
per algorithm × L̄.  Reproduces the paper's headline claim: FedBack
needs up to ~50% fewer events than random selection at the same L̄.

With ``grid=True`` (the default; ``--smoke`` on the CLI selects the
tiny always-on tier) the whole (seeds × rates) grid per algorithm is
advanced through ``repro.launch.sweep``'s one-program scan-of-vmap
runner first — traces land in the ``experiments/paper/`` cache and the
table is assembled from the cached runs, so re-emitting never
recomputes.
"""
from __future__ import annotations

import argparse

from .common import ALGORITHMS, PRESETS, events_to_accuracy, run_grid, \
    run_sweep


def run(dataset: str = "mnist", preset: str = "quick", rates=None,
        algorithms=ALGORITHMS, grid: bool = True):
    rates = rates or PRESETS[preset]["rates"]
    rows = []
    for alg in algorithms:
        if grid:
            run_grid(dataset, alg, preset_name=preset, rates=rates)
        for rate in rates:
            trace = run_sweep(dataset, alg, rate, preset_name=preset)
            ev = events_to_accuracy(trace)
            rows.append({
                "dataset": dataset, "algorithm": alg, "rate": rate,
                "events_to_target": ev,
                "target": trace["target_accuracy"],
                "final_acc": trace["accuracy"][-1][1],
            })
    return rows


def emit(rows, print_fn=print):
    print_fn("table1,dataset,algorithm,rate,events_to_target,final_acc")
    for r in rows:
        ev = r["events_to_target"]
        print_fn(f"table1,{r['dataset']},{r['algorithm']},{r['rate']},"
                 f"{ev if ev is not None else 'N/A'},"
                 f"{r['final_acc']:.4f}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dataset", default="mnist",
                    choices=["mnist", "cifar"])
    ap.add_argument("--preset", default="quick", choices=list(PRESETS))
    ap.add_argument("--smoke", action="store_true",
                    help="smoke tier: tiny one-program grids, traces "
                         "cached under experiments/paper/ (full grids "
                         "stay nightly)")
    ap.add_argument("--no-grid", action="store_true",
                    help="fall back to the per-run python-loop driver")
    args = ap.parse_args()
    preset = "smoke" if args.smoke else args.preset
    emit(run(args.dataset, preset=preset, grid=not args.no_grid))


if __name__ == "__main__":
    main()
