"""Serving-engine benchmark → BENCH_serve.json.

Measures the rounds-as-a-service scheduler (``repro.core.schedule``)
and pins its two contracts:

* ``serve_bursty`` — a bursty arrival trace (flash crowds over a quiet
  baseline) through the compacted serve step: p50/p99 admission→commit
  latency in ticks (deterministic per seed) and in wall-clock µs,
  plus sustained commits/sec and ticks/sec.  Wall-clock keys gate
  under the env-fingerprint guard in ``benchmarks/compare.py``; the
  deterministic keys (tick latencies, counts, ``conservation_ok``)
  gate unconditionally.
* ``serve_parity`` — the degenerate "everyone fires every tick" trace
  must reproduce the synchronous round engine bit for bit: events AND
  fp32 ω.  ``serve_parity_bitexact`` is gated unconditionally.

Run with ``BENCH_DIR=benchmarks/baselines`` to regenerate the
committed baseline intentionally.
"""
from __future__ import annotations

import json
import os

import jax
import numpy as np

from repro.core.fedback import init_state, make_round_fn, run_rounds
from repro.core.schedule import TraceConfig, make_trace, run_trace, serve, \
    sync_trace
from repro.launch.serve_fl import build_serve_problem

BENCH_DIR = os.environ.get("BENCH_DIR", ".")

#: Bursty-trace workload (big enough that per-tick work dominates the
#: host loop, small enough for the nightly CPU runner).
N_CLIENTS = 256
TICKS = 96
RATE = 0.25
PARITY_N = 64
PARITY_TICKS = 12


def _env_fingerprint() -> str:
    import platform
    return (f"jax={jax.__version__};backend={jax.default_backend()};"
            f"machine={platform.machine()}")


def bench_bursty(report: dict) -> None:
    cfg, round_fn, state = build_serve_problem(
        N_CLIENTS, participation=RATE, compact=True)
    trace = make_trace(TraceConfig(
        kind="bursty", n_clients=N_CLIENTS, ticks=TICKS, rate=RATE,
        seed=0))
    state, rep = serve(round_fn, state, trace, warmup=True)
    report["serve_bursty"] = rep.summary()
    print(f"serve_bursty: N={N_CLIENTS} ticks={TICKS} "
          f"p50={rep.percentiles()['p50_latency_ticks']:.1f}t "
          f"p99={rep.percentiles()['p99_latency_ticks']:.1f}t "
          f"{rep.commits_per_sec:.0f} commits/s "
          f"conservation={'ok' if rep.conservation_ok else 'VIOLATED'}")


def bench_parity(report: dict) -> None:
    """Degenerate trace vs the synchronous round engine, bit for bit."""
    cfg, serve_fn, s_serve = build_serve_problem(
        PARITY_N, participation=RATE, compact=True)
    from repro.data.synthetic import make_least_squares
    from repro.utils.flatstate import make_flat_spec
    data, params0, loss_fn = make_least_squares(
        PARITY_N, n_points=8, dim=16, seed=0)
    spec = make_flat_spec(params0)
    sync_fn = make_round_fn(cfg, loss_fn, data, spec=spec)
    s_sync = init_state(cfg, params0, spec=spec)

    s_serve, m_serve = run_trace(serve_fn, s_serve,
                                 sync_trace(PARITY_N, PARITY_TICKS))
    s_sync, m_sync = run_rounds(sync_fn, s_sync, PARITY_TICKS)
    events_ok = bool(np.array_equal(np.asarray(m_serve.events),
                                    np.asarray(m_sync.events)))
    omega_ok = bool(np.array_equal(np.asarray(s_serve.omega),
                                   np.asarray(s_sync.omega)))
    report["serve_parity"] = {
        "serve_parity_bitexact": events_ok and omega_ok,
        "events_bitexact": events_ok,
        "omega_bitexact": omega_ok,
        "ticks": PARITY_TICKS,
        "n_clients": PARITY_N,
    }
    print(f"serve_parity: events={'ok' if events_ok else 'MISMATCH'} "
          f"omega={'ok' if omega_ok else 'MISMATCH'}")


def main() -> None:
    report: dict = {"_env": _env_fingerprint()}
    bench_bursty(report)
    bench_parity(report)
    out = os.path.join(BENCH_DIR, "BENCH_serve.json")
    with open(out, "w") as fh:
        json.dump(report, fh, indent=2, sort_keys=True)
        fh.write("\n")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
