"""Paper Fig. 1: validation-accuracy-per-round curves and the variance
claim — FedBack's deterministic selection yields much lower round-to-
round variance of the server model than random sampling at low L̄."""
from __future__ import annotations

from .common import ALGORITHMS, PRESETS, accuracy_variance, run_sweep


def run(dataset: str = "mnist", preset: str = "quick", rates=None,
        algorithms=ALGORITHMS):
    rates = rates or PRESETS[preset]["rates"]
    rows = []
    for rate in rates:
        for alg in algorithms:
            trace = run_sweep(dataset, alg, rate, preset_name=preset)
            rows.append({
                "dataset": dataset, "algorithm": alg, "rate": rate,
                "tail_step_variance": accuracy_variance(trace),
                "curve": trace["accuracy"],
            })
    return rows


def emit(rows, print_fn=print):
    print_fn("fig1,dataset,algorithm,rate,tail_step_variance,final_acc")
    for r in rows:
        print_fn(f"fig1,{r['dataset']},{r['algorithm']},{r['rate']},"
                 f"{r['tail_step_variance']:.3e},{r['curve'][-1][1]:.4f}")


def emit_curves(rows, print_fn=print):
    print_fn("fig1_curve,dataset,algorithm,rate,round,accuracy")
    for r in rows:
        for k, a in r["curve"]:
            print_fn(f"fig1_curve,{r['dataset']},{r['algorithm']},"
                     f"{r['rate']},{k},{a:.4f}")
