"""Shared experiment runner for the paper-reproduction benchmarks.

One sweep = (dataset, algorithm, L̄) → per-round traces (events,
accuracy, losses, controller state).  Table 1 (events-to-accuracy),
Table 2 (realized participation) and Fig. 1 (accuracy curves/variance)
are all views over the same traces, which are cached as JSON under
``experiments/paper/`` so the three benchmarks never recompute a run.

Two runners fill the cache:

* :func:`run_sweep` — one (algorithm, rate, seed) at a time, a python
  round loop with inline evals (the original paper-faithful driver);
* :func:`run_grid` — the rate grid through ``repro.launch.sweep``'s
  scan-of-vmap **one-program** runner, in ``eval_every``-round segments
  with a vmapped eval between segments.  One XLA compile covers all of
  a seed's rates for FedBack (the target rate is a runtime controller
  override; open-loop baselines recompile per rate), seeds run as
  separate programs (data partition and model init are seed-derived),
  and each run's trace lands in the same cache files ``run_sweep``
  reads — so Table 1/2 and Fig. 1 consume grid-produced traces
  unchanged.  The ``smoke`` preset is the CI-sized tier of the full
  Table-1/Table-2 grids.
"""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from repro.configs import paper_cifar, paper_mnist
from repro.core import init_state, make_eval_fn, make_flat_spec, \
    make_round_fn
from repro.data import federated_arrays, make_synthetic_cifar, \
    make_synthetic_mnist
from repro.models.mlp import (
    cnn_logits,
    init_cnn,
    init_mlp,
    make_loss_and_acc_fn,
    make_loss_fn,
    mlp_logits,
)

CACHE_DIR = os.environ.get("REPRO_PAPER_CACHE", "experiments/paper")

# smoke preset: the tiny always-on tier of the Table-1/2 grids (cached
# one-program runs); quick preset: CI-sized but same structure; paper
# preset: §5 scale (nightly/manual)
PRESETS = {
    "smoke": dict(n_clients=16, n_train=1920, n_test=480, max_rounds=24,
                  eval_every=8, rates=(0.1, 0.2), seeds=(0,)),
    "quick": dict(n_clients=32, n_train=6400, n_test=1500, max_rounds=220,
                  eval_every=4, rates=(0.1, 0.2), seeds=(0,),
                  per_dataset={"cifar": dict(n_train=4000, max_rounds=120,
                                             eval_every=6)}),
    "mid": dict(n_clients=64, n_train=12000, n_test=2000, max_rounds=600,
                eval_every=5, rates=(0.05, 0.1, 0.2, 0.4), seeds=(0,)),
    "paper": dict(n_clients=100, n_train=12000, n_test=2000,
                  max_rounds=1500, eval_every=5,
                  rates=(0.05, 0.1, 0.15, 0.2, 0.4, 0.6), seeds=(0,)),
}

ALGORITHMS = ("fedback", "fedadmm", "fedavg", "fedprox")


def _apply_per_dataset(preset: dict, dataset: str) -> dict:
    p = dict(preset)
    p.update(p.pop("per_dataset", {}).get(dataset, {}))
    return p


def _setup(dataset: str, preset: dict, seed: int):
    """Dataset/model wiring; runs on the flat (N, D) client-state layout
    (``spec``) so the paper benchmarks exercise the engine's primary
    layout — model code stays pytree-based, the codec handles the rest.
    """
    if dataset == "mnist":
        ds = make_synthetic_mnist(preset["n_train"], preset["n_test"])
        data, test = federated_arrays(ds, n_clients=preset["n_clients"],
                                      scheme="label_shard", seed=seed)
        params0 = init_mlp(jax.random.PRNGKey(seed))
        spec = make_flat_spec(params0)
        loss_fn = make_loss_fn(mlp_logits)
        laa_fn = make_loss_and_acc_fn(mlp_logits)
        mkcfg = paper_mnist.fl_config
        target = paper_mnist.TARGET_ACCURACY
    elif dataset == "cifar":
        ds = make_synthetic_cifar(preset["n_train"], preset["n_test"])
        data, test = federated_arrays(ds, n_clients=preset["n_clients"],
                                      scheme="dirichlet",
                                      beta=paper_cifar.DIRICHLET_BETA,
                                      seed=seed)
        params0 = init_cnn(jax.random.PRNGKey(seed))
        spec = make_flat_spec(params0)
        loss_fn = make_loss_fn(cnn_logits)
        laa_fn = make_loss_and_acc_fn(cnn_logits)
        mkcfg = paper_cifar.fl_config
        target = paper_cifar.TARGET_ACCURACY
    else:
        raise ValueError(dataset)
    return data, test, params0, spec, loss_fn, laa_fn, mkcfg, target


def run_sweep(dataset: str, algorithm: str, rate: float, *,
              preset_name: str = "quick", seed: int = 0,
              use_cache: bool = True) -> dict:
    """Run (or load) one FL trajectory; returns the trace dict."""
    preset = _apply_per_dataset(PRESETS[preset_name], dataset)
    path = _trace_path(dataset, algorithm, rate, preset_name, seed)
    if use_cache and os.path.exists(path):
        with open(path) as f:
            return json.load(f)

    data, test, params0, spec, loss_fn, laa_fn, mkcfg, target = _setup(
        dataset, preset, seed)
    eval_fn = make_eval_fn(laa_fn, spec=spec)
    cfg = mkcfg(algorithm=algorithm, participation=rate,
                n_clients=preset["n_clients"], seed=seed)
    state = init_state(cfg, params0, spec=spec)
    round_fn = make_round_fn(cfg, loss_fn, data, spec=spec)

    events_per_round, acc_trace, loss_trace, load_trace = [], [], [], []
    event_counts = np.zeros(preset["n_clients"], np.int64)
    t0 = time.time()
    for k in range(preset["max_rounds"]):
        state, m = round_fn(state)
        ev = int(m.num_events)
        events_per_round.append(ev)
        event_counts += np.asarray(m.events)
        # Segment-end cadence (rounds eval_every-1, 2·eval_every-1, ...)
        # — the same sample points run_grid's one-program segments hit,
        # so loop- and grid-produced traces in the shared cache are
        # directly comparable.
        if (k + 1) % preset["eval_every"] == 0 \
                or k == preset["max_rounds"] - 1:
            loss, acc = eval_fn(state, test["x"], test["y"])
            acc_trace.append((k, float(acc)))
            loss_trace.append((k, float(loss)))
        load_trace.append(float(np.mean(np.asarray(m.load))))

    trace = {
        "dataset": dataset, "algorithm": algorithm, "rate": rate,
        "preset": preset_name, "seed": seed,
        "target_accuracy": target,
        "events_per_round": events_per_round,
        "accuracy": acc_trace,
        "loss": loss_trace,
        "mean_load": load_trace,
        "client_event_counts": event_counts.tolist(),
        "rounds": preset["max_rounds"],
        "n_clients": preset["n_clients"],
        "wall_s": time.time() - t0,
    }
    os.makedirs(CACHE_DIR, exist_ok=True)
    with open(path, "w") as f:
        json.dump(trace, f)
    return trace


def _trace_path(dataset, algorithm, rate, preset_name, seed) -> str:
    return os.path.join(
        CACHE_DIR, f"{dataset}_{algorithm}_L{rate}_{preset_name}_s{seed}"
        ".json")


def run_grid(dataset: str, algorithm: str, *, preset_name: str = "quick",
             rates=None, seeds=None, use_cache: bool = True) -> list[dict]:
    """Run the (seeds × rates) grid as one-program sweeps; fill the cache.

    The whole grid advances through ``repro.launch.sweep`` — a single
    scan-of-vmap program per compile covering every run — in
    ``eval_every``-round segments, with a jitted vmapped eval of all
    runs' server models between segments.  Each run's trajectory is
    written to the same per-run JSON files :func:`run_sweep` produces,
    so Table 1/2 and Fig. 1 read grid-produced traces unchanged.

    FedBack grids cover all of one seed's rates in ONE program (the
    target rate is a runtime controller override); open-loop baselines
    (random selection) bake the rate into the selection draw, so they
    compile once per rate.  Seeds run as separate programs because the
    data partition and the model init are seed-derived, exactly as in
    :func:`run_sweep` — batching them would silently share one dataset
    split across seeds and understate seed variance.  Returns the
    traces in (seed-major, rate-minor) grid order.
    """
    from repro.launch.sweep import init_sweep, make_sweep_fn, SweepGrid

    preset = _apply_per_dataset(PRESETS[preset_name], dataset)
    rates = tuple(rates if rates is not None else preset["rates"])
    seeds = tuple(seeds if seeds is not None else preset.get("seeds", (0,)))
    if use_cache and all(
            os.path.exists(_trace_path(dataset, algorithm, r, preset_name,
                                       s))
            for s in seeds for r in rates):
        return [json.load(open(_trace_path(dataset, algorithm, r,
                                           preset_name, s)))
                for s in seeds for r in rates]

    n = preset["n_clients"]
    seg = preset["eval_every"]
    n_segs = -(-preset["max_rounds"] // seg)  # ceil
    rounds = n_segs * seg
    # fedback: every rate in one program; baselines: one program per rate
    rate_groups = ([rates] if algorithm == "fedback"
                   else [(r,) for r in rates])
    traces = {}
    for seed in seeds:
        data, test, params0, spec, loss_fn, laa_fn, mkcfg, target = \
            _setup(dataset, preset, seed)
        vm_eval = jax.jit(jax.vmap(
            lambda om, x, y: laa_fn(spec.unflatten(om), x, y),
            in_axes=(0, None, None)))
        for group in rate_groups:
            t0 = time.time()
            cfg = mkcfg(algorithm=algorithm, participation=group[0],
                        n_clients=n, seed=seed)
            grid = SweepGrid(seeds=(seed,), target_rates=group)
            states, overrides, runs = init_sweep(cfg, params0, grid,
                                                 spec=spec)
            sweep_fn = make_sweep_fn(cfg, loss_fn, data, rounds=seg,
                                     spec=spec)
            acc = {r: [] for r in runs}
            losses = {r: [] for r in runs}
            events, loads = [], []
            for s in range(n_segs):
                states, hist = sweep_fn(states, overrides)
                events.append(np.asarray(hist.events))  # (seg, runs, N)
                loads.append(np.asarray(hist.load))
                ev_loss, ev_acc = vm_eval(states.omega, test["x"],
                                          test["y"])
                for i, run in enumerate(runs):
                    acc[run].append(((s + 1) * seg - 1, float(ev_acc[i])))
                    losses[run].append(((s + 1) * seg - 1,
                                        float(ev_loss[i])))
            events = np.concatenate(events)  # (rounds, runs, N)
            loads = np.concatenate(loads)
            group_wall = time.time() - t0
            for i, run in enumerate(runs):
                rate = run[2]
                trace = {
                    "dataset": dataset, "algorithm": algorithm,
                    "rate": float(rate), "preset": preset_name,
                    "seed": int(seed), "grid": True,
                    "target_accuracy": target,
                    "events_per_round":
                        events[:, i].sum(axis=1).astype(int).tolist(),
                    "accuracy": acc[run],
                    "loss": losses[run],
                    "mean_load": loads[:, i].mean(axis=1).tolist(),
                    "client_event_counts":
                        events[:, i].sum(axis=0).astype(int).tolist(),
                    "rounds": rounds,
                    "n_clients": n,
                    # the one-program group's wall-clock amortized over
                    # its runs (comparable to run_sweep's per-run wall_s)
                    "wall_s": group_wall / max(len(runs), 1),
                }
                os.makedirs(CACHE_DIR, exist_ok=True)
                with open(_trace_path(dataset, algorithm, rate,
                                      preset_name, seed), "w") as f:
                    json.dump(trace, f)
                traces[(int(seed), float(rate))] = trace
    return [traces[(int(s), float(r))] for s in seeds for r in rates]


def events_to_accuracy(trace: dict, target: float | None = None):
    """Total participation events until the target accuracy is first
    reached (the paper's Tab. 1 metric).  None if never reached."""
    target = target if target is not None else trace["target_accuracy"]
    acc = dict(trace["accuracy"])
    cum = np.cumsum(trace["events_per_round"])
    reached = [k for k, a in trace["accuracy"] if a >= target]
    if not reached:
        return None
    k = min(reached)
    return int(cum[k])


def realized_rate(trace: dict) -> float:
    """Average per-client participation rate (paper Tab. 2 metric)."""
    counts = np.asarray(trace["client_event_counts"], float)
    return float(np.mean(counts / trace["rounds"]))


def accuracy_variance(trace: dict, tail_frac: float = 0.5) -> float:
    """Round-to-round variance of validation accuracy over the tail of
    training (Fig. 1's qualitative claim, quantified)."""
    accs = np.asarray([a for _, a in trace["accuracy"]])
    tail = accs[int(len(accs) * (1 - tail_frac)):]
    return float(np.var(np.diff(tail))) if len(tail) > 2 else float("nan")
