"""Shared experiment runner for the paper-reproduction benchmarks.

One sweep = (dataset, algorithm, L̄) → per-round traces (events,
accuracy, losses, controller state).  Table 1 (events-to-accuracy),
Table 2 (realized participation) and Fig. 1 (accuracy curves/variance)
are all views over the same traces, which are cached as JSON under
``experiments/paper/`` so the three benchmarks never recompute a run.
"""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from repro.configs import paper_cifar, paper_mnist
from repro.core import init_state, make_eval_fn, make_flat_spec, \
    make_round_fn
from repro.data import federated_arrays, make_synthetic_cifar, \
    make_synthetic_mnist
from repro.models.mlp import (
    cnn_logits,
    init_cnn,
    init_mlp,
    make_loss_and_acc_fn,
    make_loss_fn,
    mlp_logits,
)

CACHE_DIR = os.environ.get("REPRO_PAPER_CACHE", "experiments/paper")

# quick preset: CI-sized but same structure; paper preset: §5 scale
PRESETS = {
    "quick": dict(n_clients=32, n_train=6400, n_test=1500, max_rounds=220,
                  eval_every=4, rates=(0.1, 0.2), seeds=(0,),
                  per_dataset={"cifar": dict(n_train=4000, max_rounds=120,
                                             eval_every=6)}),
    "mid": dict(n_clients=64, n_train=12000, n_test=2000, max_rounds=600,
                eval_every=5, rates=(0.05, 0.1, 0.2, 0.4), seeds=(0,)),
    "paper": dict(n_clients=100, n_train=12000, n_test=2000,
                  max_rounds=1500, eval_every=5,
                  rates=(0.05, 0.1, 0.15, 0.2, 0.4, 0.6), seeds=(0,)),
}

ALGORITHMS = ("fedback", "fedadmm", "fedavg", "fedprox")


def _apply_per_dataset(preset: dict, dataset: str) -> dict:
    p = dict(preset)
    p.update(p.pop("per_dataset", {}).get(dataset, {}))
    return p


def _setup(dataset: str, preset: dict, seed: int):
    """Dataset/model wiring; runs on the flat (N, D) client-state layout
    (``spec``) so the paper benchmarks exercise the engine's primary
    layout — model code stays pytree-based, the codec handles the rest.
    """
    if dataset == "mnist":
        ds = make_synthetic_mnist(preset["n_train"], preset["n_test"])
        data, test = federated_arrays(ds, n_clients=preset["n_clients"],
                                      scheme="label_shard", seed=seed)
        params0 = init_mlp(jax.random.PRNGKey(seed))
        spec = make_flat_spec(params0)
        loss_fn = make_loss_fn(mlp_logits)
        eval_fn = make_eval_fn(make_loss_and_acc_fn(mlp_logits), spec=spec)
        mkcfg = paper_mnist.fl_config
        target = paper_mnist.TARGET_ACCURACY
    elif dataset == "cifar":
        ds = make_synthetic_cifar(preset["n_train"], preset["n_test"])
        data, test = federated_arrays(ds, n_clients=preset["n_clients"],
                                      scheme="dirichlet",
                                      beta=paper_cifar.DIRICHLET_BETA,
                                      seed=seed)
        params0 = init_cnn(jax.random.PRNGKey(seed))
        spec = make_flat_spec(params0)
        loss_fn = make_loss_fn(cnn_logits)
        eval_fn = make_eval_fn(make_loss_and_acc_fn(cnn_logits), spec=spec)
        mkcfg = paper_cifar.fl_config
        target = paper_cifar.TARGET_ACCURACY
    else:
        raise ValueError(dataset)
    return data, test, params0, spec, loss_fn, eval_fn, mkcfg, target


def run_sweep(dataset: str, algorithm: str, rate: float, *,
              preset_name: str = "quick", seed: int = 0,
              use_cache: bool = True) -> dict:
    """Run (or load) one FL trajectory; returns the trace dict."""
    preset = _apply_per_dataset(PRESETS[preset_name], dataset)
    tag = f"{dataset}_{algorithm}_L{rate}_{preset_name}_s{seed}"
    path = os.path.join(CACHE_DIR, tag + ".json")
    if use_cache and os.path.exists(path):
        with open(path) as f:
            return json.load(f)

    data, test, params0, spec, loss_fn, eval_fn, mkcfg, target = _setup(
        dataset, preset, seed)
    cfg = mkcfg(algorithm=algorithm, participation=rate,
                n_clients=preset["n_clients"], seed=seed)
    state = init_state(cfg, params0, spec=spec)
    round_fn = make_round_fn(cfg, loss_fn, data, spec=spec)

    events_per_round, acc_trace, loss_trace, load_trace = [], [], [], []
    event_counts = np.zeros(preset["n_clients"], np.int64)
    t0 = time.time()
    for k in range(preset["max_rounds"]):
        state, m = round_fn(state)
        ev = int(m.num_events)
        events_per_round.append(ev)
        event_counts += np.asarray(m.events)
        if k % preset["eval_every"] == 0 or k == preset["max_rounds"] - 1:
            loss, acc = eval_fn(state, test["x"], test["y"])
            acc_trace.append((k, float(acc)))
            loss_trace.append((k, float(loss)))
        load_trace.append(float(np.mean(np.asarray(m.load))))

    trace = {
        "dataset": dataset, "algorithm": algorithm, "rate": rate,
        "preset": preset_name, "seed": seed,
        "target_accuracy": target,
        "events_per_round": events_per_round,
        "accuracy": acc_trace,
        "loss": loss_trace,
        "mean_load": load_trace,
        "client_event_counts": event_counts.tolist(),
        "rounds": preset["max_rounds"],
        "n_clients": preset["n_clients"],
        "wall_s": time.time() - t0,
    }
    os.makedirs(CACHE_DIR, exist_ok=True)
    with open(path, "w") as f:
        json.dump(trace, f)
    return trace


def events_to_accuracy(trace: dict, target: float | None = None):
    """Total participation events until the target accuracy is first
    reached (the paper's Tab. 1 metric).  None if never reached."""
    target = target if target is not None else trace["target_accuracy"]
    acc = dict(trace["accuracy"])
    cum = np.cumsum(trace["events_per_round"])
    reached = [k for k, a in trace["accuracy"] if a >= target]
    if not reached:
        return None
    k = min(reached)
    return int(cum[k])


def realized_rate(trace: dict) -> float:
    """Average per-client participation rate (paper Tab. 2 metric)."""
    counts = np.asarray(trace["client_event_counts"], float)
    return float(np.mean(counts / trace["rounds"]))


def accuracy_variance(trace: dict, tail_frac: float = 0.5) -> float:
    """Round-to-round variance of validation accuracy over the tail of
    training (Fig. 1's qualitative claim, quantified)."""
    accs = np.asarray([a for _, a in trace["accuracy"]])
    tail = accs[int(len(accs) * (1 - tail_frac)):]
    return float(np.var(np.diff(tail))) if len(tail) > 2 else float("nan")
