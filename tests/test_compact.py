"""Capacity-bounded compaction: plan mechanics, dense-path parity
(capacity=N ⇒ bit-identical events, fp32-tolerance state), overflow
deferral, the 2-device mesh path, and the fused-round op-count
assertions (--runslow)."""
import dataclasses
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ControllerConfig, FLConfig, init_state, \
    make_flat_spec, make_round_fn, run_rounds
from repro.core.compact import capacity_for, compact_plan
from repro.core.engine import participant_mean
from repro.data import make_least_squares

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _cfg(n, **kw):
    base = dict(algorithm="fedback", n_clients=n, participation=0.5,
                rho=1.0, lr=0.1, momentum=0.0, epochs=2, batch_size=4,
                controller=ControllerConfig(K=0.2, alpha=0.9))
    base.update(kw)
    return FLConfig(**base)


class TestCompactPlan:
    def test_prioritizes_largest_distances(self):
        events = jnp.asarray([True, True, False, True, True])
        dist = jnp.asarray([0.1, 0.9, 5.0, 0.5, 0.3])
        plan = compact_plan(events, dist, capacity=2)
        # stalest fired clients: 1 (0.9) then 3 (0.5); client 2 did not fire
        np.testing.assert_array_equal(np.asarray(plan.idx), [1, 3])
        assert np.asarray(plan.valid).all()
        np.testing.assert_array_equal(
            np.asarray(plan.committed), [False, True, False, True, False])
        assert int(plan.num_deferred) == 2

    def test_capacity_exceeds_fired(self):
        events = jnp.asarray([False, True, False, False])
        dist = jnp.ones((4,))
        plan = compact_plan(events, dist, capacity=3)
        np.testing.assert_array_equal(np.asarray(plan.valid),
                                      [True, False, False])
        assert int(plan.num_deferred) == 0
        np.testing.assert_array_equal(np.asarray(plan.committed), events)

    def test_tie_break_is_deterministic_low_index_first(self):
        events = jnp.ones((4,), bool)
        plan = compact_plan(events, jnp.zeros((4,)), capacity=2)
        np.testing.assert_array_equal(np.asarray(plan.idx), [0, 1])

    def test_capacity_for(self):
        assert capacity_for(100, 0.25, 1.5) == 38  # ceil(37.5)
        assert capacity_for(100, 0.25, 1.5, capacity=100) == 100
        assert capacity_for(100, 1.0, 2.0) == 100  # clamped to N
        assert capacity_for(8, 0.25, 1.5, n_shards=2) == 2  # ceil(3/2)
        assert capacity_for(4, 0.0, 1.5) == 1  # floor of one row


class TestCompactParity:
    @pytest.mark.parametrize("algorithm", ["fedback", "fedavg"])
    def test_capacity_n_matches_dense(self, algorithm):
        n = 8
        data, params0, ls = make_least_squares(n, 8, 5)
        spec = make_flat_spec(params0)
        kw = dict(rho=0.0) if algorithm == "fedavg" else {}
        dense = _cfg(n, algorithm=algorithm, **kw)
        compact = dataclasses.replace(dense, compact=True, capacity=n)

        def run(cfg):
            state = init_state(cfg, params0, spec=spec)
            round_fn = make_round_fn(cfg, ls, data, spec=spec)
            events = []
            for _ in range(10):
                state, m = round_fn(state)
                events.append(np.asarray(m.events).astype(int).tolist())
                assert int(m.num_deferred) == 0
            return state, events

        st_d, ev_d = run(dense)
        st_c, ev_c = run(compact)
        assert ev_d == ev_c  # bit-identical event decisions
        for name in ("theta", "lam", "z_prev", "omega"):
            np.testing.assert_allclose(
                np.asarray(getattr(st_c, name)),
                np.asarray(getattr(st_d, name)), rtol=1e-6, atol=1e-7,
                err_msg=name)

    def test_capacity_n_matches_dense_tree_layout(self):
        n = 6
        data, params0, ls = make_least_squares(n, 8, 5)
        dense = _cfg(n)
        compact = dataclasses.replace(dense, compact=True, capacity=n)

        def run(cfg):
            state = init_state(cfg, params0)
            round_fn = make_round_fn(cfg, ls, data)
            for _ in range(8):
                state, m = round_fn(state)
            return state

        st_d, st_c = run(dense), run(compact)
        np.testing.assert_allclose(np.asarray(st_c.omega["theta"]),
                                   np.asarray(st_d.omega["theta"]),
                                   rtol=1e-6, atol=1e-7)


class TestOverflowDeferral:
    def test_round_zero_overflow_defers_and_keeps_state(self):
        """δ⁰=0 fires all N; with capacity C < N exactly C commit and
        the deferred clients' state is untouched."""
        n, cap = 8, 3
        data, params0, ls = make_least_squares(n, 8, 5)
        spec = make_flat_spec(params0)
        cfg = _cfg(n, compact=True, capacity=cap)
        state = init_state(cfg, params0, spec=spec)
        round_fn = make_round_fn(cfg, ls, data, spec=spec)
        th0 = np.asarray(state.theta)
        state2, m = round_fn(state)
        assert int(m.num_events) == n
        assert int(m.num_deferred) == n - cap
        changed = np.abs(np.asarray(state2.theta) - th0).max(axis=1) > 0
        assert int(changed.sum()) == cap

    def test_deferral_is_transient_under_controller(self):
        """Once the controller throttles toward L̄, firing mostly fits
        the slack capacity: deferral collapses from the round-0 burst
        (N − C clients) to a small oscillation residual."""
        n = 16
        data, params0, ls = make_least_squares(n, 8, 5)
        spec = make_flat_spec(params0)
        cfg = _cfg(n, participation=0.25, compact=True, capacity_slack=1.5,
                   controller=ControllerConfig(K=0.5, alpha=0.9))
        state = init_state(cfg, params0, spec=spec)
        round_fn = make_round_fn(cfg, ls, data, spec=spec)
        state, hist = run_rounds(round_fn, state, 30)
        deferred = np.asarray(hist.num_deferred)
        cap = capacity_for(n, 0.25, 1.5)
        assert deferred[0] == n - cap  # round 0 fires everyone
        assert deferred[-10:].mean() < 1.0  # throttled into capacity


class TestRunRoundsDriver:
    def test_metrics_stay_on_device_and_stack(self):
        n = 4
        data, params0, ls = make_least_squares(n, 8, 5)
        cfg = _cfg(n)
        state = init_state(cfg, params0)
        round_fn = make_round_fn(cfg, ls, data)
        state2, hist = run_rounds(round_fn, state, 5)
        assert isinstance(hist.events, jax.Array)  # no host fetch inside
        assert hist.events.shape == (5, n)
        assert hist.num_events.shape == (5,)
        # matches a manual python loop driving the same program
        state3, evs = init_state(cfg, params0), []
        for _ in range(5):
            state3, m = round_fn(state3)
            evs.append(np.asarray(m.events))
        np.testing.assert_array_equal(np.asarray(hist.events),
                                      np.stack(evs))


class TestParticipantMeanDtype:
    def test_bf16_leaves_stay_bf16(self):
        events = jnp.asarray([True, False, True])
        per_client = {"w": jnp.ones((3, 4), jnp.bfloat16)}
        fallback = {"w": jnp.zeros((4,), jnp.bfloat16)}
        out = participant_mean(per_client, events, fallback)
        assert out["w"].dtype == jnp.bfloat16
        np.testing.assert_allclose(np.asarray(out["w"], np.float32), 1.0)

    def test_fp32_unchanged(self):
        events = jnp.asarray([True, True])
        per_client = {"w": jnp.asarray([[2.0], [4.0]], jnp.float32)}
        fallback = {"w": jnp.zeros((1,), jnp.float32)}
        out = participant_mean(per_client, events, fallback)
        assert out["w"].dtype == jnp.float32
        np.testing.assert_allclose(np.asarray(out["w"]), [3.0])


_MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import dataclasses, json
import jax, numpy as np
from repro.core import ControllerConfig, FLConfig, init_state, \
    make_flat_spec, make_round_fn
from repro.data import make_least_squares
from repro.sharding.clients import make_client_mesh

N = 8
data, p0, ls = make_least_squares(N, 8, 5)
spec = make_flat_spec(p0)
cfg = FLConfig(algorithm="fedback", n_clients=N, participation=0.5, rho=1.0,
               lr=0.1, momentum=0.0, epochs=2, batch_size=4,
               controller=ControllerConfig(K=0.2, alpha=0.9))
ccfg = dataclasses.replace(cfg, compact=True, capacity=N)
mesh = make_client_mesh(2)
out = {}
for name, c, m in (("dense_single", cfg, None),
                   ("compact_sharded", ccfg, mesh)):
    state = init_state(c, p0, spec=spec, mesh=m)
    round_fn = make_round_fn(c, ls, data, spec=spec, mesh=m)
    events = []
    for _ in range(10):
        state, met = round_fn(state)
        events.append(np.asarray(met.events).astype(int).tolist())
    out[name] = {"events": events,
                 "omega": np.asarray(state.omega).tolist(),
                 "sharding": str(state.theta.sharding)}
print("RESULT:" + json.dumps(out))
"""


class TestCompactShardedParity:
    @pytest.fixture(scope="class")
    def result(self):
        env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
        out = subprocess.run([sys.executable, "-c", _MESH_SCRIPT], env=env,
                             capture_output=True, text=True, timeout=560,
                             cwd=REPO)
        assert out.returncode == 0, out.stderr[-3000:]
        line = [l for l in out.stdout.splitlines()
                if l.startswith("RESULT:")]
        return json.loads(line[-1][len("RESULT:"):])

    def test_state_is_client_sharded(self, result):
        assert "clients" in result["compact_sharded"]["sharding"]

    def test_events_bit_identical_to_single_device_dense(self, result):
        assert (result["dense_single"]["events"]
                == result["compact_sharded"]["events"])

    def test_omega_within_fp32_tolerance(self, result):
        a = np.asarray(result["dense_single"]["omega"])
        b = np.asarray(result["compact_sharded"]["omega"])
        np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-5)


@pytest.mark.slow
class TestFusedRoundOpCounts:
    """Acceptance: the jitted flat round contains exactly one fused
    ADMM-update pass — λ⁺/center come out of ONE pallas_call and no
    separate full-width λ/z/center elementwise sweep survives at the
    top level (utils/hlo.py op-count assertions)."""

    def _flat_round_jaxpr(self, compact):
        n = 8
        data, params0, ls = make_least_squares(n, 8, 5)
        spec = make_flat_spec(params0)
        cfg = _cfg(n, use_trigger_kernel=True, use_admm_kernel=True,
                   compact=compact, capacity=n)
        state = init_state(cfg, params0, spec=spec)
        round_fn = make_round_fn(cfg, ls, data, spec=spec, jit=False)
        return jax.make_jaxpr(round_fn)(state), n, spec.dim

    def test_exactly_one_fused_admm_pass(self):
        from repro.utils.hlo import jaxpr_eqn_counts
        jaxpr, _, _ = self._flat_round_jaxpr(compact=False)
        counts = jaxpr_eqn_counts(jaxpr)
        # one trigger-norm kernel + one fused λ⁺/center kernel
        assert counts.get("pallas_call") == 2, counts.get("pallas_call")

    def test_no_separate_lambda_center_sweeps(self):
        from repro.utils.hlo import toplevel_elementwise_shapes
        jaxpr, n, d = self._flat_round_jaxpr(compact=False)
        full = [s for s in toplevel_elementwise_shapes(jaxpr)
                if s == (n, d)]
        # the single allowed full-width elementwise op is the post-solve
        # z = θ_out + λ⁺ assembly (fused into the commit by XLA)
        assert len(full) <= 1, full

    def test_compact_round_also_single_fused_pass(self):
        from repro.utils.hlo import jaxpr_eqn_counts
        jaxpr, _, _ = self._flat_round_jaxpr(compact=True)
        counts = jaxpr_eqn_counts(jaxpr)
        assert counts.get("pallas_call") == 2, counts.get("pallas_call")

    def test_tree_layout_reference_has_no_kernel(self):
        from repro.utils.hlo import jaxpr_eqn_counts
        n = 8
        data, params0, ls = make_least_squares(n, 8, 5)
        cfg = _cfg(n)  # kernels auto-off on CPU, tree layout
        state = init_state(cfg, params0)
        round_fn = make_round_fn(cfg, ls, data, jit=False)
        counts = jaxpr_eqn_counts(jax.make_jaxpr(round_fn)(state))
        assert counts.get("pallas_call") is None
