"""Capacity-bounded compaction: plan mechanics, dense-path parity
(capacity=N ⇒ bit-identical events, fp32-tolerance state, with the
deferral queue enabled, across {1,2}-device meshes × {flat, pytree}
layouts × kernel forms), queue carry + adaptive capacity behavior,
overflow deferral, and the fused-round op-count assertions (--runslow).
Quantified invariants live in tests/test_compact_properties.py."""
import dataclasses
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ControllerConfig, FLConfig, init_state, \
    make_flat_spec, make_round_fn, run_rounds
from repro.core.compact import capacity_for, compact_plan
from repro.core.engine import participant_mean
from repro.data import make_least_squares

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _cfg(n, **kw):
    base = dict(algorithm="fedback", n_clients=n, participation=0.5,
                rho=1.0, lr=0.1, momentum=0.0, epochs=2, batch_size=4,
                controller=ControllerConfig(K=0.2, alpha=0.9))
    base.update(kw)
    return FLConfig(**base)


class TestCompactPlan:
    def test_prioritizes_largest_distances(self):
        events = jnp.asarray([True, True, False, True, True])
        dist = jnp.asarray([0.1, 0.9, 5.0, 0.5, 0.3])
        plan = compact_plan(events, dist, capacity=2)
        # stalest fired clients: 1 (0.9) then 3 (0.5); client 2 did not fire
        np.testing.assert_array_equal(np.asarray(plan.idx), [1, 3])
        assert np.asarray(plan.valid).all()
        np.testing.assert_array_equal(
            np.asarray(plan.committed), [False, True, False, True, False])
        assert int(plan.num_deferred) == 2

    def test_capacity_exceeds_fired(self):
        events = jnp.asarray([False, True, False, False])
        dist = jnp.ones((4,))
        plan = compact_plan(events, dist, capacity=3)
        np.testing.assert_array_equal(np.asarray(plan.valid),
                                      [True, False, False])
        assert int(plan.num_deferred) == 0
        np.testing.assert_array_equal(np.asarray(plan.committed), events)

    def test_tie_break_is_deterministic_low_index_first(self):
        events = jnp.ones((4,), bool)
        plan = compact_plan(events, jnp.zeros((4,)), capacity=2)
        np.testing.assert_array_equal(np.asarray(plan.idx), [0, 1])

    def test_capacity_for(self):
        assert capacity_for(100, 0.25, 1.5) == 38  # ceil(37.5)
        assert capacity_for(100, 0.25, 1.5, capacity=100) == 100
        assert capacity_for(100, 1.0, 2.0) == 100  # clamped to N
        assert capacity_for(8, 0.25, 1.5, n_shards=2) == 2  # ceil(3/2)
        assert capacity_for(4, 0.0, 1.5) == 1  # floor of one row

    def test_capacity_for_per_shard_rounds_up(self):
        """Regression: C_global=5 over 4 shards must give ⌈5/4⌉=2 per
        shard (a floor split would lose the remainder client)."""
        assert capacity_for(16, 0.3, 1.0, n_shards=4) == 2
        # global sum always covers the budget (up to the N ceiling)
        for n, rate, slack, shards in [(16, 0.3, 1.0, 4), (12, 0.5, 1.1, 3),
                                       (64, 0.17, 1.3, 8), (6, 0.9, 2.0, 2)]:
            import math
            c_global = math.ceil(slack * rate * n)
            per = capacity_for(n, rate, slack, n_shards=shards)
            assert per * shards >= min(c_global, n), (n, rate, slack, shards)

    def test_capacity_for_rejects_uneven_shards(self):
        with pytest.raises(ValueError):
            capacity_for(10, 0.5, 1.0, n_shards=3)

    def test_capacity_bounds(self):
        from repro.core.compact import capacity_bounds
        c_min, c_max = capacity_bounds(100, 0.25, 1.5)
        assert (c_min, c_max) == (25, 38)
        # explicit budget pins both views of the ceiling
        assert capacity_bounds(100, 0.25, 1.5, capacity=30)[1] == 30
        # tightest slack collapses the interval
        c_min, c_max = capacity_bounds(16, 0.25, 1.0)
        assert c_min == c_max == 4

    def test_queue_priority_age_beats_distance(self):
        """A deferred client outranks every fresh fire even with the
        smallest trigger distance (starvation-free ordering)."""
        events = jnp.asarray([True, True, True, True])
        dist = jnp.asarray([9.0, 8.0, 7.0, 0.01])
        age = jnp.asarray([0, 0, 1, 2], jnp.int32)
        plan = compact_plan(events, dist, capacity=2, age=age)
        np.testing.assert_array_equal(np.asarray(plan.idx), [3, 2])

    def test_limit_caps_commits_below_capacity(self):
        events = jnp.ones((6,), bool)
        plan = compact_plan(events, jnp.arange(6, 0, -1.0), capacity=4,
                            limit=2)
        assert int(np.asarray(plan.committed).sum()) == 2
        assert int(np.asarray(plan.valid).sum()) == 2
        assert int(plan.num_deferred) == 4


class TestCompactParity:
    @pytest.mark.parametrize("algorithm", ["fedback", "fedavg"])
    def test_capacity_n_matches_dense(self, algorithm):
        n = 8
        data, params0, ls = make_least_squares(n, 8, 5)
        spec = make_flat_spec(params0)
        kw = dict(rho=0.0) if algorithm == "fedavg" else {}
        dense = _cfg(n, algorithm=algorithm, **kw)
        compact = dataclasses.replace(dense, compact=True, capacity=n)

        def run(cfg):
            state = init_state(cfg, params0, spec=spec)
            round_fn = make_round_fn(cfg, ls, data, spec=spec)
            events = []
            for _ in range(10):
                state, m = round_fn(state)
                events.append(np.asarray(m.events).astype(int).tolist())
                assert int(m.num_deferred) == 0
            return state, events

        st_d, ev_d = run(dense)
        st_c, ev_c = run(compact)
        assert ev_d == ev_c  # bit-identical event decisions
        for name in ("theta", "lam", "z_prev", "omega"):
            np.testing.assert_allclose(
                np.asarray(getattr(st_c, name)),
                np.asarray(getattr(st_d, name)), rtol=1e-6, atol=1e-7,
                err_msg=name)

    def test_capacity_n_matches_dense_tree_layout(self):
        n = 6
        data, params0, ls = make_least_squares(n, 8, 5)
        dense = _cfg(n)
        compact = dataclasses.replace(dense, compact=True, capacity=n)

        def run(cfg):
            state = init_state(cfg, params0)
            round_fn = make_round_fn(cfg, ls, data)
            for _ in range(8):
                state, m = round_fn(state)
            return state

        st_d, st_c = run(dense), run(compact)
        np.testing.assert_allclose(np.asarray(st_c.omega["theta"]),
                                   np.asarray(st_d.omega["theta"]),
                                   rtol=1e-6, atol=1e-7)

    @pytest.mark.parametrize("layout,kernel", [
        ("flat", False), ("flat", True), ("tree", False)])
    def test_parity_matrix_single_device(self, layout, kernel):
        """capacity=N compact vs dense, queue enabled: bit-identical
        events, fp32-tolerant ω — {flat, pytree} layouts × {reference,
        fused-kernel} ADMM forms (the kernel form needs the flat
        layout; the 2-device leg of the matrix runs in
        TestCompactShardedParity)."""
        n = 8
        data, params0, ls = make_least_squares(n, 8, 5)
        spec = make_flat_spec(params0) if layout == "flat" else None
        dense = _cfg(n, use_admm_kernel=kernel, use_trigger_kernel=kernel)
        compact = dataclasses.replace(dense, compact=True, capacity=n)

        def run(cfg):
            state = init_state(cfg, params0, spec=spec)
            round_fn = make_round_fn(cfg, ls, data, spec=spec)
            events = []
            for _ in range(8):
                state, m = round_fn(state)
                events.append(np.asarray(m.events).astype(int).tolist())
                assert int(m.num_deferred) == 0
                assert np.asarray(state.queue.age).max() == 0
            return state, events

        st_d, ev_d = run(dense)
        st_c, ev_c = run(compact)
        assert ev_d == ev_c
        omega_d = (st_d.omega if layout == "flat" else st_d.omega["theta"])
        omega_c = (st_c.omega if layout == "flat" else st_c.omega["theta"])
        np.testing.assert_allclose(np.asarray(omega_c),
                                   np.asarray(omega_d),
                                   rtol=1e-6, atol=1e-7)

    def test_kernel_with_z_forms_agree(self):
        """The two fused kernel forms used by the round engines agree
        bit-wise on λ⁺/center, and the with_z=False form's post-solve z
        assembly matches the with_z=True kernel output."""
        from repro.kernels import ops
        rng = np.random.default_rng(0)
        theta = jnp.asarray(rng.standard_normal((8, 33)), jnp.float32)
        lam = jnp.asarray(rng.standard_normal((8, 33)), jnp.float32)
        omega = jnp.asarray(rng.standard_normal((33,)), jnp.float32)
        lam3, z3, c3 = ops.admm_update(theta, lam, omega, with_z=True)
        lam2, c2 = ops.admm_update(theta, lam, omega, with_z=False)
        np.testing.assert_array_equal(np.asarray(lam3), np.asarray(lam2))
        np.testing.assert_array_equal(np.asarray(c3), np.asarray(c2))
        np.testing.assert_array_equal(np.asarray(z3),
                                      np.asarray(theta + lam2))


class TestOverflowDeferral:
    def test_round_zero_overflow_defers_and_keeps_state(self):
        """δ⁰=0 fires all N; with capacity C < N exactly C commit and
        the deferred clients' state is untouched."""
        n, cap = 8, 3
        data, params0, ls = make_least_squares(n, 8, 5)
        spec = make_flat_spec(params0)
        cfg = _cfg(n, compact=True, capacity=cap)
        state = init_state(cfg, params0, spec=spec)
        round_fn = make_round_fn(cfg, ls, data, spec=spec)
        th0 = np.asarray(state.theta)
        state2, m = round_fn(state)
        assert int(m.num_events) == n
        assert int(m.num_deferred) == n - cap
        changed = np.abs(np.asarray(state2.theta) - th0).max(axis=1) > 0
        assert int(changed.sum()) == cap

    def test_deferral_is_transient_under_controller(self):
        """Once the controller throttles toward L̄, firing mostly fits
        the slack capacity: deferral collapses from the round-0 burst
        (N − C clients) to a small oscillation residual."""
        n = 16
        data, params0, ls = make_least_squares(n, 8, 5)
        spec = make_flat_spec(params0)
        cfg = _cfg(n, participation=0.25, compact=True, capacity_slack=1.5,
                   controller=ControllerConfig(K=0.5, alpha=0.9))
        state = init_state(cfg, params0, spec=spec)
        round_fn = make_round_fn(cfg, ls, data, spec=spec)
        state, hist = run_rounds(round_fn, state, 30)
        deferred = np.asarray(hist.num_deferred)
        cap = capacity_for(n, 0.25, 1.5)
        assert deferred[0] == n - cap  # round 0 fires everyone
        assert deferred[-10:].mean() < 1.0  # throttled into capacity


class TestDeferralCarry:
    def test_carried_client_served_without_refiring(self):
        """A deferred client is carried into the next plan by the queue:
        it gets served even when its trigger stays quiet (no re-fire)."""
        n, cap = 8, 2
        data, params0, ls = make_least_squares(n, 8, 5)
        spec = make_flat_spec(params0)
        cfg = _cfg(n, compact=True, capacity=cap)
        state = init_state(cfg, params0, spec=spec)
        round_fn = make_round_fn(cfg, ls, data, spec=spec)
        state, m = round_fn(state)  # δ⁰=0: all fire, cap commit
        assert int(m.num_deferred) == n - cap
        pending = np.asarray(state.queue.age) > 0
        # mute every trigger: no fresh event can fire next round
        state = state._replace(ctrl=state.ctrl._replace(
            delta=jnp.full((n,), 1e9, jnp.float32)))
        th_before = np.asarray(state.theta)
        state, m = round_fn(state)
        assert int(m.num_events) == 0  # nothing fired...
        changed = np.abs(np.asarray(state.theta) - th_before).max(axis=1) > 0
        assert int(changed.sum()) == cap  # ...yet cap carried rows served
        assert np.all(pending[changed])  # exactly from the queue
        assert int(m.num_deferred) == n - 2 * cap

    def test_queue_drains_oldest_first(self):
        """Round-robin service of the round-0 burst: every client is
        served exactly once within ⌈N/C⌉ rounds at an explicit budget."""
        n, cap = 8, 2
        data, params0, ls = make_least_squares(n, 8, 5)
        spec = make_flat_spec(params0)
        cfg = _cfg(n, compact=True, capacity=cap)
        state = init_state(cfg, params0, spec=spec)
        round_fn = make_round_fn(cfg, ls, data, spec=spec)
        th0 = np.asarray(state.theta)
        served_total = np.zeros(n, bool)
        for _ in range(n // cap):  # ⌈N/C⌉ rounds
            state, m = round_fn(state)
            # mute fresh triggers so only the burst queue is in play
            state = state._replace(ctrl=state.ctrl._replace(
                delta=jnp.full((n,), 1e9, jnp.float32)))
        served_total = np.abs(np.asarray(state.theta) - th0).max(axis=1) > 0
        assert served_total.all()  # the whole burst served, none starved
        assert int(m.num_deferred) == 0
        assert np.asarray(state.queue.age).max() == 0


class TestAdaptiveCapacity:
    def test_realized_capacity_within_bounds_and_adapts(self):
        from repro.core.compact import capacity_bounds
        n = 16
        data, params0, ls = make_least_squares(n, 8, 5)
        spec = make_flat_spec(params0)
        cfg = _cfg(n, participation=0.25, compact=True, capacity_slack=2.0,
                   controller=ControllerConfig(K=0.5, alpha=0.9))
        c_min, c_max = capacity_bounds(n, 0.25, 2.0)
        state = init_state(cfg, params0, spec=spec)
        round_fn = make_round_fn(cfg, ls, data, spec=spec)
        state, hist = run_rounds(round_fn, state, 40)
        caps = np.asarray(hist.realized_capacity)
        slacks = np.asarray(hist.realized_slack)
        assert np.all((caps >= c_min) & (caps <= c_max))
        assert caps[0] == c_max  # δ⁰=0 burst predicted by the load init
        assert caps.min() < c_max  # throttles once demand subsides
        np.testing.assert_allclose(slacks, caps / (0.25 * n), rtol=1e-6)

    def test_explicit_budget_pins_the_limit(self):
        n, cap = 8, 3
        data, params0, ls = make_least_squares(n, 8, 5)
        spec = make_flat_spec(params0)
        cfg = _cfg(n, compact=True, capacity=cap)
        state = init_state(cfg, params0, spec=spec)
        round_fn = make_round_fn(cfg, ls, data, spec=spec)
        state, hist = run_rounds(round_fn, state, 6)
        np.testing.assert_array_equal(np.asarray(hist.realized_capacity),
                                      cap)

    def test_dense_reports_full_capacity(self):
        n = 6
        data, params0, ls = make_least_squares(n, 8, 5)
        cfg = _cfg(n)
        state = init_state(cfg, params0)
        round_fn = make_round_fn(cfg, ls, data)
        state, m = round_fn(state)
        assert int(m.realized_capacity) == n
        assert float(m.realized_slack) == pytest.approx(n / (0.5 * n))


class TestRunRoundsDriver:
    def test_metrics_stay_on_device_and_stack(self):
        n = 4
        data, params0, ls = make_least_squares(n, 8, 5)
        cfg = _cfg(n)
        state = init_state(cfg, params0)
        round_fn = make_round_fn(cfg, ls, data)
        state2, hist = run_rounds(round_fn, state, 5)
        assert isinstance(hist.events, jax.Array)  # no host fetch inside
        assert hist.events.shape == (5, n)
        assert hist.num_events.shape == (5,)
        # matches a manual python loop driving the same program
        state3, evs = init_state(cfg, params0), []
        for _ in range(5):
            state3, m = round_fn(state3)
            evs.append(np.asarray(m.events))
        np.testing.assert_array_equal(np.asarray(hist.events),
                                      np.stack(evs))


class TestParticipantMeanDtype:
    def test_bf16_leaves_stay_bf16(self):
        events = jnp.asarray([True, False, True])
        per_client = {"w": jnp.ones((3, 4), jnp.bfloat16)}
        fallback = {"w": jnp.zeros((4,), jnp.bfloat16)}
        out = participant_mean(per_client, events, fallback)
        assert out["w"].dtype == jnp.bfloat16
        np.testing.assert_allclose(np.asarray(out["w"], np.float32), 1.0)

    def test_fp32_unchanged(self):
        events = jnp.asarray([True, True])
        per_client = {"w": jnp.asarray([[2.0], [4.0]], jnp.float32)}
        fallback = {"w": jnp.zeros((1,), jnp.float32)}
        out = participant_mean(per_client, events, fallback)
        assert out["w"].dtype == jnp.float32
        np.testing.assert_allclose(np.asarray(out["w"]), [3.0])


_MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import dataclasses, json
import jax, numpy as np
from repro.core import ControllerConfig, FLConfig, init_state, \
    make_flat_spec, make_round_fn
from repro.data import make_least_squares
from repro.sharding.clients import make_client_mesh

N = 8
data, p0, ls = make_least_squares(N, 8, 5)
spec = make_flat_spec(p0)
base = FLConfig(algorithm="fedback", n_clients=N, participation=0.5,
                rho=1.0, lr=0.1, momentum=0.0, epochs=2, batch_size=4,
                controller=ControllerConfig(K=0.2, alpha=0.9))
kernel = dataclasses.replace(base, use_trigger_kernel=True,
                             use_admm_kernel=True)
variants = {"flat": (base, spec), "tree": (base, None),
            "kernel": (kernel, spec)}
mesh = make_client_mesh(2)
out = {}
for vname, (vcfg, vspec) in variants.items():
    ccfg = dataclasses.replace(vcfg, compact=True, capacity=N)
    for tag, c, m in (("dense_single", vcfg, None),
                      ("compact_sharded", ccfg, mesh)):
        state = init_state(c, p0, spec=vspec, mesh=m)
        round_fn = make_round_fn(c, ls, data, spec=vspec, mesh=m)
        events, deferred = [], 0
        for _ in range(10):
            state, met = round_fn(state)
            events.append(np.asarray(met.events).astype(int).tolist())
            deferred += int(met.num_deferred)
        w = np.concatenate([np.asarray(l, np.float64).ravel()
                            for l in jax.tree.leaves(state.omega)])
        th = jax.tree.leaves(state.theta)[0]
        age = jax.tree.leaves(state.queue.age)[0]
        out[f"{vname}/{tag}"] = {
            "events": events, "omega": w.tolist(), "deferred": deferred,
            "sharding": str(th.sharding),
            "queue_sharding": str(age.sharding)}
print("RESULT:" + json.dumps(out))
"""


class TestCompactShardedParity:
    """2-device legs of the parity matrix: {flat, tree, kernel} compact
    sharded runs vs their single-device dense references — queue
    enabled, capacity=N (nothing may defer)."""

    VARIANTS = ("flat", "tree", "kernel")

    @pytest.fixture(scope="class")
    def result(self):
        env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
        out = subprocess.run([sys.executable, "-c", _MESH_SCRIPT], env=env,
                             capture_output=True, text=True, timeout=560,
                             cwd=REPO)
        assert out.returncode == 0, out.stderr[-3000:]
        line = [l for l in out.stdout.splitlines()
                if l.startswith("RESULT:")]
        return json.loads(line[-1][len("RESULT:"):])

    @pytest.mark.parametrize("variant", VARIANTS)
    def test_state_and_queue_are_client_sharded(self, result, variant):
        r = result[f"{variant}/compact_sharded"]
        assert "clients" in r["sharding"]
        assert "clients" in r["queue_sharding"]

    @pytest.mark.parametrize("variant", VARIANTS)
    def test_events_bit_identical_to_single_device_dense(self, result,
                                                         variant):
        assert (result[f"{variant}/dense_single"]["events"]
                == result[f"{variant}/compact_sharded"]["events"])

    @pytest.mark.parametrize("variant", VARIANTS)
    def test_omega_within_fp32_tolerance(self, result, variant):
        a = np.asarray(result[f"{variant}/dense_single"]["omega"])
        b = np.asarray(result[f"{variant}/compact_sharded"]["omega"])
        np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-5)

    @pytest.mark.parametrize("variant", VARIANTS)
    def test_capacity_n_never_defers(self, result, variant):
        assert result[f"{variant}/compact_sharded"]["deferred"] == 0


# The fused-round op-count assertions (exactly one Pallas ADMM pass,
# no surviving full-width sweeps, tree layout kernel-free) moved onto
# the repro.analysis rule engine -- tests/test_analysis.py runs them
# in tier-1 over a fast configuration subset, and the tracecheck CLI
# gates the full matrix nightly.  See docs/analysis.md.
