"""End-to-end behaviour tests for the FedBack system.

These are the paper's claims executed at CI scale:
  * FedBack converges on non-iid data and tracks L̄ (Thm. 2 / Tab. 2).
  * Deterministic selection beats random selection on events-to-accuracy
    (Tab. 1's direction, at reduced scale).
  * The full algorithm family runs under one engine.
"""
import jax
import numpy as np
import pytest

from repro.core import (
    ControllerConfig,
    FLConfig,
    init_state,
    make_eval_fn,
    make_round_fn,
    realized_rate,
)
from repro.data import federated_arrays, make_synthetic_mnist
from repro.models.mlp import (
    init_mlp,
    make_loss_and_acc_fn,
    make_loss_fn,
    mlp_logits,
)

N = 16
ROUNDS = 90


@pytest.fixture(scope="module")
def mnist_setup():
    ds = make_synthetic_mnist(n_train=3360, n_test=800)
    data, test = federated_arrays(ds, n_clients=N, scheme="label_shard")
    params0 = init_mlp(jax.random.PRNGKey(0))
    loss_fn = make_loss_fn(mlp_logits)
    eval_fn = make_eval_fn(make_loss_and_acc_fn(mlp_logits))
    return data, test, params0, loss_fn, eval_fn


def _run(alg, mnist_setup, rate=0.25, rounds=ROUNDS, K=2.0):
    data, test, params0, loss_fn, eval_fn = mnist_setup
    cfg = FLConfig(algorithm=alg, n_clients=N, participation=rate,
                   rho=0.01, mu=0.01, lr=0.01, epochs=2, batch_size=42,
                   controller=ControllerConfig(K=K, alpha=0.9), seed=1)
    state = init_state(cfg, params0)
    round_fn = make_round_fn(cfg, loss_fn, data)
    events = []
    accs = []
    for k in range(rounds):
        state, m = round_fn(state)
        events.append(int(m.num_events))
        if k % 10 == 0 or k == rounds - 1:
            _, acc = eval_fn(state, test["x"], test["y"])
            accs.append(float(acc))
    return state, events, accs


class TestFedBackEndToEnd:
    def test_converges_on_noniid_mnist(self, mnist_setup):
        state, events, accs = _run("fedback", mnist_setup)
        assert accs[-1] > 0.85, accs

    def test_tracks_target_rate(self, mnist_setup):
        state, events, accs = _run("fedback", mnist_setup)
        rate = np.asarray(realized_rate(state.ctrl)).mean()
        # O(1/T) with a full-participation transient: generous band
        assert 0.15 <= rate <= 0.45, rate

    def test_round_zero_fires_everyone_then_throttles(self, mnist_setup):
        state, events, accs = _run("fedback", mnist_setup)
        assert events[0] == N
        tail = events[len(events) // 2:]
        assert np.mean(tail) < 0.6 * N

    def test_all_algorithms_learn(self, mnist_setup):
        for alg in ("fedadmm", "fedavg", "fedprox"):
            state, events, accs = _run(alg, mnist_setup, rounds=60)
            assert accs[-1] > 0.5, (alg, accs)

    def test_fedback_beats_random_on_events_to_accuracy(self, mnist_setup):
        """Tab. 1 direction at CI scale: same (good) accuracy from fewer
        participation events than random FedADMM selection.

        The target sits near the run's accuracy plateau (~0.94), which
        is where the paper's claim lives: deterministic selection
        reaches *stable* accuracy in fewer events, while random
        selection's round-to-round accuracy variance (Fig. 1) delays
        it.  At N=16 the integral controller's rate transient dominates
        the low-accuracy regime (the exactly-2-classes conservation-
        exact label shards are genuinely heterogeneous), so a low
        target would measure the transient, not the selection rule.
        """
        target = 0.93
        _, ev_fb, acc_fb = _run("fedback", mnist_setup, rounds=ROUNDS)
        _, ev_fa, acc_fa = _run("fedadmm", mnist_setup, rounds=ROUNDS)

        def events_to(evs, accs, rounds_per_eval=10):
            cum = np.cumsum(evs)
            for i, a in enumerate(accs):
                if a >= target:
                    return cum[min(i * rounds_per_eval, len(cum) - 1)]
            return np.inf

        e_fb = events_to(ev_fb, acc_fb)
        e_fa = events_to(ev_fa, acc_fa)
        assert e_fb < np.inf, "fedback never reached target"
        # deterministic selection should not be slower than random
        assert e_fb <= 1.2 * e_fa, (e_fb, e_fa)
