"""ADMM engine correctness on analytically-solvable problems.

Per-client least squares f_i(θ) = (1/2 n_i)‖A_i θ − b_i‖² gives a
closed-form global minimizer of Σ_i f_i — the engine must converge to it
(Theorem 5 is about stationary points; for strongly convex quadratics
the stationary point is unique and global).
"""
import jax.numpy as jnp
import numpy as np

from repro.core import (
    ControllerConfig,
    FLConfig,
    init_state,
    make_round_fn,
)

D = 5
N_CLIENTS = 4
N_POINTS = 8


def _quadratic_problem(seed=0):
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(N_CLIENTS, N_POINTS, D)).astype(np.float32)
    # heterogeneous targets → genuinely different local minimizers
    theta_true = rng.normal(size=(N_CLIENTS, D)).astype(np.float32)
    b = np.einsum("npd,nd->np", A, theta_true) + 0.05 * rng.normal(
        size=(N_CLIENTS, N_POINTS)).astype(np.float32)
    # global minimizer of Σ_i (1/2 n_i)‖A_i θ − b_i‖²
    H = sum(A[i].T @ A[i] / N_POINTS for i in range(N_CLIENTS))
    g = sum(A[i].T @ b[i] / N_POINTS for i in range(N_CLIENTS))
    theta_star = np.linalg.solve(H, g)
    data = {"x": jnp.asarray(A), "y": jnp.asarray(b)}
    return data, theta_star




def ls_loss(params, x, y):
    r = x @ params["theta"] - y
    return 0.5 * jnp.mean(r * r)


def _run(alg, data, *, rounds, participation=1.0, rho=1.0, lr=0.15,
         epochs=40, seed=0, controller=None, warm_start=True, mu=0.0):
    cfg = FLConfig(
        algorithm=alg, n_clients=N_CLIENTS, participation=participation,
        rho=rho, mu=mu, lr=lr, momentum=0.0, epochs=epochs,
        batch_size=N_POINTS, seed=seed, warm_start=warm_start,
        controller=controller or ControllerConfig(K=0.05, alpha=0.9))
    params0 = {"theta": jnp.zeros((D,), jnp.float32)}
    state = init_state(cfg, params0)
    round_fn = make_round_fn(cfg, ls_loss, data)
    evs = []
    for _ in range(rounds):
        state, m = round_fn(state)
        evs.append(int(m.num_events))
    return state, evs


class TestVanillaADMM:
    def test_converges_to_global_minimizer(self):
        data, theta_star = _quadratic_problem()
        state, _ = _run("admm", data, rounds=40)
        got = np.asarray(state.omega["theta"])
        np.testing.assert_allclose(got, theta_star, atol=2e-2)

    def test_duals_sum_to_near_zero(self):
        """At consensus Σλ_i ⊥ residuals; ω-update keeps mean λ ≈ 0."""
        data, _ = _quadratic_problem()
        state, _ = _run("admm", data, rounds=40)
        lam_mean = np.asarray(jnp.mean(state.lam["theta"], 0))
        # z-average construction: ω = mean(θ)+mean(λ); consensus θ_i→ω
        assert np.linalg.norm(lam_mean) < 0.5

    def test_full_participation_every_round(self):
        data, _ = _quadratic_problem()
        _, evs = _run("admm", data, rounds=10)
        assert all(e == N_CLIENTS for e in evs)


class TestFedBackReducesToADMM:
    def test_delta_zero_gain_zero_matches_vanilla(self):
        """K=0, δ⁰=0 ⇒ every trigger fires (distance ≥ 0) ⇒ vanilla ADMM."""
        data, _ = _quadratic_problem()
        ctrl = ControllerConfig(K=0.0, alpha=0.9, delta0=0.0)
        s_fb, ev_fb = _run("fedback", data, rounds=15, controller=ctrl)
        s_admm, ev_admm = _run("admm", data, rounds=15)
        assert ev_fb == ev_admm == [N_CLIENTS] * 15
        np.testing.assert_allclose(
            np.asarray(s_fb.omega["theta"]),
            np.asarray(s_admm.omega["theta"]), rtol=1e-5, atol=1e-6)


class TestFedBackQuadratic:
    def test_converges_with_partial_participation(self):
        data, theta_star = _quadratic_problem()
        ctrl = ControllerConfig(K=0.2, alpha=0.9)
        state, evs = _run("fedback", data, rounds=150, participation=0.5,
                          rho=1.0, controller=ctrl)
        got = np.asarray(state.omega["theta"])
        np.testing.assert_allclose(got, theta_star, atol=5e-2)
        rate = sum(evs) / (150 * N_CLIENTS)
        assert abs(rate - 0.5) < 0.1, rate

    def test_fedadmm_random_also_converges(self):
        data, theta_star = _quadratic_problem()
        state, evs = _run("fedadmm", data, rounds=150, participation=0.5)
        np.testing.assert_allclose(np.asarray(state.omega["theta"]),
                                   theta_star, atol=5e-2)
        assert all(e == 2 for e in evs)  # exactly ⌊0.5·4⌋ random clients


class TestAvgFamily:
    def test_fedavg_converges_on_iid_quadratic(self):
        # identical clients → FedAvg's fixed point is the true minimizer
        rng = np.random.default_rng(1)
        A0 = rng.normal(size=(N_POINTS, D)).astype(np.float32)
        theta_true = rng.normal(size=(D,)).astype(np.float32)
        b0 = (A0 @ theta_true).astype(np.float32)
        data = {"x": jnp.asarray(np.stack([A0] * N_CLIENTS)),
                "y": jnp.asarray(np.stack([b0] * N_CLIENTS))}
        state, _ = _run("fedavg", data, rounds=30, rho=0.0)
        np.testing.assert_allclose(np.asarray(state.omega["theta"]),
                                   theta_true, atol=2e-2)

    def test_fedprox_prox_term_limits_drift(self):
        data, _ = _quadratic_problem()
        s_prox, _ = _run("fedprox", data, rounds=1, mu=5.0, epochs=40)
        s_avg, _ = _run("fedavg", data, rounds=1, epochs=40)
        w0 = np.zeros(D, np.float32)
        d_prox = np.linalg.norm(np.asarray(s_prox.omega["theta"]) - w0)
        d_avg = np.linalg.norm(np.asarray(s_avg.omega["theta"]) - w0)
        assert d_prox < d_avg  # μ‖θ−ω‖² anchors locals to the server


class TestEngineMechanics:
    def test_non_participants_keep_state(self):
        data, _ = _quadratic_problem()
        cfg = FLConfig(algorithm="fedadmm", n_clients=N_CLIENTS,
                       participation=0.25, rho=1.0, lr=0.1, momentum=0.0,
                       epochs=2, batch_size=N_POINTS, seed=3)
        params0 = {"theta": jnp.zeros((D,), jnp.float32)}
        state = init_state(cfg, params0)
        round_fn = make_round_fn(cfg, ls_loss, data)
        prev_theta = np.asarray(state.theta["theta"])
        state2, m = round_fn(state)
        ev = np.asarray(m.events)
        new_theta = np.asarray(state2.theta["theta"])
        for i in range(N_CLIENTS):
            if not ev[i]:
                np.testing.assert_array_equal(new_theta[i], prev_theta[i])
            else:
                assert not np.allclose(new_theta[i], prev_theta[i])

    def test_round_zero_full_participation_under_fedback(self):
        """δ⁰=0 and z_i^prev=θ⁰=ω⁰ ⇒ distance 0 ≥ 0 fires everyone."""
        data, _ = _quadratic_problem()
        cfg = FLConfig(algorithm="fedback", n_clients=N_CLIENTS,
                       participation=0.25, rho=1.0, lr=0.1, epochs=2,
                       batch_size=N_POINTS)
        params0 = {"theta": jnp.zeros((D,), jnp.float32)}
        state = init_state(cfg, params0)
        round_fn = make_round_fn(cfg, ls_loss, data)
        _, m = round_fn(state)
        assert int(m.num_events) == N_CLIENTS
