"""Stale-tolerant round engine (core/fedback.py max_staleness).

Three layers:

* **parity** — the async pipeline at ``max_staleness=0`` reproduces the
  synchronous engine bit-identically (events) / bitwise (ω on a single
  device), across {dense, compact-with-deferral} × {flat, tree} layouts
  and on a 2-device mesh (subprocess leg, mirroring the PR 2/3 parity
  matrices);
* **pipeline mechanics** — delayed solves land exactly δ_i rounds after
  service, in-flight clients are ineligible to re-fire or be planned,
  and the controller measures commit-time events;
* **conservation properties** (hypothesis / the executing mini
  fallback) — no unit of in-flight work is lost or duplicated:
  issued − committed = in-flight, at every round, for adversarial
  event streams.
"""
import dataclasses
import json
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import ControllerConfig, FLConfig, init_state, \
    make_flat_spec, make_round_fn, run_rounds
from repro.core.controller import clamp_target_rate, feasible_rate
from repro.core.engine import measured_commits, record_issue, \
    staleness_masks
from repro.core.state import delay_schedule
from repro.data import make_least_squares


REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _cfg(n, **kw):
    base = dict(algorithm="fedback", n_clients=n, participation=0.5,
                rho=1.0, lr=0.1, momentum=0.0, epochs=2, batch_size=4,
                controller=ControllerConfig(K=0.2, alpha=0.9))
    base.update(kw)
    return FLConfig(**base)


def _run(cfg, data, params0, ls, *, spec=None, rounds=10):
    state = init_state(cfg, params0, spec=spec)
    round_fn = make_round_fn(cfg, ls, data, spec=spec)
    state, hist = run_rounds(round_fn, state, rounds)
    return state, hist


class TestDelaySchedule:
    def test_roundrobin_is_uniform_and_deterministic(self):
        d = np.asarray(delay_schedule(9, 2))
        np.testing.assert_array_equal(d, np.arange(9) % 3)
        assert d.min() == 0 and d.max() == 2

    def test_uniform_is_seed_deterministic_and_bounded(self):
        a = np.asarray(delay_schedule(64, 3, kind="uniform", seed=7))
        b = np.asarray(delay_schedule(64, 3, kind="uniform", seed=7))
        np.testing.assert_array_equal(a, b)
        assert a.min() >= 0 and a.max() <= 3
        assert not np.array_equal(
            a, np.asarray(delay_schedule(64, 3, kind="uniform", seed=8)))

    def test_zero_staleness_schedule_is_all_zero(self):
        np.testing.assert_array_equal(np.asarray(delay_schedule(5, 0)), 0)


class TestStalenessZeroParity:
    """max_staleness=0 ≡ the synchronous engine, bit for bit — including
    the compact path with genuine deferral (capacity < N), which is a
    *stronger* leg than the PR 2/3 capacity=N matrices."""

    def _pair(self, cfg, *, flat=True, rounds=10):
        n = cfg.n_clients
        data, params0, ls = make_least_squares(n, 8, 5)
        spec = make_flat_spec(params0) if flat else None
        st_sync, h_sync = _run(cfg, data, params0, ls, spec=spec,
                               rounds=rounds)
        st_async, h_async = _run(dataclasses.replace(cfg, max_staleness=0),
                                 data, params0, ls, spec=spec,
                                 rounds=rounds)
        return st_sync, h_sync, st_async, h_async

    def _assert_identical(self, st_sync, h_sync, st_async, h_async,
                          *, flat=True):
        np.testing.assert_array_equal(np.asarray(h_sync.events),
                                      np.asarray(h_async.events))
        a = st_sync.omega if flat else st_sync.omega["theta"]
        b = st_async.omega if flat else st_async.omega["theta"]
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_dense_flat(self):
        self._assert_identical(*self._pair(_cfg(8)))

    def test_dense_tree_layout(self):
        self._assert_identical(*self._pair(_cfg(6), flat=False),
                               flat=False)

    def test_compact_with_deferral(self):
        cfg = _cfg(8, compact=True, capacity=3)  # round 0 must defer 5
        st_s, h_s, st_a, h_a = self._pair(cfg)
        self._assert_identical(st_s, h_s, st_a, h_a)
        np.testing.assert_array_equal(np.asarray(h_s.num_deferred),
                                      np.asarray(h_a.num_deferred))

    def test_compact_adaptive_capacity(self):
        cfg = _cfg(16, participation=0.25, compact=True,
                   capacity_slack=1.5,
                   controller=ControllerConfig(K=0.5, alpha=0.9))
        st_s, h_s, st_a, h_a = self._pair(cfg, rounds=15)
        self._assert_identical(st_s, h_s, st_a, h_a)
        np.testing.assert_array_equal(np.asarray(h_s.realized_capacity),
                                      np.asarray(h_a.realized_capacity))

    def test_fedavg_family(self):
        cfg = _cfg(8, algorithm="fedavg", rho=0.0)
        self._assert_identical(*self._pair(cfg))

    def test_async_metrics_are_inert_at_zero_staleness(self):
        _, _, _, h_async = self._pair(_cfg(8))
        np.testing.assert_array_equal(np.asarray(h_async.num_inflight), 0)
        np.testing.assert_array_equal(np.asarray(h_async.num_landed), 0)


class TestDelayPipeline:
    def test_delayed_solve_lands_exactly_delta_rounds_later(self):
        """One client, forced δ=2: its θ row must stay untouched for two
        rounds after service and change exactly at landing."""
        n = 4
        data, params0, ls = make_least_squares(n, 8, 5)
        spec = make_flat_spec(params0)
        cfg = _cfg(n, max_staleness=2)
        state = init_state(cfg, params0, spec=spec)
        # pin the schedule: client 0 fires with δ=2, nobody else fires
        state = state._replace(
            inflight=state.inflight._replace(
                delay=jnp.asarray([2, 0, 0, 0], jnp.int32)),
            ctrl=state.ctrl._replace(
                delta=jnp.asarray([-1.0, 1e9, 1e9, 1e9], jnp.float32)))
        round_fn = make_round_fn(cfg, ls, data, spec=spec)
        th0 = np.asarray(state.theta)

        state, m = round_fn(state)  # service round: parks, no commit
        assert int(m.num_events) == 1
        assert int(m.num_inflight) == 1 and int(m.num_landed) == 0
        np.testing.assert_array_equal(np.asarray(state.theta), th0)
        # mute all triggers from here on
        state = state._replace(ctrl=state.ctrl._replace(
            delta=jnp.full((n,), 1e9, jnp.float32)))

        state, m = round_fn(state)  # still in flight
        assert int(m.num_inflight) == 1 and int(m.num_landed) == 0
        np.testing.assert_array_equal(np.asarray(state.theta), th0)

        state, m = round_fn(state)  # lands now
        assert int(m.num_landed) == 1 and int(m.num_inflight) == 0
        changed = np.abs(np.asarray(state.theta) - th0).max(axis=1) > 0
        np.testing.assert_array_equal(changed, [True, False, False, False])

    def test_inflight_client_cannot_refire(self):
        """A client with a parked solve is ineligible even when its
        trigger distance exceeds the threshold every round."""
        n = 4
        data, params0, ls = make_least_squares(n, 8, 5)
        spec = make_flat_spec(params0)
        cfg = _cfg(n, max_staleness=3)
        state = init_state(cfg, params0, spec=spec)
        state = state._replace(
            inflight=state.inflight._replace(
                delay=jnp.full((n,), 3, jnp.int32)))
        round_fn = make_round_fn(cfg, ls, data, spec=spec)
        state, m = round_fn(state)  # δ⁰=0: everyone fires, all park
        assert int(m.num_events) == n
        # thresholds stay at their controller values (≤ distances), yet
        # nothing may re-fire while the pipeline is full
        state, m = round_fn(state)
        assert int(m.num_events) == 0
        state, m = round_fn(state)
        assert int(m.num_events) == 0

    def test_controller_measures_commit_time_events(self):
        """With a uniform delay δ=2 the controller's event_count stays
        zero until the first landings arrive, then tracks issues with a
        two-round lag."""
        n = 4
        data, params0, ls = make_least_squares(n, 8, 5)
        spec = make_flat_spec(params0)
        cfg = _cfg(n, max_staleness=2)
        state = init_state(cfg, params0, spec=spec)
        state = state._replace(
            inflight=state.inflight._replace(
                delay=jnp.full((n,), 2, jnp.int32)))
        round_fn = make_round_fn(cfg, ls, data, spec=spec)
        state, m = round_fn(state)  # round 0: all issue, none measured
        assert int(m.num_events) == n
        assert int(np.asarray(state.ctrl.event_count).sum()) == 0
        state, m = round_fn(state)  # round 1: still nothing measured
        assert int(np.asarray(state.ctrl.event_count).sum()) == 0
        state, m = round_fn(state)  # round 2: round-0 issues measured
        assert int(np.asarray(state.ctrl.event_count).sum()) == n

    def test_compact_queue_composes_with_staleness(self):
        """Deferral queue + pipeline: the round-0 burst drains through
        capacity slots and every serviced solve still lands δ_i rounds
        later; nothing is lost."""
        n = 8
        data, params0, ls = make_least_squares(n, 8, 5)
        spec = make_flat_spec(params0)
        cfg = _cfg(n, compact=True, capacity=2, max_staleness=2)
        state = init_state(cfg, params0, spec=spec)
        round_fn = make_round_fn(cfg, ls, data, spec=spec)
        th0 = np.asarray(state.theta)
        for _ in range(3 * n):
            state, m = round_fn(state)
            # mute fresh triggers after the burst so only the queue plays
            state = state._replace(ctrl=state.ctrl._replace(
                delta=jnp.full((n,), 1e9, jnp.float32)))
        served = np.abs(np.asarray(state.theta) - th0).max(axis=1) > 0
        assert served.all()  # the whole burst landed eventually
        assert int(np.asarray(state.queue.age).max()) == 0
        assert int(np.asarray(state.inflight.ttl).max()) == 0

    def test_random_selection_redraws_among_eligible(self):
        """Open-loop random selection must hit the feasible rate under
        staleness, not the under-shot fixed point L̄/(1+L̄): with uniform
        δ=1 and L̄=0.5 the redraw-among-eligible draw alternates halves
        at realized rate 0.5 (the naive discard would settle at ~1/3)."""
        n = 8
        data, params0, ls = make_least_squares(n, 8, 5)
        spec = make_flat_spec(params0)
        cfg = _cfg(n, algorithm="fedavg", rho=0.0, max_staleness=1)
        state = init_state(cfg, params0, spec=spec)
        state = state._replace(inflight=state.inflight._replace(
            delay=jnp.ones((n,), jnp.int32)))
        round_fn = make_round_fn(cfg, ls, data, spec=spec)
        state, hist = run_rounds(round_fn, state, 30)
        realized = float(np.asarray(hist.events, np.float32).mean())
        assert realized > 0.45, realized  # feasible 0.5, naive ~0.33

    def test_feasible_rate_clamp(self):
        d = jnp.asarray([0, 1, 3], jnp.int32)
        np.testing.assert_allclose(np.asarray(feasible_rate(d)),
                                   [1.0, 0.5, 0.25])
        np.testing.assert_allclose(
            np.asarray(clamp_target_rate(0.4, d)), [0.4, 0.4, 0.25])


_MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import dataclasses, json
import jax, numpy as np
from repro.core import ControllerConfig, FLConfig, init_state, \
    make_flat_spec, make_round_fn
from repro.data import make_least_squares
from repro.sharding.clients import make_client_mesh

N = 8
data, p0, ls = make_least_squares(N, 8, 5)
spec = make_flat_spec(p0)
base = FLConfig(algorithm="fedback", n_clients=N, participation=0.5,
                rho=1.0, lr=0.1, momentum=0.0, epochs=2, batch_size=4,
                controller=ControllerConfig(K=0.2, alpha=0.9))
mesh = make_client_mesh(2)
variants = {
    "dense": base,
    "compact_defer": dataclasses.replace(
        base, compact=True, participation=0.25, capacity_slack=1.5),
}
out = {}
for vname, vcfg in variants.items():
    for tag, c in (("sync", vcfg),
                   ("async0", dataclasses.replace(vcfg, max_staleness=0)),
                   ("async2", dataclasses.replace(vcfg, max_staleness=2))):
        state = init_state(c, p0, spec=spec, mesh=mesh)
        round_fn = make_round_fn(c, ls, data, spec=spec, mesh=mesh)
        events, landed = [], 0
        for _ in range(10):
            state, met = round_fn(state)
            events.append(np.asarray(met.events).astype(int).tolist())
            landed += int(met.num_landed)
        rec = {"events": events,
               "omega": np.asarray(state.omega, np.float64).tolist(),
               "landed": landed}
        if state.inflight is not None:
            rec["ttl_sharding"] = str(state.inflight.ttl.sharding)
            rec["hist_sharding"] = str(state.inflight.hist.sharding)
        out[f"{vname}/{tag}"] = rec
print("RESULT:" + json.dumps(out))
"""


class TestShardedAsyncParity:
    """2-device mesh legs: the async pipeline under the clients mesh —
    staleness-0 bit-identical to the sharded synchronous engine, the
    pipeline state client-sharded (shard-local, no cross-device
    migration), and staleness-2 actually exercising the delay line."""

    VARIANTS = ("dense", "compact_defer")

    @pytest.fixture(scope="class")
    def result(self):
        env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
        out = subprocess.run([sys.executable, "-c", _MESH_SCRIPT], env=env,
                             capture_output=True, text=True, timeout=560,
                             cwd=REPO)
        assert out.returncode == 0, out.stderr[-3000:]
        line = [ln for ln in out.stdout.splitlines()
                if ln.startswith("RESULT:")]
        return json.loads(line[-1][len("RESULT:"):])

    @pytest.mark.parametrize("variant", VARIANTS)
    def test_staleness0_bit_identical_to_sync(self, result, variant):
        assert (result[f"{variant}/sync"]["events"]
                == result[f"{variant}/async0"]["events"])
        np.testing.assert_array_equal(
            np.asarray(result[f"{variant}/sync"]["omega"]),
            np.asarray(result[f"{variant}/async0"]["omega"]))

    @pytest.mark.parametrize("variant", VARIANTS)
    def test_pipeline_state_is_client_sharded(self, result, variant):
        rec = result[f"{variant}/async2"]
        assert "clients" in rec["ttl_sharding"]
        assert "clients" in rec["hist_sharding"]

    @pytest.mark.parametrize("variant", VARIANTS)
    def test_staleness2_exercises_the_delay_line(self, result, variant):
        assert result[f"{variant}/async2"]["landed"] > 0


class TestInflightConservation:
    """issued − committed = in-flight, no duplicates — the pipeline-side
    conservation law, mirroring the queue-side one in
    tests/test_compact_properties.py."""

    @settings(max_examples=20, deadline=None)
    @given(n=st.integers(2, 24), max_staleness=st.integers(0, 4),
           fire_p=st.floats(0.0, 1.0), seed=st.integers(0, 2**31 - 1))
    def test_mask_algebra_conserves_work(self, n, max_staleness, fire_p,
                                         seed):
        """Drive the pure mask algebra (staleness_masks + the event
        ring) over an adversarial stream: at every round
        Σ issued = Σ direct + Σ landed + #in-flight, and a serviced
        client always has an empty slot (no duplicate/clobbered work)."""
        rng = np.random.default_rng(seed)
        delay = np.asarray(delay_schedule(n, max_staleness, kind="uniform",
                                          seed=seed % 1000))
        ttl = jnp.zeros((n,), jnp.int32)
        hist = jnp.zeros((n, max_staleness + 1), bool)
        issued = np.zeros(n, np.int64)
        committed = np.zeros(n, np.int64)
        for rnd in range(3 * (max_staleness + 1) + 4):
            eligible = np.asarray(ttl) == 0
            events = (rng.random(n) < fire_p) & eligible
            # no duplicates: a serviced client must have an empty slot
            assert not np.any(events & ~eligible)
            land, direct, defer, ttl = staleness_masks(
                jnp.asarray(events), jnp.asarray(delay), ttl)
            land, direct, defer = (np.asarray(x)
                                   for x in (land, direct, defer))
            assert not np.any(land & (direct | defer))  # disjoint
            hist = record_issue(hist, jnp.asarray(events),
                                jnp.asarray(rnd, jnp.int32))
            issued += events
            committed += direct | land
            inflight_now = int(np.sum(np.asarray(ttl) > 0))
            assert int(issued.sum()) - int(committed.sum()) \
                == inflight_now
        # drain: with no fresh issues everything lands within S rounds
        for rnd in range(rnd + 1, rnd + 2 + max_staleness):
            land, direct, defer, ttl = staleness_masks(
                jnp.zeros((n,), bool), jnp.asarray(delay), ttl)
            committed += np.asarray(land)
        assert int(np.asarray(ttl).max()) == 0
        np.testing.assert_array_equal(issued, committed)

    @settings(max_examples=15, deadline=None)
    @given(n=st.integers(2, 16), max_staleness=st.integers(0, 3),
           seed=st.integers(0, 2**31 - 1))
    def test_measurement_is_delayed_issue_stream(self, n, max_staleness,
                                                 seed):
        """The ring buffer reproduces each client's issue bit-stream
        shifted by exactly δ_i rounds (commit-time measurement)."""
        rng = np.random.default_rng(seed)
        delay = rng.integers(0, max_staleness + 1, n).astype(np.int32)
        hist = jnp.zeros((n, max_staleness + 1), bool)
        stream, measured_log = [], []
        for rnd in range(4 * (max_staleness + 1)):
            events = rng.random(n) < 0.5
            stream.append(events)
            hist = record_issue(hist, jnp.asarray(events),
                                jnp.asarray(rnd, jnp.int32))
            measured_log.append(np.asarray(measured_commits(
                hist, jnp.asarray(delay), jnp.asarray(rnd, jnp.int32))))
        stream = np.asarray(stream)
        measured = np.asarray(measured_log)
        for i in range(n):
            d = int(delay[i])
            expect = np.concatenate([np.zeros(d, bool), stream[:, i]])
            np.testing.assert_array_equal(measured[:, i],
                                          expect[:len(measured)])

    def test_engine_level_conservation_with_queue(self):
        """Full engine, compact + staleness: every issued event is at
        any moment exactly one of {committed, queued, in flight} — the
        cumulative commit count implied by that partition never goes
        negative or decreases, and a trigger-muted drain flushes both
        the queue and the pipeline so every issue ends committed."""
        n = 8
        data, params0, ls = make_least_squares(n, 8, 5)
        spec = make_flat_spec(params0)
        cfg = _cfg(n, compact=True, capacity=3, max_staleness=2)
        state = init_state(cfg, params0, spec=spec)
        round_fn = make_round_fn(cfg, ls, data, spec=spec)
        cum_issued, prev_committed = 0, 0
        for _ in range(20):
            state, m = round_fn(state)
            cum_issued += int(m.num_events)
            backlog = int(m.num_deferred) + int(m.num_inflight)
            cum_committed = cum_issued - backlog
            assert cum_committed >= prev_committed  # no loss, no dupes
            prev_committed = cum_committed
        # drain: no fresh issues; queue + pipeline must flush completely
        for _ in range(n + cfg.max_staleness + 2):
            state = state._replace(ctrl=state.ctrl._replace(
                delta=jnp.full((n,), 1e9, jnp.float32)))
            state, m = round_fn(state)
            assert int(m.num_events) == 0
        assert int(np.asarray(state.queue.age).max()) == 0
        assert int(np.asarray(state.inflight.ttl).max()) == 0
        assert int(m.num_deferred) == 0 and int(m.num_inflight) == 0