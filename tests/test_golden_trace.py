"""Golden-trace regression: a fixed-seed 30-round N=64 FedBack run.

The compacted round engine (deferral queue + adaptive capacity, flat
layout) is replayed against a checked-in trace: the full event stream
(bit-exact) and the final server ω (sha256 of the fp32 bytes plus a
value-level comparison).  Any silent numerical drift from a future
kernel/compaction refactor trips this before it can contaminate
benchmark baselines.

Regenerate intentionally with:

    python -m pytest tests/test_golden_trace.py --update-golden
"""
import hashlib
import json
import os
import platform

import jax
import numpy as np
import pytest

from repro.core import ControllerConfig, FLConfig, init_state, \
    make_flat_spec, make_round_fn, run_rounds
from repro.data import make_least_squares

GOLDEN_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "golden", "fedback_n64_r30.json")
N, ROUNDS = 64, 30


def _run_trace():
    data, params0, ls = make_least_squares(N, 8, 5)
    spec = make_flat_spec(params0)
    cfg = FLConfig(algorithm="fedback", n_clients=N, participation=0.25,
                   rho=1.0, lr=0.1, momentum=0.0, epochs=2, batch_size=4,
                   seed=0, compact=True, capacity_slack=1.25,
                   controller=ControllerConfig(K=0.5, alpha=0.9))
    state = init_state(cfg, params0, spec=spec)
    round_fn = make_round_fn(cfg, ls, data, spec=spec)
    state, hist = run_rounds(round_fn, state, ROUNDS)
    events = np.asarray(hist.events).astype(np.uint8)
    omega = np.asarray(state.omega, np.float32).reshape(-1)
    deferred = np.asarray(hist.num_deferred).astype(int)
    return events, omega, deferred


def _event_hex(events: np.ndarray) -> list[str]:
    return [np.packbits(row).tobytes().hex() for row in events]


def _env_fingerprint() -> str:
    """Environment the golden bytes were produced on.  ULP-level float
    differences across jaxlib versions / CPU archs are legitimate, so
    the bit-exact hash is only enforced on a matching fingerprint (the
    value-level and event-stream asserts always run)."""
    return (f"jax={jax.__version__};backend={jax.default_backend()};"
            f"machine={platform.machine()}")


def _record(events, omega, deferred) -> dict:
    return {
        "n_clients": N,
        "rounds": ROUNDS,
        "env": _env_fingerprint(),
        "events_hex": _event_hex(events),
        "deferred": deferred.tolist(),
        "omega": [float(x) for x in omega],
        "omega_sha256": hashlib.sha256(omega.tobytes()).hexdigest(),
    }


class TestGoldenTrace:
    def test_fixed_seed_run_matches_golden(self, request):
        events, omega, deferred = _run_trace()
        record = _record(events, omega, deferred)
        if request.config.getoption("--update-golden"):
            os.makedirs(os.path.dirname(GOLDEN_PATH), exist_ok=True)
            with open(GOLDEN_PATH, "w") as f:
                json.dump(record, f, indent=1)
            pytest.skip(f"golden trace rewritten: {GOLDEN_PATH}")
        assert os.path.exists(GOLDEN_PATH), \
            "no golden trace checked in — run with --update-golden"
        with open(GOLDEN_PATH) as f:
            golden = json.load(f)
        if (record["env"] != golden.get("env")
                and not os.environ.get("REPRO_GOLDEN_BITEXACT")):
            # ULP-level float drift across jaxlib versions / CPU archs
            # can legitimately flip near-threshold trigger events, so
            # off the generating environment the discrete trace is not
            # comparable either; numerics are guarded there by the
            # parity matrix in tests/test_compact.py instead.
            pytest.skip(f"golden generated on {golden.get('env')!r}, "
                        f"running on {record['env']!r} — regenerate with "
                        "--update-golden or force via REPRO_GOLDEN_BITEXACT")
        assert record["events_hex"] == golden["events_hex"], \
            "event stream drifted from the golden trace"
        assert record["deferred"] == golden["deferred"], \
            "deferral-queue trajectory drifted from the golden trace"
        np.testing.assert_allclose(
            omega, np.asarray(golden["omega"], np.float32),
            rtol=1e-6, atol=1e-7,
            err_msg="final ω drifted beyond fp32 tolerance")
        assert record["omega_sha256"] == golden["omega_sha256"], \
            ("final ω bytes changed (within tolerance, but bit-level "
             "drift — inspect, then --update-golden if intentional)")
