"""Golden-trace regression: fixed-seed 30-round N=64 FedBack runs.

Four traces are pinned — the compacted synchronous engine (deferral
queue + adaptive capacity, flat layout), the stale-tolerant engine
at ``max_staleness=2`` (delay pipeline + commit-time controller
measurements on top of the same compacted round), the **ragged**
compacted engine (Dirichlet-drawn heterogeneous shard sizes pooled
into one CSR buffer — size-bucketed masked solves through the capacity
slots), so future PRs can't silently change ragged numerics, and the
**int8 compressed-consensus** engine (``consensus_compress="int8"``,
core/compress.py: quantized z-deltas + error-feedback residual on the
same compacted round), so quantizer or residual refactors can't
silently move the compressed trajectory.  Each is replayed
against a checked-in record: the full event stream (bit-exact), the
deferral/in-flight trajectories, and the final server ω (sha256 of the
fp32 bytes plus a value-level comparison).  Any silent numerical drift
from a future kernel/compaction/staleness refactor trips this before it
can contaminate benchmark baselines.

Regenerate intentionally with:

    python -m pytest tests/test_golden_trace.py --update-golden
"""
import hashlib
import json
import os
import platform

import jax
import numpy as np
import pytest

from repro.core import ControllerConfig, FLConfig, init_state, \
    make_flat_spec, make_round_fn, run_rounds
from repro.data import make_least_squares

GOLDEN_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          "golden")
GOLDEN_PATHS = {
    "sync": os.path.join(GOLDEN_DIR, "fedback_n64_r30.json"),
    "async_s2": os.path.join(GOLDEN_DIR, "fedback_async_n64_r30.json"),
    "ragged": os.path.join(GOLDEN_DIR, "fedback_ragged_n64_r30.json"),
    "int8": os.path.join(GOLDEN_DIR, "fedback_int8_n64_r30.json"),
}
N, ROUNDS = 64, 30


def _ragged_pool(data):
    """Deterministic Dirichlet-proportional shard sizes in [4, 16]."""
    from repro.utils.ragged import pool_data

    rng = np.random.default_rng(42)
    props = rng.dirichlet(np.full(N, 3.0))
    n_points = data["x"].shape[1]
    sizes = np.clip((props * N * n_points * 0.75).astype(int), 4,
                    n_points)
    return pool_data(
        [np.asarray(data["x"][i])[:s] for i, s in enumerate(sizes)],
        [np.asarray(data["y"][i])[:s] for i, s in enumerate(sizes)])


def _run_trace(variant: str = "sync"):
    data, params0, ls = make_least_squares(N, 16 if variant == "ragged"
                                           else 8, 5)
    spec = make_flat_spec(params0)
    ragged = None
    if variant == "ragged":
        data, ragged = _ragged_pool(data)
        assert not ragged.uniform  # the masked bucket path is pinned
    cfg = FLConfig(algorithm="fedback", n_clients=N, participation=0.25,
                   rho=1.0, lr=0.1, momentum=0.0, epochs=2, batch_size=4,
                   seed=0, compact=True, capacity_slack=1.25,
                   max_staleness=2 if variant == "async_s2" else None,
                   consensus_compress="int8" if variant == "int8"
                   else "none",
                   controller=ControllerConfig(K=0.5, alpha=0.9))
    state = init_state(cfg, params0, spec=spec)
    round_fn = make_round_fn(cfg, ls, data, spec=spec, ragged=ragged)
    state, hist = run_rounds(round_fn, state, ROUNDS)
    events = np.asarray(hist.events).astype(np.uint8)
    omega = np.asarray(state.omega, np.float32).reshape(-1)
    deferred = np.asarray(hist.num_deferred).astype(int)
    inflight = np.asarray(hist.num_inflight).astype(int)
    return events, omega, deferred, inflight


def _event_hex(events: np.ndarray) -> list[str]:
    return [np.packbits(row).tobytes().hex() for row in events]


def _env_fingerprint() -> str:
    """Environment the golden bytes were produced on.  ULP-level float
    differences across jaxlib versions / CPU archs are legitimate, so
    the bit-exact hash is only enforced on a matching fingerprint (the
    value-level and event-stream asserts always run)."""
    return (f"jax={jax.__version__};backend={jax.default_backend()};"
            f"machine={platform.machine()}")


def _record(events, omega, deferred, inflight) -> dict:
    return {
        "n_clients": N,
        "rounds": ROUNDS,
        "env": _env_fingerprint(),
        "events_hex": _event_hex(events),
        "deferred": deferred.tolist(),
        "inflight": inflight.tolist(),
        "omega": [float(x) for x in omega],
        "omega_sha256": hashlib.sha256(omega.tobytes()).hexdigest(),
    }


class TestGoldenTrace:
    @pytest.mark.parametrize("variant",
                             ["sync", "async_s2", "ragged", "int8"])
    def test_fixed_seed_run_matches_golden(self, request, variant):
        golden_path = GOLDEN_PATHS[variant]
        events, omega, deferred, inflight = _run_trace(variant)
        record = _record(events, omega, deferred, inflight)
        if request.config.getoption("--update-golden"):
            os.makedirs(os.path.dirname(golden_path), exist_ok=True)
            with open(golden_path, "w") as f:
                json.dump(record, f, indent=1)
            pytest.skip(f"golden trace rewritten: {golden_path}")
        assert os.path.exists(golden_path), \
            "no golden trace checked in — run with --update-golden"
        with open(golden_path) as f:
            golden = json.load(f)
        if (record["env"] != golden.get("env")
                and not os.environ.get("REPRO_GOLDEN_BITEXACT")):
            # ULP-level float drift across jaxlib versions / CPU archs
            # can legitimately flip near-threshold trigger events, so
            # off the generating environment the discrete trace is not
            # comparable either; numerics are guarded there by the
            # parity matrices in tests/test_compact.py and
            # tests/test_async.py instead.
            pytest.skip(f"golden generated on {golden.get('env')!r}, "
                        f"running on {record['env']!r} — regenerate with "
                        "--update-golden or force via REPRO_GOLDEN_BITEXACT")
        assert record["events_hex"] == golden["events_hex"], \
            "event stream drifted from the golden trace"
        assert record["deferred"] == golden["deferred"], \
            "deferral-queue trajectory drifted from the golden trace"
        assert record["inflight"] == golden.get("inflight",
                                                record["inflight"]), \
            "in-flight trajectory drifted from the golden trace"
        np.testing.assert_allclose(
            omega, np.asarray(golden["omega"], np.float32),
            rtol=1e-6, atol=1e-7,
            err_msg="final ω drifted beyond fp32 tolerance")
        assert record["omega_sha256"] == golden["omega_sha256"], \
            ("final ω bytes changed (within tolerance, but bit-level "
             "drift — inspect, then --update-golden if intentional)")
