"""Shared test configuration.

Three concerns live here:

* **Optional-dev-dep fallback** — the property-test modules do
  ``from hypothesis import given, settings, strategies as st`` at import
  time.  When ``hypothesis`` (a dev extra, see pyproject.toml) is not
  installed, a *mini* implementation is installed in its place that
  actually **executes** every ``@given`` test with deterministic
  pseudo-random examples (seeded per test from ``--hypothesis-seed``),
  instead of the old skip-stub — the property layer guards the
  compaction subsystem even without the real dependency.  The fallback
  supports the strategy surface this suite uses (``integers``,
  ``floats``, ``booleans``, ``sampled_from``); anything else skips with
  a clear message.  Example counts are capped (default 8, override via
  ``REPRO_MINI_HYPOTHESIS_EXAMPLES``) so tier-1 stays fast; CI installs
  real hypothesis and runs the full declared ``max_examples`` with a
  fixed ``--hypothesis-seed`` for reproducibility.
* **``slow`` marker** — the dry-run suites compile reduced transformer
  programs on 512 forced host devices (minutes per fixture).  They are
  skipped by default and enabled with ``--runslow`` or ``RUN_SLOW=1`` so
  the default tier-1 command stays fast.
* **``--update-golden``** — rewrites the golden-trace artifacts under
  tests/golden/ (see tests/test_golden_trace.py) instead of comparing
  against them.
"""
from __future__ import annotations

import os
import random
import sys
import types
import zlib

import pytest

_HAVE_REAL_HYPOTHESIS = True
_MINI_SEED = [0]  # filled from --hypothesis-seed in pytest_configure


def _mini_example_cap() -> int:
    return int(os.environ.get("REPRO_MINI_HYPOTHESIS_EXAMPLES", "8"))


class _MiniStrategy:
    """A drawable strategy of the mini-hypothesis fallback."""

    def __init__(self, name: str, draw=None):
        self.name = name
        self._draw = draw

    def draw(self, rng: random.Random):
        if self._draw is None:
            pytest.skip(f"strategy {self.name} is not supported by the "
                        "mini-hypothesis fallback (pip install .[dev])")
        return self._draw(rng)

    def __repr__(self):
        return f"<mini-hypothesis strategy {self.name}>"


def _mini_strategies() -> types.ModuleType:
    st = types.ModuleType("hypothesis.strategies")
    st.__stub__ = True  # marker for debugging / schema tests

    def integers(min_value, max_value):
        return _MiniStrategy(
            f"integers({min_value}, {max_value})",
            lambda rng: rng.randint(min_value, max_value))

    def floats(min_value, max_value, **_kw):
        return _MiniStrategy(
            f"floats({min_value}, {max_value})",
            lambda rng: rng.uniform(min_value, max_value))

    def booleans():
        return _MiniStrategy("booleans()", lambda rng: rng.random() < 0.5)

    def sampled_from(elements):
        seq = list(elements)
        return _MiniStrategy(f"sampled_from({seq!r})",
                             lambda rng: seq[rng.randrange(len(seq))])

    st.integers = integers
    st.floats = floats
    st.booleans = booleans
    st.sampled_from = sampled_from
    # Unknown strategies degrade to a clean per-test skip, never a
    # collection error.
    st.__getattr__ = lambda name: (  # PEP 562
        lambda *a, **k: _MiniStrategy(name))
    return st


def _install_hypothesis_fallback() -> None:
    global _HAVE_REAL_HYPOTHESIS
    try:
        import hypothesis  # noqa: F401
        return
    except ModuleNotFoundError:
        _HAVE_REAL_HYPOTHESIS = False

    mod = types.ModuleType("hypothesis")
    mod.__stub__ = True

    def given(*_args, **strategies):
        if _args:
            raise TypeError(
                "mini-hypothesis fallback supports keyword strategies "
                "only — write @given(x=st.integers(...)) or install the "
                "real dependency (pip install .[dev])")

        def deco(fn):
            def runner(*args, **kwargs):
                cfg = getattr(runner, "_mini_settings", None) or \
                    getattr(fn, "_mini_settings", None) or {}
                n_examples = min(cfg.get("max_examples", 25),
                                 _mini_example_cap())
                base = zlib.crc32(fn.__qualname__.encode()) ^ _MINI_SEED[0]
                for i in range(n_examples):
                    rng = random.Random(base + i)
                    example = {k: s.draw(rng)
                               for k, s in strategies.items()}
                    try:
                        fn(*args, **example, **kwargs)
                    except Exception:
                        print(f"\nmini-hypothesis falsifying example "
                              f"(seed {_MINI_SEED[0]}, #{i}): {example}",
                              file=sys.stderr)
                        raise

            runner.__name__ = getattr(fn, "__name__", "hypothesis_test")
            runner.__doc__ = getattr(fn, "__doc__", None)
            return runner

        return deco

    def settings(*_args, **kwargs):
        def deco(fn):
            fn._mini_settings = kwargs
            return fn

        return deco

    st = _mini_strategies()
    mod.given = given
    mod.settings = settings
    mod.strategies = st
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st


_install_hypothesis_fallback()


def pytest_addoption(parser):
    parser.addoption(
        "--runslow", action="store_true", default=False,
        help="run tests marked slow (multi-minute dry-run compiles)")
    parser.addoption(
        "--update-golden", action="store_true", default=False,
        help="rewrite the golden-trace artifacts under tests/golden/")
    if not _HAVE_REAL_HYPOTHESIS:
        # Real hypothesis registers this itself; the fallback accepts the
        # same flag so CI/local commands stay identical.
        parser.addoption(
            "--hypothesis-seed", action="store", default="0",
            help="base seed of the mini-hypothesis fallback examples")


def pytest_configure(config):
    if not _HAVE_REAL_HYPOTHESIS:
        try:
            _MINI_SEED[0] = int(config.getoption("--hypothesis-seed"))
        except (TypeError, ValueError):
            _MINI_SEED[0] = 0


def pytest_collection_modifyitems(config, items):
    run_slow = os.environ.get("RUN_SLOW", "").lower() not in ("", "0",
                                                              "false")
    if config.getoption("--runslow") or run_slow:
        return
    skip = pytest.mark.skip(
        reason="slow compile test (enable with --runslow or RUN_SLOW=1)")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip)
