"""Shared test configuration.

Two concerns live here:

* **Optional-dev-dep fallback** — the property-test modules do
  ``from hypothesis import given, settings, strategies as st`` at import
  time.  When ``hypothesis`` (a dev extra, see pyproject.toml) is not
  installed, that used to abort *collection* of four modules and with it
  the whole tier-1 run.  We install a stub module instead: every
  ``@given`` test body becomes a clean ``pytest.skip``, while the plain
  unit tests in the same modules still run.
* **``slow`` marker** — the dry-run suites compile reduced transformer
  programs on 512 forced host devices (minutes per fixture).  They are
  skipped by default and enabled with ``--runslow`` or ``RUN_SLOW=1`` so
  the default tier-1 command stays fast.
"""
from __future__ import annotations

import os
import sys
import types

import pytest


def _install_hypothesis_stub() -> None:
    try:
        import hypothesis  # noqa: F401
        return
    except ModuleNotFoundError:
        pass

    mod = types.ModuleType("hypothesis")
    mod.__stub__ = True  # marker for debugging / schema tests

    def given(*_args, **_kwargs):
        def deco(fn):
            def skipper(*args, **kwargs):
                pytest.skip("hypothesis not installed (pip install .[dev])")

            skipper.__name__ = getattr(fn, "__name__", "hypothesis_test")
            skipper.__doc__ = getattr(fn, "__doc__", None)
            return skipper

        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    class _Strategy:
        """Inert placeholder for strategy expressions (st.integers(...))."""

        def __init__(self, name: str):
            self._name = name

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, item):
            return _Strategy(f"{self._name}.{item}")

        def __repr__(self):
            return f"<hypothesis-stub strategy {self._name}>"

    st = types.ModuleType("hypothesis.strategies")
    st.__stub__ = True
    st.__getattr__ = lambda name: _Strategy(name)  # PEP 562
    mod.given = given
    mod.settings = settings
    mod.strategies = st
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st


_install_hypothesis_stub()


def pytest_addoption(parser):
    parser.addoption(
        "--runslow", action="store_true", default=False,
        help="run tests marked slow (multi-minute dry-run compiles)")


def pytest_collection_modifyitems(config, items):
    run_slow = os.environ.get("RUN_SLOW", "").lower() not in ("", "0",
                                                              "false")
    if config.getoption("--runslow") or run_slow:
        return
    skip = pytest.mark.skip(
        reason="slow compile test (enable with --runslow or RUN_SLOW=1)")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip)
