"""Distribution layer tests.

Sharding-spec rules are pure functions (tested in-process); mesh
execution needs >1 device, so those tests run a subprocess with forced
host devices (the parent pytest process has already locked jax to 1
device).
"""
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.models.api import abstract_params, build_model
from repro.sharding.specs import batch_specs, param_specs, pod_stacked_specs

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


class TestParamSpecs:
    def _specs(self, arch, mode):
        cfg = get_config(arch)
        shapes = abstract_params(build_model(cfg))
        mesh = FakeMesh({"data": 16, "model": 16})
        return shapes, param_specs(shapes, mesh, mode=mode)

    @pytest.mark.parametrize("arch", ["granite_3_2b", "mixtral_8x7b",
                                      "mamba2_2_7b", "zamba2_2_7b"])
    def test_fsdp_divisibility(self, arch):
        shapes, specs = self._specs(arch, "fsdp")
        for (path, shape), (_, spec) in zip(
                jax.tree_util.tree_flatten_with_path(shapes)[0],
                jax.tree_util.tree_flatten_with_path(
                    specs, is_leaf=lambda x: isinstance(x, P))[0], strict=True):
            for dim, axis in enumerate(spec):
                if axis is None:
                    continue
                assert shape.shape[dim] % 16 == 0, (path, shape.shape, spec)

    def test_fsdp_never_shards_layer_axis(self):
        shapes, specs = self._specs("granite_3_2b", "fsdp")
        flat_sh = jax.tree_util.tree_flatten_with_path(shapes)[0]
        flat_sp = jax.tree_util.tree_flatten_with_path(
            specs, is_leaf=lambda x: isinstance(x, P))[0]
        for (path, shape), (_, spec) in zip(flat_sh, flat_sp, strict=True):
            names = [str(getattr(p, "key", "")) for p in path]
            if "layers" in names and len(spec) > 0:
                assert spec[0] is None, (names, spec)

    def _moe_spec(self, arch, mode, leaf):
        shapes, specs = self._specs(arch, mode)
        for (path, shape), (_, spec) in zip(
                jax.tree_util.tree_flatten_with_path(shapes)[0],
                jax.tree_util.tree_flatten_with_path(
                    specs, is_leaf=lambda x: isinstance(x, P))[0], strict=True):
            names = [str(getattr(p, "key", "")) for p in path]
            if "moe" in names and names[-1] == leaf:
                return spec
        raise AssertionError("leaf not found")

    def test_tp_moe_output_dim_only(self):
        # (L, E, d, f) / (L, E, f, d): LAST dim over model (output-dim
        # sharding, no contraction partial-sums)
        assert self._moe_spec("qwen3_moe_235b_a22b", "tp",
                              "w_gate")[3] == "model"
        assert self._moe_spec("qwen3_moe_235b_a22b", "tp",
                              "w_down")[3] == "model"
        assert self._moe_spec("mixtral_8x7b", "tp", "w_gate")[3] == "model"

    def test_fsdp_tp_moe_zero_shards_expert_dim(self):
        assert self._moe_spec("qwen3_moe_235b_a22b", "fsdp_tp",
                              "w_gate")[1] == "data"  # E=128 divides 16
        assert self._moe_spec("mixtral_8x7b", "fsdp_tp",
                              "w_gate")[1] is None  # E=8 does not

    def test_ep_mode_shards_expert_axis_when_divisible(self):
        assert self._moe_spec("qwen3_moe_235b_a22b", "ep",
                              "w_gate")[1] == "model"
        # mixtral: 8 experts < 16 → falls back to intra-expert TP
        assert self._moe_spec("mixtral_8x7b", "ep", "w_gate")[3] == "model"

    def test_vocab_parallel_head_and_local_embed_gather(self):
        shapes, specs = self._specs("granite_3_2b", "fsdp")
        assert specs["lm_head"][1] == "model"  # padded vocab divides 16
        # embed sharded on d: the token gather stays device-local
        assert specs["embed"] == P(None, "model")

    def test_pod_stacking_prepends_axis(self):
        shapes, specs = self._specs("granite_3_2b", "fsdp")
        pod = pod_stacked_specs(specs)
        assert pod["lm_head"][0] == "pod"
        assert pod["lm_head"][2] == "model"

    def test_batch_specs(self):
        b = {"tokens": jax.ShapeDtypeStruct((256, 4096), jax.numpy.int32)}
        sp = batch_specs(b, batch_axes="data")
        assert sp["tokens"] == P("data", None)


_MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.core.controller import ControllerConfig
from repro.core.crosspod import (CrossPodConfig, init_cross_pod_state,
                                 make_cross_pod_round)
from repro.models.api import build_model
from repro.sharding.actshard import activation_sharding
from repro.sharding.specs import param_specs, pod_stacked_specs

mesh = jax.sharding.Mesh(
    np.asarray(jax.devices()[:8]).reshape(2, 2, 2), ("pod", "data", "model"))
cfg = get_config("granite-3-2b").reduced(num_layers=2, d_model=128,
                                         vocab_size=512, remat=False)
model = build_model(cfg)
cp = CrossPodConfig(n_pods=2, rho=1e-3, lr=5e-3, local_steps=2,
                    controller=ControllerConfig(K=0.05, alpha=0.9,
                                                target_rate=0.5))

def sharded_loss(params, batch):
    with activation_sharding(mesh, "data"):
        return model.loss(params, batch)

round_fn = make_cross_pod_round(cp, sharded_loss)
params0 = model.init(jax.random.PRNGKey(0))
state = init_cross_pod_state(cp, params0)
pspec = param_specs(jax.eval_shape(lambda: params0), mesh, mode="fsdp")
pod_pspec = pod_stacked_specs(pspec)
named = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                               is_leaf=lambda x: isinstance(x, P))
state_sh = type(state)(
    theta=named(pod_pspec), lam=named(pod_pspec), z_prev=named(pod_pspec),
    ctrl=jax.tree.map(lambda _: NamedSharding(mesh, P()), state.ctrl),
    rng=NamedSharding(mesh, P()), round=NamedSharding(mesh, P()))
bsh = NamedSharding(mesh, P("pod", None, "data", None))
step = jax.jit(round_fn,
               in_shardings=(state_sh, {"tokens": bsh, "labels": bsh}),
               out_shardings=(state_sh, None))
rng = np.random.default_rng(0)
state = jax.device_put(state, state_sh)
events = []
losses = []
for k in range(10):
    toks = rng.integers(0, 512, (2, 2, 8, 33))
    batch = {"tokens": jnp.asarray(toks[..., :-1], jnp.int32),
             "labels": jnp.asarray(toks[..., 1:], jnp.int32)}
    state, m = step(state, batch)
    events.append(np.asarray(m.events).astype(int).tolist())
    losses.append(float(m.train_loss))
# consensus sanity: omega implied by z_prev must be finite
zmean = float(jnp.mean(jnp.abs(jax.tree.leaves(state.z_prev)[0])))
print(json.dumps({"events": events, "losses": losses, "zmean": zmean,
                  "event_count": np.asarray(
                      jax.device_get(state.ctrl.event_count)).tolist()}))
"""


class TestCrossPodExecution:
    @pytest.fixture(scope="class")
    def result(self):
        env = dict(os.environ,
                   PYTHONPATH=os.path.join(REPO, "src"))
        out = subprocess.run(
            [sys.executable, "-c", _MESH_SCRIPT], env=env,
            capture_output=True, text=True, timeout=560, cwd=REPO)
        assert out.returncode == 0, out.stderr[-3000:]
        return json.loads(out.stdout.strip().splitlines()[-1])

    def test_round_zero_full_participation(self, result):
        assert result["events"][0] == [1, 1]

    def test_losses_finite_and_decreasing_when_active(self, result):
        active = [l for e, l in zip(result["events"], result["losses"], strict=True)
                  if sum(e)]
        assert all(np.isfinite(l) for l in active)

    def test_controller_throttles(self, result):
        # with target rate 0.5, not every round fires both pods
        total = sum(sum(e) for e in result["events"])
        assert total < 2 * len(result["events"])

    def test_state_finite(self, result):
        assert np.isfinite(result["zmean"])
