"""Data pipeline: synthetic sets, non-iid partitioners, determinism."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data import (
    federated_arrays,
    make_synthetic_cifar,
    make_synthetic_mnist,
)
from repro.data.partition import (
    label_histogram,
    partition_dirichlet,
    partition_label_shard,
)


class TestSynthetic:
    def test_mnist_shapes_and_ranges(self):
        ds = make_synthetic_mnist(n_train=2000, n_test=400)
        assert ds.x_train.shape == (2000, 784)
        assert ds.x_test.shape == (400, 784)
        assert ds.x_train.min() >= 0.0 and ds.x_train.max() <= 1.0
        assert set(np.unique(ds.y_train)) <= set(range(10))

    def test_cifar_shapes_and_ranges(self):
        ds = make_synthetic_cifar(n_train=1000, n_test=200)
        assert ds.x_train.shape == (1000, 3072)
        assert ds.x_train.min() >= -1.0 and ds.x_train.max() <= 1.0

    def test_deterministic(self):
        a = make_synthetic_mnist(n_train=500, n_test=100)
        b = make_synthetic_mnist(n_train=500, n_test=100)
        np.testing.assert_array_equal(a.x_train, b.x_train)
        np.testing.assert_array_equal(a.y_train, b.y_train)

    def test_all_classes_present(self):
        ds = make_synthetic_mnist(n_train=2000, n_test=400)
        assert len(np.unique(ds.y_train)) == 10


class TestLabelShard:
    def test_each_client_has_at_most_two_classes(self):
        ds = make_synthetic_mnist(n_train=4000, n_test=100)
        xs, ys = partition_label_shard(ds.x_train, ds.y_train, n_clients=20,
                                       classes_per_client=2, seed=0)
        hist = label_histogram(ys, 10)
        assert ((hist > 0).sum(axis=1) <= 2).all()

    def test_equal_shard_sizes(self):
        ds = make_synthetic_mnist(n_train=4000, n_test=100)
        xs, ys = partition_label_shard(ds.x_train, ds.y_train, n_clients=20)
        assert xs.shape[0] == 20 and xs.shape[1] == ys.shape[1]

    @settings(max_examples=10, deadline=None)
    @given(n_clients=st.sampled_from([5, 10, 20, 25]),
           cpc=st.sampled_from([1, 2, 4]))
    def test_property_class_restriction(self, n_clients, cpc):
        ds = make_synthetic_mnist(n_train=3000, n_test=100)
        xs, ys = partition_label_shard(
            ds.x_train, ds.y_train, n_clients=n_clients,
            classes_per_client=cpc, seed=1)
        hist = label_histogram(ys, 10)
        assert ((hist > 0).sum(axis=1) <= cpc).all()


class TestDirichlet:
    def test_nontrivial_heterogeneity(self):
        ds = make_synthetic_cifar(n_train=4000, n_test=100)
        xs, ys = partition_dirichlet(ds.x_train, ds.y_train, n_clients=20,
                                     beta=0.5, seed=0)
        hist = label_histogram(ys, 10).astype(float)
        p = hist / hist.sum(1, keepdims=True)
        # client label distributions differ strongly from the global one
        kl = (p * np.log((p + 1e-9) / 0.1)).sum(1)
        assert kl.mean() > 0.2

    def test_min_points_respected(self):
        ds = make_synthetic_cifar(n_train=4000, n_test=100)
        xs, ys = partition_dirichlet(ds.x_train, ds.y_train, n_clients=10,
                                     beta=0.5, seed=2, min_points=8)
        assert ys.shape[1] >= 8


class TestFederatedArrays:
    @pytest.mark.parametrize("scheme", ["label_shard", "dirichlet", "iid"])
    def test_schemes(self, scheme):
        ds = make_synthetic_mnist(n_train=2000, n_test=200)
        data, test = federated_arrays(ds, n_clients=10, scheme=scheme)
        assert data["x"].shape[0] == 10
        assert data["x"].shape[:2] == data["y"].shape
        assert test["x"].shape[0] == 200
