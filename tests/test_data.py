"""Data pipeline: synthetic sets, ragged non-iid partitioners,
conservation, determinism, and the pooled CSR layout."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data import (
    federated_arrays,
    federated_pooled,
    make_synthetic_cifar,
    make_synthetic_mnist,
    stack_trimmed,
)
from repro.data.partition import (
    label_histogram,
    partition_dirichlet,
    partition_label_shard,
)


class TestSynthetic:
    def test_mnist_shapes_and_ranges(self):
        ds = make_synthetic_mnist(n_train=2000, n_test=400)
        assert ds.x_train.shape == (2000, 784)
        assert ds.x_test.shape == (400, 784)
        assert ds.x_train.min() >= 0.0 and ds.x_train.max() <= 1.0
        assert set(np.unique(ds.y_train)) <= set(range(10))

    def test_cifar_shapes_and_ranges(self):
        ds = make_synthetic_cifar(n_train=1000, n_test=200)
        assert ds.x_train.shape == (1000, 3072)
        assert ds.x_train.min() >= -1.0 and ds.x_train.max() <= 1.0

    def test_deterministic(self):
        a = make_synthetic_mnist(n_train=500, n_test=100)
        b = make_synthetic_mnist(n_train=500, n_test=100)
        np.testing.assert_array_equal(a.x_train, b.x_train)
        np.testing.assert_array_equal(a.y_train, b.y_train)

    def test_all_classes_present(self):
        ds = make_synthetic_mnist(n_train=2000, n_test=400)
        assert len(np.unique(ds.y_train)) == 10


class TestLabelShard:
    def test_exactly_classes_per_client(self):
        ds = make_synthetic_mnist(n_train=4000, n_test=100)
        xs, ys, stats = partition_label_shard(
            ds.x_train, ds.y_train, n_clients=20, classes_per_client=2,
            seed=0)
        hist = label_histogram(ys, 10)
        # exactly 2 distinct labels per client (class-major deal: the
        # same class can never land twice on one client)
        assert ((hist > 0).sum(axis=1) == 2).all()
        np.testing.assert_array_equal(hist, stats.label_histogram)

    def test_conservation_and_stats(self):
        ds = make_synthetic_mnist(n_train=4000, n_test=100)
        xs, ys, stats = partition_label_shard(ds.x_train, ds.y_train,
                                              n_clients=20)
        assert stats.dropped == 0
        assert stats.total == 4000
        assert sum(len(y) for y in ys) == 4000
        assert [len(x) for x in xs] == list(stats.sizes)

    def test_deterministic_under_seed(self):
        ds = make_synthetic_mnist(n_train=3000, n_test=100)
        a = partition_label_shard(ds.x_train, ds.y_train, n_clients=10,
                                  seed=3)
        b = partition_label_shard(ds.x_train, ds.y_train, n_clients=10,
                                  seed=3)
        c = partition_label_shard(ds.x_train, ds.y_train, n_clients=10,
                                  seed=4)
        for sa, sb in zip(a[1], b[1], strict=True):
            np.testing.assert_array_equal(sa, sb)
        assert any(not np.array_equal(sa, sc)
                   for sa, sc in zip(a[1], c[1], strict=True))

    def test_infeasible_configs_raise(self):
        ds = make_synthetic_mnist(n_train=1000, n_test=100)
        with pytest.raises(ValueError):  # 5 shards cannot cover 10 classes
            partition_label_shard(ds.x_train, ds.y_train, n_clients=5,
                                  classes_per_client=1)
        with pytest.raises(ValueError):
            partition_label_shard(ds.x_train, ds.y_train, n_clients=5,
                                  classes_per_client=11)

    @settings(max_examples=10, deadline=None)
    @given(n_clients=st.sampled_from([5, 10, 20, 25]),
           cpc=st.sampled_from([2, 4]))
    def test_property_class_restriction_and_conservation(self, n_clients,
                                                         cpc):
        ds = make_synthetic_mnist(n_train=3000, n_test=100)
        xs, ys, stats = partition_label_shard(
            ds.x_train, ds.y_train, n_clients=n_clients,
            classes_per_client=cpc, seed=1)
        hist = label_histogram(ys, 10)
        assert ((hist > 0).sum(axis=1) <= cpc).all()
        assert stats.dropped == 0 and stats.total == 3000


class TestDirichlet:
    def test_nontrivial_heterogeneity(self):
        ds = make_synthetic_cifar(n_train=4000, n_test=100)
        xs, ys, stats = partition_dirichlet(ds.x_train, ds.y_train,
                                            n_clients=20, beta=0.5, seed=0)
        hist = label_histogram(ys, 10).astype(float)
        p = hist / hist.sum(1, keepdims=True)
        # client label distributions differ strongly from the global one
        kl = (p * np.log((p + 1e-9) / 0.1)).sum(1)
        assert kl.mean() > 0.2

    def test_min_points_respected(self):
        ds = make_synthetic_cifar(n_train=4000, n_test=100)
        xs, ys, stats = partition_dirichlet(ds.x_train, ds.y_train,
                                            n_clients=10, beta=0.5, seed=2,
                                            min_points=8)
        assert stats.sizes.min() >= 8

    def test_proportions_match_beta_in_expectation(self):
        """Dirichlet(β) component moments: E[p_i] = 1/N and
        Var[p_i] = (1/N)(1−1/N)/(Nβ+1) — the empirical per-class client
        proportions must match both within loose statistical bounds,
        and a small β must be visibly more dispersed than a large one.
        """
        ds = make_synthetic_cifar(n_train=6000, n_test=100)
        n = 10

        def dispersion(beta, seed):
            _, ys, stats = partition_dirichlet(
                ds.x_train, ds.y_train, n_clients=n, beta=beta, seed=seed,
                min_points=1)
            hist = stats.label_histogram.astype(float)
            p = hist / np.maximum(hist.sum(axis=0, keepdims=True), 1)
            # mean over classes of the across-client variance of p
            return float(p.var(axis=0).mean()), float(p.mean())

        var_lo, mean_lo = dispersion(0.2, seed=0)
        var_hi, mean_hi = dispersion(50.0, seed=0)
        for m in (mean_lo, mean_hi):  # E[p] = 1/N regardless of β
            assert abs(m - 1.0 / n) < 1e-6
        theory = lambda b: (1 / n) * (1 - 1 / n) / (n * b + 1)  # noqa: E731
        assert var_lo > var_hi * 5  # smaller β ⇒ more heterogeneity
        # loose factor-of-3 agreement with the theoretical variance
        assert theory(0.2) / 3 < var_lo < theory(0.2) * 3
        assert var_hi < theory(50.0) * 3

    def test_deterministic_under_seed(self):
        ds = make_synthetic_cifar(n_train=2000, n_test=100)
        a = partition_dirichlet(ds.x_train, ds.y_train, n_clients=8, seed=7)
        b = partition_dirichlet(ds.x_train, ds.y_train, n_clients=8, seed=7)
        for sa, sb in zip(a[1], b[1], strict=True):
            np.testing.assert_array_equal(sa, sb)

    @settings(max_examples=8, deadline=None)
    @given(n_clients=st.sampled_from([4, 8, 10, 16]),
           beta=st.sampled_from([0.1, 0.5, 2.0]))
    def test_property_conservation(self, n_clients, beta):
        """Σnᵢ equals the dataset size — no partition ever drops data."""
        ds = make_synthetic_cifar(n_train=2000, n_test=100)
        xs, ys, stats = partition_dirichlet(
            ds.x_train, ds.y_train, n_clients=n_clients, beta=beta,
            seed=11, min_points=1)
        assert stats.dropped == 0
        assert stats.total == 2000
        assert sum(len(y) for y in ys) == 2000


class TestStackTrimmed:
    def test_trim_accounting(self):
        ds = make_synthetic_cifar(n_train=2000, n_test=100)
        xs, ys, stats = partition_dirichlet(ds.x_train, ds.y_train,
                                            n_clients=10, beta=0.5, seed=0)
        sx, sy, dropped = stack_trimmed(xs, ys)
        n_min = stats.sizes.min()
        assert sx.shape[:2] == (10, n_min) and sy.shape == (10, n_min)
        assert dropped == 2000 - 10 * n_min  # loss is explicit, not silent


class TestFederatedArrays:
    @pytest.mark.parametrize("scheme", ["label_shard", "dirichlet", "iid"])
    def test_schemes(self, scheme):
        ds = make_synthetic_mnist(n_train=2000, n_test=200)
        data, test = federated_arrays(ds, n_clients=10, scheme=scheme)
        assert data["x"].shape[0] == 10
        assert data["x"].shape[:2] == data["y"].shape
        assert test["x"].shape[0] == 200


class TestFederatedPooled:
    @pytest.mark.parametrize("scheme", ["label_shard", "dirichlet", "iid"])
    def test_lossless_pooling(self, scheme):
        ds = make_synthetic_mnist(n_train=2000, n_test=200)
        data, test, spec, stats = federated_pooled(
            ds, n_clients=10, scheme=scheme)
        assert spec.total == 2000 and stats.dropped == 0
        assert data["x"].shape[0] == spec.buffer_rows
        assert data["y"].shape[0] == spec.buffer_rows
        # CSR slices reassemble each client's shard exactly
        x = np.asarray(data["x"])
        for i in range(10):
            assert spec.client_slice(i).stop - spec.client_slice(i).start \
                == stats.sizes[i]
        assert x[: spec.total].shape[0] == sum(stats.sizes)

    def test_dirichlet_is_heterogeneous(self):
        ds = make_synthetic_mnist(n_train=2000, n_test=200)
        _, _, spec, stats = federated_pooled(ds, n_clients=10,
                                             scheme="dirichlet", beta=0.3)
        assert not spec.uniform  # ragged sizes survive the pipeline
        assert stats.sizes.max() > stats.sizes.min()
