"""Mutation matrix: tracecheck must prove itself by catching seeded
violations with exactly the intended rule.

Each test plants one regression the analyzer exists to prevent — a
stray full-width sweep, a host transfer staged inside the round, a
dropped donation, an f64 leak, a forced retrace, a replicated-state
all-gather — and asserts the rule engine turns *that* rule red while
every other rule stays green.  ``body_transform`` (threaded through
``make_round_fn``) is the seeding hook: it wraps the round body after
construction, so the engine code itself stays untouched.

The cheap mutations trace a jaxpr only and run in tier-1; the
two-device replication mutation and the CLI end-to-end check compile
under a forced multi-device env and are ``--runslow``.
"""
import json
import os
import pathlib
import subprocess
import sys
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.artifacts import (
    DEFAULT_DIM,
    DEFAULT_N,
    FAST_MATRIX,
    ConfigKey,
    build_artifact,
)
from repro.analysis.retrace import run_single_trace_check
from repro.analysis.rules import DtypeBan, evaluate

REPO = pathlib.Path(__file__).resolve().parents[1]
DENSE_FLAT = ConfigKey("dense", "flat", "sync", "uniform", 1)
COMPACT_FLAT = ConfigKey("compact", "flat", "sync", "uniform", 1)
HOST_COMPACT = ConfigKey("compact", "flat", "sync", "uniform", 1,
                         "none", "host")


def failing_rules(art):
    return sorted(r.rule for r in evaluate(art) if r.status == "fail")


@pytest.fixture(scope="module")
def compiled_art():
    return build_artifact(DENSE_FLAT)


class TestBaselineGreen:
    def test_unmutated_round_passes_every_rule(self, compiled_art):
        for r in evaluate(compiled_art):
            assert r.status != "fail", (r.rule, r.violations)


class TestSeededMutations:
    def test_stray_full_width_subtraction(self):
        # A no-op (N, D) subtraction on θ before the round — one extra
        # top-level sweep over the sweep budget, and nothing else.
        def extra_sweep(body):
            def wrapped(state, *args, **kw):
                state = state._replace(
                    theta=state.theta - jnp.float32(0.0))
                return body(state, *args, **kw)
            return wrapped

        art = build_artifact(DENSE_FLAT, compile=False,
                             body_transform=extra_sweep)
        assert failing_rules(art) == ["no-full-width-sweeps"]

    def test_host_transfer_staged_in_round(self):
        # jax.device_put of a host scalar inside the traced body — the
        # classic "constant built per round instead of at build time".
        def host_staging(body):
            def wrapped(state, *args, **kw):
                state = state._replace(
                    round=state.round + jax.device_put(np.int32(0)))
                return body(state, *args, **kw)
            return wrapped

        art = build_artifact(DENSE_FLAT, compile=False,
                             body_transform=host_staging)
        assert failing_rules(art) == ["host-transfer-budget"]

    def test_stray_full_width_transfer_on_host_leg(self):
        # Host-backend leg: stage a full (N, D) device_put inside the
        # streamed solve program — the exact transfer the budget
        # exists to ban (the planned row stream is (C, D) tiles only,
        # never the whole client-state matrix).
        def full_width_leak(solve):
            def wrapped(omega, idx, keys_rows, th_tiles, lam_tiles):
                stray = jax.device_put(
                    np.zeros((DEFAULT_N, DEFAULT_DIM), np.float32))
                return solve(omega + 0.0 * stray[0], idx, keys_rows,
                             th_tiles, lam_tiles)
            return wrapped

        art = build_artifact(HOST_COMPACT, compile=False,
                             body_transform=full_width_leak)
        assert failing_rules(art) == ["host-transfer-budget"]

    def test_unmutated_host_round_green(self):
        # The host leg itself must trace green — its planned row
        # stream (5·C·D·4 B) fits the 8·C·D·4 B budget — or the
        # mutation above proves nothing.
        art = build_artifact(HOST_COMPACT, compile=False)
        assert failing_rules(art) == []

    def test_dropped_admm_kernel(self):
        # Unfusing the ADMM kernel is one mutation, two coupled
        # symptoms: the Pallas-call count drops AND the unfused algebra
        # reintroduces full-width sweeps.  Both rules must fire.
        art = build_artifact(DENSE_FLAT, compile=False,
                             cfg_overrides={"use_admm_kernel": False})
        assert failing_rules(art) == ["fused-admm-pass",
                                      "no-full-width-sweeps"]

    def test_unfused_compact_commit(self):
        # Un-fusing the compacted commit (fused_gss=False) silently
        # reverts to the three-pass gather/z-assembly/scatter dataflow —
        # numerically identical, so only the fused-admm-pass budget can
        # catch it: the compact policy expects exactly one fused-commit
        # pallas_call and zero separate admm passes.
        art = build_artifact(COMPACT_FLAT, compile=False,
                             cfg_overrides={"fused_gss": False})
        assert failing_rules(art) == ["fused-admm-pass"]

    def test_unmutated_fused_compact_round_green(self):
        # The policy default (compact-flat ⇒ fused commit) itself must
        # trace green, or the mutation above proves nothing.
        art = build_artifact(COMPACT_FLAT, compile=False)
        assert failing_rules(art) == []

    def test_f64_leak(self):
        with jax.experimental.enable_x64():
            j64 = jax.make_jaxpr(lambda x: x * 2.0)(
                jnp.ones((4,), jnp.float64))
        fake = types.SimpleNamespace(
            key=types.SimpleNamespace(name="f64-mutant"),
            jaxpr=j64, compiled_text=None)
        res = DtypeBan().check(fake)
        assert res.status == "fail"
        assert "float64" in res.violations[0]

    def test_dropped_donation(self):
        art = build_artifact(DENSE_FLAT, donate=False)
        assert failing_rules(art) == ["donated-state-aliases"]
        res = {r.rule: r for r in evaluate(art)}["donated-state-aliases"]
        assert res.metrics["aliased_params"] == 0


class TestRetraceSentry:
    def test_value_overrides_do_not_retrace(self):
        res = run_single_trace_check()
        assert res.status == "pass", res.violations
        assert res.metrics["traces"] == 1

    def test_shape_mutation_forces_retrace(self):
        res = run_single_trace_check(shape_mutation=True)
        assert res.status == "fail"
        assert res.metrics["traces"] > 1

    def test_serve_arrival_masks_do_not_retrace(self):
        from repro.analysis.retrace import run_serve_trace_check
        res = run_serve_trace_check()
        assert res.status == "pass", res.violations
        assert res.metrics["traces"] == 1

    def test_serve_aval_mutation_forces_retrace(self):
        from repro.analysis.retrace import run_serve_trace_check
        res = run_serve_trace_check(shape_mutation=True)
        assert res.status == "fail"
        assert res.metrics["traces"] > 1


_REPLICATE_SCRIPT = """
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=2")
os.environ["JAX_PLATFORMS"] = "cpu"
import jax
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.analysis.artifacts import ConfigKey, build_artifact
from repro.analysis.rules import evaluate
from repro.sharding.clients import make_client_mesh

mesh = make_client_mesh(2)

def replicate_state(body):
    def wrapped(state, *args, **kw):
        state = state._replace(z_prev=jax.lax.with_sharding_constraint(
            state.z_prev, NamedSharding(mesh, P())))
        return body(state, *args, **kw)
    return wrapped

art = build_artifact(ConfigKey("dense", "flat", "sync", "uniform", 2),
                     body_transform=replicate_state)
failing = sorted(r.rule for r in evaluate(art) if r.status == "fail")
print("FAILING=" + ",".join(failing))
"""


def _run(cmd, **kw):
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"),
               JAX_PLATFORMS="cpu")
    return subprocess.run(cmd, cwd=REPO, env=env, capture_output=True,
                          text=True, timeout=900, **kw)


@pytest.mark.slow
class TestMultiDeviceMutations:
    def test_replicated_state_trips_allgather_cap(self):
        # Replicate-instead-of-shard: pinning z_prev to P() makes SPMD
        # all-gather the (N, D) state every round — only the collective
        # budget may fire.
        proc = _run([sys.executable, "-c", _REPLICATE_SCRIPT])
        assert proc.returncode == 0, proc.stderr[-2000:]
        assert "FAILING=collective-budget" in proc.stdout


@pytest.mark.slow
class TestCliEndToEnd:
    def test_fast_matrix_gates_clean_against_baseline(self, tmp_path):
        out = tmp_path / "report.json"
        proc = _run([
            sys.executable, "-m", "repro.analysis", "--matrix", "fast",
            "--json", str(out),
            "--baseline", "benchmarks/baselines/ANALYSIS.json"])
        assert proc.returncode == 0, (proc.stdout[-2000:],
                                      proc.stderr[-2000:])
        report = json.loads(out.read_text())
        assert report["lint"]["status"] == "pass"
        assert len(report["configs"]) == len(FAST_MATRIX)
