"""Deep unit tests for model components (beyond the per-arch smoke)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.models.attention import blockwise_attention
from repro.models.layers import (
    apply_rope,
    chunked_lm_loss,
    cross_entropy_logits,
    rmsnorm,
)
from repro.models.moe import moe_apply, moe_init, moe_ref
from repro.models.ssm import ssd_chunked, ssm_cache_init, ssm_decode_step, \
    ssm_forward, ssm_init


def _naive_attn(q, k, v, mode="causal", window=0, prefix_len=0):
    B, S, H, hd = q.shape
    Kv = k.shape[2]
    qg = q.reshape(B, S, Kv, H // Kv, hd)
    s = jnp.einsum("bskgh,btkh->bskgt", qg, k) / hd ** 0.5
    qa = jnp.arange(S)[:, None]
    ka = jnp.arange(S)[None, :]
    ok = {"causal": ka <= qa, "bidir": jnp.ones((S, S), bool),
          "prefix": (ka <= qa) | (ka < prefix_len)}[mode]
    if window:
        ok = ok & (ka > qa - window)
    s = jnp.where(ok[None, :, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, -1)
    return jnp.einsum("bskgt,btkh->bskgh", w, v).reshape(B, S, H, hd)


class TestBlockwiseAttention:
    @settings(max_examples=12, deadline=None)
    @given(s=st.integers(4, 70), kvb=st.integers(3, 32),
           mode=st.sampled_from(["causal", "bidir", "prefix"]),
           window=st.sampled_from([0, 5]))
    def test_property_matches_naive(self, s, kvb, mode, window):
        rng = np.random.default_rng(s * 100 + kvb)
        q = jnp.asarray(rng.normal(size=(1, s, 4, 8)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(1, s, 2, 8)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(1, s, 2, 8)), jnp.float32)
        pos = jnp.arange(s)
        got = blockwise_attention(q, k, v, q_positions=pos, kv_positions=pos,
                                  mask_mode=mode, window=window,
                                  prefix_len=3, kv_block=kvb)
        want = _naive_attn(q, k, v, mode, window, 3)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    def test_unroll_matches_rolled(self):
        rng = np.random.default_rng(0)
        q = jnp.asarray(rng.normal(size=(2, 32, 4, 8)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(2, 32, 2, 8)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(2, 32, 2, 8)), jnp.float32)
        pos = jnp.arange(32)
        a = blockwise_attention(q, k, v, q_positions=pos, kv_positions=pos,
                                kv_block=8, unroll=False)
        b = blockwise_attention(q, k, v, q_positions=pos, kv_positions=pos,
                                kv_block=8, unroll=True)
        # rolled scan vs unrolled python loop fuse differently on XLA-CPU;
        # allow fp32 reassociation noise (observed 2e-6 relative on 1/2048
        # elements the first time this module actually ran in CI).
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                                   atol=1e-6)


class TestMoE:
    def test_capacity_drops_bounded(self):
        """With cf=1.0 the dropped fraction is bounded and out stays
        finite even under adversarial (all-same-expert) routing."""
        p = moe_init(jax.random.PRNGKey(0), 8, 16, 4, jnp.float32)
        # force every token to expert 0: positive inputs × rigged router
        p = dict(p)
        p["router"] = jnp.zeros_like(p["router"]).at[:, 0].set(10.0)
        x = jnp.abs(jax.random.normal(jax.random.PRNGKey(1), (2, 16, 8))) \
            + 0.1
        out, aux = moe_apply(p, x, top_k=2, capacity_factor=1.0)
        assert bool(jnp.isfinite(out).all())
        # aux loss must flag the imbalance (≫ 1 = balanced value)
        assert float(aux) > 1.5

    @settings(max_examples=10, deadline=None)
    @given(e=st.sampled_from([2, 4, 8]), k=st.integers(1, 2),
           s=st.integers(2, 24), seed=st.integers(0, 50))
    def test_property_no_drop_matches_dense(self, e, k, s, seed):
        p = moe_init(jax.random.PRNGKey(seed), 8, 16, e, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, s, 8))
        out, _ = moe_apply(p, x, top_k=k, capacity_factor=float(e))
        ref = moe_ref(p, x, top_k=k)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)

    def test_grad_flows_through_router(self):
        p = moe_init(jax.random.PRNGKey(0), 8, 16, 4, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 8))

        def loss(p):
            out, aux = moe_apply(p, x, top_k=2, capacity_factor=4.0)
            return jnp.sum(out ** 2) + 0.01 * aux

        g = jax.grad(loss)(p)
        assert float(jnp.abs(g["router"]).sum()) > 0


class TestSSM:
    def test_decode_matches_forward_token_by_token(self):
        """Sequential decode must replay the chunked forward exactly."""
        d, E, N, P, K = 16, 2, 8, 8, 4
        p = ssm_init(jax.random.PRNGKey(0), d, expand=E, ssm_state=N,
                     head_dim=P, conv_kernel=K, dtype=jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, d)) * 0.5
        full = ssm_forward(p, x, expand=E, ssm_state=N, head_dim=P,
                           conv_kernel=K, chunk=4)
        cache = ssm_cache_init(2, d, expand=E, ssm_state=N, head_dim=P,
                               conv_kernel=K, dtype=jnp.float32)
        outs = []
        for t in range(12):
            y, cache = ssm_decode_step(p, x[:, t:t + 1], cache, expand=E,
                                       ssm_state=N, head_dim=P,
                                       conv_kernel=K)
            outs.append(y)
        step = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(step), np.asarray(full),
                                   rtol=2e-3, atol=2e-3)

    @settings(max_examples=8, deadline=None)
    @given(s=st.integers(3, 40), q=st.sampled_from([2, 4, 8]),
           seed=st.integers(0, 20))
    def test_property_chunked_equals_sequential(self, s, q, seed):
        rng = np.random.default_rng(seed)
        B, H, P, N = 1, 2, 4, 4
        x = jnp.asarray(rng.normal(size=(B, s, H, P)), jnp.float32)
        dt = jnp.asarray(rng.uniform(0.01, 0.4, (B, s, H)), jnp.float32)
        a_log = jnp.asarray(rng.uniform(-1, 1, (H,)), jnp.float32)
        bm = jnp.asarray(rng.normal(size=(B, s, N)), jnp.float32)
        cm = jnp.asarray(rng.normal(size=(B, s, N)), jnp.float32)
        y, h = ssd_chunked(x, dt, a_log, bm, cm, chunk=q)
        # sequential oracle
        a = -jnp.exp(a_log)
        hs = jnp.zeros((B, H, P, N))
        ys = []
        for t in range(s):
            at = jnp.exp(a * dt[:, t])
            upd = (dt[:, t][..., None] * x[:, t])[..., None] * \
                bm[:, t][:, None, None, :]
            hs = hs * at[..., None, None] + upd
            ys.append(jnp.einsum("bhpn,bn->bhp", hs, cm[:, t]))
        np.testing.assert_allclose(np.asarray(y),
                                   np.asarray(jnp.stack(ys, 1)),
                                   rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(np.asarray(h), np.asarray(hs),
                                   rtol=1e-3, atol=1e-3)


class TestLayers:
    def test_chunked_loss_matches_full(self):
        rng = np.random.default_rng(0)
        h = jnp.asarray(rng.normal(size=(2, 16, 8)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(8, 32)), jnp.float32)
        y = jnp.asarray(rng.integers(0, 30, (2, 16)), jnp.int32)
        full = chunked_lm_loss(h, w, y, 0, valid_vocab=30)
        chunked = chunked_lm_loss(h, w, y, 4, valid_vocab=30)
        np.testing.assert_allclose(float(full), float(chunked), rtol=1e-5)

    def test_vocab_padding_masked(self):
        """Padded vocab columns must not change the loss."""
        rng = np.random.default_rng(1)
        h = jnp.asarray(rng.normal(size=(1, 8, 8)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(8, 20)), jnp.float32)
        wp = jnp.concatenate([w, jnp.full((8, 12), 50.0)], axis=1)
        y = jnp.asarray(rng.integers(0, 20, (1, 8)), jnp.int32)
        a = cross_entropy_logits(h @ w, y)
        b = cross_entropy_logits(h @ wp, y, valid_vocab=20)
        np.testing.assert_allclose(float(a), float(b), rtol=1e-5)

    def test_rope_preserves_norm_and_relative_phase(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (1, 6, 2, 8))
        pos = jnp.arange(6)[None]
        y = apply_rope(x, pos)
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(x), axis=-1),
            np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-5)
        # shift equivariance: <rope(q,i), rope(k,j)> depends on i-j only
        q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 8))
        k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, 8))
        def dot(i, j):
            qi = apply_rope(q, jnp.asarray([[i]]))
            kj = apply_rope(k, jnp.asarray([[j]]))
            return float(jnp.vdot(qi, kj))
        np.testing.assert_allclose(dot(3, 5), dot(10, 12), rtol=1e-4)

    def test_rmsnorm_scale_invariant_direction(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (2, 4, 8))
        g = jnp.ones((8,))
        a = rmsnorm(x, g)
        b = rmsnorm(3.0 * x, g)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                                   atol=1e-5)
