"""Dry-run machinery test: lower+compile a REDUCED config on the real
production meshes (512 forced host devices) in a subprocess, and check
the record schema + roofline terms.  This exercises the same code path
as the full 10×4×2 sweep at CI cost."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SCRIPT = r"""
import json
from repro.launch import dryrun
from repro.configs import get_config

cfg = get_config("granite-3-2b").reduced(
    num_layers=2, d_model=512, num_heads=8, num_kv_heads=4, head_dim=64,
    d_ff=1024, vocab_size=4096, kv_block=512, remat=True, dtype="bfloat16")
recs = []
for shape, mp in (("train_4k", False), ("train_4k", True),
                  ("decode_32k", False)):
    recs.append(dryrun.dry_run("granite-3-2b", shape, multi_pod=mp,
                               cost_correction=False, cfg=cfg))
print("\nRESULT:" + json.dumps(recs))
"""


@pytest.fixture(scope="module")
def records():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=560,
                         cwd=REPO)
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT:")]
    return json.loads(line[-1][len("RESULT:"):])


@pytest.mark.slow
class TestDryRunMachinery:
    def test_single_pod_train_compiles(self, records):
        r = records[0]
        assert r["status"] == "ok"
        assert r["n_chips"] == 256
        assert r["roofline"]["hlo_flops_per_device"] > 0

    def test_multi_pod_train_compiles_with_pod_axis(self, records):
        r = records[1]
        assert r["status"] == "ok"
        assert r["n_chips"] == 512
        assert r["mesh"] == "2x16x16"

    def test_decode_compiles_and_is_not_compute_bound(self, records):
        r = records[2]
        assert r["status"] == "ok"
        t = r["roofline"]
        assert t["dominant"] in ("memory", "collective")

    def test_roofline_terms_positive_and_schema(self, records):
        for r in records:
            t = r["roofline"]
            for k in ("compute_s", "memory_s", "collective_s"):
                assert t[k] >= 0
            assert "collectives" in t
            assert "memory_analysis" in r
            assert "analytic_hbm_bytes" in r


class TestCostAnalysisSchema:
    """Regression for the jax ≥0.4.35 cost_analysis() API drift: the
    result changed from a list-of-dicts to a dict, and ``dict(...)`` on
    the old shape raised ValueError, erroring all dry-run records."""

    def test_normalizes_both_shapes(self):
        from repro.utils.hlo import cost_analysis_dict
        props = {"flops": 1.0, "bytes accessed": 2.0}
        assert cost_analysis_dict(props) == props          # jax >= 0.4.35
        assert cost_analysis_dict([props]) == props        # jax < 0.4.35
        assert cost_analysis_dict(None) == {}
        assert cost_analysis_dict([]) == {}
        assert cost_analysis_dict([None, props]) == props

    def test_real_compiled_module(self):
        import jax
        import jax.numpy as jnp
        from repro.utils.hlo import cost_analysis_dict
        compiled = jax.jit(lambda x: x @ x).lower(
            jnp.ones((8, 8), jnp.float32)).compile()
        ca = cost_analysis_dict(compiled.cost_analysis())
        assert isinstance(ca, dict) and ca, "empty cost analysis"
        assert float(ca.get("flops", 0.0)) >= 0.0


class TestSkipRules:
    def test_skip_rules_via_dry_run(self):
        from repro.launch.dryrun import build_step  # noqa: F401 — light import check
        from repro.configs import get_config, shape_applicable
        ok, reason = shape_applicable(get_config("hubert-xlarge"),
                                      "decode_32k")
        assert not ok and "encoder-only" in reason
        ok, reason = shape_applicable(get_config("deepseek-67b"),
                                      "long_500k")
        assert not ok and "sub-quadratic" in reason
        ok, _ = shape_applicable(get_config("mixtral-8x7b"), "long_500k")
        assert ok
