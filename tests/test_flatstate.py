"""Flat client-state codec: pytree ⇄ (N, D) fp32 roundtrips, loss
adaption, and engine equivalence of the flat layout."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ControllerConfig, FLConfig, init_state, \
    make_eval_fn, make_round_fn
from repro.data import make_least_squares
from repro.models.mlp import init_mlp, make_loss_fn, mlp_logits
from repro.utils.flatstate import (
    flat_loss_fn,
    flatten_problem,
    make_flat_spec,
)


class TestCodec:
    def test_roundtrip_mixed_shapes_and_dtypes(self):
        tree = {
            "w": jnp.asarray(np.arange(12).reshape(3, 4), jnp.float32),
            "b": jnp.asarray([1.5, -2.0], jnp.bfloat16),
            "scale": jnp.asarray(3.0, jnp.float32),
        }
        spec = make_flat_spec(tree)
        assert spec.dim == 12 + 2 + 1
        vec = spec.flatten(tree)
        assert vec.shape == (spec.dim,) and vec.dtype == jnp.float32
        back = spec.unflatten(vec)
        assert jax.tree.structure(back) == jax.tree.structure(tree)
        for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(tree), strict=True):
            assert a.shape == b.shape and a.dtype == b.dtype
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32))

    def test_stacked_roundtrip(self):
        params = init_mlp(jax.random.PRNGKey(0), 24, 16, 4)
        spec = make_flat_spec(params)
        n = 5
        stacked = jax.tree.map(
            lambda x: x[None] + jnp.arange(n, dtype=x.dtype).reshape(
                (n,) + (1,) * x.ndim), params)
        mat = spec.flatten_stacked(stacked)
        assert mat.shape == (n, spec.dim)
        back = spec.unflatten_stacked(mat)
        for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(stacked), strict=True):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b))

    def test_spec_is_hashable_static(self):
        params = init_mlp(jax.random.PRNGKey(0), 8, 8, 2)
        s1, s2 = make_flat_spec(params), make_flat_spec(params)
        assert s1 == s2 and hash(s1) == hash(s2)

    def test_row_major_offsets(self):
        tree = {"a": jnp.ones((2, 3)), "b": jnp.zeros((4,))}
        spec = make_flat_spec(tree)
        leaves, _ = jax.tree.flatten(tree)
        sizes = [x.size for x in leaves]
        assert list(spec.offsets) == [0, sizes[0]]
        assert spec.dim == sum(sizes)


class TestFlatLoss:
    def test_loss_and_grad_match_pytree_path(self):
        params = init_mlp(jax.random.PRNGKey(1), 24, 16, 4)
        loss = make_loss_fn(mlp_logits)
        spec, vec0, floss = flatten_problem(params, loss)
        x = jnp.asarray(np.random.default_rng(0).normal(size=(6, 24)),
                        jnp.float32)
        y = jnp.asarray([0, 1, 2, 3, 0, 1], jnp.int32)
        np.testing.assert_allclose(float(floss(vec0, x, y)),
                                   float(loss(params, x, y)), rtol=1e-6)
        g_flat = jax.grad(floss)(vec0, x, y)
        g_tree = jax.grad(loss)(params, x, y)
        np.testing.assert_allclose(np.asarray(g_flat),
                                   np.asarray(spec.flatten(g_tree)),
                                   rtol=1e-5, atol=1e-6)

    def test_flat_loss_same_fn_as_spec_unflatten(self):
        params = {"theta": jnp.arange(4, dtype=jnp.float32)}
        spec = make_flat_spec(params)
        floss = flat_loss_fn(spec, lambda p, x, y: jnp.sum(p["theta"] * x))
        out = floss(spec.flatten(params), jnp.ones((4,)), None)
        assert float(out) == pytest.approx(6.0)


class TestFlatEngineEquivalence:
    def test_flat_round_matches_tree_round(self):
        n = 6
        data, params0, ls = make_least_squares(n, 8, 5)
        cfg = FLConfig(algorithm="fedback", n_clients=n, participation=0.5,
                       rho=1.0, lr=0.1, momentum=0.0, epochs=2, batch_size=4,
                       controller=ControllerConfig(K=0.2, alpha=0.9))
        spec = make_flat_spec(params0)

        def run(spec_arg):
            state = init_state(cfg, params0, spec=spec_arg)
            round_fn = make_round_fn(cfg, ls, data, spec=spec_arg)
            events = []
            for _ in range(10):
                state, m = round_fn(state)
                events.append(np.asarray(m.events).astype(int).tolist())
            return state, events

        st_tree, ev_tree = run(None)
        st_flat, ev_flat = run(spec)
        assert ev_tree == ev_flat  # bit-identical event decisions
        assert st_flat.theta.shape == (n, spec.dim)
        assert st_flat.omega.shape == (spec.dim,)
        np.testing.assert_allclose(
            np.asarray(st_flat.omega),
            np.asarray(spec.flatten(st_tree.omega)), rtol=1e-6, atol=1e-7)

    def test_eval_fn_unflattens_flat_omega(self):
        n = 4
        data, params0, ls = make_least_squares(n, 8, 5)
        cfg = FLConfig(n_clients=n, participation=1.0, rho=1.0, lr=0.1,
                       momentum=0.0, epochs=1, batch_size=8)
        spec = make_flat_spec(params0)
        state = init_state(cfg, params0, spec=spec)
        eval_fn = make_eval_fn(
            lambda p, x, y: (ls(p, x, y), jnp.zeros(())), spec=spec)
        loss, _ = eval_fn(state, data["x"][0], data["y"][0])
        ref = ls(params0, data["x"][0], data["y"][0])
        np.testing.assert_allclose(float(loss), float(ref), rtol=1e-6)
