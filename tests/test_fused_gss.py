"""Fused gather→ADMM→scatter commit: parity with the three-pass path.

* kernel level: interpret-mode ``fused_gss`` is bit-identical to the
  jnp ``fused_gss_ref`` oracle — both ``with_z`` forms, lane-padded D,
  masked (invalid) lanes, and untouched rows preserved through the
  aliased outputs; the recomputed λ⁺ matches the ``admm_update`` Pallas
  kernel bit for bit (same ``_kernel2``/``_kernel3`` op order);
* round level: the fused compacted engine (``cfg.fused_gss``)
  reproduces the reference gather/z-assembly/scatter engine
  bit-identically — events AND fp32 ω/θ/λ/z_prev — across
  {sync, async} × {uniform, ragged} and, in a forced-2-device
  subprocess, under the client mesh;
* config validation: the fused commit refuses non-compact, non-ADMM
  and tree-layout rounds loudly.

No golden trace is regenerated here: the fused path is opt-in
(``fused_gss=False`` default), so the committed traces must keep
passing byte-identical.
"""
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ControllerConfig, FLConfig, init_state, \
    make_flat_spec, make_round_fn, pool_data, run_rounds
from repro.data import make_least_squares
from repro.kernels import ops
from repro.kernels.fused_gss import fused_gss, fused_gss_ref

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _problem(n, c, d, seed=0, frac_valid=0.8):
    rng = np.random.default_rng(seed)
    mk = lambda *s: jnp.asarray(rng.normal(size=s), jnp.float32)  # noqa: E731
    theta, lam, z = mk(n, d), mk(n, d), mk(n, d)
    omega, solved = mk(d), mk(c, d)
    idx = jnp.asarray(rng.permutation(n)[:c], jnp.int32)
    valid = jnp.asarray(rng.random(c) < frac_valid)
    return idx, valid, solved, omega, theta, lam, z


class TestFusedKernel:
    @pytest.mark.parametrize("n,c,d", [
        (16, 8, 128),    # lane-aligned D
        (64, 24, 256),
        (16, 5, 100),    # D padded up to 128
        (8, 3, 7),       # tiny padded D
        (32, 32, 64),    # every row planned
    ])
    @pytest.mark.parametrize("with_z", [True, False])
    def test_bit_identical_to_ref(self, n, c, d, with_z):
        idx, valid, solved, omega, theta, lam, z = _problem(n, c, d,
                                                            seed=n + d)
        zarg = z if with_z else None
        got = fused_gss(idx, valid, solved, omega, theta, lam, zarg,
                        interpret=True, with_z=with_z)
        want = fused_gss_ref(idx, valid, solved, omega, theta, lam, zarg,
                             with_z=with_z)
        for g, w in zip(got, want, strict=True):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))

    def test_unplanned_and_masked_rows_untouched(self):
        idx, valid, solved, omega, theta, lam, z = _problem(
            32, 12, 64, frac_valid=0.5)
        tho, lao, zo = fused_gss(idx, valid, solved, omega, theta, lam, z,
                                 interpret=True)
        committed = set(np.asarray(idx)[np.asarray(valid)].tolist())
        untouched = [r for r in range(32) if r not in committed]
        for out, inp in ((tho, theta), (lao, lam), (zo, z)):
            np.testing.assert_array_equal(
                np.asarray(out)[untouched], np.asarray(inp)[untouched])

    def test_lambda_matches_admm_kernel_bitwise(self):
        # λ⁺ must come out of the same expression the admm_update
        # kernel computes — bit-identical fp32, not merely close.
        idx, valid, solved, omega, theta, lam, z = _problem(
            24, 10, 128, frac_valid=1.0)
        _, lao, _ = fused_gss(idx, valid, solved, omega, theta, lam, z,
                              interpret=True)
        lam_k = ops.admm_update(theta[idx], lam[idx], omega,
                                interpret=True, with_z=False)[0]
        np.testing.assert_array_equal(np.asarray(lao)[np.asarray(idx)],
                                      np.asarray(lam_k))

    def test_z_is_solved_plus_lambda(self):
        idx, valid, solved, omega, theta, lam, z = _problem(
            16, 6, 32, frac_valid=1.0)
        tho, lao, zo = fused_gss(idx, valid, solved, omega, theta, lam, z,
                                 interpret=True)
        rows = np.asarray(idx)
        np.testing.assert_array_equal(np.asarray(tho)[rows],
                                      np.asarray(solved))
        np.testing.assert_array_equal(
            np.asarray(zo)[rows],
            np.asarray(solved + jnp.asarray(lao)[idx]))


def _cfg(n, npts, **kw):
    base = dict(algorithm="fedback", n_clients=n, participation=0.25,
                rho=1.0, lr=0.1, momentum=0.0, epochs=1, batch_size=npts,
                compact=True, capacity_slack=1.5,
                controller=ControllerConfig(K=0.5, alpha=0.9))
    base.update(kw)
    return FLConfig(**base)


def _parity(cfg_a, cfg_b, *, rounds=10, n=32, npts=8, dim=16,
            ragged=False):
    data, params0, loss_fn = make_least_squares(n, npts, dim)
    spec = make_flat_spec(params0)
    rspec = None
    if ragged:
        sizes = [max(npts - 2 * (i % 3), 2) for i in range(n)]
        data, rspec = pool_data(
            [np.asarray(data["x"][i])[:s] for i, s in enumerate(sizes)],
            [np.asarray(data["y"][i])[:s] for i, s in enumerate(sizes)])
    out = []
    for cfg in (cfg_a, cfg_b):
        state = init_state(cfg, params0, spec=spec)
        rf = make_round_fn(cfg, loss_fn, data, spec=spec, ragged=rspec)
        state, hist = run_rounds(rf, state, rounds)
        out.append((state, hist))
    (sa, ha), (sb, hb) = out
    np.testing.assert_array_equal(np.asarray(ha.events),
                                  np.asarray(hb.events))
    for field in ("omega", "theta", "lam", "z_prev"):
        a = np.asarray(getattr(sa, field), np.float32)
        b = np.asarray(getattr(sb, field), np.float32)
        assert a.tobytes() == b.tobytes(), f"{field} not bit-identical"


class TestRoundParity:
    @pytest.mark.parametrize("staleness", [None, 2],
                             ids=["sync", "async"])
    @pytest.mark.parametrize("ragged", [False, True],
                             ids=["uniform", "ragged"])
    def test_fused_matches_reference(self, staleness, ragged):
        _parity(_cfg(32, 8, fused_gss=True, max_staleness=staleness),
                _cfg(32, 8, fused_gss=False, max_staleness=staleness),
                ragged=ragged)

    def test_fused_kernel_matches_fused_jnp(self):
        # The interpret-mode Pallas commit and the jnp fused_gss_ref
        # form of the same round must agree bit for bit too (the trigger
        # kernel runs in both so event decisions share one code path).
        _parity(_cfg(32, 8, fused_gss=True, use_admm_kernel=True,
                     use_trigger_kernel=True),
                _cfg(32, 8, fused_gss=True, use_admm_kernel=False,
                     use_trigger_kernel=True))

    def test_overflow_and_underfill_lanes(self):
        # High target rate + tight slack → rounds that overflow capacity
        # (deferrals) and rounds with invalid plan lanes; the masked
        # write-back must stay bit-exact through both.
        _parity(_cfg(32, 8, participation=0.6, capacity_slack=1.1,
                     fused_gss=True),
                _cfg(32, 8, participation=0.6, capacity_slack=1.1,
                     fused_gss=False), rounds=15)


class TestConfigValidation:
    def test_fused_needs_compact(self):
        data, params0, loss_fn = make_least_squares(8, 4, 5)
        spec = make_flat_spec(params0)
        cfg = _cfg(8, 4, compact=False, fused_gss=True)
        with pytest.raises(ValueError, match="fused_gss"):
            make_round_fn(cfg, loss_fn, data, spec=spec)

    def test_fused_needs_flat_layout(self):
        data, params0, loss_fn = make_least_squares(8, 4, 5)
        cfg = _cfg(8, 4, fused_gss=True)
        with pytest.raises(ValueError, match="fused_gss"):
            make_round_fn(cfg, loss_fn, data)  # tree layout

    def test_fused_needs_admm_family(self):
        from repro.core.compact import make_compact_block
        with pytest.raises(ValueError, match="ADMM"):
            make_compact_block(None, None, 4, is_admm=False,
                               warm_start=False, fused=True)


_TWO_DEVICE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=2")
os.environ["JAX_PLATFORMS"] = "cpu"
import numpy as np
from repro.core import ControllerConfig, FLConfig, init_state, \
    make_flat_spec, make_round_fn, pool_data, run_rounds
from repro.data import make_least_squares
from repro.sharding.clients import make_client_mesh

N, NP, D = 32, 8, 16
mesh = make_client_mesh(2)
for ragged in (False, True):
    data, params0, loss_fn = make_least_squares(N, NP, D)
    spec = make_flat_spec(params0)
    rspec = None
    if ragged:
        sizes = [max(NP - 2 * (i % 3), 2) for i in range(N)]
        data, rspec = pool_data(
            [np.asarray(data["x"][i])[:s] for i, s in enumerate(sizes)],
            [np.asarray(data["y"][i])[:s] for i, s in enumerate(sizes)])
    outs = []
    for fused in (True, False):
        cfg = FLConfig(algorithm="fedback", n_clients=N,
                       participation=0.25, rho=1.0, lr=0.1, momentum=0.0,
                       epochs=1, batch_size=NP, compact=True,
                       capacity_slack=1.5, fused_gss=fused,
                       controller=ControllerConfig(K=0.5, alpha=0.9))
        state = init_state(cfg, params0, mesh=mesh, spec=spec)
        rf = make_round_fn(cfg, loss_fn, data, mesh=mesh, spec=spec,
                           ragged=rspec)
        state, hist = run_rounds(rf, state, 10)
        outs.append((state, hist))
    (sa, ha), (sb, hb) = outs
    assert np.array_equal(np.asarray(ha.events), np.asarray(hb.events)), \
        ("events", ragged)
    for f in ("omega", "theta", "lam", "z_prev"):
        a = np.asarray(getattr(sa, f), np.float32).tobytes()
        b = np.asarray(getattr(sb, f), np.float32).tobytes()
        assert a == b, (f, ragged)
print("TWO_DEVICE_PARITY_OK")
"""


class TestTwoDeviceParity:
    def test_fused_matches_reference_under_mesh(self):
        env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
                   JAX_PLATFORMS="cpu")
        proc = subprocess.run([sys.executable, "-c", _TWO_DEVICE_SCRIPT],
                              cwd=REPO, env=env, capture_output=True,
                              text=True, timeout=900)
        assert proc.returncode == 0, proc.stderr[-2000:]
        assert "TWO_DEVICE_PARITY_OK" in proc.stdout
