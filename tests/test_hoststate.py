"""Host-offloaded state backend (core/hoststate.py): bit-exact parity
with the device engine, streaming-byte accounting, and device-memory
footprint.

The parity matrix is the backend's contract: with the same config the
host backend must reproduce the device engine *bit for bit* — event
decisions AND the fp32 state (ω, θ, λ, z_prev, the EF residual, the
async park buffers) — across {sync, async} × {uniform, ragged} ×
{fused, unfused} at small N.  Tiling the H2D row stream must never
change bits (tiles concatenate back to the same (C, D) working set
inside one program), and the measured per-round transfer bytes must
match the planned model exactly.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ControllerConfig,
    FLConfig,
    HostState,
    init_state,
    make_flat_spec,
    make_round_fn,
    pool_data,
    run_rounds,
)
from repro.data import make_least_squares

N = 12
POINTS = 6
DIM = 4


def _cfg(**kw):
    base = dict(algorithm="fedback", n_clients=N, participation=0.5,
                rho=1.0, lr=0.1, momentum=0.0, epochs=2, batch_size=3,
                controller=ControllerConfig(K=0.2, alpha=0.9),
                compact=True)
    base.update(kw)
    return FLConfig(**base)


def _problem(ragged_kind="none"):
    data, params0, ls = make_least_squares(N, POINTS, DIM)
    spec = make_flat_spec(params0)
    if ragged_kind == "none":
        return data, params0, ls, spec, None
    sizes = ([POINTS] * N if ragged_kind == "uniform"
             else [2 + (i % 4) for i in range(N)])
    xs = [np.asarray(data["x"][i][:s]) for i, s in enumerate(sizes)]
    ys = [np.asarray(data["y"][i][:s]) for i, s in enumerate(sizes)]
    pooled, rspec = pool_data(xs, ys)
    return pooled, params0, ls, spec, rspec


def _run(cfg, data, params0, ls, spec, rspec, rounds=5):
    state = init_state(cfg, params0, spec=spec)
    round_fn = make_round_fn(cfg, ls, data, spec=spec, ragged=rspec)
    events = []
    for _ in range(rounds):
        state, m = round_fn(state)
        events.append(np.asarray(m.events).astype(int).tolist())
    return state, events, round_fn


def _assert_bitexact(dev_st, host_st, *, compress=False, async_mode=False):
    for name in ("theta", "lam", "z_prev", "omega"):
        np.testing.assert_array_equal(
            np.asarray(getattr(dev_st, name)),
            np.asarray(getattr(host_st, name)), err_msg=name)
    if compress:
        np.testing.assert_array_equal(np.asarray(dev_st.comm),
                                      np.asarray(host_st.comm),
                                      err_msg="comm")
    if async_mode:
        for f in ("ttl", "hist", "theta", "lam", "z"):
            np.testing.assert_array_equal(
                np.asarray(getattr(dev_st.inflight, f)),
                np.asarray(getattr(host_st.inflight, f)),
                err_msg=f"inflight.{f}")


class TestHostParity:
    """Host backend ≡ device backend, bit for bit."""

    @pytest.mark.parametrize("sync", ["sync", "async"])
    @pytest.mark.parametrize("ragged_kind", ["uniform", "masked"])
    @pytest.mark.parametrize("fused", [False, True])
    def test_parity_matrix(self, sync, ragged_kind, fused):
        data, params0, ls, spec, rspec = _problem(ragged_kind)
        cfg = _cfg(max_staleness=(2 if sync == "async" else None),
                   fused_gss=fused)
        dev_st, dev_ev, _ = _run(cfg, data, params0, ls, spec, rspec)
        host_st, host_ev, _ = _run(
            dataclasses.replace(cfg, state_backend="host"),
            data, params0, ls, spec, rspec)
        assert dev_ev == host_ev
        _assert_bitexact(dev_st, host_st, async_mode=(sync == "async"))

    def test_parity_rectangular_data(self):
        """Non-ragged (N, n, ...) data path (slot gather on device)."""
        data, params0, ls, spec, _ = _problem("none")
        cfg = _cfg()
        dev_st, dev_ev, _ = _run(cfg, data, params0, ls, spec, None)
        host_st, host_ev, _ = _run(
            dataclasses.replace(cfg, state_backend="host"),
            data, params0, ls, spec, None)
        assert dev_ev == host_ev
        _assert_bitexact(dev_st, host_st)

    def test_parity_compressed_consensus(self):
        """EF residual streams through the full-width server pass."""
        data, params0, ls, spec, _ = _problem("none")
        cfg = _cfg(consensus_compress="int8")
        dev_st, dev_ev, _ = _run(cfg, data, params0, ls, spec, None)
        host_st, host_ev, _ = _run(
            dataclasses.replace(cfg, state_backend="host"),
            data, params0, ls, spec, None)
        assert dev_ev == host_ev
        _assert_bitexact(dev_st, host_st, compress=True)

    def test_parity_fedavg(self):
        """Non-ADMM family: participant mean, λ stays zero."""
        data, params0, ls, spec, _ = _problem("none")
        cfg = _cfg(algorithm="fedavg", rho=0.0)
        dev_st, dev_ev, _ = _run(cfg, data, params0, ls, spec, None)
        host_st, host_ev, _ = _run(
            dataclasses.replace(cfg, state_backend="host"),
            data, params0, ls, spec, None)
        assert dev_ev == host_ev
        _assert_bitexact(dev_st, host_st)

    def test_tiling_never_changes_bits(self):
        """stream_tiles is copy granularity only: the tiles concatenate
        back to one (C, D) working set inside the solve program."""
        data, params0, ls, spec, _ = _problem("none")
        states = []
        for tiles in (1, 4):
            cfg = _cfg(state_backend="host", stream_tiles=tiles)
            st, _, _ = _run(cfg, data, params0, ls, spec, None)
            states.append(st)
        _assert_bitexact(states[0], states[1])

    def test_metrics_match_device(self):
        """Scalar round metrics agree (the trace consumers read these)."""
        data, params0, ls, spec, _ = _problem("none")
        cfg = _cfg()

        def trace(c):
            st = init_state(c, params0, spec=spec)
            fn = make_round_fn(c, ls, data, spec=spec)
            rows = []
            for _ in range(4):
                st, m = fn(st)
                rows.append((int(m.num_events), int(m.num_deferred),
                             int(m.realized_capacity),
                             float(m.realized_slack),
                             float(m.train_loss),
                             np.asarray(m.distances).tolist(),
                             np.asarray(m.committed).tolist()))
            return rows

        assert trace(cfg) == trace(
            dataclasses.replace(cfg, state_backend="host"))

    def test_run_rounds_compatible(self):
        """The generic trace driver works unchanged on the host backend."""
        data, params0, ls, spec, _ = _problem("none")
        cfg = _cfg(state_backend="host")
        state = init_state(cfg, params0, spec=spec)
        round_fn = make_round_fn(cfg, ls, data, spec=spec)
        state, hist = run_rounds(round_fn, state, 3)
        assert isinstance(state, HostState)
        assert np.asarray(hist.num_events).shape == (3,)


class TestHostDispatch:
    def test_init_returns_host_state(self):
        data, params0, ls, spec, _ = _problem("none")
        st = init_state(_cfg(state_backend="host"), params0, spec=spec)
        assert isinstance(st, HostState)
        assert isinstance(st.theta, np.ndarray)
        assert st.distances is None  # lazy until the first round

    def test_unknown_backend_rejected(self):
        data, params0, ls, spec, _ = _problem("none")
        with pytest.raises(ValueError, match="unknown state_backend"):
            init_state(_cfg(state_backend="tpu"), params0, spec=spec)
        with pytest.raises(ValueError, match="unknown state_backend"):
            make_round_fn(_cfg(state_backend="tpu"), ls, data, spec=spec)

    def test_host_needs_flat_and_compact(self):
        data, params0, ls, spec, _ = _problem("none")
        with pytest.raises(ValueError, match="flat"):
            init_state(_cfg(state_backend="host"), params0)
        with pytest.raises(ValueError, match="compact"):
            init_state(_cfg(state_backend="host", compact=False),
                       params0, spec=spec)
        with pytest.raises(ValueError, match="compact"):
            make_round_fn(_cfg(state_backend="host", compact=False),
                          ls, data, spec=spec)

    def test_host_rejects_mesh(self):
        data, params0, ls, spec, _ = _problem("none")
        mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("clients",))
        with pytest.raises(ValueError, match="single-host"):
            make_round_fn(_cfg(state_backend="host"), ls, data,
                          spec=spec, mesh=mesh)


class TestStreamingBytes:
    def test_measured_bytes_match_plan_model(self):
        data, params0, ls, spec, _ = _problem("none")
        cfg = _cfg(state_backend="host")
        st, _, fn = _run(cfg, data, params0, ls, spec, None, rounds=5)
        planned = fn.planned_bytes
        # Row-stream legs: exactly the planned C-row traffic per round.
        assert fn.stats["h2d_row_bytes"] == 5 * planned["row_stream_h2d"]
        assert fn.stats["d2h_row_bytes"] == 5 * planned["row_stream_d2h"]
        # One full-width server pass per round, plus the one-shot lazy
        # trigger seed on the first call.
        assert fn.stats["h2d_full_bytes"] == \
            (5 + 1) * planned["server_pass_h2d"]
        assert fn.stats["d2h_full_bytes"] == 5 * planned["server_pass_d2h"]
        # The streamed rows stay within the budgeted envelope.
        assert (planned["row_stream_h2d"] + planned["row_stream_d2h"]
                <= planned["row_stream_budget"])

    def test_persistent_device_bytes_are_o_n_not_o_nd(self):
        """Between rounds, no (N, D) client matrix is device-resident:
        the persistent device state is O(N) vectors + the (D,) ω."""
        data, params0, ls, spec, _ = _problem("none")
        cfg = _cfg(state_backend="host", consensus_compress="int8",
                   max_staleness=2)
        st, _, fn = _run(cfg, data, params0, ls, spec, None, rounds=3)
        n, d = N, spec.dim
        # 4 host matrices (θ, λ, z, comm) + 3 park buffers.
        assert st.host_state_bytes() == 7 * n * d * 4
        # Device: ω (D) + distances (N) + ctrl/queue/delay/ttl/hist/rng
        # vectors — all O(N) + O(D), strictly below ONE (N, D) matrix.
        assert st.device_state_bytes() < n * d * 4 + 64 * n

    def test_live_device_memory_stays_o_cd(self):
        stats_fn = getattr(jax.local_devices()[0], "memory_stats", None)
        stats = stats_fn() if stats_fn is not None else None
        if not stats or "bytes_in_use" not in stats:
            pytest.skip("allocator memory_stats unavailable (CPU)")
        data, params0, ls, spec, _ = _problem("none")
        cfg = _cfg(state_backend="host")
        baseline = stats_fn()["bytes_in_use"]
        st, _, fn = _run(cfg, data, params0, ls, spec, None, rounds=3)
        live = stats_fn()["bytes_in_use"] - baseline
        n, d = N, spec.dim
        cap = fn.static_info["capacity"]
        # Working set + persistent vectors + data + slack: far below
        # the 3·N·D·4 the device backend would keep resident.
        bound = (8 * cap * d * 4 + st.device_state_bytes()
                 + int(np.asarray(data["x"]).nbytes)
                 + int(np.asarray(data["y"]).nbytes) + (1 << 20))
        assert live <= bound, (live, bound)


class TestHostStateContainer:
    def test_checkpoint_tree_leaves_stay_numpy(self):
        """to_checkpoint_tree must hand the store host buffers directly
        — no device round-trip of the (N, D) matrices."""
        data, params0, ls, spec, _ = _problem("none")
        cfg = _cfg(state_backend="host", consensus_compress="int8")
        st = init_state(cfg, params0, spec=spec)
        tree = st.to_checkpoint_tree()
        for leaf in (tree.theta, tree.lam, tree.z_prev, tree.comm):
            assert isinstance(leaf, np.ndarray)

    def test_fused_flag_validation_mirrors_device(self):
        data, params0, ls, spec, _ = _problem("none")
        cfg = _cfg(state_backend="host", algorithm="fedavg", rho=0.0,
                   fused_gss=True)
        with pytest.raises(ValueError, match="fused_gss"):
            make_round_fn(cfg, ls, data, spec=spec)
