"""Unit + property tests for the participation controller.

Validates the paper's theory numerically:
* Lemma 1  — δ_i^k stays inside the stated bounds for *any* bounded
  trigger process (hypothesis sweeps gains and adversarial distances).
* Theorem 2 — the time-averaged participation rate tracks L̄ at O(1/T)
  with the stated constants c1, c2.
* Lemma 4  — no client starves (events keep occurring indefinitely).
"""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.controller import (
    ControllerConfig,
    controller_step,
    delta_bounds,
    init_controller,
    realized_rate,
    tracking_error_bounds,
)
from repro.core.trigger import evaluate_trigger


def _run_closed_loop(cfg, distances, n_clients=1):
    """Drive the closed loop with an exogenous distance process.

    distances: (T, N) — plays the role of ‖ω^k − z_i^prev‖ (bounded).
    Returns (events (T, N), deltas (T, N), final state).
    """
    state = init_controller(n_clients, cfg)

    def step(state, dist):
        ev = evaluate_trigger(dist, state.delta)
        new = controller_step(state, ev, cfg)
        return new, (ev, new.delta)

    state, (events, deltas) = jax.lax.scan(step, state, distances)
    return np.asarray(events), np.asarray(deltas), state


class TestLemma1Bounds:
    @settings(max_examples=40, deadline=None)
    @given(
        K=st.floats(0.05, 10.0),
        alpha=st.floats(0.05, 0.99),
        target=st.floats(0.01, 1.0),
        delta0=st.floats(-5.0, 5.0),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_delta_bounded_for_any_bounded_distance_process(
            self, K, alpha, target, delta0, seed):
        cfg = ControllerConfig(K=K, alpha=alpha, target_rate=target,
                               delta0=delta0)
        rng = np.random.default_rng(seed)
        dist_max = 3.0
        dists = jnp.asarray(
            rng.uniform(0.0, dist_max, size=(400, 1)), jnp.float32)
        _, deltas, _ = _run_closed_loop(cfg, dists)
        # Any δ₊ > dist_max saturates the trigger (S(δ)=0 ∀δ≥δ₊).
        lo, hi = delta_bounds(cfg, dist_max + 1e-6)
        tol = 1e-4 * max(1.0, abs(lo), abs(hi))
        assert deltas.min() >= lo - tol, (deltas.min(), lo)
        assert deltas.max() <= hi + tol, (deltas.max(), hi)

    def test_paper_gains_mnist(self):
        # The paper's MNIST gains: K=2, α=0.9, L̄ ∈ {.05,…,.6}.
        for target in (0.05, 0.1, 0.2, 0.4, 0.6):
            cfg = ControllerConfig(K=2.0, alpha=0.9, target_rate=target)
            rng = np.random.default_rng(0)
            dists = jnp.asarray(rng.uniform(0, 5.0, (2000, 1)), jnp.float32)
            _, deltas, _ = _run_closed_loop(cfg, dists)
            lo, hi = delta_bounds(cfg, 5.0 + 1e-6)
            assert lo <= deltas.min() and deltas.max() <= hi


class TestTheorem2Tracking:
    @settings(max_examples=30, deadline=None)
    @given(
        K=st.floats(0.1, 5.0),
        alpha=st.floats(0.2, 0.95),
        target=st.floats(0.05, 0.95),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_rate_tracks_target_with_thm2_constants(self, K, alpha, target,
                                                    seed):
        cfg = ControllerConfig(K=K, alpha=alpha, target_rate=target)
        rng = np.random.default_rng(seed)
        T = 3000
        dist_max = 2.0
        dists = jnp.asarray(rng.uniform(0, dist_max, (T, 1)), jnp.float32)
        events, _, state = _run_closed_loop(cfg, dists)
        rate = events.mean()
        lo, hi = tracking_error_bounds(cfg, dist_max + 1e-6, T)
        assert lo - 1e-6 <= rate - target <= hi + 1e-6, (
            rate, target, lo, hi)

    def test_rate_converges_at_one_over_t(self):
        """err(T) ≤ c/T: doubling the horizon halves the error envelope."""
        cfg = ControllerConfig(K=1.0, alpha=0.9, target_rate=0.3)
        rng = np.random.default_rng(7)
        errs = []
        for T in (500, 1000, 2000, 4000):
            dists = jnp.asarray(rng.uniform(0, 1.0, (T, 1)), jnp.float32)
            events, _, _ = _run_closed_loop(cfg, dists)
            errs.append(abs(events.mean() - 0.3))
        # envelope: err_T * T bounded by a constant
        scaled = [e * T for e, T in zip(errs, (500, 1000, 2000, 4000), strict=True)]
        assert max(scaled) <= max(
            tracking_error_bounds(cfg, 1.0, 1)[1],
            -tracking_error_bounds(cfg, 1.0, 1)[0])

    def test_heterogeneous_targets(self):
        """L̄_i may differ between clients (paper §3)."""
        targets = jnp.asarray([0.05, 0.2, 0.5, 0.8], jnp.float32)
        cfg = ControllerConfig(K=1.0, alpha=0.9, target_rate=targets)
        rng = np.random.default_rng(3)
        dists = jnp.asarray(rng.uniform(0, 1.0, (4000, 4)), jnp.float32)
        events, _, state = _run_closed_loop(cfg, dists, n_clients=4)
        np.testing.assert_allclose(events.mean(0), np.asarray(targets),
                                   atol=0.02)


class TestLemma4NoStarvation:
    def test_events_never_stop(self):
        cfg = ControllerConfig(K=0.5, alpha=0.9, target_rate=0.1)
        rng = np.random.default_rng(11)
        dists = jnp.asarray(rng.uniform(0.5, 1.0, (5000, 1)), jnp.float32)
        events, _, _ = _run_closed_loop(cfg, dists)
        # every length-200 tail window contains at least one event
        for s in range(2000, 4800, 200):
            assert events[s:s + 200].any(), f"starved in window {s}"


class TestControllerMechanics:
    def test_low_pass_filter_stays_in_unit_interval(self):
        cfg = ControllerConfig(K=1.0, alpha=0.7, target_rate=0.5)
        state = init_controller(3, cfg)
        rng = np.random.default_rng(0)
        for _ in range(200):
            ev = jnp.asarray(rng.integers(0, 2, 3), bool)
            state = controller_step(state, ev, cfg)
            assert (state.load >= 0).all() and (state.load <= 1).all()

    def test_full_participation_drives_delta_up(self):
        cfg = ControllerConfig(K=1.0, alpha=0.9, target_rate=0.1)
        state = init_controller(1, cfg)
        for _ in range(50):
            state = controller_step(state, jnp.ones((1,), bool), cfg)
        assert float(state.delta[0]) > 0  # raises threshold to choke events

    def test_silence_drives_delta_down(self):
        cfg = ControllerConfig(K=1.0, alpha=0.9, target_rate=0.5)
        state = init_controller(1, cfg)
        for _ in range(50):
            state = controller_step(state, jnp.zeros((1,), bool), cfg)
        # negative δ means the trigger fires unconditionally (distance ≥ 0)
        assert float(state.delta[0]) < 0

    def test_realized_rate_counts(self):
        cfg = ControllerConfig()
        state = init_controller(2, cfg)
        pattern = [(True, False), (True, True), (False, False), (True, False)]
        for ev in pattern:
            state = controller_step(state, jnp.asarray(ev), cfg)
        np.testing.assert_allclose(np.asarray(realized_rate(state)),
                                   [0.75, 0.25])


class TestTargetRateDefaulting:
    """`_ctrl_cfg` defaults L̄ from FLConfig.participation for any python
    scalar target — an int (e.g. target_rate=1) must not bypass it."""

    def _resolved(self, target_rate):
        from repro.core.fedback import FLConfig, _ctrl_cfg
        cfg = FLConfig(participation=0.25,
                       controller=ControllerConfig(target_rate=target_rate))
        return _ctrl_cfg(cfg).target_rate

    def test_float_target_is_replaced(self):
        assert self._resolved(0.1) == 0.25

    def test_int_target_is_replaced(self):
        assert self._resolved(1) == 0.25

    def test_per_client_array_takes_precedence(self):
        targets = jnp.asarray([0.1, 0.9], jnp.float32)
        resolved = self._resolved(targets)
        np.testing.assert_array_equal(np.asarray(resolved),
                                      np.asarray(targets))

    def test_resolved_target_is_float(self):
        assert isinstance(self._resolved(1), float)
