"""Per-architecture smoke tests (deliverable f).

Each assigned architecture is instantiated as a REDUCED variant of the
same family (≤2 layers / 4 for hybrid grouping, d_model ≤ 128,
≤4 experts) and runs one forward/train step on CPU asserting output
shapes and finiteness; decode-capable archs also run prefill + two
decode steps and check prefill/decode logit consistency.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHITECTURES, get_config, shape_applicable
from repro.models.api import build_model, input_specs
from repro.optim.sgd import sgd_init, sgd_step

BATCH, SEQ = 2, 32


def _concrete_batch(cfg, mode, batch=BATCH, seq=SEQ):
    specs = input_specs(cfg, mode=mode, batch=batch, seq=seq)
    rng = np.random.default_rng(0)

    def make(s):
        if jnp.issubdtype(s.dtype, jnp.integer):
            hi = max(cfg.vocab_size - 1, 2)
            return jnp.asarray(rng.integers(0, hi, s.shape), s.dtype)
        return jnp.asarray(rng.normal(size=s.shape) * 0.3, s.dtype)

    return jax.tree.map(make, specs)


@pytest.fixture(scope="module", params=ARCHITECTURES)
def arch_setup(request):
    cfg = get_config(request.param).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return request.param, cfg, model, params


class TestSmokeTrainStep:
    def test_loss_finite(self, arch_setup):
        arch, cfg, model, params = arch_setup
        batch = _concrete_batch(cfg, "train")
        loss = jax.jit(model.loss)(params, batch)
        assert loss.shape == ()
        assert bool(jnp.isfinite(loss)), f"{arch}: loss={loss}"

    def test_one_train_step_updates_and_no_nans(self, arch_setup):
        arch, cfg, model, params = arch_setup
        batch = _concrete_batch(cfg, "train")

        @jax.jit
        def step(params):
            loss, g = jax.value_and_grad(model.loss)(params, batch)
            new, _ = sgd_step(params, g, sgd_init(params), 0.01, 0.9)
            return loss, new

        loss, new_params = step(params)
        assert bool(jnp.isfinite(loss))
        leaves_old = jax.tree.leaves(params)
        leaves_new = jax.tree.leaves(new_params)
        assert all(bool(jnp.isfinite(l).all()) for l in leaves_new), arch
        changed = any(
            not np.allclose(np.asarray(a, np.float32),
                            np.asarray(b, np.float32))
            for a, b in zip(leaves_old, leaves_new, strict=True))
        assert changed, f"{arch}: no parameter moved"

    def test_loss_decreases_over_few_steps(self, arch_setup):
        arch, cfg, model, params = arch_setup
        batch = _concrete_batch(cfg, "train")
        opt = sgd_init(params)

        @jax.jit
        def step(params, opt):
            loss, g = jax.value_and_grad(model.loss)(params, batch)
            params, opt = sgd_step(params, g, opt, 0.05, 0.9)
            return params, opt, loss

        losses = []
        for _ in range(8):
            params, opt, loss = step(params, opt)
            losses.append(float(loss))
        assert losses[-1] < losses[0], f"{arch}: {losses}"


class TestSmokeServe:
    def test_prefill_then_decode_matches_shapes(self, arch_setup):
        arch, cfg, model, params = arch_setup
        if not cfg.supports_decode:
            pytest.skip("encoder-only")
        batch = _concrete_batch(cfg, "prefill")
        max_seq = SEQ + 8
        logits, cache = jax.jit(
            lambda p, b: model.prefill(p, b, max_seq))(params, batch)
        assert logits.shape == (BATCH, 1, cfg.vocab_size)
        assert bool(jnp.isfinite(logits).all()), arch
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        step = jax.jit(model.decode_step)
        for _ in range(2):
            logits, cache = step(params, tok, cache)
            assert logits.shape == (BATCH, 1, cfg.vocab_size)
            assert bool(jnp.isfinite(logits).all()), arch
            tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]

    def test_decode_consistent_with_prefill(self, arch_setup):
        """Prefill(t₀..t_{n}) last-logits == decode after prefill(t₀..t_{n−1})."""
        arch, cfg, model, params = arch_setup
        if not cfg.supports_decode:
            pytest.skip("encoder-only")
        full = _concrete_batch(cfg, "prefill", seq=SEQ)
        shorter = jax.tree.map(lambda x: x, full)
        shorter["tokens"] = full["tokens"][:, :-1]
        last_tok = full["tokens"][:, -1:]

        logits_full, _ = jax.jit(
            lambda p, b: model.prefill(p, b, SEQ))(params, full)
        _, cache = jax.jit(
            lambda p, b: model.prefill(p, b, SEQ))(params, shorter)
        logits_dec, _ = jax.jit(model.decode_step)(params, last_tok, cache)
        np.testing.assert_allclose(
            np.asarray(logits_full[:, 0]), np.asarray(logits_dec[:, 0]),
            rtol=2e-2, atol=2e-2)


class TestShapeApplicability:
    def test_skip_matrix_matches_design(self):
        skips = {}
        for arch in ARCHITECTURES:
            cfg = get_config(arch)
            skips[arch] = {
                s: shape_applicable(cfg, s)[0]
                for s in ("train_4k", "prefill_32k", "decode_32k",
                          "long_500k")
            }
        # encoder-only: no decode at all
        assert not skips["hubert_xlarge"]["decode_32k"]
        assert not skips["hubert_xlarge"]["long_500k"]
        assert skips["hubert_xlarge"]["train_4k"]
        # sub-quadratic archs run long_500k
        for a in ("mamba2_2_7b", "zamba2_2_7b", "mixtral_8x7b"):
            assert skips[a]["long_500k"], a
        # pure full-attention dense archs skip long_500k
        for a in ("deepseek_67b", "granite_3_2b", "phi3_medium_14b",
                  "qwen3_moe_235b_a22b", "paligemma_3b",
                  "moonshot_v1_16b_a3b"):
            assert not skips[a]["long_500k"], a
        # everything trains and prefill-compiles
        for a, row in skips.items():
            assert row["train_4k"], a
            assert row["prefill_32k"], a
