"""Baseline presets, selection strategies and the SCAFFOLD engine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ControllerConfig, FLConfig, init_state
from repro.core.baselines import (
    baseline_config,
    init_scaffold,
    make_scaffold_round,
)
from repro.core.selection import make_selection
from repro.core.state import FLState


class TestPresets:
    def test_known_presets(self):
        for name in ("fedback", "fedadmm", "admm", "fedavg", "fedprox"):
            cfg = baseline_config(name, n_clients=8)
            assert cfg.n_clients == 8

    def test_admm_is_full_participation(self):
        assert baseline_config("admm").participation == 1.0

    def test_unknown_raises(self):
        with pytest.raises(ValueError):
            baseline_config("fedsgd")


class TestSelectionStrategies:
    def _state(self, n=10):
        cfg = FLConfig(n_clients=n)
        return init_state(cfg, {"w": jnp.zeros((3,))})

    @pytest.mark.parametrize("name,rate,expected", [
        ("random", 0.3, 3), ("round_robin", 0.2, 2), ("full", 0.9, 10),
    ])
    def test_cardinality(self, name, rate, expected):
        sel = make_selection(name, rate=rate,
                             controller=ControllerConfig(target_rate=rate))
        state = self._state()
        ev, _ = sel(jax.random.PRNGKey(0), state, jnp.zeros((10,)))
        assert int(ev.sum()) == expected

    def test_round_robin_cycles_through_all(self):
        sel = make_selection("round_robin", rate=0.2,
                             controller=ControllerConfig())
        state = self._state()
        seen = np.zeros(10, bool)
        for k in range(5):
            ev, ctrl = sel(jax.random.PRNGKey(k), state, jnp.zeros((10,)))
            seen |= np.asarray(ev)
            state = FLState(state.theta, state.lam, state.z_prev,
                            state.omega, ctrl, state.rng, state.round + 1)
        assert seen.all()

    def test_random_is_permutation_based_exact(self):
        sel = make_selection("random", rate=0.5,
                             controller=ControllerConfig())
        state = self._state()
        for k in range(5):
            ev, _ = sel(jax.random.PRNGKey(k), state, jnp.zeros((10,)))
            assert int(ev.sum()) == 5


class TestSubsetSize:
    """Regression grid for the k = ⌊rate·n⌋ cardinality rule.

    The old ``int(round(rate * n))`` went through banker's rounding,
    so half-integer products drew a cohort whose size depended on the
    *parity* of the neighbouring integer — 0.35·10 → 4 but 0.45·10
    → 4, 0.55·10 → 6 — and rates strictly below the next integer
    could still round up (0.15·10 → 2).  ``subset_size`` floors (with
    a 1-ulp nudge for products like 0.29·100 = 28.999…96 that land
    just below the integer in binary) and clamps to ≥ 1.  The grid
    pins the floor semantics, with the round-vs-floor disagreements
    called out.
    """

    @pytest.mark.parametrize("rate,n,expected", [
        (0.35, 10, 3),    # round() gave 4 (3.5 → even 4)
        (0.55, 10, 5),    # round() gave 6 (5.5 → even 6)
        (0.15, 10, 1),    # round() gave 2
        (0.1, 16, 1),     # round() gave 2 (1.6 rounds up)
        (0.25, 10, 2),    # 2.5 → even 2: round happened to agree
        (0.45, 10, 4),    # 4.5 → even 4: round happened to agree
        (0.1, 5, 1),      # floor(0.5) = 0 → clamped to 1
        (0.29, 100, 29),  # 28.999…96 in binary — the epsilon case
        (0.3, 10, 3),     # exact product, both agree
        (0.5, 10, 5),
        (0.25, 16, 4),
        (1.0, 7, 7),
        (0.01, 8, 1),     # floor(0.08) = 0 → clamped to 1
        (0.75, 4, 3),
    ])
    def test_rate_grid_pins_k(self, rate, n, expected):
        from repro.core.selection import subset_size
        assert subset_size(rate, n) == expected

    @pytest.mark.parametrize("name", ["random", "round_robin"])
    def test_strategies_draw_floor_cardinality(self, name):
        """The half-integer product that exposed the bug: rate 0.35 on
        n=10 must select 3, not round()'s 4."""
        sel = make_selection(name, rate=0.35,
                             controller=ControllerConfig(target_rate=0.35))
        cfg = FLConfig(n_clients=10)
        state = init_state(cfg, {"w": jnp.zeros((3,))})
        ev, _ = sel(jax.random.PRNGKey(0), state, jnp.zeros((10,)))
        assert int(ev.sum()) == 3


class TestScaffold:
    def test_converges_on_iid_quadratic(self):
        rng = np.random.default_rng(0)
        D, NP, N = 4, 8, 4
        A = rng.normal(size=(NP, D)).astype(np.float32)
        theta_true = rng.normal(size=(D,)).astype(np.float32)
        b = (A @ theta_true).astype(np.float32)
        data = {"x": jnp.asarray(np.stack([A] * N)),
                "y": jnp.asarray(np.stack([b] * N))}

        def ls_loss(params, x, y):
            r = x @ params["theta"] - y
            return 0.5 * jnp.mean(r * r)

        cfg = FLConfig(algorithm="fedavg", n_clients=N, participation=0.5,
                       lr=0.1, momentum=0.0, epochs=20, batch_size=NP)
        state = init_scaffold(cfg, {"theta": jnp.zeros((D,), jnp.float32)})
        round_fn = make_scaffold_round(cfg, ls_loss, data)
        for _ in range(40):
            state, m = round_fn(state)
        np.testing.assert_allclose(np.asarray(state.omega["theta"]),
                                   theta_true, atol=5e-2)

    def test_control_variates_update_only_for_participants(self):
        rng = np.random.default_rng(1)
        D, NP, N = 3, 6, 4
        data = {"x": jnp.asarray(rng.normal(size=(N, NP, D)),
                                 jnp.float32),
                "y": jnp.asarray(rng.normal(size=(N, NP)), jnp.float32)}

        def ls_loss(params, x, y):
            r = x @ params["theta"] - y
            return 0.5 * jnp.mean(r * r)

        cfg = FLConfig(algorithm="fedavg", n_clients=N, participation=0.25,
                       lr=0.05, momentum=0.0, epochs=4, batch_size=NP,
                       seed=7)
        state = init_scaffold(cfg, {"theta": jnp.zeros((D,), jnp.float32)})
        round_fn = make_scaffold_round(cfg, ls_loss, data)
        prev = np.asarray(state.c_clients["theta"])
        state2, m = round_fn(state)
        ev = np.asarray(m["events"])
        new = np.asarray(state2.c_clients["theta"])
        for i in range(N):
            if ev[i]:
                assert not np.allclose(new[i], prev[i])
            else:
                np.testing.assert_array_equal(new[i], prev[i])
