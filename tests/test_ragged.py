"""Ragged heterogeneous client shards: CSR codec + engine parity.

The refactor's contract, pinned here:

* ``RaggedSpec`` is a correct, hashable CSR codec (offsets/sizes over
  one pooled buffer; split/pool round-trips; deterministic size
  buckets covering every client exactly once);
* **uniform sizes reproduce the rectangular engines bit for bit** —
  events AND ω — across {flat, tree} layout × {dense, compact} engine
  on one device, and on a 2-device ``clients`` mesh (subprocess leg,
  mirroring the PR 2/3/4 parity matrices);
* non-uniform shards run through size-bucketed masked solves that
  (a) drop no data (conservation) and (b) agree with a per-client
  reference solve on each client's own rows;
* ``balanced_permutation`` balances total data rows across mesh blocks.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import ControllerConfig, FLConfig, init_state, \
    make_flat_spec, make_round_fn, run_rounds
from repro.data import make_least_squares
from repro.sharding.clients import balanced_permutation
from repro.utils.ragged import make_ragged_spec, pool_data, pool_rows

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _cfg(n, **kw):
    base = dict(algorithm="fedback", n_clients=n, participation=0.3,
                rho=1.0, lr=0.1, momentum=0.0, epochs=2, batch_size=4,
                seed=0, controller=ControllerConfig(K=0.5, alpha=0.9))
    base.update(kw)
    return FLConfig(**base)


def _ragged_least_squares(n, n_points, dim, sizes):
    data, p0, ls = make_least_squares(n, n_points, dim)
    pooled, spec = pool_data(
        [np.asarray(data["x"][i])[:s] for i, s in enumerate(sizes)],
        [np.asarray(data["y"][i])[:s] for i, s in enumerate(sizes)])
    return data, pooled, spec, p0, ls


def _omega_bytes(state):
    return np.concatenate([np.asarray(leaf, np.float32).ravel()
                           for leaf in jax.tree.leaves(state.omega)])


class TestRaggedSpec:
    def test_csr_layout(self):
        spec = make_ragged_spec([3, 5, 2])
        assert spec.offsets == (0, 3, 8)
        assert spec.total == 10
        assert spec.max_size == 5 and spec.min_size == 2
        assert not spec.uniform
        assert spec.client_slice(1) == slice(3, 8)

    def test_hashable_static(self):
        a = make_ragged_spec([4, 4, 4])
        b = make_ragged_spec([4, 4, 4])
        assert hash(a) == hash(b) and a == b  # jit cache key stability
        assert a.uniform and a.padding == 0

    def test_padding_keeps_block_slices_in_bounds(self):
        spec = make_ragged_spec([8, 3])
        assert spec.padding == 5  # last client needs max_size=8 rows
        assert spec.buffer_rows == 16
        assert max(o + spec.max_size for o in spec.offsets) \
            <= spec.buffer_rows

    def test_buckets_partition_clients(self):
        sizes = [3, 9, 4, 9, 5, 17, 3, 12]
        spec = make_ragged_spec(sizes, max_buckets=3)
        members = sorted(i for b in spec.buckets for i in b.members)
        assert members == list(range(len(sizes)))  # exactly once each
        for b in spec.buckets:
            assert all(sizes[i] <= b.capacity for i in b.members)
            assert b.padded == any(sizes[i] < b.capacity
                                   for i in b.members)
        assert len(spec.buckets) <= 3

    def test_uniform_single_identity_bucket(self):
        spec = make_ragged_spec([6] * 10)
        (b,) = spec.buckets
        assert b.capacity == 6 and not b.padded
        assert b.members == tuple(range(10))

    def test_pool_split_roundtrip(self):
        rng = np.random.default_rng(0)
        shards = [rng.normal(size=(s, 3)).astype(np.float32)
                  for s in (2, 7, 4)]
        pooled, spec = pool_rows(shards)
        assert pooled.shape[0] == spec.buffer_rows
        back = spec.split(pooled)
        for a, b in zip(shards, back, strict=True):
            np.testing.assert_array_equal(a, b)

    def test_rejects_bad_sizes(self):
        with pytest.raises(ValueError):
            make_ragged_spec([])
        with pytest.raises(ValueError):
            make_ragged_spec([3, 0, 2])

    @settings(max_examples=10, deadline=None)
    @given(n=st.integers(1, 12), seed=st.integers(0, 5))
    def test_property_conservation(self, n, seed):
        """Σnᵢ == pooled data rows for arbitrary size draws."""
        rng = np.random.default_rng(seed)
        sizes = rng.integers(1, 20, size=n)
        spec = make_ragged_spec(sizes)
        assert spec.total == int(sizes.sum())
        assert spec.offsets == tuple(np.cumsum([0, *sizes[:-1]]).tolist())


class TestUniformParity:
    """Uniform-size pooled data must reproduce the rectangular engines
    bit for bit — events AND ω (single-device legs of the matrix)."""

    N, POINTS, DIM, ROUNDS = 16, 8, 5, 8

    @pytest.fixture(scope="class")
    def problem(self):
        data, p0, ls = make_least_squares(self.N, self.POINTS, self.DIM)
        pooled, spec = pool_data(
            [np.asarray(data["x"][i]) for i in range(self.N)],
            [np.asarray(data["y"][i]) for i in range(self.N)])
        assert spec.uniform and spec.padding == 0
        return data, pooled, spec, p0, ls

    @pytest.mark.parametrize("layout", ["flat", "tree"])
    @pytest.mark.parametrize("compact", [False, True])
    def test_bitexact_vs_rectangular(self, problem, layout, compact):
        data, pooled, rspec, p0, ls = problem
        spec = make_flat_spec(p0) if layout == "flat" else None
        cfg = _cfg(self.N, compact=compact, capacity_slack=1.5)
        s_ref = init_state(cfg, p0, spec=spec)
        s_rag = init_state(cfg, p0, spec=spec)
        rf_ref = make_round_fn(cfg, ls, data, spec=spec)
        rf_rag = make_round_fn(cfg, ls, pooled, spec=spec, ragged=rspec)
        s_ref, h_ref = run_rounds(rf_ref, s_ref, self.ROUNDS)
        s_rag, h_rag = run_rounds(rf_rag, s_rag, self.ROUNDS)
        np.testing.assert_array_equal(np.asarray(h_ref.events),
                                      np.asarray(h_rag.events))
        w_ref, w_rag = _omega_bytes(s_ref), _omega_bytes(s_rag)
        assert w_ref.tobytes() == w_rag.tobytes(), \
            "uniform ragged ω drifted from the rectangular engine"

    def test_bitexact_async_pipeline(self, problem):
        data, pooled, rspec, p0, ls = problem
        spec = make_flat_spec(p0)
        cfg = _cfg(self.N, compact=True, capacity_slack=1.5,
                   max_staleness=2)
        s_ref = init_state(cfg, p0, spec=spec)
        s_rag = init_state(cfg, p0, spec=spec)
        rf_ref = make_round_fn(cfg, ls, data, spec=spec)
        rf_rag = make_round_fn(cfg, ls, pooled, spec=spec, ragged=rspec)
        s_ref, h_ref = run_rounds(rf_ref, s_ref, self.ROUNDS)
        s_rag, h_rag = run_rounds(rf_rag, s_rag, self.ROUNDS)
        np.testing.assert_array_equal(np.asarray(h_ref.events),
                                      np.asarray(h_rag.events))
        assert _omega_bytes(s_ref).tobytes() == \
            _omega_bytes(s_rag).tobytes()


class TestNonUniform:
    N, POINTS, DIM = 16, 12, 5

    @pytest.fixture(scope="class")
    def problem(self):
        sizes = np.random.default_rng(3).integers(4, 13, size=self.N)
        return sizes, *_ragged_least_squares(self.N, self.POINTS,
                                             self.DIM, sizes)

    def test_conservation_through_engine(self, problem):
        sizes, data, pooled, rspec, p0, ls = problem
        assert rspec.total == int(sizes.sum())
        assert pooled["x"].shape[0] == rspec.buffer_rows
        assert not rspec.uniform

    @pytest.mark.parametrize("compact", [False, True])
    def test_runs_and_learns(self, problem, compact):
        sizes, data, pooled, rspec, p0, ls = problem
        spec = make_flat_spec(p0)
        cfg = _cfg(self.N, compact=compact, capacity_slack=1.5)
        s = init_state(cfg, p0, spec=spec)
        rf = make_round_fn(cfg, ls, pooled, spec=spec, ragged=rspec)
        s, h = run_rounds(rf, s, 10)
        tl = np.asarray(h.train_loss)
        assert np.isfinite(tl).all()
        assert np.asarray(h.num_events).sum() > 0

    def test_masked_bucket_solve_matches_per_client_reference(self,
                                                              problem):
        """Each ragged client's first-round solve equals a standalone
        solve over exactly its own nᵢ rows — padding must be invisible.
        """
        from functools import partial

        from repro.core.fedback import _epoch_indices, _local_solve, \
            _masked_local_solve

        sizes, data, pooled, rspec, p0, ls = problem
        solver = partial(_local_solve, ls, rho=1.0, lr=0.1, momentum=0.0)
        masked = partial(_masked_local_solve, ls, rho=1.0, lr=0.1,
                         momentum=0.0)
        key = jax.random.PRNGKey(9)
        zeros = {"theta": jnp.zeros((self.DIM,))}
        for i in (0, 5, self.N - 1):
            n_i = int(sizes[i])
            cap = next(b.capacity for b in rspec.buckets
                       if i in b.members)
            idx_v = _epoch_indices(key, cap, 4, 2)
            off = rspec.offsets[i]
            th_m, _ = masked(zeros, zeros, pooled["x"], pooled["y"],
                             jnp.asarray(off), jnp.asarray(n_i), idx_v)
            # reference: same virtual indices collapsed onto the
            # client's own rows with the same clamp + mask semantics
            # is exactly what the masked solver must compute; with
            # n_i == cap it must equal the plain solver bit for bit.
            if n_i == cap:
                gidx = off + idx_v
                th_r, _ = solver(zeros, zeros, pooled["x"], pooled["y"],
                                 gidx)
                np.testing.assert_array_equal(
                    np.asarray(th_m["theta"]), np.asarray(th_r["theta"]))
            else:
                assert np.isfinite(np.asarray(th_m["theta"])).all()

    def test_masked_loss_ignores_padding(self):
        """Gradients/losses must not see rows beyond a client's size:
        perturbing the neighbor's rows cannot change the solve."""
        from functools import partial

        from repro.core.fedback import _epoch_indices, _masked_local_solve

        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(20, 4)).astype(np.float32))
        y = jnp.asarray(rng.normal(size=(20,)).astype(np.float32))
        x2 = x.at[6:].multiply(100.0)  # client 0 owns rows [0, 6)
        y2 = y.at[6:].multiply(100.0)

        def ls(params, xb, yb):
            r = xb @ params["theta"] - yb
            return 0.5 * jnp.mean(r * r)

        masked = partial(_masked_local_solve, ls, rho=0.5, lr=0.05,
                         momentum=0.0)
        zeros = {"theta": jnp.zeros((4,))}
        idx_v = _epoch_indices(jax.random.PRNGKey(0), 12, 4, 2)
        th_a, l_a = masked(zeros, zeros, x, y, jnp.asarray(0),
                           jnp.asarray(6), idx_v)
        th_b, l_b = masked(zeros, zeros, x2, y2, jnp.asarray(0),
                           jnp.asarray(6), idx_v)
        np.testing.assert_array_equal(np.asarray(th_a["theta"]),
                                      np.asarray(th_b["theta"]))
        assert float(l_a) == float(l_b)

    def test_all_padding_steps_are_skipped(self):
        """A scan step whose batch is entirely padding must not move
        params (no prox-pull toward the center) nor dilute the loss."""
        from functools import partial

        from repro.core.fedback import _masked_local_solve

        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.normal(size=(8, 3)).astype(np.float32))
        y = jnp.asarray(rng.normal(size=(8,)).astype(np.float32))

        def ls(params, xb, yb):
            r = xb @ params["theta"] - yb
            return 0.5 * jnp.mean(r * r)

        masked = partial(_masked_local_solve, ls, rho=1.0, lr=0.1,
                         momentum=0.9)
        theta0 = {"theta": jnp.ones((3,))}
        center = {"theta": jnp.zeros((3,))}
        # one step of real data, then one all-padding step (size=2)
        idx_two = jnp.asarray([[0, 1], [5, 7]])
        idx_one = jnp.asarray([[0, 1]])
        th_two, l_two = masked(theta0, center, x, y, jnp.asarray(0),
                               jnp.asarray(2), idx_two)
        th_one, l_one = masked(theta0, center, x, y, jnp.asarray(0),
                               jnp.asarray(2), idx_one)
        np.testing.assert_array_equal(np.asarray(th_two["theta"]),
                                      np.asarray(th_one["theta"]))
        assert float(l_two) == float(l_one)  # 0-loss steps not averaged


class TestBalancedPermutation:
    def test_balances_rows(self):
        rng = np.random.default_rng(0)
        sizes = rng.integers(1, 100, size=32)
        perm = balanced_permutation(sizes, 4)
        assert sorted(perm) == list(range(32))  # a permutation
        loads = sizes[perm].reshape(4, 8).sum(axis=1)
        # LPT greedy: max block ≤ 4/3 · mean + largest item slack; in
        # practice far tighter — assert a conservative bound
        assert loads.max() - loads.min() <= int(sizes.max())

    def test_uniform_is_identity_friendly(self):
        perm = balanced_permutation([5] * 8, 2)
        assert sorted(perm[:4]) + sorted(perm[4:]) == list(perm)

    def test_rejects_indivisible(self):
        with pytest.raises(ValueError):
            balanced_permutation([1, 2, 3], 2)


_MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import json
import jax, numpy as np
from repro.core import ControllerConfig, FLConfig, init_state, \
    make_flat_spec, make_round_fn, pool_data, run_rounds
from repro.data import make_least_squares
from repro.sharding.clients import make_client_mesh

N = 8
data, p0, ls = make_least_squares(N, 8, 5)
pooled, rspec = pool_data([np.asarray(data["x"][i]) for i in range(N)],
                          [np.asarray(data["y"][i]) for i in range(N)])
spec = make_flat_spec(p0)
mesh = make_client_mesh(2)
out = {}
for compact in (False, True):
    cfg = FLConfig(algorithm="fedback", n_clients=N, participation=0.5,
                   rho=1.0, lr=0.1, momentum=0.0, epochs=2, batch_size=4,
                   compact=compact, capacity_slack=1.5,
                   controller=ControllerConfig(K=0.2, alpha=0.9))
    res = {}
    for tag, d, rg, m in (("rect", data, None, mesh),
                          ("ragged", pooled, rspec, mesh)):
        state = init_state(cfg, p0, spec=spec, mesh=m)
        rf = make_round_fn(cfg, ls, d, spec=spec, ragged=rg, mesh=m)
        events = []
        for _ in range(8):
            state, met = rf(state)
            events.append(np.asarray(met.events).astype(int).tolist())
        w = np.asarray(state.omega, np.float32)
        res[tag] = {"events": events, "omega": w.tolist(),
                    "sharding": str(jax.tree.leaves(state.theta)[0]
                                    .sharding)}
    out["compact" if compact else "dense"] = res
print("RESULT:" + json.dumps(out))
"""


class TestRaggedShardedParity:
    """2-device legs: uniform ragged sharded runs must match the
    rectangular sharded engine bit for bit (events and ω)."""

    @pytest.fixture(scope="class")
    def result(self):
        env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
        out = subprocess.run([sys.executable, "-c", _MESH_SCRIPT], env=env,
                             capture_output=True, text=True, timeout=560,
                             cwd=REPO)
        assert out.returncode == 0, out.stderr[-3000:]
        line = [li for li in out.stdout.splitlines()
                if li.startswith("RESULT:")]
        return json.loads(line[-1][len("RESULT:"):])

    @pytest.mark.parametrize("engine", ["dense", "compact"])
    def test_events_bit_identical(self, result, engine):
        r = result[engine]
        assert r["ragged"]["events"] == r["rect"]["events"]

    @pytest.mark.parametrize("engine", ["dense", "compact"])
    def test_omega_bit_identical(self, result, engine):
        r = result[engine]
        a = np.asarray(r["ragged"]["omega"], np.float32)
        b = np.asarray(r["rect"]["omega"], np.float32)
        assert a.tobytes() == b.tobytes()

    def test_state_stays_client_sharded(self, result):
        assert "clients" in result["compact"]["ragged"]["sharding"]


class TestRaggedSweep:
    def test_sweep_threads_ragged(self):
        """The scan-of-vmap sweep composes with the pooled CSR layout."""
        from repro.launch.sweep import run_sweep

        n = 8
        sizes = np.random.default_rng(1).integers(4, 9, size=n)
        data, pooled, rspec, p0, ls = _ragged_least_squares(n, 8, 5, sizes)
        spec = make_flat_spec(p0)
        cfg = _cfg(n, compact=True, capacity_slack=1.5)
        runs, final, hist = run_sweep(cfg, ls, pooled, p0, rounds=6,
                                      seeds=(0, 1), spec=spec,
                                      ragged=rspec)
        assert hist.events.shape == (6, 2, n)
        assert np.isfinite(np.asarray(hist.train_loss)).all()
