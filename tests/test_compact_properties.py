"""Property layer over the compaction subsystem (core/compact.py).

Runs with real hypothesis when installed (CI: fixed --hypothesis-seed)
and with the executing mini-hypothesis fallback otherwise — these tests
never skip; they are the invariant lock that makes the compaction
subsystem safe to keep refactoring:

* **conservation** — across any round sequence, no unit of work is lost
  or duplicated: served ⊎ carried = demand, exactly;
* **age monotonicity** — deferral age increases by exactly 1 per
  unserved round and resets on service;
* **starvation-freedom** — at the tightest capacity (slack=1.0) every
  demand client is served within ⌈N/C⌉ rounds;
* **capacity bounds** — the adaptive limit lives in [⌈L̄·N⌉, ⌈slack·L̄·N⌉]
  and per-shard budgets always cover the global one;
* **scatter/gather round-trip** — identity on committed rows, untouched
  state elsewhere.
"""
import math

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import DeferQueue
from repro.core.compact import (
    adaptive_limit,
    capacity_bounds,
    capacity_for,
    compact_plan,
    gather_rows,
    init_queue,
    queue_update,
    scatter_rows,
)


def _random_rounds(rng, n, rounds, fire_p):
    """(rounds, N) bool fresh-event stream."""
    return rng.random((rounds, n)) < fire_p


def _play(events_seq, distances_seq, n, capacity, *, limit=None,
          alpha=0.9):
    """Drive plan → queue_update over a round sequence; yield per-round
    (plan, pending_before, queue_after)."""
    queue = init_queue(n)
    out = []
    for events, dist in zip(events_seq, distances_seq, strict=True):
        pending = np.asarray(queue.age) > 0
        plan = compact_plan(jnp.asarray(events), jnp.asarray(dist),
                            capacity, age=queue.age, limit=limit)
        queue = queue_update(queue, plan, alpha=alpha)
        out.append((plan, pending, queue))
    return out


class TestConservation:
    @settings(max_examples=20, deadline=None)
    @given(n=st.integers(2, 24), cap_frac=st.floats(0.1, 1.0),
           fire_p=st.floats(0.0, 1.0), seed=st.integers(0, 2**31 - 1))
    def test_no_event_lost_or_duplicated(self, n, cap_frac, fire_p, seed):
        """served ⊎ carried = demand at every round; a pending client is
        carried until served and never re-enters as a duplicate."""
        rng = np.random.default_rng(seed)
        capacity = max(1, int(round(cap_frac * n)))
        rounds = 12
        events_seq = _random_rounds(rng, n, rounds, fire_p)
        dist_seq = rng.random((rounds, n)).astype(np.float32)
        for plan, pending, queue in _play(events_seq, dist_seq, n,
                                          capacity):
            demand = np.asarray(plan.demand)
            committed = np.asarray(plan.committed)
            carried = np.asarray(queue.age) > 0
            # demand is exactly fresh events ∪ carry — nothing else
            # may be served (no duplication of completed work)
            assert not np.any(committed & ~demand)
            # partition: every demand client is either served now or
            # carried to the next round (no loss), never both
            np.testing.assert_array_equal(committed | carried, demand)
            assert not np.any(committed & carried)
            assert int(plan.num_deferred) == int(carried.sum())
            # pending clients from the previous round are still demand
            assert np.all(demand[pending])

    @settings(max_examples=20, deadline=None)
    @given(n=st.integers(2, 20), seed=st.integers(0, 2**31 - 1))
    def test_committed_count_is_min_demand_limit(self, n, seed):
        rng = np.random.default_rng(seed)
        events = rng.random(n) < 0.7
        dist = rng.random(n).astype(np.float32)
        age = (rng.integers(0, 3, n)).astype(np.int32)
        capacity = max(1, n // 2)
        limit = int(rng.integers(1, capacity + 1))
        plan = compact_plan(jnp.asarray(events), jnp.asarray(dist),
                            capacity, age=jnp.asarray(age),
                            limit=jnp.asarray(limit))
        committed = int(np.asarray(plan.committed).sum())
        assert committed == min(int(plan.num_demand), limit)
        assert int(np.asarray(plan.valid).sum()) == committed


class TestAgeMonotonicity:
    @settings(max_examples=15, deadline=None)
    @given(n=st.integers(2, 16), fire_p=st.floats(0.2, 1.0),
           seed=st.integers(0, 2**31 - 1))
    def test_age_increments_until_served_then_resets(self, n, fire_p,
                                                     seed):
        rng = np.random.default_rng(seed)
        capacity = max(1, n // 3)
        rounds = 10
        events_seq = _random_rounds(rng, n, rounds, fire_p)
        dist_seq = rng.random((rounds, n)).astype(np.float32)
        prev_age = np.zeros(n, np.int32)
        for plan, _, queue in _play(events_seq, dist_seq, n, capacity):
            age = np.asarray(queue.age)
            demand = np.asarray(plan.demand)
            committed = np.asarray(plan.committed)
            unserved = demand & ~committed
            np.testing.assert_array_equal(age[unserved],
                                          prev_age[unserved] + 1)
            assert np.all(age[~unserved] == 0)
            prev_age = age


class TestStarvationFreedom:
    @settings(max_examples=15, deadline=None)
    @given(n=st.integers(4, 32), rate=st.floats(0.1, 0.6),
           fire_p=st.floats(0.3, 1.0), seed=st.integers(0, 2**31 - 1))
    def test_bounded_service_at_tightest_slack(self, n, rate, fire_p,
                                               seed):
        """Acceptance: at slack=1.0 (C = ⌈L̄·N⌉, the tightest capacity)
        every client entering demand is served within ⌈N/C⌉ rounds, for
        an adversarial random event stream."""
        rng = np.random.default_rng(seed)
        capacity = capacity_for(n, rate, 1.0)
        bound = math.ceil(n / capacity)
        rounds = 4 * bound + 8
        events_seq = _random_rounds(rng, n, rounds, fire_p)
        dist_seq = rng.random((rounds, n)).astype(np.float32)
        waiting = np.full(n, -1)  # rounds spent in demand, -1 = idle
        for plan, _, _ in _play(events_seq, dist_seq, n, capacity):
            demand = np.asarray(plan.demand)
            committed = np.asarray(plan.committed)
            waiting = np.where(demand & (waiting < 0), 0, waiting)
            assert np.all(waiting[demand] <= bound), \
                (waiting.max(), bound, capacity)
            waiting = np.where(committed, -1,
                               np.where(demand, waiting + 1, waiting))

    @settings(max_examples=15, deadline=None)
    @given(n=st.integers(4, 24), seed=st.integers(0, 2**31 - 1))
    def test_deferred_outranks_fresh(self, n, seed):
        """A deferred client outranks every fresh event regardless of
        trigger distance (age-ordered priority)."""
        rng = np.random.default_rng(seed)
        events = np.ones(n, bool)
        dist = rng.random(n).astype(np.float32)
        age = np.zeros(n, np.int32)
        stale = int(rng.integers(0, n))
        age[stale] = int(rng.integers(1, 5))
        dist[stale] = 0.0  # smallest distance — age must still win
        plan = compact_plan(jnp.asarray(events), jnp.asarray(dist), 1,
                            age=jnp.asarray(age))
        assert int(plan.idx[0]) == stale
        assert bool(plan.committed[stale])


class TestCapacityBounds:
    @settings(max_examples=25, deadline=None)
    @given(n=st.integers(2, 64), rate=st.floats(0.05, 1.0),
           slack=st.floats(1.0, 3.0), seed=st.integers(0, 2**31 - 1))
    def test_adaptive_limit_within_bounds(self, n, rate, slack, seed):
        rng = np.random.default_rng(seed)
        c_min, c_max = capacity_bounds(n, rate, slack)
        assert math.ceil(rate * n) >= c_min or c_min == c_max
        assert 1 <= c_min <= c_max <= n
        qload = jnp.asarray(rng.random(n).astype(np.float32) * 2.0)
        lim = int(adaptive_limit(qload, c_min, c_max))
        assert c_min <= lim <= c_max

    @settings(max_examples=25, deadline=None)
    @given(n_local=st.integers(1, 32), n_shards=st.sampled_from([1, 2, 3,
                                                                 4, 8]),
           rate=st.floats(0.05, 1.0), slack=st.floats(1.0, 2.5))
    def test_per_shard_budgets_cover_global(self, n_local, n_shards, rate,
                                            slack):
        """Regression (per-shard split): the rounded-up per-shard budget
        summed over shards always covers the global C (up to the hard N
        ceiling), for any non-divisible slack·L̄·N."""
        n = n_local * n_shards
        c_global = math.ceil(slack * rate * n)
        per_shard = capacity_for(n, rate, slack, n_shards=n_shards)
        assert per_shard * n_shards >= min(c_global, n)
        assert 1 <= per_shard <= n_local
        # (the concrete ⌈5/4⌉ remainder regression lives in
        # tests/test_compact.py::test_capacity_for_per_shard_rounds_up)


class TestScatterGatherRoundTrip:
    @settings(max_examples=20, deadline=None)
    @given(n=st.integers(2, 16), d=st.integers(1, 8),
           fire_p=st.floats(0.0, 1.0), seed=st.integers(0, 2**31 - 1))
    def test_identity_on_committed_rows(self, n, d, fire_p, seed):
        rng = np.random.default_rng(seed)
        events = jnp.asarray(rng.random(n) < fire_p)
        dist = jnp.asarray(rng.random(n).astype(np.float32))
        capacity = max(1, n // 2)
        plan = compact_plan(events, dist, capacity)
        tree = {"w": jnp.asarray(rng.standard_normal((n, d)),
                                 jnp.float32),
                "b": jnp.asarray(rng.standard_normal((n,)), jnp.float32)}
        rows = gather_rows(tree, plan.idx)
        back = scatter_rows(tree, rows, plan.idx, plan.valid)
        # gather → scatter of untouched rows is the identity everywhere
        for k in tree:
            np.testing.assert_array_equal(np.asarray(back[k]),
                                          np.asarray(tree[k]))
        # modified rows land exactly on the committed clients
        bumped = {k: r + 1.0 for k, r in rows.items()}
        out = scatter_rows(tree, bumped, plan.idx, plan.valid)
        committed = np.asarray(plan.committed)
        for k in tree:
            diff = (np.asarray(out[k]) != np.asarray(tree[k]))
            changed = np.any(diff.reshape(n, -1), axis=1)
            np.testing.assert_array_equal(changed, committed)


class TestQueueStateDefaults:
    def test_init_queue_predicts_round_zero_burst(self):
        q = init_queue(5)
        assert isinstance(q, DeferQueue)
        np.testing.assert_array_equal(np.asarray(q.age), 0)
        np.testing.assert_array_equal(np.asarray(q.load), 1.0)
        # load=1 per client ⇒ the adaptive limit opens to the ceiling
        assert int(adaptive_limit(q.load, 2, 4)) == 4
