"""Per-kernel correctness: interpret-mode Pallas vs. pure-jnp oracle,
swept over shapes and dtypes (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops
from repro.kernels.ref import (
    admm_update_ref,
    flash_attention_ref,
    ssd_scan_ref,
    trigger_sq_norms_ref,
)


def _rand(rng, shape, dtype):
    x = rng.normal(size=shape).astype(np.float32)
    return jnp.asarray(x, dtype)


class TestTriggerNorms:
    @pytest.mark.parametrize("n,d", [(1, 7), (8, 1024), (13, 777),
                                     (100, 4096), (32, 159010)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, n, d, dtype):
        rng = np.random.default_rng(n * 1000 + d)
        z = _rand(rng, (n, d), dtype)
        w = _rand(rng, (d,), dtype)
        got = ops.trigger_sq_norms(z, w, interpret=True)
        want = trigger_sq_norms_ref(z, w)
        tol = 1e-5 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=tol, atol=tol * d ** 0.5)

    def test_pytree_frontend_matches_engine_trigger(self):
        from repro.core.trigger import trigger_distances
        from repro.models.mlp import init_mlp
        from repro.utils.pytree import tree_broadcast_like
        params = init_mlp(jax.random.PRNGKey(0), 24, 16, 4)
        n = 6
        stacked = jax.tree.map(
            lambda x: x[None] + 0.1 * jax.random.normal(
                jax.random.PRNGKey(1),
                (n,) + x.shape), tree_broadcast_like(params, 1))
        stacked = jax.tree.map(lambda x: x[:, 0] if x.ndim > 2 and
                               x.shape[1] == 1 else x, stacked)
        stacked = jax.tree.map(
            lambda x: x.reshape((n,) + jax.tree.leaves(params)[0].shape)
            if False else x, stacked)
        sq = ops.trigger_sq_norms_pytree(stacked, params, interpret=True)
        ref = trigger_distances(params, stacked) ** 2
        np.testing.assert_allclose(np.asarray(sq), np.asarray(ref),
                                   rtol=1e-4)


class TestAdmmUpdate:
    @pytest.mark.parametrize("n,d", [(4, 64), (8, 1024), (5, 2049)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, n, d, dtype):
        rng = np.random.default_rng(0)
        th = _rand(rng, (n, d), dtype)
        la = _rand(rng, (n, d), dtype)
        w = _rand(rng, (d,), dtype)
        got = ops.admm_update(th, la, w, interpret=True)
        want = admm_update_ref(th, la, w)
        for g, r in zip(got, want, strict=True):
            np.testing.assert_allclose(
                np.asarray(g, np.float32), np.asarray(r, np.float32),
                rtol=1e-2 if dtype == jnp.bfloat16 else 1e-6, atol=1e-2)

    @settings(max_examples=15, deadline=None)
    @given(n=st.integers(1, 17), d=st.integers(1, 300),
           seed=st.integers(0, 100))
    def test_property_random_shapes(self, n, d, seed):
        rng = np.random.default_rng(seed)
        th = _rand(rng, (n, d), jnp.float32)
        la = _rand(rng, (n, d), jnp.float32)
        w = _rand(rng, (d,), jnp.float32)
        got = ops.admm_update(th, la, w, interpret=True)
        want = admm_update_ref(th, la, w)
        for g, r in zip(got, want, strict=True):
            np.testing.assert_allclose(np.asarray(g), np.asarray(r),
                                       rtol=1e-6, atol=1e-6)


class TestFlashAttention:
    @pytest.mark.parametrize("b,h,kvh,s,hd", [
        (1, 4, 4, 128, 64),   # MHA
        (2, 8, 2, 256, 64),   # GQA 4:1
        (1, 4, 1, 128, 128),  # MQA
        (1, 2, 2, 100, 32),   # ragged seq (padding path)
        (1, 2, 1, 37, 16),    # small ragged
    ])
    def test_causal_matches_ref(self, b, h, kvh, s, hd):
        rng = np.random.default_rng(s)
        q = _rand(rng, (b, h, s, hd), jnp.float32)
        k = _rand(rng, (b, kvh, s, hd), jnp.float32)
        v = _rand(rng, (b, kvh, s, hd), jnp.float32)
        got = ops.flash_attention(q, k, v, causal=True, block_q=32,
                                  block_k=32, interpret=True)
        want = flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("window", [16, 64])
    def test_sliding_window_matches_ref(self, window):
        rng = np.random.default_rng(7)
        q = _rand(rng, (1, 4, 128, 32), jnp.float32)
        k = _rand(rng, (1, 2, 128, 32), jnp.float32)
        v = _rand(rng, (1, 2, 128, 32), jnp.float32)
        got = ops.flash_attention(q, k, v, causal=True, window=window,
                                  block_q=32, block_k=32, interpret=True)
        want = flash_attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    def test_bfloat16(self):
        rng = np.random.default_rng(9)
        q = _rand(rng, (1, 2, 64, 32), jnp.bfloat16)
        k = _rand(rng, (1, 2, 64, 32), jnp.bfloat16)
        v = _rand(rng, (1, 2, 64, 32), jnp.bfloat16)
        got = ops.flash_attention(q, k, v, block_q=32, block_k=32,
                                  interpret=True)
        want = flash_attention_ref(q, k, v)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            rtol=3e-2, atol=3e-2)

    def test_matches_model_attention_path(self):
        """Kernel agrees with the model's blockwise-jnp attention."""
        from repro.models.attention import blockwise_attention
        rng = np.random.default_rng(3)
        b, s, h, kvh, hd = 2, 96, 4, 2, 32
        q = _rand(rng, (b, s, h, hd), jnp.float32)
        k = _rand(rng, (b, s, kvh, hd), jnp.float32)
        v = _rand(rng, (b, s, kvh, hd), jnp.float32)
        pos = jnp.arange(s)
        model_out = blockwise_attention(
            q, k, v, q_positions=pos, kv_positions=pos, mask_mode="causal",
            kv_block=32)
        kern_out = ops.flash_attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=True, block_q=32, block_k=32,
            interpret=True).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(np.asarray(kern_out),
                                   np.asarray(model_out), rtol=2e-4,
                                   atol=2e-4)


class TestSsdScan:
    @pytest.mark.parametrize("b,c,h,p,n", [
        (1, 4, 2, 8, 16), (2, 16, 3, 64, 128), (1, 1, 1, 8, 8),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32])
    def test_matches_ref(self, b, c, h, p, n, dtype):
        rng = np.random.default_rng(c)
        states = _rand(rng, (b, c, h, p, n), dtype)
        decays = jnp.asarray(rng.uniform(0.2, 0.99, (b, c, h)), dtype)
        got_prev, got_last = ops.ssd_scan(states, decays, interpret=True)
        want_prev, want_last = ssd_scan_ref(states, decays)
        np.testing.assert_allclose(np.asarray(got_prev),
                                   np.asarray(want_prev), rtol=1e-5,
                                   atol=1e-5)
        np.testing.assert_allclose(np.asarray(got_last),
                                   np.asarray(want_last), rtol=1e-5,
                                   atol=1e-5)

    def test_matches_model_ssd_chunked_states(self):
        """Kernel scan reproduces the carried states inside ssd_chunked."""
        from repro.models.ssm import ssd_chunked
        rng = np.random.default_rng(0)
        b, s, h, p, n, q = 2, 64, 2, 4, 8, 8
        x = jnp.asarray(rng.normal(size=(b, s, h, p)), jnp.float32)
        dt = jnp.asarray(rng.uniform(0.01, 0.3, (b, s, h)), jnp.float32)
        a_log = jnp.asarray(rng.uniform(-1, 1, (h,)), jnp.float32)
        bm = jnp.asarray(rng.normal(size=(b, s, n)), jnp.float32)
        cm = jnp.asarray(rng.normal(size=(b, s, n)), jnp.float32)
        _, h_last = ssd_chunked(x, dt, a_log, bm, cm, chunk=q)
        # rebuild the chunk quantities exactly as ssd_chunked does
        loga = (dt * -jnp.exp(a_log)).reshape(b, s // q, q, h)
        cum = jnp.cumsum(loga, axis=2)
        xdt = (x * dt[..., None]).reshape(b, s // q, q, h, p)
        bc = bm.reshape(b, s // q, q, n)
        decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)
        states = jnp.einsum("bcjhp,bcjn,bcjh->bchpn", xdt, bc, decay_to_end)
        chunk_decay = jnp.exp(cum[:, :, -1, :])
        _, k_last = ops.ssd_scan(states, chunk_decay, interpret=True)
        np.testing.assert_allclose(np.asarray(k_last), np.asarray(h_last),
                                   rtol=1e-4, atol=1e-4)
