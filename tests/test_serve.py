"""Rounds-as-a-service scheduler (core/schedule.py + launch/serve_fl.py).

Five layers:

* **traces** — the :func:`make_trace` generators are deterministic per
  seed, correctly shaped, and each kind has its advertised structure
  (all-ones sync anchor, bursty flash crowds over a quiet baseline);
* **parity matrix** — the degenerate "everyone fires every tick" trace
  reproduces the synchronous round engine bit for bit (events AND fp32
  ω) across {dense, compact, compact+staleness} × {uniform, ragged}
  on one device, and across {dense, compact} on a 2-device mesh
  (subprocess leg, mirroring tests/test_async.py);
* **golden trace** — a fixed-seed bursty run through the compacted
  serve step is pinned byte for byte
  (tests/golden/fedback_serve_bursty_n64_t30.json, regenerate with
  ``--update-golden``);
* **latency bookkeeping** — instant commits on the dense path, queue
  waits under capacity pressure, queued demand served without
  re-arrival, and one latency sample per admission→commit pair;
* **conservation properties** (hypothesis / the executing mini
  fallback) — arrivals − commits = in-flight + deferred at the end of
  every trace the generators can produce.
"""
import hashlib
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import ControllerConfig, FLConfig, init_state, \
    make_flat_spec, make_round_fn, run_rounds
from repro.core.schedule import TraceConfig, make_trace, run_trace, \
    serve, sync_trace
from repro.data import make_least_squares

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
GOLDEN_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "golden", "fedback_serve_bursty_n64_t30.json")


def _cfg(n, **kw):
    base = dict(algorithm="fedback", n_clients=n, participation=0.5,
                rho=1.0, lr=0.1, momentum=0.0, epochs=2, batch_size=4,
                controller=ControllerConfig(K=0.2, alpha=0.9))
    base.update(kw)
    return FLConfig(**base)


def _problem(n, *, n_points=8, dim=5, ragged=False):
    data, params0, ls = make_least_squares(n, n_points, dim)
    spec = make_flat_spec(params0)
    rag = None
    if ragged:
        from repro.utils.ragged import pool_data
        sizes = [max(n_points - 2 * (i % 3), 2) for i in range(n)]
        data, rag = pool_data(
            [np.asarray(data["x"][i])[:s] for i, s in enumerate(sizes)],
            [np.asarray(data["y"][i])[:s] for i, s in enumerate(sizes)])
    return data, params0, ls, spec, rag


class TestTraces:
    def test_shape_dtype_and_determinism(self):
        cfg = TraceConfig(kind="poisson", n_clients=12, ticks=20, seed=3)
        a, b = make_trace(cfg), make_trace(cfg)
        assert a.shape == (20, 12) and a.dtype == bool
        np.testing.assert_array_equal(a, b)
        c = make_trace(TraceConfig(kind="poisson", n_clients=12,
                                   ticks=20, seed=4))
        assert not np.array_equal(a, c)

    def test_sync_trace_is_all_ones(self):
        np.testing.assert_array_equal(sync_trace(5, 7),
                                      np.ones((7, 5), bool))

    def test_bursty_bursts_beat_the_quiet_baseline(self):
        cfg = TraceConfig(kind="bursty", n_clients=256, ticks=64,
                          rate=0.25, seed=0, burst_every=16, burst_len=4,
                          burst_rate=0.9)
        tr = make_trace(cfg)
        burst = np.zeros(64, bool)
        for s in range(0, 64, 16):
            burst[s: s + 4] = True
        assert tr[burst].mean() > 4 * tr[~burst].mean()

    def test_diurnal_swings_with_the_period(self):
        cfg = TraceConfig(kind="diurnal", n_clients=512, ticks=48,
                          rate=0.5, period=24, amplitude=0.9, seed=1)
        tr = make_trace(cfg).mean(axis=1)
        assert tr[6] > 0.7 and tr[18] < 0.3  # peak vs trough

    def test_unknown_kind_raises(self):
        with pytest.raises(ValueError, match="unknown trace kind"):
            make_trace(TraceConfig(kind="fractal"))


class TestDegenerateTraceParity:
    """All-ones trace ≡ the synchronous round engine, bit for bit —
    events AND fp32 ω (the PR 8 parity anchor)."""

    TICKS = 10

    def _pair(self, cfg, *, ragged=False):
        n = cfg.n_clients
        data, params0, ls, spec, rag = _problem(n, ragged=ragged)
        serve_fn = make_round_fn(cfg, ls, data, spec=spec, ragged=rag,
                                 arrivals_arg=True)
        sync_fn = make_round_fn(cfg, ls, data, spec=spec, ragged=rag)
        s_serve, m_serve = run_trace(serve_fn,
                                     init_state(cfg, params0, spec=spec),
                                     sync_trace(n, self.TICKS))
        s_sync, m_sync = run_rounds(sync_fn,
                                    init_state(cfg, params0, spec=spec),
                                    self.TICKS)
        return s_serve, m_serve, s_sync, m_sync

    def _assert_bitexact(self, s_serve, m_serve, s_sync, m_sync):
        np.testing.assert_array_equal(np.asarray(m_serve.events),
                                      np.asarray(m_sync.events))
        np.testing.assert_array_equal(
            np.asarray(s_serve.omega, np.float32).view(np.uint32),
            np.asarray(s_sync.omega, np.float32).view(np.uint32))

    def test_dense_uniform(self):
        self._assert_bitexact(*self._pair(_cfg(8, compact=False)))

    def test_compact_with_deferral(self):
        cfg = _cfg(8, compact=True, capacity=3)
        s_serve, m_serve, s_sync, m_sync = self._pair(cfg)
        self._assert_bitexact(s_serve, m_serve, s_sync, m_sync)
        np.testing.assert_array_equal(np.asarray(m_serve.num_deferred),
                                      np.asarray(m_sync.num_deferred))

    def test_compact_adaptive_capacity(self):
        cfg = _cfg(16, participation=0.25, compact=True,
                   capacity_slack=1.5,
                   controller=ControllerConfig(K=0.5, alpha=0.9))
        self._assert_bitexact(*self._pair(cfg))

    def test_compact_ragged(self):
        cfg = _cfg(12, compact=True, capacity_slack=1.5,
                   participation=0.25)
        self._assert_bitexact(*self._pair(cfg, ragged=True))

    def test_compact_with_staleness(self):
        cfg = _cfg(8, compact=True, capacity=3, max_staleness=2)
        s_serve, m_serve, s_sync, m_sync = self._pair(cfg)
        self._assert_bitexact(s_serve, m_serve, s_sync, m_sync)
        np.testing.assert_array_equal(np.asarray(m_serve.num_inflight),
                                      np.asarray(m_sync.num_inflight))

    def test_fedavg_family(self):
        self._assert_bitexact(
            *self._pair(_cfg(8, algorithm="fedavg", rho=0.0,
                             compact=False)))

    def test_committed_matches_events_on_dense_sync_path(self):
        cfg = _cfg(8, compact=False)
        _, m_serve, _, _ = self._pair(cfg)
        np.testing.assert_array_equal(np.asarray(m_serve.committed),
                                      np.asarray(m_serve.events))


def _event_hex(events: np.ndarray) -> list[str]:
    return [np.packbits(row).tobytes().hex() for row in events]


def _env_fingerprint() -> str:
    import platform
    return (f"jax={jax.__version__};backend={jax.default_backend()};"
            f"machine={platform.machine()}")


class TestGoldenServeTrace:
    """Fixed-seed bursty trace through the compacted serve step, pinned
    byte for byte (events, commits, queue/pipeline depths, final ω)."""

    N, TICKS = 64, 30

    def test_bursty_run_matches_golden(self, request):
        data, params0, ls, spec, _ = _problem(self.N)
        cfg = _cfg(self.N, participation=0.25, compact=True,
                   capacity_slack=1.25, seed=0,
                   controller=ControllerConfig(K=0.5, alpha=0.9))
        round_fn = make_round_fn(cfg, ls, data, spec=spec,
                                 arrivals_arg=True)
        trace = make_trace(TraceConfig(
            kind="bursty", n_clients=self.N, ticks=self.TICKS, rate=0.25,
            seed=0, burst_every=10, burst_len=3, burst_rate=0.9))
        state, hist = run_trace(round_fn,
                                init_state(cfg, params0, spec=spec),
                                trace)
        omega = np.asarray(state.omega, np.float32).reshape(-1)
        record = {
            "n_clients": self.N,
            "ticks": self.TICKS,
            "env": _env_fingerprint(),
            "arrivals_hex": _event_hex(trace.astype(np.uint8)),
            "events_hex": _event_hex(
                np.asarray(hist.events).astype(np.uint8)),
            "committed_hex": _event_hex(
                np.asarray(hist.committed).astype(np.uint8)),
            "deferred": np.asarray(hist.num_deferred).astype(int).tolist(),
            "omega": [float(x) for x in omega],
            "omega_sha256": hashlib.sha256(omega.tobytes()).hexdigest(),
        }
        if request.config.getoption("--update-golden"):
            os.makedirs(os.path.dirname(GOLDEN_PATH), exist_ok=True)
            with open(GOLDEN_PATH, "w") as f:
                json.dump(record, f, indent=1)
            pytest.skip(f"golden serve trace rewritten: {GOLDEN_PATH}")
        assert os.path.exists(GOLDEN_PATH), \
            "no golden serve trace checked in — run with --update-golden"
        with open(GOLDEN_PATH) as f:
            golden = json.load(f)
        assert record["arrivals_hex"] == golden["arrivals_hex"], \
            "the trace generator itself drifted (check make_trace)"
        if (record["env"] != golden.get("env")
                and not os.environ.get("REPRO_GOLDEN_BITEXACT")):
            # Same rationale as tests/test_golden_trace.py: ULP drift
            # across jaxlib versions can flip near-threshold triggers;
            # off the generating environment the parity matrix above is
            # the numerical guard.
            pytest.skip(f"golden generated on {golden.get('env')!r}, "
                        f"running on {record['env']!r} — regenerate with "
                        "--update-golden or force via REPRO_GOLDEN_BITEXACT")
        assert record["events_hex"] == golden["events_hex"], \
            "admission-event stream drifted from the golden serve trace"
        assert record["committed_hex"] == golden["committed_hex"], \
            "commit stream drifted from the golden serve trace"
        assert record["deferred"] == golden["deferred"], \
            "deferral trajectory drifted from the golden serve trace"
        np.testing.assert_allclose(
            omega, np.asarray(golden["omega"], np.float32),
            rtol=1e-6, atol=1e-7,
            err_msg="final ω drifted beyond fp32 tolerance")
        assert record["omega_sha256"] == golden["omega_sha256"], \
            ("final ω bytes changed (within tolerance, but bit-level "
             "drift — inspect, then --update-golden if intentional)")


class TestLatencyBookkeeping:
    def test_dense_path_commits_instantly(self):
        n = 8
        data, params0, ls, spec, _ = _problem(n)
        cfg = _cfg(n, compact=False)
        round_fn = make_round_fn(cfg, ls, data, spec=spec,
                                 arrivals_arg=True)
        trace = make_trace(TraceConfig(kind="poisson", n_clients=n,
                                       ticks=8, rate=0.6, seed=2))
        _, rep = serve(round_fn, init_state(cfg, params0, spec=spec),
                       trace, warmup=True)
        assert rep.conservation_ok
        assert rep.admitted_total == rep.commits_total
        assert rep.pending_final == 0
        np.testing.assert_array_equal(rep.latency_ticks, 0)

    def test_capacity_pressure_creates_queue_latency_then_drains(self):
        """A one-tick flash crowd through capacity=2: commits trickle
        out over the following arrival-free ticks — queued demand is
        served WITHOUT re-arrival, and every admission eventually
        closes with its queue wait as the latency sample."""
        n = 8
        data, params0, ls, spec, _ = _problem(n)
        cfg = _cfg(n, compact=True, capacity=2,
                   controller=ControllerConfig(K=0.2, alpha=0.9,
                                               target_rate=1.0))
        round_fn = make_round_fn(cfg, ls, data, spec=spec,
                                 arrivals_arg=True)
        trace = np.zeros((n, n), bool)
        trace[0] = True  # everyone arrives once, then silence
        _, rep = serve(round_fn, init_state(cfg, params0, spec=spec),
                       trace, warmup=True)
        assert rep.conservation_ok
        assert rep.pending_final == 0  # the queue fully drained
        assert rep.admitted_total == rep.commits_total
        assert rep.latency_ticks.max() > 0  # someone actually waited
        assert rep.latency_ticks.size == rep.commits_total

    def test_report_summary_schema(self):
        n = 6
        data, params0, ls, spec, _ = _problem(n)
        cfg = _cfg(n, compact=True, capacity_slack=1.5,
                   participation=0.25)
        round_fn = make_round_fn(cfg, ls, data, spec=spec,
                                 arrivals_arg=True)
        trace = make_trace(TraceConfig(kind="poisson", n_clients=n,
                                       ticks=5, rate=0.5, seed=0))
        _, rep = serve(round_fn, init_state(cfg, params0, spec=spec),
                       trace)
        s = rep.summary()
        for key in ("arrivals_total", "admitted_total", "commits_total",
                    "pending_final", "conservation_ok",
                    "p50_latency_ticks", "p99_latency_ticks",
                    "p50_latency_us", "p99_latency_us",
                    "commits_per_sec", "ticks_per_sec", "wall_s"):
            assert key in s, key
        assert s["commits_per_sec"] >= 0 and s["wall_s"] > 0

    def test_empty_trace_yields_empty_report(self):
        n = 4
        data, params0, ls, spec, _ = _problem(n)
        cfg = _cfg(n, compact=False)
        round_fn = make_round_fn(cfg, ls, data, spec=spec,
                                 arrivals_arg=True)
        _, rep = serve(round_fn, init_state(cfg, params0, spec=spec),
                       np.zeros((0, n), bool))
        assert rep.commits_total == 0 and rep.admitted_total == 0
        assert rep.conservation_ok
        assert rep.percentiles()["p99_latency_ticks"] == 0.0

    def test_launcher_smoke(self, tmp_path):
        from repro.launch.serve_fl import main
        out = tmp_path / "serve.json"
        rc = main(["--trace", "poisson", "--n-clients", "12",
                   "--ticks", "6", "--dim", "4", "--json", str(out)])
        assert rc == 0
        blob = json.loads(out.read_text())
        assert blob["serve_poisson"]["conservation_ok"] is True


class _SharedRounds:
    """One compiled serve step per (compact,) config, shared across the
    property examples so the fallback stays inside tier-1 budget."""

    _cache: dict = {}

    @classmethod
    def get(cls, compact: bool):
        if compact not in cls._cache:
            n = 12
            data, params0, ls, spec, _ = _problem(n)
            cfg = _cfg(n, participation=0.25,
                       compact=compact,
                       **({"capacity_slack": 1.25} if compact else {}))
            round_fn = make_round_fn(cfg, ls, data, spec=spec,
                                     arrivals_arg=True)
            cls._cache[compact] = (cfg, params0, spec, round_fn)
        return cls._cache[compact]


class TestServeConservation:
    """arrivals − commits = in-flight + deferred, for every trace the
    generators can produce (the serve-side conservation law, mirroring
    tests/test_async.py's pipeline-side one)."""

    @settings(max_examples=12, deadline=None)
    @given(kind=st.sampled_from(("poisson", "diurnal", "bursty")),
           rate=st.floats(0.05, 0.95), seed=st.integers(0, 2**31 - 1),
           compact=st.booleans())
    def test_every_trace_conserves_admissions(self, kind, rate, seed,
                                              compact):
        cfg, params0, spec, round_fn = _SharedRounds.get(compact)
        trace = make_trace(TraceConfig(
            kind=kind, n_clients=cfg.n_clients, ticks=10, rate=rate,
            seed=seed))
        _, rep = serve(round_fn, init_state(cfg, params0, spec=spec),
                       trace)
        assert rep.conservation_ok, rep.summary()
        assert rep.admitted_total <= rep.arrivals_total
        assert rep.admitted_total - rep.commits_total == rep.pending_final
        assert rep.pending_final \
            == rep.final_num_deferred + rep.final_num_inflight
        assert rep.latency_ticks.size == rep.commits_total
        assert rep.latency_ticks.min(initial=0) >= 0


_MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import dataclasses, json
import numpy as np
from repro.core import ControllerConfig, FLConfig, init_state, \
    make_flat_spec, make_round_fn, run_rounds
from repro.core.schedule import TraceConfig, make_trace, run_trace, \
    serve, sync_trace
from repro.data import make_least_squares
from repro.sharding.clients import make_client_mesh

N, TICKS = 8, 8
data, p0, ls = make_least_squares(N, 8, 5)
spec = make_flat_spec(p0)
base = FLConfig(algorithm="fedback", n_clients=N, participation=0.5,
                rho=1.0, lr=0.1, momentum=0.0, epochs=2, batch_size=4,
                controller=ControllerConfig(K=0.2, alpha=0.9))
mesh = make_client_mesh(2)
variants = {
    "dense": dataclasses.replace(base, compact=False),
    "compact_defer": dataclasses.replace(
        base, compact=True, participation=0.25, capacity_slack=1.5),
}
out = {}
for vname, c in variants.items():
    serve_fn = make_round_fn(c, ls, data, spec=spec, mesh=mesh,
                             arrivals_arg=True)
    sync_fn = make_round_fn(c, ls, data, spec=spec, mesh=mesh)
    s_serve, m_serve = run_trace(serve_fn,
                                 init_state(c, p0, spec=spec, mesh=mesh),
                                 sync_trace(N, TICKS))
    s_sync, m_sync = run_rounds(sync_fn,
                                init_state(c, p0, spec=spec, mesh=mesh),
                                TICKS)
    bursty = make_trace(TraceConfig(kind="bursty", n_clients=N,
                                    ticks=TICKS, rate=0.5, seed=0,
                                    burst_every=4, burst_len=2))
    _, rep = serve(serve_fn, init_state(c, p0, spec=spec, mesh=mesh),
                   bursty)
    out[vname] = {
        "events_equal": bool(np.array_equal(np.asarray(m_serve.events),
                                            np.asarray(m_sync.events))),
        "omega_bitexact": bool(np.array_equal(
            np.asarray(s_serve.omega, np.float32).view(np.uint32),
            np.asarray(s_sync.omega, np.float32).view(np.uint32))),
        "bursty_conservation_ok": bool(rep.conservation_ok),
    }
print("RESULT:" + json.dumps(out))
"""


class TestShardedServeParity:
    """2-device mesh legs: the serve admission step under the clients
    mesh — degenerate trace bit-identical to the sharded synchronous
    engine, and a bursty run still conserving admissions."""

    VARIANTS = ("dense", "compact_defer")

    @pytest.fixture(scope="class")
    def result(self):
        env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
        out = subprocess.run([sys.executable, "-c", _MESH_SCRIPT], env=env,
                             capture_output=True, text=True, timeout=560,
                             cwd=REPO)
        assert out.returncode == 0, out.stderr[-3000:]
        line = [ln for ln in out.stdout.splitlines()
                if ln.startswith("RESULT:")]
        return json.loads(line[-1][len("RESULT:"):])

    @pytest.mark.parametrize("variant", VARIANTS)
    def test_degenerate_trace_bit_identical_to_sync(self, result, variant):
        assert result[variant]["events_equal"]
        assert result[variant]["omega_bitexact"]

    @pytest.mark.parametrize("variant", VARIANTS)
    def test_bursty_trace_conserves_on_the_mesh(self, result, variant):
        assert result[variant]["bursty_conservation_ok"]
