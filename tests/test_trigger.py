"""Event-trigger unit tests: the three distance metrics (Remark 3), the
threshold semantics, and the kernel-free reference path used by every
engine."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.trigger import evaluate_trigger, trigger_distances


def _stacked(z):
    return {"w": jnp.asarray(z, jnp.float32)}


class TestTriggerDistances:
    def setup_method(self):
        self.omega = {"w": jnp.asarray([3.0, 0.0], jnp.float32)}
        self.z = _stacked([[0.0, 4.0],  # diff (3, -4): l2=5, linf=4
                           [3.0, 0.0]])  # diff 0

    def test_l2(self):
        d = trigger_distances(self.omega, self.z, "l2")
        np.testing.assert_allclose(np.asarray(d), [5.0, 0.0], atol=1e-6)

    def test_linf(self):
        d = trigger_distances(self.omega, self.z, "linf")
        np.testing.assert_allclose(np.asarray(d), [4.0, 0.0], atol=1e-6)

    def test_cosine_scales_by_z_norm(self):
        d = trigger_distances(self.omega, self.z, "cosine")
        np.testing.assert_allclose(np.asarray(d)[0], 5.0 / 4.0, atol=1e-5)
        np.testing.assert_allclose(np.asarray(d)[1], 0.0, atol=1e-5)

    def test_unknown_metric_raises(self):
        with pytest.raises(ValueError, match="unknown trigger metric"):
            trigger_distances(self.omega, self.z, "l1")

    def test_multi_leaf_pytree_accumulates(self):
        omega = {"a": jnp.zeros((2,), jnp.float32),
                 "b": jnp.zeros((2,), jnp.float32)}
        z = {"a": jnp.full((3, 2), 1.0, jnp.float32),
             "b": jnp.full((3, 2), 2.0, jnp.float32)}
        d = trigger_distances(omega, z, "l2")
        np.testing.assert_allclose(np.asarray(d),
                                   np.sqrt(2 * 1.0 + 2 * 4.0), atol=1e-6)
        d_inf = trigger_distances(omega, z, "linf")
        np.testing.assert_allclose(np.asarray(d_inf), 2.0, atol=1e-6)


class TestEvaluateTrigger:
    def test_fires_at_or_above_threshold(self):
        events = evaluate_trigger(jnp.asarray([1.0, 2.0, 3.0]),
                                  jnp.asarray([2.0, 2.0, 2.0]))
        np.testing.assert_array_equal(np.asarray(events),
                                      [False, True, True])

    def test_negative_delta_always_fires(self):
        """Lemma 1 dynamics drive δ negative to force participation."""
        events = evaluate_trigger(jnp.zeros((3,)),
                                  jnp.asarray([-0.1, -5.0, 0.0]))
        np.testing.assert_array_equal(np.asarray(events),
                                      [True, True, True])
