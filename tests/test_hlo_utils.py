"""HLO collective-parsing unit tests (synthetic HLO snippets + a real
compiled module)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.utils.hlo import (
    collective_inventory,
    count_op,
    total_collective_bytes,
)

SNIPPET = """
  %all-reduce.5 = f32[128,1024]{1,0} all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%add
  %all-gather.2 = bf16[64,2048]{1,0} all-gather(%y), replica_groups=[2,4]<=[8], dimensions={1}
  %reduce-scatter.1 = f32[32]{0} reduce-scatter(%z), replica_groups={{0,1}}, to_apply=%add
  %tuple.1 = (f32[8]{0}, f32[8]{0}) all-to-all(%a, %b), replica_groups={{0,1,2,3,4,5,6,7}}
  %cp = f32[16,16]{1,0} collective-permute(%w), source_target_pairs={{0,1}}
"""


class TestParsing:
    def test_inventory_kinds_and_counts(self):
        inv = collective_inventory(SNIPPET, world_size=8)
        assert inv["all-reduce"]["count"] == 1
        assert inv["all-gather"]["count"] == 1
        assert inv["reduce-scatter"]["count"] == 1
        assert inv["all-to-all"]["count"] == 1
        assert inv["collective-permute"]["count"] == 1

    def test_ring_multipliers(self):
        inv = collective_inventory(SNIPPET, world_size=8)
        ar = 128 * 1024 * 4
        assert inv["all-reduce"]["bytes"] == pytest.approx(
            2 * ar * 3 / 4)  # group of 4
        ag = 64 * 2048 * 2
        assert inv["all-gather"]["bytes"] == pytest.approx(ag * 3 / 4)
        cp = 16 * 16 * 4
        assert inv["collective-permute"]["bytes"] == pytest.approx(cp)

    def test_tuple_shapes_counted(self):
        inv = collective_inventory(SNIPPET, world_size=8)
        a2a = 2 * 8 * 4
        assert inv["all-to-all"]["raw_bytes"] == pytest.approx(a2a)

    def test_total(self):
        t = total_collective_bytes(SNIPPET, world_size=8)
        assert t > 0

    def test_count_op(self):
        assert count_op(SNIPPET, "all-gather") == 1


class TestOnRealModule:
    def test_matmul_allreduce_detected(self):
        mesh = jax.sharding.Mesh(
            np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
        # single-device: no collectives expected
        f = jax.jit(lambda x: (x @ x.T).sum())
        hlo = f.lower(jnp.ones((8, 8))).compile().as_text()
        inv = collective_inventory(hlo, world_size=1)
        assert sum(v["count"] for v in inv.values()) == 0
