"""Device-mesh round engine tests.

* sharded-vs-single-device equivalence: same seed ⇒ bit-identical event
  decisions and fp32-tolerance ω (the consensus all-reduce may reorder
  the sum), exercised in a subprocess with 8 forced host devices;
* the batched sweep runner: one program reproduces per-run histories
  that match individually-driven runs;
* regression tests for the `_epoch_indices` batch-size clamp and state
  donation.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ControllerConfig, FLConfig, init_state, make_round_fn
from repro.core.fedback import _epoch_indices
from repro.data import make_least_squares

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_ls_loss = make_least_squares(1)[2]


def _quadratic(n_clients, n_points=8, dim=5, seed=0):
    data, params0, _ = make_least_squares(n_clients, n_points, dim, seed)
    return data, params0


_MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp, numpy as np
from repro.core import ControllerConfig, FLConfig, init_state, make_round_fn
from repro.data import make_least_squares
from repro.kernels import ops
from repro.sharding.clients import make_client_mesh

rng = np.random.default_rng(0)
N, NP, D = 8, 8, 5
data, p0, ls = make_least_squares(N, NP, D)

cfg = FLConfig(algorithm="fedback", n_clients=N, participation=0.5, rho=1.0,
               lr=0.1, momentum=0.0, epochs=4, batch_size=NP,
               controller=ControllerConfig(K=0.2, alpha=0.9))
out = {}
mesh = make_client_mesh(8)
for name, m in (("single", None), ("sharded", mesh)):
    state = init_state(cfg, p0, mesh=m)
    round_fn = make_round_fn(cfg, ls, data, mesh=m)
    events = []
    for _ in range(15):
        state, met = round_fn(state)
        events.append(np.asarray(met.events).astype(int).tolist())
    out[name] = {"events": events,
                 "omega": np.asarray(state.omega["theta"]).tolist(),
                 "sharding": str(jax.tree.leaves(state.theta)[0].sharding)}

# Pallas trigger kernel under shard_map == jnp reference, on sharded rows
z = jnp.asarray(rng.normal(size=(N, 96)), jnp.float32)
w = jnp.asarray(rng.normal(size=(96,)), jnp.float32)
z_sh = jax.device_put(z, jax.sharding.NamedSharding(
    mesh, jax.sharding.PartitionSpec("clients", None)))
sq_sharded = ops.trigger_sq_norms_pytree(
    {"p": z_sh}, {"p": w}, mesh=mesh)
sq_ref = np.sum((np.asarray(z) - np.asarray(w)) ** 2, axis=1)
out["kernel_max_err"] = float(np.abs(np.asarray(sq_sharded) - sq_ref).max())
print("RESULT:" + json.dumps(out))
"""


class TestShardedEquivalence:
    @pytest.fixture(scope="class")
    def result(self):
        env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
        out = subprocess.run([sys.executable, "-c", _MESH_SCRIPT], env=env,
                             capture_output=True, text=True, timeout=560,
                             cwd=REPO)
        assert out.returncode == 0, out.stderr[-3000:]
        line = [l for l in out.stdout.splitlines() if l.startswith("RESULT:")]
        return json.loads(line[-1][len("RESULT:"):])

    def test_state_is_client_sharded(self, result):
        assert "clients" in result["sharded"]["sharding"]

    def test_events_bit_identical(self, result):
        assert result["single"]["events"] == result["sharded"]["events"]

    def test_round_zero_fires_everyone(self, result):
        assert result["sharded"]["events"][0] == [1] * 8

    def test_omega_within_fp32_tolerance(self, result):
        a = np.asarray(result["single"]["omega"])
        b = np.asarray(result["sharded"]["omega"])
        np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-5)

    def test_trigger_kernel_sharded_matches_reference(self, result):
        assert result["kernel_max_err"] < 1e-3


class TestSweepRunner:
    def test_sweep_matches_individual_runs(self):
        from repro.launch.sweep import run_sweep
        n, rounds = 8, 10
        data, params0 = _quadratic(n)
        cfg = FLConfig(algorithm="fedback", n_clients=n, participation=0.5,
                       rho=1.0, lr=0.1, momentum=0.0, epochs=2, batch_size=4,
                       controller=ControllerConfig(K=0.2, alpha=0.9))
        runs, final, hist = run_sweep(cfg, _ls_loss, data, params0,
                                      rounds=rounds, seeds=(0, 3),
                                      gains=(0.2,))
        assert [r[0] for r in runs] == [0, 3]
        assert hist.events.shape == (rounds, 2, n)
        for b, (seed, K, _) in enumerate(runs):
            icfg = FLConfig(algorithm="fedback", n_clients=n,
                            participation=0.5, rho=1.0, lr=0.1, momentum=0.0,
                            epochs=2, batch_size=4, seed=seed,
                            controller=ControllerConfig(K=K, alpha=0.9))
            state = init_state(icfg, params0)
            round_fn = make_round_fn(icfg, _ls_loss, data)
            for k in range(rounds):
                state, m = round_fn(state)
                np.testing.assert_array_equal(
                    np.asarray(hist.events[k, b]), np.asarray(m.events))
            np.testing.assert_allclose(
                np.asarray(jax.tree.leaves(final.omega)[0][b]),
                np.asarray(jax.tree.leaves(state.omega)[0]),
                rtol=1e-5, atol=1e-6)

    def test_gain_grid_changes_dynamics_without_retrace(self):
        from repro.launch.sweep import init_sweep, make_sweep_fn, SweepGrid
        n = 8
        data, params0 = _quadratic(n)
        cfg = FLConfig(algorithm="fedback", n_clients=n, participation=0.2,
                       rho=1.0, lr=0.1, momentum=0.0, epochs=1, batch_size=8,
                       controller=ControllerConfig(K=0.1, alpha=0.9))
        grid = SweepGrid(seeds=(0,), gains=(0.05, 5.0))
        states, overrides, runs = init_sweep(cfg, params0, grid)
        sweep_fn = make_sweep_fn(cfg, _ls_loss, data, rounds=30)
        _, hist = sweep_fn(states, overrides)
        rates = np.asarray(jnp.mean(hist.events.astype(jnp.float32),
                                    axis=(0, 2)))
        # the high-gain run throttles much harder toward L̄=0.2
        assert rates[1] < rates[0] - 0.05, rates


class TestEpochIndicesClamp:
    def test_oversized_batch_clamps_to_shard(self):
        idx = _epoch_indices(jax.random.PRNGKey(0), n_points=6,
                             batch_size=100, epochs=2)
        assert idx.shape == (2, 6)  # one full-shard batch per epoch
        assert int(idx.max()) < 6

    def test_round_with_oversized_batch_has_finite_loss(self):
        """batch_size > n_points used to scan 0 steps → NaN train loss."""
        n = 4
        data, params0 = _quadratic(n, n_points=6)
        cfg = FLConfig(algorithm="fedback", n_clients=n, participation=1.0,
                       rho=1.0, lr=0.1, momentum=0.0, epochs=2,
                       batch_size=100)
        state = init_state(cfg, params0)
        round_fn = make_round_fn(cfg, _ls_loss, data)
        state, m = round_fn(state)
        assert np.isfinite(float(m.train_loss))
        assert all(np.isfinite(x).all() for x in
                   jax.tree.leaves(jax.device_get(state)))


class TestDonation:
    def test_donated_round_matches_undonated(self):
        n = 4
        data, params0 = _quadratic(n)
        cfg = FLConfig(algorithm="fedback", n_clients=n, participation=0.5,
                       rho=1.0, lr=0.1, momentum=0.0, epochs=2, batch_size=4,
                       controller=ControllerConfig(K=0.2, alpha=0.9))
        outs = []
        for donate in (False, True):
            state = init_state(cfg, params0)
            round_fn = make_round_fn(cfg, _ls_loss, data, donate=donate)
            for _ in range(5):
                state, m = round_fn(state)
            outs.append(np.asarray(state.omega["theta"]))
        np.testing.assert_array_equal(outs[0], outs[1])

    def test_init_state_materializes_zprev(self):
        """θ and z_prev must be distinct buffers or donation would alias."""
        cfg = FLConfig(n_clients=4)
        state = init_state(cfg, {"w": jnp.ones((3,), jnp.float32)})
        th = state.theta["w"]
        zp = state.z_prev["w"]
        assert th.unsafe_buffer_pointer() != zp.unsafe_buffer_pointer()
