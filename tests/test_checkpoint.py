"""Checkpoint store round-trips full federated state.

The dtype layer is pinned explicitly: ``np.savez`` serializes the
ml_dtypes family (bfloat16) as raw void bytes, so without the
``__dtypes__`` sidecar a bf16 client state silently round-trips as
garbage.  bf16 and mixed-dtype trees must restore exactly, a bf16
checkpoint must resume into an fp32 template via a cast (and vice
versa), and genuinely incompatible kinds (float row into an int32
queue age) must be rejected loudly instead of corrupting state.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_checkpoint, load_checkpoint, save_checkpoint
from repro.core import FLConfig, init_state
from repro.models.mlp import init_mlp


def _state():
    cfg = FLConfig(algorithm="fedback", n_clients=5, participation=0.2)
    return cfg, init_state(cfg, init_mlp(jax.random.PRNGKey(0), 16, 8, 4))


class TestStore:
    def test_roundtrip_flstate(self, tmp_path):
        cfg, state = _state()
        path = save_checkpoint(str(tmp_path), 3, state)
        restored = load_checkpoint(path, state)
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored), strict=True):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_latest_discovery(self, tmp_path):
        cfg, state = _state()
        save_checkpoint(str(tmp_path), 1, state)
        p5 = save_checkpoint(str(tmp_path), 5, state)
        save_checkpoint(str(tmp_path), 2, state)
        assert latest_checkpoint(str(tmp_path)) == p5

    def test_missing_dir(self):
        assert latest_checkpoint("/nonexistent/dir") is None

    def test_shape_mismatch_raises(self, tmp_path):
        cfg, state = _state()
        path = save_checkpoint(str(tmp_path), 0, state)
        bad = jax.tree.map(lambda x: x, state)._replace(
            omega=init_mlp(jax.random.PRNGKey(1), 16, 9, 4))
        with pytest.raises(ValueError):
            load_checkpoint(path, bad)


class TestDtypes:
    """The ``__dtypes__`` sidecar: extended dtypes round-trip exactly,
    kind-compatible casts resume, kind clashes fail loudly."""

    def _mixed_tree(self):
        rng = np.random.default_rng(0)
        return {
            "theta_bf16": jnp.asarray(rng.normal(size=(4, 3)),
                                      jnp.bfloat16),
            "omega_f32": jnp.asarray(rng.normal(size=(3,)), jnp.float32),
            "age_i32": jnp.asarray([0, 2, 5, 1], jnp.int32),
            "mask_bool": jnp.asarray([True, False, True]),
            "count_u32": jnp.asarray([7, 9], jnp.uint32),
        }

    def test_bf16_and_mixed_dtype_roundtrip_exact(self, tmp_path):
        tree = self._mixed_tree()
        path = save_checkpoint(str(tmp_path), 0, tree)
        restored = load_checkpoint(path, tree)
        for key in tree:
            a, b = np.asarray(tree[key]), np.asarray(restored[key])
            assert a.dtype == b.dtype, key
            np.testing.assert_array_equal(
                a.view(np.uint8), b.view(np.uint8),
                err_msg=f"{key} did not round-trip bit-exactly")

    def test_bf16_checkpoint_resumes_into_f32_template(self, tmp_path):
        tree = {"w": jnp.asarray([1.5, -2.25, 0.125], jnp.bfloat16)}
        path = save_checkpoint(str(tmp_path), 0, tree)
        restored = load_checkpoint(
            path, {"w": jnp.zeros((3,), jnp.float32)})
        assert np.asarray(restored["w"]).dtype == np.float32
        # bf16 → f32 widening is exact
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      [1.5, -2.25, 0.125])

    def test_f32_checkpoint_resumes_into_bf16_template(self, tmp_path):
        tree = {"w": jnp.asarray([1.5, -2.25], jnp.float32)}
        path = save_checkpoint(str(tmp_path), 0, tree)
        restored = load_checkpoint(
            path, {"w": jnp.zeros((2,), jnp.bfloat16)})
        assert np.asarray(restored["w"]).dtype == jnp.bfloat16
        np.testing.assert_array_equal(
            np.asarray(restored["w"], np.float32), [1.5, -2.25])

    def test_matching_signedness_int_cast_is_allowed(self, tmp_path):
        tree = {"age": jnp.asarray([1, 2, 3], jnp.int32)}
        path = save_checkpoint(str(tmp_path), 0, tree)
        restored = load_checkpoint(
            path, {"age": jnp.zeros((3,), jnp.int64)})
        np.testing.assert_array_equal(np.asarray(restored["age"]),
                                      [1, 2, 3])

    @pytest.mark.parametrize("stored,template", [
        (np.float32, np.int32),    # float row into a queue age
        (np.int32, np.float32),    # int counter into a weight row
        (np.int32, np.uint32),     # signedness flip
        (np.bool_, np.int32),      # mask into a counter
    ])
    def test_incompatible_kind_is_rejected_loudly(self, tmp_path, stored,
                                                  template):
        tree = {"leaf": jnp.zeros((2,), stored)}
        path = save_checkpoint(str(tmp_path), 0, tree)
        with pytest.raises(ValueError, match="incompatible dtype"):
            load_checkpoint(path, {"leaf": jnp.zeros((2,), template)})

    def test_bf16_into_int_template_is_rejected(self, tmp_path):
        tree = {"leaf": jnp.zeros((2,), jnp.bfloat16)}
        path = save_checkpoint(str(tmp_path), 0, tree)
        with pytest.raises(ValueError, match="incompatible dtype"):
            load_checkpoint(path, {"leaf": jnp.zeros((2,), jnp.int32)})

    def test_treedef_mismatch_names_both_structures(self, tmp_path):
        tree = {"a": jnp.zeros((2,)), "b": jnp.ones((2,))}
        path = save_checkpoint(str(tmp_path), 0, tree)
        with pytest.raises(ValueError,
                           match="checkpoint structure mismatch"):
            load_checkpoint(path, {"a": jnp.zeros((2,)),
                                   "c": jnp.ones((2,))})

    def test_bf16_flstate_roundtrip(self, tmp_path):
        """Full FLState with bf16 client rows — the mixed-precision
        resume scenario the sidecar exists for."""
        cfg, state = _state()
        state = state._replace(
            theta=jax.tree.map(lambda x: x.astype(jnp.bfloat16),
                               state.theta))
        path = save_checkpoint(str(tmp_path), 1, state)
        restored = load_checkpoint(path, state)
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored),
                        strict=True):
            a, b = np.atleast_1d(np.asarray(a)), np.atleast_1d(np.asarray(b))
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(a.view(np.uint8),
                                          b.view(np.uint8))
