"""Checkpoint store round-trips full federated state.

The dtype layer is pinned explicitly: ``np.savez`` serializes the
ml_dtypes family (bfloat16) as raw void bytes, so without the
``__dtypes__`` sidecar a bf16 client state silently round-trips as
garbage.  bf16 and mixed-dtype trees must restore exactly, a bf16
checkpoint must resume into an fp32 template via a cast (and vice
versa), and genuinely incompatible kinds (float row into an int32
queue age) must be rejected loudly instead of corrupting state.

The host state backend (core/hoststate.py) checkpoints through the
same store with *numpy* (N, D) leaves — no device round-trip — and its
FLState-shaped tree is structurally identical to a device checkpoint
of the same config, so resumes cross backends both ways bit-exactly.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_checkpoint, load_checkpoint, save_checkpoint
from repro.core import FLConfig, init_state
from repro.models.mlp import init_mlp


def _state():
    cfg = FLConfig(algorithm="fedback", n_clients=5, participation=0.2)
    return cfg, init_state(cfg, init_mlp(jax.random.PRNGKey(0), 16, 8, 4))


class TestStore:
    def test_roundtrip_flstate(self, tmp_path):
        cfg, state = _state()
        path = save_checkpoint(str(tmp_path), 3, state)
        restored = load_checkpoint(path, state)
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored), strict=True):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_latest_discovery(self, tmp_path):
        cfg, state = _state()
        save_checkpoint(str(tmp_path), 1, state)
        p5 = save_checkpoint(str(tmp_path), 5, state)
        save_checkpoint(str(tmp_path), 2, state)
        assert latest_checkpoint(str(tmp_path)) == p5

    def test_missing_dir(self):
        assert latest_checkpoint("/nonexistent/dir") is None

    def test_shape_mismatch_raises(self, tmp_path):
        cfg, state = _state()
        path = save_checkpoint(str(tmp_path), 0, state)
        bad = jax.tree.map(lambda x: x, state)._replace(
            omega=init_mlp(jax.random.PRNGKey(1), 16, 9, 4))
        with pytest.raises(ValueError):
            load_checkpoint(path, bad)


class TestDtypes:
    """The ``__dtypes__`` sidecar: extended dtypes round-trip exactly,
    kind-compatible casts resume, kind clashes fail loudly."""

    def _mixed_tree(self):
        rng = np.random.default_rng(0)
        return {
            "theta_bf16": jnp.asarray(rng.normal(size=(4, 3)),
                                      jnp.bfloat16),
            "omega_f32": jnp.asarray(rng.normal(size=(3,)), jnp.float32),
            "age_i32": jnp.asarray([0, 2, 5, 1], jnp.int32),
            "mask_bool": jnp.asarray([True, False, True]),
            "count_u32": jnp.asarray([7, 9], jnp.uint32),
        }

    def test_bf16_and_mixed_dtype_roundtrip_exact(self, tmp_path):
        tree = self._mixed_tree()
        path = save_checkpoint(str(tmp_path), 0, tree)
        restored = load_checkpoint(path, tree)
        for key in tree:
            a, b = np.asarray(tree[key]), np.asarray(restored[key])
            assert a.dtype == b.dtype, key
            np.testing.assert_array_equal(
                a.view(np.uint8), b.view(np.uint8),
                err_msg=f"{key} did not round-trip bit-exactly")

    def test_bf16_checkpoint_resumes_into_f32_template(self, tmp_path):
        tree = {"w": jnp.asarray([1.5, -2.25, 0.125], jnp.bfloat16)}
        path = save_checkpoint(str(tmp_path), 0, tree)
        restored = load_checkpoint(
            path, {"w": jnp.zeros((3,), jnp.float32)})
        assert np.asarray(restored["w"]).dtype == np.float32
        # bf16 → f32 widening is exact
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      [1.5, -2.25, 0.125])

    def test_f32_checkpoint_resumes_into_bf16_template(self, tmp_path):
        tree = {"w": jnp.asarray([1.5, -2.25], jnp.float32)}
        path = save_checkpoint(str(tmp_path), 0, tree)
        restored = load_checkpoint(
            path, {"w": jnp.zeros((2,), jnp.bfloat16)})
        assert np.asarray(restored["w"]).dtype == jnp.bfloat16
        np.testing.assert_array_equal(
            np.asarray(restored["w"], np.float32), [1.5, -2.25])

    def test_matching_signedness_int_cast_is_allowed(self, tmp_path):
        tree = {"age": jnp.asarray([1, 2, 3], jnp.int32)}
        path = save_checkpoint(str(tmp_path), 0, tree)
        restored = load_checkpoint(
            path, {"age": jnp.zeros((3,), jnp.int64)})
        np.testing.assert_array_equal(np.asarray(restored["age"]),
                                      [1, 2, 3])

    @pytest.mark.parametrize("stored,template", [
        (np.float32, np.int32),    # float row into a queue age
        (np.int32, np.float32),    # int counter into a weight row
        (np.int32, np.uint32),     # signedness flip
        (np.bool_, np.int32),      # mask into a counter
    ])
    def test_incompatible_kind_is_rejected_loudly(self, tmp_path, stored,
                                                  template):
        tree = {"leaf": jnp.zeros((2,), stored)}
        path = save_checkpoint(str(tmp_path), 0, tree)
        with pytest.raises(ValueError, match="incompatible dtype"):
            load_checkpoint(path, {"leaf": jnp.zeros((2,), template)})

    def test_bf16_into_int_template_is_rejected(self, tmp_path):
        tree = {"leaf": jnp.zeros((2,), jnp.bfloat16)}
        path = save_checkpoint(str(tmp_path), 0, tree)
        with pytest.raises(ValueError, match="incompatible dtype"):
            load_checkpoint(path, {"leaf": jnp.zeros((2,), jnp.int32)})

    def test_treedef_mismatch_names_both_structures(self, tmp_path):
        tree = {"a": jnp.zeros((2,)), "b": jnp.ones((2,))}
        path = save_checkpoint(str(tmp_path), 0, tree)
        with pytest.raises(ValueError,
                           match="checkpoint structure mismatch"):
            load_checkpoint(path, {"a": jnp.zeros((2,)),
                                   "c": jnp.ones((2,))})

    def test_bf16_sidecar_on_numpy_host_leaves(self, tmp_path):
        """The sidecar path must work for trees whose leaves never
        touched the device (host-backend checkpoints): an ml_dtypes
        bf16 *numpy* matrix round-trips bit-exactly."""
        import ml_dtypes
        rng = np.random.default_rng(3)
        tree = {"rows": rng.normal(size=(6, 4)).astype(ml_dtypes.bfloat16),
                "aux": np.arange(6, dtype=np.int32)}
        path = save_checkpoint(str(tmp_path), 0, tree)
        restored = load_checkpoint(path, tree)
        assert np.asarray(restored["rows"]).dtype == ml_dtypes.bfloat16
        np.testing.assert_array_equal(
            np.asarray(tree["rows"]).view(np.uint8),
            np.asarray(restored["rows"]).view(np.uint8))

    def test_bf16_flstate_roundtrip(self, tmp_path):
        """Full FLState with bf16 client rows — the mixed-precision
        resume scenario the sidecar exists for."""
        cfg, state = _state()
        state = state._replace(
            theta=jax.tree.map(lambda x: x.astype(jnp.bfloat16),
                               state.theta))
        path = save_checkpoint(str(tmp_path), 1, state)
        restored = load_checkpoint(path, state)
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored),
                        strict=True):
            a, b = np.atleast_1d(np.asarray(a)), np.atleast_1d(np.asarray(b))
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(a.view(np.uint8),
                                          b.view(np.uint8))


class TestHostBackendCheckpoint:
    """Host-backend checkpoints: saved straight from host buffers (no
    device round-trip of the (N, D) matrices) and resumable across
    backends both ways, bit-exactly."""

    N = 10

    def _problem(self):
        from repro.core import make_flat_spec
        from repro.data import make_least_squares
        data, params0, ls = make_least_squares(self.N, 6, 4)
        return data, params0, ls, make_flat_spec(params0)

    def _cfg(self, **kw):
        from repro.core import ControllerConfig
        base = dict(algorithm="fedback", n_clients=self.N,
                    participation=0.5, rho=1.0, lr=0.1, momentum=0.0,
                    epochs=1, batch_size=3, compact=True,
                    consensus_compress="int8",
                    controller=ControllerConfig(K=0.2, alpha=0.9))
        base.update(kw)
        return FLConfig(**base)

    def _run(self, cfg, state, rounds):
        from repro.core import make_round_fn
        data, params0, ls, spec = self._problem()
        fn = make_round_fn(cfg, ls, data, spec=spec)
        for _ in range(rounds):
            state, _ = fn(state)
        return state

    def _assert_state_equal(self, a, b):
        ta = a.to_checkpoint_tree() if hasattr(a, "to_checkpoint_tree") \
            else a
        tb = b.to_checkpoint_tree() if hasattr(b, "to_checkpoint_tree") \
            else b
        for x, y in zip(jax.tree.leaves(ta), jax.tree.leaves(tb),
                        strict=True):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_host_roundtrip_resumes_bitexact(self, tmp_path):
        from repro.core import host_state_from_tree
        data, params0, ls, spec = self._problem()
        cfg = self._cfg(state_backend="host")
        st = init_state(cfg, params0, spec=spec)
        st = self._run(cfg, st, 2)
        tree = st.to_checkpoint_tree()
        # The store receives host buffers directly — numpy in …
        assert isinstance(tree.theta, np.ndarray)
        assert isinstance(tree.comm, np.ndarray)
        path = save_checkpoint(str(tmp_path), 2, tree)
        # … and hands numpy back out (device_get is the identity here).
        loaded = load_checkpoint(path, tree)
        assert isinstance(loaded.theta, np.ndarray)
        resumed = host_state_from_tree(loaded, cfg, spec=spec)
        final_a = self._run(cfg, resumed, 2)
        final_b = self._run(cfg, st, 2)  # uninterrupted continuation
        self._assert_state_equal(final_a, final_b)

    def test_resume_device_checkpoint_on_host(self, tmp_path):
        from repro.core import host_state_from_tree
        data, params0, ls, spec = self._problem()
        dev_cfg = self._cfg()
        host_cfg = dataclasses.replace(dev_cfg, state_backend="host")
        dev_st = self._run(dev_cfg,
                           init_state(dev_cfg, params0, spec=spec), 2)
        path = save_checkpoint(str(tmp_path), 2, dev_st)
        host_template = init_state(host_cfg, params0,
                                   spec=spec).to_checkpoint_tree()
        loaded = load_checkpoint(path, host_template)
        host_final = self._run(
            host_cfg, host_state_from_tree(loaded, host_cfg, spec=spec), 2)
        dev_final = self._run(dev_cfg, dev_st, 2)
        self._assert_state_equal(dev_final, host_final)

    def test_resume_host_checkpoint_on_device(self, tmp_path):
        data, params0, ls, spec = self._problem()
        dev_cfg = self._cfg()
        host_cfg = dataclasses.replace(dev_cfg, state_backend="host")
        host_st = self._run(host_cfg,
                            init_state(host_cfg, params0, spec=spec), 2)
        path = save_checkpoint(str(tmp_path), 2,
                               host_st.to_checkpoint_tree())
        dev_template = init_state(dev_cfg, params0, spec=spec)
        loaded = load_checkpoint(path, dev_template)
        loaded = jax.tree.map(jnp.asarray, loaded)
        dev_final = self._run(dev_cfg, loaded, 2)
        host_final = self._run(host_cfg, host_st, 2)
        self._assert_state_equal(dev_final, host_final)

    def test_async_park_buffers_roundtrip(self, tmp_path):
        from repro.core import host_state_from_tree
        data, params0, ls, spec = self._problem()
        cfg = self._cfg(state_backend="host", max_staleness=2)
        st = self._run(cfg, init_state(cfg, params0, spec=spec), 3)
        tree = st.to_checkpoint_tree()
        assert isinstance(tree.inflight.theta, np.ndarray)
        path = save_checkpoint(str(tmp_path), 3, tree)
        resumed = host_state_from_tree(load_checkpoint(path, tree), cfg,
                                       spec=spec)
        final_a = self._run(cfg, resumed, 2)
        final_b = self._run(cfg, st, 2)
        self._assert_state_equal(final_a, final_b)
