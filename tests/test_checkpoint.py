"""Checkpoint store round-trips full federated state."""
import jax
import numpy as np
import pytest

from repro.checkpoint import latest_checkpoint, load_checkpoint, save_checkpoint
from repro.core import FLConfig, init_state
from repro.models.mlp import init_mlp


def _state():
    cfg = FLConfig(algorithm="fedback", n_clients=5, participation=0.2)
    return cfg, init_state(cfg, init_mlp(jax.random.PRNGKey(0), 16, 8, 4))


class TestStore:
    def test_roundtrip_flstate(self, tmp_path):
        cfg, state = _state()
        path = save_checkpoint(str(tmp_path), 3, state)
        restored = load_checkpoint(path, state)
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored), strict=True):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_latest_discovery(self, tmp_path):
        cfg, state = _state()
        save_checkpoint(str(tmp_path), 1, state)
        p5 = save_checkpoint(str(tmp_path), 5, state)
        save_checkpoint(str(tmp_path), 2, state)
        assert latest_checkpoint(str(tmp_path)) == p5

    def test_missing_dir(self):
        assert latest_checkpoint("/nonexistent/dir") is None

    def test_shape_mismatch_raises(self, tmp_path):
        cfg, state = _state()
        path = save_checkpoint(str(tmp_path), 0, state)
        bad = jax.tree.map(lambda x: x, state)._replace(
            omega=init_mlp(jax.random.PRNGKey(1), 16, 9, 4))
        with pytest.raises(ValueError):
            load_checkpoint(path, bad)
