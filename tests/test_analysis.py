"""tracecheck self-tests: HLO parser units, the AST lint, the rule
engine over cheap jaxpr-only artifacts, and the baseline-compare gate.

The jaxpr-only rule assertions here are the tier-1 migration of the
old ``--runslow`` fused-round op-count tests (``TestFusedRoundOpCounts``
in tests/test_compact.py): tracing a round is cheap, so the Pallas-call
and full-width-sweep budgets now gate every PR instead of nightly only.
The compiled-module mutation matrix lives in
tests/test_analysis_mutations.py.
"""
import copy

import pytest

from repro.analysis import astlint
from repro.analysis.artifacts import (
    ConfigKey,
    FAST_MATRIX,
    FULL_MATRIX,
    build_artifact,
)
from repro.analysis.cli import compare_to_baseline, report_failures
from repro.analysis.rules import evaluate
from repro.utils import hlo as H

# ---------------------------------------------------------------------------
# HLO parser units
# ---------------------------------------------------------------------------


class TestGroupSizes:
    def _ar(self, groups: str) -> str:
        return (f"  %ar = f32[64]{{0}} all-reduce(%x), "
                f"replica_groups={groups}, to_apply=%add\n")

    def _link_frac(self, groups: str, world_size: int = 8) -> float:
        inv = H.collective_inventory(self._ar(groups),
                                     world_size=world_size)
        return inv["all-reduce"]["bytes"] / (2.0 * 64 * 4)

    def test_multi_group_uses_largest(self):
        # {{0,1},{2,3,4,5}} → the budget must charge the 4-wide group,
        # not the first group's 2.
        assert self._link_frac("{{0,1},{2,3,4,5}}") == pytest.approx(3 / 4)

    def test_iota_two_dim(self):
        # [2,4]<=[8]: 2 groups of 4.
        assert self._link_frac("[2,4]<=[8]") == pytest.approx(3 / 4)

    def test_iota_flat(self):
        # [8]<=[8]: one group of 8.
        assert self._link_frac("[8]<=[8]") == pytest.approx(7 / 8)

    def test_flat_single_group(self):
        assert self._link_frac("{0,1,2}") == pytest.approx(2 / 3)

    def test_no_annotation_falls_back_to_world_size(self):
        line = "  %ar = f32[64]{0} all-reduce(%x), to_apply=%add\n"
        inv = H.collective_inventory(line, world_size=4)
        assert inv["all-reduce"]["bytes"] == pytest.approx(
            2.0 * 64 * 4 * 3 / 4)


class TestCountOp:
    MENTIONS = (
        '  %fusion.1 = f32[8]{0} fusion(%a), kind=kLoop, '
        'calls=%all-reduce_fusion, metadata={op_name="jit(f)/all-reduce"}\n'
        "  %ar.1 = f32[8]{0} all-reduce(%a), replica_groups={{0,1}}, "
        "to_apply=%add\n")

    def test_instruction_sites_only(self):
        # The fusion label and the op_name metadata string both mention
        # "all-reduce" — only the real instruction site counts.
        assert H.count_op(self.MENTIONS, "all-reduce") == 1

    def test_tuple_result_site(self):
        text = ("  %t = (f32[8]{0}, u32[]) all-to-all(%a, %b), "
                "replica_groups={{0,1}}\n")
        assert H.count_op(text, "all-to-all") == 1


class TestNarrowDtypes:
    def test_f8_bytes(self):
        text = ("  %ag = f8e4m3[64,2]{1,0} all-gather(%x), "
                "replica_groups={{0,1}}, dimensions={0}\n")
        inv = H.collective_inventory(text, world_size=2)
        assert inv["all-gather"]["raw_bytes"] == pytest.approx(128.0)

    def test_sub_byte_rounds_up(self):
        text = ("  %ag = f4e2m1fn[3]{0} all-gather(%x), "
                "replica_groups={{0,1}}, dimensions={0}\n")
        inv = H.collective_inventory(text, world_size=2)
        assert inv["all-gather"]["raw_bytes"] == pytest.approx(2.0)


class TestAliasAndEntryParsing:
    HEADER = (
        "HloModule jit_round_fn, input_output_alias={ {0}: (0, {}, "
        "may-alias), {1}: (2, {}, must-alias), {2, 0}: (3, {1}) }, "
        "entry_computation_layout={(f32[32,16])->f32[]}\n"
        "\n"
        "ENTRY %main.42 (Arg_0.1: f32[32,16], Arg_1.2: f32[32,16], "
        "Arg_2.3: u32[64], Arg_3.4: s32[]) -> (f32[32,16], f32[]) {\n"
        "  ROOT %r = f32[] constant(0)\n"
        "}\n")

    def test_alias_entries(self):
        aliases = H.parse_input_output_aliases(self.HEADER)
        assert len(aliases) == 3
        assert aliases[0] == {"output_index": (0,), "param_number": 0,
                              "param_index": (), "kind": "may-alias"}
        assert aliases[1]["param_number"] == 2
        assert aliases[1]["kind"] == "must-alias"
        # Nested param index (tuple-typed parameter leaf).
        assert aliases[2]["output_index"] == (2, 0)
        assert aliases[2]["param_index"] == (1,)

    def test_no_alias_header(self):
        assert H.parse_input_output_aliases("HloModule bare\n") == []

    def test_entry_parameters(self):
        params = H.entry_parameters(self.HEADER)
        assert params == [
            ("Arg_0.1", "f32", (32, 16)),
            ("Arg_1.2", "f32", (32, 16)),
            ("Arg_2.3", "u32", (64,)),
            ("Arg_3.4", "s32", ()),
        ]


class TestHostAndDtypeScans:
    def test_count_dtype_refs(self):
        text = "  %a = f64[4]{0} add(%x, %y)\n  %b = f32[4]{0} copy(%a)\n"
        assert H.count_dtype_refs(text, "f64") == 1
        assert H.count_dtype_refs(text, "c128") == 0

    def test_host_transfer_sites(self):
        text = (
            "  %o = token[] outfeed(%x, %tok)\n"
            '  %cc = f32[2]{0} custom-call(%x), '
            'custom_call_target="xla_python_cpu_callback"\n'
            '  %f = f32[2]{0} fusion(%x), metadata={op_name="outfeed"}\n')
        assert H.count_host_transfer_ops(text) == 2


# ---------------------------------------------------------------------------
# AST lint
# ---------------------------------------------------------------------------


class TestAstLint:
    SCOPES = {"m.py": ("traced",)}

    def _codes(self, src, scopes=None):
        return [f.code for f in astlint.lint_source(
            src, "m.py", scopes=scopes or self.SCOPES)]

    def test_repo_is_clean(self):
        findings = astlint.lint_repo()
        assert findings == [], [f"{f.path}:{f.line} {f.code}"
                                for f in findings]

    def test_tc101_numpy_call(self):
        src = "def traced(x):\n    return np.sum(x)\n"
        assert self._codes(src) == ["TC101"]

    def test_tc102_item(self):
        src = "def traced(x):\n    return x.sum().item()\n"
        assert self._codes(src) == ["TC102"]

    def test_tc103_float_coercion(self):
        src = "def traced(x):\n    return float(jnp.sum(x))\n"
        assert self._codes(src) == ["TC103"]

    def test_tc104_python_branch(self):
        src = ("def traced(x):\n"
               "    if jnp.any(x > 0):\n"
               "        return x\n"
               "    return -x\n")
        assert self._codes(src) == ["TC104"]

    def test_pragma_exempts_the_line(self):
        src = ("def traced(shape):\n"
               "    return int(np.prod(shape))  # tracecheck: ok\n")
        assert self._codes(src) == []

    def test_pragma_on_other_line_does_not_exempt(self):
        src = ("def traced(shape):\n"
               "    # tracecheck: ok\n"
               "    return int(np.prod(shape))\n")
        assert self._codes(src) == ["TC101"]

    def test_nested_function_inherits_traced_scope(self):
        src = ("def traced(x):\n"
               "    def inner(y):\n"
               "        return np.sum(y)\n"
               "    return inner(x)\n")
        assert self._codes(src) == ["TC101"]

    def test_nested_lambda_inherits_traced_scope(self):
        src = ("def traced(xs):\n"
               "    return jax.tree.map(lambda y: np.abs(y), xs)\n")
        assert self._codes(src) == ["TC101"]

    def test_untraced_function_ignored(self):
        src = "def helper(x):\n    return np.sum(x)\n"
        assert self._codes(src) == []

    def test_module_level_lambda_ignored(self):
        src = "f = lambda x: np.sum(x)\n"
        assert self._codes(src) == []

    def test_unregistered_module_not_linted(self):
        src = "def traced(x):\n    return np.sum(x)\n"
        assert astlint.lint_source(src, "other.py",
                                   scopes=self.SCOPES) == []

    def test_missing_registered_module(self, tmp_path):
        findings = astlint.lint_repo(
            src_root=tmp_path, scopes={"ghost.py": "*"})
        assert [f.code for f in findings] == ["TC100"]

    def test_star_scope_lints_every_function(self):
        src = "def anything(x):\n    return np.sum(x)\n"
        assert self._codes(src, scopes={"m.py": "*"}) == ["TC101"]


# ---------------------------------------------------------------------------
# Rule engine over cheap jaxpr-only artifacts (tier-1 op-count gate)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def jaxpr_arts():
    keys = (
        ConfigKey("dense", "flat", "sync", "uniform", 1),
        ConfigKey("compact", "flat", "sync", "uniform", 1),
        ConfigKey("dense", "tree", "sync", "uniform", 1),
        ConfigKey("compact", "flat", "async", "ragged", 1),
    )
    return {k.name: build_artifact(k, compile=False) for k in keys}


def _by_rule(art):
    return {r.rule: r for r in evaluate(art)}


class TestRuleEngineJaxpr:
    def test_flat_rounds_have_two_fused_passes(self, jaxpr_arts):
        for name in ("dense-flat-sync-uniform-1d",
                     "compact-flat-sync-uniform-1d",
                     "compact-flat-async-ragged-1d"):
            res = _by_rule(jaxpr_arts[name])["fused-admm-pass"]
            assert res.status == "pass", res.violations
            assert res.metrics["pallas_call"] == 2

    def test_tree_round_is_pallas_free(self, jaxpr_arts):
        res = _by_rule(
            jaxpr_arts["dense-tree-sync-uniform-1d"])["fused-admm-pass"]
        assert res.status == "pass", res.violations
        assert res.metrics["pallas_call"] == 0

    def test_dense_sweep_budget_is_one(self, jaxpr_arts):
        res = _by_rule(
            jaxpr_arts["dense-flat-sync-uniform-1d"])["no-full-width-sweeps"]
        assert res.status == "pass", res.violations
        assert res.metrics["full_width_sweeps"] <= 1

    def test_compact_round_has_no_full_width_sweeps(self, jaxpr_arts):
        for name in ("compact-flat-sync-uniform-1d",
                     "compact-flat-async-ragged-1d"):
            res = _by_rule(jaxpr_arts[name])["no-full-width-sweeps"]
            assert res.status == "pass", res.violations
            assert res.metrics["full_width_sweeps"] == 0

    def test_jaxpr_rules_all_green(self, jaxpr_arts):
        for name, art in jaxpr_arts.items():
            for res in evaluate(art):
                assert res.status != "fail", (name, res.rule,
                                              res.violations)

    def test_compiled_only_rules_skip_without_hlo(self, jaxpr_arts):
        by_rule = _by_rule(jaxpr_arts["dense-flat-sync-uniform-1d"])
        assert by_rule["donated-state-aliases"].status == "skip"
        assert by_rule["collective-budget"].status == "skip"

    def test_matrices_are_consistent(self):
        # 48 uncompressed ({dense,compact}×{flat,tree}×{sync,async,
        # serve}×{uniform,ragged}×{1,2}d) + 11 compressed-consensus
        # legs (analysis/artifacts._compress_matrix) + 4 host-backend
        # legs (analysis/artifacts._host_matrix).
        assert len(FULL_MATRIX) == 48 + 11 + 4
        assert sum(k.compress != "none" for k in FULL_MATRIX) == 11 + 2
        assert sum(k.backend == "host" for k in FULL_MATRIX) == 4
        assert sum(k.compress != "none" for k in FAST_MATRIX) == 3
        assert sum(k.backend == "host" for k in FAST_MATRIX) == 2
        assert set(FAST_MATRIX) <= set(FULL_MATRIX)
        names = [k.name for k in FULL_MATRIX]
        assert len(names) == len(set(names))

    def test_host_leg_names_are_suffixed(self):
        key = ConfigKey("compact", "flat", "sync", "uniform", 1,
                        "none", "host")
        assert key.name == "compact-flat-sync-uniform-1d-host"
        assert not key.kernels_on  # kernel policy is device-only


class TestRuleEngineHostLeg:
    """The host-backend artifact is the streamed solve program: it
    must carry zero (N, D) ops, zero staged transfers, and a planned
    row stream inside the 8·C·D·4 B budget."""

    @pytest.fixture(scope="class")
    def host_art(self):
        return build_artifact(
            ConfigKey("compact", "flat", "sync", "uniform", 1,
                      "none", "host"), compile=False)

    def test_transfer_budget_green_with_headroom(self, host_art):
        res = {r.rule: r for r in evaluate(host_art)}[
            "host-transfer-budget"]
        assert res.status == "pass", res.violations
        assert res.metrics["backend"] == "host"
        # 5·C·D·4 planned vs 8·C·D·4 allowed.
        assert (res.metrics["planned_row_stream_bytes"]
                == 5 * host_art.capacity * host_art.dim * 4)
        assert (res.metrics["planned_row_stream_bytes"]
                <= res.metrics["row_stream_budget"])

    def test_solve_program_is_working_set_width(self, host_art):
        res = {r.rule: r for r in evaluate(host_art)}[
            "no-full-width-sweeps"]
        assert res.status == "pass", res.violations
        assert res.metrics["full_width_sweeps"] == 0
        assert res.metrics["budget"] == 0

    def test_all_rules_green(self, host_art):
        for res in evaluate(host_art):
            assert res.status != "fail", (res.rule, res.violations)


# ---------------------------------------------------------------------------
# Baseline compare gate
# ---------------------------------------------------------------------------


def _report():
    return {
        "_env": "jax=x;backend=cpu;machine=test",
        "_matrix": "fast",
        "lint": {"status": "pass", "findings": []},
        "exec": {"single-trace": {"status": "pass", "violations": [],
                                  "metrics": {"traces": 1}}},
        "configs": {
            "dense-flat-sync-uniform-1d": {
                "fused-admm-pass": {
                    "status": "pass", "violations": [],
                    "metrics": {"pallas_call": 2, "expected": 2}},
                "collective-budget": {
                    "status": "skip", "violations": [],
                    "metrics": {"skipped": "single device"}},
            },
            "dense-flat-sync-uniform-2d": {
                "collective-budget": {
                    "status": "pass", "violations": [],
                    "metrics": {"all-reduce": {"count": 3, "bytes": 340.0},
                                "budget_bytes": 736.0}},
            },
            "skipped-cfg": {"_status": "skip", "_reason": "needs 4 devices"},
        },
    }


class TestCompareBaseline:
    def test_identical_reports_have_no_regressions(self):
        base = _report()
        assert compare_to_baseline(base, copy.deepcopy(base)) == []

    def test_status_regression(self):
        fresh = _report()
        cfg = fresh["configs"]["dense-flat-sync-uniform-1d"]
        cfg["fused-admm-pass"]["status"] = "fail"
        regs = compare_to_baseline(_report(), fresh)
        assert any("pass → fail" in r for r in regs)

    def test_pallas_count_drift(self):
        fresh = _report()
        cfg = fresh["configs"]["dense-flat-sync-uniform-1d"]
        cfg["fused-admm-pass"]["metrics"]["pallas_call"] = 3
        regs = compare_to_baseline(_report(), fresh)
        assert any("pallas_call 2 → 3" in r for r in regs)

    def test_allreduce_growth_beyond_drift(self):
        fresh = _report()
        cfg = fresh["configs"]["dense-flat-sync-uniform-2d"]
        cfg["collective-budget"]["metrics"]["all-reduce"]["bytes"] = 500.0
        regs = compare_to_baseline(_report(), fresh)
        assert any("all-reduce bytes" in r for r in regs)

    def test_allreduce_growth_within_drift_ok(self):
        fresh = _report()
        cfg = fresh["configs"]["dense-flat-sync-uniform-2d"]
        cfg["collective-budget"]["metrics"]["all-reduce"]["bytes"] = 380.0
        assert compare_to_baseline(_report(), fresh) == []

    def test_vanished_configuration(self):
        fresh = _report()
        del fresh["configs"]["dense-flat-sync-uniform-1d"]
        regs = compare_to_baseline(_report(), fresh)
        assert any("vanished" in r for r in regs)

    def test_vanished_rule(self):
        fresh = _report()
        del fresh["configs"]["dense-flat-sync-uniform-1d"]["fused-admm-pass"]
        regs = compare_to_baseline(_report(), fresh)
        assert any("rule vanished" in r for r in regs)

    def test_baseline_skip_configs_ignored(self):
        fresh = _report()
        del fresh["configs"]["skipped-cfg"]
        assert compare_to_baseline(_report(), fresh) == []

    def test_report_failures_collects_all_layers(self):
        rep = _report()
        assert report_failures(rep) == []
        rep["lint"] = {"status": "fail", "findings": [{"code": "TC101"}]}
        rep["exec"]["single-trace"]["status"] = "fail"
        cfg = rep["configs"]["dense-flat-sync-uniform-1d"]
        cfg["fused-admm-pass"]["status"] = "fail"
        assert len(report_failures(rep)) == 3

    def test_committed_baseline_is_loadable(self):
        import json
        import pathlib
        path = (pathlib.Path(__file__).resolve().parents[1]
                / "benchmarks" / "baselines" / "ANALYSIS.json")
        base = json.loads(path.read_text())
        assert base["_matrix"] == "fast"
        assert set(base["configs"]) == {k.name for k in FAST_MATRIX}
        assert compare_to_baseline(base, copy.deepcopy(base)) == []
