"""Compressed-consensus invariants (core/compress.py).

Property layer (real hypothesis when installed, the executing
mini-hypothesis fallback otherwise — fixed ``--hypothesis-seed`` on CI)
plus engine-level locks:

* **quantizer round-trip bounds** — int8 error ≤ half a scale step
  (blockmax/(2·127)) per coordinate, bf16 error ≤ 2⁻⁸·|x|, zeros are
  exact;
* **error-feedback conservation** — at every round (hence every
  prefix), Σ residual-change + transmitted total == Σ true deltas, for
  both the consensus (ADMM) and the masked participant (FedAvg) forms;
* **``compress="none"`` bit-parity** — the explicit "none" config runs
  the identical program as the default config (no residual state, no
  new collectives) across {dense, compact, staleness, serve} on one
  device and, via a subprocess 2-device mesh, under the clients mesh
  (the committed golden traces separately pin "none" ≡ the pre-feature
  engine bit for bit; the int8 golden lives in test_golden_trace.py);
* **EF tracking** — compressed final ω stays close to the fp32 ω on
  the same fixed-seed run (the convergence claim the comm bench
  gates);
* **state plumbing** — the (N, D) residual checkpoints through the
  dtype-sidecar store, shards client-stacked under the mesh, and the
  tree layout is rejected loudly.
"""
import dataclasses
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import ControllerConfig, FLConfig, init_state, \
    make_flat_spec, make_round_fn
from repro.core.compress import (
    block_layout,
    check_mode,
    consensus_wire_bytes,
    ef_consensus,
    ef_participant_mean,
    init_residual,
    int8_dequantize,
    int8_quantize,
    quantize_dequantize,
)
from repro.data import make_least_squares

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _rng_mat(seed, n, d, scale=1.0):
    return (np.random.default_rng(seed).standard_normal((n, d))
            .astype(np.float32) * scale)


class TestQuantizer:
    @settings(max_examples=20, deadline=None)
    @given(n=st.integers(1, 12), d=st.integers(1, 300),
           block=st.integers(1, 300), seed=st.integers(0, 2**31 - 1),
           scale=st.floats(1e-3, 1e3))
    def test_int8_roundtrip_bound(self, n, d, block, seed, scale):
        x = _rng_mat(seed, n, d, scale)
        codes, scales = int8_quantize(jnp.asarray(x), block=block)
        back = np.asarray(int8_dequantize(codes, scales, d))
        nb, b = block_layout(d, block)
        assert codes.shape == (n, nb, b) and scales.shape == (n, nb)
        err = np.abs(back - x)
        pad = nb * b - d
        xb = np.pad(x, [(0, 0), (0, pad)]).reshape(n, nb, b)
        # Half a scale step per coordinate: blockmax/(2·127), plus a
        # small fp32 epsilon for the scale division itself.
        bound = (np.abs(xb).max(axis=-1, keepdims=True) / (2 * 127)
                 * (1 + 1e-5) + 1e-7)
        errb = np.pad(err, [(0, 0), (0, pad)]).reshape(n, nb, b)
        assert (errb <= bound).all()

    @settings(max_examples=20, deadline=None)
    @given(n=st.integers(1, 8), d=st.integers(1, 64),
           seed=st.integers(0, 2**31 - 1))
    def test_bf16_roundtrip_relative_bound(self, n, d, seed):
        x = _rng_mat(seed, n, d)
        back = np.asarray(quantize_dequantize(jnp.asarray(x), "bf16"))
        # bf16 keeps 8 significant bits → relative error ≤ 2⁻⁸.
        assert (np.abs(back - x) <= np.abs(x) * 2.0**-8 + 1e-30).all()

    def test_zero_vector_is_exact_and_none_is_identity(self):
        z = jnp.zeros((3, 40), jnp.float32)
        codes, scales = int8_quantize(z, block=16)
        assert not np.asarray(codes).any() and not np.asarray(scales).any()
        assert not np.asarray(int8_dequantize(codes, scales, 40)).any()
        x = jnp.asarray(_rng_mat(0, 2, 7))
        assert np.array_equal(np.asarray(quantize_dequantize(x, "none")),
                              np.asarray(x))

    def test_block_layout_clamps_to_dim(self):
        assert block_layout(16, 256) == (1, 16)
        assert block_layout(300, 128) == (3, 128)
        assert block_layout(5, 1) == (5, 1)

    def test_check_mode_rejects_unknown(self):
        with pytest.raises(ValueError, match="consensus_compress"):
            check_mode("fp8")


class TestConservation:
    """Σ residual + Σ transmitted == Σ true deltas, at every prefix.

    Per round: Σᵢ eᵢ⁺ + T == Σᵢ eᵢ + Σ_{i∈mask} (zᵢ − ω), where the
    transmitted total T is recovered exactly as (ω⁺ − ω)·denom.
    Holding at every round makes every prefix telescope.
    """

    @settings(max_examples=10, deadline=None)
    @given(mode=st.sampled_from(["none", "bf16", "int8"]),
           n=st.integers(2, 12), d=st.integers(3, 40),
           seed=st.integers(0, 2**31 - 1))
    def test_consensus_prefix_conservation(self, mode, n, d, seed):
        omega = jnp.zeros((d,), jnp.float32)
        resid = init_residual(n, d)
        for r in range(5):
            z = jnp.asarray(_rng_mat(seed + r, n, d))
            omega_new, resid_new = ef_consensus(
                z, omega, resid, mode=mode, block=8)
            lhs = (np.asarray(resid_new, np.float64).sum(axis=0)
                   + np.asarray(omega_new - omega, np.float64) * n)
            rhs = (np.asarray(resid, np.float64).sum(axis=0)
                   + np.asarray(z - omega[None, :],
                                np.float64).sum(axis=0))
            np.testing.assert_allclose(lhs, rhs, rtol=2e-4, atol=2e-4)
            omega, resid = omega_new, resid_new

    @settings(max_examples=10, deadline=None)
    @given(mode=st.sampled_from(["bf16", "int8"]),
           n=st.integers(2, 12), d=st.integers(3, 40),
           seed=st.integers(0, 2**31 - 1))
    def test_participant_prefix_conservation(self, mode, n, d, seed):
        rng = np.random.default_rng(seed ^ 0xC0FFEE)
        omega = jnp.zeros((d,), jnp.float32)
        resid = init_residual(n, d)
        for r in range(5):
            z = jnp.asarray(_rng_mat(seed + r, n, d))
            mask = rng.random(n) < 0.5
            m = int(mask.sum())
            omega_new, resid_new = ef_participant_mean(
                z, jnp.asarray(mask), omega, resid,
                jnp.int32(m), mode=mode, block=8)
            lhs = (np.asarray(resid_new, np.float64).sum(axis=0)
                   + np.asarray(omega_new - omega, np.float64) * max(m, 1))
            rhs = (np.asarray(resid, np.float64).sum(axis=0)
                   + np.asarray(z - omega[None, :],
                                np.float64)[mask].sum(axis=0))
            np.testing.assert_allclose(lhs, rhs, rtol=2e-4, atol=2e-4)
            # Non-transmitters keep their residual rows untouched.
            np.testing.assert_array_equal(
                np.asarray(resid_new)[~mask], np.asarray(resid)[~mask])
            omega, resid = omega_new, resid_new

    def test_zero_committed_leaves_omega_and_residual(self):
        n, d = 6, 9
        omega = jnp.asarray(np.linspace(-1, 1, d), jnp.float32)
        resid = jnp.asarray(_rng_mat(7, n, d) * 0.01)
        z = jnp.asarray(_rng_mat(8, n, d))
        o2, r2 = ef_participant_mean(
            z, jnp.zeros((n,), bool), omega, resid, jnp.int32(0),
            mode="int8")
        np.testing.assert_array_equal(np.asarray(o2), np.asarray(omega))
        np.testing.assert_array_equal(np.asarray(r2), np.asarray(resid))


def _variant_cfgs(n):
    base = FLConfig(algorithm="fedback", n_clients=n, participation=0.5,
                    rho=1.0, lr=0.1, momentum=0.0, epochs=1,
                    batch_size=4, seed=0,
                    controller=ControllerConfig(K=0.5, alpha=0.9))
    return {
        "dense": base,
        "compact": dataclasses.replace(
            base, compact=True, participation=0.25, capacity_slack=1.5),
        "staleness": dataclasses.replace(
            base, compact=True, participation=0.25, capacity_slack=1.5,
            max_staleness=2),
        "serve": dataclasses.replace(
            base, compact=True, participation=0.25, capacity_slack=1.5),
    }


def _run_variant(cfg, data, params0, loss_fn, spec, *, rounds=6,
                 mesh=None, serve=False):
    state = init_state(cfg, params0, spec=spec, mesh=mesh)
    round_fn = make_round_fn(cfg, loss_fn, data, spec=spec, mesh=mesh,
                             arrivals_arg=serve)
    events, omegas = [], None
    rng = np.random.default_rng(123)
    for _ in range(rounds):
        if serve:
            arrivals = jnp.asarray(rng.random(cfg.n_clients) < 0.7)
            state, m = round_fn(state, arrivals)
        else:
            state, m = round_fn(state)
        events.append(np.asarray(m.events))
    omegas = np.asarray(state.omega, np.float32)
    return np.stack(events), omegas, state


class TestNoneBitParity:
    """consensus_compress="none" is the identical program as the
    default config — no residual state, same bits — on every path."""

    @pytest.mark.parametrize("variant",
                             ["dense", "compact", "staleness", "serve"])
    def test_single_device(self, variant):
        n = 16
        data, params0, loss_fn = make_least_squares(n, 8, 5)
        spec = make_flat_spec(params0)
        cfg = _variant_cfgs(n)[variant]
        serve = variant == "serve"
        ev_a, om_a, st_a = _run_variant(cfg, data, params0, loss_fn,
                                        spec, serve=serve)
        explicit = dataclasses.replace(cfg, consensus_compress="none")
        ev_b, om_b, st_b = _run_variant(explicit, data, params0, loss_fn,
                                        spec, serve=serve)
        assert st_a.comm is None and st_b.comm is None
        np.testing.assert_array_equal(ev_a, ev_b)
        assert om_a.tobytes() == om_b.tobytes()


_MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import dataclasses, json
import jax, jax.numpy as jnp, numpy as np
from repro.core import ControllerConfig, FLConfig, init_state, \
    make_flat_spec, make_round_fn
from repro.core.compress import ef_consensus, init_residual
from repro.data import make_least_squares
from repro.sharding.clients import make_client_mesh

N = 8
data, p0, ls = make_least_squares(N, 8, 5)
spec = make_flat_spec(p0)
base = FLConfig(algorithm="fedback", n_clients=N, participation=0.5,
                rho=1.0, lr=0.1, momentum=0.0, epochs=1, batch_size=4,
                seed=0, controller=ControllerConfig(K=0.5, alpha=0.9))
mesh = make_client_mesh(2)
variants = {
    "dense": base,
    "compact": dataclasses.replace(base, compact=True,
                                   participation=0.25,
                                   capacity_slack=1.5),
    "staleness": dataclasses.replace(base, compact=True,
                                     participation=0.25,
                                     capacity_slack=1.5,
                                     max_staleness=2),
}
out = {}
for vname, vcfg in variants.items():
    recs = {}
    for tag, c in (("default", vcfg),
                   ("none", dataclasses.replace(
                       vcfg, consensus_compress="none"))):
        state = init_state(c, p0, spec=spec, mesh=mesh)
        rf = make_round_fn(c, ls, data, spec=spec, mesh=mesh)
        evs = []
        for _ in range(6):
            state, m = rf(state)
            evs.append(np.asarray(m.events).astype(int).tolist())
        recs[tag] = {"events": evs,
                     "omega_hex": np.asarray(state.omega,
                                             np.float32).tobytes().hex(),
                     "comm_none": state.comm is None}
    out[vname] = recs

# int8 under the mesh: comm shards client-stacked; the round runs.
c8 = dataclasses.replace(base, consensus_compress="int8")
state = init_state(c8, p0, spec=spec, mesh=mesh)
rf = make_round_fn(c8, ls, data, spec=spec, mesh=mesh)
for _ in range(4):
    state, m = rf(state)
out["int8_mesh"] = {
    "comm_shape": list(state.comm.shape),
    "comm_sharding": str(state.comm.sharding.spec),
    "omega_finite": bool(jnp.isfinite(state.omega).all()),
}

# Distributed EF conservation: the shard-local wire error folds back
# into the transmitting rows' residuals across BOTH devices.
rng = np.random.default_rng(0)
z = jnp.asarray(rng.standard_normal((N, 12)).astype(np.float32))
omega = jnp.zeros((12,), jnp.float32)
resid = init_residual(N, 12)
o2, r2 = ef_consensus(z, omega, resid, mode="int8", block=4, mesh=mesh)
lhs = (np.asarray(r2, np.float64).sum(axis=0)
       + np.asarray(o2 - omega, np.float64) * N)
rhs = np.asarray(z, np.float64).sum(axis=0)
out["mesh_conservation_max_err"] = float(np.abs(lhs - rhs).max())
print("RESULT:" + json.dumps(out))
"""


@pytest.mark.skipif(os.environ.get("REPRO_SKIP_SUBPROCESS") == "1",
                    reason="subprocess legs disabled")
class TestTwoDeviceParity:
    @pytest.fixture(scope="class")
    def result(self):
        env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
        out = subprocess.run([sys.executable, "-c", _MESH_SCRIPT],
                             env=env, capture_output=True, text=True,
                             timeout=560, cwd=REPO)
        assert out.returncode == 0, out.stderr[-3000:]
        line = [ln for ln in out.stdout.splitlines()
                if ln.startswith("RESULT:")]
        return json.loads(line[-1][len("RESULT:"):])

    @pytest.mark.parametrize("variant", ["dense", "compact", "staleness"])
    def test_none_bit_parity_under_mesh(self, result, variant):
        rec = result[variant]
        assert rec["default"]["comm_none"] and rec["none"]["comm_none"]
        assert rec["default"]["events"] == rec["none"]["events"]
        assert rec["default"]["omega_hex"] == rec["none"]["omega_hex"]

    def test_int8_residual_client_sharded(self, result):
        rec = result["int8_mesh"]
        assert rec["comm_shape"] == [8, 5]
        assert "clients" in rec["comm_sharding"]
        assert rec["omega_finite"]

    def test_mesh_conservation(self, result):
        assert result["mesh_conservation_max_err"] < 2e-4


class TestEngineIntegration:
    def test_tree_layout_rejected(self):
        n = 8
        data, params0, loss_fn = make_least_squares(n, 8, 5)
        cfg = dataclasses.replace(_variant_cfgs(n)["dense"],
                                  consensus_compress="int8")
        with pytest.raises(ValueError, match="flat"):
            init_state(cfg, params0)  # no spec= → tree layout
        spec = make_flat_spec(params0)
        state = init_state(cfg, params0, spec=spec)
        assert state.comm.shape == (n, spec.dim)
        with pytest.raises(ValueError, match="flat"):
            make_round_fn(cfg, loss_fn, data)  # no spec= → tree layout

    @pytest.mark.parametrize("mode", ["bf16", "int8"])
    def test_compressed_tracks_fp32_omega(self, mode):
        n, rounds = 16, 20
        data, params0, loss_fn = make_least_squares(n, 8, 5)
        spec = make_flat_spec(params0)
        base = _variant_cfgs(n)["compact"]
        omegas = {}
        for m in ("none", mode):
            cfg = dataclasses.replace(base, consensus_compress=m)
            state = init_state(cfg, params0, spec=spec)
            rf = make_round_fn(cfg, loss_fn, data, spec=spec)
            for _ in range(rounds):
                state, _ = rf(state)
            omegas[m] = np.asarray(state.omega, np.float64)
        scale = max(float(np.abs(omegas["none"]).max()), 1e-6)
        drift = float(np.abs(omegas[mode] - omegas["none"]).max()) / scale
        assert drift < 5e-2, \
            f"{mode} ω drifted {drift:.3%} from the fp32 trajectory"

    def test_residual_checkpoint_roundtrip(self, tmp_path):
        from repro.checkpoint.store import load_checkpoint, \
            save_checkpoint
        n = 8
        data, params0, loss_fn = make_least_squares(n, 8, 5)
        spec = make_flat_spec(params0)
        cfg = dataclasses.replace(_variant_cfgs(n)["dense"],
                                  consensus_compress="int8")
        state = init_state(cfg, params0, spec=spec)
        rf = make_round_fn(cfg, loss_fn, data, spec=spec)
        for _ in range(3):
            state, _ = rf(state)
        assert np.abs(np.asarray(state.comm)).max() > 0  # EF is live
        path = save_checkpoint(str(tmp_path), 3, state)
        template = init_state(cfg, params0, spec=spec)
        restored = load_checkpoint(path, template)
        np.testing.assert_array_equal(np.asarray(restored.comm),
                                      np.asarray(state.comm))
        assert restored.comm.dtype == jnp.float32

    def test_wire_bytes_model(self):
        none = consensus_wire_bytes(64, mode="none", world_size=2)
        i8 = consensus_wire_bytes(64, mode="int8", world_size=2,
                                  block=256)
        b16 = consensus_wire_bytes(64, mode="bf16", world_size=2)
        assert i8["payload_link_bytes"] == none["payload_link_bytes"] / 4
        assert i8["payload_link_bytes"] / none["payload_link_bytes"] \
            <= 0.3
        assert b16["payload_link_bytes"] == none["payload_link_bytes"] / 2
        assert i8["overhead_link_bytes"] > 0  # the shared-scale MAX term
        # Single device: no cross-device wire, uplink still compresses.
        solo = consensus_wire_bytes(64, mode="int8", world_size=1)
        assert solo["total_link_bytes"] == 0.0
        assert solo["uplink_bytes_per_client"] < 64 * 4
