"""Client-sharded FedBack + one-program sweeps, end to end.

Two capabilities of the device-mesh round engine in one script:

1. the same round program running single-device and client-sharded
   (event decisions are bit-identical; ω agrees to fp32 tolerance), and
2. a (seeds × controller gains) sweep compiled as ONE XLA program.

Runs on CPU by forcing host devices, so it works anywhere:

    python examples/sharded_sweep.py        # PYTHONPATH=src if no install
"""
import os

if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=8"
                               ).strip()

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import ControllerConfig, FLConfig, init_state, \
    make_round_fn  # noqa: E402
from repro.data import make_least_squares  # noqa: E402
from repro.launch.sweep import run_sweep  # noqa: E402
from repro.sharding.clients import make_client_mesh  # noqa: E402


def main():
    n = 64
    data, params0, loss_fn = make_least_squares(n)
    cfg = FLConfig(algorithm="fedback", n_clients=n, participation=0.3,
                   rho=1.0, lr=0.1, momentum=0.0, epochs=2, batch_size=8,
                   controller=ControllerConfig(K=0.5, alpha=0.9))

    # --- 1. single-device vs client-sharded: same program, same events --
    mesh = make_client_mesh(8)
    print(f"devices: {len(jax.devices())}, client mesh: {mesh}")
    runs = {}
    for name, m in (("single", None), ("sharded", mesh)):
        state = init_state(cfg, params0, mesh=m)
        round_fn = make_round_fn(cfg, loss_fn, data, mesh=m)
        events = []
        for _ in range(20):
            state, met = round_fn(state)
            events.append(np.asarray(met.events))
        runs[name] = (np.stack(events), np.asarray(state.omega["theta"]))
    ev_equal = bool((runs["single"][0] == runs["sharded"][0]).all())
    omega_gap = float(np.abs(runs["single"][1] - runs["sharded"][1]).max())
    print(f"events bit-identical: {ev_equal}   max |Δω|: {omega_gap:.2e}")

    # --- 2. a whole ablation row as one compiled program ----------------
    grid_runs, final, hist = run_sweep(
        cfg, loss_fn, data, params0, rounds=60,
        seeds=(0, 1, 2, 3), gains=(0.25, 1.0))
    rates = np.asarray(jnp.mean(hist.events.astype(jnp.float32), axis=(0, 2)))
    print("\nseed  K     realized participation (target 0.3)")
    for (seed, k, _), rate in zip(grid_runs, rates, strict=True):
        print(f"{seed:4d}  {k:4.2f}  {rate:.3f}")


if __name__ == "__main__":
    main()
