"""Serve a small LM with batched requests: prefill + decode loop.

Builds a ~45M-parameter granite-family decoder, prefts a batch of
prompts, then decodes greedily — exercising the same
prefill/decode_step paths the 32k dry-runs lower at production scale.

    PYTHONPATH=src python examples/serve_lm.py --batch 8 --prompt-len 64 \\
        --new-tokens 32
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.api import build_model, param_count


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--arch", default="granite-3-2b")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced(
        num_layers=4, d_model=512, num_heads=8, num_kv_heads=4, head_dim=64,
        d_ff=1536, vocab_size=8192, kv_block=64)
    model = build_model(cfg)
    print(f"model: {cfg.name} ({param_count(cfg)/1e6:.1f}M params)")

    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size,
                     (args.batch, args.prompt_len)), jnp.int32)
    max_seq = args.prompt_len + args.new_tokens

    prefill = jax.jit(lambda p, b: model.prefill(p, b, max_seq))
    decode = jax.jit(model.decode_step)

    t0 = time.time()
    logits, cache = prefill(params, {"tokens": prompts})
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]

    generated = [tok]
    t0 = time.time()
    for _ in range(args.new_tokens - 1):
        logits, cache = decode(params, tok, cache)
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        generated.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    out = jnp.concatenate(generated, axis=1)
    n_tok = args.batch * (args.new_tokens - 1)
    print(f"prefill: {args.batch}×{args.prompt_len} tokens "
          f"in {t_prefill*1e3:.0f} ms "
          f"({args.batch*args.prompt_len/t_prefill:.0f} tok/s)")
    print(f"decode:  {n_tok} tokens in {t_decode*1e3:.0f} ms "
          f"({n_tok/max(t_decode,1e-9):.0f} tok/s)")
    print(f"sample continuation (request 0): {out[0, :16].tolist()}")


if __name__ == "__main__":
    main()
