"""End-to-end federated image-classification driver (paper §5 setup).

Runs any of the four algorithms on the synthetic MNIST/CIFAR suites
with the paper's hyper-parameters, checkpointing, and an events-to-
accuracy report:

    PYTHONPATH=src python examples/federated_image.py \\
        --dataset mnist --algorithm fedback --rate 0.1 --rounds 300
"""
import argparse
import os

import jax

from repro.checkpoint import latest_checkpoint, load_checkpoint, \
    save_checkpoint
from repro.configs import paper_cifar, paper_mnist
from repro.core import init_state, make_eval_fn, make_round_fn
from repro.data import federated_arrays, make_synthetic_cifar, \
    make_synthetic_mnist
from repro.models.mlp import (
    cnn_logits,
    init_cnn,
    init_mlp,
    make_loss_and_acc_fn,
    make_loss_fn,
    mlp_logits,
)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dataset", default="mnist",
                    choices=["mnist", "cifar"])
    ap.add_argument("--algorithm", default="fedback",
                    choices=["fedback", "fedadmm", "fedavg", "fedprox",
                             "admm"])
    ap.add_argument("--rate", type=float, default=0.1)
    ap.add_argument("--rounds", type=int, default=300)
    ap.add_argument("--clients", type=int, default=50)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    args = ap.parse_args()

    if args.dataset == "mnist":
        ds = make_synthetic_mnist()
        data, test = federated_arrays(ds, n_clients=args.clients,
                                      scheme="label_shard")
        params0 = init_mlp(jax.random.PRNGKey(0))
        logits = mlp_logits
        cfg = paper_mnist.fl_config(args.algorithm, args.rate,
                                    n_clients=args.clients)
        target = paper_mnist.TARGET_ACCURACY
    else:
        ds = make_synthetic_cifar()
        data, test = federated_arrays(ds, n_clients=args.clients,
                                      scheme="dirichlet", beta=0.5)
        params0 = init_cnn(jax.random.PRNGKey(0))
        logits = cnn_logits
        cfg = paper_cifar.fl_config(args.algorithm, args.rate,
                                    n_clients=args.clients)
        target = paper_cifar.TARGET_ACCURACY

    state = init_state(cfg, params0)
    start = 0
    if args.ckpt_dir:
        ck = latest_checkpoint(args.ckpt_dir)
        if ck:
            state = load_checkpoint(ck, state)
            start = int(os.path.basename(ck).split("_")[1].split(".")[0])
            print(f"resumed from {ck} (round {start})")

    round_fn = make_round_fn(cfg, make_loss_fn(logits), data)
    eval_fn = make_eval_fn(make_loss_and_acc_fn(logits))

    cum_events, reached = 0, None
    for k in range(start, args.rounds):
        state, m = round_fn(state)
        cum_events += int(m.num_events)
        if k % 5 == 0 or k == args.rounds - 1:
            loss, acc = eval_fn(state, test["x"], test["y"])
            if reached is None and float(acc) >= target:
                reached = cum_events
            print(f"round {k:4d} events={int(m.num_events):3d} "
                  f"cum={cum_events:6d} loss={float(loss):.4f} "
                  f"acc={float(acc):.4f}")
        if args.ckpt_dir and k and k % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, k, state)

    print(f"\n{args.algorithm} @ L̄={args.rate}: "
          + (f"reached {target:.0%} after {reached} participation events"
             if reached else f"did not reach {target:.0%} "
             f"in {args.rounds} rounds ({cum_events} events)"))


if __name__ == "__main__":
    main()
