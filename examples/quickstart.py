"""Quickstart: FedBack on synthetic non-iid MNIST in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core import ControllerConfig, FLConfig, init_state, \
    make_eval_fn, make_flat_spec, make_round_fn
from repro.data import federated_arrays, make_synthetic_mnist
from repro.models.mlp import (
    init_mlp,
    make_loss_and_acc_fn,
    make_loss_fn,
    mlp_logits,
)


def main():
    # 20 clients, 2 digits each (pathological non-iid), target rate 20%
    ds = make_synthetic_mnist(n_train=4200, n_test=1000)
    data, test = federated_arrays(ds, n_clients=20, scheme="label_shard")

    cfg = FLConfig(
        algorithm="fedback", n_clients=20, participation=0.2,
        rho=0.01, lr=0.01, epochs=2, batch_size=42,
        compact=True, capacity_slack=1.5,  # solver rows ≤ ⌈slack·L̄·N⌉,
        # overflow carried by the deferral queue (lossless)
        controller=ControllerConfig(K=2.0, alpha=0.9))
    params0 = init_mlp(jax.random.PRNGKey(0))
    # flat (N, D) client-state layout: single-pass per-round algebra
    spec = make_flat_spec(params0)
    state = init_state(cfg, params0, spec=spec)
    round_fn = make_round_fn(cfg, make_loss_fn(mlp_logits), data, spec=spec)
    eval_fn = make_eval_fn(make_loss_and_acc_fn(mlp_logits), spec=spec)

    total_events = 0
    print(f"{'round':>5} {'events':>6} {'cum_events':>10} "
          f"{'mean_delta':>10} {'deferred':>8} {'slack':>6} "
          f"{'accuracy':>8}")
    for k in range(120):
        state, m = round_fn(state)
        total_events += int(m.num_events)
        if k % 10 == 0 or k == 119:
            loss, acc = eval_fn(state, test["x"], test["y"])
            print(f"{k:5d} {int(m.num_events):6d} {total_events:10d} "
                  f"{float(m.delta.mean()):10.3f} "
                  f"{int(m.num_deferred):8d} "
                  f"{float(m.realized_slack):6.2f} {float(acc):8.3f}")
    rate = total_events / (120 * 20)
    print(f"\nrealized participation rate: {rate:.3f} (target 0.2)")
    print(f"deferral queue at exit: {int(m.num_deferred)} "
          f"(lossless carry; see docs/compaction.md)")


if __name__ == "__main__":
    main()
