"""Cross-pod FedBack on a small LM — the distributed engine EXECUTING
(not just lowering) on 8 host devices: mesh (pod=2, data=2, model=2).

Each pod is one silo training a reduced granite-family decoder on its
own (skewed) synthetic token distribution; the ADMM consensus is a real
collective over the pod axis and the integral controller gates pod
participation round by round.

    PYTHONPATH=src python examples/fedback_transformer.py
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.core.controller import ControllerConfig  # noqa: E402
from repro.core.crosspod import (  # noqa: E402
    CrossPodConfig,
    init_cross_pod_state,
    make_cross_pod_round,
)
from repro.models.api import build_model  # noqa: E402
from repro.sharding.actshard import activation_sharding  # noqa: E402
from repro.sharding.specs import param_specs, pod_stacked_specs  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402


def synthetic_tokens(rng, pods, steps, batch, seq, vocab, skew):
    """Per-pod token streams with different unigram skews (non-iid)."""
    out = []
    for i in range(pods):
        logits = skew * rng.standard_normal(vocab)
        p = np.exp(logits - logits.max())
        p /= p.sum()
        out.append(rng.choice(vocab, size=(steps, batch, seq + 1), p=p))
    toks = np.stack(out)  # (pods, steps, batch, seq+1)
    return {"tokens": jnp.asarray(toks[..., :-1], jnp.int32),
            "labels": jnp.asarray(toks[..., 1:], jnp.int32)}


def main():
    mesh = jax.sharding.Mesh(
        np.asarray(jax.devices()[:8]).reshape(2, 2, 2),
        ("pod", "data", "model"))
    cfg = get_config("granite-3-2b").reduced(
        num_layers=2, d_model=128, vocab_size=512, remat=False)
    model = build_model(cfg)

    cp = CrossPodConfig(
        n_pods=2, rho=1e-3, lr=5e-3, local_steps=2,
        controller=ControllerConfig(K=0.05, alpha=0.9, target_rate=0.5))

    def sharded_loss(params, batch):
        with activation_sharding(mesh, "data"):
            return model.loss(params, batch)

    round_fn = make_cross_pod_round(cp, sharded_loss)
    params0 = model.init(jax.random.PRNGKey(0))
    state = init_cross_pod_state(cp, params0)

    pspec = param_specs(jax.eval_shape(lambda: params0), mesh, mode="fsdp")
    pod_pspec = pod_stacked_specs(pspec)
    named = lambda t: jax.tree.map(
        lambda s: NamedSharding(mesh, s), t,
        is_leaf=lambda x: isinstance(x, P))
    state_sh = type(state)(
        theta=named(pod_pspec), lam=named(pod_pspec),
        z_prev=named(pod_pspec),
        ctrl=jax.tree.map(lambda _: NamedSharding(mesh, P()), state.ctrl),
        rng=NamedSharding(mesh, P()), round=NamedSharding(mesh, P()))
    batch_sh = jax.tree.map(
        lambda _: NamedSharding(mesh, P("pod", None, "data", None)),
        {"tokens": 0, "labels": 0})

    step = jax.jit(round_fn, in_shardings=(state_sh, batch_sh),
                   out_shardings=(state_sh, None))

    rng = np.random.default_rng(0)
    state = jax.device_put(state, state_sh)
    print(f"{'round':>5} {'events':>7} {'dist(pod0,pod1)':>22} "
          f"{'delta':>16} {'loss':>8}")
    for k in range(24):
        batch = jax.device_put(
            synthetic_tokens(rng, 2, cp.local_steps, 8, 64,
                             cfg.vocab_size, skew=1.5), batch_sh)
        state, m = step(state, batch)
        d = np.asarray(m.distances)
        dl = np.asarray(m.delta)
        print(f"{k:5d} {np.asarray(m.events).astype(int).tolist()!s:>7} "
              f"[{d[0]:8.3f} {d[1]:8.3f}] [{dl[0]:6.3f} {dl[1]:6.3f}] "
              f"{float(m.train_loss):8.4f}")
    ev = np.asarray(jax.device_get(state.ctrl.event_count))
    print(f"\nper-pod participation over 24 rounds: {ev.tolist()} "
          f"(target rate {cp.controller.target_rate})")


if __name__ == "__main__":
    main()
